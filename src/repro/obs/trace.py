"""Span tracing for the DSI pipeline: nested, attributed, exportable.

A :class:`Tracer` records spans (``storage.read``, ``cache.fill``,
``extract.decode``, ``transform.fused``, ``load.materialize``,
``client.stall``, ``train.step``, ``session.run``, ...) with arbitrary
labels (tenant/session/split/worker), a per-thread parent stack for
nesting, and an injected ``clock=`` (REPRO-C001 style) so duration math
is testable without wall-clock sleeps.

Three ways to record:

  * ``with tracer.span("extract.decode", tenant=t) as sp:`` — the only
    form allowed inside ``src/repro/core/**`` (rule REPRO-S001): the
    context manager guarantees the span closes on every exit path;
  * ``tracer.record(name, t0, t1, **labels)`` — an atomic, already-timed
    span (the worker's transform/load intervals are measured with
    ``perf_counter`` for the metrics anyway; ``record`` reuses those
    endpoints instead of double-clocking);
  * ``tracer.instant(name, **labels)`` — a zero-duration marker
    (``cache.hit`` / ``cache.miss``).

Tracing is **disabled by default**: every traced component takes
``tracer=NULL_TRACER``, whose span handle is a shared singleton — no
allocation, no clock read, no lock (overhead asserted in
``benchmarks/bench_obs.py``).

``chrome_trace()`` exports the span list as Chrome-trace/Perfetto JSON
(complete ``"X"`` events, microsecond timestamps normalized to the
earliest span) so a whole ``run_to_completion`` loads in
https://ui.perfetto.dev — see docs/observability.md.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional


class _TraceLocal(threading.local):
    """Per-thread span stack; ``__init__`` re-runs in every thread that
    touches the tracer, so ``stack`` always exists without the tracer
    ever mutating shared state to create it."""

    def __init__(self):
        self.stack: List[str] = []


class Span:
    """One completed span. ``t0``/``t1`` are in the tracer's clock domain."""

    __slots__ = ("name", "t0", "t1", "labels", "tid", "parent")

    def __init__(self, name: str, t0: float, t1: float,
                 labels: Dict[str, Any], tid: int, parent: Optional[str]):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.labels = labels
        self.tid = tid
        self.parent = parent

    @property
    def duration(self) -> float:
        return self.t1 - self.t0


class _SpanHandle:
    """Context manager returned by ``Tracer.span``: opens on ``__enter__``,
    appends the completed span on ``__exit__``."""

    __slots__ = ("_tracer", "name", "labels", "t0")

    def __init__(self, tracer: "Tracer", name: str, labels: Dict[str, Any]):
        self._tracer = tracer
        self.name = name
        self.labels = labels
        self.t0 = 0.0

    def set(self, **labels: Any) -> "_SpanHandle":
        """Attach labels discovered mid-span (byte counts, row counts)."""
        self.labels.update(labels)
        return self

    def __enter__(self) -> "_SpanHandle":
        tr = self._tracer
        stack = tr._stack()
        stack.append(self.name)
        with tr._lock:
            tr._open += 1
        self.t0 = tr._clock()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tr = self._tracer
        t1 = tr._clock()
        stack = tr._stack()
        stack.pop()
        parent = stack[-1] if stack else None
        with tr._lock:
            tr._open -= 1
            tr._append_locked(Span(
                self.name, self.t0, t1, self.labels,
                threading.get_ident(), parent,
            ))
        return False


class Tracer:
    """Thread-safe span recorder with an injected clock.

    ``max_spans`` bounds memory: past it, new spans are counted as
    dropped instead of stored (the drop count rides in the export's
    ``otherData`` so a truncated trace is never mistaken for a short run).
    """

    enabled = True

    def __init__(self, clock: Callable[[], float] = time.perf_counter,
                 max_spans: int = 200_000):
        self._clock = clock
        self.max_spans = max_spans
        self._lock = threading.Lock()
        self._spans: List[Span] = []
        self._open = 0
        self._dropped = 0
        self._local = _TraceLocal()

    # -- recording ----------------------------------------------------------

    def _stack(self) -> List[str]:
        return self._local.stack

    def now(self) -> float:
        """The tracer's clock — use for ``record()`` endpoints."""
        return self._clock()

    def span(self, name: str, **labels: Any) -> _SpanHandle:
        return _SpanHandle(self, name, labels)

    def record(self, name: str, t0: float, t1: float, **labels: Any) -> None:
        """Append an already-timed span (atomic: opened and closed in one
        call, so it can never orphan — exempt from REPRO-S001)."""
        parent_stack = self._stack()
        parent = parent_stack[-1] if parent_stack else None
        with self._lock:
            self._append_locked(Span(
                name, t0, t1, labels, threading.get_ident(), parent,
            ))

    def instant(self, name: str, **labels: Any) -> None:
        t = self._clock()
        self.record(name, t, t, **labels)

    def _append_locked(self, span: Span) -> None:
        if len(self._spans) >= self.max_spans:
            self._dropped += 1
            return
        self._spans.append(span)

    # -- inspection ---------------------------------------------------------

    def spans(self) -> List[Span]:
        with self._lock:
            return list(self._spans)

    def open_spans(self) -> int:
        """Spans entered but not yet exited; 0 after a complete run —
        anything else is an orphan and fails ``report --check``."""
        with self._lock:
            return self._open

    def dropped_spans(self) -> int:
        with self._lock:
            return self._dropped

    # -- export -------------------------------------------------------------

    def chrome_trace(self, metrics: Optional[Dict[str, Any]] = None) -> Dict:
        """Chrome-trace/Perfetto JSON document: ``traceEvents`` holds
        complete ``"X"`` events (ts/dur in µs, normalized so the earliest
        span starts at 0), ``otherData`` the span accounting, and
        ``metrics`` an optional registry-snapshot payload the
        stall-attribution report consumes alongside the spans."""
        spans = self.spans()
        base = min((s.t0 for s in spans), default=0.0)
        events = []
        for s in sorted(spans, key=lambda s: (s.t0, s.t1)):
            args = dict(s.labels)
            if s.parent:
                args["parent"] = s.parent
            events.append({
                "name": s.name,
                "cat": s.name.split(".", 1)[0],
                "ph": "X",
                "ts": (s.t0 - base) * 1e6,
                "dur": max(s.t1 - s.t0, 0.0) * 1e6,
                "pid": 1,
                "tid": s.tid,
                "args": args,
            })
        doc: Dict[str, Any] = {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {
                "open_spans": self.open_spans(),
                "dropped_spans": self.dropped_spans(),
                "num_spans": len(events),
            },
        }
        if metrics is not None:
            doc["metrics"] = metrics
        return doc

    def write(self, path, metrics: Optional[Dict[str, Any]] = None) -> Path:
        """Serialize ``chrome_trace()`` to ``path``; the file opens
        directly in Perfetto / ``chrome://tracing``."""
        p = Path(path)
        p.write_text(json.dumps(self.chrome_trace(metrics)) + "\n")
        return p


class _NullSpan:
    """Shared no-op span handle: entering, exiting, and labeling cost a
    method call on a singleton — no allocation, no clock read, no lock."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        return False

    def set(self, **labels: Any) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class NullTracer:
    """The disabled-by-default tracer: every operation is a no-op
    returning shared singletons, so instrumented hot paths pay only the
    call dispatch (asserted ≤ 2% of bench_dpp throughput in
    ``benchmarks/bench_obs.py``)."""

    __slots__ = ()

    enabled = False

    def now(self) -> float:
        return 0.0

    def span(self, name: str, **labels: Any) -> _NullSpan:
        return _NULL_SPAN

    def record(self, name: str, t0: float, t1: float, **labels: Any) -> None:
        return None

    def instant(self, name: str, **labels: Any) -> None:
        return None

    def spans(self) -> List[Span]:
        return []

    def open_spans(self) -> int:
        return 0

    def dropped_spans(self) -> int:
        return 0

    def chrome_trace(self, metrics: Optional[Dict[str, Any]] = None) -> Dict:
        return {"traceEvents": [], "otherData": {
            "open_spans": 0, "dropped_spans": 0, "num_spans": 0,
        }}


NULL_TRACER = NullTracer()
