import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn
from repro.models import build_model
from repro import configs as cfglib

KEY = jax.random.PRNGKey(0)


def test_blocked_matches_dense_fwd_bwd():
    b, s, h, kvh, d = 2, 192, 6, 2, 32
    q = jax.random.normal(KEY, (b, s, h, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kvh, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kvh, d))

    def lb(q, k, v):
        return jnp.sum(jnp.sin(attn.blocked_attention(q, k, v, causal=True, chunk=64)))

    def ld(q, k, v):
        return jnp.sum(jnp.sin(attn._dense_attention(q, k, v, causal=True, scale=d ** -0.5)))

    np.testing.assert_allclose(lb(q, k, v), ld(q, k, v), rtol=1e-5)
    g1 = jax.grad(lb, (0, 1, 2))(q, k, v)
    g2 = jax.grad(ld, (0, 1, 2))(q, k, v)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5)


@pytest.mark.parametrize("arch", ["qwen3-8b", "deepseek-v2-236b", "llava-next-mistral-7b"])
def test_decode_matches_prefill_logits(arch):
    """Prefill logits for the last prompt token must match the decode-step
    logits when replaying the same tokens through the cache."""
    cfg = cfglib.get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (b, s), 0, cfg.vocab_size)
    pre = {"tokens": toks}
    if cfg.frontend == "vision":
        pre["image_embeds"] = jax.random.normal(
            jax.random.PRNGKey(4), (b, cfg.num_patches, cfg.d_model), cfg.compute_dtype
        ) * 0.02
    logits_prefill, _ = model.prefill(params, pre)

    cache = jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), model.abstract_cache(b, s))
    logits = None
    for i in range(s):
        batch = {"token": toks[:, i:i + 1], "pos": jnp.asarray(i, jnp.int32), "cache": cache}
        if i == 0 and cfg.frontend == "vision":
            pass  # smoke: image tokens replayed as text is fine for cache math
        logits, cache = model.decode_step(params, batch)
    if cfg.frontend == "vision":
        return  # prefill embeds differ for image positions; covered by dense archs
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32),
        np.asarray(logits_prefill[:, 0], np.float32),
        atol=0.1, rtol=0.05,
    )


def test_mla_decode_matches_prefill():
    cfg = cfglib.get_smoke_config("deepseek-v2-236b")
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 1, 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab_size)
    lp, cache = model.prefill(params, {"tokens": toks})
    # append one token via decode on top of the prefill cache (padded)
    cap = 16
    pad = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, cap - s), (0, 0)))
    cache = {k: pad(v) for k, v in cache.items()}
    batch = {"token": toks[:, -1:], "pos": jnp.asarray(s - 1, jnp.int32), "cache": cache}
    ld, _ = model.decode_step(params, batch)
    np.testing.assert_allclose(
        np.asarray(ld[:, 0], np.float32), np.asarray(lp[:, 0], np.float32),
        atol=0.1, rtol=0.05,
    )
