"""Pallas TPU kernel: Bucketize (feature generation via bucket borders).

TPU adaptation: instead of a per-element binary search (poor on VPU), the
border list (<= a few hundred) is broadcast across lanes and the bucket
index is the count of borders <= value — a dense compare+sum that maps to
8x128 vector ops.  Borders live in VMEM and are shared by every tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(vals_ref, borders_ref, out_ref):
    v = vals_ref[...]                              # (br, bc) f32
    borders = borders_ref[...]                     # (1, nb) f32
    # count borders strictly < v per element ((br, bc, nb) compare, sum over
    # nb) == np.searchsorted(borders, v) side='left' — the transforms.py
    # reference semantics
    cmp = v[:, :, None] > borders[0][None, None, :]
    out_ref[...] = jnp.sum(cmp, axis=-1).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_cols", "interpret")
)
def bucketize(
    values: jax.Array,          # (rows, cols) f32
    borders: jax.Array,         # (nb,) f32 sorted
    *,
    block_rows: int = 128,
    block_cols: int = 256,
    interpret: bool = False,
) -> jax.Array:
    rows, cols = values.shape
    nb = borders.shape[0]
    br = min(block_rows, rows)
    bc = min(block_cols, cols)
    grid = (pl.cdiv(rows, br), pl.cdiv(cols, bc))
    borders2d = borders.reshape(1, nb).astype(jnp.float32)
    return pl.pallas_call(
        _kernel,
        grid_spec=pl.GridSpec(
            grid=grid,
            in_specs=[
                pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                pl.BlockSpec((1, nb), lambda i, j: (0, 0)),
            ],
            out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        interpret=interpret,
    )(values.astype(jnp.float32), borders2d)
