"""llama3-405b — dense GQA, 128k vocab [arXiv:2407.21783]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="llama3-smoke", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512, remat=False,
)
