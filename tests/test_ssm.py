import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ssm as S
from repro import configs as cfglib
from repro.models import build_model

KEY = jax.random.PRNGKey(0)


def _naive_ssd(x, dt, A, B_, C_):
    """Sequential reference recurrence: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    b, s, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    hg = h // g
    state = np.zeros((b, h, p, n), np.float64)
    ys = np.zeros((b, s, h, p), np.float64)
    x = np.asarray(x, np.float64); dt = np.asarray(dt, np.float64)
    A = np.asarray(A, np.float64); B_ = np.asarray(B_, np.float64); C_ = np.asarray(C_, np.float64)
    for t in range(s):
        da = np.exp(dt[:, t] * A)                      # (b,h)
        Bh = np.repeat(B_[:, t], hg, axis=1)           # (b,h,n)
        Ch = np.repeat(C_[:, t], hg, axis=1)
        state = state * da[:, :, None, None] + np.einsum(
            "bhn,bhp,bh->bhpn", Bh, x[:, t], dt[:, t]
        )
        ys[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch)
    return ys, state


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_sequential(chunk):
    b, s, h, p, g, n = 2, 32, 4, 8, 1, 16
    x = jax.random.normal(KEY, (b, s, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)) * 0.3)
    B_ = jax.random.normal(jax.random.PRNGKey(3), (b, s, g, n)) * 0.5
    C_ = jax.random.normal(jax.random.PRNGKey(4), (b, s, g, n)) * 0.5
    y, st = S.ssd_chunked(x, dt, A, B_, C_, chunk=chunk)
    y_ref, st_ref = _naive_ssd(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y, np.float64), y_ref, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st, np.float64), st_ref, atol=1e-3)


def test_decode_continues_prefill_state():
    """Running prefill then one decode step == prefill over s+1 tokens."""
    cfg = cfglib.get_smoke_config("mamba2-2.7b")
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 2, 17
    toks = jax.random.randint(jax.random.PRNGKey(5), (b, s), 0, cfg.vocab_size)
    lp_full, _ = model.prefill(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :-1]})
    ld, _ = model.decode_step(
        params, {"token": toks[:, -1:], "pos": jnp.asarray(s - 1, jnp.int32), "cache": cache}
    )
    np.testing.assert_allclose(
        np.asarray(ld[:, 0], np.float32), np.asarray(lp_full[:, 0], np.float32),
        atol=0.08, rtol=0.05,
    )


def test_jamba_decode_continues_prefill():
    # f32: bf16 accumulation drift through 8 heterogeneous sublayers
    # obscures the equivalence this test checks (verified 8.6e-6 in f32)
    import dataclasses
    cfg = dataclasses.replace(
        cfglib.get_smoke_config("jamba-1.5-large-398b"),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )
    model = build_model(cfg)
    params = model.init(KEY)
    b, s = 1, 9
    toks = jax.random.randint(jax.random.PRNGKey(6), (b, s), 0, cfg.vocab_size)
    lp_full, _ = model.prefill(params, {"tokens": toks})
    _, cache = model.prefill(params, {"tokens": toks[:, :-1]})
    # pad attention KV cache seq dim to hold the new token
    def pad_kv(v, name):
        if name in ("k", "v"):
            return jnp.pad(v, ((0, 0), (0, 0), (0, 1), (0, 0), (0, 0)))
        return v
    cache = {k: pad_kv(v, k) for k, v in cache.items()}
    ld, _ = model.decode_step(
        params, {"token": toks[:, -1:], "pos": jnp.asarray(s - 1, jnp.int32), "cache": cache}
    )
    np.testing.assert_allclose(
        np.asarray(ld[:, 0], np.float32), np.asarray(lp_full[:, 0], np.float32),
        atol=0.08, rtol=0.05,
    )
