"""Pluggable TransformEngine: fused Pallas execution of the transform DAG.

The paper's §7.2 flagship observation is that launching one kernel over a
tensor combining ~1000 sparse features is ~3 orders of magnitude faster
than per-feature dispatch, and §6.3 shows transform dominating DPP worker
cycles.  This module closes the gap between that observation and the DPP
worker's production path:

  * ``NumpyEngine`` — the reference engine: executes the per-feature DAG
    exactly like ``TransformPipeline.__call__`` (one vectorized numpy call
    per spec), while accounting per-op "kernel launches".
  * ``PallasEngine`` — compiles the DAG into **waves** of fusable ops
    (SigridHash, PositiveModulus, Clamp, Bucketize), packs each wave into
    the (rows, features) op-code/param layout of
    ``repro.kernels.fused_transform`` and executes the whole wave in ONE
    ``pallas_call`` (interpret mode on CPU, compiled on TPU).  Ops the
    kernel cannot express (NGram, Cartesian, MapId, FirstX, ...) fall back
    per-feature to the numpy implementations.

Both engines produce **byte-identical** environments (and therefore
byte-identical minibatches): the SigridHash mixer is the shared 32-bit
two-round multiply-xor-shift (``transforms._mix32`` == kernel
``_hash_u32``), bucketize compares in float32 on both paths, and any op
whose inputs would break bit-parity (ids outside int32 for
PositiveModulus, non-float32 dense columns, ...) is *demoted* to the
numpy fallback at run time.  TensorCache entries therefore stay
engine-agnostic.

``EngineStats`` feeds ``WorkerMetrics`` (fused vs fallback feature counts,
kernel launches, per-path transform seconds) so Table-9-style breakdowns
can compare engines.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.schema import ColumnBatch, SparseColumn
from repro.core.transforms import (
    _OPS,
    Column,
    TransformPipeline,
    TransformSpec,
)
from repro.obs import counter

# Op codes mirror repro.kernels.fused_transform (kept import-light: jax is
# only pulled in when a PallasEngine actually launches a wave).
OP_IDENTITY = 0
OP_SIGRID_HASH = 1
OP_POSITIVE_MODULUS = 2
OP_CLAMP = 3
OP_BUCKETIZE = 4
OP_CLAMP_F = 5
OP_BUCKETIZE_F = 6

_I32_MIN = -(2 ** 31)
_I32_MAX = 2 ** 31 - 1
_MAX_BORDERS = 512
_F32_TINY = float(np.finfo(np.float32).tiny)   # smallest normal float32


def _subnormal(arr: np.ndarray) -> bool:
    """XLA's CPU/TPU paths may flush subnormal float32 to zero (FTZ/DAZ)
    while numpy preserves them — values in (0, tiny) break bit-parity."""
    a = np.abs(arr, dtype=np.float32)
    return bool(np.any((a > 0) & (a < _F32_TINY)))


# ---------------------------------------------------------------------------
# Engine accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EngineStats:
    """Cumulative per-engine accounting (mirrored into ``WorkerMetrics``)."""

    fused_features: int = counter()      # op executions served by a fused kernel
    fallback_features: int = counter()   # op executions served by per-feature numpy
    demoted_features: int = counter()    # fused-eligible ops demoted at run time
    kernel_launches: int = counter()     # fused pallas_calls + per-feature op calls
    fused_s: float = counter(0.0)        # transform_s attribution: fused path
    fallback_s: float = counter(0.0)     # transform_s attribution: numpy path


# ---------------------------------------------------------------------------
# Compilation: transform DAG -> waves of packed fused ops + fallback steps
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedOp:
    """One packed column of a fused wave: op code + int32 params (float
    params ride as float32 bit patterns, like in the kernel)."""

    spec: TransformSpec
    code: int
    p0: int
    p1: int
    kind: str                              # "sparse" | "dense" | "dense_bucket"
    borders: Optional[np.ndarray] = None   # (nb,) float32, BUCKETIZE_F only


@dataclasses.dataclass(frozen=True)
class FusedWave:
    ops: Tuple[FusedOp, ...]


@dataclasses.dataclass(frozen=True)
class FallbackStep:
    spec: TransformSpec


@dataclasses.dataclass(frozen=True)
class CompiledPlan:
    """Ordered execution steps: each step is a FusedWave (one kernel
    launch) or a FallbackStep (one per-feature numpy call)."""

    steps: Tuple[Union[FusedWave, FallbackStep], ...]

    @property
    def fused_ops(self) -> List[FusedOp]:
        return [op for s in self.steps if isinstance(s, FusedWave) for op in s.ops]

    @property
    def fallback_specs(self) -> List[TransformSpec]:
        return [s.spec for s in self.steps if isinstance(s, FallbackStep)]


def _f32_exact(x: Any) -> bool:
    try:
        x = float(x)
    except (TypeError, ValueError):
        return False
    # NaN params stay on the numpy path: XLA min/max NaN propagation
    # differs from numpy's.  (NaN != NaN, so the equality rejects it.)
    f = float(np.float32(x))
    return f == x


def _f32_bits(x: float) -> int:
    return int(np.float32(x).view(np.int32))


def _bits_f32(b: int) -> float:
    return float(np.int32(b).view(np.float32))


def _try_fuse(spec: TransformSpec) -> Optional[FusedOp]:
    """Static fusability: can this spec be expressed as one fused-kernel
    column with bit-exact numpy parity?  Returns None for fallback."""
    kw = spec.kwargs
    if len(spec.inputs) != 1:
        return None
    if spec.op == "SigridHash" and set(kw) == {"salt", "max_value"}:
        salt, mv = kw["salt"], kw["max_value"]
        if isinstance(salt, (int, np.integer)) and isinstance(mv, (int, np.integer)) \
                and 0 <= salt <= _I32_MAX and 1 <= mv <= _I32_MAX:
            return FusedOp(spec, OP_SIGRID_HASH, int(salt), int(mv), "sparse")
    elif spec.op == "PositiveModulus" and set(kw) == {"m"}:
        m = kw["m"]
        if isinstance(m, (int, np.integer)) and 1 <= m <= _I32_MAX:
            return FusedOp(spec, OP_POSITIVE_MODULUS, int(m), int(m), "sparse")
    elif spec.op == "Clamp" and set(kw) == {"lo", "hi"}:
        lo, hi = kw["lo"], kw["hi"]
        if (
            _f32_exact(lo) and _f32_exact(hi)
            and not _subnormal(np.array([lo, hi], np.float32))
        ):
            return FusedOp(
                spec, OP_CLAMP_F, _f32_bits(float(lo)), _f32_bits(float(hi)),
                "dense",
            )
    elif spec.op == "Bucketize" and set(kw) == {"borders"}:
        b = np.asarray(kw["borders"], np.float32)
        if (
            b.ndim == 1 and 1 <= b.size <= _MAX_BORDERS
            and np.all(np.isfinite(b)) and np.all(np.diff(b) >= 0)
            and not _subnormal(b)
        ):
            return FusedOp(spec, OP_BUCKETIZE_F, 0, 0, "dense_bucket", b)
    return None


def compile_pipeline(
    specs: Sequence[TransformSpec],
) -> CompiledPlan:
    """Greedy level scheduling: at every round, every not-yet-executed
    fusable spec whose inputs are already materialized joins one fused
    wave (one kernel launch); otherwise the next spec in topological
    order runs as a per-feature fallback.  DAGs that reassign an output
    key compile to pure fallback (wave reordering would change the
    sequential-overwrite semantics of ``TransformPipeline``)."""
    specs = list(specs)
    # single-assignment check with read-before-overwrite detection: if a
    # spec's output key was already read (by an earlier spec, or by itself)
    # or already written, sequential execution order is load-bearing — an
    # earlier reader must see the PRE-overwrite value, which wave
    # reordering would destroy.  ``{outputs} & ({inputs} - {outputs})``
    # is NOT sufficient: a later spec overwriting a raw batch key that an
    # earlier spec reads leaves that key out of the external set entirely.
    seen_inputs: set = set()
    written: set = set()
    for s in specs:
        seen_inputs.update(s.inputs)       # reads happen before this write
        if s.output in seen_inputs or s.output in written:
            return CompiledPlan(tuple(FallbackStep(s) for s in specs))
        written.add(s.output)
    external = {i for s in specs for i in s.inputs} - written

    fusable = {id(s): _try_fuse(s) for s in specs}
    avail = set(external)
    remaining = list(specs)
    steps: List[Union[FusedWave, FallbackStep]] = []
    while remaining:
        # drain every ready fallback FIRST: postponing fusable ops until no
        # fallback can run widens each wave (e.g. all FirstX feeds complete
        # before their SigridHashes fuse into ONE launch).  Safe because
        # single-assignment makes execution order irrelevant to results.
        progressed = True
        while progressed:
            progressed = False
            for s in list(remaining):
                if fusable[id(s)] is None and all(i in avail for i in s.inputs):
                    steps.append(FallbackStep(s))
                    avail.add(s.output)
                    remaining.remove(s)
                    progressed = True
        wave = [
            s for s in remaining
            if fusable[id(s)] is not None and all(i in avail for i in s.inputs)
        ]
        if not wave:
            # nothing ready at all: an unsatisfiable input.  Preserve the
            # sequential pipeline's behavior (KeyError at execution time).
            steps.extend(FallbackStep(s) for s in remaining)
            break
        # split by row class: sparse columns pack nnz values (~rows x
        # avg_len lanes) while dense columns pack one value per row —
        # co-packing would pad every dense column to the tallest nnz and
        # drag the borders compare over the tall tile.  Two well-shaped
        # launches beat one badly-shaped one; amortization stays
        # O(features) per launch.
        sparse_ops = tuple(
            fusable[id(s)] for s in wave if fusable[id(s)].kind == "sparse"
        )
        dense_ops = tuple(
            fusable[id(s)] for s in wave if fusable[id(s)].kind != "sparse"
        )
        for ops in (sparse_ops, dense_ops):
            if ops:
                steps.append(FusedWave(ops))
        for s in wave:
            avail.add(s.output)
            remaining.remove(s)
    return CompiledPlan(tuple(steps))


def decode_plan(plan: CompiledPlan) -> List[TransformSpec]:
    """Reconstruct the fused specs from their packed op-code/param columns
    — the round-trip witness that packing loses nothing (borders are
    canonicalized to float32, the precision the kernel compares in)."""
    out: List[TransformSpec] = []
    for op in plan.fused_ops:
        src = op.spec
        if op.code == OP_SIGRID_HASH:
            params = (("salt", op.p0), ("max_value", op.p1))
        elif op.code == OP_POSITIVE_MODULUS:
            params = (("m", op.p0),)
        elif op.code == OP_CLAMP_F:
            params = (("lo", _bits_f32(op.p0)), ("hi", _bits_f32(op.p1)))
        elif op.code == OP_BUCKETIZE_F:
            params = (("borders", op.borders),)
        else:  # pragma: no cover - no other codes are emitted by _try_fuse
            raise ValueError(f"unknown fused op code {op.code}")
        out.append(TransformSpec(src.op, src.inputs, src.output, params))
    return out


# ---------------------------------------------------------------------------
# Engines
# ---------------------------------------------------------------------------


class TransformEngine:
    """Executes a session's transform DAG over a ColumnBatch."""

    name = "base"

    def __init__(self, pipeline: TransformPipeline):
        self.pipeline = pipeline
        self.stats = EngineStats()

    def run(self, batch: ColumnBatch) -> Dict[str, Column]:
        raise NotImplementedError

    def __call__(self, batch: ColumnBatch) -> Dict[str, Column]:
        return self.run(batch)

    # -- shared helpers -----------------------------------------------------

    @staticmethod
    def _seed_env(batch: ColumnBatch) -> Dict[str, Column]:
        env: Dict[str, Column] = {}
        for fid, col in batch.dense.items():
            env[f"f{fid}"] = col
        for fid, col in batch.sparse.items():
            env[f"f{fid}"] = col
        return env

    def _apply_fallback(self, spec: TransformSpec, env: Dict[str, Column]) -> None:
        t0 = time.perf_counter()
        fn = _OPS[spec.op]
        env[spec.output] = fn(*[env[i] for i in spec.inputs], **spec.kwargs)
        self.stats.fallback_s += time.perf_counter() - t0
        self.stats.fallback_features += 1
        self.stats.kernel_launches += 1


class NumpyEngine(TransformEngine):
    """Per-feature reference execution — one vectorized numpy call per
    spec, each accounted as one kernel launch (the per-feature dispatch
    regime of §7.2)."""

    name = "numpy"

    def run(self, batch: ColumnBatch) -> Dict[str, Column]:
        env = self._seed_env(batch)
        for spec in self.pipeline.specs:
            self._apply_fallback(spec, env)
        return env


class PallasEngine(TransformEngine):
    """Wave-fused execution via ``kernels.fused_transform``.

    ``row_quantum`` pads the packed tile's row count up to a multiple, so
    ragged stripe sizes reuse a handful of compiled kernel shapes instead
    of recompiling per batch (pad lanes compute garbage that is sliced
    away on unpack).

    ``use_pallas`` is the wave dispatch (the ``repro.kernels`` contract):
    ``None`` (default) runs the compiled Pallas kernel on TPU and the
    XLA-compiled static-codes oracle elsewhere — the fast fused path for
    whatever backend is present, so ``engine="pallas"`` never regresses a
    CPU deployment into emulation.  ``True`` always runs the Pallas
    kernel — compiled on TPU, **interpret mode** off-TPU (bit-accurate
    but emulation-slow: how the differential suite validates the kernel
    on CPU).  All paths compute identical bits, so the engine stays
    byte-compatible with ``NumpyEngine`` either way.
    """

    name = "pallas"

    def __init__(
        self,
        pipeline: TransformPipeline,
        block_rows: int = 256,
        block_cols: int = 512,
        row_quantum: int = 512,
        use_pallas: Optional[bool] = None,
    ):
        super().__init__(pipeline)
        self.plan = compile_pipeline(pipeline.specs)
        self.block_rows = block_rows
        self.block_cols = block_cols
        self.row_quantum = max(1, row_quantum)
        self.use_pallas = use_pallas

    def run(self, batch: ColumnBatch) -> Dict[str, Column]:
        env = self._seed_env(batch)
        for step in self.plan.steps:
            if isinstance(step, FallbackStep):
                self._apply_fallback(step.spec, env)
            else:
                self._run_wave(step, env)
        return env

    # -- wave execution -----------------------------------------------------

    def _pack_column(self, fop: FusedOp, col: Column) -> Optional[np.ndarray]:
        """Return this op's input as int32-assignable lanes (int64 sparse
        ids wrap to their low 32 bits on assignment; dense float32 rides
        as bit patterns), or None to demote the op to the numpy fallback."""
        if fop.kind == "sparse":
            if not isinstance(col, SparseColumn):
                return None
            v = col.values
            if fop.code == OP_POSITIVE_MODULUS and v.size and (
                v.min() < _I32_MIN or v.max() > _I32_MAX
            ):
                return None      # int32 wrap would diverge from int64 numpy
            # SigridHash truncates to the low 32 bits on both paths, so
            # any int64 id packs exactly (setitem wrap == astype wrap).
            return v
        if not isinstance(col, np.ndarray) or col.ndim != 1:
            return None
        if fop.kind == "dense" and col.dtype != np.float32:
            return None          # f64 clamp-then-cast can diverge from f32
        v32 = np.nan_to_num(col, nan=0.0).astype(np.float32)
        if _subnormal(v32):
            return None          # XLA flush-to-zero would diverge from numpy
        return v32.view(np.int32)

    def _run_wave(self, wave: FusedWave, env: Dict[str, Column]) -> None:
        t0 = time.perf_counter()
        entries: List[Tuple[FusedOp, Column, np.ndarray]] = []
        demoted: List[FusedOp] = []
        for fop in wave.ops:
            col = env[fop.spec.inputs[0]]
            packed = self._pack_column(fop, col)
            if packed is None:
                demoted.append(fop)
            else:
                entries.append((fop, col, packed))

        if entries:
            rows = max(len(p) for _, _, p in entries)
            feats = len(entries)
            if rows == 0:
                out32 = np.zeros((feats, 0), np.int32)
            else:
                # features-major packing: one contiguous row per feature
                # (fast fills; int64 ids wrap to their low 32 bits on
                # assignment, matching the kernel's lane truncation)
                q = self.row_quantum
                rows_pad = -(-rows // q) * q
                mat = np.zeros((feats, rows_pad), np.int32)
                codes = np.zeros(feats, np.int32)
                p0 = np.zeros(feats, np.int32)
                p1 = np.zeros(feats, np.int32)
                nb = max(
                    [f.borders.size for f, _, _ in entries if f.borders is not None],
                    default=1,
                )
                borders = np.full((feats, nb), np.inf, np.float32)
                for j, (fop, _, packed) in enumerate(entries):
                    mat[j, : len(packed)] = packed
                    codes[j] = fop.code
                    p0[j] = fop.p0
                    p1[j] = fop.p1
                    if fop.borders is not None:
                        borders[j, : fop.borders.size] = fop.borders
                out32 = self._launch(mat, codes, p0, p1, borders)
            self.stats.kernel_launches += 1
            self.stats.fused_features += feats
            # vectorized unpack: at most one widening cast for the whole
            # wave; per-feature outputs are contiguous row views
            out64 = (
                out32.astype(np.int64)
                if any(f.kind != "dense" for f, _, _ in entries) else None
            )
            for j, (fop, col, packed) in enumerate(entries):
                env[fop.spec.output] = self._unpack(
                    fop, col, out32, out64, j, len(packed)
                )
            self.stats.fused_s += time.perf_counter() - t0

        for fop in demoted:
            self.stats.demoted_features += 1
            self._apply_fallback(fop.spec, env)

    def _launch(self, mat, codes, p0, p1, borders) -> np.ndarray:
        """Run one wave over the (features, rows) packed tile; returns the
        transformed tile in the same layout."""
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        use = kops._on_tpu() if self.use_pallas is None else self.use_pallas
        if use:
            # the Pallas kernel tiles (rows, features) with features on
            # the 128-lane minor axis; transposes happen device-side
            out = kops.fused_transform(
                jnp.asarray(mat).T, jnp.asarray(codes), jnp.asarray(p0),
                jnp.asarray(p1), jnp.asarray(borders),
                block_rows=self.block_rows, block_cols=self.block_cols,
                use_pallas=True,
            )
            return np.ascontiguousarray(np.asarray(out).T)
        # oracle dispatch: the wave's op codes are known at compile time,
        # so the static-codes oracle skips every absent candidate branch
        # and computes directly in the packing layout (no transposes)
        out = _static_oracle()(
            jnp.asarray(mat), tuple(int(c) for c in codes),
            jnp.asarray(p0), jnp.asarray(p1), jnp.asarray(borders),
            features_major=True,
        )
        return np.asarray(out)

    @staticmethod
    def _unpack(
        fop: FusedOp, col: Column,
        out32: np.ndarray, out64: Optional[np.ndarray], j: int, n: int,
    ) -> Column:
        if fop.kind == "sparse":
            return SparseColumn(
                offsets=col.offsets, values=out64[j, :n], scores=col.scores,
            )
        if fop.kind == "dense":        # Clamp: float32 bits back to floats
            return out32[j, :n].view(np.float32)
        # dense_bucket: one bucket id per row, arange offsets — exactly
        # the transforms.bucketize output shape
        return SparseColumn(
            offsets=np.arange(n + 1, dtype=np.int64),
            values=out64[j, :n], scores=None,
        )


_STATIC_ORACLE = None


def _static_oracle():
    """Lazily-jitted ``ref.fused_transform_static`` (op codes static)."""
    global _STATIC_ORACLE
    if _STATIC_ORACLE is None:
        import jax

        from repro.kernels import ref

        _STATIC_ORACLE = jax.jit(
            ref.fused_transform_static,
            static_argnums=(1,), static_argnames=("features_major",),
        )
    return _STATIC_ORACLE


ENGINES = {"numpy": NumpyEngine, "pallas": PallasEngine}


def make_engine(
    engine: Union[str, TransformEngine, None],
    pipeline: TransformPipeline,
) -> TransformEngine:
    """Resolve an engine choice (name, instance, or factory) for one
    exclusive owner (engines accumulate stats; don't share instances
    across workers)."""
    if engine is None:
        return NumpyEngine(pipeline)
    if isinstance(engine, TransformEngine):
        return engine
    if isinstance(engine, str):
        try:
            return ENGINES[engine](pipeline)
        except KeyError:
            raise ValueError(
                f"unknown transform engine {engine!r}; "
                f"expected one of {sorted(ENGINES)}"
            ) from None
    return engine(pipeline)      # factory callable
