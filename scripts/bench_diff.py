#!/usr/bin/env python3
"""Compare two ``BENCH_quick.json`` artifacts and flag regressions.

  python scripts/bench_diff.py OLD.json NEW.json [--threshold 0.25]

A metric row regresses when its ``us_per_call`` grew by more than
``threshold`` (default 25% — benchmark timings on shared CI hosts are
noisy; tighten per-invocation for quiet machines).  A section regresses
when its status flips from ``ok`` to a failure.  Rows that appear or
vanish between the two artifacts are reported informationally — renames
are a review concern, not an automatic failure.

A few rows also carry *derived* ``key=value`` metrics that are quality
signals rather than timings; those are guarded absolutely (points, not
ratios — a hot-rate of 0.76 dropping to 0.60 is a policy regression no
matter how fast it ran).  ``_DERIVED_GUARDS`` lists each guarded key
with its direction and tolerance.  Exits 1 iff at least one regression
was found, so CI can gate on trend directly:

  python -m benchmarks.run --quick        # writes BENCH_quick.json
  python scripts/bench_diff.py baseline.json BENCH_quick.json
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Optional, Tuple


# (metric-name, derived-key) -> (direction, tolerance in absolute points).
# "floor": the value must not drop more than `tol` below the baseline
# (hit rates, fractions-of-good); "ceil": it must not rise more than
# `tol` above it (stall shares, fractions-of-bad).
_DERIVED_GUARDS: Dict[Tuple[str, str], Tuple[str, float]] = {
    ("train_e2e.hot_rate", "tiered"): ("floor", 0.05),
    ("train_e2e.step_breakdown", "data_pct"): ("ceil", 10.0),
    ("train_e2e.step_breakdown", "embed_pct"): ("ceil", 10.0),
    # batched decode must keep amortizing launches and beating the
    # per-stream engine (bench_extract.py also asserts absolute floors)
    ("extract.fused_batched", "amortization"): ("floor", 50.0),
    ("extract.fused_batched", "extract_cut"): ("floor", 0.30),
}


def _rows(report: Dict) -> Dict[Tuple[str, str], float]:
    """(section, metric-name) -> us_per_call."""
    out: Dict[Tuple[str, str], float] = {}
    for section, body in report.get("sections", {}).items():
        for row in body.get("metrics", []):
            out[(section, row["name"])] = float(row["us_per_call"])
    return out


def _derived(report: Dict) -> Dict[str, Dict[str, float]]:
    """metric-name -> parsed ``key=value`` floats from the derived column
    (non-numeric values are skipped)."""
    out: Dict[str, Dict[str, float]] = {}
    for body in report.get("sections", {}).values():
        for row in body.get("metrics", []):
            vals: Dict[str, float] = {}
            for tok in str(row.get("derived", "")).split():
                key, _, raw = tok.partition("=")
                if not _:
                    continue
                try:
                    # ratio/percent annotations ("1.54x", "76%") are
                    # still numbers to the trend gate
                    vals[key] = float(raw.rstrip("x%"))
                except ValueError:
                    continue
            if vals:
                out[row["name"]] = vals
    return out


def _statuses(report: Dict) -> Dict[str, str]:
    return {
        section: body.get("status", "ok")
        for section, body in report.get("sections", {}).items()
    }


def compare(old: Dict, new: Dict, threshold: float) -> Tuple[List[str], List[str]]:
    """(regressions, notes) — human-readable lines."""
    regressions: List[str] = []
    notes: List[str] = []
    old_status, new_status = _statuses(old), _statuses(new)
    for section, status in sorted(new_status.items()):
        prev = old_status.get(section)
        if prev is None:
            notes.append(f"section {section}: new (status={status})")
        elif prev == "ok" and status.startswith("failed"):
            regressions.append(f"section {section}: ok -> {status}")
        elif prev != status:
            notes.append(f"section {section}: status {prev} -> {status}")
    old_rows, new_rows = _rows(old), _rows(new)
    for key, new_us in sorted(new_rows.items()):
        section, name = key
        old_us = old_rows.get(key)
        if old_us is None:
            notes.append(f"row {name} [{section}]: added")
            continue
        if old_us <= 0.0:
            continue                     # flag-style rows time at 0
        ratio = new_us / old_us
        line = (
            f"row {name} [{section}]: {old_us:.1f} -> {new_us:.1f} us "
            f"({ratio:.2f}x)"
        )
        if ratio > 1.0 + threshold:
            regressions.append(line)
        elif ratio < 1.0 / (1.0 + threshold):
            notes.append(line + "  (improved)")
    for key in sorted(set(old_rows) - set(new_rows)):
        notes.append(f"row {key[1]} [{key[0]}]: removed")
    old_derived, new_derived = _derived(old), _derived(new)
    for (name, dkey), (direction, tol) in sorted(_DERIVED_GUARDS.items()):
        ov = old_derived.get(name, {}).get(dkey)
        nv = new_derived.get(name, {}).get(dkey)
        if ov is None or nv is None:
            continue                     # row absent on one side: a note above
        line = f"derived {name}:{dkey}: {ov:.3f} -> {nv:.3f}"
        if direction == "floor" and nv < ov - tol:
            regressions.append(f"{line} (dropped > {tol:g})")
        elif direction == "ceil" and nv > ov + tol:
            regressions.append(f"{line} (rose > {tol:g})")
    return regressions, notes


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH_quick.json artifacts; exit 1 on "
                    "regression"
    )
    ap.add_argument("old", help="baseline BENCH_quick.json")
    ap.add_argument("new", help="candidate BENCH_quick.json")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="fractional us_per_call growth tolerated "
                         "(default 0.25 = +25%%)")
    args = ap.parse_args(argv)
    with open(args.old, "r", encoding="utf-8") as f:
        old = json.load(f)
    with open(args.new, "r", encoding="utf-8") as f:
        new = json.load(f)
    regressions, notes = compare(old, new, args.threshold)
    for line in notes:
        print(f"  note: {line}")
    for line in regressions:
        print(f"  REGRESSION: {line}")
    if regressions:
        print(f"bench_diff: {len(regressions)} regression(s) "
              f"(threshold +{args.threshold * 100:.0f}%)")
        return 1
    print(f"bench_diff: ok — {len(_rows(new))} row(s) within "
          f"+{args.threshold * 100:.0f}%")
    return 0


if __name__ == "__main__":
    sys.exit(main())
