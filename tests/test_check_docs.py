"""Tests for the doc-drift gate (``scripts/check_docs.py``).

The gate is itself part of CI, so it gets the same treatment as any other
checker: fixture trees proving it fires on stale references, and a
real-tree run proving the shipped docs are clean.
"""
from __future__ import annotations

import importlib.util
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]

_spec = importlib.util.spec_from_file_location(
    "check_docs", REPO / "scripts" / "check_docs.py"
)
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def _doc_repo(tmp_path: Path, readme: str) -> Path:
    (tmp_path / "docs").mkdir()
    (tmp_path / "src" / "repro").mkdir(parents=True)
    (tmp_path / "src" / "repro" / "real.py").write_text("x = 1\n")
    (tmp_path / "README.md").write_text(textwrap.dedent(readme))
    return tmp_path


def test_flags_removed_src_path(tmp_path, capsys):
    repo = _doc_repo(tmp_path, """\
        See `src/repro/real.py` (exists) and `src/repro/removed.py`
        (deleted two PRs ago).
    """)
    assert check_docs.main(repo) == 1
    out = capsys.readouterr().out
    assert "src/repro/removed.py" in out and "src/repro/real.py" not in out


def test_flags_stale_python_m_command(tmp_path, capsys):
    repo = _doc_repo(tmp_path, """\
        Run `python -m repro.no_such_module_anywhere` to reproduce.
    """)
    assert check_docs.main(repo) == 1
    assert "repro.no_such_module_anywhere" in capsys.readouterr().out


def test_flags_missing_script_and_docs_file(tmp_path, capsys):
    repo = _doc_repo(tmp_path, """\
        Run `python scripts/gone.py`; background in docs/missing.md.
    """)
    assert check_docs.main(repo) == 1
    out = capsys.readouterr().out
    assert "scripts/gone.py" in out and "docs/missing.md" in out


def test_flags_unknown_analysis_rule_id(tmp_path, capsys):
    # repro.analysis is importable from the dev environment, so fixture
    # docs citing an unregistered rule id must be flagged as drift
    repo = _doc_repo(tmp_path, """\
        Suppress with `# repro: noqa(REPRO-L001)` (real) or
        `# repro: noqa(REPRO-Z999)` (never registered).
    """)
    assert check_docs.main(repo) == 1
    out = capsys.readouterr().out
    assert "REPRO-Z999" in out and "REPRO-L001" not in out


def test_clean_fixture_tree_passes(tmp_path, capsys):
    repo = _doc_repo(tmp_path, """\
        See `src/repro/real.py`, wildcard src/repro/*.py, and the family
        src/repro/... — all resolvable.  Rule REPRO-C001 is registered.
    """)
    assert check_docs.main(repo) == 0
    assert "doc drift: ok" in capsys.readouterr().out


def test_real_tree_is_clean(capsys):
    """The shipped README + docs/ must pass their own gate."""
    assert check_docs.main(REPO) == 0
    out = capsys.readouterr().out
    assert "doc drift: ok" in out
