"""Training runtime: DPP-fed, fault-tolerant, elastic.

The loop every trainer runs:
  batch = dpp_client.get_batch()   (data-stall accounted, Table 7 style)
  state = train_step(state, batch) (jitted, sharded)
  periodic checkpoint (atomic, resumable)

With an attached :class:`~repro.train.embedding_cache.TieredEmbeddingStore`
the DLRM sparse path runs instead: embedding bags are served from the
hot/cold tier (``embed.fetch`` span), the jitted step trains only the MLPs
by autodiff and returns d(pooled), and the store applies the row-wise
AdaGrad scatter to the host tier — the MTrainS-style heterogeneous-memory
training loop.  Every step feeds ``StepMetrics`` into a ``MetricsRegistry``
(``train.*`` + ``embed.*``) so ``repro.obs.report`` can attribute step time
across data stall, embedding fetch, and compute.

Fault tolerance: resume from the newest complete checkpoint (trainer
crash), DPP master checkpoint/restore + stateless worker restart (data
plane), and ``remesh`` for elastic scaling — re-lower the step on a new
device count and re-shard the state (parameters are resharded by device_put
under the new mesh).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.distributed.context import sharding_context
from repro.distributed.sharding import TRAIN_RULES
from repro.models import build_model
from repro.models.common import init_params, partition_specs
from repro.obs import NULL_TRACER, MetricsRegistry, counter, gauge
from repro.optim import OptimizerConfig, adamw_init, adamw_update, wsd_schedule


@dataclasses.dataclass
class TrainerConfig:
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    log_every: int = 10
    max_steps: int = 200
    batch_timeout_s: float = 30.0
    tenant: str = ""            # tenant label on trainer spans (Table-7 rows)
    trace_stall: bool = True    # off when the batch source traces client.stall
    kernel_bags: bool = False   # serve fully-hot bags via the Pallas kernel


@dataclasses.dataclass
class StepMetrics:
    """Per-step point readings — gauges, not counters: each row is one
    step's level, never accumulated across steps by ``merge_metrics``."""

    step: int = gauge(merge="last")
    loss: float = gauge(0.0, merge="last")
    grad_norm: float = gauge(0.0, merge="last")
    step_time_s: float = gauge(0.0, merge="last")
    stall_s: float = gauge(0.0, merge="last")
    embed_fetch_s: float = gauge(0.0, merge="last")   # tiered-store lookup time
    hot_rate: float = gauge(0.0, merge="last")        # cumulative device-tier hit rate


@dataclasses.dataclass
class TrainMetrics:
    """Cumulative run totals the registry snapshots as ``train.*`` —
    counters accumulate across steps, loss/grad_norm report the level."""

    steps: int = counter()
    loss: float = gauge(0.0, merge="last")
    grad_norm: float = gauge(0.0, merge="last")
    step_s: float = counter(0.0)
    stall_s: float = counter(0.0)
    embed_fetch_s: float = counter(0.0)


class Trainer:
    def __init__(
        self,
        model_cfg: Any,
        opt_cfg: Optional[OptimizerConfig] = None,
        trainer_cfg: Optional[TrainerConfig] = None,
        mesh: Optional[Any] = None,
        rules=TRAIN_RULES,
        tracer=NULL_TRACER,
        embedding_store: Optional[Any] = None,
        registry: Optional[MetricsRegistry] = None,
    ):
        self.tracer = tracer
        self.model_cfg = model_cfg
        self.model = build_model(model_cfg)
        self.opt_cfg = opt_cfg or OptimizerConfig()
        self.cfg = trainer_cfg or TrainerConfig()
        self.mesh = mesh
        self.rules = rules
        self.store = embedding_store
        self._sparse = (
            embedding_store is not None
            and hasattr(self.model, "loss_from_pooled")
        )
        self.ckpt = (
            CheckpointManager(self.cfg.checkpoint_dir)
            if self.cfg.checkpoint_dir
            else None
        )
        self._train_step = (
            self._build_sparse_step() if self._sparse else self._build_step()
        )
        self.history: list[StepMetrics] = []
        self.metrics = TrainMetrics()
        self.registry = registry or MetricsRegistry()
        self.registry.register("train", lambda: self.metrics)
        if self.store is not None:
            self.registry.register("embed", lambda: self.store.stats)

    # -- step ------------------------------------------------------------

    def _build_step(self) -> Callable:
        model, opt_cfg, mesh, rules = self.model, self.opt_cfg, self.mesh, self.rules

        def train_step(params, opt_state, batch):
            def run():
                loss, grads = jax.value_and_grad(model.loss)(params, batch)
                new_p, new_o, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
                return new_p, new_o, loss, gnorm

            if mesh is not None:
                with sharding_context(mesh, rules):
                    return run()
            return run()

        return jax.jit(train_step, donate_argnums=(0, 1))

    def _build_sparse_step(self) -> Callable:
        """MLP-only jitted step for the tiered-embedding path: pooled bags
        come in as data, d(pooled) goes back out for the store's row-wise
        AdaGrad scatter (``DLRM.sparse_table_update`` semantics), along
        with the schedule lr the scatter must use."""
        model, opt_cfg = self.model, self.opt_cfg

        def train_step(mlp_params, opt_state, pooled, batch):
            def lf(mp, pl):
                return model.loss_from_pooled(mp, pl, batch)

            loss, (g_mlp, g_pooled) = jax.value_and_grad(
                lf, argnums=(0, 1)
            )(mlp_params, pooled)
            new_p, new_o, gnorm = adamw_update(
                mlp_params, g_mlp, opt_state, opt_cfg
            )
            lr = wsd_schedule(opt_cfg, new_o["step"])
            return new_p, new_o, loss, gnorm, g_pooled, lr

        return jax.jit(train_step, donate_argnums=(0, 1))

    def init_state(self, seed: int = 0) -> Dict[str, Any]:
        if self._sparse:
            # embedding tables live in the store's host tier; the jitted
            # state carries only the dense/interaction MLPs
            specs = {
                k: v for k, v in self.model.param_specs().items()
                if k != "tables"
            }
            params = init_params(specs, jax.random.PRNGKey(seed))
            return {
                "params": params,
                "opt": adamw_init(params, self.opt_cfg),
                "step": 0,
            }
        params = self.model.init(jax.random.PRNGKey(seed))
        if self.mesh is not None:
            specs = partition_specs(self.model.param_specs(), self.rules, self.mesh)
            from repro.distributed.sharding import shard_tree

            params = shard_tree(params, specs, self.mesh)
        return {"params": params, "opt": adamw_init(params, self.opt_cfg), "step": 0}

    # -- fault tolerance ---------------------------------------------------

    def maybe_restore(self, state: Dict[str, Any]) -> Dict[str, Any]:
        if self.ckpt and self.ckpt.latest_step() is not None:
            step, restored = self.ckpt.restore(
                {"params": state["params"], "opt": state["opt"]}
            )
            return {"params": restored["params"], "opt": restored["opt"], "step": step}
        return state

    def remesh(self, new_mesh) -> None:
        """Elastic scaling: rebuild the jitted step for a new device mesh.
        Existing state is resharded lazily on the next device_put."""
        self.mesh = new_mesh
        self._train_step = (
            self._build_sparse_step() if self._sparse else self._build_step()
        )

    # -- loop -----------------------------------------------------------------

    def _span_labels(self, step: int) -> Dict[str, Any]:
        if self.cfg.tenant:
            return {"step": step, "tenant": self.cfg.tenant}
        return {"step": step}

    def fit(
        self,
        batches: Iterable[Dict[str, np.ndarray]],
        state: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        state = state or self.init_state()
        state = self.maybe_restore(state)
        params, opt, step = state["params"], state["opt"], state["step"]

        it = iter(batches)
        while step < self.cfg.max_steps:
            t0 = time.perf_counter()
            try:
                batch = next(it)
            except StopIteration:
                break
            if batch is None:
                continue
            t1 = time.perf_counter()
            if self._sparse:
                ids = np.asarray(batch["sparse_ids"])
                smask = np.asarray(batch["sparse_mask"], np.float32)
                pooled = self.store.pooled(
                    ids, smask, use_kernel=self.cfg.kernel_bags
                )
                te = time.perf_counter()
                jb = {
                    "dense": jnp.asarray(batch["dense"]),
                    "label": jnp.asarray(batch["label"]),
                }
                params, opt, loss, gnorm, dpooled, lr = self._train_step(
                    params, opt, jnp.asarray(pooled), jb
                )
                self.store.apply_sparse_update(
                    np.asarray(dpooled), ids, smask, lr=float(lr)
                )
            else:
                te = t1
                jb = {k: jnp.asarray(v) for k, v in batch.items()}
                params, opt, loss, gnorm = self._train_step(params, opt, jb)
            step += 1
            t2 = time.perf_counter()
            if self.tracer.enabled:
                if self.cfg.trace_stall and t1 > t0:
                    # batch-fetch wait: trainer-side stall (Table 7)
                    self.tracer.record(
                        "client.stall", t0, t1, **self._span_labels(step)
                    )
                if te > t1:
                    # tiered-embedding lookup: the embed-fetch share
                    self.tracer.record(
                        "embed.fetch", t1, te, **self._span_labels(step)
                    )
                self.tracer.record(
                    "train.step", te, t2, **self._span_labels(step)
                )
            m = StepMetrics(
                step=step, loss=float(loss), grad_norm=float(gnorm),
                step_time_s=t2 - te, stall_s=t1 - t0,
                embed_fetch_s=te - t1,
                hot_rate=self.store.stats.hot_rate if self._sparse else 0.0,
            )
            self.history.append(m)
            self.metrics.steps += 1
            self.metrics.loss = m.loss
            self.metrics.grad_norm = m.grad_norm
            self.metrics.step_s += m.step_time_s
            self.metrics.stall_s += m.stall_s
            self.metrics.embed_fetch_s += m.embed_fetch_s
            if self.ckpt and step % self.cfg.checkpoint_every == 0:
                self.ckpt.save(step, {"params": params, "opt": opt})
        if self.ckpt:
            self.ckpt.save(step, {"params": params, "opt": opt})
        return {"params": params, "opt": opt, "step": step}

    # -- reporting ----------------------------------------------------------------

    def stall_fraction(self) -> float:
        tot = sum(
            m.step_time_s + m.embed_fetch_s + m.stall_s for m in self.history
        )
        stall = sum(m.stall_s for m in self.history)
        return stall / tot if tot else 0.0

    def embed_fetch_fraction(self) -> float:
        tot = sum(
            m.step_time_s + m.embed_fetch_s + m.stall_s for m in self.history
        )
        emb = sum(m.embed_fetch_s for m in self.history)
        return emb / tot if tot else 0.0
