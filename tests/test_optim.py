import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    OptimizerConfig, adamw_init, adamw_update, clip_by_global_norm,
    compress_grads, global_norm, wsd_schedule,
)


def test_adamw_minimizes_quadratic():
    cfg = OptimizerConfig(learning_rate=0.1, weight_decay=0.0, warmup_steps=1, total_steps=200)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = adamw_init(params, cfg)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(params, grads, state, cfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_clip_by_global_norm():
    g = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) == 20.0


def test_schedule_phases():
    cfg = OptimizerConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    assert float(wsd_schedule(cfg, jnp.asarray(5))) == 0.5
    assert float(wsd_schedule(cfg, jnp.asarray(50))) == 1.0
    assert float(wsd_schedule(cfg, jnp.asarray(100))) < 0.2


def test_grad_compression_roundtrip_close():
    g = {"w": jnp.linspace(-1, 1, 128)}
    d = compress_grads(g)
    assert d["w"].dtype == jnp.bfloat16
    from repro.optim import decompress_grads
    back = decompress_grads(d)
    np.testing.assert_allclose(np.asarray(back["w"]), np.asarray(g["w"]), atol=1e-2)


def test_bf16_optimizer_state():
    cfg = OptimizerConfig(state_dtype=jnp.bfloat16, warmup_steps=1, total_steps=10)
    params = {"w": jnp.ones(8)}
    state = adamw_init(params, cfg)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    params, state, _ = adamw_update(params, {"w": jnp.ones(8)}, state, cfg)
    assert state["nu"]["w"].dtype == jnp.bfloat16
    assert np.isfinite(np.asarray(params["w"])).all()


@pytest.mark.parametrize("ndim", [1, 2])
@pytest.mark.parametrize("dim", [1, 3, 17, 64])
def test_update_preserves_shapes_property(ndim, dim):
    shape = (dim,) * min(ndim, 2)
    cfg = OptimizerConfig(warmup_steps=1, total_steps=10)
    params = {"w": jnp.ones(shape)}
    state = adamw_init(params, cfg)
    p2, s2, gn = adamw_update(params, {"w": jnp.ones(shape)}, state, cfg)
    assert p2["w"].shape == shape
    assert float(gn) >= 0
