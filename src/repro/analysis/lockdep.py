"""Runtime lock-order sanitizer ("lockdep", after the Linux kernel's).

Static rules prove each class takes *its own* lock; what they cannot see
is the **order** different classes' locks nest in at run time.  The cache
read path routinely holds ``TectonicFS._mutate_lock`` while entering
``StripeCache._lock`` (read -> admit); if any other path ever nests them
the other way around, two threads can deadlock — the classic A->B / B->A
inversion, and exactly the failure shape of the PR-3 rewrite-vs-read
race.

Mechanism: :func:`patched` monkeypatches ``threading.Lock``/``RLock`` so
every lock constructed inside the ``with`` block is a :class:`TrackedLock`
named after its construction site (``file.py:123``).  Each acquisition
records edges *held-lock -> new-lock* into a shared :class:`LockGraph`
with the acquisition stacks of both ends.  A cycle in that graph means
there exists a schedule where the involved threads deadlock — no actual
deadlock needs to occur for detection, so single-threaded tests catch
inversions too.

Usage (the opt-in pytest fixture in ``tests/conftest.py``)::

    def test_heavy_concurrency(lockdep):
        ...build caches/masters/workers inside the test...
        # teardown runs lockdep.assert_no_cycles()

Locks are aggregated by construction site, not instance: two
``StripeCache`` instances share one node.  That is the useful
granularity for ordering rules (and the kernel's choice too); per-
instance ordering schemes (e.g. address-ordered lock ladders) would need
a suppression via ``LockGraph(ignore=...)``.
"""
from __future__ import annotations

import _thread
import dataclasses
import threading
import traceback
from contextlib import contextmanager
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Set, Tuple


class LockOrderError(AssertionError):
    """A cycle in the lock acquisition graph: potential deadlock."""


def _site(depth: int = 1) -> str:
    """``file.py:lineno`` of the frame ``depth`` levels above the caller —
    with the default, whoever called the caller (the ``threading.Lock()``
    construction site when called from the patched factory)."""
    frame = traceback.extract_stack(limit=depth + 2)[0]
    return f"{Path(frame.filename).name}:{frame.lineno}"


def _stack_summary(limit: int = 8) -> Tuple[str, ...]:
    frames = traceback.extract_stack()
    out = []
    for fr in frames:
        name = Path(fr.filename).name
        if name in ("lockdep.py",):
            continue
        out.append(f"{name}:{fr.lineno} in {fr.name}")
    return tuple(out[-limit:])


@dataclasses.dataclass
class _Held:
    name: str
    count: int                      # reentrant acquisitions (RLock)
    stack: Tuple[str, ...]          # where it was first acquired


@dataclasses.dataclass
class _Edge:
    src: str
    dst: str
    src_stack: Tuple[str, ...]      # acquisition stack of the held lock
    dst_stack: Tuple[str, ...]      # acquisition stack of the new lock
    thread: str


class LockGraph:
    """Thread-safe acquisition-order graph with sample stacks per edge."""

    def __init__(self, ignore: Iterable[str] = ()):
        # a REAL lock: the graph must work while threading.Lock is patched
        self._mu = _thread.allocate_lock()
        self._edges: Dict[Tuple[str, str], _Edge] = {}
        self._nodes: Set[str] = set()
        self._ignore = set(ignore)
        self._tls = threading.local()

    # -- per-thread held-lock bookkeeping (called by TrackedLock) ----------

    def _held(self) -> List[_Held]:
        held = getattr(self._tls, "held", None)
        if held is None:
            held = self._tls.held = []
        return held

    def note_acquire(self, name: str) -> None:
        held = self._held()
        for h in held:
            if h.name == name:          # reentrant re-acquire: no new edge
                h.count += 1
                return
        stack = _stack_summary()
        with self._mu:
            self._nodes.add(name)
            for h in held:
                key = (h.name, name)
                if h.name != name and key not in self._edges \
                        and h.name not in self._ignore \
                        and name not in self._ignore:
                    self._edges[key] = _Edge(
                        h.name, name, h.stack, stack,
                        threading.current_thread().name,
                    )
        held.append(_Held(name, 1, stack))

    def note_release(self, name: str) -> None:
        held = self._held()
        for i in range(len(held) - 1, -1, -1):
            if held[i].name == name:
                held[i].count -= 1
                if held[i].count == 0:
                    del held[i]
                return

    # -- analysis ----------------------------------------------------------

    def edges(self) -> List[Tuple[str, str]]:
        with self._mu:
            return sorted(self._edges)

    def cycles(self) -> List[List[str]]:
        """Elementary cycles via iterative DFS over the edge set (the graph
        is small: nodes are lock construction sites)."""
        with self._mu:
            adj: Dict[str, List[str]] = {}
            for a, b in self._edges:
                adj.setdefault(a, []).append(b)
        found: List[List[str]] = []
        seen_keys: Set[Tuple[str, ...]] = set()
        for start in sorted(adj):
            stack = [(start, iter(adj.get(start, ())))]
            path = [start]
            on_path = {start}
            while stack:
                node, it = stack[-1]
                nxt = next(it, None)
                if nxt is None:
                    stack.pop()
                    path.pop()
                    on_path.discard(node)
                    continue
                if nxt == start:
                    cyc = path + [start]
                    # canonical key: rotation-invariant
                    key = tuple(sorted(cyc[:-1]))
                    if key not in seen_keys:
                        seen_keys.add(key)
                        found.append(list(cyc))
                elif nxt not in on_path and nxt >= start:
                    # only explore nodes >= start: each cycle is reported
                    # from its smallest node exactly once
                    stack.append((nxt, iter(adj.get(nxt, ()))))
                    path.append(nxt)
                    on_path.add(nxt)
        return found

    def report(self) -> str:
        cycles = self.cycles()
        if not cycles:
            return (f"lockdep: ok — {len(self._nodes)} lock site(s), "
                    f"{len(self._edges)} ordered edge(s), no cycles")
        lines = [f"lockdep: {len(cycles)} lock-order cycle(s) — "
                 "potential deadlock:"]
        with self._mu:
            edges = dict(self._edges)
        for cyc in cycles:
            lines.append("  cycle: " + " -> ".join(cyc))
            for a, b in zip(cyc, cyc[1:]):
                e = edges.get((a, b))
                if e is None:
                    continue
                lines.append(f"    {a} held, then acquired {b} "
                             f"[thread {e.thread}]")
                lines.append(f"      {a} acquired at:")
                lines.extend(f"        {fr}" for fr in e.src_stack[-4:])
                lines.append(f"      {b} acquired at:")
                lines.extend(f"        {fr}" for fr in e.dst_stack[-4:])
        return "\n".join(lines)

    def assert_no_cycles(self) -> None:
        if self.cycles():
            raise LockOrderError(self.report())


class TrackedLock:
    """Wrapper around a real ``Lock``/``RLock`` feeding a LockGraph.

    Exposes the full lock protocol plus the private hooks
    ``threading.Condition`` uses (``_is_owned``, ``_release_save``,
    ``_acquire_restore``) so wrapped locks keep working as Condition /
    Queue / Event internals.
    """

    def __init__(self, graph: LockGraph, name: str, inner, reentrant: bool):
        self._graph = graph
        self._name = name
        self._inner = inner
        self._reentrant = reentrant

    # -- core protocol -----------------------------------------------------

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            self._graph.note_acquire(self._name)
        return got

    def release(self) -> None:
        self._inner.release()
        self._graph.note_release(self._name)

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __repr__(self) -> str:
        return f"<TrackedLock {self._name} wrapping {self._inner!r}>"

    # -- Condition integration --------------------------------------------

    def _is_owned(self) -> bool:
        if hasattr(self._inner, "_is_owned"):
            return self._inner._is_owned()
        # plain Lock: owned iff this thread has it in its held list
        return any(
            h.name == self._name for h in self._graph._held()
        ) and self._inner.locked()

    def _release_save(self):
        held = self._graph._held()
        count = next((h.count for h in held if h.name == self._name), 1)
        if hasattr(self._inner, "_release_save"):
            state = self._inner._release_save()
        else:
            self._inner.release()
            state = None
        for _ in range(count):
            self._graph.note_release(self._name)
        return (state, count)

    def _acquire_restore(self, saved) -> None:
        state, count = saved
        if state is not None and hasattr(self._inner, "_acquire_restore"):
            self._inner._acquire_restore(state)
        else:
            self._inner.acquire()
        # re-entering after a wait() is a real acquisition ordering event
        self._graph.note_acquire(self._name)
        for _ in range(count - 1):
            self._graph.note_acquire(self._name)


@contextmanager
def patched(
    graph: Optional[LockGraph] = None,
    name_filter: Optional[Callable[[str], bool]] = None,
):
    """Patch ``threading.Lock``/``RLock`` so locks born inside the block
    are tracked in ``graph`` (a fresh one by default).  Yields the graph.

    ``name_filter(site) -> bool`` limits tracking to interesting sites
    (e.g. ``lambda s: s.startswith(("stripe_cache", "tectonic"))``) —
    unfiltered runs also track stdlib ``queue``/``Condition`` internals,
    which is harmless for cycle detection but noisier to read.
    """
    g = graph if graph is not None else LockGraph()
    real_lock, real_rlock = threading.Lock, threading.RLock

    def make_lock():
        site = _site()
        inner = real_lock()
        if name_filter is not None and not name_filter(site):
            return inner
        return TrackedLock(g, site, inner, reentrant=False)

    def make_rlock():
        site = _site()
        inner = real_rlock()
        if name_filter is not None and not name_filter(site):
            return inner
        return TrackedLock(g, site, inner, reentrant=True)

    threading.Lock = make_lock
    threading.RLock = make_rlock
    try:
        yield g
    finally:
        threading.Lock = real_lock
        threading.RLock = real_rlock
