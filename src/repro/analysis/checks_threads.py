"""Thread-hygiene rules (REPRO-T001/T002).

The DPP fleet leans hard on background threads (workers, producers,
monitors, prefetch fills).  Two failure shapes keep reappearing in
concurrency post-mortems, so they are banned statically:

  * **T001** — a ``threading.Thread`` that is neither ``daemon=True`` nor
    ever ``join()``-ed: it outlives the test/session that spawned it and
    wedges interpreter shutdown.  A thread passes when its constructor
    has a literal ``daemon=True``, its target variable/attribute is
    ``.join()``-ed (or ``.daemon = True``-ed) somewhere in the module, or
    it is collected into a container that is iterated and joined.
  * **T002** — bare ``except:`` — it swallows ``KeyboardInterrupt`` and
    ``SystemExit``, turning a Ctrl-C during a stuck drain into a hung
    worker.  Catch ``Exception`` (or ``BaseException`` where a re-raise
    follows) instead.
"""
from __future__ import annotations

import ast
from typing import List, Optional

from repro.analysis.core import CheckContext, Finding, attr_chain, checker, \
    enclosing_symbol, rule

T001 = rule("REPRO-T001",
            "threading.Thread neither daemonized nor joined — leaks past "
            "shutdown")
T002 = rule("REPRO-T002",
            "bare `except:` swallows KeyboardInterrupt/SystemExit")


def _is_thread_ctor(node: ast.Call) -> bool:
    chain = attr_chain(node.func)
    return bool(chain) and chain[-1] == "Thread" and (
        len(chain) == 1 or chain[-2] == "threading"
    )


def _daemon_true(node: ast.Call) -> bool:
    for kw in node.keywords:
        if kw.arg == "daemon" and isinstance(kw.value, ast.Constant):
            return kw.value.value is True
    return False


def _assigned_names(parents: List[ast.AST]) -> List[str]:
    """Names/attrs the Thread(...) value is bound to via the direct parent
    statement: ``t = Thread()`` / ``self._t = Thread()``."""
    out: List[str] = []
    stmt = parents[-1] if parents else None
    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            chain = attr_chain(t)
            if chain:
                out.append(".".join(chain))
    elif isinstance(stmt, ast.AnnAssign):
        chain = attr_chain(stmt.target)
        if chain:
            out.append(".".join(chain))
    return out


class _ThreadScan(ast.NodeVisitor):
    def __init__(self, tree: ast.Module):
        self.tree = tree
        self.joined: set = set()       # dotted names x where x.join(...) occurs
        self.daemoned: set = set()     # dotted names x where x.daemon = True
        self.any_loop_join = False     # for t in <...>: t.join() patterns
        self._collect()

    def _collect(self) -> None:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "join":
                chain = attr_chain(node.func.value)
                if chain:
                    self.joined.add(".".join(chain))
                    self.any_loop_join = True
            if isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Attribute) and t.attr == "daemon":
                        chain = attr_chain(t.value)
                        if chain and isinstance(node.value, ast.Constant) \
                                and node.value.value is True:
                            self.daemoned.add(".".join(chain))


@checker("thread-hygiene")
def check_threads(ctx: CheckContext):
    findings: List[Finding] = []
    for mod in ctx.src_modules():
        scan = _ThreadScan(mod.tree)
        # walk with parent statements so we can see what a ctor binds to
        stack: List[ast.AST] = []

        def walk(node: ast.AST) -> None:
            if isinstance(node, ast.Call) and _is_thread_ctor(node):
                if not _daemon_true(node):
                    names = _assigned_names(
                        [p for p in stack if isinstance(p, ast.stmt)][-1:]
                    )
                    covered = any(
                        n in scan.joined or n in scan.daemoned for n in names
                    )
                    # threads built inline into a joined/iterated container
                    # (e.g. `threads = [Thread(...) for ...]` + loop join):
                    in_comp = any(
                        isinstance(p, (ast.ListComp, ast.SetComp,
                                       ast.GeneratorExp))
                        for p in stack
                    )
                    in_container = (not names or in_comp) \
                        and scan.any_loop_join
                    if not covered and not in_container:
                        findings.append(Finding(
                            T001, mod.rel, node.lineno,
                            "thread is neither daemon=True nor joined in "
                            "this module",
                            enclosing_symbol([
                                p for p in stack
                                if isinstance(p, (ast.ClassDef, ast.FunctionDef,
                                                  ast.AsyncFunctionDef))
                            ]),
                        ))
            stack.append(node)
            for child in ast.iter_child_nodes(node):
                walk(child)
            stack.pop()

        walk(mod.tree)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                findings.append(Finding(
                    T002, mod.rel, node.lineno,
                    "bare `except:` — catch Exception (or BaseException + "
                    "re-raise)",
                ))
    return findings
