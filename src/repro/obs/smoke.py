"""Traced two-tenant DPP smoke run — the stall report's input producer.

``python -m repro.obs.smoke --out trace.json [--rows N]`` spins up a
``DPPService`` with a live :class:`repro.obs.Tracer`, runs two tenants
concurrently over one warehouse (the combo-window shape of §5.2: tenant B
re-reads tenant A's table through the shared stripe cache) and writes the
Chrome-trace artifact with each tenant's registry snapshot embedded as
the ``metrics`` payload.  ``python -m repro.obs.report trace.json
--check`` then validates the whole telemetry path end to end — the smoke
stage ``scripts/ci.sh`` runs on every commit.
"""
from __future__ import annotations

import argparse
from typing import List, Optional

from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.dpp import DPPService, SessionSpec
from repro.core.schema import make_schema
from repro.core.tectonic import TectonicFS
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Table, Warehouse
from repro.obs import Tracer

STRIPE = 256


def _make_table(wh: Warehouse, name: str, n_parts: int, rows: int) -> Table:
    t = wh.create_table(make_schema(name, 20, 6, seed=0))
    t.generate(
        n_parts, DataGenConfig(rows_per_partition=rows, seed=1),
        dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE),
    )
    return t


def _spec(t: Table) -> SessionSpec:
    dense = t.schema.dense_ids[:6]
    sparse = t.schema.sparse_ids[:3]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=500)
    return SessionSpec(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=256, rows_per_split=256,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )


def run_smoke(out: str, rows: int = 512, latency: float = 1.0) -> dict:
    """Run the traced two-tenant session pair and write the artifact.
    Returns the per-tenant batch lists (for callers asserting delivery)."""
    tracer = Tracer()
    wh = Warehouse(TectonicFS(io_latency_scale=latency))
    table = _make_table(wh, "obs_smoke", 2, rows)
    svc = DPPService(wh, tracer=tracer)
    spec = _spec(table)
    # two tenants over the same table: tenant_b's reads land on the
    # stripes tenant_a already pulled, so the trace shows both
    # storage.read (cold) and cache.hit/fill (warm) paths
    svc.create_session("tenant_a", spec, dram_share=0.2, n_workers=2)
    svc.create_session("tenant_b", spec, dram_share=0.2, n_workers=2)
    results = svc.run_all(timeout_s=120)
    metrics = {
        "tenants": {
            name: sess.registry.snapshot().values
            for name, sess in svc.sessions.items()
        },
        "cache": svc.tenant_summary(),
    }
    tracer.write(out, metrics=metrics)
    return results


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.smoke",
        description="traced two-tenant DPP run -> Chrome-trace artifact",
    )
    ap.add_argument("--out", required=True, help="artifact path (JSON)")
    ap.add_argument("--rows", type=int, default=512,
                    help="rows per partition (2 partitions per tenant)")
    args = ap.parse_args(argv)
    results = run_smoke(args.out, rows=args.rows)
    for name in sorted(results):
        print(f"{name}: {len(results[name])} batches")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
