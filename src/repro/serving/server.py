"""Continuous-batching LM server: slot-managed prefill + decode.

Serving-side runtime matching the dry-run ``decode_32k`` shape: a fixed
pool of B cache slots; arriving requests are prefilled into a free slot
(cache rows written at their slot index); every engine tick decodes one
token for all active slots.  Per-slot positions are tracked host-side and
passed as a vector so heterogeneous sequence lengths coexist in one batch
(the decode path masks by per-slot position).

This is intentionally a single-process engine (the multi-host version
shards the same cache over the serving mesh via SERVE_RULES; see
steps.make_decode_step).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import build_model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int = 16
    # filled by the server:
    output: List[int] = dataclasses.field(default_factory=list)
    submitted_s: float = 0.0
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None


@dataclasses.dataclass
class ServerConfig:
    slots: int = 4
    cache_len: int = 256
    eos_id: int = -1                    # -1: never stop early


class BatchingServer:
    def __init__(self, model_cfg: Any, cfg: ServerConfig, seed: int = 0):
        self.cfg = cfg
        self.model_cfg = model_cfg
        self.model = build_model(model_cfg)
        self.params = self.model.init(jax.random.PRNGKey(seed))
        self.cache = jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.model.abstract_cache(cfg.slots, cfg.cache_len),
        )
        self._decode = jax.jit(self.model.decode_step)
        self._active: Dict[int, Request] = {}      # slot -> request
        self._pos = np.zeros(cfg.slots, np.int32)  # next write position per slot
        self._queue: List[Request] = []
        self._next_token = np.zeros((cfg.slots, 1), np.int32)

    # -- API -----------------------------------------------------------------

    def submit(self, req: Request) -> None:
        req.submitted_s = time.perf_counter()
        self._queue.append(req)

    def run(self, max_ticks: int = 1000) -> List[Request]:
        """Drive the engine until queue + slots drain; returns finished."""
        finished: List[Request] = []
        for _ in range(max_ticks):
            self._admit(finished)
            if not self._active:
                if not self._queue:
                    break
                continue
            self._tick(finished)
        return finished

    # -- internals --------------------------------------------------------------

    def _free_slots(self) -> List[int]:
        return [s for s in range(self.cfg.slots) if s not in self._active]

    def _admit(self, finished: List[Request]) -> None:
        """Prefill queued requests into free slots (token-by-token replay:
        keeps one jitted decode program; a production engine would use the
        chunked prefill kernel here).  The final replay step's argmax IS the
        first generated token — emit it here."""
        for slot in self._free_slots():
            if not self._queue:
                return
            req = self._queue.pop(0)
            self._active[slot] = req
            self._pos[slot] = 0
            for tok in req.prompt:
                self._write_token(slot, int(tok))
            self._emit(slot, int(self._next_token[slot, 0]), finished)

    def _write_token(self, slot: int, token: int) -> None:
        """Advance one position of one slot through the decode program."""
        tok_vec = np.zeros((self.cfg.slots, 1), np.int32)
        tok_vec[slot, 0] = token
        batch = {
            "token": jnp.asarray(tok_vec),
            "pos": jnp.asarray(int(self._pos[slot]), jnp.int32),
            "cache": self.cache,
        }
        logits, self.cache = self._decode(self.params, batch)
        self._pos[slot] += 1
        self._next_token[slot, 0] = int(np.argmax(np.asarray(logits[slot, 0])))

    def _tick(self, finished: List[Request]) -> None:
        """One decode step for every active slot (true continuous batching:
        all slots advance in a single jitted call when positions align; the
        general unequal-position case falls back to per-slot steps)."""
        positions = {self._pos[s] for s in self._active}
        if len(positions) == 1:
            pos = positions.pop()
            batch = {
                "token": jnp.asarray(self._next_token),
                "pos": jnp.asarray(int(pos), jnp.int32),
                "cache": self.cache,
            }
            logits, self.cache = self._decode(self.params, batch)
            toks = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)
            for s in list(self._active):
                self._pos[s] += 1
                self._emit(s, int(toks[s]), finished)
            self._next_token = toks[:, None]
        else:
            for s in list(self._active):
                self._write_token(s, int(self._next_token[s, 0]))
                self._emit(s, int(self._next_token[s, 0]), finished)

    def _emit(self, slot: int, token: int, finished: List[Request]) -> None:
        req = self._active[slot]
        if req.first_token_s is None:
            req.first_token_s = time.perf_counter()
        req.output.append(token)
        done = (
            len(req.output) >= req.max_new_tokens
            or token == self.cfg.eos_id
            or self._pos[slot] >= self.cfg.cache_len - 1
        )
        if done:
            req.done_s = time.perf_counter()
            finished.append(req)
            del self._active[slot]

    # -- metrics -------------------------------------------------------------------

    @staticmethod
    def latency_report(reqs: List[Request]) -> Dict[str, float]:
        ttft = [r.first_token_s - r.submitted_s for r in reqs if r.first_token_s]
        e2e = [r.done_s - r.submitted_s for r in reqs if r.done_s]
        toks = sum(len(r.output) for r in reqs)
        wall = max((r.done_s or 0) for r in reqs) - min(r.submitted_s for r in reqs)
        return {
            "requests": len(reqs),
            "ttft_p50_s": float(np.percentile(ttft, 50)) if ttft else 0.0,
            "e2e_p50_s": float(np.percentile(e2e, 50)) if e2e else 0.0,
            "decode_tok_per_s": toks / wall if wall > 0 else 0.0,
        }
