import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    SERVE_RULES, TRAIN_RULES, AxisRules, logical_to_spec,
)
from repro.models.common import partition_specs
from repro.models import build_model
from repro import configs as cfglib


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_basic_mapping(mesh):
    spec = logical_to_spec(("batch", "seq", "heads", None), TRAIN_RULES, mesh)
    assert spec == P(("pod", "data") if "pod" in mesh.axis_names else "data", None, "model")


def test_divisibility_fallback():
    m = jax.make_mesh((1, 1), ("data", "model"))
    # shape 8 on a (fake) 16-wide model axis -> replicate; here model=1 so ok
    spec = logical_to_spec(("kv_heads", None), TRAIN_RULES, m, shape=(8, 128))
    assert spec in (P("model"), P())


def test_no_mesh_axis_reuse(mesh):
    # heads and mlp both map to "model"; second one must fall back
    spec = logical_to_spec(("heads", "mlp"), TRAIN_RULES, mesh)
    axes = [a for a in spec if a is not None]
    assert len(axes) == len(set(axes))


def test_pod_axis_dropped_on_single_pod(mesh):
    spec = logical_to_spec(("batch",), TRAIN_RULES, mesh)
    # single-pod mesh has no "pod" axis; batch maps to data only
    assert spec == P("data")


def test_param_partition_specs_cover_tree(mesh):
    model = build_model(cfglib.get_smoke_config("qwen3-8b"))
    specs = partition_specs(model.param_specs(), TRAIN_RULES, mesh)
    leaves = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert all(isinstance(l, P) for l in leaves)
    n_params = len(jax.tree.leaves(model.abstract()))
    assert len(leaves) == n_params


def test_serve_rules_replicate_embed(mesh):
    s_train = logical_to_spec(("embed", "mlp"), TRAIN_RULES, mesh)
    s_serve = logical_to_spec(("embed", "mlp"), SERVE_RULES, mesh)
    assert s_train[0] == "data"
    assert len(s_serve) == 0 or s_serve[0] is None
