"""LM token path through the DSI pipeline.

The paper's DPP is model-agnostic: LM training jobs consume the same
warehouse/DPP substrate with a token-packing flavor instead of the DLRM
sparse-feature transforms.  Documents are stored as a sparse column
(variable-length token-id lists) in a partitioned table; the packing
transform concatenates documents into fixed-length training sequences
(with EOS separators), which is the "materialize tensors" step for LMs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import dwrf
from repro.core.schema import ColumnBatch, SparseColumn, TableSchema, FeatureDef, FeatureType
from repro.core.warehouse import Table, Warehouse

DOC_FEATURE_ID = 0
EOS = 0


def token_schema(name: str = "lm_docs") -> TableSchema:
    return TableSchema(
        name=name,
        features={
            DOC_FEATURE_ID: FeatureDef(
                fid=DOC_FEATURE_ID, name="tokens", ftype=FeatureType.SPARSE,
                coverage=1.0, avg_length=512.0, cardinality=1 << 31,
            )
        },
    )


def generate_documents(
    n_docs: int, vocab_size: int, seed: int = 0,
    mean_len: float = 512.0,
) -> ColumnBatch:
    """Synthetic corpus partition: Zipf tokens, log-normal doc lengths."""
    rng = np.random.default_rng(seed)
    lengths = np.clip(
        rng.lognormal(np.log(mean_len), 0.6, n_docs), 16, 8 * mean_len
    ).astype(np.int64)
    offsets = np.zeros(n_docs + 1, np.int64)
    np.cumsum(lengths, out=offsets[1:])
    toks = (rng.zipf(1.3, int(offsets[-1])) % (vocab_size - 1) + 1).astype(np.int64)
    col = SparseColumn(offsets=offsets, values=toks)
    return ColumnBatch(num_rows=n_docs, dense={}, sparse={DOC_FEATURE_ID: col})


def build_corpus(
    wh: Warehouse, n_partitions: int, docs_per_partition: int,
    vocab_size: int, seed: int = 0, name: str = "lm_docs",
) -> Table:
    table = wh.create_table(token_schema(name))
    for p in range(n_partitions):
        batch = generate_documents(docs_per_partition, vocab_size, seed=(seed, p).__hash__() & 0x7FFFFFFF)
        table.write_partition(p, batch, dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
    return table


@dataclasses.dataclass
class PackState:
    """Carry-over tokens between splits (documents span split boundaries)."""
    leftover: np.ndarray = dataclasses.field(default_factory=lambda: np.zeros(0, np.int64))


def pack_sequences(
    docs: SparseColumn,
    seq_len: int,
    state: Optional[PackState] = None,
) -> Tuple[np.ndarray, PackState]:
    """Concatenate docs (EOS-separated) into (n, seq_len+1) int32 rows; the
    +1 column provides next-token labels via shifting."""
    state = state or PackState()
    parts: List[np.ndarray] = [state.leftover]
    for i in range(docs.rows):
        parts.append(docs.row(i))
        parts.append(np.asarray([EOS], np.int64))
    stream = np.concatenate(parts) if parts else np.zeros(0, np.int64)
    stride = seq_len + 1
    n = len(stream) // stride
    packed = stream[: n * stride].reshape(n, stride).astype(np.int32)
    return packed, PackState(leftover=stream[n * stride:])


def lm_batches_from_table(
    table: Table,
    seq_len: int,
    batch_size: int,
    partitions: Optional[List[int]] = None,
) -> Iterator[Dict[str, np.ndarray]]:
    """One-epoch LM batch stream: selective read -> pack -> batch."""
    from repro.core.reader import TableReader

    reader = TableReader(table, [DOC_FEATURE_ID])
    state = PackState()
    buf: List[np.ndarray] = []
    for meta in table.select_partitions(partitions):
        res = reader.read_partition(meta)
        packed, state = pack_sequences(res.batch.sparse[DOC_FEATURE_ID], seq_len, state)
        buf.append(packed)
        rows = np.concatenate(buf) if buf else np.zeros((0, seq_len + 1), np.int32)
        while len(rows) >= batch_size:
            chunk, rows = rows[:batch_size], rows[batch_size:]
            yield {"tokens": chunk[:, :-1], "labels": chunk[:, 1:]}
        buf = [rows]
    reader.finish_job()
