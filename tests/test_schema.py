import numpy as np
import pytest

from repro.core.schema import (
    ColumnBatch, FeatureStatus, SparseColumn, concat_batches, make_schema,
)
from repro.core.datagen import DataGenConfig, generate_partition


def test_make_schema_counts():
    s = make_schema("t", 100, 20, seed=0)
    assert len(s.dense_ids) == 100
    assert len(s.sparse_ids) == 20
    assert len(s.logged_ids) == 120


def test_feature_lifecycle_evolution():
    s = make_schema("t", 50, 10, seed=0)
    rng = np.random.default_rng(1)
    before = len(s.features)
    s.evolve(rng, n_new=30)
    counts = s.status_counts()
    assert len(s.features) == before + 30
    assert counts.get("experimental", 0) > 0


def test_generate_partition_coverage_and_labels():
    s = make_schema("t", 30, 8, seed=2)
    b = generate_partition(s, 0, DataGenConfig(rows_per_partition=512, seed=3))
    assert b.num_rows == 512
    assert b.labels is not None and b.labels.shape == (512,)
    # coverage: NaN fraction roughly matches 1-coverage for a dense feature
    fid = s.dense_ids[0]
    cov = s.feature(fid).coverage
    observed = 1.0 - np.isnan(b.dense[fid]).mean()
    assert abs(observed - cov) < 0.15


def test_slice_concat_roundtrip():
    s = make_schema("t", 10, 4, seed=4)
    b = generate_partition(s, 0, DataGenConfig(rows_per_partition=256, seed=5))
    parts = [b.slice_rows(0, 100), b.slice_rows(100, 256)]
    merged = concat_batches(parts)
    assert merged.num_rows == 256
    for fid in b.dense:
        np.testing.assert_array_equal(
            np.nan_to_num(merged.dense[fid]), np.nan_to_num(b.dense[fid])
        )
    for fid in b.sparse:
        np.testing.assert_array_equal(merged.sparse[fid].values, b.sparse[fid].values)
        np.testing.assert_array_equal(merged.sparse[fid].offsets, b.sparse[fid].offsets)


@pytest.mark.parametrize("seed", range(25))
def test_sparse_column_slice_property(seed):
    rng = np.random.default_rng(seed)
    lengths = rng.integers(0, 7, size=int(rng.integers(1, 21))).tolist()
    start_frac, width_frac = rng.random(), rng.random()
    n = len(lengths)
    off = np.zeros(n + 1, np.int64)
    np.cumsum(lengths, out=off[1:])
    vals = np.arange(off[-1], dtype=np.int64)
    col = SparseColumn(offsets=off, values=vals)
    batch = ColumnBatch(num_rows=n, dense={}, sparse={0: col})
    start = int(start_frac * n)
    stop = min(n, start + max(1, int(width_frac * n)))
    if start >= stop:
        return
    sub = batch.slice_rows(start, stop)
    sc = sub.sparse[0]
    assert sc.rows == stop - start
    for i in range(sc.rows):
        np.testing.assert_array_equal(sc.row(i), col.row(start + i))
