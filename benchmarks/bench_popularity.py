"""Fig. 7: CDF of popular bytes vs read traffic absorbed across jobs."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.reader import TableReader
from repro.core.schema import make_schema
from repro.core.warehouse import Warehouse


def run() -> None:
    schema = make_schema("fig7", n_dense=600, n_sparse=90, seed=0)
    wh = Warehouse()
    t = wh.create_table(schema)
    t.generate(2, DataGenConfig(rows_per_partition=1024, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
    rng = np.random.default_rng(0)
    fids = np.array(schema.logged_ids)
    pops = np.array([schema.feature(f).popularity for f in fids]); pops /= pops.sum()

    # a month of jobs for one model: overlapping popularity-weighted projections
    for job in range(16):
        proj = rng.choice(fids, size=len(fids) // 9, replace=False, p=pops)
        r = TableReader(t, sorted(proj.tolist()))
        r.read_partition(t.partitions[job % 2])
        r.finish_job()

    stored = {}
    for m in t.partitions.values():
        for s in m.footer.stripes:
            for st_ in s.streams:
                if st_.fid >= 0:
                    stored[st_.fid] = stored.get(st_.fid, 0.0) + st_.length
    for target in (0.5, 0.8, 0.95):
        frac = t.popularity.bytes_fraction_for_traffic(stored, target)
        emit(
            f"fig7.bytes_for_{int(target*100)}pct_traffic", 0.0,
            f"{frac*100:.1f}% of stored bytes (paper @80%: 18-39%)",
        )
