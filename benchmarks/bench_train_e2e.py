"""Closed training loop: DPP output -> tiered-embedding Trainer -> DLRM.

The ISSUE-9 gate, two assertions on a live run:

  (a) **frequency-aware tiering pays** — under the warehouse's Zipf id
      traffic the tiered store's device hot-rate must be at least the
      *pinned bound*: the hit rate a same-capacity static placement
      (rows ``0..H-1`` pinned up front, no adaptation) achieves on the
      exact same traffic.  Admission-by-popularity has to beat blind
      pinning or the whole tier is dead weight.
  (b) **the Table-7 row closes** — the traced run's artifact passes the
      report ``check`` gate and its stall attribution (data stall /
      embedding fetch / compute, summing to 100) is emitted into
      ``BENCH_quick.json`` via ``emit_report``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit, emit_report
from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.dpp import DPPService, SessionSpec
from repro.core.schema import make_schema
from repro.core.tectonic import TectonicFS
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse
from repro.models.dlrm import DLRMConfig
from repro.obs import Tracer
from repro.obs.report import build_report, check
from repro.optim import OptimizerConfig
from repro.train import Trainer, TrainerConfig, make_store_for_model

HOT_ROWS = 64          # device-tier capacity per table (of 500-row vocab)
EPOCHS = 8             # live epoch + replays: enough traffic to converge


def _cfg() -> DLRMConfig:
    return DLRMConfig(
        num_dense=6, num_tables=3, vocab_per_table=500, embed_dim=8,
        max_ids_per_feature=8, bottom_mlp=(16, 8), top_mlp=(32, 1),
    )


def _session(rows: int, tracer):
    cfg = _cfg()
    wh = Warehouse(TectonicFS(io_latency_scale=0.5))
    schema = make_schema("bench_train_e2e", 8, 6, seed=0)
    table = wh.create_table(schema)
    table.generate(
        2, DataGenConfig(rows_per_partition=rows, seed=1),
        dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256),
    )
    dense = schema.dense_ids[: cfg.num_dense]
    sparse = schema.sparse_ids[: cfg.num_tables]
    pipe = default_dlrm_pipeline(
        dense, sparse, hash_size=cfg.vocab_per_table,
        firstx=cfg.max_ids_per_feature,
    )
    spec = SessionSpec(
        table=schema.name, partitions=(0, 1),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=128, rows_per_split=256,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=cfg.max_ids_per_feature,
    )
    svc = DPPService(wh, tracer=tracer)
    return cfg, svc, svc.create_session("train", spec, n_workers=2)


def _batches(sess, recorded: list, epochs: int):
    """Live epoch off the DPP client, then replay (steady-state traffic)."""
    while True:
        b = sess.clients[0].get_batch(timeout=5.0)
        if b is None:
            if sess.master.finished and all(
                w.buffered == 0 for w in sess.workers
            ):
                break
            continue
        recorded.append(b)
        yield b
    for _ in range(epochs - 1):
        for b in recorded:
            yield b


def _pinned_hot_rate(batches, hot_rows: int) -> float:
    """Hit rate of the no-adaptation baseline: rows 0..H-1 pinned on
    device before the run, measured over the same masked id traffic."""
    hits = total = 0
    for b in batches:
        live = b["sparse_mask"] > 0.0
        hits += int(((b["sparse_ids"] < hot_rows) & live).sum())
        total += int(live.sum())
    return hits / total if total else 0.0


def run(quick: bool = False) -> None:
    rows = 512 if quick else 2048
    tracer = Tracer()
    cfg, svc, sess = _session(rows, tracer)
    store = make_store_for_model(
        cfg, HOT_ROWS, seed=3, admit_reads=2, host_dram_rows=128
    )
    n_batches = 2 * rows // 128
    steps = EPOCHS * n_batches
    trainer = Trainer(
        cfg,
        OptimizerConfig(learning_rate=1e-2, warmup_steps=8, total_steps=steps),
        TrainerConfig(
            max_steps=steps, tenant="train",
            trace_stall=False,          # the DPP client records client.stall
            kernel_bags=True,           # fully-hot bags via the Pallas kernel
        ),
        embedding_store=store,
        tracer=tracer,
    )
    recorded: list = []
    sess.start()
    t0 = time.perf_counter()
    try:
        trainer.fit(_batches(sess, recorded, EPOCHS))
    finally:
        sess.stop()
    wall_s = time.perf_counter() - t0
    assert len(recorded) == n_batches, (
        f"DPP delivered {len(recorded)} batches, expected {n_batches}"
    )
    losses = [m.loss for m in trainer.history]
    assert losses[-1] < losses[0], "training loop did not reduce the loss"

    # (a) the frequency-aware tier must beat same-capacity static pinning
    tiered = store.stats.hot_rate
    pinned = _pinned_hot_rate(recorded, HOT_ROWS)
    assert tiered >= pinned, (
        f"tiered hot-rate {tiered:.3f} below the pinned bound {pinned:.3f}"
    )
    emit(
        "train_e2e.hot_rate", wall_s * 1e6 / max(len(trainer.history), 1),
        f"tiered={tiered:.3f} pinned={pinned:.3f} "
        f"kernel_bags={store.stats.kernel_bags}",
    )

    # (b) Table-7 row: artifact passes the report gate; shares close at 100
    fd, path = tempfile.mkstemp(prefix="train_e2e_", suffix=".json")
    os.close(fd)
    try:
        metrics = {
            "tenants": {
                "train": {
                    **sess.registry.snapshot().values,
                    **trainer.registry.snapshot().values,
                },
            },
            "cache": svc.tenant_summary(),
        }
        tracer.write(path, metrics=metrics)
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    finally:
        os.unlink(path)
    errs = check(doc)
    assert errs == [], f"trace artifact failed report checks: {errs}"
    report = build_report(doc)
    row = report["train"]
    data_pct = 100.0 - row["compute_pct"] - row["embed_fetch_pct"]
    assert row["embed_fetch_pct"] > 0.0, "no embed.fetch share attributed"
    emit_report("train_e2e.table7", report)
    emit(
        "train_e2e.step_breakdown", row["wall_us"],
        f"data_pct={data_pct:.2f} embed_pct={row['embed_fetch_pct']:.2f} "
        f"compute_pct={row['compute_pct']:.2f} "
        f"loss0={losses[0]:.4f} lossN={losses[-1]:.4f}",
    )
