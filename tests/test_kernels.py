import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("shape", [(8, 128), (64, 256), (256, 512), (100, 130)])
@pytest.mark.parametrize("max_value", [10, 1000, 1 << 20])
def test_sigrid_hash_sweep(shape, max_value):
    ids = jax.random.randint(KEY, shape, 0, 1 << 30, jnp.int32)
    a = ops.sigrid_hash(ids, 13, max_value, use_pallas=True)
    b = ref.sigrid_hash(ids, 13, max_value)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(jnp.max(a)) < max_value


@pytest.mark.parametrize("shape", [(16, 128), (128, 384)])
@pytest.mark.parametrize("nb", [4, 16, 63])
def test_bucketize_sweep(shape, nb):
    vals = jax.random.normal(KEY, shape, jnp.float32) * 3
    borders = jnp.sort(jax.random.normal(jax.random.PRNGKey(1), (nb,)))
    a = ops.bucketize(vals, borders, use_pallas=True)
    b = ref.bucketize(vals, borders)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("rows,feats", [(32, 128), (128, 640), (64, 100)])
def test_fused_transform_sweep(rows, feats):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    ids = jax.random.randint(k1, (rows, feats), -1000, 1 << 20, jnp.int32)
    codes = jax.random.randint(k2, (feats,), 0, 5, jnp.int32)
    p0 = jax.random.randint(k3, (feats,), 1, 1000, jnp.int32)
    p1 = jax.random.randint(k4, (feats,), 1, 100000, jnp.int32)
    a = ops.fused_transform(ids, codes, p0, p1, use_pallas=True)
    b = ref.fused_transform(ids, codes, p0, p1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("rows,feats,nb", [(16, 96, 7), (50, 130, 63)])
def test_fused_transform_float_ops_sweep(rows, feats, nb):
    """CLAMP_F / BUCKETIZE_F lanes: float32 bits + per-feature borders."""
    rng = np.random.default_rng(3)
    codes = rng.integers(0, 7, feats).astype(np.int32)
    ids = rng.integers(-(1 << 20), 1 << 20, (rows, feats)).astype(np.int32)
    p0 = rng.integers(1, 1000, feats).astype(np.int32)
    p1 = rng.integers(1, 100000, feats).astype(np.int32)
    fmask = np.isin(codes, (5, 6))
    ids[:, fmask] = (
        rng.normal(0, 3, (rows, int(fmask.sum()))).astype(np.float32).view(np.int32)
    )
    p0[codes == 5] = np.float32(-1.5).view(np.int32)
    p1[codes == 5] = np.float32(2.5).view(np.int32)
    borders = np.full((feats, nb), np.inf, np.float32)
    bmask = codes == 6
    borders[bmask] = np.sort(
        rng.normal(0, 2, (int(bmask.sum()), nb)).astype(np.float32), axis=1
    )
    args = [jnp.asarray(x) for x in (ids, codes, p0, p1, borders)]
    a = ops.fused_transform(*args, use_pallas=True)
    b = ref.fused_transform(*args)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("v,e,b,l", [(64, 8, 4, 4), (512, 64, 8, 16), (128, 128, 3, 7)])
def test_embedding_bag_sweep(v, e, b, l):
    table = jax.random.normal(KEY, (v, e), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(2), (b, l), 0, v, jnp.int32)
    mask = (jax.random.uniform(jax.random.PRNGKey(3), (b, l)) > 0.4).astype(jnp.float32)
    a = ops.embedding_bag(table, ids, mask, use_pallas=True)
    bb = ref.embedding_bag(table, ids, mask)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,s,d,causal", [
    (1, 2, 128, 64, True), (2, 4, 256, 64, True), (2, 2, 256, 128, False),
])
def test_flash_attention_sweep(b, h, s, d, causal, dtype):
    q = jax.random.normal(KEY, (b, h, s, d), dtype)
    k = jax.random.normal(jax.random.PRNGKey(4), (b, h, s, d), dtype)
    v = jax.random.normal(jax.random.PRNGKey(5), (b, h, s, d), dtype)
    a = ops.flash_attention(q, k, v, causal=causal, use_pallas=True)
    bb = ref.flash_attention(q, k, v, causal=causal)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(bb, np.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("bh,s,p,n,chunk", [
    (2, 64, 32, 16, 16), (4, 128, 64, 32, 32), (1, 96, 64, 64, 32),
])
def test_ssd_chunk_kernel_sweep(bh, s, p, n, chunk):
    x = jax.random.normal(KEY, (bh, s, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(6), (bh, s)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(7), (bh,)) * 0.3)
    b_ = jax.random.normal(jax.random.PRNGKey(8), (bh, s, n)) * 0.5
    c_ = jax.random.normal(jax.random.PRNGKey(9), (bh, s, n)) * 0.5
    yk = ops.ssd_chunk_forward(x, dt, a, b_, c_, chunk=chunk, use_pallas=True)
    yr = ref.ssd_chunk_forward(x, dt, a, b_, c_)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), atol=5e-4, rtol=1e-3)


def test_fused_transform_static_matches_general():
    """Static-codes oracle == general oracle, in both tile layouts."""
    rng = np.random.default_rng(4)
    rows, feats, nb = 40, 70, 5
    codes = rng.integers(0, 7, feats).astype(np.int32)
    ids = rng.integers(-(1 << 20), 1 << 20, (rows, feats)).astype(np.int32)
    p0 = rng.integers(1, 100, feats).astype(np.int32)
    p1 = rng.integers(1, 1000, feats).astype(np.int32)
    fmask = np.isin(codes, (5, 6))
    ids[:, fmask] = (
        rng.normal(0, 3, (rows, int(fmask.sum()))).astype(np.float32).view(np.int32)
    )
    borders = np.full((feats, nb), np.inf, np.float32)
    borders[codes == 6] = np.sort(
        rng.normal(0, 2, (int((codes == 6).sum()), nb)).astype(np.float32), axis=1
    )
    args = [jnp.asarray(x) for x in (ids, codes, p0, p1, borders)]
    general = np.asarray(ref.fused_transform(*args))
    static = np.asarray(ref.fused_transform_static(
        args[0], tuple(int(c) for c in codes), args[2], args[3], args[4]
    ))
    np.testing.assert_array_equal(general, static)
    static_fm = np.asarray(ref.fused_transform_static(
        args[0].T, tuple(int(c) for c in codes), args[2], args[3], args[4],
        features_major=True,
    ))
    np.testing.assert_array_equal(general, static_fm.T)


# -- ops.py dispatch contract (off-TPU routing) ------------------------------


def test_ops_dispatch_routes_off_tpu(monkeypatch):
    """``use_pallas=None`` and ``False`` take the jnp oracle off-TPU;
    ``True`` takes the Pallas kernel (interpret mode) and never the oracle."""
    assert jax.default_backend() != "tpu"   # conftest pins JAX_PLATFORMS=cpu
    calls = []
    real = ref.sigrid_hash
    monkeypatch.setattr(ref, "sigrid_hash",
                        lambda *a, **k: calls.append("ref") or real(*a, **k))
    ids = jnp.zeros((8, 128), jnp.int32)
    ops.sigrid_hash(ids, 1, 10, use_pallas=None)
    assert calls == ["ref"]
    ops.sigrid_hash(ids, 1, 10, use_pallas=False)
    assert calls == ["ref", "ref"]
    out = ops.sigrid_hash(ids, 1, 10, use_pallas=True)
    assert calls == ["ref", "ref"]          # pallas path: oracle untouched
    np.testing.assert_array_equal(np.asarray(out), np.asarray(real(ids, 1, 10)))


def test_kernels_package_exports_public_api():
    import repro.kernels as K

    for name in K.__all__:
        assert callable(getattr(K, name)), name
    assert set(K.__all__) >= {
        "sigrid_hash", "bucketize", "fused_transform",
        "embedding_bag", "flash_attention", "ssd_chunk_forward",
    }


# -- ref.py oracle vs the numpy transform reference --------------------------


def test_ref_sigrid_hash_matches_numpy_transforms():
    from repro.core import transforms as T
    from repro.core.schema import SparseColumn

    vals = np.array(
        [-1, 0, 1, 7, -(2 ** 31), 2 ** 31 - 1, 2 ** 40 + 3, -(2 ** 40)], np.int64
    )
    col = SparseColumn(
        offsets=np.array([0, len(vals)], np.int64), values=vals
    )
    for salt, mv in [(0, 1), (13, 1000), (2 ** 31 - 1, 2 ** 31 - 1)]:
        np_out = T.sigrid_hash(col, salt, mv).values
        ref_out = ref.sigrid_hash(
            jnp.asarray(vals.astype(np.int32)).reshape(1, -1), salt, mv
        )
        np.testing.assert_array_equal(
            np_out, np.asarray(ref_out).ravel().astype(np.int64)
        )


def test_ref_bucketize_matches_numpy_transforms():
    from repro.core import transforms as T

    borders = np.array([-1.0, 0.0, 0.0, 1.0], np.float32)
    vals = np.array([-2.0, -1.0, -0.5, 0.0, 0.5, 1.0, 2.0], np.float32)
    np_out = T.bucketize(vals, borders).values
    ref_out = ref.bucketize(jnp.asarray(vals), jnp.asarray(borders))
    np.testing.assert_array_equal(np_out, np.asarray(ref_out).astype(np.int64))


# -- ragged-tail tiles (rows/cols not a multiple of the block size) ----------


@pytest.mark.parametrize("rows,cols,br,bc", [(37, 70, 16, 64), (130, 5, 128, 4)])
def test_bucketize_ragged_tail_tiles(rows, cols, br, bc):
    from repro.kernels.bucketize import bucketize as bucketize_pallas

    vals = jax.random.normal(KEY, (rows, cols), jnp.float32) * 2
    borders = jnp.sort(jax.random.normal(jax.random.PRNGKey(20), (9,)))
    a = bucketize_pallas(vals, borders, block_rows=br, block_cols=bc,
                         interpret=True)
    b = ref.bucketize(vals, borders)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("v,e,b,l", [(33, 17, 5, 3), (7, 9, 1, 1)])
def test_embedding_bag_ragged_tail_tiles(v, e, b, l):
    table = jax.random.normal(KEY, (v, e), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(21), (b, l), 0, v, jnp.int32)
    mask = (jax.random.uniform(jax.random.PRNGKey(22), (b, l)) > 0.3).astype(
        jnp.float32
    )
    a = ops.embedding_bag(table, ids, mask, use_pallas=True)
    bb = ref.embedding_bag(table, ids, mask)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-5)


def test_ssd_chunk_kernel_matches_model_ssd():
    """Kernel semantics == the model's chunked SSD (G=1, per-head A)."""
    from repro.models.ssm import ssd_chunked

    b, s, h, p, n = 2, 64, 4, 16, 16
    x = jax.random.normal(KEY, (b, s, h, p), jnp.float32) * 0.5
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(10), (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(11), (h,)) * 0.3)
    b_ = jax.random.normal(jax.random.PRNGKey(12), (b, s, 1, n)) * 0.5
    c_ = jax.random.normal(jax.random.PRNGKey(13), (b, s, 1, n)) * 0.5
    y_model, _ = ssd_chunked(x, dt, a, b_, c_, chunk=16)

    # flatten to (B*H, ...) kernel layout
    xk = x.transpose(0, 2, 1, 3).reshape(b * h, s, p)
    dtk = dt.transpose(0, 2, 1).reshape(b * h, s)
    ak = jnp.tile(a, b)
    bk = jnp.repeat(b_[:, :, 0][:, None], h, axis=1).reshape(b * h, s, n)
    ck = jnp.repeat(c_[:, :, 0][:, None], h, axis=1).reshape(b * h, s, n)
    yk = ops.ssd_chunk_forward(xk, dtk, ak, bk, ck, chunk=16, use_pallas=True)
    yk = yk.reshape(b, h, s, p).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        np.asarray(yk), np.asarray(y_model, np.float32), atol=5e-3, rtol=1e-2
    )


# -- embedding_bag differential suite (ISSUE 9) ------------------------------
#
# kernel-vs-oracle parity on every bag shape the DLRM path produces: empty
# bags, single-id bags, duplicate ids inside one bag, ids on the last table
# row, and both pooling denominators (mean vs sum).


@pytest.mark.parametrize("mode", ["mean", "sum"])
def test_embedding_bag_empty_bags(mode):
    """All-masked-out bags: mean pools to 0/max(0,1) == 0, sum to 0."""
    table = jax.random.normal(KEY, (16, 8), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(30), (4, 5), 0, 16, jnp.int32)
    mask = jnp.zeros((4, 5), jnp.float32)
    a = ops.embedding_bag(table, ids, mask, mode=mode, use_pallas=True)
    bb = ref.embedding_bag(table, ids, mask, mode=mode)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(a), np.zeros((4, 8), np.float32))


@pytest.mark.parametrize("mode", ["mean", "sum"])
def test_embedding_bag_single_id_bags(mode):
    """One live slot per bag: output must equal the selected row exactly."""
    table = jax.random.normal(KEY, (32, 16), jnp.float32)
    b, l = 6, 4
    ids = jax.random.randint(jax.random.PRNGKey(31), (b, l), 0, 32, jnp.int32)
    mask = jnp.zeros((b, l), jnp.float32).at[jnp.arange(b), jnp.arange(b) % l].set(1.0)
    a = ops.embedding_bag(table, ids, mask, mode=mode, use_pallas=True)
    bb = ref.embedding_bag(table, ids, mask, mode=mode)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-6)
    picked = np.asarray(table)[np.asarray(ids)[np.arange(b), np.arange(b) % l]]
    np.testing.assert_allclose(np.asarray(a), picked, atol=1e-6)


@pytest.mark.parametrize("mode", ["mean", "sum"])
def test_embedding_bag_duplicate_ids_one_bag(mode):
    """The same id repeated in one bag must be accumulated per occurrence,
    not deduplicated (multiplicity is part of the bag semantics)."""
    table = jax.random.normal(KEY, (8, 8), jnp.float32)
    ids = jnp.array([[3, 3, 3, 5], [0, 0, 7, 7]], jnp.int32)
    mask = jnp.ones((2, 4), jnp.float32)
    a = ops.embedding_bag(table, ids, mask, mode=mode, use_pallas=True)
    bb = ref.embedding_bag(table, ids, mask, mode=mode)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-5)
    t = np.asarray(table)
    want0 = 3 * t[3] + t[5]
    if mode == "mean":
        want0 = want0 / 4.0
    np.testing.assert_allclose(np.asarray(a)[0], want0, atol=1e-5)


@pytest.mark.parametrize("mode", ["mean", "sum"])
def test_embedding_bag_last_row_ids(mode):
    """ids == V-1 select the final table row (the off-by-one block edge)."""
    v, e = 19, 8
    table = jax.random.normal(KEY, (v, e), jnp.float32)
    ids = jnp.full((3, 4), v - 1, jnp.int32)
    mask = jnp.ones((3, 4), jnp.float32)
    a = ops.embedding_bag(table, ids, mask, mode=mode, use_pallas=True)
    bb = ref.embedding_bag(table, ids, mask, mode=mode)
    np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-5)
    want = np.asarray(table)[v - 1] * (1.0 if mode == "mean" else 4.0)
    np.testing.assert_allclose(np.asarray(a)[1], want, atol=1e-5)


def test_embedding_bag_mean_vs_sum_denominator():
    """mean == sum / max(live slots, 1) — the DLRM pooling denominator."""
    table = jax.random.normal(KEY, (24, 8), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(33), (5, 6), 0, 24, jnp.int32)
    mask = (jax.random.uniform(jax.random.PRNGKey(34), (5, 6)) > 0.5).astype(
        jnp.float32
    )
    s = ops.embedding_bag(table, ids, mask, mode="sum", use_pallas=True)
    m = ops.embedding_bag(table, ids, mask, mode="mean", use_pallas=True)
    denom = np.maximum(np.asarray(mask).sum(axis=1), 1.0)
    np.testing.assert_allclose(
        np.asarray(m), np.asarray(s) / denom[:, None], atol=1e-5
    )
    with pytest.raises(ValueError):
        ops.embedding_bag(table, ids, mask, mode="max", use_pallas=False)


def test_embedding_bag_ref_matches_dlrm_pooling():
    """The ref oracle is the same formula DLRM.pooled_embeddings uses —
    one pooling definition across model, store and kernel."""
    from repro.models.dlrm import DLRM, DLRMConfig

    cfg = DLRMConfig(num_dense=4, num_tables=2, vocab_per_table=20,
                     embed_dim=8, max_ids_per_feature=5,
                     bottom_mlp=(8, 4), top_mlp=(8, 1))
    model = DLRM(cfg)
    tables = jax.random.normal(KEY, (2, 20, 8), jnp.float32)
    ids = jax.random.randint(jax.random.PRNGKey(35), (3, 2, 5), 0, 20, jnp.int32)
    mask = (jax.random.uniform(jax.random.PRNGKey(36), (3, 2, 5)) > 0.4).astype(
        jnp.float32
    )
    pooled = model.pooled_embeddings(tables, {"sparse_ids": ids, "sparse_mask": mask})
    for t in range(2):
        bagged = ref.embedding_bag(tables[t], ids[:, t], mask[:, t])
        np.testing.assert_allclose(
            np.asarray(pooled)[:, t], np.asarray(bagged), atol=1e-6
        )


# -- stripe decode ops (ISSUE 10): pallas vs oracle vs numpy semantics -------


@pytest.mark.parametrize("n", [1, 7, 64])
def test_xor_decrypt_sweep(n):
    rng = np.random.default_rng(10)
    raw = rng.integers(0, 256, n * 512, dtype=np.uint8)
    words = jnp.asarray(raw.view("<i4").reshape(n, 128))
    a = ops.xor_decrypt(words, use_pallas=True)
    b = ref.xor_decrypt(words)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # byte-wise XOR semantics: the word kernel must equal numpy on bytes
    np.testing.assert_array_equal(
        np.asarray(a).reshape(-1).view(np.uint8), raw ^ 0x5A
    )


@pytest.mark.parametrize("feats,rows", [(1, 32), (13, 100), (96, 128)])
def test_dense_unpack_sweep(feats, rows):
    rng = np.random.default_rng(11)
    w = -(-rows // 32)
    present = rng.random((feats, rows)) < 0.7
    cap = max(int(present.sum(axis=1).max()), 1)
    bm = np.zeros((feats, w * 4), np.uint8)
    vals = np.zeros((feats, cap), np.int32)
    for f in range(feats):
        bm[f, : -(-rows // 8)] = np.packbits(present[f])
        nz = int(present[f].sum())
        vals[f, :nz] = (
            rng.normal(0, 2, nz).astype(np.float32).view(np.int32)
        )
    bw = jnp.asarray(bm.view("<i4"))
    vj = jnp.asarray(vals)
    a = ops.dense_unpack(bw, vj, use_pallas=True)
    b = ref.dense_unpack(bw, vj)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # scatter semantics vs plain numpy: values land at present rows,
    # NaN bits elsewhere
    got = np.asarray(a)[:, :rows].view(np.float32)
    for f in range(feats):
        want = np.full(rows, np.nan, np.float32)
        nz = int(present[f].sum())
        want[present[f]] = vals[f, :nz].view(np.float32)
        np.testing.assert_array_equal(
            got[f].view(np.int32), want.view(np.int32)
        )


@pytest.mark.parametrize("s,m", [(2, 1), (8, 5), (32, 16)])
def test_ragged_gather_sweep(s, m):
    rng = np.random.default_rng(12)
    src = rng.integers(-(1 << 31), 1 << 31, (s, 128), dtype=np.int64)
    src = src.astype(np.int32)
    idx = rng.integers(0, s * 128 - 1, (m, 128), dtype=np.int32)
    shift = (rng.integers(0, 4, (m, 128), dtype=np.int32) * 8).astype(np.int32)
    a = ops.ragged_gather(
        jnp.asarray(src), jnp.asarray(idx), jnp.asarray(shift),
        use_pallas=True,
    )
    b = ref.ragged_gather(jnp.asarray(src), jnp.asarray(idx), jnp.asarray(shift))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # byte-offset semantics vs numpy: each lane reads 4 bytes at byte
    # offset 4*idx + shift/8 of the flat stream
    flat = src.reshape(-1).view(np.uint8)
    byte_off = idx.astype(np.int64) * 4 + shift // 8
    want = np.zeros((m, 128), np.int32)
    for r in range(m):
        for c in range(128):
            o = int(byte_off[r, c])
            want[r, c] = np.frombuffer(flat[o:o + 4].tobytes(), "<i4")[0]
    np.testing.assert_array_equal(np.asarray(a), want)
