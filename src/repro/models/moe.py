"""Mixture-of-Experts FFN with capacity-based dispatch and expert parallelism.

Experts are sharded over the "model" mesh axis (EP); the per-expert token
buffer ("expert_capacity" logical axis) is sharded over "data" so the
dispatched activation tensor (E, C, d_model) stays bounded per device.  XLA
SPMD inserts the all-to-all-equivalent collectives at the gather/scatter
boundaries — the JAX-native mapping of the Megatron/DeepSpeed EP pattern.

Routing is top-k softmax gating with a capacity factor (Switch-style token
dropping); shared experts (DeepSeek-V2 / Kimi-K2) run densely for all
tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.common import ModelConfig, MoEConfig, ParamSpec
from repro.models import layers


def moe_specs(cfg: ModelConfig, moe: Optional[MoEConfig] = None) -> Dict[str, Any]:
    m = moe or cfg.moe
    d, dt = cfg.d_model, cfg.param_dtype
    specs: Dict[str, Any] = {
        "router": ParamSpec((d, m.num_experts), ("embed", None), jnp.float32, "scaled"),
        "wi_gate": ParamSpec((m.num_experts, d, m.d_ff), ("expert", "embed", None), dt, "scaled"),
        "wi_up": ParamSpec((m.num_experts, d, m.d_ff), ("expert", "embed", None), dt, "scaled"),
        "wo": ParamSpec((m.num_experts, m.d_ff, d), ("expert", None, "embed"), dt, "scaled"),
    }
    if m.num_shared_experts:
        shared_ff = m.shared_d_ff or m.num_shared_experts * m.d_ff
        specs["shared"] = layers.mlp_specs(d, shared_ff, dt)
    return specs


def _capacity(num_tokens: int, m: MoEConfig) -> int:
    cap = int(num_tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, (cap + 7) // 8 * 8)  # 8-aligned, non-degenerate


def route(
    router_w: jax.Array, x: jax.Array, m: MoEConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (expert_idx (T,k), gates (T,k), aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(m.router_dtype), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    # load-balancing auxiliary loss (Switch/GShard form)
    density = jnp.mean(
        jax.nn.one_hot(idx[:, 0], m.num_experts, dtype=jnp.float32), axis=0
    )
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * m.num_experts
    return idx, gates.astype(x.dtype), aux


def moe_forward(
    params: Dict[str, Any],
    x: jax.Array,              # (B, S, d_model)
    cfg: ModelConfig,
    moe: Optional[MoEConfig] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output (B,S,d), aux_loss).

    Under an active sharding context whose mesh has a >1 "model" axis, the
    expert-parallel shard_map path is used (see ``moe_forward_ep``); the
    gather-based global dispatch below is the portable single-device path.
    """
    from repro.distributed.context import current_context

    ctx = current_context()
    if ctx is not None:
        mesh, _ = ctx
        if "model" in mesh.axis_names and mesh.shape["model"] > 1:
            m_ = moe or cfg.moe
            if m_.num_experts % mesh.shape["model"] == 0:
                return moe_forward_ep(params, x, cfg, mesh, m_)
    m = moe or cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    idx, gates, aux = route(params["router"], xf, m)     # (T,k)
    cap = _capacity(t, m)

    # position of each (token, k) within its expert via a segmented cumsum
    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.int32)   # (T,k,E)
    flat = onehot.reshape(t * m.top_k, m.num_experts)
    pos_in_expert = (jnp.cumsum(flat, axis=0) - flat).reshape(t, m.top_k, m.num_experts)
    pos = jnp.sum(pos_in_expert * onehot, axis=-1)                  # (T,k)
    keep = pos < cap

    # scatter token ids into the (E, C) dispatch table
    token_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, m.top_k))
    safe_e = jnp.where(keep, idx, 0)
    safe_p = jnp.where(keep, pos, cap)  # dropped slots land in a spill column
    table = jnp.full((m.num_experts, cap + 1), t, jnp.int32)
    table = table.at[safe_e.reshape(-1), safe_p.reshape(-1)].set(
        jnp.where(keep, token_ids, t).reshape(-1), mode="drop"
    )
    slot_token = table[:, :cap]                                     # (E, C)

    # gather tokens (pad row t = zeros), run experts, scatter back
    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = xpad[slot_token]                                           # (E, C, d)
    xe = constrain(xe, ("expert", "expert_capacity", None))
    gate_lin = jnp.einsum("ecd,edf->ecf", xe, params["wi_gate"])
    up = jnp.einsum("ecd,edf->ecf", xe, params["wi_up"])
    hidden = jax.nn.silu(gate_lin.astype(jnp.float32)).astype(xe.dtype) * up
    hidden = constrain(hidden, ("expert", "expert_capacity", None))
    ye = jnp.einsum("ecf,efd->ecd", hidden, params["wo"])           # (E, C, d)
    ye = constrain(ye, ("expert", "expert_capacity", None))

    # combine: for each (token, k), read back its expert slot
    ypad = jnp.concatenate([ye.reshape(-1, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    slot_flat = jnp.where(keep, safe_e * cap + safe_p, ye.shape[0] * ye.shape[1])
    yk = ypad[slot_flat]                                            # (T,k,d)
    y = jnp.sum(yk * gates[..., None], axis=1)

    if m.num_shared_experts:
        y = y + layers.mlp(params["shared"], xf)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path (§Perf hillclimb H2)
# ---------------------------------------------------------------------------
#
# The gather-based dispatch above indexes the GLOBAL token buffer with
# arbitrary indices, which the SPMD partitioner can only realize by
# all-gathering every token to every shard (measured: 12-21 TB/device/step
# on deepseek/kimi/jamba train_4k).  Here tokens stay sharded over "data",
# every "model" rank routes its local tokens to ITS OWN expert slice only,
# and partial expert outputs are combined with a single psum over "model" —
# the DeepSpeed/Megatron EP pattern expressed with shard_map.


def _local_dispatch_compute(xf, router_w, wi_gate, wi_up, wo, m: MoEConfig,
                            e_start: jax.Array, e_local: int, cap: int):
    """Route local tokens; compute only experts [e_start, e_start+e_local)."""
    t, d = xf.shape
    logits = jnp.einsum("td,de->te", xf.astype(m.router_dtype), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)
    gates = gates.astype(xf.dtype)

    density = jnp.mean(jax.nn.one_hot(idx[:, 0], m.num_experts, dtype=jnp.float32), axis=0)
    aux = jnp.sum(density * jnp.mean(probs, axis=0)) * m.num_experts

    # per-(token,k) position within its expert (global expert ids)
    onehot = jax.nn.one_hot(idx, m.num_experts, dtype=jnp.int32)
    flat = onehot.reshape(t * m.top_k, m.num_experts)
    pos = jnp.sum(
        (jnp.cumsum(flat, axis=0) - flat).reshape(t, m.top_k, m.num_experts) * onehot,
        axis=-1,
    )
    local_e = idx - e_start
    mine = (local_e >= 0) & (local_e < e_local) & (pos < cap)

    token_ids = jnp.broadcast_to(jnp.arange(t)[:, None], (t, m.top_k))
    safe_e = jnp.where(mine, local_e, 0)
    safe_p = jnp.where(mine, pos, cap)
    table = jnp.full((e_local, cap + 1), t, jnp.int32)
    table = table.at[safe_e.reshape(-1), safe_p.reshape(-1)].set(
        jnp.where(mine, token_ids, t).reshape(-1), mode="drop"
    )
    slot_token = table[:, :cap]

    xpad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xe = xpad[slot_token]                                       # (E_loc, C, d)
    hidden = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", xe, wi_gate).astype(jnp.float32)
    ).astype(xe.dtype) * jnp.einsum("ecd,edf->ecf", xe, wi_up)
    ye = jnp.einsum("ecf,efd->ecd", hidden, wo)                 # (E_loc, C, d)

    ypad = jnp.concatenate([ye.reshape(-1, d), jnp.zeros((1, d), ye.dtype)], axis=0)
    slot_flat = jnp.where(mine, safe_e * cap + safe_p, e_local * cap)
    yk = ypad[slot_flat]                                        # (t, k, d)
    y_partial = jnp.sum(yk * gates[..., None], axis=1)          # local-expert share
    return y_partial, aux


def moe_forward_ep(
    params: Dict[str, Any],
    x: jax.Array,
    cfg: ModelConfig,
    mesh,
    m: MoEConfig,
) -> Tuple[jax.Array, jax.Array]:
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    b, s, d = x.shape
    n_model = mesh.shape["model"]
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    batch_axes = data_axes if (data_axes and b % n_data == 0) else None
    n_shards = n_data if batch_axes else 1
    e_local = m.num_experts // n_model
    t_local = (b // n_shards) * s
    cap = _capacity(t_local, m)

    def body(xb, router_w, wi_gate, wi_up, wo):
        xf = xb.reshape(-1, d)
        rank = jax.lax.axis_index("model")
        y_partial, aux = _local_dispatch_compute(
            xf, router_w, wi_gate, wi_up, wo, m, rank * e_local, e_local, cap
        )
        y = jax.lax.psum(y_partial, "model")
        aux = jax.lax.pmean(aux, "model")
        return y.reshape(xb.shape), aux

    y, aux = shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(batch_axes, None, None),
            P(None, None),                 # router replicated
            P("model", None, None),
            P("model", None, None),
            P("model", None, None),
        ),
        out_specs=(P(batch_axes, None, None), P()),
        check_rep=False,
    )(x, params["router"], params["wi_gate"], params["wi_up"], params["wo"])

    if m.num_shared_experts:
        y = y + layers.mlp(params["shared"], x.reshape(-1, d)).reshape(b, s, d)
    return y, aux
