"""DPP control plane: the Master (§3.2.1).

Responsibilities (paper-faithful):
  * break the preprocessing workload into self-contained **splits**
    (successive row ranges of the dataset) and serve them to Workers,
  * track split progress; re-dispatch splits whose lease expired
    (worker failure / straggler mitigation),
  * periodic **checkpoints** of reader state for restore-on-failure,
  * worker health monitoring (heartbeats) with automatic restart hooks,
  * an **auto-scaling controller** that watches buffered-tensor depth and
    worker utilization and computes how many Workers to launch or drain.

The Master itself is replicated in production; here `checkpoint()` /
`DPPMaster.restore()` provide the equivalent failover path.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.transforms import TransformPipeline, TransformSpec


@dataclasses.dataclass(frozen=True)
class SessionSpec:
    """The PyTorch-DataSet analogue shipped by FBLearner Flow."""

    table: str
    partitions: Tuple[int, ...]
    feature_ids: Tuple[int, ...]
    transform_specs: Tuple[TransformSpec, ...]
    batch_size: int = 512
    rows_per_split: int = 2048
    dense_keys: Tuple[str, ...] = ()
    sparse_keys: Tuple[str, ...] = ()
    max_ids_per_feature: int = 32

    def pipeline(self) -> TransformPipeline:
        return TransformPipeline(list(self.transform_specs))


@dataclasses.dataclass
class Split:
    split_id: int
    partition: int
    row_start: int
    row_end: int


@dataclasses.dataclass
class _Lease:
    worker_id: str
    deadline: float


@dataclasses.dataclass
class AutoScaler:
    """§3.2.1: keep a non-zero buffered-tensor depth with maximal worker
    utilization — scale out on (near-)empty buffers, drain on deep buffers
    and low utilization."""

    target_buffer_low: int = 2
    target_buffer_high: int = 32
    util_high: float = 0.85
    util_low: float = 0.3
    min_workers: int = 1
    max_workers: int = 256

    def decide(
        self,
        n_workers: int,
        buffered_batches: int,
        mean_cpu_util: float,
        stalls_since_last: int,
    ) -> int:
        """Returns the worker-count delta (+launch / -drain)."""
        if stalls_since_last > 0 or buffered_batches < self.target_buffer_low:
            grow = max(1, int(0.5 * n_workers))
            return min(grow, self.max_workers - n_workers)
        if (
            buffered_batches > self.target_buffer_high
            and mean_cpu_util < self.util_low
            and n_workers > self.min_workers
        ):
            return -max(1, int(0.25 * n_workers))
        return 0


class DPPMaster:
    def __init__(
        self,
        spec: SessionSpec,
        partition_rows: Dict[int, int],
        lease_s: float = 30.0,
        autoscaler: Optional[AutoScaler] = None,
        partition_stripe_rows: Optional[Dict[int, int]] = None,
    ):
        self.spec = spec
        self.lease_s = lease_s
        self.autoscaler = autoscaler or AutoScaler()
        self._lock = threading.Lock()
        self._splits: Dict[int, Split] = {}
        self._pending: List[int] = []
        self._leased: Dict[int, _Lease] = {}
        self._done: set = set()
        self._workers: Dict[str, float] = {}      # worker_id -> last heartbeat
        self._restarts: List[str] = []
        self._stripe_rows = dict(partition_stripe_rows or {})
        self._build_splits(partition_rows)

    def _build_splits(self, partition_rows: Dict[int, int]) -> None:
        """Emit stripe-aligned splits: rows_per_split is rounded up to a
        multiple of the partition's stripe size so a split's row range maps
        onto whole stripes and a worker never decodes rows it throws away."""
        sid = 0
        for p in self.spec.partitions:
            rows = partition_rows[p]
            step = self.spec.rows_per_split
            stripe = self._stripe_rows.get(p, 0)
            if stripe > 0:
                step = max(1, -(-step // stripe)) * stripe
            for start in range(0, rows, step):
                end = min(start + step, rows)
                self._splits[sid] = Split(sid, p, start, end)
                self._pending.append(sid)
                sid += 1

    # -- work distribution ---------------------------------------------------

    def get_split(self, worker_id: str) -> Optional[Split]:
        with self._lock:
            self._workers[worker_id] = time.time()
            self._reclaim_expired_locked()
            if not self._pending:
                return None
            sid = self._pending.pop(0)
            self._leased[sid] = _Lease(worker_id, time.time() + self.lease_s)
            return self._splits[sid]

    def peek_pending(self, n: int) -> List[Split]:
        """The next ``n`` not-yet-leased splits, in dispatch order — the
        prefetch planner's window onto upcoming work (read-only: peeking
        does not lease)."""
        with self._lock:
            return [self._splits[sid] for sid in self._pending[:n]]

    def complete_split(self, worker_id: str, split_id: int) -> None:
        with self._lock:
            lease = self._leased.pop(split_id, None)
            self._done.add(split_id)

    def _reclaim_expired_locked(self) -> None:
        now = time.time()
        expired = [sid for sid, l in self._leased.items() if l.deadline < now]
        for sid in expired:
            # straggler mitigation / failure handling: re-dispatch
            del self._leased[sid]
            if sid not in self._done:
                self._pending.insert(0, sid)

    @property
    def progress(self) -> Tuple[int, int]:
        with self._lock:
            return len(self._done), len(self._splits)

    @property
    def finished(self) -> bool:
        done, total = self.progress
        return done >= total

    # -- health / fault tolerance ---------------------------------------------

    def heartbeat(self, worker_id: str) -> None:
        with self._lock:
            self._workers[worker_id] = time.time()

    def dead_workers(self, timeout_s: float = 10.0) -> List[str]:
        now = time.time()
        with self._lock:
            return [w for w, t in self._workers.items() if now - t > timeout_s]

    def forget_worker(self, worker_id: str) -> None:
        """Worker died: release its leases immediately (stateless workers —
        no checkpoint restore needed, §3.2.1)."""
        with self._lock:
            self._workers.pop(worker_id, None)
            for sid, l in list(self._leased.items()):
                if l.worker_id == worker_id:
                    del self._leased[sid]
                    if sid not in self._done:
                        self._pending.insert(0, sid)
            self._restarts.append(worker_id)

    # -- checkpointing -----------------------------------------------------------

    def checkpoint(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "spec": self.spec,
                "done": sorted(self._done),
                "n_splits": len(self._splits),
                "stripe_rows": dict(self._stripe_rows),
            }

    @classmethod
    def restore(
        cls,
        ckpt: Dict[str, Any],
        partition_rows: Dict[int, int],
        lease_s: float = 30.0,
    ) -> "DPPMaster":
        m = cls(
            ckpt["spec"], partition_rows, lease_s=lease_s,
            partition_stripe_rows=ckpt.get("stripe_rows"),
        )
        with m._lock:
            for sid in ckpt["done"]:
                m._done.add(sid)
                if sid in m._pending:
                    m._pending.remove(sid)
        return m

    # -- auto-scaling ---------------------------------------------------------------

    def scaling_decision(
        self, n_workers: int, buffered: int, cpu_util: float, stalls: int
    ) -> int:
        return self.autoscaler.decide(n_workers, buffered, cpu_util, stalls)
