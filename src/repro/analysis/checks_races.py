"""Shared-state publication rules backing the runtime race sanitizer.

``repro.analysis.racedep`` (the Eraser-style lockset detector) exempts
attributes a class declares in a class-level ``_unshared`` tuple —
deliberately lock-free fields (GIL-atomic monotone flags, single-writer
telemetry).  That escape hatch only stays honest if it cannot drift:

  * **REPRO-R001** — on a race-instrumented class (the
    ``racedep.INSTRUMENTED_CLASSES`` set), a field assigned outside
    ``__init__`` without the lock held must be declared in
    ``_unshared``.  Every lock-free write is therefore either visible
    to the runtime detector or explicitly, reviewably allowlisted —
    never silently both unlocked and untracked.
  * **REPRO-R002** — no double-checked locking: an attribute
    *published* under a class's lock (assigned inside ``with
    self._lock``) may not be *tested* without it (``if self.cache is
    None:`` at lock depth 0).  The check-then-act window between the
    unguarded test and the action is exactly the atomicity bug the
    interleaving explorer (``repro.analysis.sched``) exists to catch —
    snapshot the attribute into a local inside the lock instead.

Both rules are deliberately narrower than REPRO-L001: they look only at
*direct* ``self.<attr>`` rebinds/tests (what ``racedep`` observes at
attribute granularity), but they apply to private methods and to
lock-less classes too — ``PrefetchPlanner``/``ElasticController`` hold
no lock by design, so every cross-thread field they write must be in
``_unshared`` where the detector and the reviewer can see it.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.checks_locks import _declared_locks, _is_lock_expr
from repro.analysis.core import CheckContext, Finding, checker, rule
from repro.analysis.racedep import INSTRUMENTED_CLASSES

R001 = rule("REPRO-R001",
            "field on a race-instrumented class assigned outside __init__ "
            "without the lock and not declared in `_unshared`")
R002 = rule("REPRO-R002",
            "double-checked locking: attribute published under the lock "
            "is tested without it")

_LOCK_ATTR_RE = re.compile(r"^_\w*lock$")


def _unshared_decl(cls: ast.ClassDef) -> Set[str]:
    """Names in the class-level ``_unshared = ("a", "b")`` declaration."""
    names: Set[str] = set()
    for node in cls.body:
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = node.targets
            value = node.value
        elif isinstance(node, ast.AnnAssign) and node.value is not None:
            targets = [node.target]
            value = node.value
        else:
            continue
        if not any(isinstance(t, ast.Name) and t.id == "_unshared"
                   for t in targets):
            continue
        if isinstance(value, (ast.Tuple, ast.List, ast.Set)):
            for el in value.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    names.add(el.value)
    return names


class _AccessScan(ast.NodeVisitor):
    """Direct ``self.<attr>`` rebinds and condition tests, by lock depth."""

    def __init__(self, locks: Set[str], assume_locked: bool):
        self.locks = locks
        self.depth = 1 if assume_locked else 0   # _locked helper contract
        self.unlocked_writes: List[Tuple[int, str]] = []
        self.locked_writes: Set[str] = set()
        self.unlocked_tests: List[Tuple[int, str]] = []

    def visit_With(self, node: ast.With) -> None:
        is_lock = any(_is_lock_expr(i.context_expr, self.locks)
                      for i in node.items)
        if is_lock:
            self.depth += 1
        self.generic_visit(node)
        if is_lock:
            self.depth -= 1

    def _write(self, target: ast.AST, line: int) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._write(el, line)
            return
        if (isinstance(target, ast.Attribute)
                and isinstance(target.value, ast.Name)
                and target.value.id == "self"):
            if self.depth == 0:
                self.unlocked_writes.append((line, target.attr))
            else:
                self.locked_writes.add(target.attr)

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._write(t, node.lineno)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._write(node.target, node.lineno)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._write(node.target, node.lineno)
        self.generic_visit(node)

    def _test(self, expr: ast.AST, line: int) -> None:
        if self.depth > 0:
            return
        for node in ast.walk(expr):
            if (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Name)
                    and node.value.id == "self"):
                self.unlocked_tests.append((line, node.attr))
            # `self.cache.dedup` chains: the *root* attr is the tested one
            elif (isinstance(node, ast.Attribute)
                    and isinstance(node.value, ast.Attribute)
                    and isinstance(node.value.value, ast.Name)
                    and node.value.value.id == "self"):
                self.unlocked_tests.append((line, node.value.attr))

    def visit_If(self, node: ast.If) -> None:
        self._test(node.test, node.lineno)
        self.generic_visit(node)

    def visit_While(self, node: ast.While) -> None:
        self._test(node.test, node.lineno)
        self.generic_visit(node)

    def visit_IfExp(self, node: ast.IfExp) -> None:
        self._test(node.test, node.lineno)
        self.generic_visit(node)

    def visit_Assert(self, node: ast.Assert) -> None:
        self._test(node.test, node.lineno)
        self.generic_visit(node)


def _scan_class(cls: ast.ClassDef, locks: Set[str]
                ) -> Dict[str, _AccessScan]:
    scans: Dict[str, _AccessScan] = {}
    for fn in cls.body:
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        if fn.name.startswith("__"):
            continue   # __init__ & friends: pre-publication
        scan = _AccessScan(locks, assume_locked=fn.name.endswith("_locked"))
        for stmt in fn.body:
            scan.visit(stmt)
        scans[fn.name] = scan
    return scans


@checker("race-publication")
def check_races(ctx: CheckContext):
    findings: List[Finding] = []
    instrumented = INSTRUMENTED_CLASSES
    for mod in ctx.src_modules():
        race_classes = instrumented.get(mod.rel, ())
        for cls in [n for n in ast.walk(mod.tree)
                    if isinstance(n, ast.ClassDef)]:
            locks = _declared_locks(cls)
            is_instrumented = cls.name in race_classes
            if not locks and not is_instrumented:
                continue
            scans = _scan_class(cls, locks)

            if is_instrumented:
                unshared = _unshared_decl(cls)
                lockdesc = ("/".join(f"self.{l}" for l in sorted(locks))
                            or "a lock")
                for fname, scan in scans.items():
                    for line, attr in scan.unlocked_writes:
                        if attr in unshared or _LOCK_ATTR_RE.match(attr):
                            continue
                        findings.append(Finding(
                            R001, mod.rel, line,
                            f"assigns self.{attr} outside __init__ without "
                            f"{lockdesc}; guard it or declare it in "
                            f"{cls.name}._unshared (racedep then skips it)",
                            f"{cls.name}.{fname}",
                        ))

            if locks:
                published: Set[str] = set()
                for scan in scans.values():
                    published |= scan.locked_writes
                published -= {a for a in published if _LOCK_ATTR_RE.match(a)}
                seen: Set[Tuple[str, int, str]] = set()
                for fname, scan in scans.items():
                    for line, attr in scan.unlocked_tests:
                        if attr in published and (fname, line, attr) not in seen:
                            seen.add((fname, line, attr))
                            findings.append(Finding(
                                R002, mod.rel, line,
                                f"tests self.{attr} without the lock that "
                                "publishes it (double-checked locking); "
                                "snapshot it into a local inside the lock",
                                f"{cls.name}.{fname}",
                            ))
    return findings
