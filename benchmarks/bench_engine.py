"""§7.2 as a production path: fused TransformEngine vs per-feature numpy.

The kernels section benchmarks the raw fused kernel; this section
benchmarks the **engine the DPP worker actually runs**: DAG compilation
into waves, packing, one ``pallas_call`` per wave (interpret mode on CPU
— the CI-portable configuration; compiled on TPU), numpy fallback for
inexpressible ops, and per-engine metrics.

Asserted claims:
  * kernel-launch amortization: the fused engine issues >= 10x fewer
    launches than per-feature execution for a >= 64-feature DAG,
  * both engines produce byte-identical outputs (spot-checked here;
    exhaustively pinned by tests/test_engine.py).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_us
from repro.core.engine import NumpyEngine, PallasEngine
from repro.core.schema import ColumnBatch, SparseColumn
from repro.core.transforms import TransformPipeline, TransformSpec


def _batch(rows: int, n_sparse: int, n_dense: int, avg_len: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    sparse = {}
    for f in range(n_sparse):
        lengths = rng.integers(0, 2 * avg_len + 1, rows)
        off = np.zeros(rows + 1, np.int64)
        np.cumsum(lengths, out=off[1:])
        sparse[f] = SparseColumn(
            offsets=off,
            values=rng.integers(-(10 ** 12), 10 ** 12, int(off[-1])),
        )
    dense = {
        n_sparse + f: rng.normal(0, 3, rows).astype(np.float32)
        for f in range(n_dense)
    }
    return ColumnBatch(num_rows=rows, dense=dense, sparse=sparse)


def _fused_dag(n_sparse: int, n_dense: int, hash_size: int = 100_000):
    """A fully kernel-expressible DAG: one fused op per feature."""
    specs = []
    for f in range(n_sparse):
        specs.append(TransformSpec(
            "SigridHash", (f"f{f}",), f"s{f}",
            (("salt", f + 1), ("max_value", hash_size)),
        ))
    borders = np.linspace(-3, 3, 63).astype(np.float32)
    for i in range(n_dense):
        f = n_sparse + i
        if i % 2:
            specs.append(TransformSpec(
                "Clamp", (f"f{f}",), f"d{f}", (("lo", -10.0), ("hi", 10.0)),
            ))
        else:
            specs.append(TransformSpec(
                "Bucketize", (f"f{f}",), f"g{f}", (("borders", borders),),
            ))
    return TransformPipeline(specs)


def run(quick: bool = False) -> None:
    # the paper's §7.2 shape: ~1000 sparse features combined in one kernel;
    # short id lists make the per-feature regime dispatch-bound, which is
    # exactly the overhead the fused engine amortizes
    rows = 256 if quick else 1024
    n_sparse, n_dense = 1000, 24
    avg_len = 2 if quick else 4
    repeat = 2 if quick else 5

    batch = _batch(rows, n_sparse, n_dense, avg_len)
    pipe = _fused_dag(n_sparse, n_dense)
    n_features = len(pipe.specs)

    numpy_eng = NumpyEngine(pipe)
    # default dispatch (use_pallas=None): compiled Pallas kernel on TPU,
    # XLA-compiled static-codes oracle elsewhere — the production config
    xla_eng = PallasEngine(pipe)
    # interpret-mode dispatch: the bit-accurate emulation CI validates the
    # kernel with off-TPU; not a wall-clock proxy
    pallas_eng = PallasEngine(pipe, use_pallas=True)
    env_n = numpy_eng.run(batch)
    env_p = pallas_eng.run(batch)     # warm run compiles the wave kernel
    xla_eng.run(batch)                # warm: compile the fused wave
    # per-epoch launch counts, captured before the timing loops re-run
    ln, lp = numpy_eng.stats.kernel_launches, pallas_eng.stats.kernel_launches

    # parity spot check (the differential suite owns the exhaustive one)
    for k in (f"s0", f"s{n_sparse - 1}"):
        assert np.array_equal(env_n[k].values, env_p[k].values), k

    # engine instances are reused across batches (the worker pattern): the
    # DAG compiles once, the wave kernels stay jit-cached
    us_numpy = time_us(lambda: numpy_eng.run(batch), repeat=repeat)
    us_fused = time_us(lambda: xla_eng.run(batch), repeat=repeat)
    us_interp = time_us(lambda: pallas_eng.run(batch), repeat=1)

    assert n_features >= 64, "amortization claim needs a >= 64-feature DAG"
    assert lp * 10 <= ln, (
        f"fused engine must amortize launches >= 10x: {lp} vs {ln}"
    )
    emit("engine.numpy_per_feature", us_numpy,
         f"launches={ln} features={n_features}")
    emit("engine.fused_one_launch", us_fused,
         f"launches={lp} amortization={ln / max(lp, 1):.0f}x "
         f"transform_cut={us_numpy / max(us_fused, 1e-9):.2f}x")
    emit("engine.fused_interpret_mode", us_interp,
         "bit-accurate CI emulation (compiled on TPU)")
    emit("engine.pallas_metrics", 0.0,
         f"fused={pallas_eng.stats.fused_features} "
         f"fallback={pallas_eng.stats.fallback_features} "
         f"fused_s={pallas_eng.stats.fused_s:.4f} "
         f"fallback_s={pallas_eng.stats.fallback_s:.4f}")

    # a production-shaped DAG with inexpressible ops: fallback accounting
    from repro.core.transforms import default_dlrm_pipeline

    mixed = default_dlrm_pipeline(
        list(range(n_sparse, n_sparse + n_dense)), list(range(8)),
        hash_size=100_000, n_derived=6,
    )
    me = PallasEngine(mixed)
    me.run(batch)
    emit("engine.pallas_mixed_dag", 0.0,
         f"fused={me.stats.fused_features} "
         f"fallback={me.stats.fallback_features} "
         f"launches={me.stats.kernel_launches} "
         f"fused_frac={me.stats.fused_features / max(1, me.stats.fused_features + me.stats.fallback_features):.2f}")


if __name__ == "__main__":
    run()
