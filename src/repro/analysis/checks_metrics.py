"""Metrics-contract rules (REPRO-M001/M002).

The benchmarks are the repo's paper-facing numbers; they read
``WorkerMetrics``/``TierStats``/``CacheStats``/... fields by attribute.
A renamed or deleted field turns a Table-9-style benchmark into an
``AttributeError`` at best and a silently-wrong derived metric at worst.

  * **M001** — every metric attribute a benchmark reads must exist on one
    of the metric dataclasses (fields, ``@property``s, and methods all
    count).  Receivers are recognized two ways: chained access through a
    ``.metrics`` / ``.stats`` attribute (``sess.prefetcher.metrics.fills``),
    and locals assigned from a metrics getter
    (``m = sess.worker_metrics()``; ``stats = engine.stats``) — tracking
    is dropped on reassignment, so ``m = table.partitions[p]`` is never
    misread as a metrics object.
  * **M002** — metric counters are monotonic: ``x.hits -= 1`` (or
    ``x.hits = x.hits - k``) anywhere in ``src/repro`` is a finding.
    Capacity gauges legitimately shrink and are exempt: ``bytes_stored``
    (eviction) and ``buffered_batches`` (drain).

The metric vocabulary is *discovered*, not hand-listed: any class in the
src tree declaring at least one ``counter()`` / ``gauge()`` field
(:mod:`repro.obs.meta`) is a metric class; its declared counters feed
M002 and its full surface (fields + properties + methods) feeds M001.
Which fields may shrink comes from the same declarations — a field is
exempt from M002 iff some metric class declares it ``gauge()``.  If
discovery finds nothing repo-wide the checker reports that as drift
instead of silently checking nothing.
"""
from __future__ import annotations

import ast
from typing import List, Set, Tuple

from repro.analysis.core import (
    CheckContext,
    Finding,
    attr_chain,
    checker,
    enclosing_symbol,
    rule,
)

M001 = rule("REPRO-M001",
            "benchmark reads a metric attribute that no metric class "
            "defines")
M002 = rule("REPRO-M002",
            "metric counter decremented (counters are monotonic; only "
            "gauges may shrink)")

_GETTER_CALLS = {"worker_metrics", "fleet_metrics"}
_METRIC_ATTRS = {"metrics", "stats"}
_DECL_FNS = ("counter", "gauge")


def _decl_kind(stmt: ast.stmt) -> Tuple[str, str]:
    """("counter"|"gauge", field) for ``f: T = counter(...)``-style
    declarations (bare or module-qualified), else ("", "")."""
    if not (isinstance(stmt, ast.AnnAssign)
            and isinstance(stmt.target, ast.Name)
            and isinstance(stmt.value, ast.Call)):
        return "", ""
    fn = stmt.value.func
    name = fn.id if isinstance(fn, ast.Name) else (
        fn.attr if isinstance(fn, ast.Attribute) else ""
    )
    return (name, stmt.target.id) if name in _DECL_FNS else ("", "")


def _class_vocab(cls: ast.ClassDef) -> Set[str]:
    names: Set[str] = set()
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
            names.add(node.target.id)
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name):
                    names.add(t.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            names.add(node.name)
    return names


def discover_metric_classes(ctx: CheckContext):
    """Every class in the src tree declaring at least one ``counter()``
    or ``gauge()`` field, as ``(rel, ClassDef, counters, gauges)``."""
    out = []
    for mod in ctx.src_modules():
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            counters: Set[str] = set()
            gauges: Set[str] = set()
            for stmt in node.body:
                kind, field = _decl_kind(stmt)
                if kind == "counter":
                    counters.add(field)
                elif kind == "gauge":
                    gauges.add(field)
            if counters or gauges:
                out.append((mod.rel, node, counters, gauges))
    return out


def _load_vocab(ctx: CheckContext) -> Tuple[Set[str], Set[str], List[Finding]]:
    """(full vocabulary, counter fields, drift findings)."""
    vocab: Set[str] = set(_METRIC_ATTRS)   # x.metrics.stats... chains
    counters: Set[str] = set()
    gauges: Set[str] = set()
    drift: List[Finding] = []
    discovered = discover_metric_classes(ctx)
    if not discovered:
        drift.append(Finding(
            M001, "src/repro/obs/meta.py", 1,
            "no metric class discovered repo-wide — counter()/gauge() "
            "field declarations have vanished, so M001/M002 would check "
            "nothing",
        ))
    for _rel, cls, cs, gs in discovered:
        vocab |= _class_vocab(cls)
        counters |= cs
        gauges |= gs
    # name-level exemption: a field gauge() *anywhere* may shrink (the
    # M002 scan sees attribute names, not receiver types)
    return vocab, counters - gauges, drift


class _BenchScan(ast.NodeVisitor):
    """Per-function tracking of metrics-typed locals + attribute reads."""

    def __init__(self, vocab: Set[str]):
        self.vocab = vocab
        self.tracked: Set[str] = set()
        self.stack: List[ast.AST] = []
        self.bad: List[Tuple[int, str, str]] = []   # (line, attr, symbol)

    def _push(self, node):
        self.stack.append(node)
        self.generic_visit(node)
        self.stack.pop()

    visit_ClassDef = visit_FunctionDef = visit_AsyncFunctionDef = _push

    def _is_metrics_expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            return node.func.attr in _GETTER_CALLS
        if isinstance(node, ast.Attribute):
            return node.attr in _METRIC_ATTRS
        if isinstance(node, ast.Name):
            return node.id in self.tracked
        return False

    def visit_Assign(self, node: ast.Assign) -> None:
        self.generic_visit(node)
        metric = self._is_metrics_expr(node.value)
        for t in node.targets:
            if isinstance(t, ast.Name):
                if metric:
                    self.tracked.add(t.id)
                else:
                    self.tracked.discard(t.id)

    def visit_Attribute(self, node: ast.Attribute) -> None:
        recv = node.value
        is_metric_recv = (
            (isinstance(recv, ast.Name) and recv.id in self.tracked)
            or (isinstance(recv, ast.Attribute) and recv.attr in _METRIC_ATTRS)
        )
        if is_metric_recv and node.attr not in self.vocab:
            self.bad.append(
                (node.lineno, node.attr, enclosing_symbol(self.stack))
            )
        self.generic_visit(node)


@checker("metrics-contract")
def check_metrics(ctx: CheckContext):
    vocab, counters, findings = _load_vocab(ctx)
    for mod in ctx.glob_modules("benchmarks/*.py"):
        scan = _BenchScan(vocab)
        scan.visit(mod.tree)
        for line, attr, sym in scan.bad:
            findings.append(Finding(
                M001, mod.rel, line,
                f"reads .{attr} on a metrics object but no metric class "
                "defines it — renamed field or stale benchmark",
                sym,
            ))
    for mod in ctx.src_modules():
        for node in ast.walk(mod.tree):
            target = None
            if isinstance(node, ast.AugAssign) and isinstance(node.op, ast.Sub):
                target = node.target
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.value, ast.BinOp) \
                    and isinstance(node.value.op, ast.Sub):
                t, lhs = node.targets[0], node.value.left
                if isinstance(t, ast.Attribute) and isinstance(lhs, ast.Attribute) \
                        and t.attr == lhs.attr \
                        and attr_chain(t) == attr_chain(lhs):
                    target = t
            if isinstance(target, ast.Attribute) and target.attr in counters:
                findings.append(Finding(
                    M002, mod.rel, node.lineno,
                    f"decrements counter .{target.attr} — metric counters "
                    "are monotonic (use a gauge field if occupancy is "
                    "intended)",
                ))
    return findings
