"""End-to-end training driver: warehouse -> DPP -> trainer.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch dlrm-paper --steps 50 --smoke
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --steps 20 --smoke

DLRM runs the full paper pipeline (synthetic warehouse partitions -> DPP
extract/transform/load -> DLRM train steps).  LM archs are fed synthetic
token batches through the same Trainer (their data path in production is
the token-packing flavor of the same DPP service).
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

import jax

from repro import configs as cfglib
from repro.models.dlrm import DLRMConfig
from repro.optim import OptimizerConfig
from repro.train import Trainer, TrainerConfig


def dlrm_dpp_batches(cfg: DLRMConfig, batch_size: int, n_partitions: int = 2,
                     rows_per_partition: int = 2048, n_workers: int = 2):
    """Build a synthetic warehouse + DPP session; yield tensor batches."""
    from repro.core import dwrf
    from repro.core.datagen import DataGenConfig
    from repro.core.dpp import DPPSession, SessionSpec
    from repro.core.schema import make_schema
    from repro.core.transforms import default_dlrm_pipeline
    from repro.core.warehouse import Warehouse

    schema = make_schema("dlrm_table", n_dense=cfg.num_dense * 3,
                         n_sparse=max(cfg.num_tables * 3, 8), seed=0)
    wh = Warehouse()
    table = wh.create_table(schema)
    table.generate(
        n_partitions,
        DataGenConfig(rows_per_partition=rows_per_partition, seed=1),
        dwrf.DwrfWriterOptions(flattened=True, stripe_rows=512),
    )
    dense = schema.dense_ids[: cfg.num_dense]
    n_gen = max(cfg.num_tables // 4, 0)
    sparse = schema.sparse_ids[: cfg.num_tables - n_gen]
    pipe = default_dlrm_pipeline(
        dense, sparse, hash_size=cfg.vocab_per_table,
        firstx=cfg.max_ids_per_feature, n_derived=n_gen,
    )
    spec = SessionSpec(
        table=schema.name,
        partitions=tuple(range(n_partitions)),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=batch_size,
        rows_per_split=512,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse) + tuple(f"g{j}" for j in range(n_gen)),
        max_ids_per_feature=cfg.max_ids_per_feature,
    )
    session = DPPSession(spec, table, n_workers=n_workers, auto_scale=True)
    session.start()

    def gen():
        while True:
            b = session.clients[0].get_batch(timeout=5.0)
            if b is None:
                if session.master.finished and all(w.buffered == 0 for w in session.workers):
                    session.stop()
                    return
                continue
            yield b

    return gen(), session


def lm_synthetic_batches(cfg, batch_size: int, seq: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    while True:
        toks = rng.integers(0, cfg.vocab_size, (batch_size, seq), dtype=np.int32)
        batch = {"tokens": toks, "labels": np.roll(toks, -1, axis=1)}
        if cfg.frontend == "vision":
            batch["image_embeds"] = rng.normal(
                0, 0.02, (batch_size, cfg.num_patches, cfg.d_model)
            ).astype(np.float32)
        if cfg.frontend == "audio":
            batch["frames"] = rng.normal(
                0, 0.02, (batch_size, seq, cfg.d_model)
            ).astype(np.float32)
            dec = max(seq // 8, 16)
            dt = rng.integers(0, cfg.vocab_size, (batch_size, dec), dtype=np.int32)
            batch["tokens"] = dt
            batch["labels"] = np.roll(dt, -1, axis=1)
        yield batch


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dlrm-paper")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=64)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = cfglib.get_smoke_config(args.arch) if args.smoke else cfglib.get_config(args.arch)
    trainer = Trainer(
        cfg,
        OptimizerConfig(learning_rate=1e-3, warmup_steps=10, total_steps=args.steps),
        TrainerConfig(
            checkpoint_dir=args.checkpoint_dir,
            max_steps=args.steps,
            checkpoint_every=max(args.steps // 4, 10),
        ),
    )

    session = None
    if isinstance(cfg, DLRMConfig):
        batches, session = dlrm_dpp_batches(cfg, args.batch_size)
    else:
        batches = lm_synthetic_batches(cfg, args.batch_size, args.seq)

    t0 = time.time()
    state = trainer.fit(batches)
    wall = time.time() - t0
    losses = [m.loss for m in trainer.history]
    print(f"arch={cfg.name} steps={state['step']} wall_s={wall:.1f}")
    print(f"loss: first={losses[0]:.4f} last={losses[-1]:.4f}")
    print(f"data_stall_fraction={trainer.stall_fraction():.3f}")
    if session is not None:
        m = session.worker_metrics()
        print(f"dpp: storage_rx={m.storage_rx_bytes} tx={m.tx_bytes} "
              f"breakdown={ {k: round(v, 3) for k, v in m.cycle_breakdown().items()} }")
    return 0 if losses[-1] < losses[0] else 1


if __name__ == "__main__":
    sys.exit(main())
