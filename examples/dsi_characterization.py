"""Reproduce the paper's storage characterization on a synthetic table:
selective reading (Table 5), I/O sizes (Table 6), byte popularity (Fig 7),
and the Table 12 optimization ladder (FF -> CR -> FR -> LS).

  PYTHONPATH=src python examples/dsi_characterization.py
"""
import numpy as np

from repro.core import dwrf
from repro.core.datagen import DataGenConfig, generate_partition
from repro.core.reader import COALESCE_WINDOW, TableReader
from repro.core.schema import make_schema
from repro.core.warehouse import Warehouse


def main():
    schema = make_schema("rm1_like", n_dense=600, n_sparse=90, seed=0)
    wh = Warehouse()
    table = wh.create_table(schema)
    table.generate(
        2, DataGenConfig(rows_per_partition=2048, seed=1),
        dwrf.DwrfWriterOptions(flattened=True, stripe_rows=512),
    )
    rng = np.random.default_rng(0)

    # jobs select ~11% of features, weighted by popularity (drives Fig 7)
    fids = np.array(schema.logged_ids)
    pops = np.array([schema.feature(f).popularity for f in fids])
    pops /= pops.sum()
    for job in range(8):
        proj = rng.choice(fids, size=len(fids) // 9, replace=False, p=pops)
        reader = TableReader(table, sorted(proj.tolist()))
        res = reader.read_partition(table.partitions[job % 2])
        reader.finish_job()
    stats = reader.projection_stats()
    print("Table 5 (one job):", {k: round(v, 1) for k, v in stats.items() if "pct" in k},
          "(paper: ~9-11% features, 21-37% bytes)")

    io = np.array(res.io_sizes)
    print(f"Table 6 I/O sizes: mean={io.mean():.0f}B p50={np.percentile(io,50):.0f}B "
          f"p95={np.percentile(io,95):.0f}B n={len(io)}")

    stored = {
        f: 0.0 for f in fids
    }
    for m in table.partitions.values():
        for s in m.footer.stripes:
            for st in s.streams:
                if st.fid >= 0:
                    stored[st.fid] = stored.get(st.fid, 0.0) + st.length
    frac = table.popularity.bytes_fraction_for_traffic(stored, 0.8)
    print(f"Fig 7: {frac*100:.0f}% of stored bytes serve 80% of read traffic "
          f"(paper: 18-39%)")

    print("\nTable 12 ladder: see benchmarks/bench_optimizations.py for the "
          "full normalized throughput table.")


if __name__ == "__main__":
    main()
