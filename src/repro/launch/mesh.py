"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state; the dry-run sets XLA_FLAGS before first jax use.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Elastic-scaling helper: build a mesh for whatever devices exist."""
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: Optional[int] = None):
    """Smoke-scale mesh over the real local devices (CPU: 1 device)."""
    n = len(jax.devices())
    m = model_axis or 1
    return jax.make_mesh((n // m, m), ("data", "model"))


# TPU v5e hardware constants (roofline targets; this container is CPU-only).
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~4 links usable/chip)
CHIPS_PER_POD = 256
