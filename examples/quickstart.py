"""Quickstart: the full DSI pipeline in ~40 lines.

Synthesizes a feature table into the warehouse (DWRF columnar files with
feature flattening on simulated Tectonic/HDD storage), launches a DPP
session (Master + stateless Workers + Client), and trains a small DLRM on
the streamed tensor batches.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import configs as cfglib
from repro.launch.train import dlrm_dpp_batches
from repro.optim import OptimizerConfig
from repro.train import Trainer, TrainerConfig


def main():
    cfg = cfglib.get_smoke_config("dlrm-paper")
    batches, session = dlrm_dpp_batches(cfg, batch_size=128)

    trainer = Trainer(
        cfg,
        OptimizerConfig(learning_rate=1e-3, warmup_steps=5, total_steps=30),
        TrainerConfig(max_steps=30),
    )
    state = trainer.fit(batches)

    losses = [m.loss for m in trainer.history]
    print(f"trained {state['step']} steps; loss {losses[0]:.4f} -> {losses[-1]:.4f}")
    print(f"GPU-side data-stall fraction: {trainer.stall_fraction():.3f}")
    m = session.worker_metrics()
    print(
        "DPP worker bytes: storage_rx=%d extract_out=%d tensors_tx=%d"
        % (m.storage_rx_bytes, m.extract_out_bytes, m.tx_bytes)
    )
    print("DPP cycle breakdown:", {k: round(v, 3) for k, v in m.cycle_breakdown().items()})
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
