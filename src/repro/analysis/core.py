"""Checker framework: findings, rule registry, noqa/baseline, runner.

A *checker* is a function ``check(ctx) -> Iterable[Finding]`` registered
with :func:`checker`; the rules it may emit are declared up front with
:func:`rule` so the registry (and the doc-drift gate) always knows the
full rule catalog, including rules whose checker found nothing.

Suppression model (additive gate):

  * inline — a finding is dropped when its source line (or the line
    above) carries ``# repro: noqa(RULE-ID)`` / ``# repro: noqa``;
  * baseline — a checked-in file of finding keys
    (``rule|path|symbol|message``); baselined findings are reported as
    "known" and do not fail the run.  Keys avoid line numbers so pure
    line drift never invalidates the baseline.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

# -- findings ----------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str          # "REPRO-L001"
    path: str          # repo-relative posix path
    line: int          # 1-based
    message: str       # line-number free (keys must survive line drift)
    symbol: str = ""   # enclosing Class.method anchor, "" at module level

    @property
    def key(self) -> str:
        return f"{self.rule}|{self.path}|{self.symbol}|{self.message}"

    def render(self) -> str:
        where = f" [{self.symbol}]" if self.symbol else ""
        return f"{self.path}:{self.line}: {self.rule} {self.message}{where}"


# -- rule + checker registry -------------------------------------------------

_RULES: Dict[str, str] = {}
_CHECKERS: List[Tuple[str, Callable]] = []

_RULE_ID_RE = re.compile(r"^REPRO-[A-Z]\d{3}$")


def rule(rule_id: str, summary: str) -> str:
    """Declare a rule id with a one-line summary; returns the id."""
    if not _RULE_ID_RE.match(rule_id):
        raise ValueError(f"bad rule id {rule_id!r} (want REPRO-<letter><3 digits>)")
    if rule_id in _RULES and _RULES[rule_id] != summary:
        raise ValueError(f"rule {rule_id} declared twice with different summaries")
    _RULES[rule_id] = summary
    return rule_id


def checker(name: str) -> Callable[[Callable], Callable]:
    def deco(fn: Callable) -> Callable:
        _CHECKERS.append((name, fn))
        return fn
    return deco


def all_rules() -> Dict[str, str]:
    """rule-id -> summary, for ``--list-rules`` and the doc-drift gate."""
    _load_checkers()
    return dict(sorted(_RULES.items()))


def _load_checkers() -> None:
    # import for registration side effects; idempotent
    from repro.analysis import checks_clocks  # noqa: F401
    from repro.analysis import checks_kernels  # noqa: F401
    from repro.analysis import checks_locks  # noqa: F401
    from repro.analysis import checks_metrics  # noqa: F401
    from repro.analysis import checks_races  # noqa: F401
    from repro.analysis import checks_spans  # noqa: F401
    from repro.analysis import checks_threads  # noqa: F401


# -- parsed-source model -----------------------------------------------------


@dataclasses.dataclass
class SourceModule:
    path: Path
    rel: str                      # repo-relative posix path
    text: str
    lines: List[str]
    tree: ast.Module


class CheckContext:
    """Everything a checker may look at: the parsed ``src/repro`` tree plus
    lazy access to other repo files (tests, benchmarks)."""

    def __init__(self, repo: Path):
        self.repo = Path(repo)
        self.src = self.repo / "src" / "repro"
        self._cache: Dict[str, Optional[SourceModule]] = {}

    def load(self, rel: str) -> Optional[SourceModule]:
        """Parse one repo-relative file; None if absent or unparsable
        (checkers treat a missing anchor file as its own finding)."""
        if rel not in self._cache:
            p = self.repo / rel
            mod = None
            if p.is_file():
                text = p.read_text()
                try:
                    mod = SourceModule(p, rel, text, text.splitlines(),
                                       ast.parse(text, filename=rel))
                except SyntaxError:
                    mod = None
            self._cache[rel] = mod
        return self._cache[rel]

    def src_modules(self) -> List[SourceModule]:
        out = []
        for p in sorted(self.src.rglob("*.py")):
            m = self.load(p.relative_to(self.repo).as_posix())
            if m is not None:
                out.append(m)
        return out

    def glob_modules(self, pattern: str) -> List[SourceModule]:
        out = []
        for p in sorted(self.repo.glob(pattern)):
            m = self.load(p.relative_to(self.repo).as_posix())
            if m is not None:
                out.append(m)
        return out


# -- suppression -------------------------------------------------------------

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\(([^)]*)\))?")


def _suppressed(mod_lines: Sequence[str], finding: Finding) -> bool:
    # the finding's own line, or the line just above (for long statements)
    for ln in (finding.line, finding.line - 1):
        if 1 <= ln <= len(mod_lines):
            m = _NOQA_RE.search(mod_lines[ln - 1])
            if m:
                rules = m.group(1)
                if rules is None or finding.rule in {
                    r.strip() for r in rules.split(",")
                }:
                    return True
    return False


# -- baseline ----------------------------------------------------------------


def load_baseline(path: Path) -> List[str]:
    if not Path(path).is_file():
        return []
    return [
        ln for ln in Path(path).read_text().splitlines()
        if ln.strip() and not ln.lstrip().startswith("#")
    ]


def write_baseline(path: Path, findings: Iterable[Finding]) -> None:
    Path(path).parent.mkdir(parents=True, exist_ok=True)
    keys = sorted(f.key for f in findings)
    header = (
        "# repro.analysis baseline — known findings the gate tolerates.\n"
        "# Regenerate with: python -m repro.analysis --write-baseline\n"
        "# Keep this empty or near-empty: fix findings, don't bank them.\n"
    )
    Path(path).write_text(header + "".join(k + "\n" for k in keys))


# -- runner ------------------------------------------------------------------


def run_checks(
    repo: Path,
    rules: Optional[Sequence[str]] = None,
    baseline: Optional[Sequence[str]] = None,
) -> Tuple[List[Finding], List[Finding]]:
    """Run every registered checker over ``repo``.

    Returns ``(new, known)``: findings not in / in the baseline, after
    inline-noqa suppression and optional rule filtering.  CI fails iff
    ``new`` is non-empty.
    """
    _load_checkers()
    ctx = CheckContext(Path(repo))
    findings: List[Finding] = []
    for _name, fn in _CHECKERS:
        findings.extend(fn(ctx))
    if rules is not None:
        want = set(rules)
        unknown = want - set(_RULES)
        if unknown:
            raise ValueError(f"unknown rule id(s): {sorted(unknown)}")
        findings = [f for f in findings if f.rule in want]
    findings = [
        f for f in findings
        if not _mod_suppressed(ctx, f)
    ]
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    remaining = list(baseline or [])
    new, known = [], []
    for f in findings:
        if f.key in remaining:
            remaining.remove(f.key)   # baseline is a multiset
            known.append(f)
        else:
            new.append(f)
    return new, known


def _mod_suppressed(ctx: CheckContext, f: Finding) -> bool:
    mod = ctx.load(f.path)
    return mod is not None and _suppressed(mod.lines, f)


# -- shared AST helpers ------------------------------------------------------


def attr_chain(node: ast.AST) -> Optional[List[str]]:
    """``a.b.c`` -> ["a", "b", "c"]; None for non-name-rooted chains."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        parts.reverse()
        return parts
    return None


def enclosing_symbol(stack: Sequence[ast.AST]) -> str:
    names = [
        n.name for n in stack
        if isinstance(n, (ast.ClassDef, ast.FunctionDef, ast.AsyncFunctionDef))
    ]
    return ".".join(names)
