"""seamless-m4t-large-v2 — enc-dec multimodal backbone (audio frontend stub)
[arXiv:2308.11596]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    sharding_profile="fsdp",
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,          # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio",
)

SMOKE = dataclasses.replace(
    CONFIG, name="seamless-smoke", num_layers=2, encoder_layers=2,
    d_model=128, num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256,
    vocab_size=512, remat=False,
)
