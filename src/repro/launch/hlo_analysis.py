"""Trip-count-aware HLO cost analysis from compiled HLO text.

XLA's built-in ``compiled.cost_analysis()`` counts while-loop bodies ONCE,
ignoring trip counts — which undercounts a scan-over-layers model by ~the
layer count (verified empirically; see EXPERIMENTS.md §Dry-run methodology).
This module re-derives FLOPs / bytes-accessed / collective wire bytes by
walking the computation call graph with loop-trip-count multipliers taken
from each ``while`` op's ``known_trip_count`` backend config.

Covered: dot (GEMM) flops, per-op bytes for memory-touching opcodes,
ring-model collective wire bytes.  Validated against cost_analysis() on
loop-free modules in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "opaque": 0,
}

# one HLO shape like bf16[128,64]{1,0} or (tuple, of, shapes) — we parse the
# flat pieces and sum.
_SHAPE_PIECE_RE = re.compile(r"([a-z][a-z0-9]*)\[([\d,]*)\]")

_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^()]*\)|[a-z][a-z0-9]*\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")

# opcodes whose operands/results count as HBM traffic at computation level
_MEM_OPCODES = {
    "fusion", "dot", "convolution", "copy", "all-gather", "all-reduce",
    "reduce-scatter", "all-to-all", "collective-permute", "dynamic-slice",
    "dynamic-update-slice", "reduce", "broadcast", "transpose", "reshape",
    "concatenate", "slice", "pad", "gather", "scatter", "select", "sort",
    "convert", "iota", "rng-bit-generator", "custom-call", "cholesky",
    "triangular-solve", "reduce-window", "select-and-scatter", "exp", "add",
    "multiply", "subtract", "divide", "maximum", "minimum", "tanh", "log",
    "negate", "rsqrt", "sqrt", "power", "compare", "and", "or", "not",
}
_FREE_OPCODES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id",
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "all-gather-start", "all-reduce-start",
    "collective-permute-start",
}


def _shape_bytes(type_str: str, bf16_correction: bool = False) -> float:
    """Byte size of an HLO type string (sums tuple elements).

    bf16_correction: the XLA *CPU* backend promotes every bf16 dot (and its
    operands/results) to f32 because CPUs lack native bf16 GEMMs.  The TPU
    lowering of the same JAX program keeps those tensors bf16.  When the
    model's compute dtype is bf16 we therefore halve the bytes of rank>=3
    f32 tensors (activations); genuine f32 buffers in the program (norm
    scales, optimizer moments, rank<=2 reductions) are unaffected.  See
    EXPERIMENTS.md "Dry-run methodology".
    """
    total = 0.0
    for m in _SHAPE_PIECE_RE.finditer(type_str):
        dtype, dims = m.group(1), m.group(2)
        dim_list = [int(d) for d in dims.split(",") if d]
        n = 1
        for d in dim_list:
            n *= d
        nb = n * _DTYPE_BYTES.get(dtype, 4)
        if bf16_correction and dtype == "f32" and len(dim_list) >= 3:
            nb *= 0.5
        total += nb
    return total


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_PIECE_RE.search(type_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str  # operands + attributes tail (may span the rest of the line)


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    ops: List[Op]
    symbols: Dict[str, str]  # op name -> type str


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        stripped = line.strip()
        if current is None:
            hdr = _COMP_HDR_RE.match(stripped)
            if hdr and stripped.endswith("{"):
                current = Computation(
                    name=hdr.group(2), is_entry=bool(hdr.group(1)), ops=[], symbols={}
                )
            continue
        if stripped == "}":
            comps[current.name] = current
            if current.is_entry:
                entry_name = current.name
            current = None
            continue
        m = _OP_RE.match(line)
        if m:
            op = Op(name=m.group(1), type_str=m.group(2), opcode=m.group(3), rest=m.group(4))
            current.ops.append(op)
            current.symbols[op.name] = op.type_str
        elif stripped.startswith("%") and "parameter(" in stripped:
            pm = re.match(r"%([\w.\-]+)\s*=\s*(\S+)\s+parameter", stripped)
            if pm:
                op = Op(pm.group(1), pm.group(2), "parameter", "")
                current.ops.append(op)
                current.symbols[op.name] = op.type_str
    return comps


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    wire_bytes: float = 0.0            # per-device collective bytes (ring model)
    collective_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    dot_flops_by_meta: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0

    def merge_scaled(self, other: "HloCost", mult: float) -> None:
        self.flops += other.flops * mult
        self.bytes_accessed += other.bytes_accessed * mult
        self.wire_bytes += other.wire_bytes * mult
        self.unknown_trip_loops += other.unknown_trip_loops
        for k, v in other.collective_counts.items():
            self.collective_counts[k] = self.collective_counts.get(k, 0) + v * mult
        for k, v in other.dot_flops_by_meta.items():
            self.dot_flops_by_meta[k] = self.dot_flops_by_meta.get(k, 0) + v * mult


def _dot_flops(op: Op, symbols: Dict[str, str]) -> float:
    result_elems = 1
    for d in _shape_dims(op.type_str):
        result_elems *= d
    contract = _CONTRACT_RE.search(op.rest)
    if not contract:
        return 0.0
    c_dims = [int(d) for d in contract.group(1).split(",") if d]
    operands = _OPERAND_RE.findall(op.rest.split(")")[0])
    if not operands:
        return 0.0
    lhs_type = symbols.get(operands[0])
    if lhs_type is None:
        return 2.0 * result_elems  # conservative fallback
    lhs_dims = _shape_dims(lhs_type)
    k = 1
    for d in c_dims:
        if d < len(lhs_dims):
            k *= lhs_dims[d]
    return 2.0 * result_elems * k


def _group_size(rest: str, default: int = 1) -> int:
    gm = _GROUPS_IOTA_RE.search(rest)
    if gm:
        return int(gm.group(2))
    gm = _GROUPS_LIST_RE.search(rest)
    if gm:
        return len([x for x in gm.group(1).split(",") if x.strip()])
    return default


def _collective_wire(kind: str, nbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    kind = kind.replace("-start", "")
    if kind == "all-gather":
        return nbytes * (g - 1) / g
    if kind == "all-reduce":
        return 2.0 * nbytes * (g - 1) / g
    if kind == "reduce-scatter":
        return nbytes * (g - 1)
    if kind == "all-to-all":
        return nbytes * (g - 1) / g
    if kind == "collective-permute":
        return float(nbytes)
    return 0.0


def analyze(text: str, default_trip: int = 1, bf16_activations: bool = False) -> HloCost:
    comps = parse_module(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return HloCost()
    cache: Dict[str, HloCost] = {}

    def _op_bytes(op: Op, comp: Computation) -> float:
        """HBM bytes for one computation-level op, mirroring XLA's model:
        slicing ops are output-driven (they never stream the whole buffer),
        everything else reads operands + writes the result."""
        result = _shape_bytes(op.type_str, bf16_activations)
        if op.opcode in ("dynamic-slice", "gather", "slice"):
            return 2.0 * result
        if op.opcode == "dynamic-update-slice":
            # read + write of the updated window (operand 1), buffer aliased
            operands = _OPERAND_RE.findall(
                op.rest[: op.rest.index(")")] if ")" in op.rest else op.rest
            )
            upd = (
                _shape_bytes(comp.symbols.get(operands[1], ""), bf16_activations)
                if len(operands) > 1 else 0
            )
            return 2.0 * upd
        if op.opcode in ("broadcast", "iota"):
            return float(result)
        nbytes = float(result)
        operands = _OPERAND_RE.findall(
            op.rest[: op.rest.index(")")] if ")" in op.rest else op.rest
        )
        for o in operands:
            t = comp.symbols.get(o)
            if t:
                nbytes += _shape_bytes(t, bf16_activations)
        return nbytes

    def comp_cost(name: str, depth: int = 0, fused: bool = False) -> HloCost:
        key = (name, fused)
        if key in cache:
            return cache[key]
        comp = comps.get(name)
        cost = HloCost()
        if comp is None or depth > 64:
            return cost
        cache[key] = cost  # provisional (cycles shouldn't occur)
        for op in comp.ops:
            if op.opcode in _FREE_OPCODES:
                continue
            if op.opcode == "while":
                trip = default_trip
                tm = _TRIP_RE.search(op.rest)
                if tm:
                    trip = int(tm.group(1))
                else:
                    cost.unknown_trip_loops += 1
                bm = _BODY_RE.search(op.rest)
                cm = _COND_RE.search(op.rest)
                if bm:
                    cost.merge_scaled(comp_cost(bm.group(1), depth + 1, fused), trip)
                if cm:
                    cost.merge_scaled(comp_cost(cm.group(1), depth + 1, fused), trip + 1)
                continue
            if op.opcode in ("fusion", "call", "conditional", "map", "sort", "reduce",
                             "reduce-window", "scatter", "select-and-scatter",
                             "async-start", "custom-call"):
                sub_fused = fused or op.opcode == "fusion"
                for sub in _CALLS_RE.findall(op.rest):
                    cost.merge_scaled(comp_cost(sub, depth + 1, sub_fused), 1.0)
            if op.opcode == "dot":
                f = _dot_flops(op, comp.symbols)
                cost.flops += f
                meta = re.search(r'op_name="([^"]*)"', op.rest)
                key_ = meta.group(1).split("/")[-1] if meta else "dot"
                cost.dot_flops_by_meta[key_] = cost.dot_flops_by_meta.get(key_, 0) + f
            base = op.opcode.replace("-start", "")
            if base in _COLLECTIVES or op.opcode in _COLLECTIVES:
                nbytes = _shape_bytes(op.type_str, bf16_activations)
                g = _group_size(op.rest)
                cost.wire_bytes += _collective_wire(base, nbytes, g)
                cost.collective_counts[base] = cost.collective_counts.get(base, 0) + 1
            if not fused and op.opcode in _MEM_OPCODES:
                cost.bytes_accessed += _op_bytes(op, comp)
        return cost

    total = HloCost()
    total.merge_scaled(comp_cost(entry.name), 1.0)
    return total
