"""Roofline-term derivation from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips * peak FLOP/s)
memory term     = HLO_bytes / (chips * HBM bw)
collective term = collective bytes-on-wire per chip / link bw

cost_analysis() provides flops/bytes.  Collective bytes are NOT in
cost_analysis, so we parse the (post-SPMD) HLO text: for each all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute we take the
result shape, the replica-group size, and a ring-algorithm cost model to get
per-device bytes on the wire.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Any, Dict, List, Optional

from repro.launch import mesh as meshlib

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([\d,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(",
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    wire_bytes_per_device: float     # ring-model bytes each device sends
    result_bytes: Dict[str, int]

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: Dict[str, int] = {}
    result_bytes: Dict[str, int] = {}
    wire = 0.0
    for m in _COLLECTIVE_RE.finditer(hlo_text):
        dtype, dims, kind = m.group(1), m.group(2), m.group(3)
        nbytes = _shape_bytes(dtype, dims)
        # group size: scan forward a bounded window for replica_groups
        window = hlo_text[m.end(): m.end() + 2000]
        g = 1
        gm = _IOTA_GROUPS_RE.search(window)
        if gm:
            g = int(gm.group(2))
        else:
            gm = _GROUPS_RE.search(window)
            if gm:
                g = len(gm.group(1).split(","))
        counts[kind] = counts.get(kind, 0) + 1
        result_bytes[kind] = result_bytes.get(kind, 0) + nbytes
        if g <= 1:
            continue
        # ring-model wire bytes per participating device
        if kind == "all-gather":
            wire += nbytes * (g - 1) / g            # result is the gathered buf
        elif kind == "all-reduce":
            wire += 2.0 * nbytes * (g - 1) / g      # reduce-scatter + all-gather
        elif kind == "reduce-scatter":
            wire += nbytes * (g - 1)                 # result is the scattered shard
        elif kind == "all-to-all":
            wire += nbytes * (g - 1) / g
        elif kind == "collective-permute":
            wire += nbytes
    return CollectiveStats(counts=counts, wire_bytes_per_device=wire, result_bytes=result_bytes)


@dataclasses.dataclass
class RooflineTerms:
    flops: float                 # total HLO flops (all devices)
    hbm_bytes: float             # total HLO bytes accessed
    wire_bytes_per_device: float
    chips: int
    model_flops: float = 0.0     # 6*N*D useful flops

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * meshlib.PEAK_FLOPS_BF16)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * meshlib.HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.wire_bytes_per_device / meshlib.ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Roofline step-time estimate: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        if self.model_flops and self.flops:
            return self.model_flops / self.flops
        return None

    @property
    def roofline_fraction(self) -> Optional[float]:
        """Fraction of chip peak spent on *useful* model flops at the
        roofline-predicted step time (MFU upper bound for this lowering)."""
        if not self.model_flops:
            return None
        t = self.step_time_s
        return self.model_flops / (t * self.chips * meshlib.PEAK_FLOPS_BF16)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "wire_bytes_per_device": self.wire_bytes_per_device,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops_estimate(cfg: Any, shape: Any) -> float:
    """6*N_active*D for train; 2*N_active*D for prefill; 2*N_active*B for decode."""
    from repro.models.common import ModelConfig, param_count
    from repro.models.dlrm import DLRMConfig
    from repro.models import build_model

    if isinstance(cfg, DLRMConfig):
        n = param_count(build_model(cfg).param_specs())
        # embedding lookups are sparse; MLP params dominate compute
        tokens = shape.global_batch
        return 6.0 * n * tokens * 1e-3  # rough: tables are lookup-bound
    n_active = cfg.active_params()
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def attention_flops_estimate(cfg: Any, shape: Any) -> float:
    """Causal attention score+value flops (not in 6ND), for context."""
    from repro.models.dlrm import DLRMConfig

    if isinstance(cfg, DLRMConfig) or getattr(cfg, "attention_free", False):
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    h, d = cfg.num_heads, cfg.head_dim
    if cfg.family == "hybrid":
        layers = cfg.num_layers // cfg.block_period
    else:
        layers = cfg.num_layers
    if shape.mode == "train":
        return 3.0 * 2.0 * b * h * s * s * d * layers  # fwd+bwd, causal half
    if shape.mode == "prefill":
        return 2.0 * b * h * s * s * d * layers / 2
    return 2.0 * 2.0 * b * h * s * d * layers
