import numpy as np

from repro.core.popularity import PopularityTracker


def test_cdf_skewed_reuse():
    """Fig 7: with Zipf-like reuse, a minority of bytes serves most traffic."""
    tr = PopularityTracker()
    stored = {i: 100.0 for i in range(100)}
    rng = np.random.default_rng(0)
    for job in range(50):
        feats = rng.zipf(1.5, 10) % 100
        tr.record_job({int(f): 100.0 for f in feats})
    frac = tr.bytes_fraction_for_traffic(stored, 0.8)
    assert frac < 0.45


def test_feature_order_by_bytes():
    tr = PopularityTracker()
    tr.record_job({1: 10.0, 2: 1000.0, 3: 1.0})
    assert tr.feature_order() == [2, 1, 3]
