"""§7.2: fused multi-feature kernel vs per-feature dispatch, plus per-kernel
timings (XLA-compiled oracle path on CPU; the Pallas kernels are the TPU
target and are correctness-validated in interpret mode by tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_us
from repro.kernels import ref


def run() -> None:
    rows, feats = 512, 1024
    key = jax.random.PRNGKey(0)
    ids = jax.random.randint(key, (rows, feats), 0, 1 << 30, jnp.int32)
    codes = jnp.ones((feats,), jnp.int32)               # all SigridHash
    p0 = jnp.arange(feats, dtype=jnp.int32) + 1
    p1 = jnp.full((feats,), 100_000, jnp.int32)

    fused = jax.jit(ref.fused_transform)
    fused(ids, codes, p0, p1).block_until_ready()
    us_fused = time_us(lambda: fused(ids, codes, p0, p1).block_until_ready())

    per_feature = jax.jit(lambda col, salt: ref.sigrid_hash(col, salt, 100_000))
    per_feature(ids[:, 0], 1).block_until_ready()

    def per_feature_all():
        for f in range(feats):
            per_feature(ids[:, f], f + 1)
        jax.block_until_ready(per_feature(ids[:, feats - 1], feats))

    us_per = time_us(per_feature_all, repeat=1)
    emit("sec7_2.fused_1024_features", us_fused, f"rows={rows}")
    emit("sec7_2.per_feature_1024_dispatches", us_per,
         f"speedup={us_per/us_fused:.0f}x (paper: ~3 orders of magnitude on GPU)")

    # per-kernel oracle timings at a production-ish tile
    vals = jax.random.normal(key, (512, 512))
    borders = jnp.linspace(-3, 3, 63)
    bk = jax.jit(ref.bucketize)
    bk(vals, borders).block_until_ready()
    emit("kernel.bucketize_512x512", time_us(lambda: bk(vals, borders).block_until_ready()), "")

    table = jax.random.normal(key, (100_000, 64))
    bag = jax.random.randint(key, (256, 32), 0, 100_000, jnp.int32)
    mask = jnp.ones((256, 32), jnp.float32)
    eb = jax.jit(ref.embedding_bag)
    eb(table, bag, mask).block_until_ready()
    emit("kernel.embedding_bag_256x32x64", time_us(lambda: eb(table, bag, mask).block_until_ready()), "")

    q = jax.random.normal(key, (1, 8, 512, 64), jnp.float32)
    fa = jax.jit(lambda q: ref.flash_attention(q, q, q, causal=True))
    fa(q).block_until_ready()
    emit("kernel.attention_8h_512s", time_us(lambda: fa(q).block_until_ready()), "")

    # SSD chunk recurrence (Mamba-2 trainer hot-spot; chunked vs sequential)
    bh, s, p, n = 8, 1024, 64, 64
    xs = jax.random.normal(key, (bh, s, p)) * 0.5
    dts = jax.nn.softplus(jax.random.normal(key, (bh, s)))
    a_ = -jnp.exp(jax.random.normal(key, (bh,)) * 0.3)
    bv = jax.random.normal(key, (bh, s, n)) * 0.5
    seq = jax.jit(ref.ssd_chunk_forward)
    seq(xs, dts, a_, bv, bv).block_until_ready()
    us_seq = time_us(lambda: seq(xs, dts, a_, bv, bv).block_until_ready())
    emit("kernel.ssd_sequential_8h_1024s", us_seq,
         "chunked Pallas kernel validated in tests/test_kernels.py")
