"""Selective table reader: feature projection -> I/O plan -> decoded columns.

Implements the read-path co-design ladder of Table 12:
  * map files: whole-stripe reads (baseline; massive over-read),
  * flattened files: per-feature stream reads (tiny I/Os, HDD seek cliff),
  * **coalesced reads (CR)**: merge selected stream extents whose gap keeps
    the merged I/O within ``coalesce_window`` bytes (1.25 MiB, §7.5) —
    over-reading the skipped bytes to amortize seeks,
  * feature reordering (FR) happens at write time (warehouse) and shows up
    here as fewer over-read bytes inside each coalesced window.

Every read returns both the decoded columns and an I/O accounting record
(bytes used vs read, I/O size distribution — Tables 5 and 6).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dwrf
from repro.core.schema import ColumnBatch
from repro.core.tectonic import IOStats, TectonicFS
from repro.core.warehouse import PartitionMeta, Table

COALESCE_WINDOW = int(1.25 * 1024 * 1024)   # §7.5


@dataclasses.dataclass
class ReadPlan:
    extents: List[Tuple[int, int]]                      # (offset, len) I/Os
    wanted: List[Tuple[int, int, dwrf.StreamInfo]]      # (stripe_idx, fid, stream)
    bytes_wanted: int
    bytes_planned: int

    @property
    def over_read_ratio(self) -> float:
        return self.bytes_planned / max(self.bytes_wanted, 1)


@dataclasses.dataclass
class ReadResult:
    batch: ColumnBatch
    bytes_read: int
    bytes_used: int
    io_sizes: List[int]
    feature_bytes: Dict[int, int]


def plan_reads(
    footer: dwrf.DwrfFooter,
    feature_ids: Sequence[int],
    coalesce_window: int = 0,
    include_labels: bool = True,
) -> ReadPlan:
    """Build the extent list for a feature projection over one file."""
    want_f = set(feature_ids)
    wanted: List[Tuple[int, int, dwrf.StreamInfo]] = []
    for si, stripe in enumerate(footer.stripes):
        if footer.flattened:
            for s in stripe.streams:
                if s.fid in want_f or (include_labels and s.kind == "labels"):
                    wanted.append((si, s.fid, s))
        else:
            # map encoding: must read the monolithic map (+ labels) streams
            for s in stripe.streams:
                wanted.append((si, s.fid, s))

    streams = sorted((s for _, _, s in wanted), key=lambda s: s.offset)
    bytes_wanted = sum(s.length for s in streams)

    extents: List[Tuple[int, int]] = []
    for s in streams:
        if (
            coalesce_window
            and extents
            and s.offset + s.length - extents[-1][0] <= coalesce_window
        ):
            off, ln = extents[-1]
            extents[-1] = (off, max(ln, s.offset + s.length - off))
        else:
            extents.append((s.offset, s.length))
    bytes_planned = sum(l for _, l in extents)
    return ReadPlan(
        extents=extents, wanted=wanted,
        bytes_wanted=bytes_wanted, bytes_planned=bytes_planned,
    )


class TableReader:
    """Reads a feature projection from a table's partitions with accounting."""

    def __init__(
        self,
        table: Table,
        feature_ids: Sequence[int],
        coalesce_window: int = COALESCE_WINDOW,
        record_popularity: bool = True,
    ):
        self.table = table
        self.feature_ids = list(feature_ids)
        self.coalesce_window = coalesce_window
        self.record_popularity = record_popularity
        self._job_feature_bytes: Dict[int, float] = {}

    def read_partition(
        self, meta: PartitionMeta, row_limit: Optional[int] = None
    ) -> ReadResult:
        footer = meta.footer
        plan = plan_reads(footer, self.feature_ids, self.coalesce_window)
        blobs = self.table.fs.read_extents(meta.path, plan.extents)

        # slice each wanted stream back out of its (possibly merged) extent
        extent_map: List[Tuple[int, int, bytes]] = [
            (off, ln, blob) for (off, ln), blob in zip(plan.extents, blobs)
        ]
        extent_offsets = np.array([e[0] for e in extent_map])

        per_stripe: Dict[int, Dict[Tuple[int, str], bytes]] = {}
        feature_bytes: Dict[int, int] = {}
        for si, fid, s in plan.wanted:
            ei = int(np.searchsorted(extent_offsets, s.offset, "right") - 1)
            off0, _, blob = extent_map[ei]
            raw = blob[s.offset - off0: s.offset - off0 + s.length]
            per_stripe.setdefault(si, {})[(s.fid, s.kind)] = raw
            if fid >= 0:
                feature_bytes[fid] = feature_bytes.get(fid, 0) + s.length

        from repro.core.schema import concat_batches

        parts = []
        for si in sorted(per_stripe):
            stripe = footer.stripes[si]
            parts.append(
                dwrf.decode_stripe_features(stripe, per_stripe[si], self.feature_ids)
            )
            if row_limit and sum(p.num_rows for p in parts) >= row_limit:
                break
        batch = concat_batches(parts)
        if row_limit:
            batch = batch.slice_rows(0, min(row_limit, batch.num_rows))

        for fid, nb in feature_bytes.items():
            self._job_feature_bytes[fid] = self._job_feature_bytes.get(fid, 0) + nb

        return ReadResult(
            batch=batch,
            bytes_read=plan.bytes_planned,
            bytes_used=plan.bytes_wanted,
            io_sizes=[l for _, l in plan.extents],
            feature_bytes=feature_bytes,
        )

    def finish_job(self) -> None:
        """Record this job's feature-read footprint into table popularity."""
        if self.record_popularity and self._job_feature_bytes:
            self.table.popularity.record_job(self._job_feature_bytes)
            self._job_feature_bytes = {}

    # -- dataset-level accounting (Tables 3 & 5) ----------------------------

    def projection_stats(self, partitions: Optional[Sequence[int]] = None) -> Dict[str, float]:
        metas = self.table.select_partitions(partitions)
        bytes_total = sum(m.nbytes for m in metas)
        bytes_used = 0
        feats_total = len(self.table.schema.logged_ids)
        for m in metas:
            plan = plan_reads(m.footer, self.feature_ids, 0, include_labels=False)
            bytes_used += plan.bytes_wanted
        return {
            "pct_features_used": 100.0 * len(self.feature_ids) / max(feats_total, 1),
            "pct_bytes_used": 100.0 * bytes_used / max(bytes_total, 1),
            "bytes_total": float(bytes_total),
            "bytes_used": float(bytes_used),
        }
