import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as M
from repro.models.common import ModelConfig, MoEConfig
from repro.models.common import init_params

KEY = jax.random.PRNGKey(0)


def _cfg(e=4, k=2, dff=32, d=16):
    return ModelConfig(
        name="t", family="moe", num_layers=1, d_model=d, num_heads=2,
        num_kv_heads=2, d_ff=dff, vocab_size=64, head_dim=8,
        moe=MoEConfig(num_experts=e, top_k=k, d_ff=dff, capacity_factor=8.0),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )


def _dense_moe(params, x, m):
    """Reference: run every expert densely, combine by gates."""
    t = x.shape[0]
    idx, gates, _ = M.route(params["router"], x, m)
    outs = []
    for e in range(m.num_experts):
        h = jax.nn.silu(x @ params["wi_gate"][e]) * (x @ params["wi_up"][e])
        outs.append(h @ params["wo"][e])
    outs = jnp.stack(outs, 1)          # (T, E, d)
    oh = jax.nn.one_hot(idx, m.num_experts)        # (T,k,E)
    w = jnp.einsum("tke,tk->te", oh, gates)
    return jnp.einsum("te,ted->td", w, outs)


def test_moe_matches_dense_reference_with_ample_capacity():
    cfg = _cfg()
    params = init_params(M.moe_specs(cfg), KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y, aux = M.moe_forward(params, x, cfg)
    y_ref = _dense_moe(params, x.reshape(-1, cfg.d_model), cfg.moe).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
    assert float(aux) > 0


def test_capacity_dropping_bounds_tokens():
    cfg = _cfg()
    m = cfg.moe
    import dataclasses
    tight = dataclasses.replace(m, capacity_factor=0.25)
    cfg2 = dataclasses.replace(cfg, moe=tight)
    params = init_params(M.moe_specs(cfg2), KEY)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, cfg.d_model))
    y, _ = M.moe_forward(params, x, cfg2)
    assert np.isfinite(np.asarray(y)).all()


def test_shared_experts_added():
    import dataclasses
    cfg = _cfg()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, num_shared_experts=1, shared_d_ff=32)
    )
    params = init_params(M.moe_specs(cfg), KEY)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 4, cfg.d_model))
    y, _ = M.moe_forward(params, x, cfg)
    # zeroing shared expert changes the output
    params2 = dict(params)
    params2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    y2, _ = M.moe_forward(params2, x, cfg)
    assert float(jnp.max(jnp.abs(y - y2))) > 1e-6


def test_router_gates_normalized():
    cfg = _cfg()
    params = init_params(M.moe_specs(cfg), KEY)
    x = jax.random.normal(jax.random.PRNGKey(4), (32, cfg.d_model))
    idx, gates, aux = M.route(params["router"], x, cfg.moe)
    np.testing.assert_allclose(np.asarray(jnp.sum(gates, -1)), 1.0, atol=1e-5)
    assert int(jnp.max(idx)) < cfg.moe.num_experts
