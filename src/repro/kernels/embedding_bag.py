"""Pallas TPU kernel: pooled embedding-bag (DLRM's hot sparse op).

TPU adaptation of the GPU gather: the grid walks (batch_tile, bag_slot);
for each slot the scalar-prefetched ids pick the embedding-table block to
stream into VMEM (BlockSpec index_map reads the prefetch ref), and the
output tile accumulates mask-weighted rows across the L bag slots — a
gather expressed as data-dependent block scheduling instead of
random-access loads.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, mask_ref, table_row_ref, out_ref, denom_ref, *, mean: bool):
    l = pl.program_id(1)
    n_l = pl.num_programs(1)

    @pl.when(l == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)
        denom_ref[...] = jnp.zeros_like(denom_ref)

    m = mask_ref[0, l]                                 # scalar f32
    out_ref[...] += table_row_ref[...] * m
    denom_ref[...] += m

    if mean:
        @pl.when(l == n_l - 1)
        def _finish():
            out_ref[...] = out_ref[...] / jnp.maximum(denom_ref[...], 1.0)


@functools.partial(jax.jit, static_argnames=("mode", "interpret"))
def embedding_bag(
    table: jax.Array,     # (V, E) f32
    ids: jax.Array,       # (B, L) int32
    mask: jax.Array,      # (B, L) f32
    *,
    mode: str = "mean",   # "mean" | "sum" (static: picks the finish pass)
    interpret: bool = False,
) -> jax.Array:
    """Pooled bag: out[b] = sum_l mask[b,l] * table[ids[b,l]], divided by
    max(sum(mask), 1) when ``mode="mean"`` (the DLRM pooling denominator)."""
    if mode not in ("mean", "sum"):
        raise ValueError(f"mode must be 'mean' or 'sum', got {mode!r}")
    v, e = table.shape
    b, l = ids.shape

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, l),
        in_specs=[
            pl.BlockSpec((1, l), lambda i, j, ids_p: (i, 0)),        # mask row
            pl.BlockSpec((1, e), lambda i, j, ids_p: (ids_p[i, j], 0)),  # table row
        ],
        out_specs=[
            pl.BlockSpec((1, e), lambda i, j, ids_p: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, ids_p: (i, 0)),
        ],
    )
    out, _ = pl.pallas_call(
        functools.partial(_kernel, mean=(mode == "mean")),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, e), table.dtype),
            jax.ShapeDtypeStruct((b, 1), jnp.float32),
        ],
        interpret=interpret,
    )(ids, mask.astype(jnp.float32), table)
    return out
