"""End-to-end training loop: DPP output -> Trainer -> DLRM with the tiered
embedding store (ISSUE 9).

Covers the four acceptance properties:
  (a) loss decreases over a live two-tenant DPP run,
  (b) batches consumed == batches produced (no drop, no duplicate),
  (c) tiered-embedding lookups are byte-identical to a flat-table run —
      the hot/cold split is a pure optimization,
  (d) a partition rewrite mid-run never serves stale embedding rows
      (generation invalidation, checked under the lock-order sanitizer).
"""
import threading

import numpy as np
import pytest

from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.dpp import DPPService, SessionSpec
from repro.core.schema import make_schema
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse
from repro.models.dlrm import DLRMConfig
from repro.optim import OptimizerConfig
from repro.train import (
    TieredEmbeddingStore,
    Trainer,
    TrainerConfig,
    make_store_for_model,
)

BATCH = 128
ROWS_PER_SPLIT = 256
ROWS_PER_PART = 512
N_PARTS = 2


def _dlrm_cfg() -> DLRMConfig:
    return DLRMConfig(
        num_dense=6, num_tables=3, vocab_per_table=500, embed_dim=8,
        max_ids_per_feature=8, bottom_mlp=(16, 8), top_mlp=(32, 1),
    )


def _build_service(seed: int = 1):
    """Warehouse + DPPService + a SessionSpec whose tensor shapes match
    ``_dlrm_cfg()`` (dense width, table count, bag length, vocab)."""
    cfg = _dlrm_cfg()
    wh = Warehouse()
    schema = make_schema("train_e2e", 8, 6, seed=0)
    table = wh.create_table(schema)
    table.generate(
        N_PARTS, DataGenConfig(rows_per_partition=ROWS_PER_PART, seed=seed),
        dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256),
    )
    dense = schema.dense_ids[: cfg.num_dense]
    sparse = schema.sparse_ids[: cfg.num_tables]
    pipe = default_dlrm_pipeline(
        dense, sparse, hash_size=cfg.vocab_per_table,
        firstx=cfg.max_ids_per_feature,
    )
    spec = SessionSpec(
        table=schema.name, partitions=tuple(range(N_PARTS)),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=BATCH, rows_per_split=ROWS_PER_SPLIT,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=cfg.max_ids_per_feature,
    )
    return DPPService(wh), spec, cfg, table


def _opt_cfg(steps: int) -> OptimizerConfig:
    return OptimizerConfig(
        learning_rate=1e-2, warmup_steps=4, total_steps=steps
    )


def _client_batches(sess, epochs_extra: int = 0):
    """Yield every batch the session's client delivers (one epoch), then
    optionally replay the recorded epoch ``epochs_extra`` more times."""
    replay = []
    while True:
        b = sess.clients[0].get_batch(timeout=5.0)
        if b is None:
            if sess.master.finished and all(
                w.buffered == 0 for w in sess.workers
            ):
                break
            continue
        replay.append(b)
        yield b
    for _ in range(epochs_extra):
        for b in replay:
            yield b


def test_two_tenant_dpp_to_trainer_loss_and_delivery():
    """(a) + (b): tenant_a feeds a tiered-store Trainer while tenant_b
    drains the same table through the shared cache concurrently."""
    svc, spec, cfg, _ = _build_service()
    sess_a = svc.create_session("tenant_a", spec, n_workers=2)
    sess_b = svc.create_session("tenant_b", spec, n_workers=2)
    results = {}
    tb = threading.Thread(
        target=lambda: results.update(
            b=sess_b.run_to_completion(timeout_s=120)
        )
    )
    tb.start()

    store = make_store_for_model(
        cfg, hot_rows_per_table=64, seed=3, admit_reads=2, host_dram_rows=64
    )
    steps = 40
    trainer = Trainer(
        cfg, _opt_cfg(steps),
        TrainerConfig(max_steps=steps, trace_stall=False),
        embedding_store=store,
    )
    sess_a.start()
    try:
        state = trainer.fit(_client_batches(sess_a, epochs_extra=10))
    finally:
        sess_a.stop()
    tb.join(timeout=120)
    assert not tb.is_alive()

    # (a) the loop actually trains
    losses = [m.loss for m in trainer.history]
    assert state["step"] == steps
    assert losses[-1] < losses[0]
    assert store.stats.hot_hits > 0          # the tier saw real traffic

    # (b) delivery accounting: every produced batch was consumed, exactly
    # once — split layout fixes the expected count (no partial chunks:
    # ROWS_PER_SPLIT is a multiple of BATCH)
    total_rows = N_PARTS * ROWS_PER_PART
    expected_batches = total_rows // BATCH
    assert sess_a.clients[0].metrics.batches == expected_batches
    assert sess_a.worker_metrics().rows_done == total_rows
    assert len(results["b"]) == expected_batches
    assert sum(len(b["dense"]) for b in results["b"]) == total_rows


def test_tiered_lookups_byte_identical_to_flat():
    """(c): the same recorded DPP epoch trained through a tiered store and
    through a flat (hot capacity 0) store gives bit-equal losses and
    bit-equal final host tables — tiering is a pure optimization."""
    svc, spec, cfg, _ = _build_service()
    sess = svc.create_session("tenant_c", spec, n_workers=2)
    batches = sess.run_to_completion(timeout_s=120)
    assert batches

    rng = np.random.default_rng(7)
    tables = rng.normal(
        0, 0.01, (cfg.num_tables, cfg.vocab_per_table, cfg.embed_dim)
    ).astype(np.float32)

    # store-level differential on the raw batch tensors first
    tiered = TieredEmbeddingStore(tables, 64, admit_reads=1, host_dram_rows=32)
    flat = TieredEmbeddingStore(tables, 0)
    for b in batches:
        p_t = tiered.pooled(b["sparse_ids"], b["sparse_mask"])
        p_f = flat.pooled(b["sparse_ids"], b["sparse_mask"])
        assert np.array_equal(p_t, p_f)
    assert tiered.stats.hot_hits > 0

    def run(hot_rows: int):
        store = TieredEmbeddingStore(
            tables, hot_rows, admit_reads=1, host_dram_rows=32
        )
        steps = 3 * len(batches)
        tr = Trainer(
            cfg, _opt_cfg(steps),
            TrainerConfig(max_steps=steps, trace_stall=False),
            embedding_store=store,
        )
        tr.fit(iter(list(batches) * 3))
        return [m.loss for m in tr.history], store

    losses_flat, store_flat = run(0)
    losses_tiered, store_tiered = run(64)
    assert losses_flat == losses_tiered
    assert np.array_equal(
        store_flat.host_tables(), store_tiered.host_tables()
    )
    assert np.array_equal(
        store_flat.adagrad_state(), store_tiered.adagrad_state()
    )
    assert store_tiered.stats.hot_hits > 0
    assert store_flat.stats.hot_hits == 0


@pytest.mark.lockdep
def test_partition_rewrite_never_serves_stale_rows():
    """(d): a mid-run partition rewrite triggers a table reload +
    generation bump; concurrent lookups see either the old or the new
    tables atomically, and after the reload no pre-bump row is served."""
    svc, spec, cfg, table = _build_service()
    rng = np.random.default_rng(11)
    shape = (cfg.num_tables, cfg.vocab_per_table, cfg.embed_dim)
    old_tables = rng.normal(0, 0.01, shape).astype(np.float32)
    new_tables = rng.normal(0, 0.01, shape).astype(np.float32)
    store = TieredEmbeddingStore(
        old_tables, 64, admit_reads=1, host_dram_rows=32
    )

    ids = (rng.integers(0, cfg.vocab_per_table,
                        (16, cfg.num_tables, cfg.max_ids_per_feature))
           .astype(np.int64))
    mask = np.ones(ids.shape, np.float32)

    def expect(tabs):
        emb = np.stack([tabs[t][ids[:, t]] for t in range(cfg.num_tables)], 1)
        return (emb.sum(axis=2) / ids.shape[2]).astype(np.float32)

    p_old, p_new = expect(old_tables), expect(new_tables)
    # warm the hot tier on the old generation
    for _ in range(3):
        assert np.array_equal(store.pooled(ids, mask), p_old)
    assert store.stats.hot_rows > 0

    stop = threading.Event()
    violations = []

    def reader():
        while not stop.is_set():
            got = store.pooled(ids, mask)
            # atomic per lookup: entirely old or entirely new, never a mix
            if not (np.array_equal(got, p_old) or np.array_equal(got, p_new)):
                violations.append(got)

    th = threading.Thread(target=reader)
    th.start()
    try:
        # the data-plane rewrite, then the embedding-side reload it forces
        from repro.core.datagen import generate_partition

        table.rewrite_partition(
            0,
            generate_partition(
                table.schema, 0,
                DataGenConfig(rows_per_partition=ROWS_PER_PART, seed=99),
            ),
            dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256),
        )
        gen = store.load_tables(new_tables)
    finally:
        stop.set()
        th.join(timeout=60)
    assert not th.is_alive()
    assert not violations
    assert gen == store.generation == 1

    # post-reload: stale hot copies are refreshed, never served
    for _ in range(3):
        assert np.array_equal(store.pooled(ids, mask), p_new)
    assert store.stats.stale_refreshes > 0
