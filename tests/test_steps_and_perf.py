"""Coverage for the §Perf paths: step builders compile on a small mesh,
EP MoE matches the portable path, DLRM sparse update matches dense grads."""
import os
import subprocess
import sys

import numpy as np
import pytest

SUB = '''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
import dataclasses
from repro.launch.steps import make_train_step, make_decode_step
from repro import configs as cfglib

mesh = jax.make_mesh((2, 4), ("data", "model"))

# 1) train step compiles + runs for a smoke MoE config on the mesh
cfg = cfglib.get_smoke_config("deepseek-v2-236b")
cfg = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, num_experts=8))
b = make_train_step(cfg, mesh, batch=4, seq=32)
step = b.jit()
params_a, opt_a, batch_a = b.abstract_args
params = jax.tree.map(lambda s: 0.02*jnp.ones(s.shape, s.dtype), params_a)
opt = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), opt_a)
bt = {"tokens": jnp.ones((4,32), jnp.int32), "labels": jnp.ones((4,32), jnp.int32)}
p2, o2, m = step(params, opt, bt)
assert np.isfinite(float(m["loss"])), m
print("moe_train_ok", float(m["loss"]))

# 2) DLRM sparse train step on the mesh
dc = cfglib.get_smoke_config("dlrm-paper")
dc = dataclasses.replace(dc, vocab_per_table=1600)   # divisible by model=4
b2 = make_train_step(dc, mesh, batch=8, seq=0)
step2 = b2.jit()
pa, oa, ba = b2.abstract_args
params = jax.tree.map(lambda s: 0.05*jnp.ones(s.shape, s.dtype), pa)
opt = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), oa)
rng = np.random.default_rng(0)
bt = {
  "dense": jnp.asarray(rng.normal(0,1,(8, dc.num_dense)), jnp.float32),
  "sparse_ids": jnp.asarray(rng.integers(0, 1600, (8, dc.num_tables, dc.max_ids_per_feature)), jnp.int32),
  "sparse_mask": jnp.ones((8, dc.num_tables, dc.max_ids_per_feature), jnp.float32),
  "label": jnp.asarray(rng.integers(0,2,8), jnp.float32),
}
p2, o2, m = step2(params, opt, bt)
assert np.isfinite(float(m["loss"]))
# tables actually changed (sparse update applied)
delta = float(jnp.sum(jnp.abs(p2["tables"] - params["tables"])))
assert delta > 0
print("dlrm_sparse_ok", float(m["loss"]), delta)

# 3) decode step compiles on the mesh
cfg3 = cfglib.get_smoke_config("qwen3-8b")
b3 = make_decode_step(cfg3, mesh, batch=4, seq=16)
lowered = b3.lower()
lowered.compile()
print("decode_compile_ok")
'''


def test_steps_on_virtual_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("JAX_PLATFORMS", None)
    r = subprocess.run([sys.executable, "-c", SUB], capture_output=True,
                       text=True, env=env, timeout=900, cwd=os.path.dirname(os.path.dirname(__file__)))
    assert "moe_train_ok" in r.stdout, r.stdout + r.stderr
    assert "dlrm_sparse_ok" in r.stdout, r.stdout + r.stderr
    assert "decode_compile_ok" in r.stdout, r.stdout + r.stderr


def test_dlrm_sparse_update_matches_dense_gradient():
    """Row-wise sparse update direction == dense autodiff table gradient."""
    import jax
    import jax.numpy as jnp
    from repro import configs as cfglib
    from repro.models import build_model

    cfg = cfglib.get_smoke_config("dlrm-paper")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    bt = {
        "dense": jnp.asarray(rng.normal(0, 1, (8, cfg.num_dense)), jnp.float32),
        "sparse_ids": jnp.asarray(
            rng.integers(0, cfg.vocab_per_table, (8, cfg.num_tables, cfg.max_ids_per_feature)),
            jnp.int32),
        "sparse_mask": jnp.ones((8, cfg.num_tables, cfg.max_ids_per_feature), jnp.float32),
        "label": jnp.asarray(rng.integers(0, 2, 8), jnp.float32),
    }
    dense_grads = jax.grad(model.loss)(params, bt)["tables"]

    mlp = {"bottom": params["bottom"], "top": params["top"]}
    pooled = model.pooled_embeddings(params["tables"], bt)
    dpooled = jax.grad(model.loss_from_pooled, argnums=1)(mlp, pooled, bt)
    acc = jnp.zeros((cfg.num_tables, cfg.vocab_per_table), jnp.float32)
    new_tables, _ = model.sparse_table_update(
        params["tables"], acc, dpooled, bt, lr=jnp.asarray(1.0)
    )
    sparse_delta = np.asarray(new_tables - params["tables"], np.float64)
    dg = np.asarray(dense_grads, np.float64)
    # updates happen exactly where dense grads are nonzero, opposite sign
    touched = np.abs(dg) > 1e-12
    assert (np.abs(sparse_delta[~touched]) < 1e-9).all()
    dot = np.sum(sparse_delta * dg)
    assert dot < 0  # descent direction
