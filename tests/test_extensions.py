"""Tests for the beyond-core layers: LM token path, continuous-batching
server, §7.3 scheduler, §7.5 tensor cache."""
import numpy as np
import pytest

from repro.core.warehouse import Warehouse


# -- LM token path -----------------------------------------------------------

def test_token_packing_roundtrip():
    from repro.core import tokens as T

    wh = Warehouse()
    table = T.build_corpus(wh, n_partitions=2, docs_per_partition=64,
                           vocab_size=512, seed=0)
    batches = list(T.lm_batches_from_table(table, seq_len=64, batch_size=8))
    assert len(batches) > 4
    for b in batches:
        assert b["tokens"].shape == (8, 64)
        assert b["labels"].shape == (8, 64)
        # labels are next-token shifted
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
        assert (b["tokens"] >= 0).all() and (b["tokens"] < 512).all()


def test_pack_sequences_preserves_tokens():
    from repro.core.schema import SparseColumn
    from repro.core.tokens import EOS, PackState, pack_sequences

    lists = [[5, 6, 7], [8, 9], [10, 11, 12, 13]]
    off = np.zeros(4, np.int64)
    np.cumsum([len(l) for l in lists], out=off[1:])
    col = SparseColumn(offsets=off, values=np.concatenate(lists).astype(np.int64))
    packed, state = pack_sequences(col, seq_len=3)
    stream = np.concatenate([packed.reshape(-1), state.leftover])
    expect = [5, 6, 7, EOS, 8, 9, EOS, 10, 11, 12, 13, EOS]
    np.testing.assert_array_equal(stream, expect)


def test_lm_trains_through_dsi_pipeline():
    from repro.core import tokens as T
    from repro import configs as cfglib
    from repro.optim import OptimizerConfig
    from repro.train import Trainer, TrainerConfig

    cfg = cfglib.get_smoke_config("qwen3-8b")
    wh = Warehouse()
    table = T.build_corpus(wh, 2, 48, cfg.vocab_size, seed=1)
    batches = T.lm_batches_from_table(table, seq_len=64, batch_size=4)
    tr = Trainer(cfg, OptimizerConfig(learning_rate=3e-3, warmup_steps=2, total_steps=10),
                 TrainerConfig(max_steps=10))
    tr.fit(batches)
    losses = [m.loss for m in tr.history]
    assert len(losses) >= 5 and losses[-1] < losses[0]


# -- continuous batching server ------------------------------------------------

def test_batching_server_serves_requests():
    from repro import configs as cfglib
    from repro.serving import BatchingServer, Request, ServerConfig

    cfg = cfglib.get_smoke_config("qwen3-8b")
    srv = BatchingServer(cfg, ServerConfig(slots=2, cache_len=64))
    rng = np.random.default_rng(0)
    reqs = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab_size, 8 + 4 * i).astype(np.int32),
                max_new_tokens=6)
        for i in range(4)
    ]
    for r in reqs:
        srv.submit(r)
    done = srv.run(max_ticks=400)
    assert len(done) == 4
    for r in done:
        assert len(r.output) == 6
        assert all(0 <= t < cfg.vocab_size for t in r.output)
    rep = BatchingServer.latency_report(done)
    assert rep["decode_tok_per_s"] > 0


def test_server_matches_offline_decode():
    """Server greedy decode == direct prefill+argmax for a single request."""
    import jax, jax.numpy as jnp
    from repro import configs as cfglib
    from repro.models import build_model
    from repro.serving import BatchingServer, Request, ServerConfig

    cfg = cfglib.get_smoke_config("mamba2-2.7b")
    srv = BatchingServer(cfg, ServerConfig(slots=1, cache_len=64))
    prompt = np.arange(1, 9, dtype=np.int32)
    srv.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
    done = srv.run()
    model, params = srv.model, srv.params
    logits, _ = model.prefill(params, {"tokens": jnp.asarray(prompt[None, :])})
    expect = int(jnp.argmax(logits[0, -1]))
    assert done[0].output[0] == expect


# -- §7.3 scheduler -------------------------------------------------------------

def test_scheduler_saves_storage_meets_peak():
    from repro.core.coordination import ReleaseProcessConfig, simulate
    from repro.core.scheduler import (
        Region, demands_from_release_sim, greedy_colocate,
        replicate_everywhere, replication_report,
    )

    jobs = simulate(ReleaseProcessConfig(days=60, seed=3))
    demands = demands_from_release_sim(jobs, {})
    total_peak = sum(d.peak_compute for d in demands)
    regions = [Region(f"R{i}", capacity=total_peak, storage_pb=1e3) for i in range(5)]
    base = replicate_everywhere(demands, regions)
    packed = greedy_colocate(demands, regions)
    rep = replication_report(demands, base, packed)
    assert rep["storage_saved_frac"] > 0.3          # §7.3 bin-packing win
    for d in demands:
        assert packed.replicas(d.name) >= 2         # availability floor
    # capacity respected
    for r in regions:
        assert packed.region_peak[r.name] <= r.capacity + 1e-6


# -- §7.5 tensor cache -----------------------------------------------------------

def test_tensor_cache_hits_across_jobs():
    from repro.core import dwrf
    from repro.core.datagen import DataGenConfig
    from repro.core.dpp import DPPSession, SessionSpec
    from repro.core.dpp.tensor_cache import TensorCache
    from repro.core.schema import make_schema
    from repro.core.transforms import default_dlrm_pipeline

    schema = make_schema("tc", 16, 4, seed=0)
    wh = Warehouse()
    t = wh.create_table(schema)
    t.generate(1, DataGenConfig(rows_per_partition=512, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=128))
    dense, sparse = schema.dense_ids[:4], schema.sparse_ids[:2]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=100)
    spec = SessionSpec(
        table="tc", partitions=(0,), feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs), batch_size=128, rows_per_split=128,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse), max_ids_per_feature=8,
    )
    cache = TensorCache(capacity_bytes=64 * 1024 * 1024)
    out1 = DPPSession(spec, t, n_workers=1, tensor_cache=cache).run_to_completion(timeout_s=30)
    assert cache.stats.misses == 4 and cache.stats.hits == 0
    # second job, same projection + pipeline (the §5.2 reuse pattern)
    out2 = DPPSession(spec, t, n_workers=1, tensor_cache=cache).run_to_completion(timeout_s=30)
    assert cache.stats.hits == 4
    assert cache.stats.cpu_s_saved > 0
    assert len(out2) == len(out1)
    np.testing.assert_array_equal(out1[0]["dense"], out2[0]["dense"])


def test_tensor_cache_distinguishes_pipelines():
    from repro.core.dpp.master import SessionSpec
    from repro.core.dpp.tensor_cache import pipeline_fingerprint
    from repro.core.transforms import default_dlrm_pipeline

    p1 = default_dlrm_pipeline([0], [10], hash_size=100)
    p2 = default_dlrm_pipeline([0], [10], hash_size=200)
    mk = lambda p: SessionSpec(
        table="x", partitions=(0,), feature_ids=(0, 10),
        transform_specs=tuple(p.specs), dense_keys=("d0",), sparse_keys=("s10",),
    )
    assert pipeline_fingerprint(mk(p1)) != pipeline_fingerprint(mk(p2))


def test_tensor_cache_eviction():
    from repro.core.dpp.tensor_cache import TensorCache

    c = TensorCache(capacity_bytes=1000)
    big = [{"x": np.zeros(200, np.float32)}]        # 800 B
    c.put(("a",), big, 0.1)
    c.put(("b",), big, 0.1)                          # evicts a
    assert c.stats.evictions == 1
    assert c.get(("a",)) is None
    assert c.get(("b",)) is not None
