"""Observability layer for the DSI pipeline (ISSUE 7).

Three stdlib-only pieces, threaded through every DSI stage:

  * :mod:`repro.obs.trace` — thread-safe span tracing with clock
    injection and a Chrome-trace/Perfetto exporter.  Disabled by default
    (``NULL_TRACER``), zero-cost when off.
  * :mod:`repro.obs.meta` — per-field counter/gauge metadata for the
    metric dataclasses; one source of truth shared by ``merge`` methods,
    the registry, and the REPRO-M002 monotonicity rule.
  * :mod:`repro.obs.registry` — a ``MetricsRegistry`` unifying the metric
    dataclasses behind one snapshot/delta API; the ``ElasticController``
    observations are rebuilt on these deltas.

``python -m repro.obs.report`` turns a trace + registry snapshot into the
paper's Table-7/Table-9 stall-attribution breakdown;
``python -m repro.obs.smoke`` produces a traced two-tenant artifact for
CI (see docs/observability.md).
"""
from repro.obs.meta import counter, gauge, merge_metrics, metric_fields
from repro.obs.registry import MetricsRegistry, Snapshot
from repro.obs.trace import NULL_TRACER, NullTracer, Tracer

__all__ = [
    "counter", "gauge", "merge_metrics", "metric_fields",
    "MetricsRegistry", "Snapshot",
    "Tracer", "NullTracer", "NULL_TRACER",
]
