"""Offline data generation: the ETL path of Fig. 3, synthesized.

Serving-time feature/event logs -> streaming join + label -> partitioned
training tables.  We synthesize statistically-calibrated samples: per-feature
coverage, Zipf-distributed categorical ids, log-normal list lengths, and a
label rate typical of CTR tasks.  The generator is deterministic per
(seed, partition) so tests and benchmarks are reproducible.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core.schema import (
    ColumnBatch,
    FeatureType,
    SparseColumn,
    TableSchema,
)


@dataclasses.dataclass(frozen=True)
class DataGenConfig:
    rows_per_partition: int = 4096
    label_rate: float = 0.03            # positive-event rate
    zipf_a: float = 1.3                 # categorical id skew
    seed: int = 0
    labeled: bool = True                # False: label stream not yet joined


def generate_partition(
    schema: TableSchema, partition_index: int, cfg: DataGenConfig
) -> ColumnBatch:
    """Generate one (e.g. hourly) partition of labeled samples."""
    rng = np.random.default_rng((cfg.seed, partition_index))
    n = cfg.rows_per_partition
    dense: Dict[int, np.ndarray] = {}
    sparse: Dict[int, SparseColumn] = {}

    for f in schema.features.values():
        if not f.logged:
            continue
        present = rng.random(n) < f.coverage
        if f.ftype == FeatureType.DENSE:
            col = rng.normal(0.0, 1.0, n).astype(np.float32)
            col[~present] = np.nan
            dense[f.fid] = col
        else:
            lengths = np.where(
                present,
                np.clip(rng.poisson(f.avg_length, n), 1, 4 * int(f.avg_length) + 4),
                0,
            ).astype(np.int64)
            offsets = np.zeros(n + 1, np.int64)
            np.cumsum(lengths, out=offsets[1:])
            nnz = int(offsets[-1])
            # Zipf ids bounded by the feature's cardinality
            vals = rng.zipf(cfg.zipf_a, nnz).astype(np.int64) % f.cardinality
            scores = (
                rng.random(nnz).astype(np.float32)
                if f.ftype == FeatureType.SPARSE_SCORED
                else None
            )
            sparse[f.fid] = SparseColumn(offsets=offsets, values=vals, scores=scores)

    labels = (
        (rng.random(n) < cfg.label_rate).astype(np.float32)
        if cfg.labeled else None
    )
    return ColumnBatch(num_rows=n, dense=dense, sparse=sparse, labels=labels)


def stream_partitions(
    schema: TableSchema, n_partitions: int, cfg: DataGenConfig
) -> Iterator[ColumnBatch]:
    for p in range(n_partitions):
        yield generate_partition(schema, p, cfg)
