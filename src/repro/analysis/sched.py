"""Deterministic interleaving explorer ("sched") for the DPP control plane.

``lockdep`` proves lock *orderings* are consistent and ``racedep``
proves shared attributes *have* a lockset — but a controller can hold
every lock correctly and still be wrong under an unlucky schedule:
check-then-act windows (read under the lock, act after dropping it),
lost updates, a lease expiring between a worker's delivery and its
completion report.  Those are *atomicity* bugs; finding them requires
actually running the interesting interleavings, deterministically.

Mechanism: a cooperative scheduler serializes a small set of scenario
threads at **sync points** — lock acquire/release (``threading.Lock`` /
``RLock`` constructed by repo modules are swapped for cooperative
:class:`SchedLock`\\ s, reusing lockdep's construction-site naming),
``queue.Queue.put``/``get``, and explicit :func:`yield_point` markers.
Between sync points exactly one thread runs; at each point the
scheduler picks which thread proceeds.  Exhaustively enumerating those
picks (depth-first over the decision tree, replaying a forced prefix
each run against a fresh ``scenario.setup()``) visits every bounded
interleaving, and ``scenario.check`` asserts the subsystem invariant
after each one.  A schedule in which no runnable thread exists is a
real deadlock and is reported with the full decision trace.

DPOR-lite: schedules that only reorder *commuting* operations (ops on
different locks/queues/yield tags) are pruned with Godefroid-style
**sleep sets** — after exploring thread ``t`` first at a decision
point, sibling branches keep ``t`` asleep until some executed op
conflicts with ``t``'s pending op; a branch that completes with a
thread still uselessly asleep is a Mazurkiewicz-equivalent replay of an
explored schedule and is abandoned (counted in ``Exploration.pruned``).
The reduction is sound: every equivalence class of schedules is still
visited.

Writing scenarios (see the subclasses below and
``docs/static_analysis.md``):

  * ``setup()`` builds fresh subsystem state — runs uncontrolled on the
    main thread, once per schedule;
  * ``threads(state)`` returns 2–3 argless callables, each a few sync
    points long (schedules grow exponentially in sync-point count);
  * plain attribute reads/writes are invisible to the scheduler — mark
    a racy window explicitly with ``yield_point("tag")``; ops sharing a
    tag are treated as conflicting, ops on distinct tags commute;
  * ``check(state)`` asserts the invariant; an ``AssertionError``
    (there, or in a thread body) surfaces as :class:`ScheduleError`
    carrying the exact schedule that broke it;
  * avoid blocking waits the scheduler cannot see (``Event.wait``,
    timeout-ful queue gets) — the driver aborts a run whose thread
    stays off a sync point for 10s.

CLI gate (wired into ``scripts/ci.sh``)::

    PYTHONPATH=src python -m repro.analysis.sched            # all scenarios
    PYTHONPATH=src python -m repro.analysis.sched --list
    PYTHONPATH=src python -m repro.analysis.sched -k lease
"""
from __future__ import annotations

import _thread
import argparse
import dataclasses
import queue as queue_mod
import sys
import threading
import traceback
from contextlib import contextmanager
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

Op = Tuple[str, str]   # (kind, resource-name)

# Construction sites whose locks become cooperative SchedLocks (the same
# file set conftest's lockdep fixture tracks): repo modules only —
# threading/queue internals must stay real locks.
_REPO_LOCK_FILES = (
    "stripe_cache.py", "tectonic.py", "master.py", "worker.py",
    "service.py", "client.py", "prefetch.py", "tensor_cache.py",
    "dedup.py", "warehouse.py", "autoscale.py", "engine.py", "trainer.py",
)

_STDLIB_LOCK_FILES = ("threading.py", "queue.py")


class ScheduleError(AssertionError):
    """A schedule deadlocked, broke an invariant, or wedged the driver."""


class _AbortRun(BaseException):
    """Unwinds controlled threads when a schedule is abandoned."""


class _Gate:
    """One-shot handoff on a raw ``_thread`` lock (immune to patching)."""

    def __init__(self) -> None:
        self._lk = _thread.allocate_lock()
        self._lk.acquire()

    def wait(self, timeout: Optional[float] = None) -> bool:
        if timeout is None:
            self._lk.acquire()
            return True
        return self._lk.acquire(timeout=timeout)

    def set(self) -> None:
        try:
            self._lk.release()
        except RuntimeError:
            pass   # already open (benign during abort teardown)


@dataclasses.dataclass
class _CThread:
    name: str
    gate: _Gate
    pending: Optional[Op] = None
    finished: bool = False
    error: Optional[BaseException] = None
    tb: str = ""
    thread: Optional[threading.Thread] = None


class SchedLock:
    """Cooperative stand-in for ``threading.Lock``/``RLock``.

    Needs no real mutual exclusion: controlled threads run one at a
    time between sync points, and uncontrolled phases (setup / check on
    the main thread) are single-threaded by construction.  ``acquire``
    from a controlled thread syncs first — the scheduler only schedules
    it once the lock is free — so the actual take never contends.
    """

    _MAIN = object()   # owner sentinel for uncontrolled (setup) phases

    def __init__(self, sched: "Scheduler", name: str, reentrant: bool):
        self._sched = sched
        self.name = name
        self.reentrant = reentrant
        self.owner: Optional[object] = None
        self.count = 0

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        me = self._sched._current() or SchedLock._MAIN
        if self.reentrant and self.owner is me:
            self.count += 1
            return True
        if not blocking and self.owner is not None:
            return False
        if me is SchedLock._MAIN:
            if self.owner is not None:
                raise ScheduleError(
                    f"sched: lock {self.name} acquired from outside the "
                    "scheduler while a controlled thread holds it"
                )
        else:
            self._sched.sync(("acquire", self.name))
        self.owner = me
        self.count = 1
        return True

    def release(self) -> None:
        if self.count <= 0:
            raise RuntimeError(f"release of unheld SchedLock {self.name}")
        self.count -= 1
        if self.count == 0:
            self.owner = None
            if self._sched._current() is not None:
                self._sched.sync(("release", self.name))

    def locked(self) -> bool:
        return self.owner is not None

    def __enter__(self) -> "SchedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()


@dataclasses.dataclass
class _Node:
    """One decision point on the DFS trail."""

    enabled: Tuple[str, ...]             # thread names enabled here
    sleep_entry: frozenset               # sleep set on entry to the node
    explored: List[str]                  # choices already fully explored
    choice: str                          # choice for the current branch


def _resource(op: Optional[Op]) -> Optional[Tuple[str, str]]:
    if op is None:
        return None
    kind, name = op
    if kind in ("acquire", "release"):
        return ("lock", name)
    if kind in ("queue.put", "queue.get"):
        return ("queue", name)
    if kind == "yield":
        return ("yield", name)
    return None                           # "start": touches nothing shared


def _independent(a: Optional[Op], b: Optional[Op]) -> bool:
    ra, rb = _resource(a), _resource(b)
    return ra is None or rb is None or ra != rb


class Scheduler:
    """Drives controlled threads one sync-point step at a time."""

    def __init__(self) -> None:
        self._threads: List[_CThread] = []
        self._control = _Gate()
        self._tls = threading.local()
        self._locks: Dict[str, SchedLock] = {}
        self._lockseq = 0
        self._queues: Dict[str, "queue_mod.Queue"] = {}
        self._queue_names: Dict[int, str] = {}
        self._aborting = False
        self.trace: List[Tuple[str, Op]] = []

    # -- controlled-thread side ----------------------------------------------

    def _current(self) -> Optional[_CThread]:
        return getattr(self._tls, "me", None)

    def sync(self, op: Op) -> None:
        me = self._current()
        me.pending = op
        self._control.set()
        me.gate.wait()
        me.pending = None
        if self._aborting:
            raise _AbortRun()

    def spawn(self, name: str, fn: Callable[[], None]) -> None:
        ct = _CThread(name=name, gate=_Gate())

        def wrapper() -> None:
            self._tls.me = ct
            ct.gate.wait()
            try:
                if not self._aborting:
                    fn()
            except _AbortRun:
                pass
            except BaseException as e:   # surfaced by the driver
                ct.error = e
                ct.tb = traceback.format_exc()
            finally:
                ct.finished = True
                self._control.set()

        ct.pending = ("start", name)
        ct.thread = threading.Thread(target=wrapper, name=name, daemon=True)
        self._threads.append(ct)
        ct.thread.start()

    # -- lock / queue registration -------------------------------------------

    def make_lock(self, site: str, reentrant: bool) -> SchedLock:
        self._lockseq += 1
        lk = SchedLock(self, f"{site}#{self._lockseq}", reentrant)
        self._locks[lk.name] = lk
        return lk

    def queue_id(self, q: "queue_mod.Queue") -> str:
        name = self._queue_names.get(id(q))
        if name is None:
            name = f"queue#{len(self._queue_names)}"
            self._queue_names[id(q)] = name
            self._queues[name] = q
        return name

    # -- driver side ----------------------------------------------------------

    def _enabled(self, op: Optional[Op]) -> bool:
        if op is None:
            return False
        kind, name = op
        if kind == "acquire":
            return self._locks[name].owner is None
        if kind == "queue.put":
            q = self._queues[name]
            return q.maxsize <= 0 or q.qsize() < q.maxsize
        if kind == "queue.get":
            return self._queues[name].qsize() > 0
        return True

    def _by_name(self, name: str) -> Optional[_CThread]:
        for t in self._threads:
            if t.name == name:
                return t
        return None

    def _fmt_trace(self) -> str:
        return "\n".join(f"    {i:3d}. {name}: {op[0]}({op[1]})"
                         for i, (name, op) in enumerate(self.trace))

    def drive(self, trail: List[_Node], max_steps: int) -> str:
        """Run one schedule; extends ``trail`` past the forced prefix.
        Returns ``"completed"`` or ``"redundant"`` (sleep-set pruned)."""
        d = 0
        sleep: Set[str] = set()
        while True:
            live = [t for t in self._threads if not t.finished]
            if not live:
                return "completed"
            enabled = [t for t in live if self._enabled(t.pending)]
            if not enabled:
                waits = "; ".join(
                    f"{t.name} blocked at {t.pending[0]}({t.pending[1]})"
                    for t in live)
                raise ScheduleError(
                    "sched: DEADLOCK — no runnable thread: "
                    f"{waits}\n  schedule so far:\n{self._fmt_trace()}")
            if d >= max_steps:
                raise ScheduleError(
                    f"sched: schedule exceeded max_steps={max_steps} "
                    f"(livelock?)\n{self._fmt_trace()}")
            if d < len(trail):               # replay the forced prefix
                node = trail[d]
                sleep = set(node.sleep_entry) | set(node.explored)
                t = self._by_name(node.choice)
                if t is None or t not in enabled:
                    raise ScheduleError(
                        "sched: nondeterministic replay — thread "
                        f"{node.choice} not enabled at step {d}; scenario "
                        "setup/threads must be deterministic")
            else:
                candidates = [t for t in enabled if t.name not in sleep]
                if not candidates:
                    return "redundant"       # equivalent schedule explored
                t = candidates[0]
                trail.append(_Node(
                    enabled=tuple(x.name for x in enabled),
                    sleep_entry=frozenset(sleep),
                    explored=[], choice=t.name,
                ))
            op = t.pending
            self.trace.append((t.name, op))
            # a sleeping thread wakes when a conflicting op executes
            sleep = {s for s in sleep
                     if not self._woken_by(s, op)}
            t.gate.set()
            if not self._control.wait(timeout=10.0):
                raise ScheduleError(
                    f"sched: thread {t.name} did not reach a sync point "
                    "within 10s — blocking wait the scheduler cannot see? "
                    f"(Event.wait, timeout queue get)\n{self._fmt_trace()}")
            d += 1

    def _woken_by(self, sleeper: str, op: Op) -> bool:
        t = self._by_name(sleeper)
        if t is None or t.finished:
            return True
        return not _independent(t.pending, op)

    def abort_run(self) -> None:
        """Unwind remaining threads of an abandoned schedule."""
        self._aborting = True
        for _ in range(1000):
            live = [t for t in self._threads if not t.finished]
            if not live:
                return
            for t in live:
                t.gate.set()
            self._control.wait(timeout=0.5)


_ACTIVE: Optional[Scheduler] = None


def yield_point(tag: str = "yield") -> None:
    """Explicit sync point marking a shared access the scheduler cannot
    otherwise see.  No-op outside a controlled run or on the main thread,
    so production code *could* carry permanent yield points for free."""
    s = _ACTIVE
    if s is not None and s._current() is not None:
        s.sync(("yield", tag))


def _default_name_filter(site: str) -> bool:
    return site.startswith(_REPO_LOCK_FILES)


@contextmanager
def controlled(name_filter: Optional[Callable[[str], bool]] = None):
    """Patch ``threading.Lock``/``RLock`` and ``queue.Queue.put``/``get``
    so repo-constructed locks and all queue traffic from controlled
    threads become scheduler sync points.  Yields the :class:`Scheduler`."""
    global _ACTIVE
    if _ACTIVE is not None:
        raise RuntimeError("sched.controlled() does not nest")
    sched = Scheduler()
    flt = name_filter or _default_name_filter
    real_lock, real_rlock = threading.Lock, threading.RLock
    orig_put, orig_get = queue_mod.Queue.put, queue_mod.Queue.get

    def _factory(reentrant: bool, real):
        def make():
            f = sys._getframe(1)
            fname = Path(f.f_code.co_filename).name
            site = f"{fname}:{f.f_lineno}"
            # threading/queue internals (Event, Condition, Queue.mutex)
            # must stay real whatever the filter says: they synchronize
            # thread bootstrap, which runs outside scheduler control
            if fname in _STDLIB_LOCK_FILES or not flt(site):
                return real()
            return sched.make_lock(site, reentrant)
        return make

    def put(self, item, block=True, timeout=None):
        s = _ACTIVE
        if s is not None and s._current() is not None:
            s.sync(("queue.put", s.queue_id(self)))
            return orig_put(self, item, block=False)
        return orig_put(self, item, block, timeout)

    def get(self, block=True, timeout=None):
        s = _ACTIVE
        if s is not None and s._current() is not None:
            s.sync(("queue.get", s.queue_id(self)))
            return orig_get(self, block=False)
        return orig_get(self, block, timeout)

    threading.Lock = _factory(False, real_lock)     # type: ignore[misc]
    threading.RLock = _factory(True, real_rlock)    # type: ignore[misc]
    queue_mod.Queue.put = put                       # type: ignore[assignment]
    queue_mod.Queue.get = get                       # type: ignore[assignment]
    _ACTIVE = sched
    try:
        yield sched
    finally:
        _ACTIVE = None
        threading.Lock = real_lock                  # type: ignore[misc]
        threading.RLock = real_rlock                # type: ignore[misc]
        queue_mod.Queue.put = orig_put              # type: ignore[assignment]
        queue_mod.Queue.get = orig_get              # type: ignore[assignment]


# -- exploration --------------------------------------------------------------


class Scenario:
    """A bounded interleaving scenario: fresh state, 2–3 short threads,
    one invariant checked after every schedule."""

    name = "unnamed scenario"

    def setup(self):
        raise NotImplementedError

    def threads(self, state) -> Sequence[Callable[[], None]]:
        raise NotImplementedError

    def check(self, state) -> None:
        pass


@dataclasses.dataclass
class Exploration:
    scenario: str
    schedules: int        # distinct (non-equivalent) schedules checked
    pruned: int           # sleep-set-abandoned redundant branches
    exhausted: bool       # False iff max_schedules stopped us early


def _run_once(scenario: Scenario, trail: List[_Node], max_steps: int,
              name_filter) -> str:
    with controlled(name_filter) as sched:
        state = scenario.setup()
        fns = scenario.threads(state)
        for i, fn in enumerate(fns):
            sched.spawn(f"T{i}", fn)
        try:
            status = sched.drive(trail, max_steps)
        except ScheduleError:
            sched.abort_run()
            raise
        if status == "redundant":
            sched.abort_run()
            return status
        bad = next((t for t in sched._threads if t.error is not None), None)
        if bad is not None:
            raise ScheduleError(
                f"sched: thread {bad.name} raised in scenario "
                f"'{scenario.name}' under schedule:\n{sched._fmt_trace()}\n"
                f"{bad.tb}")
        try:
            scenario.check(state)
        except AssertionError as e:
            raise ScheduleError(
                f"sched: invariant broken in scenario '{scenario.name}' "
                f"under schedule:\n{sched._fmt_trace()}\n  {e}") from e
    return "completed"


def explore(scenario: Scenario, max_schedules: int = 2000,
            max_steps: int = 500, name_filter=None) -> Exploration:
    """Exhaustively explore ``scenario``'s bounded interleavings, checking
    the invariant after each.  Raises :class:`ScheduleError` on the first
    schedule that deadlocks or breaks the invariant."""
    trail: List[_Node] = []
    schedules = pruned = 0
    while True:
        status = _run_once(scenario, trail, max_steps, name_filter)
        if status == "completed":
            schedules += 1
        else:
            pruned += 1
        if schedules + pruned >= max_schedules:
            return Exploration(scenario.name, schedules, pruned,
                               exhausted=False)
        while trail:   # backtrack to the deepest node with untried options
            node = trail[-1]
            node.explored.append(node.choice)
            nxt = [n for n in node.enabled
                   if n not in node.sleep_entry and n not in node.explored]
            if nxt:
                node.choice = nxt[0]
                break
            trail.pop()
        if not trail:
            return Exploration(scenario.name, schedules, pruned,
                               exhausted=True)


# -- the gate's scenario set --------------------------------------------------
#
# Each targets one coordination seam the control plane depends on; all
# must hold under EVERY bounded interleaving. Keep thread bodies short:
# schedules grow exponentially with sync-point count.


class CompleteVsLeaseExpiry(Scenario):
    """``complete_split`` racing a lease-expiry reclaim + redispatch.

    The split was leased to w1 and the lease has expired.  w1's (late)
    ``ok`` report races w2's ``get_split`` which reclaims the lease and
    may redispatch.  Whatever the order: the session must end COMPLETED
    with the split done exactly once and nothing quarantined."""

    name = "master: complete_split vs lease-expiry redispatch"

    def setup(self):
        from repro.core.dpp.master import DPPMaster, SessionSpec

        now = [100.0]
        spec = SessionSpec(table="t", partitions=(0,), feature_ids=(0,),
                           transform_specs=(), rows_per_split=64)
        m = DPPMaster(spec, {0: 64}, lease_s=1.0, clock=lambda: now[0])
        s = m.get_split("w1")
        assert s is not None and s.split_id == 0
        now[0] += 10.0                      # w1's lease is now expired
        return m

    def threads(self, m):
        def late_finisher():
            m.complete_split("w1", 0)

        def redispatcher():
            s = m.get_split("w2")            # reclaims the expired lease
            if s is not None:
                yield_point("w2-processing")
                m.complete_split("w2", s.split_id)

        return [late_finisher, redispatcher]

    def check(self, m):
        assert m.finished, f"split lost: state={m.state} progress={m.progress}"
        assert m.state == "COMPLETED", m.state
        assert not m.quarantined, m.quarantined
        done, total = m.progress
        assert (done, total) == (1, 1), (done, total)


class AdmitVsInvalidate(Scenario):
    """``StripeCache.admit`` of a pre-rewrite read racing
    ``invalidate_path`` for the rewrite.

    A reader resolved a path-addressed key, went to storage, and admits
    the (stale) bytes while the rewriter invalidates the path.  Whatever
    the order: post-rewrite resolution must yield a new-generation key
    that can never hit the stale entry."""

    name = "stripe-cache: admit vs invalidate_path after rewrite"

    def setup(self):
        from repro.core.cache.stripe_cache import StripeCache

        cache = StripeCache(dram_capacity_bytes=1 << 20)
        state = {
            "cache": cache,
            "old_key": cache.resolve("/part0", 0, 64),
            "payload": b"s" * 64,
        }
        return state

    def threads(self, state):
        cache = state["cache"]

        def stale_admitter():
            cache.admit(state["old_key"], state["payload"], tenant="a")

        def rewriter():
            cache.invalidate_path("/part0")

        return [stale_admitter, rewriter]

    def check(self, state):
        cache = state["cache"]
        new_key = cache.resolve("/part0", 0, 64)
        assert new_key != state["old_key"], "generation did not advance"
        assert not cache.peek(new_key), "post-rewrite key hits stale bytes"


class TensorCachePutVsGenerationBump(Scenario):
    """``TensorCache`` put of generation-0 tensors racing a reader that
    switches to the generation-1 key mid-flight (partition rewrite).

    Generation is part of the key, so the post-bump reader must miss in
    every schedule — a hit would serve pre-rewrite tensors."""

    name = "tensor-cache: put/get vs generation bump"

    def setup(self):
        import numpy as np

        from repro.core.dpp.master import SessionSpec, Split
        from repro.core.dpp.tensor_cache import TensorCache

        tc = TensorCache(capacity_bytes=1 << 20)
        spec = SessionSpec(table="t", partitions=(0,), feature_ids=(0,),
                           transform_specs=(), rows_per_split=64)
        split = Split(split_id=0, partition=0, row_start=0, row_end=64)
        state = {
            "tc": tc,
            "k0": TensorCache.key(spec, split, generation=0),
            "k1": TensorCache.key(spec, split, generation=1),
            "batches": [{"d": np.zeros(4, dtype=np.float32)}],
            "gen1_hit": "unset",
        }
        return state

    def threads(self, state):
        tc = state["tc"]

        def writer():
            tc.put(state["k0"], state["batches"], cpu_s=0.01)

        def bumped_reader():
            tc.get(state["k0"])
            yield_point("generation-bump")   # rewrite lands here
            state["gen1_hit"] = tc.get(state["k1"])

        return [writer, bumped_reader]

    def check(self, state):
        assert state["gen1_hit"] is None, (
            "generation-1 key served generation-0 tensors")


class ScaleDownVsDelivery(Scenario):
    """Elastic scale-down racing a worker's in-flight delivery.

    The worker has one split leased and is about to deliver its batch
    and report ``ok`` when the monitor retires it (``drain()``).  In
    every schedule the delivered batch must stay in the buffer and the
    split must be reported — graceful scale-down loses nothing."""

    name = "elastic: scale-down vs in-flight delivery"

    def setup(self):
        import numpy as np

        from repro.core.dpp.master import DPPMaster, SessionSpec
        from repro.core.dpp.worker import DPPWorker

        spec = SessionSpec(table="t", partitions=(0,), feature_ids=(0,),
                           transform_specs=(), rows_per_split=64)
        m = DPPMaster(spec, {0: 64})
        w = DPPWorker("w0", m, table=None)   # never started: threads below
        s = m.get_split("w0")                # play its delivery path
        assert s is not None
        state = {"m": m, "w": w,
                 "batch": {"d": np.zeros(2, dtype=np.float32)}}
        return state

    def threads(self, state):
        m, w = state["m"], state["w"]

        def delivery():
            w.buffer.put(state["batch"])
            yield_point("scale-down")    # retire window mid-delivery
            m.complete_split("w0", 0)

        def monitor():
            yield_point("scale-down")
            w.retired = True
            w.drain()

        return [delivery, monitor]

    def check(self, state):
        m, w = state["m"], state["w"]
        assert m.finished, "delivered split never reported done"
        assert w.buffered == 1, "scale-down dropped a delivered batch"
        assert w.retired and w._drain.is_set()


SCENARIOS: Tuple[Scenario, ...] = (
    CompleteVsLeaseExpiry(),
    AdmitVsInvalidate(),
    TensorCachePutVsGenerationBump(),
    ScaleDownVsDelivery(),
)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.sched",
        description="Deterministic interleaving explorer: run every "
                    "control-plane scenario under all bounded schedules.")
    ap.add_argument("-k", metavar="SUBSTR", default=None,
                    help="only scenarios whose name contains SUBSTR")
    ap.add_argument("--list", action="store_true", help="list scenarios")
    ap.add_argument("--max-schedules", type=int, default=2000)
    ap.add_argument("-q", "--quiet", action="store_true")
    args = ap.parse_args(argv)

    picked = [s for s in SCENARIOS
              if args.k is None or args.k.lower() in s.name.lower()]
    if args.list:
        for s in picked:
            print(s.name)
        return 0
    if not picked:
        print(f"sched: no scenario matches {args.k!r}", file=sys.stderr)
        return 2

    total = pruned = 0
    for s in picked:
        try:
            res = explore(s, max_schedules=args.max_schedules)
        except ScheduleError as e:
            print(f"sched: FAIL — {s.name}\n{e}", file=sys.stderr)
            return 1
        total += res.schedules
        pruned += res.pruned
        if not args.quiet:
            tail = "" if res.exhausted else "  (TRUNCATED by --max-schedules)"
            print(f"sched: ok — {s.name}: {res.schedules} schedule(s), "
                  f"{res.pruned} pruned{tail}")
    if not args.quiet:
        print(f"sched: ok — {len(picked)} scenario(s), {total} schedules "
              f"explored, {pruned} pruned as equivalent")
    return 0


if __name__ == "__main__":
    sys.exit(main())
