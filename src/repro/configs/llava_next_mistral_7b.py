"""llava-next-mistral-7b — VLM, mistral backbone + anyres patch stub
[hf:llava-hf/llava-v1.6-mistral-7b-hf]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    sharding_profile="fsdp",
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    frontend="vision",
    num_patches=2880,      # anyres: (4 tiles + base) x 576 patches
)

SMOKE = dataclasses.replace(
    CONFIG, name="llava-smoke", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512,
    num_patches=16, remat=False,
)
