"""codeqwen1.5-7b — dense MHA w/ QKV bias [hf:Qwen/CodeQwen1.5-7B]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    sharding_profile="fsdp",
    name="codeqwen1.5-7b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    head_dim=128,
    d_ff=13440,
    vocab_size=92416,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="codeqwen-smoke", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=4, head_dim=32, d_ff=256, vocab_size=512, remat=False,
)
