"""Logical-axis sharding: map named tensor axes onto physical mesh axes.

The framework annotates every parameter / activation with *logical* axis
names ("embed", "heads", "mlp", "expert", ...).  A rule table maps logical
names to mesh axes ("pod", "data", "model").  This is the MaxText-style
indirection that lets one model definition serve DP / FSDP / TP / EP / SP
layouts on both the single-pod (16, 16) and multi-pod (2, 16, 16) meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxis = Union[str, Sequence[str], None]


@dataclasses.dataclass(frozen=True)
class AxisRules:
    """Mapping of logical axis name -> mesh axis (or tuple of mesh axes)."""

    rules: Mapping[str, MeshAxis]

    def mesh_axes(self, logical: str, mesh: Optional[Mesh] = None) -> MeshAxis:
        ax = self.rules.get(logical)
        if ax is None:
            return None
        if mesh is not None:
            # Drop mesh axes that do not exist on this mesh (e.g. "pod" on a
            # single-pod mesh) so one rule table serves both meshes.
            names = set(mesh.axis_names)
            if isinstance(ax, str):
                return ax if ax in names else None
            kept = tuple(a for a in ax if a in names)
            if not kept:
                return None
            return kept[0] if len(kept) == 1 else kept

        return ax

    def replace(self, **updates: MeshAxis) -> "AxisRules":
        merged = dict(self.rules)
        merged.update(updates)
        return AxisRules(merged)


# Default rule tables.  "embed" is FSDP-sharded over the data axis during
# training (ZeRO-3 style: XLA inserts per-layer all-gathers inside the layer
# scan, overlapping them with compute); it is *replicated* for serving where
# latency matters more than memory.
TRAIN_RULES = AxisRules(
    {
        "batch": ("pod", "data"),
        "embed": "data",          # FSDP axis for parameters
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "expert": "model",
        "ssm_heads": "model",
        "seq": None,
        "seq_sp": "model",        # Megatron sequence parallelism: residual
                                  # stream sharded over model between matmuls
        "kv_seq": None,
        "expert_capacity": "data",
        "stack": None,            # scan-over-layers dim, never sharded
    }
)

# FSDP-heavy profile for small models on a big mesh: the model axis carries
# parameter shards + batch, not tensor-parallel compute — eliminating the
# per-layer activation all-reduces that dominate TP-16 for <=10B models
# (EXPERIMENTS.md §Perf H1).
FSDP_RULES = AxisRules(
    {
        "batch": ("pod", "data", "model"),
        "embed": ("data", "model"),
        "vocab": None,
        "heads": None,
        "kv_heads": None,
        "mlp": None,
        "expert": "model",
        "ssm_heads": None,
        "seq": None,
        "seq_sp": None,           # model axis already carries batch
        "kv_seq": None,
        "expert_capacity": "data",
        "stack": None,
    }
)

SERVE_RULES = AxisRules(
    {
        "batch": ("pod", "data"),
        "embed": None,            # replicate params over data for serving
        "vocab": "model",
        "heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "expert": "model",
        "ssm_heads": "model",
        "seq": None,
        "seq_sp": "model",        # sequence parallelism for prefill
                                  # (no-op for decode: seq dim is 1)
        "kv_seq": "model",        # sequence-parallel KV cache for decode
        "expert_capacity": "data",
        "stack": None,
    }
)


def _divisible(dim: int, ax: MeshAxis, mesh: Mesh) -> bool:
    if ax is None:
        return True
    axes = (ax,) if isinstance(ax, str) else tuple(ax)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return dim % n == 0


def logical_to_spec(
    logical_axes: Sequence[Optional[str]],
    rules: AxisRules,
    mesh: Mesh,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Build a PartitionSpec from per-dimension logical axis names.

    Any mesh axis may appear at most once in a PartitionSpec; later logical
    axes that would reuse an already-consumed mesh axis fall back to
    replication.  Dimensions not divisible by the mesh-axis size are also
    replicated (e.g. kv_heads=8 on a 16-way model axis).
    """
    used: set = set()
    out = []
    for i, name in enumerate(logical_axes):
        ax = rules.mesh_axes(name, mesh) if name else None
        if ax is None:
            out.append(None)
            continue
        axes = tuple(a for a in ((ax,) if isinstance(ax, str) else tuple(ax))
                     if a not in used)
        # longest prefix of the requested mesh axes that divides the dim
        # (e.g. batch=(data,model) degrades to (data,) for small batches)
        while axes and shape is not None and not _divisible(shape[i], axes, mesh):
            axes = axes[:-1]
        if not axes:
            out.append(None)
            continue
        used.update(axes)
        out.append(axes[0] if len(axes) == 1 else axes)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(
    logical_axes: Sequence[Optional[str]],
    rules: AxisRules,
    mesh: Mesh,
    shape: Optional[Sequence[int]] = None,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical_axes, rules, mesh, shape))


def spec_tree(logical_tree: Any, rules: AxisRules, mesh: Mesh, shape_tree: Any = None) -> Any:
    """Map a pytree of logical-axes tuples to a pytree of PartitionSpecs."""
    if shape_tree is None:
        return jax.tree.map(
            lambda la: logical_to_spec(la, rules, mesh),
            logical_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
        )
    return jax.tree.map(
        lambda la, sh: logical_to_spec(la, rules, mesh, sh),
        logical_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )


def shard_tree(tree: Any, spec_tree_: Any, mesh: Mesh) -> Any:
    """Device-put a pytree according to a PartitionSpec pytree."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, NamedSharding(mesh, s)), tree, spec_tree_
    )


def with_logical(x: jax.Array, logical_axes: Sequence[Optional[str]], rules: AxisRules, mesh: Optional[Mesh]) -> jax.Array:
    """Apply a sharding constraint derived from logical axes (no-op if no mesh)."""
    if mesh is None:
        return x
    spec = logical_to_spec(logical_axes, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
