"""Pure SSM language model (Mamba-2): attention-free, FFN-free blocks."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers, ssm as ssm_lib
from repro.models.common import ModelConfig, stack_tree
from repro.models.hybrid import _ssm_prefill_with_state
from repro.models.transformer import DecoderLM


class SSMLM(DecoderLM):
    def layer_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "ln1": layers.rmsnorm_spec(cfg.d_model),
            "mixer": ssm_lib.ssm_specs(cfg),
        }

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": layers.embed_specs(cfg),
            "layers": stack_tree(self.layer_specs(), cfg.num_layers),
            "ln_f": layers.rmsnorm_spec(cfg.d_model),
        }

    def backbone(self, params, x, positions):
        cfg = self.cfg

        def body(h, lp):
            hn = layers.rmsnorm(h, lp["ln1"], cfg.rms_eps)
            return h + ssm_lib.ssm_forward(lp["mixer"], hn, cfg), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["layers"])
        return layers.rmsnorm(x, params["ln_f"], cfg.rms_eps), jnp.zeros((), jnp.float32)

    # -- caches ----------------------------------------------------------------

    def abstract_cache(self, batch: int, seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        s_cfg = cfg.ssm
        l = cfg.num_layers
        din = s_cfg.d_inner(cfg.d_model)
        h = s_cfg.n_heads(cfg.d_model)
        gn = s_cfg.n_groups * s_cfg.d_state
        dt = cfg.compute_dtype
        return {
            "state": jax.ShapeDtypeStruct((l, batch, h, s_cfg.head_dim, s_cfg.d_state), jnp.float32),
            "conv_x": jax.ShapeDtypeStruct((l, batch, s_cfg.conv_width - 1, din), dt),
            "conv_B": jax.ShapeDtypeStruct((l, batch, s_cfg.conv_width - 1, gn), dt),
            "conv_C": jax.ShapeDtypeStruct((l, batch, s_cfg.conv_width - 1, gn), dt),
        }

    def cache_logical_axes(self) -> Dict[str, Tuple]:
        return {
            "state": ("stack", "batch", "ssm_heads", None, None),
            "conv_x": ("stack", "batch", None, "mlp"),
            "conv_B": ("stack", "batch", None, None),
            "conv_C": ("stack", "batch", None, None),
        }

    # -- serving ----------------------------------------------------------------

    def prefill(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]
        x = layers.embed_tokens(params["embed"], tokens, cfg)

        def body(h, lp):
            hn = layers.rmsnorm(h, lp["ln1"], cfg.rms_eps)
            mix, state, cx, cb, cc = _ssm_prefill_with_state(lp["mixer"], hn, cfg)
            return h + mix, {"state": state, "conv_x": cx, "conv_B": cb, "conv_C": cc}

        x, cache = jax.lax.scan(body, x, params["layers"])
        x = layers.rmsnorm(x, params["ln_f"], cfg.rms_eps)
        logits = layers.output_logits(params["embed"], x[:, -1:, :], cfg)
        return logits, cache

    def decode_step(self, params, batch):
        cfg = self.cfg
        token, cache = batch["token"], batch["cache"]
        x = layers.embed_tokens(params["embed"], token, cfg)

        def body(h, inp):
            lp, state, cx, cb, cc = inp
            hn = layers.rmsnorm(h, lp["ln1"], cfg.rms_eps)
            sub = {"state": state, "conv_x": cx, "conv_B": cb, "conv_C": cc}
            mix, sub = ssm_lib.ssm_decode_step(lp["mixer"], hn, sub, cfg)
            return h + mix, sub

        xs = (params["layers"], cache["state"], cache["conv_x"], cache["conv_B"], cache["conv_C"])
        x, new_cache = jax.lax.scan(body, x, xs)
        x = layers.rmsnorm(x, params["ln_f"], cfg.rms_eps)
        logits = layers.output_logits(params["embed"], x, cfg)
        return logits, new_cache
