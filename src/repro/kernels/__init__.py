"""Pallas TPU kernels for the DSI hot spots (§7.2) + their dispatch layer.

Public API — import from here (``from repro.kernels import sigrid_hash``):
every op is a jit'd wrapper with a ``use_pallas`` knob implementing one
dispatch contract:

  * ``use_pallas=None`` (default): the Pallas kernel **compiled** on TPU;
    the pure-jnp oracle (``repro.kernels.ref``) everywhere else — the
    fast correct path for whatever backend is present.
  * ``use_pallas=True``: always the Pallas kernel — compiled on TPU,
    **interpret mode** off-TPU (slow, bit-accurate; how CI validates the
    kernels on CPU).
  * ``use_pallas=False``: always the jnp oracle.

The per-kernel modules (``fused_transform``, ``sigrid_hash``, ...) hold
the raw ``pallas_call`` implementations; ``repro.core.engine`` builds the
DPP worker's fused TransformEngine on top of ``fused_transform``.
"""
from repro.kernels.ops import (
    bucketize,
    dense_unpack,
    embedding_bag,
    flash_attention,
    fused_transform,
    ragged_gather,
    sigrid_hash,
    ssd_chunk_forward,
    xor_decrypt,
)

__all__ = [
    "bucketize",
    "dense_unpack",
    "embedding_bag",
    "flash_attention",
    "fused_transform",
    "ragged_gather",
    "sigrid_hash",
    "ssd_chunk_forward",
    "xor_decrypt",
]
