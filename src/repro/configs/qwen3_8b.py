"""qwen3-8b — dense GQA with qk_norm [hf:Qwen/Qwen3-8B]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    sharding_profile="fsdp",
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen3-smoke", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512, remat=False,
)
