#!/usr/bin/env bash
# Tier-1 gate: the whole suite, one command.
#   ./scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
