"""Split-scoped read path: per-split bytes scale with split size, not
partition size (ISSUE 1 acceptance; extends the Table 12 read-path ladder).

Compares the pre-fix behavior (every split re-reads + decodes the whole
partition) against stripe-pruned split-scoped reads, measured on a real
DPP session and cross-checked against the analytic amplification model.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.dpp import DPPSession, SessionSpec
from repro.core.dpp.simulator import dsi_power_split, split_over_read_amplification, RM1
from repro.core.reader import COALESCE_WINDOW, TableReader, plan_reads
from repro.core.schema import make_schema
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse

ROWS = 4096
STRIPE = 512


def run() -> None:
    schema = make_schema("brdr", 60, 12, seed=0)
    wh = Warehouse()
    t = wh.create_table(schema)
    t.generate(1, DataGenConfig(rows_per_partition=ROWS, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE))
    meta = t.partitions[0]
    proj = schema.logged_ids[:24]
    reader = TableReader(t, proj, record_popularity=False)

    # per-split bytes_read vs split size (partition bytes held constant)
    full = reader.read_partition(meta)
    for n_splits in (1, 2, 4, 8):
        split_rows = ROWS // n_splits
        t.fs.reset_stats()
        t0 = time.perf_counter()
        per_split = [
            reader.read_rows(meta, i * split_rows, (i + 1) * split_rows).bytes_read
            for i in range(n_splits)
        ]
        us = (time.perf_counter() - t0) / n_splits * 1e6
        emit(
            f"read_path.split_scoped.{n_splits}_splits", us,
            f"bytes_per_split={sum(per_split)//n_splits} "
            f"epoch_bytes={sum(per_split)} full_partition={full.bytes_read}",
        )

    # over-read ratio: seed behavior (partition re-read per split) vs fixed
    n_splits = 4
    plan = plan_reads(meta.footer, proj, COALESCE_WINDOW)
    seed_epoch_bytes = n_splits * plan.bytes_planned

    dense, sparse = schema.dense_ids[:12], schema.sparse_ids[:6]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=10_000)
    spec = SessionSpec(
        table="brdr", partitions=(0,), feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs), batch_size=512,
        rows_per_split=ROWS // n_splits,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=16,
    )
    sess = DPPSession(spec, t, n_workers=2)
    batches = sess.run_to_completion(timeout_s=120)
    m = sess.worker_metrics()
    rows = sum(b["label"].shape[0] for b in batches)
    improvement = seed_epoch_bytes / max(m.storage_rx_bytes, 1)
    emit(
        "read_path.session_over_read.4_splits", 0.0,
        f"storage_rx={m.storage_rx_bytes} seed_rx={seed_epoch_bytes} "
        f"improvement={improvement:.2f}x rows={rows} "
        f"stripes_read={m.stripes_read} decode_over_read={m.over_read_ratio:.3f}",
    )

    # analytic model + fleet power impact of the fix (Fig. 1 currency);
    # 700-row splits over 512-row stripes shows the stripe-edge waste that
    # stripe-aligned splits remove
    for scoped, aligned, tag in ((False, False, "seed"), (True, False, "unaligned"),
                                 (True, True, "aligned")):
        amp = split_over_read_amplification(
            ROWS, 700, STRIPE, split_scoped=scoped, stripe_aligned=aligned
        )
        p = dsi_power_split(RM1, n_trainers=16, storage_amplification=amp)
        emit(
            f"read_path.amplification.{tag}", 0.0,
            f"amp={amp:.2f} storage_frac={p['storage_frac']:.3f}",
        )
