"""Content-addressed stripe identity (RecD-style dedup, arXiv 2211.05239).

Combo-window jobs re-read the same partitions, and warehouse re-ingestion /
table forks produce byte-identical stripes under *different* paths.  Keying
the cache by path would miss both; keying by **content** collapses them.

At warehouse-write time every encoded stripe payload is hashed and the
``(path, offset, length) -> digest`` mapping is registered here.  A read
extent that falls inside a registered stripe resolves to a content key
``(digest, rel_off, length)`` *without touching storage*, so the second
job (or the second byte-identical partition) hits the cache even though it
never read that path before.  Extents that cross stripe boundaries (e.g.
window-coalesced reads spanning stripes) fall back to a path-addressed key:
still cacheable, just not content-deduplicated.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

from repro.obs import counter

# Cache key: ("c", digest, rel_off, length) for content-addressed extents,
# ("p", (path, generation), offset, length) for the path-addressed fallback.
# The generation is bumped on every invalidation (partition rewrite), so a
# key resolved *before* a rewrite can never collide with one resolved after
# it — an in-flight reader admitting pre-rewrite bytes under an old-gen key
# cannot be re-served to post-rewrite readers.  Content keys need no
# generation: the digest IS the bytes.
CacheKey = Tuple


def stripe_digest(payload: bytes) -> str:
    return hashlib.sha1(payload).hexdigest()


@dataclasses.dataclass
class _StripeSpan:
    offset: int
    length: int
    digest: str


@dataclasses.dataclass
class DedupStats:
    stripes_registered: int = counter()
    logical_bytes: int = counter()    # sum of registered stripe lengths
    unique_bytes: int = counter()     # sum over distinct digests

    @property
    def dedup_ratio(self) -> float:
        """Logical bytes per unique byte; 1.0 = no duplicates."""
        return self.logical_bytes / max(self.unique_bytes, 1)


class DedupIndex:
    """Maps file byte ranges to stripe content digests."""

    def __init__(self):
        self._spans: Dict[str, List[_StripeSpan]] = {}
        self._digest_bytes: Dict[str, int] = {}   # digest -> stripe length
        self._generation: Dict[str, int] = {}     # path -> rewrite count
        self.stats = DedupStats()

    def register(self, path: str, offset: int, length: int, payload: bytes) -> str:
        """Idempotent on (path, offset): re-attaching a cache that already
        indexed this file must not double-count the dedup statistics."""
        for span in self._spans.get(path, ()):
            if span.offset == offset:
                return span.digest
        d = stripe_digest(payload)
        self._spans.setdefault(path, []).append(_StripeSpan(offset, length, d))
        self.stats.stripes_registered += 1
        self.stats.logical_bytes += length
        if d not in self._digest_bytes:
            self._digest_bytes[d] = length
            self.stats.unique_bytes += length
        return d

    def invalidate(self, path: str) -> None:
        """Drop a path's spans and bump its generation (the file was
        rewritten, e.g. by append or partition churn): path keys resolved
        from now on cannot match anything admitted under the old bytes."""
        self._spans.pop(path, None)
        self._generation[path] = self._generation.get(path, 0) + 1

    def generation(self, path: str) -> int:
        return self._generation.get(path, 0)

    @property
    def unique_stripes(self) -> int:
        return len(self._digest_bytes)

    def resolve(self, path: str, offset: int, length: int) -> CacheKey:
        """Content key if [offset, offset+length) sits inside one registered
        stripe, else the path-addressed fallback key."""
        for span in self._spans.get(path, ()):
            if span.offset <= offset and offset + length <= span.offset + span.length:
                return ("c", span.digest, offset - span.offset, length)
        return ("p", (path, self._generation.get(path, 0)), offset, length)

    def segments(self, path: str, offset: int, length: int) -> List[Tuple[int, int]]:
        """Split [offset, offset+length) along registered stripe boundaries.

        Window-coalesced extents can span stripes; caching them whole would
        pin the cache to one job's coalescing pattern.  Cutting at stripe
        edges makes every cacheable unit resolve to a content key, so jobs
        with different windows/projections still share entries."""
        end = offset + length
        cur = offset
        out: List[Tuple[int, int]] = []
        for span in sorted(self._spans.get(path, ()), key=lambda s: s.offset):
            if span.offset + span.length <= cur or span.offset >= end:
                continue
            if cur < span.offset:               # unregistered gap before span
                out.append((cur, span.offset - cur))
                cur = span.offset
            seg_end = min(end, span.offset + span.length)
            out.append((cur, seg_end - cur))
            cur = seg_end
        if cur < end:
            out.append((cur, end - cur))
        return out
