"""DPP control plane: the Master (§3.2.1).

Responsibilities (paper-faithful):
  * break the preprocessing workload into self-contained **splits**
    (successive row ranges of the dataset) and serve them to Workers,
  * track split progress; re-dispatch splits whose lease expired
    (worker failure / straggler mitigation),
  * periodic **checkpoints** of reader state for restore-on-failure,
  * worker health monitoring (heartbeats) with automatic restart hooks.

Fleet sizing is NOT the Master's job here: the hysteresis-aware
feedback controller lives in ``repro.core.dpp.autoscale`` and is
actuated by the ``DPPSession`` monitor.

Failure domains (ISSUE 4): every split carries a **dispatch budget**.
Completions are typed (``ok`` / ``worker_lost`` / ``data_error``) so the
Master can tell a preempted worker from poisoned data; a split that
exhausts its budget is **quarantined** instead of re-dispatched forever,
and the session reaches a terminal state (``COMPLETED`` / ``DEGRADED`` /
``FAILED``) that surfaces the offending split and its exception chain.
DSI jobs run for days across preemptible fleets — without budgets a
single bad split (e.g. mixed labeled/unlabeled stripes) livelocks the
whole session on worker restarts.

The Master itself is replicated in production; here `checkpoint()` /
`DPPMaster.restore()` provide the equivalent failover path.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.transforms import TransformPipeline, TransformSpec


@dataclasses.dataclass(frozen=True)
class SessionSpec:
    """The PyTorch-DataSet analogue shipped by FBLearner Flow."""

    table: str
    partitions: Tuple[int, ...]
    feature_ids: Tuple[int, ...]
    transform_specs: Tuple[TransformSpec, ...]
    batch_size: int = 512
    rows_per_split: int = 2048
    dense_keys: Tuple[str, ...] = ()
    sparse_keys: Tuple[str, ...] = ()
    max_ids_per_feature: int = 32

    def pipeline(self) -> TransformPipeline:
        return TransformPipeline(list(self.transform_specs))


@dataclasses.dataclass
class Split:
    split_id: int
    partition: int
    row_start: int
    row_end: int


@dataclasses.dataclass
class _Lease:
    worker_id: str
    deadline: float


# -- typed completion reports + failure domains (ISSUE 4) --------------------

REPORT_OK = "ok"
REPORT_WORKER_LOST = "worker_lost"    # lease expiry / dead worker
REPORT_DATA_ERROR = "data_error"      # extract/transform raised on the data

REPORT_STATUSES = (REPORT_OK, REPORT_WORKER_LOST, REPORT_DATA_ERROR)


class SessionState:
    """Session-level states.  ``RUNNING`` is the only non-terminal one."""

    RUNNING = "RUNNING"
    COMPLETED = "COMPLETED"    # every split done
    DEGRADED = "DEGRADED"      # some splits quarantined, the rest done
    FAILED = "FAILED"          # every split quarantined — nothing produced

    TERMINAL = (COMPLETED, DEGRADED, FAILED)


@dataclasses.dataclass
class FailureReport:
    """One failed dispatch of a split."""

    status: str          # REPORT_WORKER_LOST | REPORT_DATA_ERROR
    worker_id: str
    error: str           # human-readable cause (traceback for data errors)


@dataclasses.dataclass
class SplitFailure:
    """A quarantined split: its identity plus the full exception chain."""

    split_id: int
    partition: int
    row_start: int
    row_end: int
    dispatches: int
    reports: List[FailureReport]

    @property
    def last_error(self) -> str:
        return self.reports[-1].error if self.reports else ""

    @property
    def statuses(self) -> List[str]:
        return [r.status for r in self.reports]


class DPPMaster:
    def __init__(
        self,
        spec: SessionSpec,
        partition_rows: Dict[int, int],
        lease_s: float = 30.0,
        partition_stripe_rows: Optional[Dict[int, int]] = None,
        dispatch_budget: int = 3,
        clock: Callable[[], float] = time.time,
    ):
        self.spec = spec
        self.lease_s = lease_s
        # injected clock (REPRO-C001): lease expiry / heartbeat tests can
        # drive time deterministically instead of sleeping
        self._clock = clock
        self.dispatch_budget = max(1, dispatch_budget)
        self._lock = threading.Lock()
        self._splits: Dict[int, Split] = {}
        self._pending: List[int] = []
        self._leased: Dict[int, _Lease] = {}
        self._done: set = set()
        self._dispatches: Dict[int, int] = {}     # split -> times leased
        self._failures: Dict[int, List[FailureReport]] = {}
        self._quarantined: Dict[int, SplitFailure] = {}
        self._workers: Dict[str, float] = {}      # worker_id -> last heartbeat
        self._restarts: List[str] = []
        self._stripe_rows = dict(partition_stripe_rows or {})
        self._build_splits(partition_rows)

    def _build_splits(self, partition_rows: Dict[int, int]) -> None:
        """Emit stripe-aligned splits: rows_per_split is rounded up to a
        multiple of the partition's stripe size so a split's row range maps
        onto whole stripes and a worker never decodes rows it throws away."""
        sid = 0
        for p in self.spec.partitions:
            rows = partition_rows[p]
            step = self.spec.rows_per_split
            stripe = self._stripe_rows.get(p, 0)
            if stripe > 0:
                step = max(1, -(-step // stripe)) * stripe
            for start in range(0, rows, step):
                end = min(start + step, rows)
                self._splits[sid] = Split(sid, p, start, end)
                self._pending.append(sid)
                sid += 1

    # -- work distribution ---------------------------------------------------

    def get_split(self, worker_id: str) -> Optional[Split]:
        with self._lock:
            self._workers[worker_id] = self._clock()
            self._reclaim_expired_locked()
            if not self._pending:
                return None
            sid = self._pending.pop(0)
            self._dispatches[sid] = self._dispatches.get(sid, 0) + 1
            self._leased[sid] = _Lease(worker_id, self._clock() + self.lease_s)
            return self._splits[sid]

    def peek_pending(self, n: int) -> List[Split]:
        """The next ``n`` not-yet-leased splits, in dispatch order — the
        prefetch planner's window onto upcoming work (read-only: peeking
        does not lease)."""
        with self._lock:
            return [self._splits[sid] for sid in self._pending[:n]]

    def complete_split(
        self,
        worker_id: str,
        split_id: int,
        status: str = REPORT_OK,
        error: Optional[str] = None,
    ) -> None:
        """Typed completion report.  ``ok`` marks the split done;
        ``data_error`` (the worker's extract/transform raised on the
        split's bytes — deterministic, so retrying on another worker only
        helps against transient corruption) and ``worker_lost`` charge the
        split's dispatch budget and either re-queue or quarantine it.

        Reports are validated against lease ownership: a failure report
        from a *superseded* dispatch (its lease already expired and was
        charged ``worker_lost`` at reclaim) is ignored rather than
        double-charging the budget and cancelling the current holder's
        lease.  A late ``ok`` is always accepted — the work is done,
        whoever finished it."""
        if status not in REPORT_STATUSES:
            raise ValueError(f"unknown completion status: {status!r}")
        with self._lock:
            lease = self._leased.get(split_id)
            owns = lease is not None and lease.worker_id == worker_id
            if status == REPORT_OK:
                if owns:
                    del self._leased[split_id]
                # a late ok un-quarantines: the split's batches WERE
                # produced and delivered (e.g. a worker that out-slept its
                # budget's worth of lease expiries but finished anyway), so
                # reporting it failed would mislabel delivered data
                self._quarantined.pop(split_id, None)
                self._done.add(split_id)
                if split_id in self._pending:
                    self._pending.remove(split_id)
                return
            if split_id in self._done or split_id in self._quarantined:
                if owns:
                    del self._leased[split_id]
                return
            if not owns:
                return
            del self._leased[split_id]
            self._record_failure_locked(
                split_id, status, worker_id, error or status
            )

    def _record_failure_locked(
        self, sid: int, status: str, worker_id: str, error: str
    ) -> None:
        """Charge one failed dispatch; re-queue under budget, else
        quarantine (never re-dispatched — the anti-livelock invariant)."""
        self._failures.setdefault(sid, []).append(
            FailureReport(status=status, worker_id=worker_id, error=error)
        )
        if self._dispatches.get(sid, 0) >= self.dispatch_budget:
            sp = self._splits[sid]
            self._quarantined[sid] = SplitFailure(
                split_id=sid, partition=sp.partition,
                row_start=sp.row_start, row_end=sp.row_end,
                dispatches=self._dispatches.get(sid, 0),
                reports=list(self._failures[sid]),
            )
            if sid in self._pending:
                self._pending.remove(sid)
        elif sid not in self._pending:
            self._pending.insert(0, sid)

    def _reclaim_expired_locked(self) -> None:
        now = self._clock()
        expired = [sid for sid, l in self._leased.items() if l.deadline < now]
        for sid in expired:
            # straggler mitigation / failure handling: a silent lease expiry
            # is a lost worker — typed so it charges the dispatch budget
            lease = self._leased.pop(sid)
            if sid not in self._done:
                self._record_failure_locked(
                    sid, REPORT_WORKER_LOST, lease.worker_id,
                    f"lease expired after {self.lease_s}s "
                    f"(worker {lease.worker_id} lost or straggling)",
                )

    @property
    def progress(self) -> Tuple[int, int]:
        with self._lock:
            return len(self._done), len(self._splits)

    @property
    def finished(self) -> bool:
        """Terminal: every split is either done or quarantined.  (Without
        counting quarantine a poisoned split would keep ``finished`` False
        forever — the livelock this redesign removes.)"""
        with self._lock:
            return (
                len(self._done) + len(self._quarantined) >= len(self._splits)
            )

    # -- session state + failure surfacing -------------------------------------

    @property
    def state(self) -> str:
        with self._lock:
            total = len(self._splits)
            if len(self._done) + len(self._quarantined) < total:
                return SessionState.RUNNING
            if not self._quarantined:
                return SessionState.COMPLETED
            return (
                SessionState.FAILED if not self._done else SessionState.DEGRADED
            )

    @property
    def quarantined(self) -> Dict[int, SplitFailure]:
        with self._lock:
            return dict(self._quarantined)

    def failure_report(self) -> List[SplitFailure]:
        """Quarantined splits with their full per-dispatch exception chain,
        in split order — what ``SessionFailed`` carries to the trainer."""
        with self._lock:
            return [self._quarantined[s] for s in sorted(self._quarantined)]

    # -- health / fault tolerance ---------------------------------------------

    def heartbeat(self, worker_id: str) -> None:
        """Liveness signal from a worker mid-ETL.  Extends the worker's
        lease deadlines: a slow-but-alive worker (long split, back-pressured
        buffer) must not be charged ``worker_lost`` against its split's
        dispatch budget.  A genuinely lost worker stops heartbeating, so
        straggler re-dispatch still fires on real failures.  (``get_split``
        deliberately does NOT extend leases — only active processing does.)"""
        now = self._clock()
        with self._lock:
            self._workers[worker_id] = now
            for l in self._leased.values():
                if l.worker_id == worker_id:
                    l.deadline = now + self.lease_s

    def dead_workers(self, timeout_s: float = 10.0) -> List[str]:
        now = self._clock()
        with self._lock:
            return [w for w, t in self._workers.items() if now - t > timeout_s]

    def forget_worker(self, worker_id: str) -> None:
        """Worker died: release its leases immediately (stateless workers —
        no checkpoint restore needed, §3.2.1).  Each released lease is a
        typed ``worker_lost`` failure charged to the split's budget."""
        with self._lock:
            self._workers.pop(worker_id, None)
            for sid, l in list(self._leased.items()):
                if l.worker_id == worker_id:
                    del self._leased[sid]
                    if sid not in self._done:
                        self._record_failure_locked(
                            sid, REPORT_WORKER_LOST, worker_id,
                            f"worker {worker_id} died holding the lease",
                        )
            self._restarts.append(worker_id)

    # -- checkpointing -----------------------------------------------------------

    def checkpoint(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "spec": self.spec,
                "done": sorted(self._done),
                "n_splits": len(self._splits),
                "stripe_rows": dict(self._stripe_rows),
                "dispatches": dict(self._dispatches),
                "quarantined": [
                    dataclasses.asdict(f) for f in self._quarantined.values()
                ],
                # failure history of splits still under budget: a restored
                # Master must quarantine with the FULL report chain, not
                # just the reports accumulated after failover
                "failures": {
                    sid: [dataclasses.asdict(r) for r in reports]
                    for sid, reports in self._failures.items()
                    if sid not in self._quarantined
                },
            }

    @classmethod
    def restore(
        cls,
        ckpt: Dict[str, Any],
        partition_rows: Dict[int, int],
        lease_s: float = 30.0,
        dispatch_budget: int = 3,
        clock: Callable[[], float] = time.time,
    ) -> "DPPMaster":
        m = cls(
            ckpt["spec"], partition_rows, lease_s=lease_s,
            partition_stripe_rows=ckpt.get("stripe_rows"),
            dispatch_budget=dispatch_budget,
            clock=clock,
        )
        with m._lock:
            for sid in ckpt["done"]:
                m._done.add(sid)
                if sid in m._pending:
                    m._pending.remove(sid)
            m._dispatches.update(ckpt.get("dispatches", {}))
            for sid, reports in ckpt.get("failures", {}).items():
                m._failures[sid] = [FailureReport(**r) for r in reports]
            for f in ckpt.get("quarantined", ()):
                sf = SplitFailure(
                    split_id=f["split_id"], partition=f["partition"],
                    row_start=f["row_start"], row_end=f["row_end"],
                    dispatches=f["dispatches"],
                    reports=[FailureReport(**r) for r in f["reports"]],
                )
                m._quarantined[sf.split_id] = sf
                m._failures[sf.split_id] = list(sf.reports)
                if sf.split_id in m._pending:
                    m._pending.remove(sf.split_id)
        return m
