#!/usr/bin/env bash
# Tier-1 gate: the whole suite + invariant gate + benchmark smoke, one command.
#   ./scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
# invariant gate: lock discipline, clock injection, kernel parity,
# metrics contract, span hygiene, thread hygiene (docs/static_analysis.md)
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis
# concurrency sanitizers (docs/static_analysis.md "runtime sanitizers"):
# raced-marked tests rerun real subsystems under the lockset race
# detector, then the interleaving explorer checks every control-plane
# scenario invariant under all bounded schedules — both ship with an
# empty baseline, so any finding fails CI
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q -m raced tests/test_racedep.py
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis.sched -q
# telemetry smoke: traced two-tenant run -> artifact -> stall-report gate
# (Perfetto-loadable trace, shares sum to 100, no span left open)
OBS_TRACE="$(mktemp /tmp/obs_trace.XXXXXX.json)"
trap 'rm -f "$OBS_TRACE"' EXIT
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.obs.smoke --out "$OBS_TRACE" --rows 256
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.obs.report "$OBS_TRACE" --check
# benchmark smoke: every bench module must import; quick-capable sections run
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick
# doc drift: every path / python -m command / REPRO rule id the docs
# reference must exist
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/check_docs.py
