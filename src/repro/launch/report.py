"""Render the roofline table (EXPERIMENTS.md §Roofline) from dryrun JSONL.

  PYTHONPATH=src python -m repro.launch.report results/dryrun.jsonl [--md]
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List


def load(path: str) -> List[dict]:
    rows = []
    seen = {}
    with open(path) as f:
        for line in f:
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("multi_pod"))
            seen[key] = r  # last write wins (resumed sweeps)
    return list(seen.values())


def fmt_row(r: dict) -> str:
    if r["status"] == "skipped":
        return (
            f"| {r['arch']} | {r['shape']} | {'2x16x16' if r['multi_pod'] else '16x16'} "
            f"| skipped | — | — | — | — | — | {r['reason'][:58]} |"
        )
    if r["status"] != "ok":
        return f"| {r['arch']} | {r['shape']} | — | ERROR | — | — | — | — | — | {r.get('error','')[:58]} |"
    rf = r["roofline"]
    ratio = rf.get("useful_flops_ratio")
    frac = rf.get("roofline_fraction")
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} | {rf['bottleneck']} "
        f"| {rf['compute_s']:.3g} | {rf['memory_s']:.3g} | {rf['collective_s']:.3g} "
        f"| {ratio:.2f} | {frac*100 if frac else 0:.1f}% "
        f"| mem/dev={r['memory_analysis'].get('total_bytes_per_device', 0)/1e9:.1f}GB |"
    )


HEADER = (
    "| arch | shape | mesh | bottleneck | compute_s | memory_s | collective_s "
    "| 6ND/HLO | roofline-frac | notes |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("path")
    ap.add_argument("--single-pod-only", action="store_true")
    args = ap.parse_args()
    rows = load(args.path)
    rows.sort(key=lambda r: (r.get("arch", ""), r.get("shape", ""), r.get("multi_pod", False)))
    print(HEADER)
    for r in rows:
        if args.single_pod_only and r.get("multi_pod"):
            continue
        print(fmt_row(r))
    ok = [r for r in rows if r["status"] == "ok" and not r.get("multi_pod")]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"].get("roofline_fraction") or 1.0)
        coll = max(ok, key=lambda r: r["roofline"]["collective_s"] / max(r["roofline"]["step_time_s"], 1e-9))
        print(f"\n# worst roofline fraction: {worst['arch']}/{worst['shape']} "
              f"({(worst['roofline']['roofline_fraction'] or 0)*100:.1f}%)")
        print(f"# most collective-bound: {coll['arch']}/{coll['shape']} "
              f"(collective_s={coll['roofline']['collective_s']:.3g} of step {coll['roofline']['step_time_s']:.3g})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
