"""Mamba-2 (SSD — state-space duality) layer, TPU-friendly chunked form.

Training/prefill uses the chunked SSD algorithm: within-chunk quadratic
(matmul-heavy, MXU-friendly) + an inter-chunk ``lax.scan`` over the running
state — the standard TPU adaptation of the Mamba-2 recurrence.  Decode is a
single-step state update with O(1) memory in sequence length, which is what
makes the ``long_500k`` shape runnable for SSM/hybrid architectures.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.common import ModelConfig, ParamSpec, SSMConfig
from repro.models.layers import rmsnorm


def ssm_specs(cfg: ModelConfig, d_model: Optional[int] = None) -> Dict[str, ParamSpec]:
    s = cfg.ssm
    assert s is not None
    d = d_model or cfg.d_model
    din = s.d_inner(d)
    h = s.n_heads(d)
    gn = s.n_groups * s.d_state
    dt = cfg.param_dtype
    return {
        "wz": ParamSpec((d, din), ("embed", "mlp"), dt, "scaled"),
        "wx": ParamSpec((d, din), ("embed", "mlp"), dt, "scaled"),
        "wB": ParamSpec((d, gn), ("embed", None), dt, "scaled"),
        "wC": ParamSpec((d, gn), ("embed", None), dt, "scaled"),
        "wdt": ParamSpec((d, h), ("embed", "ssm_heads"), dt, "scaled"),
        "conv_x": ParamSpec((s.conv_width, din), (None, "mlp"), dt, "scaled"),
        "conv_B": ParamSpec((s.conv_width, gn), (None, None), dt, "scaled"),
        "conv_C": ParamSpec((s.conv_width, gn), (None, None), dt, "scaled"),
        "A_log": ParamSpec((h,), ("ssm_heads",), jnp.float32, "zeros"),
        "D": ParamSpec((h,), ("ssm_heads",), jnp.float32, "ones"),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), jnp.float32, "zeros"),
        "norm": ParamSpec((din,), ("mlp",), jnp.float32, "ones"),
        "out": ParamSpec((din, d), ("mlp", "embed"), dt, "scaled"),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv over the sequence dim via shifted adds.

    x: (B, S, C); w: (W, C).  Width is tiny (4), so four shifted
    element-wise multiplies beat a general conv lowering on TPU.
    """
    width = w.shape[0]
    out = x * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, : x.shape[1], :]
        out = out + shifted * w[width - 1 - i]
    return out


def _conv_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array):
    """Single decode step of the causal conv.  x_t: (B, C); state: (B, W-1, C)."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", window, w)
    return out, window[:, 1:, :]


def ssd_chunked(
    x: jax.Array,        # (B, S, H, P)
    dt: jax.Array,       # (B, S, H) — post-softplus
    A: jax.Array,        # (H,) — negative
    B_: jax.Array,       # (B, S, G, N)
    C_: jax.Array,       # (B, S, G, N)
    chunk: int,
    initial_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    b, s, h, p = x.shape
    g, n = B_.shape[2], B_.shape[3]
    hg = h // g
    q = min(chunk, s)
    if s % q:
        q = s
    nc = s // q

    def split(t, extra_shape):
        return t.reshape((b, nc, q) + extra_shape).swapaxes(0, 1)

    xc = split(x, (h, p))              # (nc, B, Q, H, P)
    dtc = split(dt, (h,)).astype(jnp.float32)
    Bc = split(B_, (g, n))
    Cc = split(C_, (g, n))

    state0 = (
        initial_state
        if initial_state is not None
        else jnp.zeros((b, h, p, n), jnp.float32)
    )

    def body(state, inp):
        x_, dt_, b_, c_ = inp
        x_ = constrain(x_, ("batch", None, "ssm_heads", None))
        state = constrain(state, ("batch", "ssm_heads", None, None))
        da = dt_ * A                                   # (B,Q,H), negative
        cs = jnp.cumsum(da, axis=1)                    # inclusive cumsum
        # intra-chunk: L[i,j] = exp(cs[i]-cs[j]) for i >= j.  Mask BEFORE the
        # exp: the i<j entries are positive and exp-overflow to inf, which
        # would poison the backward pass through the where (NaN grads).
        seg = cs[:, :, None, :] - cs[:, None, :, :]    # (B,Q,Q,H)
        causal = jnp.tril(jnp.ones((q, q), bool))
        L = jnp.exp(jnp.where(causal[None, :, :, None], seg, -1e30))
        cb = jnp.einsum("bqgn,bkgn->bgqk", c_, b_).astype(jnp.float32)
        # expand groups to heads: head h belongs to group h // hg
        cb_h = jnp.repeat(cb, hg, axis=1).transpose(0, 2, 3, 1)  # (B,Q,K,H)
        m = cb_h * L * dt_[:, None, :, :]
        y_intra = jnp.einsum(
            "bqkh,bkhp->bqhp", m.astype(x_.dtype), x_
        ).astype(jnp.float32)
        # inter-chunk: contribution of the carried state
        c_h = jnp.repeat(c_, hg, axis=2)               # (B,Q,H,N)
        y_inter = jnp.einsum(
            "bqhn,bhpn->bqhp", (c_h.astype(jnp.float32) * jnp.exp(cs)[..., None]), state
        )
        # state update
        decay_out = jnp.exp(cs[:, -1, None, :] - cs)   # (B,Q,H)
        b_h = jnp.repeat(b_, hg, axis=2)               # (B,Q,H,N)
        dstate = jnp.einsum(
            "bqhn,bqhp->bhpn",
            b_h.astype(jnp.float32) * (dt_ * decay_out)[..., None],
            x_.astype(jnp.float32),
        )
        state = jnp.exp(cs[:, -1])[:, :, None, None] * state + dstate
        return state, (y_intra + y_inter).astype(x.dtype)

    # remat the chunk body: without this, backward-of-scan stacks the
    # (B,Q,Q,H) intra-chunk decay/score tensors for every chunk x layer
    # (measured 30%+ of mamba2 train HBM traffic); recomputing them per
    # chunk costs ~1 extra intra-chunk pass of cheap elementwise work.
    final_state, ys = jax.lax.scan(jax.checkpoint(body), state0, (xc, dtc, Bc, Cc))
    y = ys.swapaxes(0, 1).reshape(b, s, h, p)
    return y, final_state


def ssm_forward(
    params: Dict[str, jax.Array],
    x: jax.Array,             # (B, S, d_model)
    cfg: ModelConfig,
    d_model: Optional[int] = None,
) -> jax.Array:
    """Full Mamba-2 mixer for training / prefill."""
    s_cfg = cfg.ssm
    d = d_model or cfg.d_model
    din = s_cfg.d_inner(d)
    h = s_cfg.n_heads(d)
    p = s_cfg.head_dim
    g, n = s_cfg.n_groups, s_cfg.d_state

    z = jnp.einsum("bsd,de->bse", x, params["wz"])
    xi = jnp.einsum("bsd,de->bse", x, params["wx"])
    Bv = jnp.einsum("bsd,de->bse", x, params["wB"])
    Cv = jnp.einsum("bsd,de->bse", x, params["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, params["wdt"]).astype(jnp.float32)

    xi = jax.nn.silu(_causal_conv(xi, params["conv_x"]).astype(jnp.float32)).astype(x.dtype)
    Bv = jax.nn.silu(_causal_conv(Bv, params["conv_B"]).astype(jnp.float32)).astype(x.dtype)
    Cv = jax.nn.silu(_causal_conv(Cv, params["conv_C"]).astype(jnp.float32)).astype(x.dtype)
    xi = constrain(xi, ("batch", "seq", "mlp"))

    dt = jax.nn.softplus(dt + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    b, s = x.shape[:2]
    y, _ = ssd_chunked(
        xi.reshape(b, s, h, p), dt, A,
        Bv.reshape(b, s, g, n), Cv.reshape(b, s, g, n),
        chunk=s_cfg.chunk,
    )
    y = y + xi.reshape(b, s, h, p) * params["D"][None, None, :, None].astype(x.dtype)
    y = y.reshape(b, s, din)
    # gated RMSNorm (Mamba-2 style)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm"], cfg.rms_eps)
    return jnp.einsum("bse,ed->bsd", y, params["out"])


def ssm_init_cache(cfg: ModelConfig, batch: int, d_model: Optional[int] = None, dtype=jnp.float32):
    s = cfg.ssm
    d = d_model or cfg.d_model
    din = s.d_inner(d)
    h = s.n_heads(d)
    gn = s.n_groups * s.d_state
    return {
        "state": jnp.zeros((batch, h, s.head_dim, s.d_state), jnp.float32),
        "conv_x": jnp.zeros((batch, s.conv_width - 1, din), dtype),
        "conv_B": jnp.zeros((batch, s.conv_width - 1, gn), dtype),
        "conv_C": jnp.zeros((batch, s.conv_width - 1, gn), dtype),
    }


def ssm_decode_step(
    params: Dict[str, jax.Array],
    x: jax.Array,             # (B, 1, d_model)
    cache: Dict[str, jax.Array],
    cfg: ModelConfig,
    d_model: Optional[int] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """O(1)-state decode step."""
    s_cfg = cfg.ssm
    d = d_model or cfg.d_model
    din = s_cfg.d_inner(d)
    h = s_cfg.n_heads(d)
    p = s_cfg.head_dim
    g, n = s_cfg.n_groups, s_cfg.d_state
    hg = h // g

    xt = x[:, 0, :]
    z = xt @ params["wz"]
    xi = xt @ params["wx"]
    Bv = xt @ params["wB"]
    Cv = xt @ params["wC"]
    dt = (xt @ params["wdt"]).astype(jnp.float32)

    xi, conv_x = _conv_step(xi, cache["conv_x"], params["conv_x"])
    Bv, conv_B = _conv_step(Bv, cache["conv_B"], params["conv_B"])
    Cv, conv_C = _conv_step(Cv, cache["conv_C"], params["conv_C"])
    xi = jax.nn.silu(xi.astype(jnp.float32))
    Bv = jax.nn.silu(Bv.astype(jnp.float32))
    Cv = jax.nn.silu(Cv.astype(jnp.float32))

    dt = jax.nn.softplus(dt + params["dt_bias"])          # (B,H)
    A = -jnp.exp(params["A_log"])                          # (H,)
    da = jnp.exp(dt * A)                                   # (B,H)

    xh = xi.reshape(-1, h, p)
    Bh = jnp.repeat(Bv.reshape(-1, g, n), hg, axis=1)      # (B,H,N)
    Ch = jnp.repeat(Cv.reshape(-1, g, n), hg, axis=1)

    state = cache["state"] * da[:, :, None, None] + jnp.einsum(
        "bhn,bhp,bh->bhpn", Bh, xh, dt
    )
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + xh * params["D"][None, :, None]
    y = y.reshape(-1, din).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), params["norm"], cfg.rms_eps)
    out = (y @ params["out"])[:, None, :]
    new_cache = {"state": state, "conv_x": conv_x, "conv_B": conv_B, "conv_C": conv_C}
    return out, new_cache
