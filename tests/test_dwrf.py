import numpy as np
import pytest

from repro.core import dwrf
from repro.core.datagen import DataGenConfig, generate_partition
from repro.core.schema import make_schema


def _batch(n_dense=12, n_sparse=5, rows=300, seed=0):
    s = make_schema("t", n_dense, n_sparse, seed=seed)
    return s, generate_partition(s, 0, DataGenConfig(rows_per_partition=rows, seed=seed))


@pytest.mark.parametrize("flattened", [True, False])
@pytest.mark.parametrize("stripe_rows", [64, 1000])
def test_roundtrip(flattened, stripe_rows):
    s, b = _batch()
    f = dwrf.write_dwrf(b, dwrf.DwrfWriterOptions(flattened=flattened, stripe_rows=stripe_rows))
    assert f.data[:4] == b"DWRF"
    want = s.logged_ids
    # decode every stripe fully
    from repro.core.schema import concat_batches
    parts = []
    for stripe in f.footer.stripes:
        fetch = {}
        for st_ in stripe.streams:
            fetch[(st_.fid, st_.kind)] = f.data[st_.offset: st_.offset + st_.length]
        parts.append(dwrf.decode_stripe_features(stripe, fetch, want))
    dec = concat_batches(parts)
    assert dec.num_rows == b.num_rows
    for fid in b.dense:
        np.testing.assert_allclose(
            np.nan_to_num(dec.dense[fid]), np.nan_to_num(b.dense[fid]), rtol=1e-6
        )
    for fid in b.sparse:
        np.testing.assert_array_equal(dec.sparse[fid].values, b.sparse[fid].values)
    np.testing.assert_array_equal(dec.labels, b.labels)


def test_feature_order_respected():
    s, b = _batch()
    order = sorted(set(b.dense) | set(b.sparse), reverse=True)
    f = dwrf.write_dwrf(b, dwrf.DwrfWriterOptions(feature_order=order, stripe_rows=1000))
    stripe = f.footer.stripes[0]
    fids = [st_.fid for st_ in stripe.streams if st_.fid >= 0]
    assert fids == order


def test_flattening_increases_file_size_slightly():
    # FF costs ~12% storage (paper) due to per-stream metadata/compression
    s, b = _batch(rows=600)
    flat = dwrf.write_dwrf(b, dwrf.DwrfWriterOptions(flattened=True, stripe_rows=200))
    mapf = dwrf.write_dwrf(b, dwrf.DwrfWriterOptions(flattened=False, stripe_rows=200))
    assert flat.nbytes > mapf.nbytes
    assert flat.nbytes < 1.6 * mapf.nbytes


def test_large_stripes_reduce_stream_count():
    s, b = _batch(rows=600)
    small = dwrf.write_dwrf(b, dwrf.DwrfWriterOptions(stripe_rows=100))
    large = dwrf.write_dwrf(b, dwrf.DwrfWriterOptions(stripe_rows=600))
    assert len(large.footer.stripes) < len(small.footer.stripes)
    mean_small = np.mean([st_.length for s_ in small.footer.stripes for st_ in s_.streams])
    mean_large = np.mean([st_.length for s_ in large.footer.stripes for st_ in s_.streams])
    assert mean_large > mean_small


@pytest.mark.parametrize("codec", dwrf.available_codecs())
@pytest.mark.parametrize("seed", range(8))
def test_stream_codec_roundtrip(codec, seed):
    rng = np.random.default_rng(seed)
    size = int(rng.integers(0, 2001))
    data = rng.integers(0, 256, size=size, dtype=np.uint8).tobytes()
    enc = dwrf.encode_stream(data, codec=codec)
    assert enc[0] == dwrf.get_codec(codec).cid
    assert dwrf.decode_stream(enc) == data


def test_zlib_codec_always_available():
    assert "zlib" in dwrf.available_codecs()
    assert dwrf.DEFAULT_CODEC in dwrf.available_codecs()


def test_unknown_codec_name_raises():
    with pytest.raises(KeyError):
        dwrf.encode_stream(b"x", codec="lz77-nope")


def test_unknown_codec_id_raises():
    bad = bytes([255]) + dwrf.encode_stream(b"x")[1:]
    with pytest.raises(KeyError):
        dwrf.decode_stream(bad)


def test_file_roundtrip_with_explicit_zlib_codec():
    s, b = _batch(rows=128)
    f = dwrf.write_dwrf(
        b, dwrf.DwrfWriterOptions(flattened=True, stripe_rows=64, codec="zlib")
    )
    stripe = f.footer.stripes[0]
    fetch = {
        (st_.fid, st_.kind): f.data[st_.offset: st_.offset + st_.length]
        for st_ in stripe.streams
    }
    dec = dwrf.decode_stripe_features(stripe, fetch, s.logged_ids)
    assert dec.num_rows == stripe.num_rows
    # every fetched stream carries the zlib codec id byte
    assert all(raw[0] == dwrf.get_codec("zlib").cid for raw in fetch.values())
