from repro.core.dpp.master import DPPMaster, SessionSpec, Split, AutoScaler
from repro.core.dpp.worker import DPPWorker, WorkerMetrics
from repro.core.dpp.client import DPPClient
from repro.core.dpp.service import DPPService, DPPSession
from repro.core.dpp.prefetch import PrefetchMetrics, PrefetchPlanner
