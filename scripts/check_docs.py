#!/usr/bin/env python
"""Doc-drift gate: every repo path, `python -m` command, and analysis
rule id the docs mention must actually exist.

Scans README.md and docs/*.md for

  * `src/repro/...`, `benchmarks/...`, `tests/...`, `examples/...`,
    `scripts/...`, `docs/...` path references (with or without backticks;
    trailing `:line`, wildcards, and `...` ellipses are tolerated),
  * `python -m <module>` / `python <script.py>` invocations, and
  * `REPRO-<X><NNN>` rule ids, which must be registered in
    ``repro.analysis.all_rules()`` — so the rule catalog in
    docs/static_analysis.md can never drift from the checkers,

then verifies each path exists and each module resolves under
`PYTHONPATH=src` — so a rename or deletion can never leave the
documentation silently pointing at nothing.

  PYTHONPATH=src python scripts/check_docs.py [repo-root]
"""
from __future__ import annotations

import importlib.util
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

PATH_RE = re.compile(
    r"(?<![\w/.-])((?:src/repro|benchmarks|tests|examples|scripts|docs)"
    r"(?:/[A-Za-z0-9_.\-*]+)*/?)"
)
MODULE_RE = re.compile(r"python\s+-m\s+([A-Za-z0-9_.]+)")
SCRIPT_RE = re.compile(r"python\s+((?:[A-Za-z0-9_\-]+/)+[A-Za-z0-9_\-]+\.py)")
RULE_RE = re.compile(r"\bREPRO-[A-Z]\d{3}\b")


def _doc_files(repo: Path) -> list:
    readme = repo / "README.md"
    return ([readme] if readme.is_file() else []) \
        + sorted((repo / "docs").glob("*.md"))


def _check_path(repo: Path, ref: str) -> bool:
    # tolerate wildcard ("bench_*.py") and ellipsis ("core/...") mentions:
    # they name a family, not a file — require at least one match
    ref = ref.rstrip("/").split(":", 1)[0]
    if ref.endswith("..."):
        ref = ref[: -len("...")].rstrip("/")
    if "*" in ref:
        parent = repo / ref.rsplit("/", 1)[0]
        return parent.is_dir() and any(parent.glob(ref.rsplit("/", 1)[1]))
    return (repo / ref).exists()


def _check_module(mod: str) -> bool:
    try:
        return importlib.util.find_spec(mod) is not None
    except (ImportError, ModuleNotFoundError):
        return False


def _known_rules() -> set:
    try:
        from repro.analysis import all_rules
        return set(all_rules())
    except ImportError:
        return set()


def main(repo: Path = REPO) -> int:
    # resolve modules the way the documented commands run them: from the
    # repo root with PYTHONPATH=src
    for p in (str(repo), str(repo / "src")):
        if p not in sys.path:
            sys.path.insert(0, p)
    failures = []
    checked = 0
    rules = _known_rules()
    for doc in _doc_files(repo):
        text = doc.read_text()
        rel = doc.relative_to(repo)
        for m in PATH_RE.finditer(text):
            checked += 1
            if not _check_path(repo, m.group(1)):
                failures.append(f"{rel}: missing path  {m.group(1)}")
        for m in MODULE_RE.finditer(text):
            checked += 1
            if not _check_module(m.group(1)):
                failures.append(f"{rel}: missing module python -m {m.group(1)}")
        for m in SCRIPT_RE.finditer(text):
            checked += 1
            if not (repo / m.group(1)).is_file():
                failures.append(f"{rel}: missing script {m.group(1)}")
        for rid in sorted(set(RULE_RE.findall(text))):
            checked += 1
            if rules and rid not in rules:
                failures.append(
                    f"{rel}: unknown analysis rule {rid} "
                    "(not registered in repro.analysis)"
                )
    if failures:
        print(f"doc drift: {len(failures)} stale reference(s):")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"doc drift: ok ({checked} references across "
          f"{len(_doc_files(repo))} docs)")
    return 0


if __name__ == "__main__":
    sys.exit(main(Path(sys.argv[1]).resolve() if len(sys.argv) > 1 else REPO))
