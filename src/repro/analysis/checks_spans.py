"""Span-hygiene rule (REPRO-S001).

A tracer span that is opened but never closed poisons the whole trace
artifact: ``otherData.open_spans`` goes non-zero, the report's ``--check``
gate fails, and the span's duration silently vanishes from the Table-7
attribution.  Manually-paired ``__enter__``/``__exit__`` (or a handle
stashed in a variable and closed "later") leaks exactly this way on any
exception path.

  * **S001** — in ``src/repro/core/**``, ``<tracer>.span(...)`` may only
    appear as a ``with``-statement context expression, where the span is
    closed on every exit path by construction.  The atomic APIs
    (``record`` / ``instant``) are exempt — they never hold a span open.

A call is recognized as a span-open when the receiver chain contains a
``tracer``-named part (``self.tracer.span(...)``, ``tracer.span(...)``),
so unrelated ``.span`` methods on other objects are not captured.
"""
from __future__ import annotations

import ast
from typing import List, Set, Tuple

from repro.analysis.core import (
    CheckContext,
    Finding,
    attr_chain,
    checker,
    enclosing_symbol,
    rule,
)

S001 = rule("REPRO-S001",
            "tracer span in core/ opened outside a `with` block")

_SCOPE = "src/repro/core/"


def _is_span_open(node: ast.AST) -> bool:
    if not (isinstance(node, ast.Call)
            and isinstance(node.func, ast.Attribute)
            and node.func.attr == "span"):
        return False
    chain = attr_chain(node.func.value) or []
    return any("tracer" in part.lower() for part in chain)


class _Scan(ast.NodeVisitor):
    """Collect span-open calls that are not ``with``-item contexts."""

    def __init__(self) -> None:
        self.stack: List[ast.AST] = []
        self._with_ctx: Set[int] = set()
        self.bad: List[Tuple[int, str]] = []     # (line, symbol)

    def _push(self, node: ast.AST) -> None:
        self.stack.append(node)
        self.generic_visit(node)
        self.stack.pop()

    visit_ClassDef = visit_FunctionDef = visit_AsyncFunctionDef = _push

    def _visit_with(self, node) -> None:
        # mark the context expressions BEFORE descending into them, so
        # the Call visit below sees them as sanctioned
        for item in node.items:
            if _is_span_open(item.context_expr):
                self._with_ctx.add(id(item.context_expr))
        self.generic_visit(node)

    visit_With = visit_AsyncWith = _visit_with

    def visit_Call(self, node: ast.Call) -> None:
        if _is_span_open(node) and id(node) not in self._with_ctx:
            self.bad.append((node.lineno, enclosing_symbol(self.stack)))
        self.generic_visit(node)


@checker("span-hygiene")
def check_spans(ctx: CheckContext) -> List[Finding]:
    findings: List[Finding] = []
    for mod in ctx.src_modules():
        if not mod.rel.startswith(_SCOPE):
            continue
        scan = _Scan()
        scan.visit(mod.tree)
        for line, sym in scan.bad:
            findings.append(Finding(
                S001, mod.rel, line,
                "tracer span opened outside a `with` block — core spans "
                "must close via context manager on every exit path (use "
                "record()/instant() for atomic events)",
                sym,
            ))
    return findings
