"""Pallas TPU kernels for batched stripe decode — the extract half of §6.3.

Table 9 shows extract (decrypt + decompress + column decode) dominating
DPP preprocessing compute alongside transform.  PR 5 fused the transform
stage; these kernels fuse the decode stage: instead of one numpy pass per
stream and one scatter/gather per feature, a whole stripe decodes in at
most three launches:

  * ``xor_decrypt`` — the datacenter-tax byte pass.  Every stream's
    encrypted body is concatenated, padded to int32 words, and XORed with
    the replicated key in one launch (byte-wise XOR is position-local, so
    the word view is exact).
  * ``dense_unpack`` — batched presence-bitmap unpack + dense scatter,
    features-major: row f of the bitmap operand holds feature f's
    ``np.packbits`` bytes viewed as little-endian int32 words, row f of
    the value operand its present float32 values as bit patterns.  The
    kernel expands bits (packbits is MSB-first per byte), ranks present
    rows with a prefix sum, gathers each row's value, and emits NaN bits
    for absent rows — all in the int32 bit domain, so NaN/subnormal
    payloads round-trip exactly and no float demotion rule is needed.
  * ``ragged_gather`` — batched extraction of byte-unaligned array
    regions (sparse offsets/values/scores and map-encoded columns) from
    the concatenated payload buffer: ``out = src[idx] >> shift | src[idx
    + 1] << (32 - shift)``, one launch for every region of every stream.

``repro.core.decode.PallasDecodeEngine`` packs the operands and owns the
demotion rules; the jnp oracles live in ``repro.kernels.ref`` and the
dispatch wrappers in ``repro.kernels.ops`` (same ``use_pallas`` contract
as every other kernel).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

XOR_KEY32 = 0x5A5A5A5A           # dwrf._XOR_KEY replicated into each byte
NAN_BITS = int(np.float32(np.nan).view(np.int32))   # the np.full(nan) fill


def _xor_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] ^ jnp.int32(XOR_KEY32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def xor_decrypt(
    words: jax.Array,            # (n, 128) int32 — padded byte stream
    *,
    block_rows: int = 1024,
    interpret: bool = False,
) -> jax.Array:
    """XOR every byte with the stream key (one pass, any stream mix)."""
    rows, lanes = words.shape
    br = min(block_rows, max(rows, 1))
    return pl.pallas_call(
        _xor_kernel,
        grid_spec=pl.GridSpec(
            grid=(pl.cdiv(rows, br),),
            in_specs=[pl.BlockSpec((br, lanes), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
        interpret=interpret,
    )(words)


def _dense_kernel(bm_ref, val_ref, out_ref):
    bm = bm_ref[...]                               # (bf, W) i32 bitmap words
    vals = val_ref[...]                            # (bf, C) i32 value bits
    bf, w = bm.shape
    lane = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 32), 2)
    # np.packbits is MSB-first within each byte while the int32 word is a
    # little-endian byte view, so row 32w+k lives at bit 8*(k//8)+7-(k%8)
    shift = (lane & ~7) + 7 - (lane & 7)
    bits = jax.lax.shift_right_logical(bm[:, :, None], shift) & 1
    bits = bits.reshape(bf, w * 32)                # (bf, rows_pad) presence
    rank = jnp.cumsum(bits, axis=1) - 1            # index of each present row
    idx = jnp.clip(rank, 0, vals.shape[1] - 1)
    gathered = jnp.take_along_axis(vals, idx, axis=1)
    out_ref[...] = jnp.where(bits == 1, gathered, jnp.int32(NAN_BITS))


@functools.partial(jax.jit, static_argnames=("block_feats", "interpret"))
def dense_unpack(
    bitmap_words: jax.Array,     # (F, W) int32 — packbits bytes, LE words
    values: jax.Array,           # (F, C) int32 — present f32 values as bits
    *,
    block_feats: int = 8,
    interpret: bool = False,
) -> jax.Array:
    """Batched presence-bitmap unpack + dense scatter -> (F, W*32) f32 bits
    (NaN bits where absent); the caller slices column 0..rows."""
    feats, w = bitmap_words.shape
    c = values.shape[1]
    bf = min(block_feats, max(feats, 1))
    return pl.pallas_call(
        _dense_kernel,
        grid_spec=pl.GridSpec(
            grid=(pl.cdiv(feats, bf),),
            in_specs=[
                pl.BlockSpec((bf, w), lambda i: (i, 0)),
                pl.BlockSpec((bf, c), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((bf, w * 32), lambda i: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((feats, w * 32), jnp.int32),
        interpret=interpret,
    )(bitmap_words, values)


def _gather_kernel(src_ref, idx_ref, sh_ref, out_ref):
    src = src_ref[...].reshape(-1)                 # (S*128,) source words
    idx = idx_ref[...]                             # (m, 128) low-word index
    sh = sh_ref[...]                               # (m, 128) bit shift {0,8,16,24}
    lo = jax.lax.shift_right_logical(jnp.take(src, idx, axis=0), sh)
    hi = jnp.take(src, idx + 1, axis=0)
    hi = jnp.where(sh == 0, 0, jax.lax.shift_left(hi, (32 - sh) & 31))
    out_ref[...] = lo | hi


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def ragged_gather(
    src: jax.Array,              # (S, 128) int32 — concatenated payload words
    idx: jax.Array,              # (M, 128) int32 — low word index per output
    shift: jax.Array,            # (M, 128) int32 — byte misalignment * 8
    *,
    block_rows: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Gather byte-unaligned word regions: each output word splices two
    neighboring source words at its region's constant misalignment.  The
    caller must pad ``src`` so ``idx + 1`` stays in range."""
    m, lanes = idx.shape
    s, _ = src.shape
    br = min(block_rows, max(m, 1))
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=pl.GridSpec(
            grid=(pl.cdiv(m, br),),
            in_specs=[
                pl.BlockSpec((s, lanes), lambda i: (0, 0)),
                pl.BlockSpec((br, lanes), lambda i: (i, 0)),
                pl.BlockSpec((br, lanes), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec((br, lanes), lambda i: (i, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((m, lanes), jnp.int32),
        interpret=interpret,
    )(src, idx, shift)
