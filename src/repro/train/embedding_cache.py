"""Frequency-aware tiered embedding store (ISSUE 9 — closes the training loop).

DLRM embedding tables dwarf device memory (MTrainS, PAPERS.md), but RecD's
observation — id traffic is heavily Zipf-skewed — means a small device-side
*hot* tier absorbs most lookups.  This store generalizes the ``StripeCache``
tiering machinery to embedding rows:

  * **Hot tier (device HBM)** — a fixed-capacity per-table slot array holding
    exact copies of the most frequently accessed rows.  Fully-hot bags can be
    served by the ``embedding_bag`` Pallas kernel over the compact slot table
    (``pooled(..., use_kernel=True)``).
  * **Host tier (DRAM + flash)** — the authoritative full tables.  A cold row
    fetch is charged to host DRAM when the row is in the host-DRAM working
    set (LRU over ``host_dram_rows``), else to flash — the same
    ``MediaSpec``/``IOStats`` device models the stripe cache uses, so the
    modeled fetch cost lands in the Table-7 style step breakdown.
  * **Admission/eviction** are *frequency-driven*: row access counts are
    tracked with the same ``PopularityTracker`` the storage path uses
    (``core/popularity.py``, one "job" per lookup batch).  A row becomes
    hot-resident once it has been touched in at least ``admit_reads``
    distinct batches; when the hot tier is full the least-popular resident
    is evicted, and only for a strictly more popular newcomer (no thrash
    between equally-warm rows) — the embedding-row analog of the stripe
    cache's ``flash_admit_reads`` pollution guard.
  * **Generation-aware invalidation** mirrors the cache tier's partition
    rewrite semantics: ``bump_generation()`` (call it whenever the
    underlying data generation moves, e.g. a warehouse partition rewrite)
    makes every resident slot stale; a stale slot is never served — the
    next lookup refreshes it from the host copy in place.  Training writes
    (``apply_sparse_update``) update the host tier and refresh resident hot
    copies in the same critical section, so the invariant *hot row bytes ==
    host row bytes* holds at every lock release.

Because hot rows are exact copies and the pooling formula is shared, the
default lookup path is **byte-identical** to a flat single-tier table — the
hot/cold split is a pure optimization (proved by ``tests/test_train_e2e.py``).
The Pallas-kernel path (``use_kernel=True``) is tolerance-tested instead
(kernel accumulation order differs at float precision).

Accounting units: ``hot_hits`` / ``dram_fetches`` / ``flash_fetches`` count
*masked id accesses* (so ``hot_rate`` is traffic-weighted, the quantity the
Zipf skew improves), while the per-tier ``IOStats`` charge one modeled I/O
per *unique* row per lookup batch (a batch fetches each missing row once).
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from typing import Dict, Tuple

import numpy as np

from repro.core.cache.stripe_cache import DRAM_TIER, FLASH_TIER
from repro.core.popularity import PopularityTracker
from repro.core.tectonic import IOStats, MediaSpec
from repro.obs import counter, gauge

# Device-memory model for the hot tier: HBM-class bandwidth, tiny capacity.
HBM_TIER = MediaSpec(name="hbm", seek_ms=0.0002, transfer_MBps=1_200_000.0,
                     capacity_TB=0.000032, power_W=150.0)


@dataclasses.dataclass
class EmbedCacheStats:
    """Tier traffic + residency for the embedding store (REPRO-M001/M002
    contract: counters only grow, gauges are levels)."""

    lookups: int = counter()           # pooled-bag lookup calls
    hot_hits: int = counter()          # masked accesses served from HBM
    dram_fetches: int = counter()      # masked accesses fetched from host DRAM
    flash_fetches: int = counter()     # masked accesses fetched from host flash
    kernel_bags: int = counter()       # fully-hot bags served by the Pallas kernel
    admitted: int = counter()          # rows promoted into the hot tier
    evicted: int = counter()           # rows demoted (capacity pressure)
    refreshed: int = counter()         # hot copies rewritten after a host write
    stale_refreshes: int = counter()   # stale-generation slots refreshed on lookup
    generation: int = counter()        # invalidation epoch (bump-only)
    hot_rows: int = gauge()            # resident rows across all tables
    hot_bytes: int = gauge()           # resident bytes across all tables
    hbm_io: IOStats = counter(factory=IOStats)
    dram_io: IOStats = counter(factory=IOStats)
    flash_io: IOStats = counter(factory=IOStats)

    @property
    def hot_rate(self) -> float:
        """Fraction of masked id accesses served from the device tier."""
        n = self.hot_hits + self.dram_fetches + self.flash_fetches
        return self.hot_hits / n if n else 0.0


class TieredEmbeddingStore:
    """Hot(HBM)/cold(host DRAM+flash) embedding tables with frequency-driven
    admission and generation-aware invalidation.  Thread-safe: every public
    method owns ``self._lock`` for its full critical section.

    ``hot_rows_per_table=0`` degenerates to a flat single-tier table (every
    lookup served from host DRAM) — the reference the differential tests
    compare against.
    """

    def __init__(
        self,
        tables: np.ndarray,                  # (T, V, E) f32 — copied, authoritative
        hot_rows_per_table: int,
        *,
        admit_reads: int = 2,
        host_dram_rows: int = 0,             # 0 = every cold fetch is DRAM-resident
        hot_media: MediaSpec = HBM_TIER,
        dram_media: MediaSpec = DRAM_TIER,
        flash_media: MediaSpec = FLASH_TIER,
    ):
        tables = np.asarray(tables, np.float32)
        if tables.ndim != 3:
            raise ValueError(f"tables must be (T, V, E), got {tables.shape}")
        self._lock = threading.Lock()
        t, v, e = tables.shape
        self.num_tables, self.vocab, self.embed_dim = t, v, e
        self.hot_capacity = int(hot_rows_per_table)
        self.admit_reads = int(admit_reads)
        self.row_bytes = e * 4
        self._hot_media = hot_media
        self._dram_media = dram_media
        self._flash_media = flash_media
        self.stats = EmbedCacheStats()

        self._host = tables.copy()                        # authoritative rows
        self._acc = np.zeros((t, v), np.float32)          # row-wise AdaGrad state
        h = max(self.hot_capacity, 1)
        self._hot = np.zeros((t, h, e), np.float32)       # device-side slot table
        self._slot_map = np.full((t, v), -1, np.int32)    # row -> slot (-1 cold)
        self._row_of = np.full((t, h), -1, np.int32)      # slot -> row
        self._slot_gen = np.zeros((t, h), np.int64)       # generation at admit
        self._resident = np.zeros(t, np.int32)            # slots in use per table
        self._generation = 0
        # id-frequency stats: one PopularityTracker "job" per lookup batch,
        # feature id = flat row id (t * V + row) — core/popularity.py reused
        # as the admission signal, exactly like flash_admit_reads.
        self._popularity = PopularityTracker()
        # host-DRAM working set over flat row ids (LRU); rows outside it
        # charge the flash MediaSpec on a cold fetch.
        self._host_dram: "OrderedDict[int, None]" = OrderedDict()
        self._host_dram_rows = int(host_dram_rows)

    # -- introspection ----------------------------------------------------

    @property
    def generation(self) -> int:
        with self._lock:
            return self._generation

    def host_tables(self) -> np.ndarray:
        """Copy of the authoritative (T, V, E) tables."""
        with self._lock:
            return self._host.copy()

    def adagrad_state(self) -> np.ndarray:
        with self._lock:
            return self._acc.copy()

    def hot_residency(self) -> Dict[int, np.ndarray]:
        """Per-table sorted array of current-generation hot row ids."""
        with self._lock:
            out = {}
            for ti in range(self.num_tables):
                slots = np.nonzero(self._row_of[ti] >= 0)[0]
                fresh = slots[self._slot_gen[ti, slots] == self._generation]
                out[ti] = np.sort(self._row_of[ti, fresh])
            return out

    def row_count(self, ti: int, row: int) -> int:
        """Popularity count (lookup batches that touched the row)."""
        with self._lock:
            return self._count_locked(ti, row)

    def _count_locked(self, ti: int, row: int) -> int:
        return self._popularity.read_count_by_feature.get(
            ti * self.vocab + int(row), 0
        )

    # -- invalidation ------------------------------------------------------

    def bump_generation(self) -> int:
        """Partition-rewrite analog: every resident slot becomes stale and
        is refreshed from the host copy before its next serve."""
        with self._lock:
            self._generation += 1
            self.stats.generation += 1
            return self._generation

    def load_tables(self, tables: np.ndarray) -> int:
        """Replace the authoritative host tables and bump the generation in
        one critical section — the embedding-side partition rewrite (table
        reload after an upstream rewrite, or a checkpoint restore).  A
        lookup racing this call sees either the old tables or the new ones
        in full, never a mix, and no lookup after the bump can be served a
        pre-reload hot copy."""
        tables = np.asarray(tables, np.float32)
        if tables.shape != self._host.shape:
            raise ValueError(
                f"tables shape {tables.shape} != {self._host.shape}"
            )
        with self._lock:
            self._host[...] = tables
            self._acc[...] = 0.0
            self._generation += 1
            self.stats.generation += 1
            return self._generation

    # -- lookup ------------------------------------------------------------

    def pooled(self, ids: np.ndarray, mask: np.ndarray, *,
               use_kernel: bool = False) -> np.ndarray:
        """Mean-pooled bags: (B, T, L) ids/mask -> (B, T, E) f32.

        Default path is byte-identical to pooling over a flat table; with
        ``use_kernel=True`` fully-hot bags go through the ``embedding_bag``
        Pallas kernel on the compact hot-slot table instead.
        """
        ids = np.asarray(ids)
        mask = np.asarray(mask, np.float32)
        if ids.shape != mask.shape or ids.ndim != 3:
            raise ValueError(f"ids/mask must both be (B, T, L), got "
                             f"{ids.shape} vs {mask.shape}")
        with self._lock:
            self.stats.lookups += 1
            rows, slot = self._gather_locked(ids, mask > 0.0)
            denom = np.maximum(mask.sum(axis=2), 1.0)
            pooled = (
                (rows * mask[..., None]).sum(axis=2) / denom[..., None]
            ).astype(np.float32)
            if use_kernel and self.hot_capacity > 0:
                pooled = self._kernel_pooled_locked(pooled, slot, mask)
            return pooled

    def _gather_locked(self, ids: np.ndarray,
                       m: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Serve (B, T, L) ids from hot/host tiers; refresh stale slots,
        account traffic and run frequency-driven admission.  Returns the
        row tensor (B, T, L, E) and per-position hot slot (-1 = cold)."""
        b, t, l = ids.shape
        ids = np.clip(ids, 0, self.vocab - 1).astype(np.int64)
        rows = np.empty((b, t, l, self.embed_dim), np.float32)
        slot_out = np.full((b, t, l), -1, np.int32)
        job_bytes: Dict[int, float] = {}
        cold_unique: Dict[int, np.ndarray] = {}
        for ti in range(t):
            idt = ids[:, ti, :]
            slot = self._slot_map[ti, idt]                       # (B, L)
            fresh = slot >= 0
            if fresh.any():
                stale = fresh.copy()
                stale[fresh] = (
                    self._slot_gen[ti, slot[fresh]] != self._generation
                )
                if stale.any():
                    self._refresh_stale_locked(ti, np.unique(idt[stale]))
            rows[:, ti] = self._host[ti, idt]
            if fresh.any():
                rows[:, ti][fresh] = self._hot[ti, slot[fresh]]
            slot_out[:, ti] = np.where(fresh, slot, -1)

            mt = m[:, ti, :]
            self.stats.hot_hits += int((fresh & mt).sum())
            cold = ~fresh & mt
            u_rows, u_counts = np.unique(idt[cold], return_counts=True)
            cold_unique[ti] = u_rows
            for r, n in zip(u_rows, u_counts):
                tier = self._host_fetch_locked(ti * self.vocab + int(r))
                if tier == "dram":
                    self.stats.dram_fetches += int(n)
                else:
                    self.stats.flash_fetches += int(n)
            all_rows, all_counts = np.unique(idt[mt], return_counts=True)
            for r, n in zip(all_rows, all_counts):
                flat = ti * self.vocab + int(r)
                job_bytes[flat] = (
                    job_bytes.get(flat, 0.0) + int(n) * self.row_bytes
                )
        if job_bytes:
            self._popularity.record_job(job_bytes)
        if self.hot_capacity > 0:
            for ti, u_rows in cold_unique.items():
                for r in u_rows:
                    self._maybe_admit_locked(ti, int(r))
        return rows, slot_out

    def _refresh_stale_locked(self, ti: int, stale_rows: np.ndarray) -> None:
        """Re-copy stale-generation hot rows from the host tier in place —
        a stale slot is never served (the generation invariant)."""
        slots = self._slot_map[ti, stale_rows]
        self._hot[ti, slots] = self._host[ti, stale_rows]
        self._slot_gen[ti, slots] = self._generation
        n = len(stale_rows)
        self.stats.stale_refreshes += n
        self.stats.hbm_io.record(n * self.row_bytes, self._hot_media)

    def _host_fetch_locked(self, flat_row: int) -> str:
        """Model one host-tier row fetch; returns the serving tier name."""
        if self._host_dram_rows <= 0 or flat_row in self._host_dram:
            if self._host_dram_rows > 0:
                self._host_dram.move_to_end(flat_row)
            self.stats.dram_io.record(self.row_bytes, self._dram_media)
            return "dram"
        self.stats.flash_io.record(self.row_bytes, self._flash_media)
        self._host_dram[flat_row] = None
        if len(self._host_dram) > self._host_dram_rows:
            self._host_dram.popitem(last=False)
        return "flash"

    def _maybe_admit_locked(self, ti: int, row: int) -> None:
        """Admit ``row`` into the hot tier once its popularity count crosses
        ``admit_reads``; under capacity pressure the least-popular resident
        is evicted, and only for a strictly more popular newcomer."""
        if self._slot_map[ti, row] >= 0:
            return
        count = self._count_locked(ti, row)
        if count < self.admit_reads:
            return
        if self._resident[ti] < self.hot_capacity:
            slot = int(np.nonzero(self._row_of[ti] < 0)[0][0])
            self._resident[ti] += 1
        else:
            res_rows = self._row_of[ti, :self.hot_capacity]
            counts = np.array(
                [self._count_locked(ti, int(r)) for r in res_rows]
            )
            victim_slot = int(np.argmin(counts))
            if counts[victim_slot] >= count:
                return                    # newcomer is not strictly hotter
            self._slot_map[ti, res_rows[victim_slot]] = -1
            self.stats.evicted += 1
            self.stats.hot_rows -= 1
            self.stats.hot_bytes -= self.row_bytes
            slot = victim_slot
        self._hot[ti, slot] = self._host[ti, row]
        self._slot_map[ti, row] = slot
        self._row_of[ti, slot] = row
        self._slot_gen[ti, slot] = self._generation
        self.stats.admitted += 1
        self.stats.hot_rows += 1
        self.stats.hot_bytes += self.row_bytes
        self.stats.hbm_io.record(self.row_bytes, self._hot_media)

    def _kernel_pooled_locked(self, pooled: np.ndarray, slot: np.ndarray,
                              mask: np.ndarray) -> np.ndarray:
        """Re-serve fully-hot bags through the ``embedding_bag`` Pallas
        kernel over the compact (H, E) slot table.  Shapes stay (B, L) per
        table (non-qualifying bags padded) so the kernel compiles once."""
        import jax.numpy as jnp

        from repro.kernels import ops

        m = mask > 0.0
        ok = np.all((slot >= 0) | ~m, axis=2) & m.any(axis=2)    # (B, T)
        for ti in range(self.num_tables):
            sel = np.nonzero(ok[:, ti])[0]
            if sel.size == 0:
                continue
            slot_ids = np.where(
                m[:, ti] & (slot[:, ti] >= 0), slot[:, ti], 0
            ).astype(np.int32)
            kmask = np.where(ok[:, ti, None], mask[:, ti], 0.0)
            out = ops.embedding_bag(
                jnp.asarray(self._hot[ti]), jnp.asarray(slot_ids),
                jnp.asarray(kmask), use_pallas=True,
            )
            pooled[sel, ti] = np.asarray(out)[sel]
            self.stats.kernel_bags += int(sel.size)
        return pooled

    # -- training writes ---------------------------------------------------

    def apply_sparse_update(self, dpooled: np.ndarray, ids: np.ndarray,
                            mask: np.ndarray, lr: float,
                            eps: float = 1e-8) -> None:
        """Row-wise AdaGrad on the host tier — the numpy mirror of
        ``DLRM.sparse_table_update`` — then refresh resident hot copies of
        every touched row inside the same critical section (write
        invalidation: the hot tier can never serve a pre-update row)."""
        dpooled = np.asarray(dpooled, np.float32)        # (B, T, E)
        mask = np.asarray(mask, np.float32)              # (B, T, L)
        with self._lock:
            ids = np.clip(
                np.asarray(ids), 0, self.vocab - 1
            ).astype(np.int64)                           # (B, T, L)
            denom = np.maximum(mask.sum(axis=2), 1.0)    # (B, T)
            w = mask / denom[..., None]                  # (B, T, L)
            rg = (
                dpooled[:, :, None, :] * w[..., None]
            ).reshape(-1, self.embed_dim).astype(np.float32)
            flat = (
                ids + np.arange(self.num_tables)[None, :, None] * self.vocab
            ).reshape(-1)
            g2 = np.mean(np.square(rg), axis=-1)
            acc_flat = self._acc.reshape(-1)
            np.add.at(acc_flat, flat, g2)
            scale = (lr / np.sqrt(acc_flat[flat] + eps)).astype(np.float32)
            host_flat = self._host.reshape(-1, self.embed_dim)
            np.add.at(host_flat, flat, -scale[:, None] * rg)
            for ti in range(self.num_tables):
                touched = np.unique(ids[:, ti][mask[:, ti] > 0.0])
                slots = self._slot_map[ti, touched]
                res = touched[slots >= 0]
                if res.size:
                    rs = self._slot_map[ti, res]
                    self._hot[ti, rs] = self._host[ti, res]
                    self._slot_gen[ti, rs] = self._generation
                    self.stats.refreshed += int(res.size)
                    self.stats.hbm_io.record(
                        int(res.size) * self.row_bytes, self._hot_media
                    )


def make_store_for_model(model_cfg, hot_rows_per_table: int, *,
                         seed: int = 0, **kwargs) -> TieredEmbeddingStore:
    """Build a store with freshly initialized tables matching a
    ``DLRMConfig`` (normal(0, 0.01), the embedding init scale)."""
    rng = np.random.default_rng(seed)
    tables = rng.normal(
        0.0, 0.01,
        (model_cfg.num_tables, model_cfg.vocab_per_table, model_cfg.embed_dim),
    ).astype(np.float32)
    return TieredEmbeddingStore(tables, hot_rows_per_table, **kwargs)
