from repro.core.cache.dedup import CacheKey, DedupIndex, DedupStats, stripe_digest
from repro.core.cache.stripe_cache import (
    ANON_TENANT,
    DRAM_TIER,
    FLASH_TIER,
    CacheLookup,
    StripeCache,
    TenantStats,
    TierStats,
    iops_per_watt,
)
from repro.core.cache.tenancy import TenantPolicy, TenantShare
