"""Fixture tests for ``scripts/bench_diff.py`` (benchmark trend gate)."""
from __future__ import annotations

import importlib.util
import json
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

_spec = importlib.util.spec_from_file_location(
    "bench_diff", REPO / "scripts" / "bench_diff.py"
)
bench_diff = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_diff)


def _report(**sections) -> dict:
    return {
        "mode": "quick",
        "sections": {
            name: {
                "status": body.get("status", "ok"),
                "metrics": [
                    {"name": row[0], "us_per_call": row[1],
                     "derived": row[2] if len(row) > 2 else ""}
                    for row in body.get("metrics", [])
                ],
            }
            for name, body in sections.items()
        },
    }


def _write(tmp_path, name, report) -> str:
    p = tmp_path / name
    p.write_text(json.dumps(report))
    return str(p)


def test_clean_diff_exits_zero(tmp_path, capsys):
    old = _report(dpp={"metrics": [("dpp.extract", 100.0)]})
    new = _report(dpp={"metrics": [("dpp.extract", 110.0)]})
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    assert rc == 0
    assert "ok" in capsys.readouterr().out


def test_slowdown_past_threshold_exits_nonzero(tmp_path, capsys):
    old = _report(dpp={"metrics": [("dpp.extract", 100.0)]})
    new = _report(dpp={"metrics": [("dpp.extract", 140.0)]})
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "REGRESSION" in out and "dpp.extract" in out


def test_threshold_is_configurable(tmp_path):
    old = _report(dpp={"metrics": [("dpp.extract", 100.0)]})
    new = _report(dpp={"metrics": [("dpp.extract", 140.0)]})
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new),
                          "--threshold", "0.5"])
    assert rc == 0


def test_status_flip_to_failed_is_regression(tmp_path, capsys):
    old = _report(engine={"metrics": []})
    new = _report(engine={"status": "failed: boom", "metrics": []})
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    assert rc == 1
    assert "ok -> failed" in capsys.readouterr().out


def test_added_and_removed_rows_are_notes_not_failures(tmp_path, capsys):
    old = _report(dpp={"metrics": [("dpp.gone", 50.0)]})
    new = _report(dpp={"metrics": [("dpp.fresh", 50.0)]},
                  obs={"metrics": [("obs.null_span", 0.3)]})
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    out = capsys.readouterr().out
    assert rc == 0
    assert "dpp.fresh" in out and "dpp.gone" in out and "note" in out


def test_zero_baseline_rows_are_skipped(tmp_path):
    # flag-style rows emit 0.0 us; they must never divide-by-zero or flag
    old = _report(faults={"metrics": [("faults.stall_driven_scaleup", 0.0)]})
    new = _report(faults={"metrics": [("faults.stall_driven_scaleup", 9.9)]})
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    assert rc == 0


# -- derived-metric guards (ISSUE 9: hot-rate floor, stall-share ceiling) ---


def _e2e_report(tiered: float, data_pct: float, embed_pct: float) -> dict:
    return _report(train_e2e={"metrics": [
        ("train_e2e.hot_rate", 100.0, f"tiered={tiered:.3f} pinned=0.200"),
        ("train_e2e.step_breakdown", 100.0,
         f"data_pct={data_pct:.2f} embed_pct={embed_pct:.2f} "
         f"compute_pct={100 - data_pct - embed_pct:.2f}"),
    ]})


def test_hot_rate_drop_past_floor_is_regression(tmp_path, capsys):
    old = _e2e_report(tiered=0.75, data_pct=60.0, embed_pct=14.0)
    new = _e2e_report(tiered=0.60, data_pct=60.0, embed_pct=14.0)
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "REGRESSION" in out and "hot_rate:tiered" in out


def test_stall_share_rise_past_ceiling_is_regression(tmp_path, capsys):
    old = _e2e_report(tiered=0.75, data_pct=60.0, embed_pct=14.0)
    new = _e2e_report(tiered=0.75, data_pct=72.0, embed_pct=14.0)
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "step_breakdown:data_pct" in out


def test_derived_within_tolerance_passes(tmp_path):
    # small wobble on every guarded key (and an improved hot rate) is fine
    old = _e2e_report(tiered=0.75, data_pct=60.0, embed_pct=14.0)
    new = _e2e_report(tiered=0.78, data_pct=64.0, embed_pct=16.0)
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    assert rc == 0


def test_derived_guard_skipped_when_row_absent(tmp_path):
    # a baseline without the e2e section must not trip the guards
    old = _report(dpp={"metrics": [("dpp.extract", 100.0)]})
    new = _e2e_report(tiered=0.10, data_pct=90.0, embed_pct=5.0)
    new["sections"]["dpp"] = _report(
        dpp={"metrics": [("dpp.extract", 100.0)]}
    )["sections"]["dpp"]
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    assert rc == 0


# -- derived-metric guards (ISSUE 10: extract cut / launch amortization) ----


def _extract_report(amort: float, cut: float) -> dict:
    # derived values carry "x" ratio suffixes exactly as bench_extract.py
    # emits them; the parser must still guard the numbers underneath
    return _report(extract={"metrics": [
        ("extract.numpy_per_stream", 5000.0, "launches=1088 streams=513"),
        ("extract.fused_batched", 3600.0,
         f"launches=3 amortization={amort:.0f}x extract_cut={cut:.2f}x"),
    ]})


def test_extract_cut_drop_past_floor_is_regression(tmp_path, capsys):
    old = _extract_report(amort=342, cut=1.40)
    new = _extract_report(amort=342, cut=0.90)
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "REGRESSION" in out and "fused_batched:extract_cut" in out


def test_amortization_collapse_is_regression(tmp_path, capsys):
    old = _extract_report(amort=342, cut=1.40)
    new = _extract_report(amort=40, cut=1.40)
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "fused_batched:amortization" in out


def test_extract_wobble_within_tolerance_passes(tmp_path):
    # a small cut dip and amortization drift stay under the floors
    old = _extract_report(amort=342, cut=1.40)
    new = _extract_report(amort=300, cut=1.25)
    rc = bench_diff.main([_write(tmp_path, "old.json", old),
                          _write(tmp_path, "new.json", new)])
    assert rc == 0
