"""DLRM — the paper's model family (Naumov et al.), in JAX.

Embedding tables are table-sharded over the "model" axis (the RecShard-style
layout the paper cites); dense/top MLPs are small and replicated; the batch
is data-parallel.  Sparse features arrive from the DSI pipeline as padded
(B, T, L) id tensors + lengths — the materialized-tensor format DPP Workers
produce.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, abstract_params, init_params


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm"
    family: str = "dlrm"
    num_dense: int = 504                 # RM3-like defaults (Table 4)
    num_tables: int = 42
    vocab_per_table: int = 100_000
    embed_dim: int = 128
    max_ids_per_feature: int = 32        # avg sparse length ~20-26 (Table 5)
    bottom_mlp: Tuple[int, ...] = (512, 256, 128)
    top_mlp: Tuple[int, ...] = (1024, 1024, 512, 256, 1)
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    sub_quadratic = True
    attention_free = True

    @property
    def num_layers(self) -> int:  # for generic tooling
        return len(self.bottom_mlp) + len(self.top_mlp)


def _mlp_specs(dims, dtype) -> Dict[str, Any]:
    specs = {}
    for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
        specs[f"w{i}"] = ParamSpec((din, dout), ("embed", "mlp"), dtype, "scaled")
        specs[f"b{i}"] = ParamSpec((dout,), (None,), dtype, "zeros")
    return specs


def _mlp_apply(params: Dict[str, Any], x: jax.Array, n: int, last_linear: bool) -> jax.Array:
    for i in range(n):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if not (last_linear and i == n - 1):
            x = jax.nn.relu(x)
    return x


class DLRM:
    def __init__(self, cfg: DLRMConfig):
        self.cfg = cfg

    def param_specs(self) -> Dict[str, Any]:
        c = self.cfg
        bot_dims = (c.num_dense,) + c.bottom_mlp
        n_pairs = (c.num_tables + 1) * c.num_tables // 2
        top_in = c.bottom_mlp[-1] + n_pairs
        top_dims = (top_in,) + c.top_mlp
        return {
            "tables": ParamSpec(
                (c.num_tables, c.vocab_per_table, c.embed_dim),
                ("expert", "vocab", None),   # table-sharded over "model" via "expert"
                c.param_dtype,
                "normal",
            ),
            "bottom": _mlp_specs(bot_dims, c.param_dtype),
            "top": _mlp_specs(top_dims, c.param_dtype),
        }

    def init(self, key: jax.Array) -> Dict[str, Any]:
        return init_params(self.param_specs(), key)

    def abstract(self) -> Dict[str, Any]:
        return abstract_params(self.param_specs())

    def input_specs(self, batch: int, seq: int = 0, mode: str = "train") -> Dict[str, Any]:
        c = self.cfg
        specs = {
            "dense": jax.ShapeDtypeStruct((batch, c.num_dense), jnp.float32),
            "sparse_ids": jax.ShapeDtypeStruct(
                (batch, c.num_tables, c.max_ids_per_feature), jnp.int32
            ),
            "sparse_mask": jax.ShapeDtypeStruct(
                (batch, c.num_tables, c.max_ids_per_feature), jnp.float32
            ),
        }
        if mode == "train":
            specs["label"] = jax.ShapeDtypeStruct((batch,), jnp.float32)
        return specs

    def forward(self, params: Dict[str, Any], batch: Dict[str, jax.Array]) -> jax.Array:
        c = self.cfg
        dense = batch["dense"].astype(c.compute_dtype)
        ids, mask = batch["sparse_ids"], batch["sparse_mask"]

        bot = _mlp_apply(params["bottom"], dense, len(c.bottom_mlp), last_linear=False)

        # pooled embedding-bag per table; kernels/embedding_bag is the Pallas
        # fast path, this is the portable XLA gather+segsum form.
        tables = params["tables"]                               # (T, V, E)
        emb = jnp.take_along_axis(
            tables[None, :, :, :],
            ids[..., None].clip(0, c.vocab_per_table - 1),
            axis=2,
        )                                                       # (B, T, L, E)
        pooled = jnp.sum(emb * mask[..., None], axis=2) / jnp.maximum(
            jnp.sum(mask, axis=2, keepdims=False)[..., None], 1.0
        )                                                       # (B, T, E)

        # pairwise dot interaction among [bottom, tables...]
        feats = jnp.concatenate([bot[:, None, :], pooled], axis=1)  # (B, T+1, E)
        inter = jnp.einsum("bte,bse->bts", feats, feats)
        iu, ju = jnp.triu_indices(feats.shape[1], k=1)
        flat = inter[:, iu, ju]                                  # (B, n_pairs)

        top_in = jnp.concatenate([bot, flat], axis=-1)
        logit = _mlp_apply(params["top"], top_in, len(self.cfg.top_mlp), last_linear=True)
        return logit[:, 0]

    def loss(self, params: Dict[str, Any], batch: Dict[str, jax.Array]) -> jax.Array:
        logit = self.forward(params, batch).astype(jnp.float32)
        label = batch["label"]
        return jnp.mean(
            jnp.maximum(logit, 0) - logit * label + jnp.log1p(jnp.exp(-jnp.abs(logit)))
        )

    # -- sparse training path (§Perf hillclimb H-DLRM) ----------------------
    #
    # The naive train step autodiffs through the embedding gather, producing
    # a DENSE (T, V, E) table gradient + a dense Adam update: ~40 GB/device
    # of optimizer traffic per step for rows that are 99.98% untouched
    # (measured — see EXPERIMENTS.md).  Production DLRM trains embeddings
    # with row-wise AdaGrad on only the touched rows; this path computes
    # d(pooled) by autodiff, expands it to per-row gradients analytically,
    # and scatter-updates just those rows.

    def pooled_embeddings(self, tables: jax.Array, batch: Dict[str, jax.Array]) -> jax.Array:
        c = self.cfg
        ids, mask = batch["sparse_ids"], batch["sparse_mask"]
        emb = jnp.take_along_axis(
            tables[None, :, :, :],
            ids[..., None].clip(0, c.vocab_per_table - 1),
            axis=2,
        )
        return jnp.sum(emb * mask[..., None], axis=2) / jnp.maximum(
            jnp.sum(mask, axis=2)[..., None], 1.0
        )

    def forward_from_pooled(self, mlp_params, pooled, batch) -> jax.Array:
        c = self.cfg
        dense = batch["dense"].astype(c.compute_dtype)
        bot = _mlp_apply(mlp_params["bottom"], dense, len(c.bottom_mlp), last_linear=False)
        feats = jnp.concatenate([bot[:, None, :], pooled], axis=1)
        inter = jnp.einsum("bte,bse->bts", feats, feats)
        iu, ju = jnp.triu_indices(feats.shape[1], k=1)
        top_in = jnp.concatenate([bot, inter[:, iu, ju]], axis=-1)
        return _mlp_apply(mlp_params["top"], top_in, len(c.top_mlp), last_linear=True)[:, 0]

    def loss_from_pooled(self, mlp_params, pooled, batch) -> jax.Array:
        logit = self.forward_from_pooled(mlp_params, pooled, batch).astype(jnp.float32)
        label = batch["label"]
        return jnp.mean(
            jnp.maximum(logit, 0) - logit * label + jnp.log1p(jnp.exp(-jnp.abs(logit)))
        )

    def sparse_table_update(
        self,
        tables: jax.Array,          # (T, V, E)
        acc: jax.Array,             # (T, V) row-wise AdaGrad accumulator
        dpooled: jax.Array,         # (B, T, E)
        batch: Dict[str, jax.Array],
        lr: jax.Array,
        eps: float = 1e-8,
    ):
        c = self.cfg
        ids = batch["sparse_ids"].clip(0, c.vocab_per_table - 1)   # (B,T,L)
        mask = batch["sparse_mask"]
        denom = jnp.maximum(jnp.sum(mask, axis=2), 1.0)            # (B,T)
        w = (mask / denom[..., None])                              # (B,T,L)
        row_grads = dpooled[:, :, None, :] * w[..., None]          # (B,T,L,E)

        b, t, l = ids.shape
        flat_ids = (ids + jnp.arange(t)[None, :, None] * c.vocab_per_table).reshape(-1)
        rg = row_grads.reshape(-1, c.embed_dim)

        acc_flat = acc.reshape(-1)
        g2 = jnp.mean(jnp.square(rg), axis=-1)                     # row grad energy
        acc_flat = acc_flat.at[flat_ids].add(g2)
        scale = lr / jnp.sqrt(acc_flat[flat_ids] + eps)            # (B*T*L,)
        tables_flat = tables.reshape(-1, c.embed_dim)
        tables_flat = tables_flat.at[flat_ids].add(
            (-scale[:, None] * rg).astype(tables.dtype)
        )
        return (
            tables_flat.reshape(tables.shape),
            acc_flat.reshape(acc.shape),
        )

    # -- model-parallel sharded table ops (shard_map over the vocab shard) --
    #
    # Forward gather and sparse update with V-sharded tables: ids, masks and
    # d(pooled) are tiny (≈7 MB/step) and are replicated to every model rank;
    # each rank gathers/scatters ONLY rows in its own vocab range (out-of-
    # range rows land in a spill slot).  Wire cost per step: one all-gather
    # of the ids/grads + one psum of pooled (B,T,E) — vs the 5 GB dense
    # table-delta all-reduce the naive scatter lowers to.

    def _vocab_shards(self, mesh):
        n = mesh.shape["model"]
        return n if (self.cfg.vocab_per_table % n == 0) else 1

    def pooled_embeddings_sharded(self, tables, batch, mesh):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        c = self.cfg
        n = self._vocab_shards(mesh)
        if n == 1:
            return self.pooled_embeddings(tables, batch)
        v_loc = c.vocab_per_table // n

        def body(tb, ids, mask):
            rank = jax.lax.axis_index("model")
            lo = rank * v_loc
            ids = ids.clip(0, c.vocab_per_table - 1)
            local = ids - lo
            sel = (local >= 0) & (local < v_loc)
            safe = jnp.where(sel, local, 0)
            emb = jnp.take_along_axis(tb[None], safe[..., None], axis=2)   # (B,T,L,E)
            w = (mask * sel).astype(tb.dtype)
            part = jnp.sum(emb * w[..., None], axis=2)
            part = jax.lax.psum(part, "model")
            denom = jnp.maximum(jnp.sum(mask, axis=2), 1.0)
            return part / denom[..., None].astype(part.dtype)

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(None, "model", None), P(None, None, None), P(None, None, None)),
            out_specs=P(None, None, None),
            check_rep=False,
        )(tables, batch["sparse_ids"], batch["sparse_mask"])

    def sparse_table_update_sharded(self, tables, acc, dpooled, batch, lr, mesh, eps=1e-8):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        c = self.cfg
        n = self._vocab_shards(mesh)
        if n == 1:
            return self.sparse_table_update(tables, acc, dpooled, batch, lr, eps)
        v_loc = c.vocab_per_table // n

        def body(tb, ac, dp, ids, mask):
            rank = jax.lax.axis_index("model")
            lo = rank * v_loc
            ids = ids.clip(0, c.vocab_per_table - 1)
            local = ids - lo
            sel = (local >= 0) & (local < v_loc)
            safe = jnp.where(sel, local, v_loc)        # spill slot
            denom = jnp.maximum(jnp.sum(mask, axis=2), 1.0)
            w = mask / denom[..., None]
            rg = (dp[:, :, None, :] * w[..., None]).reshape(-1, c.embed_dim)

            b, t, l = ids.shape
            flat = (safe + jnp.arange(t)[None, :, None] * (v_loc + 1)).reshape(-1)
            tb_pad = jnp.concatenate(
                [tb, jnp.zeros((t, 1, c.embed_dim), tb.dtype)], axis=1
            ).reshape(-1, c.embed_dim)
            ac_pad = jnp.concatenate(
                [ac, jnp.zeros((t, 1), ac.dtype)], axis=1
            ).reshape(-1)

            g2 = jnp.mean(jnp.square(rg), axis=-1)
            ac_pad = ac_pad.at[flat].add(g2)
            scale = lr / jnp.sqrt(ac_pad[flat] + eps)
            tb_pad = tb_pad.at[flat].add((-scale[:, None] * rg).astype(tb.dtype))
            tb_new = tb_pad.reshape(t, v_loc + 1, c.embed_dim)[:, :v_loc]
            ac_new = ac_pad.reshape(t, v_loc + 1)[:, :v_loc]
            return tb_new, ac_new

        return shard_map(
            body, mesh=mesh,
            in_specs=(P(None, "model", None), P(None, "model"),
                      P(None, None, None), P(None, None, None), P(None, None, None)),
            out_specs=(P(None, "model", None), P(None, "model")),
            check_rep=False,
        )(tables, acc, dpooled, batch["sparse_ids"], batch["sparse_mask"])

    def normalized_entropy(self, params, batch) -> jax.Array:
        """The paper's model-quality metric (He et al. 2014)."""
        logit = self.forward(params, batch).astype(jnp.float32)
        label = batch["label"]
        nll = jnp.mean(
            jnp.maximum(logit, 0) - logit * label + jnp.log1p(jnp.exp(-jnp.abs(logit)))
        )
        p = jnp.clip(jnp.mean(label), 1e-6, 1 - 1e-6)
        base = -(p * jnp.log(p) + (1 - p) * jnp.log(1 - p))
        return nll / base
