"""Tests for the telemetry layer (``repro.obs``): span tracing, metric
metadata + registry, stall-attribution report, and the byte-for-byte
controller-parity contract the registry migration promised.
"""
from __future__ import annotations

import dataclasses
import json
import threading

import pytest

from repro.core.dpp.autoscale import (
    ElasticController, ElasticPolicy, Observation, observation_from_delta,
)
from repro.obs import (
    NULL_TRACER, MetricsRegistry, NullTracer, Snapshot, Tracer,
    counter, gauge, merge_metrics,
)
from repro.obs.meta import flatten_metrics
from repro.obs.report import build_report, check
from repro.obs.report import main as report_main
from repro.obs.smoke import run_smoke


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# -- metric metadata + merge --------------------------------------------------


@dataclasses.dataclass
class _Inner:
    ios: int = counter()
    level: int = gauge()


@dataclasses.dataclass
class _Outer:
    name: str = "shard"                    # identity label: never merged
    done: int = counter()
    sizes: list = counter(factory=list)
    peak: int = gauge(merge="max")
    last_seen: float = gauge(0.0, merge="last")
    inner: _Inner = counter(factory=_Inner)


def test_merge_metrics_by_declared_kind():
    a = _Outer(done=2, sizes=[1], peak=5, last_seen=1.0,
               inner=_Inner(ios=3, level=10))
    b = _Outer(name="other", done=3, sizes=[2, 3], peak=4, last_seen=9.0,
               inner=_Inner(ios=4, level=1))
    out = merge_metrics(a, b)
    assert out is a
    assert a.done == 5                      # counter: sum
    assert a.sizes == [1, 2, 3]             # list counter: extend
    assert a.peak == 5                      # gauge max
    assert a.last_seen == 9.0               # gauge last
    assert a.name == "shard"                # non-metric field untouched
    assert a.inner.ios == 7 and a.inner.level == 11   # nested recursion


def test_merge_metrics_rejects_type_mismatch():
    with pytest.raises(TypeError):
        merge_metrics(_Outer(), _Inner())


def test_gauge_rejects_unknown_policy():
    with pytest.raises(ValueError):
        gauge(merge="median")


def test_flatten_descends_and_skips_non_scalars():
    flat = {n: (k, v) for n, k, v in flatten_metrics(_Outer(done=2), "t.")}
    assert flat["t.done"] == ("counter", 2)
    assert flat["t.inner.ios"] == ("counter", 0)
    assert flat["t.peak"] == ("gauge", 0)
    assert "t.sizes" not in flat            # lists are not snapshot scalars
    assert "t.name" not in flat


def test_worker_metrics_merge_is_metadata_driven():
    from repro.core.dpp.worker import WorkerMetrics

    a = WorkerMetrics(rows_done=10, extract_s=1.5)
    a.merge(WorkerMetrics(rows_done=5, extract_s=0.5))
    assert a.rows_done == 15 and a.extract_s == 2.0


# -- tracer -------------------------------------------------------------------


def test_span_durations_from_injected_clock():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with tr.span("storage.read", tenant="a") as sp:
        clock.advance(0.25)
        sp.set(bytes=128)
    [s] = tr.spans()
    assert s.name == "storage.read"
    assert s.duration == pytest.approx(0.25)
    assert s.labels == {"tenant": "a", "bytes": 128}
    assert s.parent is None


def test_nested_spans_record_parent_and_survive_exceptions():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    with pytest.raises(RuntimeError):
        with tr.span("session.run"):
            clock.advance(1.0)
            with tr.span("extract.decode"):
                clock.advance(0.5)
                raise RuntimeError("boom")
    names = {s.name: s for s in tr.spans()}
    assert names["extract.decode"].parent == "session.run"
    assert names["session.run"].parent is None
    assert tr.open_spans() == 0             # both closed despite the raise


def test_record_inherits_current_thread_parent():
    tr = Tracer(clock=FakeClock())
    with tr.span("session.run"):
        tr.record("load.materialize", 1.0, 2.0, split=3)
    rec = [s for s in tr.spans() if s.name == "load.materialize"][0]
    assert rec.parent == "session.run" and rec.labels == {"split": 3}


def test_span_nesting_is_per_thread():
    clock = FakeClock()
    tr = Tracer(clock=clock)
    done = threading.Barrier(2)

    def work(tag: str) -> None:
        with tr.span(f"outer.{tag}"):
            done.wait(timeout=5)            # both outers open concurrently
            with tr.span(f"inner.{tag}"):
                pass

    threads = [threading.Thread(target=work, args=(t,)) for t in ("a", "b")]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = {s.name: s for s in tr.spans()}
    # each inner's parent is its own thread's outer, never the sibling's
    assert spans["inner.a"].parent == "outer.a"
    assert spans["inner.b"].parent == "outer.b"
    assert spans["inner.a"].tid != spans["inner.b"].tid


def test_max_spans_drops_and_counts():
    tr = Tracer(clock=FakeClock(), max_spans=2)
    for i in range(5):
        tr.record("x", 0.0, 1.0, i=i)
    assert len(tr.spans()) == 2 and tr.dropped_spans() == 3
    assert tr.chrome_trace()["otherData"]["dropped_spans"] == 3


def test_chrome_trace_schema(tmp_path):
    clock = FakeClock(100.0)
    tr = Tracer(clock=clock)
    with tr.span("session.run", tenant="a"):
        clock.advance(0.001)
        with tr.span("cache.fill", tenant="a"):
            clock.advance(0.002)
        clock.advance(0.001)
    path = tr.write(tmp_path / "trace.json", metrics={"tenants": {}})
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert len(events) == 2
    last = -1.0
    for e in events:
        assert e["ph"] == "X"
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert e["ts"] >= last
        last = e["ts"]
        assert {"name", "cat", "pid", "tid", "args"} <= set(e)
        assert e["cat"] == e["name"].split(".", 1)[0]
    fill = [e for e in events if e["name"] == "cache.fill"][0]
    assert fill["dur"] == pytest.approx(2000.0)      # µs
    assert fill["args"]["parent"] == "session.run"
    assert doc["otherData"]["open_spans"] == 0
    assert doc["metrics"] == {"tenants": {}}
    assert check(doc) == []


def test_null_tracer_is_allocation_free_singletons():
    assert isinstance(NULL_TRACER, NullTracer)
    assert not NULL_TRACER.enabled
    # one shared handle regardless of name/labels: nothing is allocated
    h1 = NULL_TRACER.span("storage.read", tenant="a")
    h2 = NULL_TRACER.span("train.step")
    assert h1 is h2
    with h1 as sp:
        assert sp.set(bytes=1) is sp
    assert NULL_TRACER.record("x", 0.0, 1.0) is None
    assert NULL_TRACER.spans() == []
    assert NULL_TRACER.chrome_trace()["traceEvents"] == []


# -- registry -----------------------------------------------------------------


def test_registry_snapshot_and_delta():
    src = _Outer(done=5, peak=3)
    reg = MetricsRegistry()
    reg.register("shard", src)              # plain instance
    reg.register_value("fleet.depth", lambda: 7, kind="gauge")
    reg.register_value("fleet.busy_s", lambda: src.done * 2.0,
                       kind="counter")
    s1 = reg.snapshot()
    assert s1.get("shard.done") == 5
    assert s1.kinds["shard.done"] == "counter"
    assert s1.get("fleet.depth") == 7
    src.done = 9
    src.peak = 1
    s2 = reg.snapshot()
    d = s2.delta(s1)
    assert d["shard.done"] == 4             # counter: diffed
    assert d["shard.peak"] == 1             # gauge: current level
    assert d["fleet.busy_s"] == 18.0 - 10.0
    # missing previous value reads as from-zero
    assert s2.delta(None)["shard.done"] == 9


def test_registry_rejects_non_dataclass_source_and_bad_kind():
    reg = MetricsRegistry()
    reg.register("bogus", lambda: 42)
    with pytest.raises(TypeError):
        reg.snapshot()
    with pytest.raises(ValueError):
        MetricsRegistry().register_value("x", lambda: 0, kind="rate")


# -- controller parity: registry deltas vs the old inline polling -------------


def _legacy_observation(tick, last, interval_s):
    """The PR-4 monitor's inline arithmetic, verbatim."""
    stalls, waits, busy, buffered, n_active = tick
    last_stalls, last_waits, last_busy = last
    d_waits = max(waits - last_waits, 1)
    stall_rate = max(stalls - last_stalls, 0) / d_waits
    wall = max(interval_s, 1e-6) * max(n_active, 1)
    cpu_util = min(max(busy - last_busy, 0.0) / wall, 1.0)
    return Observation(
        n_workers=n_active, buffered_batches=buffered,
        stall_rate=stall_rate, cpu_util=cpu_util,
    )


def _snapshot(tick) -> Snapshot:
    stalls, waits, busy, buffered, n_active = tick
    return Snapshot(
        values={
            "client.stalls": stalls, "client.wait_calls": waits,
            "fleet.busy_s": busy, "fleet.buffered_batches": buffered,
            "fleet.active_workers": n_active,
        },
        kinds={
            "client.stalls": "counter", "client.wait_calls": "counter",
            "fleet.busy_s": "counter", "fleet.buffered_batches": "gauge",
            "fleet.active_workers": "gauge",
        },
    )


def test_observation_from_delta_matches_inline_polling_byte_for_byte():
    interval = 0.2
    # cumulative (stalls, waits, busy, buffered, active) series covering
    # pressure, steady-state, worker loss (busy clamp) and idle phases
    ticks = [
        (0, 1, 0.00, 0, 1),
        (3, 10, 0.15, 0, 1),
        (9, 25, 0.35, 1, 1),
        (9, 40, 0.90, 6, 2),
        (9, 60, 1.70, 12, 3),
        (9, 80, 1.65, 40, 3),     # busy regression: clamped to 0 util
        (9, 100, 1.80, 44, 3),
        (9, 120, 1.85, 48, 3),
        (9, 140, 1.90, 50, 2),
        (10, 160, 2.40, 0, 2),
    ]
    legacy_ctrl = ElasticController(ElasticPolicy(max_workers=8))
    new_ctrl = ElasticController(ElasticPolicy(max_workers=8))
    last = (0, 0, 0.0)
    prev = None
    for tick in ticks:
        legacy_obs = _legacy_observation(tick, last, interval)
        last = (tick[0], tick[1], tick[2])
        snap = _snapshot(tick)
        new_obs = observation_from_delta(snap.delta(prev), interval)
        prev = snap
        assert new_obs == legacy_obs        # frozen dataclass: exact equality
        assert legacy_ctrl.observe(legacy_obs) == new_ctrl.observe(new_obs)
    assert legacy_ctrl.decisions == new_ctrl.decisions
    assert legacy_ctrl.depth == new_ctrl.depth


# -- stall-attribution report -------------------------------------------------


def _event(name, ts, dur, tenant="a", tid=1):
    return {
        "name": name, "cat": name.split(".", 1)[0], "ph": "X",
        "ts": ts, "dur": dur, "pid": 1, "tid": tid,
        "args": {"tenant": tenant},
    }


def test_report_shares_sum_to_100_and_split_proportionally():
    doc = {
        "traceEvents": [
            _event("session.run", 0, 1000),
            _event("client.stall", 10, 400),
            _event("storage.read", 20, 30),
            _event("cache.fill", 60, 10),
            _event("extract.decode", 80, 5),
            _event("transform.fused", 90, 5),
            _event("load.materialize", 100, 10),
        ],
        "otherData": {"open_spans": 0},
    }
    rows = build_report(doc)
    r = rows["a"]
    total = (r["storage_pct"] + r["cache_fill_pct"] + r["transform_pct"]
             + r["load_pct"] + r["embed_fetch_pct"] + r["compute_pct"]
             + r["unattributed_pct"])
    assert total == pytest.approx(100.0, abs=1e-9)
    assert r["embed_fetch_pct"] == 0.0      # no embed.fetch spans recorded
    assert r["compute_pct"] == pytest.approx(60.0)
    # blocked 40% split by span weight: storage 30/60, fill 10/60, ...
    assert r["storage_pct"] == pytest.approx(20.0)
    assert r["cache_fill_pct"] == pytest.approx(40.0 * 10 / 60)
    assert r["transform_pct"] == pytest.approx(40.0 * 10 / 60)
    assert r["load_pct"] == pytest.approx(40.0 * 10 / 60)
    assert r["unattributed_pct"] == 0.0
    assert check(doc) == []


def test_report_embed_fetch_is_direct_share_not_stall_split():
    """``embed.fetch`` (ISSUE 9) is measured directly against the wall
    clock — it is not one of the client.stall weight buckets — and
    compute absorbs the remainder so the identity still closes at 100."""
    doc = {
        "traceEvents": [
            _event("session.run", 0, 1000),
            _event("client.stall", 10, 400),
            _event("storage.read", 20, 40),
            _event("embed.fetch", 500, 100),
            _event("embed.fetch", 700, 100),
        ],
        "otherData": {"open_spans": 0},
    }
    r = build_report(doc)["a"]
    assert r["embed_fetch_pct"] == pytest.approx(20.0)
    assert r["storage_pct"] == pytest.approx(40.0)    # full blocked share
    assert r["compute_pct"] == pytest.approx(40.0)
    total = (r["storage_pct"] + r["cache_fill_pct"] + r["transform_pct"]
             + r["load_pct"] + r["embed_fetch_pct"] + r["compute_pct"]
             + r["unattributed_pct"])
    assert total == pytest.approx(100.0, abs=1e-9)
    assert check(doc) == []


def test_report_per_tenant_rows_and_all_aggregate():
    doc = {
        "traceEvents": [
            _event("session.run", 0, 1000, tenant="a"),
            _event("client.stall", 0, 100, tenant="a"),
            _event("storage.read", 0, 50, tenant="a"),
            _event("session.run", 0, 3000, tenant="b"),
            _event("client.stall", 0, 600, tenant="b"),
            _event("load.materialize", 0, 50, tenant="b"),
        ],
        "otherData": {"open_spans": 0},
    }
    rows = build_report(doc)
    assert set(rows) == {"a", "b", "ALL"}
    assert rows["a"]["storage_pct"] == pytest.approx(10.0)
    assert rows["b"]["load_pct"] == pytest.approx(20.0)
    assert rows["ALL"]["wall_us"] == pytest.approx(4000.0)
    assert rows["ALL"]["stall_us"] == pytest.approx(700.0)
    assert rows["ALL"]["compute_pct"] == pytest.approx(100 * 3300 / 4000)


def test_report_surfaces_unattributed_stall_and_check_fails(tmp_path):
    doc = {
        "traceEvents": [
            _event("session.run", 0, 1000),
            _event("client.stall", 0, 500),   # blocked, zero work spans
        ],
        "otherData": {"open_spans": 0},
    }
    r = build_report(doc)["a"]
    assert r["unattributed_pct"] == pytest.approx(50.0)
    assert any("unattributed" in e or "no attributable" in e
               for e in check(doc))
    p = tmp_path / "bad.json"
    p.write_text(json.dumps(doc))
    assert report_main([str(p), "--check"]) == 1


def test_check_flags_open_spans_and_malformed_events():
    assert check({"traceEvents": None}) != []
    doc = {
        "traceEvents": [{"name": "x", "ph": "B", "ts": -1, "dur": 0,
                         "pid": 1, "tid": 1}],
        "otherData": {"open_spans": 2},
    }
    errs = "\n".join(check(doc))
    assert "ph=" in errs and "negative" in errs and "open" in errs


def test_report_metric_columns_from_snapshot_payload():
    doc = {
        "traceEvents": [_event("session.run", 0, 100)],
        "otherData": {"open_spans": 0},
        "metrics": {
            "tenants": {"a": {
                "worker.storage_rx_bytes": 1000,
                "worker.cache_rx_bytes": 250,
                "worker.rows_decoded": 300,
                "worker.rows_done": 200,
                "worker.rows_from_cache": 50,
                "worker.transform_fused_s": 3.0,
                "worker.transform_fallback_s": 1.0,
            }},
            "cache": {"a": {"dram_bytes_stored": 42.0,
                            "flash_bytes_stored": 7.0}},
        },
    }
    r = build_report(doc)["a"]
    assert r["storage_rx_bytes"] == 1000.0
    assert r["cache_rx_bytes"] == 250.0
    assert r["over_read"] == pytest.approx(300 / 150)
    assert r["fused_frac"] == pytest.approx(0.75)
    assert r["dram_bytes_stored"] == 42.0 and r["flash_bytes_stored"] == 7.0


# -- end to end: traced service run -> artifact -> report gate ----------------


def test_smoke_artifact_passes_report_check(tmp_path):
    out = tmp_path / "trace.json"
    results = run_smoke(str(out), rows=256)
    assert all(results[t] for t in ("tenant_a", "tenant_b"))
    doc = json.loads(out.read_text())
    assert check(doc) == [], check(doc)
    rows = build_report(doc)
    assert {"tenant_a", "tenant_b", "ALL"} <= set(rows)
    for r in rows.values():
        assert sum(r[k] for k in (
            "storage_pct", "cache_fill_pct", "transform_pct", "load_pct",
            "embed_fetch_pct", "compute_pct", "unattributed_pct",
        )) == pytest.approx(100.0, abs=0.1)
    assert report_main([str(out), "--check"]) == 0
    assert report_main([str(out), "--json"]) == 0
