"""Fault-tolerant, load-adaptive DPP control plane (ISSUE 4; §3.2.1 under
failures + InTune-style feedback scaling).

Three demonstrations, matching the acceptance criteria:

  (a) **poisoned split, no livelock** — a partition with mixed
      labeled/unlabeled stripes deterministically kills extract/transform
      on any worker.  With per-split dispatch budgets the session
      terminates within ``dispatch_budget x lease_s`` wall-clock in a
      ``DEGRADED`` state that surfaces the mixed-labels exception
      chain, while every healthy split's batches are still delivered
      (drain mode).  The pre-ISSUE-4 Master would re-dispatch the split
      on every lease expiry, forever.
  (b) **worker-kill recovery** — kill N of M workers mid-session; the
      control plane (health restarts + stall-driven elastic scale-up)
      recovers >= 0.8x the pre-kill batch throughput and the epoch
      completes exactly.
  (c) **stall-driven scale-up** — on an IO-latency-simulated warehouse, a
      1-worker session with the ``ElasticController`` enabled cuts client
      ``stall_s`` versus the same session pinned at 1 worker, by growing
      the fleet (and prefetch depth) only after sustained stall pressure.
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import dwrf
from repro.core.datagen import DataGenConfig, generate_partition
from repro.core.dpp import DPPSession, SessionSpec, SessionState
from repro.core.schema import make_schema
from repro.core.tectonic import TectonicFS
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Table, Warehouse

STRIPE = 256


def _table(name: str, n_parts: int, rows: int, latency: float = 0.0) -> Table:
    s = make_schema(name, 20, 6, seed=0)
    wh = Warehouse(TectonicFS(io_latency_scale=latency))
    t = wh.create_table(s)
    t.generate(n_parts, DataGenConfig(rows_per_partition=rows, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE))
    return t


def _poison_partition(t: Table, index: int, rows: int) -> None:
    """Install partition ``index`` with mixed labeled/unlabeled stripes: a
    labeled head (the streaming join delivered labels) concatenated with
    an unlabeled tail (labels not yet arrived) — the §4 ingestion hazard
    that the worker's per-split label-uniformity check deterministically
    rejects."""
    opts = dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE)
    head = dwrf.write_dwrf(
        generate_partition(t.schema, index,
                           DataGenConfig(rows_per_partition=STRIPE, seed=7)),
        opts,
    )
    tail = dwrf.write_dwrf(
        generate_partition(
            t.schema, index,
            DataGenConfig(rows_per_partition=rows - STRIPE, seed=8,
                          labeled=False),
        ),
        opts,
    )
    t.write_partition_encoded(index, dwrf.concat_dwrf([head, tail]))


def _spec(t: Table, **kw) -> SessionSpec:
    dense = t.schema.dense_ids[:6]
    sparse = t.schema.sparse_ids[:3]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=500)
    d = dict(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=256, rows_per_split=256,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )
    d.update(kw)
    return SessionSpec(**d)


# -- (a) poisoned split: bounded termination + DEGRADED drain ----------------


def _poisoned_split(rows: int) -> None:
    budget, lease_s = 2, 2.0
    n_parts = 4
    t = _table("bf_poison", n_parts - 1, rows)
    _poison_partition(t, n_parts - 1, rows)
    sess = DPPSession(
        _spec(t, batch_size=rows // 2, rows_per_split=rows), t,
        n_workers=2, lease_s=lease_s, dispatch_budget=budget,
    )
    t0 = time.time()
    batches = sess.run_to_completion(timeout_s=60)
    elapsed = time.time() - t0
    healthy_rows = sum(b["label"].shape[0] for b in batches)
    failures = sess.failure_report()
    emit(
        "faults.poisoned_split", elapsed * 1e6,
        f"state={sess.state} budget_x_lease_s={budget * lease_s:.1f} "
        f"elapsed_s={elapsed:.2f} quarantined={len(failures)} "
        f"healthy_rows={healthy_rows}",
    )
    assert elapsed <= budget * lease_s, (
        f"poisoned session must terminate within budget x lease: "
        f"{elapsed:.2f}s > {budget * lease_s:.2f}s (livelock?)"
    )
    assert sess.state == SessionState.DEGRADED, sess.state
    assert healthy_rows == (n_parts - 1) * rows, healthy_rows
    [f] = failures
    assert f.dispatches == budget and "mixed labeled/unlabeled" in f.last_error, (
        f.dispatches, f.last_error[-200:],
    )
    assert all(s == "data_error" for s in f.statuses), f.statuses


# -- (b) kill N of M workers: throughput recovery ----------------------------


def _worker_kill(rows: int) -> None:
    n_parts, n_workers, n_kill = 8, 4, 2
    t = _table("bf_kill", n_parts, rows, latency=2.0)
    sess = DPPSession(
        _spec(t), t, n_workers=n_workers, auto_scale=True,
        monitor_interval_s=0.1, lease_s=2.0, max_workers=8,
    )
    victims = sess.workers[:n_kill]
    for w in victims:
        w.fail_after_splits = 2      # die mid-session
    sess.start()
    stamps = []
    t_dead = None                    # when the last victim actually died
    t0 = time.time()
    deadline = t0 + 120
    try:
        while time.time() < deadline:
            if t_dead is None and all(not w.alive for w in victims):
                t_dead = time.time() - t0
            b = sess.clients[0].get_batch(timeout=0.25)
            if b is not None:
                stamps.append(time.time() - t0)
                continue
            if sess.master.finished and all(w.buffered == 0 for w in sess.workers):
                break
    finally:
        sess.stop()
    n = len(stamps)
    # event-anchored windows (batch-count windows are racy against the
    # kill timing): pre-kill rate over everything delivered before the
    # victims died; recovered rate over the last quarter of the epoch,
    # well past the restarts/scale-up.
    assert t_dead is not None, "victims never died"
    pre_n = sum(1 for s in stamps if s <= t_dead)
    pre = pre_n / t_dead if pre_n else 0.0
    k = max(4, n // 4)
    post = k / max(stamps[-1] - stamps[-k - 1], 1e-9)
    ratio = post / pre if pre > 0 else float("inf")
    emit(
        "faults.worker_kill_recovery", stamps[-1] * 1e6,
        f"state={sess.state} batches={n} restarts={len(sess.restart_events)} "
        f"scale_ups={sum(1 for e in sess.scale_events if e['delta'] > 0)} "
        f"t_dead_s={t_dead:.2f} pre_tput={pre:.1f} post_tput={post:.1f} "
        f"recovery={ratio:.2f}x",
    )
    assert sess.state == SessionState.COMPLETED, sess.state
    assert n == n_parts * rows // 256, n            # exact epoch despite kills
    assert len(sess.restart_events) >= n_kill, sess.restart_events
    assert ratio >= 0.8, (
        f"throughput must recover to >=0.8x pre-kill: {ratio:.2f}x"
    )


# -- (c) stall-driven elastic scale-up vs fixed fleet ------------------------


def _stall_scaleup(rows: int) -> None:
    def _run(elastic: bool) -> DPPSession:
        t = _table("bf_scale", 4, rows, latency=4.0)
        sess = DPPSession(
            _spec(t), t, n_workers=1, auto_scale=elastic,
            monitor_interval_s=0.05, lease_s=5.0, max_workers=8,
        )
        out = sess.run_to_completion(timeout_s=120)
        assert sum(b["label"].shape[0] for b in out) == 4 * rows
        return sess

    fixed = _run(elastic=False)
    scaled = _run(elastic=True)
    f_stall = fixed.clients[0].metrics.stall_s
    s_stall = scaled.clients[0].metrics.stall_s
    emit(
        "faults.stall_driven_scaleup", 0.0,
        f"fixed_stall_s={f_stall:.2f} elastic_stall_s={s_stall:.2f} "
        f"cut={s_stall / max(f_stall, 1e-9):.2f}x "
        f"workers_final={len(scaled.workers)} "
        f"scale_events={len(scaled.scale_events)}",
    )
    assert len(scaled.scale_events) >= 1, "controller never acted"
    assert len(scaled.workers) > 1, "fleet never grew"
    assert s_stall < f_stall, (
        f"elastic fleet must cut stall time: {s_stall:.2f}s vs {f_stall:.2f}s"
    )


def run(quick: bool = False) -> None:
    rows = 1024 if quick else 2048
    _poisoned_split(rows)
    _worker_kill(rows)
    _stall_scaleup(rows)
