"""Repo-native static analysis + runtime sanitizers (``repro.analysis``).

The DSI pipeline is only trustworthy at scale because its invariants hold
under heavy concurrency, and the hardest bugs of PRs 3-5 were exactly
invariant violations: a rewrite racing an in-flight read poisoning the
cache, a superseded lease double-charging a dispatch budget, kernel/numpy
parity breaks.  This package enforces — statically, in CI — the
conventions those fixes established by hand:

  * **lock discipline** (``REPRO-L001/L002/L003``): classes that declare a
    ``self._lock`` must not mutate shared attributes in public methods
    outside a ``with self._lock`` block; helpers that assume the lock is
    held carry a ``_locked`` suffix and are only called under the lock.
  * **clock injection** (``REPRO-C001``): ``core/dpp`` and ``core/cache``
    read absolute time only through an injected ``clock=`` callable —
    TTL/lease/heartbeat tests are deterministic exactly because of this.
  * **kernel parity** (``REPRO-K001/K002``): every ``OP_*`` code in
    ``kernels/fused_transform.py`` has a counterpart in ``kernels/ref.py``
    and is exercised by the differential suite in ``tests/test_engine.py``
    — a new op can never land without a parity oracle.
  * **metrics contract** (``REPRO-M001/M002``): benchmark-read metric
    fields must exist on the metric dataclasses, and counters are
    monotonic (no ``-=``).
  * **thread hygiene** (``REPRO-T001/T002``): every ``threading.Thread``
    is daemonized or joined; bare ``except:`` is banned.

Run the gate with ``python -m repro.analysis`` (wired into
``scripts/ci.sh``).  Findings are suppressible inline with
``# repro: noqa(RULE-ID)`` or via the checked-in baseline
(``scripts/analysis_baseline.txt``), so the gate is additive: it fails CI
only on NEW findings.

The runtime side lives in :mod:`repro.analysis.lockdep`: a lock wrapper +
acquisition-graph recorder that detects lock-order inversions (cycles in
the waits-for graph => potential deadlock), exposed as the opt-in
``lockdep`` pytest fixture for the concurrency-heavy suites.

Dependency-free by design: stdlib ``ast`` + ``threading`` only, so the
gate runs in any environment that can run the tests.
"""
from repro.analysis.core import (
    Finding,
    all_rules,
    load_baseline,
    run_checks,
    write_baseline,
)

__all__ = [
    "Finding",
    "all_rules",
    "load_baseline",
    "run_checks",
    "write_baseline",
]
