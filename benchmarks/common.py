"""Shared benchmark helpers: timing + CSV emission."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

ROWS: List[Tuple[str, float, str]] = []
REPORTS: List[Tuple[str, Dict]] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    ROWS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.3f},{derived}")


def emit_report(name: str, payload: Dict) -> None:
    """Attach a structured payload (e.g. the per-tenant stall-attribution
    table) to the current section; ``run.py --quick`` embeds it under
    ``sections[<section>]["reports"][name]`` in ``BENCH_quick.json``."""
    REPORTS.append((name, payload))


def time_us(fn: Callable, *args, repeat: int = 3, number: int = 1) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        for _ in range(number):
            fn(*args)
        best = min(best, (time.perf_counter() - t0) / number)
    return best * 1e6
