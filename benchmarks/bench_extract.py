"""Table 9 (§6.3) extract stage: batched stripe decode vs per-stream.

The kernels/engine sections cover the *transform* half of preprocessing;
this section benchmarks the **extract** half the DPP worker runs before
it: decrypt + decompress + column decode, as a ``DecodeEngine``
(``repro.core.decode``).  The per-stream reference pays one decrypt and
one unpack/scatter per stream/feature; the batched engine issues one
fused XOR launch, one dense bitmap-unpack launch, and one ragged-gather
launch per stripe.

Paper-shaped projection: a DLRM dense tower — hundreds of float features
(Table 2 puts recommendation models at O(100s-1000s) of features), small
row groups, raw codec so the decode stages are isolated from the shared
host decompress term.

Asserted claims:
  * kernel-launch amortization: the batched engine issues >= 10x fewer
    launches than the per-stream regime on the projection,
  * a measured extract_s cut vs the numpy engine on the dense-tower
    projection (best-of timing; the floor is intentionally lenient for
    noisy CI hosts — the trend gate in scripts/bench_diff.py guards the
    measured ratio run-over-run),
  * both engines produce byte-identical batches (spot-checked here;
    exhaustively pinned by tests/test_decode.py).
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_us
from repro.core import dwrf
from repro.core.decode import NumpyDecodeEngine, PallasDecodeEngine
from repro.core.schema import ColumnBatch, SparseColumn

# the extract cut the batched engine must show over the per-stream
# reference on the dense-tower projection (measured ~1.3-1.5x on CPU via
# the XLA oracles; far larger launch-bound on accelerators)
MIN_EXTRACT_CUT = 1.05


def _stripe(rows: int, n_dense: int, n_sparse: int, seed: int = 0):
    """One raw-codec flattened stripe shaped like a recommendation table:
    NaN-holed dense floats, ragged scored/unscored id lists, labels."""
    rng = np.random.default_rng(seed)
    dense = {}
    for f in range(n_dense):
        col = rng.standard_normal(rows).astype(np.float32)
        col[rng.random(rows) < 0.1] = np.nan
        dense[f] = col
    sparse = {}
    for f in range(n_dense, n_dense + n_sparse):
        lengths = rng.poisson(2, rows)
        off = np.zeros(rows + 1, np.int64)
        np.cumsum(lengths, out=off[1:])
        sparse[f] = SparseColumn(
            offsets=off,
            values=rng.integers(0, 1 << 40, int(off[-1]), dtype=np.int64),
            scores=rng.random(int(off[-1])).astype(np.float32)
            if f % 2 else None,
        )
    batch = ColumnBatch(
        num_rows=rows, dense=dense, sparse=sparse,
        labels=rng.random(rows).astype(np.float32),
    )
    f = dwrf.write_dwrf(batch, dwrf.DwrfWriterOptions(
        flattened=True, stripe_rows=rows, codec="raw",
    ))
    stripe = f.footer.stripes[0]
    fetch = {
        (s.fid, s.kind): f.data[s.offset: s.offset + s.length]
        for s in stripe.streams
    }
    return stripe, fetch, list(dense), list(sparse)


def _project(stripe, fetch, fids):
    """The fetch a planned read would issue for this projection: wanted
    feature streams plus labels."""
    want = set(fids)
    return {
        k: v for k, v in fetch.items()
        if k[1] == "labels" or k[0] in want
    }


def run(quick: bool = False) -> None:
    # the cut widens with stream count (it is per-stream overhead the
    # batched engine amortizes), so quick mode keeps enough streams for a
    # stable margin and trims the repeat count instead
    rows = 128
    n_dense, n_sparse = (512, 32) if quick else (800, 64)
    repeat = 5 if quick else 7

    stripe, fetch, dense_fids, sparse_fids = _stripe(rows, n_dense, n_sparse)

    # -- dense-tower projection: the asserted cut --------------------------
    proj = _project(stripe, fetch, dense_fids)
    numpy_eng = NumpyDecodeEngine()
    # default dispatch (use_pallas=None): compiled Pallas kernels on TPU,
    # XLA-compiled oracles elsewhere — the production config
    fused_eng = PallasDecodeEngine()
    ref = numpy_eng.decode_stripe(stripe, proj, dense_fids)
    got = fused_eng.decode_stripe(stripe, proj, dense_fids)   # warm/compile
    # per-stripe launch counts, captured before the timing loops re-run
    ln = numpy_eng.stats.kernel_launches
    lp = fused_eng.stats.kernel_launches

    # parity spot check (the differential suite owns the exhaustive one)
    for f in (dense_fids[0], dense_fids[-1]):
        assert ref.dense[f].tobytes() == got.dense[f].tobytes(), f
    assert ref.labels.tobytes() == got.labels.tobytes()

    us_numpy = time_us(
        lambda: numpy_eng.decode_stripe(stripe, proj, dense_fids),
        repeat=repeat,
    )
    us_fused = time_us(
        lambda: fused_eng.decode_stripe(stripe, proj, dense_fids),
        repeat=repeat,
    )
    cut = us_numpy / max(us_fused, 1e-9)

    n_streams = len(proj)
    assert n_streams >= 64, "amortization claim needs a >= 64-stream stripe"
    assert lp * 10 <= ln, (
        f"batched engine must amortize launches >= 10x: {lp} vs {ln}"
    )
    assert cut >= MIN_EXTRACT_CUT, (
        f"batched extract must beat the per-stream engine "
        f">= {MIN_EXTRACT_CUT}x on the dense tower: "
        f"{us_numpy:.0f}us vs {us_fused:.0f}us ({cut:.2f}x)"
    )
    emit("extract.numpy_per_stream", us_numpy,
         f"launches={ln} streams={n_streams} rows={rows}")
    emit("extract.fused_batched", us_fused,
         f"launches={lp} amortization={ln / max(lp, 1):.0f}x "
         f"extract_cut={cut:.2f}x")

    # -- mixed projection: Table-9-style stage breakdown -------------------
    all_fids = dense_fids + sparse_fids
    for eng, tag in ((NumpyDecodeEngine(), "numpy"),
                     (PallasDecodeEngine(), "fused")):
        eng.decode_stripe(stripe, fetch, all_fids)            # warm
        eng.stats = type(eng.stats)()
        us = time_us(
            lambda e=eng: e.decode_stripe(stripe, fetch, all_fids),
            repeat=repeat,
        )
        s = eng.stats
        total = max(s.decrypt_s + s.decode_s + s.gather_s + s.assemble_s,
                    1e-12)
        emit(f"extract.stages_{tag}", us,
             f"decrypt_pct={100 * s.decrypt_s / total:.0f} "
             f"decode_pct={100 * s.decode_s / total:.0f} "
             f"gather_pct={100 * s.gather_s / total:.0f} "
             f"assemble_pct={100 * s.assemble_s / total:.0f} "
             f"launches={s.kernel_launches // repeat}")

    # -- interpret-mode dispatch: the bit-accurate emulation CI validates
    # the Pallas kernels with off-TPU; not a wall-clock proxy, so a small
    # stripe and a single run
    istripe, ifetch, idense, isparse = _stripe(64, 64, 8, seed=1)
    interp = PallasDecodeEngine(use_pallas=True)
    ifids = idense + isparse
    interp.decode_stripe(istripe, ifetch, ifids)              # warm
    us_interp = time_us(
        lambda: interp.decode_stripe(istripe, ifetch, ifids), repeat=1,
    )
    emit("extract.fused_interpret_mode", us_interp,
         "bit-accurate CI emulation (compiled on TPU)")


if __name__ == "__main__":
    run()
