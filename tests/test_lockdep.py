"""Regression tests for the runtime lock-order sanitizer
(``repro.analysis.lockdep``).

The seeded-inversion tests prove the detector actually fires: an A->B /
B->A nesting — the classic deadlock shape — must raise ``LockOrderError``
from a *single-threaded* run (a cycle in the acquisition graph means a
deadlocking schedule exists; no real deadlock is needed).  The clean-run
guarantee over the real StripeCache/TectonicFS stack lives in
``test_cache.py`` / ``test_dpp.py`` via the opt-in ``lockdep`` fixture.
"""
from __future__ import annotations

import threading

import pytest

from repro.analysis.lockdep import LockGraph, LockOrderError, patched


def _nest(graph: LockGraph, *names: str) -> None:
    """Simulate one thread acquiring ``names`` in order, then releasing."""
    for n in names:
        graph.note_acquire(n)
    for n in reversed(names):
        graph.note_release(n)


# -- graph-level unit tests ---------------------------------------------------


def test_graph_detects_two_lock_inversion():
    g = LockGraph()
    _nest(g, "A", "B")
    _nest(g, "B", "A")
    cycles = g.cycles()
    assert len(cycles) == 1 and set(cycles[0]) == {"A", "B"}
    with pytest.raises(LockOrderError):
        g.assert_no_cycles()


def test_graph_detects_three_lock_cycle():
    g = LockGraph()
    _nest(g, "A", "B")
    _nest(g, "B", "C")
    _nest(g, "C", "A")
    cycles = g.cycles()
    assert len(cycles) == 1 and set(cycles[0]) == {"A", "B", "C"}


def test_graph_consistent_order_is_clean():
    g = LockGraph()
    for _ in range(3):
        _nest(g, "A", "B", "C")
    _nest(g, "A", "C")
    g.assert_no_cycles()
    assert "no cycles" in g.report()


def test_graph_ignore_suppresses_known_ladder():
    g = LockGraph(ignore=["B"])
    _nest(g, "A", "B")
    _nest(g, "B", "A")
    g.assert_no_cycles()


def test_graph_reentrant_reacquire_adds_no_edge():
    g = LockGraph()
    g.note_acquire("R")
    g.note_acquire("R")       # RLock re-entry
    g.note_release("R")
    g.note_release("R")
    assert g.edges() == []


# -- TrackedLock / patched() end-to-end --------------------------------------


def test_seeded_inversion_detected_with_stacks():
    """The acceptance fixture: two locks nested A->B on one code path and
    B->A on another must be reported as a cycle with the ordered
    acquisition stacks of both edges."""
    with patched() as g:
        a = threading.Lock()
        b = threading.Lock()
    with a:
        with b:
            pass
    with b:
        with a:                  # inversion
            pass
    with pytest.raises(LockOrderError) as ei:
        g.assert_no_cycles()
    report = str(ei.value)
    assert "lock-order cycle" in report
    assert report.count("held, then acquired") == 2
    assert report.count("acquired at:") == 4      # both ends of both edges
    assert "test_lockdep.py" in report


def test_consistent_nesting_under_patch_is_clean():
    with patched() as g:
        outer = threading.Lock()
        inner = threading.Lock()
    for _ in range(2):
        with outer:
            with inner:
                pass
    g.assert_no_cycles()
    assert len(g.edges()) == 1


def test_patched_rlock_reentry_is_not_a_cycle():
    with patched() as g:
        r = threading.RLock()
    with r:
        with r:
            pass
    g.assert_no_cycles()
    assert g.edges() == []


def test_patched_restores_real_factories():
    real_lock, real_rlock = threading.Lock, threading.RLock
    with patched():
        assert threading.Lock is not real_lock
    assert threading.Lock is real_lock and threading.RLock is real_rlock


def test_name_filter_limits_tracking():
    with patched(name_filter=lambda s: False) as g:
        a = threading.Lock()
        b = threading.Lock()
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    g.assert_no_cycles()          # nothing tracked, nothing reported
    assert g.edges() == []


def test_condition_and_threads_work_under_patch():
    """Tracked locks must keep Condition/Queue semantics: a worker thread
    waits on a Condition built from a patched Lock and is notified."""
    with patched() as g:
        lk = threading.Lock()
        cond = threading.Condition(lk)
    hits = []

    def worker():
        with cond:
            while not hits:
                cond.wait(timeout=5.0)
            hits.append("seen")

    t = threading.Thread(target=worker)
    t.start()
    with cond:
        hits.append("set")
        cond.notify_all()
    t.join(timeout=5.0)
    assert not t.is_alive() and hits == ["set", "seen"]
    g.assert_no_cycles()


def test_cross_thread_inversion_detected():
    """Each thread takes a consistent-looking order locally; together the
    orders invert.  The graph merges per-thread edges, so the cycle is
    caught without any actual deadlock (locks never held concurrently)."""
    with patched() as g:
        a = threading.Lock()
        b = threading.Lock()
    done = []

    def t1():
        with a:
            with b:
                done.append("t1")

    th = threading.Thread(target=t1)
    th.start()
    th.join(timeout=5.0)
    with b:
        with a:
            done.append("main")
    assert done == ["t1", "main"]
    with pytest.raises(LockOrderError):
        g.assert_no_cycles()
