"""Decode-engine differential suite (ISSUE 10 tentpole).

Two layers of parity net:

  * kernel level — ``xor_decrypt`` / ``dense_unpack`` / ``ragged_gather``
    in interpret mode vs the jnp oracles (``kernels.ref``), bit-for-bit
    (REPRO-K002 requires every decode kernel to be named here);
  * engine level — ``PallasDecodeEngine`` (both dispatch modes) vs
    ``NumpyDecodeEngine`` vs ``dwrf.decode_stripe_features`` on
    adversarial stripes: 0-row stripes, 0-nnz features, all-NaN dense,
    map vs flattened encodings, ragged tails, present-but-empty scores,
    legacy sparse_map blobs, run-time demotion.  "Identical" here means
    byte-identical (NaN bit patterns included), which is what keeps the
    TensorCache engine-agnostic.
"""
import numpy as np
import pytest

from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.decode import (
    DECODE_ENGINES,
    DecodeEngine,
    NumpyDecodeEngine,
    PallasDecodeEngine,
    make_decode_engine,
)
from repro.core.dpp import DPPSession
from repro.core.reader import TableReader
from repro.core.schema import ColumnBatch, SparseColumn, make_schema
from repro.core.warehouse import Warehouse
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# kernel-level differentials (interpret mode vs jnp oracle)
# ---------------------------------------------------------------------------


def test_xor_decrypt_matches_ref():
    rng = np.random.default_rng(0)
    words = rng.integers(-(2**31), 2**31, (16, 128), dtype=np.int32)
    out = np.asarray(ops.xor_decrypt(words, use_pallas=True))
    np.testing.assert_array_equal(out, np.asarray(ref.xor_decrypt(words)))
    # and the byte-domain meaning: XOR 0x5A on every byte
    want = np.frombuffer(words.tobytes(), np.uint8) ^ 0x5A
    np.testing.assert_array_equal(np.frombuffer(out.tobytes(), np.uint8), want)


def test_dense_unpack_matches_ref_and_host_scatter():
    rng = np.random.default_rng(1)
    rows, feats = 517, 5
    bitmap = np.zeros((feats, 8), np.int32)   # 8 words = 256 bits... need 517
    words = (-(-rows // 8) + 3) // 4
    bitmap = np.zeros((feats, words), np.int32)
    values = np.zeros((feats, rows), np.int32)
    host = np.full((feats, rows), np.nan, np.float32)
    for f in range(feats):
        present = rng.random(rows) < (0.0, 0.3, 1.0, 0.5, 0.9)[f]
        packed = np.packbits(present.astype(np.uint8))
        buf = np.zeros(words * 4, np.uint8)
        buf[: len(packed)] = packed
        bitmap[f] = buf.view("<i4")
        vals = rng.standard_normal(int(present.sum())).astype(np.float32)
        values[f, : len(vals)] = vals.view(np.int32)
        host[f, present] = vals
    out = np.asarray(ops.dense_unpack(bitmap, values, use_pallas=True))
    np.testing.assert_array_equal(
        out, np.asarray(ref.dense_unpack(bitmap, values))
    )
    # bit-identical to the host unpackbits+scatter reference (NaN included)
    np.testing.assert_array_equal(
        out[:, :rows], host.view(np.int32)
    )


def test_ragged_gather_matches_ref_at_every_shift():
    rng = np.random.default_rng(2)
    raw = rng.integers(0, 256, 4 * 128 * 4, dtype=np.uint8)
    src = raw.view("<i4").reshape(4, 128)
    # one request per byte shift, each 128 words long
    idx = np.zeros((4, 128), np.int32)
    shift = np.zeros((4, 128), np.int32)
    for r, sh in enumerate((0, 8, 16, 24)):
        idx[r] = np.arange(128, dtype=np.int32) + r
        shift[r] = sh
    out = np.asarray(ops.ragged_gather(src, idx, shift, use_pallas=True))
    np.testing.assert_array_equal(
        out, np.asarray(ref.ragged_gather(src, idx, shift))
    )
    # byte-domain meaning: row r is the source bytes starting at 4*r + r_sh
    flat = raw.tobytes()
    for r, sh in enumerate((0, 8, 16, 24)):
        start = 4 * r + sh // 8
        assert out[r].tobytes()[: 512 - 4 * r - sh // 8] == \
            flat[start: start + 512 - 4 * r - sh // 8]


# ---------------------------------------------------------------------------
# engine-level differentials on adversarial stripes
# ---------------------------------------------------------------------------


def _bits(a):
    return (a.view(np.int32) if a.dtype == np.float32 else a).tobytes()


def assert_bit_identical(a: ColumnBatch, b: ColumnBatch):
    """Byte-level ColumnBatch equality: dict order, dtypes, and exact bit
    patterns (NaNs compare equal only this way)."""
    assert a.num_rows == b.num_rows
    assert list(a.dense) == list(b.dense)
    assert list(a.sparse) == list(b.sparse)
    for f in a.dense:
        assert a.dense[f].dtype == b.dense[f].dtype
        assert _bits(a.dense[f]) == _bits(b.dense[f])
    for f in a.sparse:
        x, y = a.sparse[f], b.sparse[f]
        assert _bits(x.offsets) == _bits(y.offsets)
        assert _bits(x.values) == _bits(y.values)
        assert (x.scores is None) == (y.scores is None)
        if x.scores is not None:
            assert _bits(x.scores) == _bits(y.scores)
    assert (a.labels is None) == (b.labels is None)
    if a.labels is not None:
        assert _bits(a.labels) == _bits(b.labels)


def _adversarial_batch(rows, seed=0, labels=True):
    """Dense: empty/partial/full/all-NaN presence; sparse: 0-nnz, scored,
    scored-but-empty, unscored — every shape the decoder dispatches on."""
    rng = np.random.default_rng(seed)
    dense = {}
    for f, density in ((0, 0.0), (1, 0.5), (2, 1.0), (3, 0.9)):
        present = rng.random(rows) < density
        col = np.full(rows, np.nan, np.float32)
        col[present] = rng.standard_normal(int(present.sum())).astype(np.float32)
        dense[f] = col
    sparse = {}
    for f, (nnz_max, scored) in (
        (10, (0, True)),      # 0-nnz but scored: the satellite-1 shape
        (11, (5, True)),
        (12, (3, False)),
        (13, (0, False)),
    ):
        counts = rng.integers(0, nnz_max + 1, rows) if nnz_max else np.zeros(rows, np.int64)
        off = np.zeros(rows + 1, np.int64)
        np.cumsum(counts, out=off[1:])
        vals = rng.integers(0, 1 << 40, int(off[-1])).astype(np.int64)
        sc = rng.random(int(off[-1])).astype(np.float32) if scored else None
        sparse[f] = SparseColumn(offsets=off, values=vals, scores=sc)
    return ColumnBatch(
        num_rows=rows, dense=dense, sparse=sparse,
        labels=rng.random(rows).astype(np.float32) if labels else None,
    )


def _engines():
    return [
        NumpyDecodeEngine(),
        PallasDecodeEngine(use_pallas=False),   # XLA-compiled jnp oracles
        PallasDecodeEngine(use_pallas=True),    # Pallas kernels, interpret mode
    ]


def _stripe_fetch(f: dwrf.DwrfFile, stripe, drop_labels=False):
    return {
        (s.fid, s.kind): f.data[s.offset: s.offset + s.length]
        for s in stripe.streams
        if not (drop_labels and s.kind == "labels")
    }


@pytest.mark.parametrize("flattened", [True, False])
@pytest.mark.parametrize("codec", ["raw", "zlib"])
@pytest.mark.parametrize("rows", [517, 7, 0])
def test_engines_bit_identical_on_adversarial_stripes(flattened, codec, rows):
    batch = _adversarial_batch(rows, seed=rows + 1)
    f = dwrf.write_dwrf(batch, dwrf.DwrfWriterOptions(
        flattened=flattened, stripe_rows=256, codec=codec))
    fids = list(batch.dense) + list(batch.sparse)
    for drop_labels in (False, True):
        for want in (fids, [1, 10, 13]):
            for stripe in f.footer.stripes:
                fetch = _stripe_fetch(f, stripe, drop_labels)
                want_ref = dwrf.decode_stripe_features(stripe, fetch, want)
                for eng in _engines():
                    got = eng.decode_stripe(stripe, fetch, want)
                    assert_bit_identical(want_ref, got)


def test_pallas_engine_amortizes_kernel_launches_10x():
    """The §7.2 launch-amortization argument, applied to extract: one
    stripe with many features costs O(streams+features) numpy dispatches
    but a constant few batched launches."""
    rows = 256
    rng = np.random.default_rng(5)
    dense = {}
    sparse = {}
    for f in range(24):
        col = rng.standard_normal(rows).astype(np.float32)
        col[rng.random(rows) < 0.3] = np.nan
        dense[f] = col
    for f in range(24, 40):
        counts = rng.integers(0, 4, rows)
        off = np.zeros(rows + 1, np.int64)
        np.cumsum(counts, out=off[1:])
        sparse[f] = SparseColumn(
            offsets=off,
            values=rng.integers(0, 1 << 40, int(off[-1])).astype(np.int64),
            scores=None,
        )
    batch = ColumnBatch(num_rows=rows, dense=dense, sparse=sparse,
                        labels=rng.random(rows).astype(np.float32))
    f = dwrf.write_dwrf(batch, dwrf.DwrfWriterOptions(
        flattened=True, stripe_rows=rows, codec="raw"))
    stripe = f.footer.stripes[0]
    fetch = _stripe_fetch(f, stripe)
    fids = list(range(40))
    en, ep = NumpyDecodeEngine(), PallasDecodeEngine(use_pallas=False)
    assert_bit_identical(en.decode_stripe(stripe, fetch, fids),
                         ep.decode_stripe(stripe, fetch, fids))
    ln, lp = en.stats.kernel_launches, ep.stats.kernel_launches
    # numpy: one pass per stream + one decode per feature; pallas: XOR +
    # dense + gather launches plus the labels host fallback
    assert ln == 41 + 41
    assert lp == 4
    assert lp * 10 <= ln
    assert ep.stats.fused_streams == 40
    assert ep.stats.fallback_streams == 1      # labels
    assert ep.stats.demoted_streams == 0


def test_pallas_engine_demotes_unexpected_dtypes_bit_identically():
    """A stream the kernels can't express bit-exactly (f64 dense_map
    payload, f32 sparse values) must fall back to the per-stream
    reference, not crash or diverge."""
    rows = 64
    rng = np.random.default_rng(6)
    # hand-build a map stripe whose dense payload holds float64 and whose
    # sparse values are int32 — the writer never emits these, but the
    # format allows them and the reference astype-converts on decode
    dense_blob = dwrf._pack_arrays(
        [np.asarray([0], np.int64), rng.standard_normal(rows)]  # f64!
    )
    off = np.zeros(rows + 1, np.int64)
    np.cumsum(rng.integers(0, 3, rows), out=off[1:])
    sparse_blob = dwrf._pack_arrays([
        np.asarray([10], np.int64),
        off,
        rng.integers(0, 1000, int(off[-1])).astype(np.int32),   # i4!
        np.zeros(0, np.float32),
    ])
    streams = []
    buf = bytearray()
    for kind, blob in (("dense_map", dense_blob), ("sparse_map", sparse_blob)):
        enc = dwrf.encode_stream(blob, "raw")
        streams.append(dwrf.StreamInfo(fid=-1, kind=kind, offset=len(buf),
                                       length=len(enc)))
        buf.extend(enc)
    stripe = dwrf.StripeInfo(row_start=0, num_rows=rows, offset=0,
                             length=len(buf), streams=streams)
    fetch = {(s.fid, s.kind): bytes(buf[s.offset: s.offset + s.length])
             for s in streams}
    want = [0, 10]
    want_ref = dwrf.decode_stripe_features(stripe, fetch, want)
    for eng in _engines()[1:]:
        got = eng.decode_stripe(stripe, fetch, want)
        assert_bit_identical(want_ref, got)
        assert eng.stats.demoted_streams == 2
        assert eng.stats.fallback_streams == 2


def test_pallas_engine_keeps_stream_order_with_interleaved_demotion():
    """A demoted stream sandwiched between fused ones must land in the
    assembled dicts at its stream position — the reference inserts keys
    in stream order, and TensorCache keys are order-sensitive."""
    rows = 32
    rng = np.random.default_rng(8)
    streams, buf = [], bytearray()
    for fid in range(3):
        col = rng.standard_normal(rows).astype(np.float32)
        packed = np.packbits(np.ones(rows, bool))
        vals = col.astype(np.float64) if fid == 1 else col   # fid 1 demotes
        enc = dwrf.encode_stream(dwrf._pack_arrays([packed, vals]), "raw")
        streams.append(dwrf.StreamInfo(fid=fid, kind="dense",
                                       offset=len(buf), length=len(enc)))
        buf.extend(enc)
    stripe = dwrf.StripeInfo(row_start=0, num_rows=rows, offset=0,
                             length=len(buf), streams=streams)
    fetch = {(s.fid, s.kind): bytes(buf[s.offset: s.offset + s.length])
             for s in streams}
    want_ref = dwrf.decode_stripe_features(stripe, fetch, [0, 1, 2])
    assert list(want_ref.dense) == [0, 1, 2]
    for eng in _engines()[1:]:
        got = eng.decode_stripe(stripe, fetch, [0, 1, 2])
        assert_bit_identical(want_ref, got)
        assert eng.stats.demoted_streams == 1
        assert eng.stats.fused_streams == 2


def test_pallas_engine_matches_reference_error_on_scatter_mismatch():
    """A dense stream whose value count disagrees with its presence
    popcount must raise on the batched engines exactly like the
    per-stream reference — not silently produce a different batch."""
    rows = 32
    rng = np.random.default_rng(9)
    packed = np.packbits(np.ones(rows, bool))           # popcount 32 ...
    vals = rng.standard_normal(10).astype(np.float32)   # ... but 10 values
    enc = dwrf.encode_stream(dwrf._pack_arrays([packed, vals]), "raw")
    stripe = dwrf.StripeInfo(
        row_start=0, num_rows=rows, offset=0, length=len(enc),
        streams=[dwrf.StreamInfo(fid=0, kind="dense", offset=0,
                                 length=len(enc))],
    )
    fetch = {(0, "dense"): enc}
    with pytest.raises(ValueError) as ref_err:
        dwrf.decode_stripe_features(stripe, fetch, [0])
    for eng in _engines():
        with pytest.raises(ValueError) as got_err:
            eng.decode_stripe(stripe, fetch, [0])
        assert str(got_err.value) == str(ref_err.value)


def test_pallas_engine_decodes_legacy_sparse_map_blob():
    """Pre-v2 sparse_map blobs (no sentinel, no flags) must keep decoding
    on both engines — with the legacy lossy scores heuristic."""
    rows = 16
    rng = np.random.default_rng(7)
    off = np.zeros(rows + 1, np.int64)
    np.cumsum(rng.integers(0, 3, rows), out=off[1:])
    vals = rng.integers(0, 1000, int(off[-1])).astype(np.int64)
    legacy_blob = dwrf._pack_arrays([
        np.asarray([10, 11], np.int64),
        off, vals, rng.random(int(off[-1])).astype(np.float32),  # scored
        off, vals, np.zeros(0, np.float32),                      # unscored
    ])
    enc = dwrf.encode_stream(legacy_blob, "raw")
    stripe = dwrf.StripeInfo(
        row_start=0, num_rows=rows, offset=0, length=len(enc),
        streams=[dwrf.StreamInfo(fid=-1, kind="sparse_map", offset=0,
                                 length=len(enc))],
    )
    fetch = {(-1, "sparse_map"): enc}
    want_ref = dwrf.decode_stripe_features(stripe, fetch, [10, 11])
    assert want_ref.sparse[10].scores is not None
    assert want_ref.sparse[11].scores is None    # the legacy heuristic
    for eng in _engines():
        assert_bit_identical(want_ref, eng.decode_stripe(stripe, fetch, [10, 11]))


def test_make_decode_engine_contract():
    assert set(DECODE_ENGINES) == {"numpy", "pallas"}
    assert isinstance(make_decode_engine(None), NumpyDecodeEngine)
    assert isinstance(make_decode_engine("pallas"), PallasDecodeEngine)
    inst = PallasDecodeEngine(use_pallas=False)
    assert make_decode_engine(inst) is inst
    assert isinstance(make_decode_engine(NumpyDecodeEngine), DecodeEngine)
    with pytest.raises(ValueError, match="unknown decode engine"):
        make_decode_engine("turbo")


# ---------------------------------------------------------------------------
# reader / worker / session integration
# ---------------------------------------------------------------------------

ROWS = 1024
STRIPE = 256


def _table(flattened=True, name="dec"):
    s = make_schema(name, 24, 8, seed=3)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(1, DataGenConfig(rows_per_partition=ROWS, seed=4),
               dwrf.DwrfWriterOptions(flattened=flattened, stripe_rows=STRIPE))
    return t


@pytest.mark.parametrize("flattened", [True, False])
def test_reader_engines_and_double_buffer_bit_identical(flattened):
    t = _table(flattened)
    proj = t.schema.logged_ids[:10]
    meta = t.partitions[0]
    base = TableReader(t, proj).read_rows(meta, 100, 900)
    for de, db in (("numpy", True), ("pallas", False), ("pallas", True)):
        r = TableReader(t, proj, decode_engine=de, double_buffer=db)
        got = r.read_rows(meta, 100, 900)
        assert_bit_identical(base.batch, got.batch)
        stripes = list(r.iter_stripes(meta, 100, 900))
        from repro.core.schema import concat_batches

        assert_bit_identical(base.batch, concat_batches([s.batch for s in stripes]))
        # satellite-3: streaming reads report the per-extent size histogram
        for sr in stripes:
            assert sr.io_sizes and sum(sr.io_sizes) == sr.bytes_read


def _session_spec(t, rows_per_split=STRIPE):
    from repro.core.dpp import SessionSpec
    from repro.core.transforms import default_dlrm_pipeline

    dense = t.schema.dense_ids[:6]
    sparse = t.schema.sparse_ids[:3]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=500)
    return SessionSpec(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=128, rows_per_split=rows_per_split,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )


def test_session_pallas_decode_bit_identical_and_metered():
    t = _table(name="decs")
    spec = _session_spec(t)
    ref_out = DPPSession(spec, t, n_workers=1,
                         decode_engine="numpy").run_to_completion(timeout_s=60)
    sess = DPPSession(spec, t, n_workers=1, decode_engine="pallas",
                      double_buffer=True)
    got_out = sess.run_to_completion(timeout_s=60)
    assert len(ref_out) == len(got_out)
    for a, b in zip(ref_out, got_out):
        assert set(a) == set(b)
        for k in a:
            assert a[k].dtype == b[k].dtype and a[k].shape == b[k].shape
            assert a[k].tobytes() == b[k].tobytes()
    m = sess.worker_metrics()
    # stripe-aligned splits stay perfectly split-scoped under the new path
    assert m.over_read_ratio == 1.0
    assert m.decode_launches > 0
    assert m.extract_fused_s > 0.0
    assert m.io_sizes and all(s > 0 for s in m.io_sizes)
    # the whole epoch costs a handful of launches per stripe, not O(features)
    n_stripes = m.stripes_read
    assert m.decode_launches <= 4 * n_stripes
