"""DiLoCo-style pod-local training with periodic cross-pod outer sync.

Motivated directly by the paper's §4.2: cross-region (cross-pod) bandwidth
is highly constrained while within-pod bandwidth is plentiful.  Each pod
runs H local AdamW steps on its own data shard; every H steps the pods
exchange only the parameter *delta* (optionally bf16-compressed — gradient
compression at the outer level) and apply a Nesterov-momentum outer step.

Communication reduction vs per-step all-reduce over the pod axis:
``H x (32/16 if compressed)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DiLoCoConfig:
    inner_steps: int = 32            # H
    outer_lr: float = 0.7
    outer_momentum: float = 0.9
    compress_bf16: bool = True


def outer_init(params: Any) -> Any:
    return {
        "anchor": jax.tree.map(lambda p: p.astype(jnp.float32), params),
        "momentum": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def outer_step(
    pod_params: Any,              # this pod's params after H inner steps
    outer_state: Any,
    cfg: DiLoCoConfig,
    mean_over_pods: Callable[[Any], Any],
) -> Tuple[Any, Any]:
    """Exchange deltas across pods and take the outer (Nesterov) step.

    ``mean_over_pods`` is the only cross-pod communication: a psum-mean of
    the (optionally bf16) parameter delta along the "pod" mesh axis.
    """
    anchor = outer_state["anchor"]
    delta = jax.tree.map(
        lambda p, a: (a - p.astype(jnp.float32)), pod_params, anchor
    )  # outer "gradient"
    if cfg.compress_bf16:
        delta = jax.tree.map(lambda d: d.astype(jnp.bfloat16), delta)
    delta = mean_over_pods(delta)
    delta = jax.tree.map(lambda d: d.astype(jnp.float32), delta)

    new_m = jax.tree.map(
        lambda m, d: cfg.outer_momentum * m + d, outer_state["momentum"], delta
    )
    step_dir = jax.tree.map(
        lambda m, d: cfg.outer_momentum * m + d, new_m, delta
    )  # Nesterov
    new_anchor = jax.tree.map(
        lambda a, s: a - cfg.outer_lr * s, anchor, step_dir
    )
    new_params = jax.tree.map(
        lambda p, a: a.astype(p.dtype), pod_params, new_anchor
    )
    return new_params, {"anchor": new_anchor, "momentum": new_m}


def comm_savings(cfg: DiLoCoConfig, param_bytes: int) -> dict:
    """Napkin math recorded in EXPERIMENTS.md: bytes over the pod axis."""
    per_step_allreduce = 2 * param_bytes          # bf16 grads, ring 2x
    diloco_per_h = param_bytes * (0.5 if cfg.compress_bf16 else 1.0) * 2
    return {
        "baseline_bytes_per_step": per_step_allreduce,
        "diloco_bytes_per_step": diloco_per_h / cfg.inner_steps,
        "reduction_x": per_step_allreduce * cfg.inner_steps / diloco_per_h,
    }
