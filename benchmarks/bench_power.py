"""Fig. 1: storage / preprocessing / training power split per model."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core.dpp.simulator import WORKLOADS, dsi_power_split


def run() -> None:
    for name, w in WORKLOADS.items():
        p = dsi_power_split(w, n_trainers=16)
        emit(
            f"fig1.power_split.{name}", 0.0,
            f"storage={p['storage_frac']:.2f} preprocessing={p['preprocessing_frac']:.2f} "
            f"training={p['training_frac']:.2f} "
            f"dsi_total={p['storage_frac']+p['preprocessing_frac']:.2f}",
        )
