"""Unit + property tests for the frequency-aware tiered embedding store
(ISSUE 9): admission/eviction policy, host DRAM/flash accounting,
generation invalidation, kernel-path parity, and the two structural
invariants — capacity is never exceeded and every lookup serves the
latest-generation row.
"""
import numpy as np
import pytest

from repro.train import TieredEmbeddingStore

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _tables(t=2, v=40, e=4, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(0, 0.01, (t, v, e)).astype(np.float32)


def _bag(row_ids, t=1, l=1):
    """One batch of single-table bags: ids (B, t, l) + all-live mask."""
    ids = np.asarray(row_ids, np.int64).reshape(-1, t, l)
    return ids, np.ones(ids.shape, np.float32)


def _flat_pool(host, ids, mask):
    """The byte-identity oracle: mean-pool straight off the host tables
    with the same formula the store uses."""
    t = host.shape[0]
    emb = np.stack(
        [host[i][np.clip(ids[:, i], 0, host.shape[1] - 1)] for i in range(t)],
        axis=1,
    )
    denom = np.maximum(mask.sum(axis=2), 1.0)
    return (
        (emb * mask[..., None]).sum(axis=2) / denom[..., None]
    ).astype(np.float32)


def test_flat_store_is_pure_host():
    """hot capacity 0: every access is a host fetch, output == oracle."""
    tabs = _tables()
    store = TieredEmbeddingStore(tabs, 0)
    rng = np.random.default_rng(1)
    ids = rng.integers(0, 40, (8, 2, 5))
    mask = (rng.random((8, 2, 5)) < 0.7).astype(np.float32)
    got = store.pooled(ids, mask)
    assert np.array_equal(got, _flat_pool(tabs, ids, mask))
    assert store.stats.hot_hits == 0
    assert store.stats.hot_rate == 0.0
    assert store.stats.dram_fetches == int(mask.sum())
    assert store.stats.flash_fetches == 0      # no host-DRAM bound -> all DRAM


def test_admission_needs_admit_reads_batches():
    """A row turns hot only once ``admit_reads`` distinct lookup batches
    touched it; from then on it serves from the device tier."""
    store = TieredEmbeddingStore(_tables(t=1), 4, admit_reads=3)
    ids, mask = _bag([7])
    for i in range(2):
        store.pooled(ids, mask)
        assert store.stats.admitted == 0
        assert list(store.hot_residency()[0]) == []
    store.pooled(ids, mask)                    # third batch: count hits 3
    assert store.stats.admitted == 1
    assert list(store.hot_residency()[0]) == [7]
    assert store.stats.hot_hits == 0           # admitted after the serve
    store.pooled(ids, mask)
    assert store.stats.hot_hits == 1
    assert store.row_count(0, 7) == 4


def test_eviction_only_for_strictly_hotter_row():
    """Capacity pressure evicts the least-popular resident, and only for a
    newcomer with a strictly higher count — equal warmth never thrashes."""
    store = TieredEmbeddingStore(_tables(t=1), 1, admit_reads=1)
    a, am = _bag([3])
    b, bm = _bag([9])
    for _ in range(3):
        store.pooled(a, am)                    # count(3) = 3, resident
    assert list(store.hot_residency()[0]) == [3]

    store.pooled(b, bm)                        # count(9) = 1 < 3: kept out
    assert store.stats.evicted == 0
    assert list(store.hot_residency()[0]) == [3]
    for _ in range(2):
        store.pooled(b, bm)                    # count(9) = 3 == 3: still out
    assert store.stats.evicted == 0
    assert list(store.hot_residency()[0]) == [3]

    store.pooled(b, bm)                        # count(9) = 4 > 3: evict 3
    assert store.stats.evicted == 1
    assert list(store.hot_residency()[0]) == [9]
    assert store.stats.hot_rows == 1


def test_host_dram_flash_accounting():
    """Cold fetches charge flash until the row enters the host-DRAM
    working set (LRU over ``host_dram_rows``), DRAM afterwards."""
    store = TieredEmbeddingStore(
        _tables(t=1), 0, host_dram_rows=2
    )
    ids, mask = _bag([5])
    store.pooled(ids, mask)                    # miss the working set
    assert (store.stats.flash_fetches, store.stats.dram_fetches) == (1, 0)
    store.pooled(ids, mask)                    # LRU-resident now
    assert (store.stats.flash_fetches, store.stats.dram_fetches) == (1, 1)

    store.pooled(*_bag([6]))
    store.pooled(*_bag([7]))                   # capacity 2: row 5 evicted
    store.pooled(ids, mask)                    # flash again
    assert store.stats.flash_fetches == 4
    assert store.stats.dram_fetches == 1
    assert store.stats.flash_io.num_ios == 4
    assert store.stats.dram_io.num_ios == 1


def test_generation_bump_refreshes_stale_slots():
    """After ``load_tables`` every resident slot is stale; the next lookup
    refreshes it in place and serves the new bytes, never the old."""
    old = _tables(t=1)
    new = _tables(t=1, seed=5)
    store = TieredEmbeddingStore(old, 4, admit_reads=1)
    ids, mask = _bag([2])
    store.pooled(ids, mask)                    # admit under generation 0
    store.pooled(ids, mask)
    assert store.stats.hot_hits == 1

    assert store.load_tables(new) == 1
    got = store.pooled(ids, mask)
    assert np.array_equal(got, _flat_pool(new, ids, mask))
    assert store.stats.stale_refreshes == 1
    assert store.stats.generation == 1
    # the refreshed slot is fresh again: no second refresh, still a hot hit
    store.pooled(ids, mask)
    assert store.stats.stale_refreshes == 1
    assert store.stats.hot_hits == 3


def test_capacity_and_residency_gauges():
    """Skewed traffic: residency never exceeds capacity and the gauges
    track admitted-minus-evicted exactly."""
    store = TieredEmbeddingStore(_tables(), 4, admit_reads=1)
    rng = np.random.default_rng(3)
    for _ in range(30):
        ids = rng.zipf(1.5, (4, 2, 3)) % 40
        store.pooled(ids, np.ones(ids.shape, np.float32))
    res = store.hot_residency()
    for ti in (0, 1):
        assert len(res[ti]) <= 4
    assert store.stats.hot_rows == store.stats.admitted - store.stats.evicted
    assert store.stats.hot_bytes == store.stats.hot_rows * store.row_bytes
    assert store.stats.hot_rate > 0.3          # the skew pays off


def test_kernel_path_matches_exact_pooling():
    """Fully-hot bags served by the Pallas ``embedding_bag`` kernel agree
    with the exact numpy path to float tolerance."""
    tabs = _tables(t=2, v=16, e=8)
    store = TieredEmbeddingStore(tabs, 16, admit_reads=1)
    rng = np.random.default_rng(4)
    ids = rng.integers(0, 16, (4, 2, 5))
    mask = (rng.random((4, 2, 5)) < 0.8).astype(np.float32)
    store.pooled(ids, mask)                    # admit everything touched
    exact = store.pooled(ids, mask)
    viak = store.pooled(ids, mask, use_kernel=True)
    assert store.stats.kernel_bags > 0
    np.testing.assert_allclose(viak, exact, atol=1e-5)
    assert np.array_equal(exact, _flat_pool(tabs, ids, mask))


def test_sparse_update_is_adagrad_and_refreshes_hot():
    """``apply_sparse_update`` applies the row-wise AdaGrad mirror to the
    host tier and rewrites resident hot copies in the same lock."""
    tabs = _tables(t=1, v=10, e=4)
    store = TieredEmbeddingStore(tabs, 4, admit_reads=1)
    ids, mask = _bag([[1, 3]], t=1, l=2)
    store.pooled(ids, mask)                    # rows 1 and 3 go hot
    store.pooled(ids, mask)
    assert sorted(store.hot_residency()[0]) == [1, 3]

    lr, eps = 0.1, 1e-8
    dpooled = np.full((1, 1, 4), 2.0, np.float32)
    store.apply_sparse_update(dpooled, ids, mask, lr=lr, eps=eps)

    # manual mirror: each id gets dpooled * (1/2) (mean-pool weight)
    rg = np.full((4,), 1.0, np.float32)
    g2 = np.mean(rg ** 2)
    want = tabs.copy()
    for r in (1, 3):
        want[0, r] -= (lr / np.sqrt(g2 + eps)) * rg
    host = store.host_tables()
    np.testing.assert_allclose(host, want, rtol=1e-6)
    assert store.stats.refreshed == 2

    # hot copies match the updated host rows bit-for-bit
    got = store.pooled(*_bag([1]))
    assert np.array_equal(got[0, 0], host[0, 1])
    assert store.stats.hot_hits >= 3


# -- property test: capacity + latest-generation serving --------------------


def _drive(seed: int) -> None:
    """Random op sequence; after every op the store must (i) respect the
    hot capacity, (ii) keep the residency gauges consistent, and (iii)
    serve byte-exact latest-generation rows for any probe."""
    rng = np.random.default_rng(seed)
    t, v, e, cap = 2, 24, 4, 3
    store = TieredEmbeddingStore(
        _tables(t, v, e, seed=seed), cap, admit_reads=2, host_dram_rows=6
    )
    probe_ids = rng.integers(0, v, (3, t, 4))
    probe_mask = (rng.random((3, t, 4)) < 0.8).astype(np.float32)
    for _ in range(25):
        op = rng.integers(0, 4)
        if op == 0:
            ids = rng.zipf(1.4, (2, t, 3)) % v
            store.pooled(ids, np.ones(ids.shape, np.float32))
        elif op == 1:
            ids = rng.integers(0, v, (2, t, 3))
            mask = (rng.random(ids.shape) < 0.7).astype(np.float32)
            dp = rng.normal(0, 1, (2, t, e)).astype(np.float32)
            store.apply_sparse_update(dp, ids, mask, lr=0.05)
        elif op == 2:
            store.bump_generation()
        else:
            store.load_tables(
                rng.normal(0, 0.01, (t, v, e)).astype(np.float32)
            )
        res = store.hot_residency()
        assert all(len(res[ti]) <= cap for ti in range(t))
        assert store.stats.hot_rows == (
            store.stats.admitted - store.stats.evicted
        )
        assert store.stats.hot_bytes == store.stats.hot_rows * store.row_bytes
        assert 0 <= store.stats.hot_rows <= t * cap
        # latest-generation serving: the probe is byte-exact against the
        # authoritative host copy no matter what the hot tier holds
        got = store.pooled(probe_ids, probe_mask)
        want = _flat_pool(store.host_tables(), probe_ids, probe_mask)
        assert np.array_equal(got, want)


if HAVE_HYPOTHESIS:

    @given(st.integers(min_value=0, max_value=10_000))
    @settings(max_examples=25, deadline=None)
    def test_store_invariants_property(seed):
        _drive(seed)

else:

    @pytest.mark.parametrize("seed", range(12))
    def test_store_invariants_property(seed):
        _drive(seed)
