"""Sharded, atomic checkpointing for train state (fault tolerance).

Layout: ``<dir>/step_<n>/shard_<k>.msgpack`` + ``manifest.json``.  Each
process writes only the leaves it owns (addressable shards), so on a real
multi-host pod every host persists its slice; on this single-host container
there is one shard.  Writes are staged to a temp dir and renamed for
atomicity; ``latest_step`` skips incomplete checkpoints, so a crash mid-save
falls back to the previous complete one.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import msgpack
import numpy as np

import jax


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _encode_leaf(arr: Any) -> Dict[str, Any]:
    a = np.asarray(arr)
    return {
        "dtype": a.dtype.str if a.dtype != np.dtype("bfloat16") else "bfloat16",
        "shape": list(a.shape),
        "data": a.tobytes(),
    }


def _decode_leaf(d: Dict[str, Any]) -> np.ndarray:
    import ml_dtypes

    dtype = np.dtype(ml_dtypes.bfloat16) if d["dtype"] == "bfloat16" else np.dtype(d["dtype"])
    return np.frombuffer(d["data"], dtype).reshape(d["shape"])


def save_pytree(tree: Any, path: str, shard: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    payload = {k: _encode_leaf(v) for k, v in leaves}
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))
    os.replace(tmp, os.path.join(path, f"shard_{shard}.msgpack"))


def load_pytree(template: Any, path: str, shard: int = 0) -> Any:
    with open(os.path.join(path, f"shard_{shard}.msgpack"), "rb") as f:
        payload = msgpack.unpackb(f.read(), raw=False)
    leaves = _flatten_with_paths(template)
    out = []
    for k, tmpl in leaves:
        d = payload[k]
        arr = _decode_leaf(d)
        out.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def save(self, step: int, state: Dict[str, Any]) -> None:
        final = self._step_dir(step)
        stage = tempfile.mkdtemp(dir=self.directory, prefix=".staging_")
        try:
            save_pytree(state, stage)
            with open(os.path.join(stage, "manifest.json"), "w") as f:
                json.dump({"step": step, "complete": True}, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(stage, final)
        except Exception:
            shutil.rmtree(stage, ignore_errors=True)
            raise
        self._gc()

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    def all_steps(self) -> List[int]:
        out = []
        for name in sorted(os.listdir(self.directory)):
            if not name.startswith("step_"):
                continue
            manifest = os.path.join(self.directory, name, "manifest.json")
            if os.path.exists(manifest):
                try:
                    with open(manifest) as f:
                        if json.load(f).get("complete"):
                            out.append(int(name.split("_")[1]))
                except (json.JSONDecodeError, ValueError):
                    continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template: Dict[str, Any], step: Optional[int] = None) -> Tuple[int, Any]:
        step = step if step is not None else self.latest_step()
        assert step is not None, "no complete checkpoint found"
        return step, load_pytree(template, self._step_dir(step))
