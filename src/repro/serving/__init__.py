from repro.serving.server import BatchingServer, Request, ServerConfig
