"""Per-architecture smoke tests (deliverable f): reduced same-family config,
one forward/train step on CPU, asserting output shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfglib
from repro.models import build_model
from repro.models.dlrm import DLRMConfig

LM_ARCHS = [a for a in cfglib.ARCH_IDS if a != "dlrm-paper"]


def _train_batch(cfg, b, s, key):
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.frontend == "vision":
        batch["image_embeds"] = 0.02 * jax.random.normal(
            key, (b, cfg.num_patches, cfg.d_model), cfg.compute_dtype
        )
    if cfg.frontend == "audio":
        batch["frames"] = 0.02 * jax.random.normal(key, (b, s, cfg.d_model), cfg.compute_dtype)
        dec = max(s // 8, 16)
        dt = jax.random.randint(key, (b, dec), 0, cfg.vocab_size)
        batch["tokens"], batch["labels"] = dt, jnp.roll(dt, -1, axis=1)
    return batch


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step_smoke(arch):
    cfg = cfglib.get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    shape = cfglib.SMOKE_SHAPES["train_4k"]
    batch = _train_batch(cfg, shape.global_batch, shape.seq_len, jax.random.PRNGKey(1))
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_and_decode_smoke(arch):
    cfg = cfglib.get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 1, 32
    pre = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab_size)}
    if cfg.frontend == "vision":
        pre["image_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), (b, cfg.num_patches, cfg.d_model), cfg.compute_dtype
        )
    if cfg.frontend == "audio":
        pre["frames"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(3), (b, s, cfg.d_model), cfg.compute_dtype
        )
        pre["tokens"] = jax.random.randint(jax.random.PRNGKey(4), (b, 8), 0, cfg.vocab_size)
    logits, cache = jax.jit(model.prefill)(params, pre)
    assert logits.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    dec = {
        "token": jnp.ones((b, 1), jnp.int32),
        "pos": jnp.asarray(3, jnp.int32),
        "cache": jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype),
                              model.abstract_cache(b, 16)),
    }
    lg, new_cache = jax.jit(model.decode_step)(params, dec)
    assert lg.shape == (b, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all()
    assert jax.tree.structure(new_cache) == jax.tree.structure(dec["cache"])


def test_dlrm_smoke():
    cfg = cfglib.get_smoke_config("dlrm-paper")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    specs = model.input_specs(8)
    batch = {k: jnp.ones(v.shape, v.dtype) for k, v in specs.items()}
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    ne = model.normalized_entropy(params, batch)
    assert np.isfinite(float(ne))


@pytest.mark.parametrize("arch", cfglib.ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = cfglib.get_config(arch)
    table = {
        "mamba2-2.7b": dict(num_layers=64, d_model=2560, vocab_size=50280),
        "codeqwen1.5-7b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=32, d_ff=13440, vocab_size=92416),
        "llama3-405b": dict(num_layers=126, d_model=16384, num_heads=128,
                            num_kv_heads=8, d_ff=53248, vocab_size=128256),
        "qwen2-72b": dict(num_layers=80, d_model=8192, num_heads=64,
                          num_kv_heads=8, d_ff=29568, vocab_size=152064),
        "qwen3-8b": dict(num_layers=36, d_model=4096, num_heads=32,
                         num_kv_heads=8, d_ff=12288, vocab_size=151936),
        "jamba-1.5-large-398b": dict(num_layers=72, d_model=8192, num_heads=64,
                                     num_kv_heads=8, d_ff=24576, vocab_size=65536),
        "llava-next-mistral-7b": dict(num_layers=32, d_model=4096, num_heads=32,
                                      num_kv_heads=8, d_ff=14336, vocab_size=32000),
        "deepseek-v2-236b": dict(num_layers=60, d_model=5120, num_heads=128,
                                 d_ff=1536, vocab_size=102400),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, d_ff=2048, vocab_size=163840),
        "seamless-m4t-large-v2": dict(num_layers=24, d_model=1024, num_heads=16,
                                      num_kv_heads=16, d_ff=8192, vocab_size=256206),
    }
    if arch == "dlrm-paper":
        assert isinstance(cfg, DLRMConfig)
        return
    for k, v in table[arch].items():
        assert getattr(cfg, k) == v, (arch, k)
    if arch == "mamba2-2.7b":
        assert cfg.ssm.d_state == 128
    if arch == "jamba-1.5-large-398b":
        assert cfg.moe.num_experts == 16 and cfg.moe.top_k == 2
    if arch == "deepseek-v2-236b":
        assert cfg.mla.kv_lora_rank == 512
        assert cfg.moe.num_experts == 160 and cfg.moe.top_k == 6
        assert cfg.moe.num_shared_experts == 2
    if arch == "kimi-k2-1t-a32b":
        assert cfg.moe.num_experts == 384 and cfg.moe.top_k == 8
    if arch == "qwen3-8b":
        assert cfg.qk_norm
    if arch == "qwen2-72b" or arch == "codeqwen1.5-7b":
        assert cfg.qkv_bias


def test_param_counts_in_expected_range():
    from repro.models.common import param_count
    for arch, (lo, hi) in {
        "qwen3-8b": (7e9, 10e9),
        "llama3-405b": (380e9, 430e9),
        "qwen2-72b": (65e9, 80e9),
        "deepseek-v2-236b": (200e9, 260e9),
        "kimi-k2-1t-a32b": (0.85e12, 1.15e12),
        "mamba2-2.7b": (2.4e9, 3.1e9),
    }.items():
        n = param_count(cfglib.get_config(arch))
        assert lo < n < hi, (arch, n)
