import os

# Smoke tests and benches must see the single real CPU device (the dry-run
# sets its own XLA_FLAGS in-process; never globally here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


# lock construction sites the lock-order sanitizer should track: repo code
# only — stdlib Condition/Queue internals stay real locks (harmless for
# cycle detection but noisy, and patching them buys nothing)
_REPRO_LOCK_FILES = (
    "stripe_cache.py", "tectonic.py", "master.py", "worker.py",
    "service.py", "client.py", "prefetch.py", "tensor_cache.py",
    "dedup.py", "warehouse.py", "autoscale.py", "engine.py", "trainer.py",
)


@pytest.fixture
def lockdep():
    """Opt-in lock-order sanitizer: every Lock/RLock a repro module builds
    during the test is tracked; teardown fails the test on any lock-order
    cycle (potential deadlock), with ordered acquisition stacks."""
    from repro.analysis import lockdep as ld

    with ld.patched(
        name_filter=lambda s: s.startswith(_REPRO_LOCK_FILES)
    ) as graph:
        yield graph
    graph.assert_no_cycles()
