"""Encoder-decoder transformer (SeamlessM4T backbone; audio frontend stub).

The modality frontend is a stub per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d_model).  The decoder is a text LM
with self + cross attention.  Shapes map seq_len to the encoder frame count;
the decoder length is seq_len // DEC_RATIO for training (speech-to-text
compression) and seq_len for the decode cache per the assignment's
"KV cache of seq_len" convention.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import layers
from repro.models.common import ModelConfig, stack_tree
from repro.models.transformer import DecoderLM

DEC_RATIO = 8


class EncDecLM(DecoderLM):
    def __init__(self, cfg: ModelConfig):
        assert cfg.encoder_layers > 0
        super().__init__(cfg)

    # -- specs -------------------------------------------------------------

    def enc_layer_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "ln1": layers.rmsnorm_spec(cfg.d_model),
            "attn": attn.gqa_specs(cfg),
            "ln2": layers.rmsnorm_spec(cfg.d_model),
            "ffn": layers.mlp_specs(cfg.d_model, cfg.d_ff, cfg.param_dtype),
        }

    def dec_layer_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "ln1": layers.rmsnorm_spec(cfg.d_model),
            "self_attn": attn.gqa_specs(cfg),
            "ln_c": layers.rmsnorm_spec(cfg.d_model),
            "cross_attn": attn.gqa_specs(cfg),
            "ln2": layers.rmsnorm_spec(cfg.d_model),
            "ffn": layers.mlp_specs(cfg.d_model, cfg.d_ff, cfg.param_dtype),
        }

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": layers.embed_specs(cfg),
            "enc_layers": stack_tree(self.enc_layer_specs(), cfg.encoder_layers),
            "enc_ln_f": layers.rmsnorm_spec(cfg.d_model),
            "dec_layers": stack_tree(self.dec_layer_specs(), cfg.num_layers),
            "ln_f": layers.rmsnorm_spec(cfg.d_model),
        }

    # -- inputs --------------------------------------------------------------

    def input_specs(self, batch: int, seq: int, mode: str = "train") -> Dict[str, Any]:
        cfg = self.cfg
        dec_len = max(seq // DEC_RATIO, 128)
        frames = jax.ShapeDtypeStruct((batch, seq, cfg.d_model), cfg.compute_dtype)
        if mode == "train":
            return {
                "frames": frames,
                "tokens": jax.ShapeDtypeStruct((batch, dec_len), jnp.int32),
                "labels": jax.ShapeDtypeStruct((batch, dec_len), jnp.int32),
            }
        if mode == "prefill":
            return {
                "frames": frames,
                "tokens": jax.ShapeDtypeStruct((batch, dec_len), jnp.int32),
            }
        if mode == "decode":
            return {
                "token": jax.ShapeDtypeStruct((batch, 1), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32),
                "cache": self.abstract_cache(batch, seq),
            }
        raise ValueError(mode)

    def abstract_cache(self, batch: int, seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        l = cfg.num_layers
        kvh, hd = cfg.num_kv_heads, cfg.head_dim
        dt = cfg.compute_dtype
        return {
            "k": jax.ShapeDtypeStruct((l, batch, seq, kvh, hd), dt),
            "v": jax.ShapeDtypeStruct((l, batch, seq, kvh, hd), dt),
            "cross_k": jax.ShapeDtypeStruct((l, batch, seq, kvh, hd), dt),
            "cross_v": jax.ShapeDtypeStruct((l, batch, seq, kvh, hd), dt),
        }

    def cache_logical_axes(self) -> Dict[str, Tuple]:
        kv = ("stack", "batch", "kv_seq", "kv_heads", None)
        return {"k": kv, "v": kv, "cross_k": kv, "cross_v": kv}

    # -- encoder ---------------------------------------------------------------

    def encode(self, params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        b, s, _ = frames.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = frames.astype(cfg.compute_dtype)

        def body(h, lp):
            hn = layers.rmsnorm(h, lp["ln1"], cfg.rms_eps)
            q, k, v = attn.gqa_project_qkv(lp["attn"], hn, positions, cfg)
            o = attn.blocked_attention(q, k, v, causal=False, chunk=cfg.attn_chunk, k_chunk=cfg.attn_k_chunk)
            h = h + jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
            hn = layers.rmsnorm(h, lp["ln2"], cfg.rms_eps)
            return h + layers.mlp(lp["ffn"], hn), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
        return layers.rmsnorm(x, params["enc_ln_f"], cfg.rms_eps)

    # -- decoder ---------------------------------------------------------------

    def _cross_kv(self, lp, enc_out, enc_positions, cfg):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, lp["cross_attn"]["wv"])
        return k, v

    def _decoder_layer(self, lp, x, positions, enc_out, enc_positions):
        cfg = self.cfg
        h = layers.rmsnorm(x, lp["ln1"], cfg.rms_eps)
        q, k, v = attn.gqa_project_qkv(lp["self_attn"], h, positions, cfg)
        o = attn.blocked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk, k_chunk=cfg.attn_k_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["self_attn"]["wo"])
        h = layers.rmsnorm(x, lp["ln_c"], cfg.rms_eps)
        cq = jnp.einsum("bsd,dhk->bshk", h, lp["cross_attn"]["wq"])
        ck, cv = self._cross_kv(lp, enc_out, enc_positions, cfg)
        co = attn.blocked_attention(cq, ck, cv, causal=False, chunk=cfg.attn_chunk, k_chunk=cfg.attn_k_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", co, lp["cross_attn"]["wo"])
        h = layers.rmsnorm(x, lp["ln2"], cfg.rms_eps)
        return x + layers.mlp(lp["ffn"], h), (ck, cv)

    # -- training ----------------------------------------------------------------

    def loss(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        enc_positions = jnp.broadcast_to(jnp.arange(enc_out.shape[1]), enc_out.shape[:2])
        x = layers.embed_tokens(params["embed"], tokens, cfg)

        def body(h, lp):
            h2, _ = self._decoder_layer(lp, h, positions, enc_out, enc_positions)
            return h2, None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, params["dec_layers"])
        x = layers.rmsnorm(x, params["ln_f"], cfg.rms_eps)
        return layers.chunked_softmax_xent(params["embed"], x, batch["labels"], cfg)

    # -- serving -----------------------------------------------------------------

    def prefill(self, params, batch):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        s_enc = enc_out.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        enc_positions = jnp.broadcast_to(jnp.arange(s_enc), (b, s_enc))
        x = layers.embed_tokens(params["embed"], tokens, cfg)

        def body(h, lp):
            hn = layers.rmsnorm(h, lp["ln1"], cfg.rms_eps)
            q, k, v = attn.gqa_project_qkv(lp["self_attn"], hn, positions, cfg)
            o = attn.blocked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk, k_chunk=cfg.attn_k_chunk)
            h = h + jnp.einsum("bshk,hkd->bsd", o, lp["self_attn"]["wo"])
            hn = layers.rmsnorm(h, lp["ln_c"], cfg.rms_eps)
            cq = jnp.einsum("bsd,dhk->bshk", hn, lp["cross_attn"]["wq"])
            ck, cv = self._cross_kv(lp, enc_out, enc_positions, cfg)
            co = attn.blocked_attention(cq, ck, cv, causal=False, chunk=cfg.attn_chunk, k_chunk=cfg.attn_k_chunk)
            h = h + jnp.einsum("bshk,hkd->bsd", co, lp["cross_attn"]["wo"])
            hn = layers.rmsnorm(h, lp["ln2"], cfg.rms_eps)
            h = h + layers.mlp(lp["ffn"], hn)
            cache = {
                "k": k.astype(cfg.compute_dtype),
                "v": v.astype(cfg.compute_dtype),
                "cross_k": ck.astype(cfg.compute_dtype),
                "cross_v": cv.astype(cfg.compute_dtype),
            }
            return h, cache

        x, cache = jax.lax.scan(body, x, params["dec_layers"])
        x = layers.rmsnorm(x, params["ln_f"], cfg.rms_eps)
        logits = layers.output_logits(params["embed"], x[:, -1:, :], cfg)
        return logits, cache

    def decode_step(self, params, batch):
        cfg = self.cfg
        token, pos, cache = batch["token"], batch["pos"], batch["cache"]
        x = layers.embed_tokens(params["embed"], token, cfg)
        positions = jnp.broadcast_to(pos, token.shape)

        def body(h, inp):
            lp, k_c, v_c, ck, cv = inp
            hn = layers.rmsnorm(h, lp["ln1"], cfg.rms_eps)
            q, k, v = attn.gqa_project_qkv(lp["self_attn"], hn, positions, cfg)
            k_c = jax.lax.dynamic_update_slice(k_c, k.astype(k_c.dtype), (0, pos, 0, 0))
            v_c = jax.lax.dynamic_update_slice(v_c, v.astype(v_c.dtype), (0, pos, 0, 0))
            o = attn.decode_attention(q, k_c, v_c, pos)
            h = h + jnp.einsum("bshk,hkd->bsd", o, lp["self_attn"]["wo"])
            hn = layers.rmsnorm(h, lp["ln_c"], cfg.rms_eps)
            cq = jnp.einsum("bsd,dhk->bshk", hn, lp["cross_attn"]["wq"])
            co = attn.decode_attention(cq, ck, cv, jnp.asarray(ck.shape[1] - 1, jnp.int32))
            h = h + jnp.einsum("bshk,hkd->bsd", co, lp["cross_attn"]["wo"])
            hn = layers.rmsnorm(h, lp["ln2"], cfg.rms_eps)
            h = h + layers.mlp(lp["ffn"], hn)
            return h, {"k": k_c, "v": v_c, "cross_k": ck, "cross_v": cv}

        xs = (params["dec_layers"], cache["k"], cache["v"], cache["cross_k"], cache["cross_v"])
        x, new_cache = jax.lax.scan(body, x, xs)
        x = layers.rmsnorm(x, params["ln_f"], cfg.rms_eps)
        logits = layers.output_logits(params["embed"], x, cfg)
        return logits, new_cache
