"""Multi-tenant cache-tier control plane (ISSUE 3; §7.2 under production
multi-tenancy + §4 partition churn).

Three demonstrations, matching the acceptance criteria:

  (a) **capacity shares** — an antagonist scan job streaming cold
      partitions through the shared tier no longer evicts a popular job's
      working set: with a ``TenantPolicy`` guarantee the popular job's
      hit rate stays within 10% of its solo run (without one, the scan
      washes the tier);
  (b) **rewrite invalidation** — a partition rewrite (continuous feature
      engineering) is never served stale from DRAM or flash: the first
      post-rewrite read comes from storage and matches a cache-less
      reference, and re-reads hit on the *new* bytes;
  (c) **prefetch** — a background ``PrefetchPlanner`` filling only the
      uncached segments of upcoming splits cuts ``ClientMetrics.stall_s``
      versus the PR 2 baseline on the same session, with storage latency
      simulated so overlap is measured in wall-clock.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import dwrf
from repro.core.cache import StripeCache, TenantPolicy, TenantShare
from repro.core.datagen import DataGenConfig, generate_partition
from repro.core.dpp import DPPService, SessionSpec
from repro.core.reader import TableReader
from repro.core.schema import make_schema
from repro.core.tectonic import TectonicFS
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse

STRIPE = 128
N_PARTS = 8
HOT_PARTS = (0, 1)          # the popular job's working set


def _warehouse(rows: int, n_parts: int = N_PARTS, name: str = "bt",
               fs: TectonicFS = None) -> Warehouse:
    schema = make_schema(name, 24, 6, seed=11)
    wh = Warehouse(fs or TectonicFS())
    t = wh.create_table(schema)
    t.generate(n_parts, DataGenConfig(rows_per_partition=rows, seed=12),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE))
    return wh


def _run_mix(rows: int, epochs: int, with_antagonist: bool,
             policy: TenantPolicy) -> StripeCache:
    """Interleave a popular 2-partition job with (optionally) an
    antagonist scanning every other partition once per epoch."""
    wh = _warehouse(rows)
    t = wh.table("bt")
    proj = t.schema.logged_ids[:8]
    one = TableReader(t, proj, record_popularity=False).read_rows(
        t.partitions[0], 0, rows
    ).bytes_read
    cache = StripeCache(dram_capacity_bytes=int(3.0 * one),
                        flash_admit_reads=10**9,      # DRAM-only: crisp story
                        tenancy=policy)
    wh.attach_cache(cache)
    hot = TableReader(t, proj, record_popularity=False, tenant="hot")
    scan = TableReader(t, proj, record_popularity=False, tenant="scan")
    for _ in range(epochs):
        for p in HOT_PARTS:
            hot.read_rows(t.partitions[p], 0, rows)
        if with_antagonist:
            for p in range(len(HOT_PARTS), N_PARTS):
                scan.read_rows(t.partitions[p], 0, rows)
    return cache


def _tenancy_isolation(rows: int, epochs: int) -> None:
    guard = TenantPolicy({"hot": TenantShare(dram_frac=0.7)})
    solo = _run_mix(rows, epochs, with_antagonist=False, policy=guard)
    washed = _run_mix(rows, epochs, with_antagonist=True, policy=TenantPolicy())
    guarded = _run_mix(rows, epochs, with_antagonist=True, policy=guard)
    h_solo = solo.tenants["hot"].hit_rate
    h_washed = washed.tenants["hot"].hit_rate
    h_guarded = guarded.tenants["hot"].hit_rate
    emit(
        "tenancy.antagonist_isolation", 0.0,
        f"hot_hit_solo={h_solo:.3f} hot_hit_no_policy={h_washed:.3f} "
        f"hot_hit_with_shares={h_guarded:.3f} "
        f"scan_evictions={guarded.tenants['scan'].dram.evictions} "
        f"hot_evictions={guarded.tenants['hot'].dram.evictions}",
    )
    assert h_solo > 0.5, f"solo run must reuse its working set: {h_solo:.3f}"
    assert abs(h_guarded - h_solo) <= 0.1 * h_solo, (
        f"guaranteed share failed: {h_guarded:.3f} vs solo {h_solo:.3f}"
    )
    assert h_washed < h_guarded, (h_washed, h_guarded)
    # per-tenant accounting closes: resident bytes sum to the tier total
    by_tenant = sum(ts.dram.bytes_stored for ts in guarded.tenants.values())
    assert by_tenant == guarded.dram.bytes_stored, (
        by_tenant, guarded.dram.bytes_stored
    )


def _rewrite_invalidation(rows: int) -> None:
    wh = _warehouse(rows, n_parts=2, name="btr")
    t = wh.table("btr")
    proj = t.schema.logged_ids[:8]
    opts = dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE)
    cache = StripeCache()
    wh.attach_cache(cache)
    r = TableReader(t, proj, record_popularity=False, tenant="job")
    old = r.read_rows(t.partitions[0], 0, rows)
    warm = r.read_rows(t.partitions[0], 0, rows)
    assert warm.bytes_from_cache == warm.bytes_read

    new_batch = generate_partition(
        t.schema, 0, DataGenConfig(rows_per_partition=rows, seed=123)
    )
    t.rewrite_partition(0, new_batch, opts)

    ref_wh = Warehouse()
    ref_t = ref_wh.create_table(t.schema)
    ref_t.write_partition(0, new_batch, opts)
    ref = TableReader(ref_t, proj, record_popularity=False).read_rows(
        ref_t.partitions[0], 0, rows
    )

    def _sig(batch):
        return sorted(
            (fid, float(np.nan_to_num(col).sum())) for fid, col in batch.dense.items()
        )

    fresh = r.read_rows(t.partitions[0], 0, rows)
    again = r.read_rows(t.partitions[0], 0, rows)
    stale_bytes = fresh.bytes_from_cache
    emit(
        "tenancy.rewrite_invalidation", 0.0,
        f"stale_bytes_served={stale_bytes} post_rewrite_storage={fresh.bytes_from_storage} "
        f"reread_cache_hit={again.bytes_from_cache == again.bytes_read}",
    )
    assert stale_bytes == 0, "rewrite must not be served from DRAM/flash"
    assert _sig(fresh.batch) == _sig(ref.batch) != _sig(old.batch)
    assert _sig(again.batch) == _sig(ref.batch)
    assert again.bytes_from_cache == again.bytes_read   # new bytes now cached


def _spec(wh: Warehouse, name: str) -> SessionSpec:
    t = wh.table(name)
    dense = t.schema.dense_ids[:6]
    sparse = t.schema.sparse_ids[:3]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=500)
    return SessionSpec(
        table=name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=256, rows_per_split=256,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )


def _prefetch_stall_cut(rows: int, timeout_s: float) -> None:
    """Same session, storage latency simulated: PR 2 baseline (no
    prefetch) vs the prefetch planner overlapping warehouse I/O."""
    stalls = {}
    for prefetch in (False, True):
        fs = TectonicFS(io_latency_scale=3.0)
        wh = _warehouse(rows, n_parts=2, name="btp",
                        fs=fs)
        svc = DPPService(wh, stripe_cache=StripeCache())
        sess = svc.create_session(
            "job", _spec(wh, "btp"), n_workers=1, n_clients=1,
            prefetch=prefetch, prefetch_depth=16,
        )
        out = sess.run_to_completion(timeout_s=timeout_s)
        assert sum(b["label"].shape[0] for b in out) == 2 * rows
        stalls[prefetch] = sess.clients[0].metrics.stall_s
        if prefetch:
            pm = sess.prefetcher.metrics
            emit(
                "tenancy.prefetch_planner", 0.0,
                f"splits_warmed={pm.splits_warmed} bytes_fetched={pm.bytes_fetched} "
                f"bytes_already_cached={pm.bytes_already_cached} pokes={pm.pokes}",
            )
    cut = stalls[True] / max(stalls[False], 1e-9)
    emit(
        "tenancy.prefetch_stall_cut", 0.0,
        f"stall_baseline_s={stalls[False]:.3f} stall_prefetch_s={stalls[True]:.3f} "
        f"cut={cut:.3f}x",
    )
    assert stalls[True] < stalls[False], (
        f"prefetch must cut client stall_s: {stalls[True]:.3f} vs "
        f"{stalls[False]:.3f}"
    )


def run(quick: bool = False) -> None:
    rows = 512 if quick else 1024
    epochs = 3 if quick else 4
    _tenancy_isolation(rows, epochs)
    _rewrite_invalidation(rows)
    _prefetch_stall_cut(1024 if quick else 2048, timeout_s=60.0 if quick else 120.0)
