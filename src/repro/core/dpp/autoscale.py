"""Feedback-driven elastic scaling for the DPP fleet (ISSUE 4).

InTune's core observation (Nagrecha et al., 2023): static preprocessing
provisioning either starves the trainer (data stalls) or wastes fleet
CPU, because the right worker count depends on the *observed* balance
between produce and consume rates.  The controller closes that loop:

  * **signal** — the clients' stall *rate* (stalled ``get_batch`` calls
    per wait call since the last tick) plus the fleet's buffered-batch
    queue depth.  Stall rate is the trainer-side truth (Table 7);
    queue depth is the leading indicator (an empty buffer means the next
    call stalls).
  * **knobs** — the worker count (launch / drain) and the
    ``PrefetchPlanner`` depth (how many upcoming splits are cache-warmed
    ahead of the workers), so scale-ups both add transform capacity and
    pull storage I/O further off the critical path.
  * **hysteresis** — a knob only moves after ``hysteresis_ticks``
    consecutive ticks of pressure (or idleness), and every action is
    followed by ``cooldown_ticks`` of no-ops so the fleet settles before
    being measured again.  A single transient stall therefore never
    thrashes the pool.

The controller is deliberately pure/deterministic given its observation
stream — the ``DPPSession`` monitor owns the clock and actuation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class ElasticPolicy:
    """Thresholds + gains for the feedback loop."""

    stall_rate_high: float = 0.05    # stalled fraction that means pressure
    queue_low: int = 2               # buffered batches: below = pressure
    queue_high: int = 32             # above (plus idle CPU) = over-provisioned
    util_low: float = 0.3            # drain only when workers are this idle
    scale_up_frac: float = 0.5       # grow by 50% of the fleet (min 1)
    scale_down_frac: float = 0.25    # shrink by 25% of the fleet (min 1)
    min_workers: int = 1
    max_workers: int = 16
    hysteresis_ticks: int = 2        # consecutive ticks before acting
    cooldown_ticks: int = 3          # settle time after every action
    prefetch_depth_min: int = 2
    prefetch_depth_max: int = 32


@dataclasses.dataclass(frozen=True)
class Observation:
    """One monitor tick's view of the session."""

    n_workers: int
    buffered_batches: int
    stall_rate: float                # stalled get_batch fraction this tick
    cpu_util: float                  # fleet busy_s / wall


@dataclasses.dataclass(frozen=True)
class Decision:
    worker_delta: int                # +launch / -drain / 0
    prefetch_depth: Optional[int]    # None = leave the planner alone
    reason: str


def observation_from_delta(delta: Dict[str, float],
                           interval_s: float) -> Observation:
    """Build one tick's ``Observation`` from a registry snapshot delta
    (``Snapshot.delta(prev)``) — counters arrive as per-tick differences,
    gauges as current levels.

    The formulas are exactly the session monitor's original inline
    polling arithmetic, so a controller fed registry deltas emits
    byte-for-byte the same decisions as the PR-4 heuristics (regression
    test in ``tests/test_obs.py``).  Expected names: counters
    ``client.stalls`` / ``client.wait_calls`` / ``fleet.busy_s``, gauges
    ``fleet.buffered_batches`` / ``fleet.active_workers``.
    """
    active = int(delta.get("fleet.active_workers", 0))
    d_waits = max(int(delta.get("client.wait_calls", 0)), 1)
    stall_rate = max(int(delta.get("client.stalls", 0)), 0) / d_waits
    wall = max(interval_s, 1e-6) * max(active, 1)
    cpu_util = min(max(delta.get("fleet.busy_s", 0.0), 0.0) / wall, 1.0)
    return Observation(
        n_workers=active,
        buffered_batches=int(delta.get("fleet.buffered_batches", 0)),
        stall_rate=stall_rate,
        cpu_util=cpu_util,
    )


class ElasticController:
    """Hysteresis-aware scaler: consumes ``Observation``s, emits
    ``Decision``s.  Stateful (tick counters + current prefetch depth) but
    side-effect free — actuation belongs to the session monitor."""

    # single-threaded by contract: only the session monitor thread calls
    # observe(); other threads at most read `depth` (GIL-atomic int), so
    # none of this state takes a lock (REPRO-R001 / racedep allowlist)
    _unshared = ("depth", "_pressure_ticks", "_idle_ticks", "_cooldown")

    def __init__(self, policy: Optional[ElasticPolicy] = None,
                 prefetch_depth: int = 4):
        self.policy = policy or ElasticPolicy()
        self.depth = max(self.policy.prefetch_depth_min,
                         min(prefetch_depth, self.policy.prefetch_depth_max))
        self._pressure_ticks = 0
        self._idle_ticks = 0
        self._cooldown = 0
        self.decisions: List[Decision] = []    # audit trail for benchmarks

    # -- signal classification -------------------------------------------------

    def _under_pressure(self, obs: Observation) -> bool:
        return (
            obs.stall_rate > self.policy.stall_rate_high
            or obs.buffered_batches < self.policy.queue_low
        )

    def _over_provisioned(self, obs: Observation) -> bool:
        return (
            obs.stall_rate == 0.0
            and obs.buffered_batches > self.policy.queue_high
            and obs.cpu_util < self.policy.util_low
            and obs.n_workers > self.policy.min_workers
        )

    # -- the loop --------------------------------------------------------------

    def observe(self, obs: Observation) -> Decision:
        p = self.policy
        if self._under_pressure(obs):
            self._pressure_ticks += 1
            self._idle_ticks = 0
        elif self._over_provisioned(obs):
            self._idle_ticks += 1
            self._pressure_ticks = 0
        else:
            self._pressure_ticks = self._idle_ticks = 0

        if self._cooldown > 0:
            # settle after the last action; signals keep accumulating so a
            # persistent stall acts the tick the cooldown expires
            self._cooldown -= 1
            return self._emit(Decision(0, None, "cooldown"))

        if self._pressure_ticks >= p.hysteresis_ticks:
            self._pressure_ticks = 0
            self._cooldown = p.cooldown_ticks
            delta = min(
                max(1, int(p.scale_up_frac * obs.n_workers)),
                p.max_workers - obs.n_workers,
            )
            self.depth = min(self.depth * 2, p.prefetch_depth_max)
            return self._emit(Decision(
                max(delta, 0), self.depth,
                f"pressure: stall_rate={obs.stall_rate:.3f} "
                f"buffered={obs.buffered_batches}",
            ))

        if self._idle_ticks >= p.hysteresis_ticks:
            self._idle_ticks = 0
            self._cooldown = p.cooldown_ticks
            delta = min(
                max(1, int(p.scale_down_frac * obs.n_workers)),
                obs.n_workers - p.min_workers,
            )
            self.depth = max(self.depth // 2, p.prefetch_depth_min)
            return self._emit(Decision(
                -max(delta, 0), self.depth,
                f"idle: buffered={obs.buffered_batches} "
                f"util={obs.cpu_util:.2f}",
            ))

        return self._emit(Decision(0, None, "steady"))

    def _emit(self, d: Decision) -> Decision:
        if d.worker_delta != 0:
            self.decisions.append(d)
        return d
