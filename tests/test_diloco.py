import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.diloco import DiLoCoConfig, comm_savings, outer_init, outer_step


def test_outer_step_moves_toward_pod_mean():
    cfg = DiLoCoConfig(inner_steps=4, outer_lr=1.0, outer_momentum=0.0, compress_bf16=False)
    params0 = {"w": jnp.zeros(4)}
    state = outer_init(params0)
    # two "pods" diverged to +1 and -3; mean delta = anchor - mean(pods) = 1
    pods = [{"w": jnp.ones(4)}, {"w": -3 * jnp.ones(4)}]

    def mean_over_pods(tree):
        return jax.tree.map(
            lambda *xs: sum(x.astype(jnp.float32) for x in xs) / len(xs),
            *[jax.tree.map(lambda a, p=p: (state["anchor"]["w"] - p["w"]), p) for p in pods],
        )

    # emulate: delta for pod0 = anchor - p0 = -1; pod1 = +3; mean = +1
    new_p, new_s = outer_step(pods[0], state, cfg, lambda d: {"w": jnp.ones(4)})
    np.testing.assert_allclose(np.asarray(new_s["anchor"]["w"]), -1.0)   # 0 - 1*1
    np.testing.assert_allclose(np.asarray(new_p["w"]), -1.0)


def test_momentum_accumulates():
    cfg = DiLoCoConfig(outer_lr=0.5, outer_momentum=0.9, compress_bf16=False)
    params = {"w": jnp.zeros(2)}
    state = outer_init(params)
    p1, s1 = outer_step(params, state, cfg, lambda d: {"w": jnp.ones(2)})
    p2, s2 = outer_step(p1, s1, cfg, lambda d: {"w": jnp.ones(2)})
    # second step moves farther due to momentum
    step1 = abs(float(s1["anchor"]["w"][0]) - 0.0)
    step2 = abs(float(s2["anchor"]["w"][0]) - float(s1["anchor"]["w"][0]))
    assert step2 > step1


def test_comm_savings_math():
    cfg = DiLoCoConfig(inner_steps=32, compress_bf16=True)
    s = comm_savings(cfg, param_bytes=100)
    assert abs(s["reduction_x"] - 64.0) < 1e-6
