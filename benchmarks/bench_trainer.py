"""Table 8 (trainer ingest demand), Fig. 8 (frontend utilization scaling),
Table 7 (colocated preprocessing data stalls)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_us
from repro.core.dpp.simulator import (
    WORKLOADS, colocated_preprocessing_stall, trainer_loading_utilization,
)


def run() -> None:
    for name, w in WORKLOADS.items():
        emit(f"table8.{name}", 0.0,
             f"trainer_throughput={w.trainer_gbps:.2f}GB/s_per_8gpu_node")
    for gbps in (2.0, 5.0, 10.0, 16.5, 20.0):
        u = trainer_loading_utilization(gbps)
        emit(
            f"fig8.loading_at_{gbps:.1f}GBps", 0.0,
            f"cpu={u['cpu']:.2f} mem_bw={u['mem_bw']:.2f} nic={u['nic']:.2f}",
        )
    r = colocated_preprocessing_stall(WORKLOADS["RM1"])
    emit(
        "table7.colocated_RM1", 0.0,
        f"gpu_stall={r['gpu_stall_frac']:.2f} cpu={r['cpu_util']:.2f} "
        f"mem_bw={r['mem_bw_util']:.2f} (paper: 0.56 / 0.92 / 0.54)",
    )

    # measured: local DLRM train-step ingest rate (tensor bytes consumed/s)
    import jax.numpy as jnp
    from repro import configs as cfglib
    from repro.models import build_model
    from repro.optim import OptimizerConfig, adamw_init, adamw_update
    import jax

    cfg = cfglib.get_smoke_config("dlrm-paper")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt_cfg = OptimizerConfig()
    opt = adamw_init(params, opt_cfg)
    specs = model.input_specs(256)
    batch = {k: jnp.ones(v.shape, v.dtype) for k, v in specs.items()}
    nbytes = sum(np.prod(v.shape) * v.dtype.itemsize for v in specs.values())

    @jax.jit
    def step(p, o, b):
        loss, g = jax.value_and_grad(model.loss)(p, b)
        return adamw_update(p, g, o, opt_cfg)

    step(params, opt, batch)  # compile
    us = time_us(lambda: jax.block_until_ready(step(params, opt, batch)), repeat=3)
    emit("table8.measured_dlrm_step", us,
         f"ingest={nbytes/us*1e6/1e9:.3f}GB/s batch=256 (CPU container)")
