"""Build jitted, sharded step functions for any (arch config, shape, mesh).

These are the entry points the trainer, server, dry-run, and roofline all
share: ``make_train_step`` / ``make_prefill_step`` / ``make_decode_step``
return ``(fn, input_shapedtypes, in_shardings)`` ready for
``jax.jit(...).lower(...).compile()``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs as cfglib
from repro.distributed.context import sharding_context
from repro.distributed.sharding import AxisRules, FSDP_RULES, SERVE_RULES, TRAIN_RULES, logical_to_spec
from repro.models import build_model
from repro.models.common import ModelConfig, abstract_params, partition_specs
from repro.models.dlrm import DLRMConfig
from repro.optim import OptimizerConfig, adamw_init, adamw_update


# ---------------------------------------------------------------------------
# Input logical axes
# ---------------------------------------------------------------------------

_INPUT_LOGICAL = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "token": ("batch", None),
    "pos": (),
    "image_embeds": ("batch", None, None),
    "frames": ("batch", "seq", None),
    "dense": ("batch", None),
    "sparse_ids": ("batch", None, None),
    "sparse_mask": ("batch", None, None),
    "label": ("batch",),
}


def batch_shardings(
    model, inputs: Dict[str, Any], rules: AxisRules, mesh: Mesh
) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    for k, v in inputs.items():
        if k == "cache":
            cache_logical = model.cache_logical_axes()
            out[k] = {
                ck: logical_to_spec(cache_logical[ck], rules, mesh, cv.shape)
                for ck, cv in v.items()
            }
        else:
            out[k] = logical_to_spec(_INPUT_LOGICAL[k], rules, mesh, v.shape)
    return out


def to_named(tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree, is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StepBundle:
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    donate_argnums: Tuple[int, ...] = ()

    def jit(self):
        return jax.jit(
            self.fn,
            in_shardings=self.in_shardings,
            donate_argnums=self.donate_argnums,
        )

    def lower(self):
        return self.jit().lower(*self.abstract_args)


def make_train_step(
    cfg: Any,
    mesh: Mesh,
    batch: int,
    seq: int,
    rules: Optional[AxisRules] = None,
    opt_cfg: Optional[OptimizerConfig] = None,
) -> StepBundle:
    if rules is None:
        rules = FSDP_RULES if getattr(cfg, "sharding_profile", "tp") == "fsdp" else TRAIN_RULES
    opt_cfg = opt_cfg or OptimizerConfig()
    model = build_model(cfg)

    if isinstance(cfg, DLRMConfig):
        return _make_dlrm_sparse_train_step(cfg, model, mesh, batch, rules, opt_cfg)

    def train_step(params, opt_state, step_batch):
        with sharding_context(mesh, rules):
            loss, grads = jax.value_and_grad(model.loss)(params, step_batch)
            new_params, new_opt, gnorm = adamw_update(params, grads, opt_state, opt_cfg)
            metrics = {"loss": loss, "grad_norm": gnorm}
            return new_params, new_opt, metrics

    aparams = model.abstract()
    pspecs = partition_specs(model.param_specs(), rules, mesh)
    aopt = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), aparams)
    opt_specs = {
        "mu": pspecs,
        "nu": pspecs,
        "step": P(),
    }
    ainputs = model.input_specs(batch, seq, "train")
    bspecs = batch_shardings(model, ainputs, rules, mesh)
    return StepBundle(
        fn=train_step,
        abstract_args=(aparams, aopt, ainputs),
        in_shardings=(
            to_named(pspecs, mesh),
            to_named(opt_specs, mesh),
            to_named(bspecs, mesh),
        ),
        donate_argnums=(0, 1),
    )


def _make_dlrm_sparse_train_step(cfg, model, mesh, batch, rules, opt_cfg) -> StepBundle:
    """DLRM H-hillclimb step: dense AdamW for MLPs + row-wise AdaGrad sparse
    scatter-updates for the embedding tables (see models/dlrm.py)."""
    from repro.optim.optimizers import wsd_schedule

    def train_step(params, opt_state, step_batch):
        with sharding_context(mesh, rules):
            tables = params["tables"]
            mlp_params = {"bottom": params["bottom"], "top": params["top"]}
            pooled = model.pooled_embeddings_sharded(tables, step_batch, mesh)

            def loss_fn(mp, pl):
                return model.loss_from_pooled(mp, pl, step_batch)

            loss, (g_mlp, dpooled) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
                mlp_params, pooled
            )
            new_mlp, new_adam, gnorm = adamw_update(
                mlp_params, g_mlp, opt_state["adam"], opt_cfg
            )
            lr = wsd_schedule(opt_cfg, opt_state["adam"]["step"] + 1) * 10.0
            new_tables, new_acc = model.sparse_table_update_sharded(
                tables, opt_state["acc"], dpooled, step_batch, lr, mesh
            )
            new_params = {
                "tables": new_tables,
                "bottom": new_mlp["bottom"],
                "top": new_mlp["top"],
            }
            new_opt = {"adam": new_adam, "acc": new_acc}
            return new_params, new_opt, {"loss": loss, "grad_norm": gnorm}

    aparams = model.abstract()
    pspecs = partition_specs(model.param_specs(), rules, mesh)
    mlp_pspecs = {"bottom": pspecs["bottom"], "top": pspecs["top"]}
    aopt = {
        "adam": jax.eval_shape(
            lambda p: adamw_init(p, opt_cfg),
            {"bottom": aparams["bottom"], "top": aparams["top"]},
        ),
        "acc": jax.ShapeDtypeStruct(
            (cfg.num_tables, cfg.vocab_per_table), jnp.float32
        ),
    }
    opt_specs = {
        "adam": {"mu": mlp_pspecs, "nu": mlp_pspecs, "step": P()},
        "acc": pspecs["tables"].__class__(*tuple(pspecs["tables"])[:2]),
    }
    ainputs = model.input_specs(batch, 0, "train")
    bspecs = batch_shardings(model, ainputs, rules, mesh)
    return StepBundle(
        fn=train_step,
        abstract_args=(aparams, aopt, ainputs),
        in_shardings=(
            to_named(pspecs, mesh),
            to_named(opt_specs, mesh),
            to_named(bspecs, mesh),
        ),
        donate_argnums=(0, 1),
    )


def make_prefill_step(
    cfg: Any, mesh: Mesh, batch: int, seq: int, rules: Optional[AxisRules] = None
) -> StepBundle:
    rules = rules or SERVE_RULES
    model = build_model(cfg)

    def prefill_step(params, step_batch):
        with sharding_context(mesh, rules):
            return model.prefill(params, step_batch)

    aparams = model.abstract()
    pspecs = partition_specs(model.param_specs(), rules, mesh)
    ainputs = model.input_specs(batch, seq, "prefill")
    bspecs = batch_shardings(model, ainputs, rules, mesh)
    return StepBundle(
        fn=prefill_step,
        abstract_args=(aparams, ainputs),
        in_shardings=(to_named(pspecs, mesh), to_named(bspecs, mesh)),
    )


def make_decode_step(
    cfg: Any, mesh: Mesh, batch: int, seq: int, rules: Optional[AxisRules] = None
) -> StepBundle:
    rules = rules or SERVE_RULES
    model = build_model(cfg)

    def decode_step(params, step_batch):
        with sharding_context(mesh, rules):
            return model.decode_step(params, step_batch)

    aparams = model.abstract()
    pspecs = partition_specs(model.param_specs(), rules, mesh)
    ainputs = model.input_specs(batch, seq, "decode")
    bspecs = batch_shardings(model, ainputs, rules, mesh)
    return StepBundle(
        fn=decode_step,
        abstract_args=(aparams, ainputs),
        in_shardings=(to_named(pspecs, mesh), to_named(bspecs, mesh)),
        donate_argnums=(),
    )


def make_step(
    arch: str,
    shape_name: str,
    mesh: Mesh,
    smoke: bool = False,
    rules: Optional[AxisRules] = None,
) -> StepBundle:
    """Uniform entry: (arch id, shape id) -> StepBundle."""
    cfg = cfglib.get_smoke_config(arch) if smoke else cfglib.get_config(arch)
    shape = (cfglib.SMOKE_SHAPES if smoke else cfglib.SHAPES)[shape_name]
    if shape.mode == "train":
        return make_train_step(cfg, mesh, shape.global_batch, shape.seq_len, rules)
    if shape.mode == "prefill":
        return make_prefill_step(cfg, mesh, shape.global_batch, shape.seq_len, rules)
    return make_decode_step(cfg, mesh, shape.global_batch, shape.seq_len, rules)
