"""Table 12: the co-designed optimization ladder.

Normalized DPP-worker throughput (rows/s, measured on this CPU) and storage
throughput (useful bytes / simulated HDD time) as each optimization lands:

  Baseline -> +FF -> +FM -> +LO -> +CR -> +FR -> +LS

Baseline emulations are real alternative code paths: map-encoded files
(FF off), a row-format pivot during extraction (FM off), and an
unvectorized per-row transform loop (LO off).
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import emit
from repro.core import dwrf
from repro.core.datagen import DataGenConfig, generate_partition
from repro.core.reader import COALESCE_WINDOW, TableReader, plan_reads
from repro.core.schema import ColumnBatch, SparseColumn, make_schema
from repro.core.tectonic import HDD, TectonicFS
from repro.core.transforms import TransformPipeline, default_dlrm_pipeline
from repro.core.warehouse import Warehouse

ROWS = 4096


def _row_pivot_roundtrip(batch: ColumnBatch) -> ColumnBatch:
    """FM off: materialize row-major dicts then rebuild columns (the costly
    format conversion the in-memory flatmap removed)."""
    rows = []
    for i in range(batch.num_rows):
        row = {}
        for fid, col in batch.dense.items():
            row[("d", fid)] = col[i]
        for fid, col in batch.sparse.items():
            row[("s", fid)] = col.row(i).copy()
        rows.append(row)
    dense = {
        fid: np.array([r[("d", fid)] for r in rows], np.float32)
        for fid in batch.dense
    }
    sparse = {}
    for fid in batch.sparse:
        lists = [r[("s", fid)] for r in rows]
        off = np.zeros(len(lists) + 1, np.int64)
        np.cumsum([len(l) for l in lists], out=off[1:])
        sparse[fid] = SparseColumn(
            offsets=off,
            values=np.concatenate(lists) if lists else np.zeros(0, np.int64),
        )
    return ColumnBatch(batch.num_rows, dense, sparse, batch.labels)


def _slow_transform(pipe: TransformPipeline, batch: ColumnBatch, chunk: int = 8) -> None:
    """LO off: small-chunk transform loop with redundant input copies —
    emulates the pre-LO worker (per-row dispatch, null checks, extra copies
    the paper's localized optimizations removed).  NOTE: on numpy the
    vectorization delta is larger than the paper's C++ LTO/AutoFDO gains;
    we report the measured number with this caveat."""
    import copy as _copy
    for i in range(0, batch.num_rows, chunk):
        sub = batch.slice_rows(i, min(i + chunk, batch.num_rows))
        sub = ColumnBatch(
            sub.num_rows,
            {k: v.copy() for k, v in sub.dense.items()},
            {k: SparseColumn(c.offsets.copy(), c.values.copy(),
                             None if c.scores is None else c.scores.copy())
             for k, c in sub.sparse.items()},
            sub.labels,
        )
        pipe(sub)


def _storage_throughput(table, proj, window, useful_bytes=None, partition=0) -> float:
    """Projection-useful bytes / simulated HDD time for one partition read.

    For map-encoded files the read is the whole stripe but only the
    projection's share is useful, so ``useful_bytes`` (taken from the
    flattened layout's plan) normalizes the comparison the way the paper's
    Table 12 does."""
    meta = table.partitions[partition]
    plan = plan_reads(meta.footer, proj, coalesce_window=window)
    media = HDD
    t = sum(media.io_time_s(l) for _, l in plan.extents)
    useful = useful_bytes if useful_bytes is not None else plan.bytes_wanted
    return useful / max(t, 1e-12)


def run() -> None:
    schema = make_schema("t12", n_dense=400, n_sparse=60, seed=0)
    gen = DataGenConfig(rows_per_partition=ROWS, seed=1)
    rng = np.random.default_rng(0)
    fids = np.array(schema.logged_ids)
    pops = np.array([schema.feature(f).popularity for f in fids]); pops /= pops.sum()
    proj = sorted(rng.choice(fids, size=len(fids) // 9, replace=False, p=pops).tolist())
    dense = [f for f in proj if f in set(schema.dense_ids)][:30]
    sparse = [f for f in proj if f in set(schema.sparse_ids)][:8]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=100_000, n_derived=4)

    wh = Warehouse()
    t_map = wh.create_table(make_schema("t12map", 400, 60, seed=0))
    t_map.generate(1, gen, dwrf.DwrfWriterOptions(flattened=False, stripe_rows=1024))
    t_ff = wh.create_table(make_schema("t12ff", 400, 60, seed=0))
    t_ff.generate(1, gen, dwrf.DwrfWriterOptions(flattened=True, stripe_rows=1024))

    # feature-reordered + large-stripe variants
    for _ in range(3):
        r = TableReader(t_ff, proj)
        r.read_partition(t_ff.partitions[0])
        r.finish_job()
    t_fr = wh.create_table(make_schema("t12fr", 400, 60, seed=0))
    t_fr.popularity = t_ff.popularity
    t_fr.generate(1, gen, dwrf.DwrfWriterOptions(flattened=True, stripe_rows=1024))
    t_ls = wh.create_table(make_schema("t12ls", 400, 60, seed=0))
    t_ls.popularity = t_ff.popularity
    t_ls.generate(1, gen, dwrf.DwrfWriterOptions(flattened=True, stripe_rows=4096))

    n_slow = 512  # rows for the emulated pre-optimization rungs

    def dpp_rate(table, pivot: bool, vectorized: bool) -> float:
        """us/row accounted per ETL phase at consistent row counts."""
        reader = TableReader(table, proj, record_popularity=False)
        t0 = time.perf_counter()
        res = reader.read_partition(table.partitions[0])
        extract_us_row = (time.perf_counter() - t0) / res.batch.num_rows

        pivot_us_row = 0.0
        if pivot:
            t0 = time.perf_counter()
            _row_pivot_roundtrip(res.batch.slice_rows(0, n_slow))
            pivot_us_row = (time.perf_counter() - t0) / n_slow

        t0 = time.perf_counter()
        if vectorized:
            pipe(res.batch)
            tr_us_row = (time.perf_counter() - t0) / res.batch.num_rows
        else:
            _slow_transform(pipe, res.batch.slice_rows(0, n_slow))
            tr_us_row = (time.perf_counter() - t0) / n_slow
        return 1.0 / (extract_us_row + pivot_us_row + tr_us_row)

    useful = plan_reads(t_ff.partitions[0].footer, proj, 0).bytes_wanted
    ladder = []
    ladder.append(("baseline", dpp_rate(t_map, True, False),
                   _storage_throughput(t_map, proj, 0, useful_bytes=useful)))
    ladder.append(("+FF", dpp_rate(t_ff, True, False),
                   _storage_throughput(t_ff, proj, 0)))
    ladder.append(("+FM", dpp_rate(t_ff, False, False),
                   _storage_throughput(t_ff, proj, 0)))
    ladder.append(("+LO", dpp_rate(t_ff, False, True),
                   _storage_throughput(t_ff, proj, 0)))
    ladder.append(("+CR", dpp_rate(t_ff, False, True),
                   _storage_throughput(t_ff, proj, COALESCE_WINDOW)))
    ladder.append(("+FR", dpp_rate(t_fr, False, True),
                   _storage_throughput(t_fr, proj, COALESCE_WINDOW)))
    ladder.append(("+LS", dpp_rate(t_ls, False, True),
                   _storage_throughput(t_ls, proj, COALESCE_WINDOW)))

    base_dpp, base_st = ladder[0][1], ladder[0][2]
    for name, dpp, st_ in ladder:
        emit(
            f"table12.{name}", 0.0,
            f"dpp_throughput={dpp/base_dpp:.2f}x storage_throughput={st_/base_st:.2f}x",
        )
    emit("table12.paper_reference", 0.0,
         "paper DPP: 1.0/2.0/2.3/2.94/2.94/2.94/2.94; "
         "storage: 1.0/0.03/0.03/0.03/0.99/1.84/2.41")
