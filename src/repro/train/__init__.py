from repro.train.embedding_cache import (
    EmbedCacheStats,
    TieredEmbeddingStore,
    make_store_for_model,
)
from repro.train.trainer import StepMetrics, Trainer, TrainerConfig, TrainMetrics
