"""Global training-job scheduler with dataset co-location (§7.3).

The paper observes that the current policy — balance each model's jobs
across all regions — forces every region to replicate every model's
dataset, and calls out the bin-packing opportunity: route jobs so each
dataset lives in few regions, subject to (a) regional compute capacity
covering the model's peak (combo-window) demand and (b) an availability
floor of >=2 regions per dataset.

``greedy_colocate`` implements that policy; ``replication_report``
quantifies storage saved vs replicate-everywhere, reproducing the §7.3
argument quantitatively.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class ModelDemand:
    name: str
    dataset_pb: float
    mean_compute: float       # steady-state GPU units
    peak_compute: float       # combo-window peak (§4.2)


@dataclasses.dataclass
class Region:
    name: str
    capacity: float           # GPU units
    storage_pb: float


@dataclasses.dataclass
class Placement:
    model_regions: Dict[str, List[str]]
    region_load: Dict[str, float]        # mean-compute load
    region_peak: Dict[str, float]

    def replicas(self, model: str) -> int:
        return len(self.model_regions[model])


def replicate_everywhere(models: List[ModelDemand], regions: List[Region]) -> Placement:
    """The paper's current policy (Fig. 6): every region holds every dataset."""
    names = [r.name for r in regions]
    load = {r.name: 0.0 for r in regions}
    peak = {r.name: 0.0 for r in regions}
    for m in models:
        for r in regions:
            load[r.name] += m.mean_compute / len(regions)
            peak[r.name] += m.peak_compute / len(regions)
    return Placement({m.name: list(names) for m in models}, load, peak)


def greedy_colocate(
    models: List[ModelDemand],
    regions: List[Region],
    min_replicas: int = 2,
    headroom: float = 0.9,
) -> Placement:
    """Bin-pack models into the fewest regions whose remaining capacity
    covers the model's peak; large models are placed first."""
    placement: Dict[str, List[str]] = {}
    peak = {r.name: 0.0 for r in regions}
    load = {r.name: 0.0 for r in regions}
    cap = {r.name: r.capacity for r in regions}

    for m in sorted(models, key=lambda m: -m.peak_compute):
        chosen: List[str] = []
        # a model may need several regions if its peak exceeds one region
        needed_peak = m.peak_compute
        candidates = sorted(regions, key=lambda r: peak[r.name])
        for r in candidates:
            if len(chosen) >= min_replicas and needed_peak <= 0:
                break
            room = cap[r.name] * headroom - peak[r.name]
            if room <= 0 and needed_peak > 0:
                continue
            take = min(max(room, 0.0), needed_peak) if needed_peak > 0 else 0.0
            chosen.append(r.name)
            peak[r.name] += take
            needed_peak -= take
        # availability floor
        for r in candidates:
            if len(chosen) >= min_replicas:
                break
            if r.name not in chosen:
                chosen.append(r.name)
        share = 1.0 / len(chosen)
        for name in chosen:
            load[name] += m.mean_compute * share
        placement[m.name] = chosen
    return Placement(placement, load, peak)


def replication_report(
    models: List[ModelDemand], baseline: Placement, packed: Placement
) -> Dict[str, float]:
    base_pb = sum(m.dataset_pb * baseline.replicas(m.name) for m in models)
    packed_pb = sum(m.dataset_pb * packed.replicas(m.name) for m in models)
    return {
        "baseline_storage_pb": base_pb,
        "packed_storage_pb": packed_pb,
        "storage_saved_frac": 1.0 - packed_pb / max(base_pb, 1e-9),
        "max_region_peak_baseline": max(baseline.region_peak.values()),
        "max_region_peak_packed": max(packed.region_peak.values()),
    }


def demands_from_release_sim(jobs, dataset_pb: Dict[str, float]) -> List[ModelDemand]:
    """Build per-model demand profiles from the §4 coordination simulator."""
    from repro.core.coordination import daily_utilization

    by_model: Dict[str, List] = {}
    for j in jobs:
        by_model.setdefault(j.model, []).append(j)
    out = []
    for model, js in by_model.items():
        days = int(max(j.start_day + j.duration_days for j in js)) + 1
        util = daily_utilization(js, days)
        out.append(
            ModelDemand(
                name=model,
                dataset_pb=dataset_pb.get(model, 1.0),
                mean_compute=float(util.mean()),
                peak_compute=float(util.max()),
            )
        )
    return out
