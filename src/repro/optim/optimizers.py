"""In-house optimizers (AdamW / SGD-momentum) + distributed-training helpers.

Optimizer state dtype is configurable: bf16 first/second moments halve the
per-device optimizer footprint on FSDP-sharded giants (405B/1T class) — a
deliberate "optimizer-state compression" knob recorded in EXPERIMENTS.md.

``compress_grads`` casts gradients to bf16 before the cross-pod reduction
(gradient compression for the bandwidth-constrained pod axis, §4.2 of the
paper); AdamW math still runs in fp32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32      # bf16 = optimizer-state compression
    warmup_steps: int = 100
    total_steps: int = 10_000


def wsd_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Warmup-stable-decay schedule."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    decay_start = 0.8 * cfg.total_steps
    frac = jnp.clip(
        (step - decay_start) / jnp.maximum(cfg.total_steps - decay_start, 1), 0.0, 1.0
    )
    decay = 1.0 - 0.9 * frac
    return cfg.learning_rate * warm * decay


def adamw_init(params: Any, cfg: OptimizerConfig) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def compress_grads(grads: Any) -> Any:
    """bf16 gradient compression for cross-pod all-reduce."""
    return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)


def decompress_grads(grads: Any) -> Any:
    return jax.tree.map(lambda g: g.astype(jnp.float32), grads)


def adamw_update(
    params: Any,
    grads: Any,
    state: Any,
    cfg: OptimizerConfig,
) -> Tuple[Any, Any, jax.Array]:
    """Returns (new_params, new_state, grad_norm)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = wsd_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g32
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * jnp.square(g32)
        mhat = mu32 / bc1
        nhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, mu32.astype(cfg.state_dtype), nu32.astype(cfg.state_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "mu": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "nu": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "step": step,
    }
    return new_params, new_state, gnorm
