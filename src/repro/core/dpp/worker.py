"""DPP data plane: stateless Workers (§3.2.1).

Per split: **extract** (read + decrypt + decompress + decode raw stream
chunks, filter unused features), **transform** (per-feature DAG via
high-performance vectorized kernels), and partially **load** (batch into
ready-to-serve tensors kept in a bounded in-memory buffer).

Workers account bytes and CPU-time per ETL phase — the measurements behind
Table 9 ("Storage RX / Transform RX / TX") and Fig. 9's cycle breakdown.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np

from repro.core.dpp.master import DPPMaster, SessionSpec, Split
from repro.core.reader import TableReader
from repro.core.transforms import materialize_dlrm_batch
from repro.core.warehouse import Table


@dataclasses.dataclass
class WorkerMetrics:
    storage_rx_bytes: int = 0          # compressed, from storage
    extract_out_bytes: int = 0         # decoded columnar bytes (transform RX)
    tx_bytes: int = 0                  # materialized tensor bytes (transform TX)
    extract_s: float = 0.0
    transform_s: float = 0.0
    load_s: float = 0.0
    splits_done: int = 0
    rows_done: int = 0

    def merge(self, o: "WorkerMetrics") -> None:
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(o, f.name))

    @property
    def busy_s(self) -> float:
        return self.extract_s + self.transform_s + self.load_s

    def cycle_breakdown(self) -> Dict[str, float]:
        t = max(self.busy_s, 1e-9)
        return {
            "extraction": self.extract_s / t,
            "transformation": self.transform_s / t,
            "load_misc": self.load_s / t,
        }


class DPPWorker:
    """Stateless worker: pulls splits, produces tensor batches into a buffer."""

    def __init__(
        self,
        worker_id: str,
        master: DPPMaster,
        table: Table,
        buffer_size: int = 8,
        fail_after_splits: Optional[int] = None,   # fault-injection hook
        tensor_cache=None,                         # shared TensorCache (§7.5)
    ):
        self.worker_id = worker_id
        self.master = master
        self.table = table
        self.spec = master.spec
        self.pipeline = self.spec.pipeline()       # pulled from Master at startup
        self.buffer: "queue.Queue[Dict[str, np.ndarray]]" = queue.Queue(buffer_size)
        self.metrics = WorkerMetrics()
        self.fail_after_splits = fail_after_splits
        self.tensor_cache = tensor_cache
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.alive = True

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread:
            self._thread.join(timeout)

    # -- main loop ------------------------------------------------------------

    def _run(self) -> None:
        reader = TableReader(
            self.table, list(self.spec.feature_ids), record_popularity=False
        )
        while not self._stop.is_set():
            if (
                self.fail_after_splits is not None
                and self.metrics.splits_done >= self.fail_after_splits
            ):
                self.alive = False  # simulated crash: stop heartbeating
                return
            split = self.master.get_split(self.worker_id)
            if split is None:
                if self.master.finished:
                    break
                time.sleep(0.01)
                continue
            try:
                for batch in self.process_split(reader, split):
                    while not self._stop.is_set():
                        try:
                            self.buffer.put(batch, timeout=0.1)
                            break
                        except queue.Full:
                            continue
                self.master.complete_split(self.worker_id, split.split_id)
            except Exception:
                # let the lease expire; Master re-dispatches
                self.alive = False
                raise
        self.alive = False

    # -- ETL -------------------------------------------------------------------

    def process_split(self, reader: TableReader, split: Split):
        """Extract + transform + batch one split; yields tensor minibatches."""
        meta = self.table.partitions[split.partition]

        if self.tensor_cache is not None:
            from repro.core.dpp.tensor_cache import TensorCache

            key = TensorCache.key(self.spec, split)
            cached = self.tensor_cache.get(key)
            if cached is not None:
                self.metrics.splits_done += 1
                self.metrics.rows_done += split.row_end - split.row_start
                return cached

        t0 = time.perf_counter()
        result = reader.read_partition(meta, row_limit=None)
        cols = result.batch.slice_rows(split.row_start, split.row_end)
        t1 = time.perf_counter()

        env = self.pipeline(cols)
        t2 = time.perf_counter()

        bs = self.spec.batch_size
        n = cols.num_rows
        out = []
        for start in range(0, n, bs):
            stop = min(start + bs, n)
            sub_env = _slice_env(env, start, stop)
            tensors = materialize_dlrm_batch(
                sub_env,
                self.spec.dense_keys,
                self.spec.sparse_keys,
                self.spec.max_ids_per_feature,
                labels=cols.labels[start:stop] if cols.labels is not None else None,
            )
            out.append(tensors)
        t3 = time.perf_counter()

        if self.tensor_cache is not None:
            self.tensor_cache.put(key, out, cpu_s=t3 - t0)

        m = self.metrics
        m.storage_rx_bytes += result.bytes_read
        m.extract_out_bytes += cols.nbytes()
        m.tx_bytes += sum(sum(a.nbytes for a in b.values()) for b in out)
        m.extract_s += t1 - t0
        m.transform_s += t2 - t1
        m.load_s += t3 - t2
        m.splits_done += 1
        m.rows_done += n
        return out

    # -- serving to clients ------------------------------------------------------

    def get_batch(self, timeout: float = 0.5) -> Optional[Dict[str, np.ndarray]]:
        try:
            return self.buffer.get(timeout=timeout)
        except queue.Empty:
            return None

    @property
    def buffered(self) -> int:
        return self.buffer.qsize()


def _slice_env(env: Dict[str, Any], start: int, stop: int) -> Dict[str, Any]:
    from repro.core.schema import SparseColumn

    out = {}
    for k, v in env.items():
        if isinstance(v, SparseColumn):
            off = v.offsets[start: stop + 1]
            out[k] = SparseColumn(
                offsets=off - off[0],
                values=v.values[off[0]: off[-1]],
                scores=v.scores[off[0]: off[-1]] if v.scores is not None else None,
            )
        else:
            out[k] = v[start:stop]
    return out
