"""Differential suite: PallasEngine (interpret mode) vs numpy semantics.

Every fused op code is pinned to the ``core.transforms`` reference on
adversarial inputs (negative ids, ``max_value=1``, empty id-lists,
ragged tile shapes, mixed op-code columns), and a worker-level test pins
the whole DPP path: the same session run with ``engine="numpy"`` and
``engine="pallas"`` must produce byte-identical minibatches.
"""
import numpy as np
import pytest

from repro.core import transforms as T
from repro.core.datagen import DataGenConfig
from repro.core.dpp import DPPService, DPPSession, SessionSpec
from repro.core.engine import (
    FallbackStep,
    FusedWave,
    NumpyEngine,
    PallasEngine,
    compile_pipeline,
    decode_plan,
    make_engine,
)
from repro.core import dwrf
from repro.core.schema import ColumnBatch, SparseColumn, make_schema
from repro.core.transforms import TransformPipeline, TransformSpec
from repro.core.warehouse import Warehouse

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # hypothesis is dev-only; the suite must pass without
    HAVE_HYPOTHESIS = False


# -- helpers -----------------------------------------------------------------


def _col(lists, scores=None):
    lengths = [len(l) for l in lists]
    off = np.zeros(len(lists) + 1, np.int64)
    np.cumsum(lengths, out=off[1:])
    vals = (
        np.concatenate([np.asarray(l, np.int64) for l in lists])
        if lists else np.zeros(0, np.int64)
    )
    sc = (
        np.concatenate([np.asarray(s, np.float32) for s in scores])
        if scores else None
    )
    return SparseColumn(offsets=off, values=vals, scores=sc)


def _assert_column_identical(a, b, key=""):
    if isinstance(a, SparseColumn):
        assert isinstance(b, SparseColumn), key
        np.testing.assert_array_equal(a.offsets, b.offsets, err_msg=key)
        np.testing.assert_array_equal(a.values, b.values, err_msg=key)
        assert a.values.dtype == b.values.dtype, key
        assert (a.scores is None) == (b.scores is None), key
        if a.scores is not None:
            np.testing.assert_array_equal(a.scores, b.scores, err_msg=key)
    else:
        assert a.dtype == b.dtype, key
        np.testing.assert_array_equal(a, b, err_msg=key)


def _assert_engines_identical(specs, batch, **pallas_kw):
    """Run both engines over ``batch``; every env entry must be
    byte-identical.  Returns (numpy_engine, pallas_engine).

    The differential suite pins the actual Pallas kernel (interpret mode
    on CPU), not the XLA oracle the default dispatch picks off-TPU."""
    pipe = TransformPipeline(list(specs))
    pallas_kw.setdefault("use_pallas", True)
    ne, pe = NumpyEngine(pipe), PallasEngine(pipe, **pallas_kw)
    env_n, env_p = ne.run(batch), pe.run(batch)
    assert set(env_n) == set(env_p)
    for k in env_n:
        _assert_column_identical(env_n[k], env_p[k], key=k)
    return ne, pe


ADVERSARIAL_IDS = [
    [-1, -7, 0, 7],
    [2 ** 31 - 1, -(2 ** 31), 1],
    [],                          # empty id list
    [2 ** 40 + 3, -(2 ** 40)],   # beyond int32: exercises 32-bit truncation
    [],
]


# -- per-op differential tests ------------------------------------------------


@pytest.mark.parametrize("max_value", [1, 2, 997, 2 ** 31 - 1])
@pytest.mark.parametrize("salt", [0, 13, 2 ** 31 - 1])
def test_sigrid_hash_differential(salt, max_value):
    batch = ColumnBatch(num_rows=5, dense={}, sparse={0: _col(ADVERSARIAL_IDS)})
    specs = [TransformSpec(
        "SigridHash", ("f0",), "out", (("salt", salt), ("max_value", max_value)),
    )]
    ne, pe = _assert_engines_identical(specs, batch)
    assert pe.stats.fused_features == 1 and pe.stats.kernel_launches == 1


@pytest.mark.parametrize("m", [1, 2, 5, 2 ** 31 - 1])
def test_positive_modulus_differential(m):
    lists = [[-7, 7, -1], [-(2 ** 31), 2 ** 31 - 1], []]
    batch = ColumnBatch(num_rows=3, dense={}, sparse={0: _col(lists)})
    specs = [TransformSpec("PositiveModulus", ("f0",), "out", (("m", m),))]
    ne, pe = _assert_engines_identical(specs, batch)
    assert pe.stats.fused_features == 1


def test_positive_modulus_int64_demotes_to_fallback():
    # ids beyond int32 would wrap in the kernel lane — the engine must
    # demote the feature to numpy at run time and stay byte-identical
    batch = ColumnBatch(
        num_rows=2, dense={}, sparse={0: _col([[2 ** 40, -3], [5]])}
    )
    specs = [TransformSpec("PositiveModulus", ("f0",), "out", (("m", 97),))]
    ne, pe = _assert_engines_identical(specs, batch)
    assert pe.stats.demoted_features == 1
    assert pe.stats.fused_features == 0


@pytest.mark.parametrize("lo,hi", [(-10.0, 10.0), (0.5, 0.5), (-2.0 ** 100, 2.0 ** 100)])
def test_clamp_differential(lo, hi):
    vals = np.array(
        [np.nan, -np.inf, np.inf, 0.0, -10.0, 10.0, 9.999999], np.float32
    )
    batch = ColumnBatch(num_rows=len(vals), dense={0: vals}, sparse={})
    specs = [TransformSpec("Clamp", ("f0",), "out", (("lo", lo), ("hi", hi)))]
    ne, pe = _assert_engines_identical(specs, batch)
    assert pe.stats.fused_features == 1


def test_clamp_subnormal_values_demote_to_fallback():
    # XLA may flush subnormal f32 to zero (FTZ); numpy keeps them — the
    # engine must detect them at pack time and demote, staying identical
    vals = np.array([1e-40, 0.0, 1.0], np.float32)
    batch = ColumnBatch(num_rows=3, dense={0: vals}, sparse={})
    specs = [TransformSpec("Clamp", ("f0",), "out", (("lo", -1.0), ("hi", 1.0)))]
    ne, pe = _assert_engines_identical(specs, batch)
    assert pe.stats.demoted_features == 1 and pe.stats.fused_features == 0


def test_clamp_non_f32_param_falls_back():
    # 0.1 is not exactly representable in float32: f32 clamp could diverge
    # from the float64 numpy clamp, so compile must mark it fallback
    specs = [TransformSpec("Clamp", ("f0",), "out", (("lo", 0.1), ("hi", 1.0)))]
    plan = compile_pipeline(specs)
    assert isinstance(plan.steps[0], FallbackStep)
    batch = ColumnBatch(
        num_rows=3, dense={0: np.array([0.0, 0.1, 0.5], np.float32)}, sparse={}
    )
    _assert_engines_identical(specs, batch)


def test_bucketize_differential_exact_ties():
    borders = np.array([-1.0, 0.0, 0.0, 1.0])     # duplicate border too
    vals = np.array([-1.0, 0.0, 1.0, -2.0, 2.0, np.nan, 0.5], np.float32)
    batch = ColumnBatch(num_rows=len(vals), dense={0: vals}, sparse={})
    specs = [TransformSpec("Bucketize", ("f0",), "out", (("borders", borders),))]
    ne, pe = _assert_engines_identical(specs, batch)
    assert pe.stats.fused_features == 1


def test_bucketize_unsorted_borders_fall_back():
    specs = [TransformSpec(
        "Bucketize", ("f0",), "out",
        (("borders", np.array([1.0, -1.0])),),
    )]
    assert isinstance(compile_pipeline(specs).steps[0], FallbackStep)


def test_all_empty_rows_skip_the_kernel():
    batch = ColumnBatch(num_rows=3, dense={}, sparse={0: _col([[], [], []])})
    specs = [TransformSpec(
        "SigridHash", ("f0",), "out", (("salt", 1), ("max_value", 10)),
    )]
    ne, pe = _assert_engines_identical(specs, batch)
    out = pe.run(batch)["out"]
    assert out.values.size == 0 and out.offsets.tolist() == [0, 0, 0, 0]


def test_wave_feature_count_not_multiple_of_128():
    # 130 hash columns over one input: ragged feature blocks at bc=128
    rng = np.random.default_rng(0)
    lists = [rng.integers(-10 ** 9, 10 ** 9, size=rng.integers(0, 9)).tolist()
             for _ in range(17)]
    batch = ColumnBatch(num_rows=17, dense={}, sparse={0: _col(lists)})
    specs = [
        TransformSpec("SigridHash", ("f0",), f"h{j}",
                      (("salt", j), ("max_value", 1000 + j)))
        for j in range(130)
    ]
    ne, pe = _assert_engines_identical(specs, batch, block_cols=128)
    assert pe.stats.kernel_launches == 1 and pe.stats.fused_features == 130
    assert ne.stats.kernel_launches == 130


def test_wave_rows_not_multiple_of_block():
    rng = np.random.default_rng(1)
    lists = [rng.integers(-100, 100, size=3).tolist() for _ in range(13)]
    batch = ColumnBatch(
        num_rows=13,
        dense={1: rng.normal(0, 2, 13).astype(np.float32)},
        sparse={0: _col(lists)},
    )
    specs = [
        TransformSpec("SigridHash", ("f0",), "h", (("salt", 3), ("max_value", 50))),
        TransformSpec("Clamp", ("f1",), "c", (("lo", -1.0), ("hi", 1.0))),
    ]
    # 39 packed rows (13 rows x 3 ids), block_rows=8, no quantization
    _assert_engines_identical(specs, batch, block_rows=8, row_quantum=1)


def test_mixed_op_code_wave_with_scores():
    """One wave mixing every fused op kind, over ragged columns + scores."""
    rng = np.random.default_rng(2)
    n = 11
    lists = [rng.integers(-10 ** 12, 10 ** 12, size=rng.integers(0, 7)).tolist()
             for _ in range(n)]
    scores = [rng.normal(size=len(l)).astype(np.float32).tolist() for l in lists]
    batch = ColumnBatch(
        num_rows=n,
        dense={
            2: rng.normal(0, 5, n).astype(np.float32),
            3: rng.normal(0, 5, n).astype(np.float32),
        },
        sparse={0: _col(lists, scores), 1: _col([[x % 50] for x in range(n)])},
    )
    specs = [
        TransformSpec("SigridHash", ("f0",), "h", (("salt", 7), ("max_value", 33))),
        TransformSpec("PositiveModulus", ("f1",), "m", (("m", 13),)),
        TransformSpec("Clamp", ("f2",), "c", (("lo", -2.0), ("hi", 2.0))),
        TransformSpec("Bucketize", ("f3",), "b",
                      (("borders", np.linspace(-3, 3, 9)),)),
    ]
    ne, pe = _assert_engines_identical(specs, batch)
    # one sparse-row-class launch (hash+mod) + one dense-row-class launch
    # (clamp+bucketize): co-packing would pad dense columns to nnz height
    assert pe.stats.kernel_launches == 2 and pe.stats.fused_features == 4
    assert [type(s) for s in pe.plan.steps] == [FusedWave, FusedWave]
    assert {len(s.ops) for s in pe.plan.steps} == {2}


def test_chained_waves_with_fallback_between():
    """hash -> (fallback enumerate) -> hash again: waves split correctly."""
    batch = ColumnBatch(
        num_rows=2, dense={}, sparse={0: _col([[5, 6, 7], [8]])}
    )
    specs = [
        TransformSpec("SigridHash", ("f0",), "a", (("salt", 1), ("max_value", 100))),
        TransformSpec("Enumerate", ("a",), "b", ()),
        TransformSpec("SigridHash", ("b",), "c", (("salt", 2), ("max_value", 10))),
    ]
    ne, pe = _assert_engines_identical(specs, batch)
    kinds = [type(s).__name__ for s in pe.plan.steps]
    assert kinds == ["FusedWave", "FallbackStep", "FusedWave"]
    assert pe.stats.kernel_launches == 3     # 2 fused + 1 fallback


def test_output_reassignment_compiles_to_pure_fallback():
    # writing the same key twice relies on sequential-overwrite order,
    # which wave reordering would break — the compiler must refuse to fuse
    specs = [
        TransformSpec("SigridHash", ("f0",), "x", (("salt", 1), ("max_value", 9))),
        TransformSpec("SigridHash", ("x",), "x", (("salt", 2), ("max_value", 9))),
    ]
    plan = compile_pipeline(specs)
    assert all(isinstance(s, FallbackStep) for s in plan.steps)
    batch = ColumnBatch(num_rows=1, dense={}, sparse={0: _col([[3, 4]])})
    _assert_engines_identical(specs, batch)


def test_seed_key_overwritten_after_read_compiles_to_pure_fallback():
    """Review regression: spec B overwrites raw key f0 that spec A reads.
    Sequentially A must see the RAW column; the wave scheduler would defer
    A behind B (f0 "not yet available") and hash B's output instead."""
    specs = [
        TransformSpec("SigridHash", ("f0",), "g", (("salt", 1), ("max_value", 1000))),
        TransformSpec("SigridHash", ("f1",), "f0", (("salt", 2), ("max_value", 1000))),
    ]
    plan = compile_pipeline(specs)
    assert all(isinstance(s, FallbackStep) for s in plan.steps)
    batch = ColumnBatch(
        num_rows=1, dense={}, sparse={0: _col([[3, 4, 5]]), 1: _col([[6, 7]])}
    )
    _assert_engines_identical(specs, batch)


def test_op_code_tables_agree():
    """The op-code table exists in engine.py (jax-import-free), the Pallas
    kernel, and the jnp oracle — they must never drift."""
    import importlib

    from repro.core import engine as E
    from repro.kernels import ref as R

    # the package re-exports the fused_transform FUNCTION; fetch the module
    FT = importlib.import_module("repro.kernels.fused_transform")
    names = {n for n in vars(E) if n.startswith("OP_")}
    assert names == {n for n in vars(FT) if n.startswith("OP_")}
    assert names == {n for n in vars(R) if n.startswith("OP_")}
    for n in names:
        assert getattr(E, n) == getattr(FT, n) == getattr(R, n), n


def test_identity_lanes_pass_through_kernel():
    """OP_IDENTITY differential: a wave mixing identity lanes with real ops
    must leave the identity columns bit-identical to the input — in both
    the Pallas kernel and the jnp oracle.  (Identity lanes are how ragged
    padding rides through a fused wave untouched.)"""
    from repro.kernels import ref as R
    from repro.kernels.ops import fused_transform as K

    rng = np.random.default_rng(7)
    rows, feats = 6, 8
    ids = rng.integers(-2 ** 31, 2 ** 31, size=(rows, feats)).astype(np.int32)
    op_codes = np.array(
        [R.OP_IDENTITY, R.OP_SIGRID_HASH, R.OP_IDENTITY, R.OP_POSITIVE_MODULUS,
         R.OP_IDENTITY, R.OP_CLAMP, R.OP_IDENTITY, R.OP_BUCKETIZE],
        dtype=np.int32,
    )
    param0 = np.array([0, 7, 0, 0, 0, -50, 0, -100], dtype=np.int32)
    param1 = np.array([0, 33, 0, 13, 0, 50, 0, 10], dtype=np.int32)
    got_kernel = np.asarray(K(ids, op_codes, param0, param1, use_pallas=True))
    got_ref = np.asarray(R.fused_transform(ids, op_codes, param0, param1))
    np.testing.assert_array_equal(got_kernel, got_ref)
    identity_lanes = op_codes == R.OP_IDENTITY
    np.testing.assert_array_equal(
        got_kernel[:, identity_lanes], ids[:, identity_lanes]
    )
    # and the non-identity lanes actually transformed something
    assert not np.array_equal(got_kernel[:, ~identity_lanes],
                              ids[:, ~identity_lanes])


def test_xla_oracle_dispatch_matches_interpret_dispatch():
    """use_pallas=None (XLA static-codes oracle off-TPU) and use_pallas=True
    (interpret-mode pallas_call) produce identical bits."""
    rng = np.random.default_rng(5)
    lists = [rng.integers(-(10 ** 12), 10 ** 12, size=rng.integers(0, 6)).tolist()
             for _ in range(9)]
    batch = ColumnBatch(
        num_rows=9,
        dense={1: rng.normal(0, 4, 9).astype(np.float32)},
        sparse={0: _col(lists)},
    )
    specs = [
        TransformSpec("SigridHash", ("f0",), "h", (("salt", 9), ("max_value", 71))),
        TransformSpec("Bucketize", ("f1",), "b",
                      (("borders", np.linspace(-2, 2, 5)),)),
    ]
    pipe = TransformPipeline(specs)
    env_i = PallasEngine(pipe, use_pallas=True).run(batch)
    env_x = PallasEngine(pipe, use_pallas=None).run(batch)
    for k in env_i:
        _assert_column_identical(env_i[k], env_x[k], key=k)


def test_default_dlrm_pipeline_differential(rng):
    """The production-shaped DAG end to end, including generated features."""
    from repro.core.datagen import generate_partition

    s = make_schema("t", 6, 4, seed=0)
    batch = generate_partition(
        s, 0, DataGenConfig(rows_per_partition=300, seed=1)
    )
    pipe = T.default_dlrm_pipeline(
        s.dense_ids, s.sparse_ids, hash_size=500, n_derived=3
    )
    ne, pe = _assert_engines_identical(pipe.specs, batch, row_quantum=256)
    assert pe.stats.fused_features > 0
    assert pe.stats.kernel_launches < ne.stats.kernel_launches


# -- engine construction ------------------------------------------------------


def test_make_engine_resolution():
    pipe = TransformPipeline([])
    assert make_engine(None, pipe).name == "numpy"
    assert make_engine("numpy", pipe).name == "numpy"
    assert make_engine("pallas", pipe).name == "pallas"
    e = NumpyEngine(pipe)
    assert make_engine(e, pipe) is e
    assert make_engine(lambda p: PallasEngine(p), pipe).name == "pallas"
    with pytest.raises(ValueError, match="unknown transform engine"):
        make_engine("cuda", pipe)


# -- compile/decode round-trip + hash-range properties ------------------------
# Hypothesis-driven when available (dev env), seeded sweeps otherwise, so the
# suite passes with only requirements.txt installed.


def _random_fused_dag(rng) -> list:
    specs = []
    for j in range(int(rng.integers(1, 9))):
        k = int(rng.integers(0, 4))
        if k == 0:
            specs.append(TransformSpec(
                "SigridHash", (f"f{j}",), f"o{j}",
                (("salt", int(rng.integers(0, 2 ** 31))),
                 ("max_value", int(rng.integers(1, 2 ** 31)))),
            ))
        elif k == 1:
            specs.append(TransformSpec(
                "PositiveModulus", (f"f{j}",), f"o{j}",
                (("m", int(rng.integers(1, 2 ** 31))),),
            ))
        elif k == 2:
            lo, hi = sorted(
                float(np.float32(x)) for x in rng.normal(0, 100, 2)
            )
            specs.append(TransformSpec(
                "Clamp", (f"f{j}",), f"o{j}", (("lo", lo), ("hi", hi)),
            ))
        else:
            nb = int(rng.integers(1, 17))
            borders = np.sort(rng.normal(0, 3, nb).astype(np.float32))
            specs.append(TransformSpec(
                "Bucketize", (f"f{j}",), f"o{j}", (("borders", borders),),
            ))
    return specs


def _check_roundtrip(specs) -> None:
    plan = compile_pipeline(specs)
    decoded = decode_plan(plan)
    by_out = {s.output: s for s in decoded}
    fused_outputs = {op.spec.output for op in plan.fused_ops}
    for src in specs:
        if src.output not in fused_outputs:
            continue
        dec = by_out[src.output]
        assert dec.op == src.op and dec.inputs == src.inputs
        src_kw, dec_kw = src.kwargs, dec.kwargs
        assert set(src_kw) == set(dec_kw)
        for key, v in src_kw.items():
            if key == "borders":
                np.testing.assert_array_equal(
                    np.asarray(v, np.float32), dec_kw[key]
                )
            else:
                assert dec_kw[key] == v, (key, v, dec_kw[key])


@pytest.mark.parametrize("seed", range(10))
def test_pack_roundtrip_seeded(seed):
    _check_roundtrip(_random_fused_dag(np.random.default_rng(seed)))


def _check_hash_range(ids, salt, max_value) -> None:
    batch = ColumnBatch(num_rows=1, dense={}, sparse={0: _col([ids])})
    spec = TransformSpec(
        "SigridHash", ("f0",), "out", (("salt", salt), ("max_value", max_value)),
    )
    for eng in (
        NumpyEngine(TransformPipeline([spec])),
        PallasEngine(TransformPipeline([spec]), row_quantum=1, use_pallas=True),
        PallasEngine(TransformPipeline([spec]), row_quantum=1),  # XLA oracle
    ):
        out = eng.run(batch)["out"].values
        assert (out >= 0).all() and (out < max_value).all(), eng.name


@pytest.mark.parametrize("seed", range(5))
def test_hash_range_seeded(seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(-(2 ** 62), 2 ** 62, size=int(rng.integers(1, 40))).tolist()
    _check_hash_range(
        ids, int(rng.integers(0, 2 ** 31)), int(rng.integers(1, 2 ** 31))
    )


if HAVE_HYPOTHESIS:

    @given(seed=st.integers(0, 2 ** 32 - 1))
    @settings(max_examples=25, deadline=None)
    def test_pack_roundtrip_hypothesis(seed):
        _check_roundtrip(_random_fused_dag(np.random.default_rng(seed)))

    @given(
        ids=st.lists(st.integers(-(2 ** 63), 2 ** 63 - 1), max_size=64),
        salt=st.integers(0, 2 ** 31 - 1),
        max_value=st.integers(1, 2 ** 31 - 1),
    )
    @settings(max_examples=25, deadline=None)
    def test_hash_range_hypothesis(ids, salt, max_value):
        _check_hash_range(ids, salt, max_value)


# -- worker-level engine parity (the tentpole acceptance test) ----------------


def _table(n_partitions=2, rows=1024):
    s = make_schema("ept", 20, 6, seed=0)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(n_partitions, DataGenConfig(rows_per_partition=rows, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
    return wh, t


def _spec(t):
    dense = t.schema.dense_ids[:6]
    sparse = t.schema.sparse_ids[:3]
    pipe = T.default_dlrm_pipeline(dense, sparse, hash_size=500)
    return SessionSpec(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=256, rows_per_split=256,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )


def test_worker_level_engine_parity():
    """Same session, numpy vs pallas engine: byte-identical minibatches,
    identical over_read_ratio, and fused-engine metrics reported."""
    _, t = _table()
    spec = _spec(t)
    runs = {}
    metrics = {}
    for engine in ("numpy", "pallas"):
        sess = DPPSession(spec, t, n_workers=1, engine=engine)
        runs[engine] = sess.run_to_completion(timeout_s=120)
        metrics[engine] = sess.worker_metrics()

    a, b = runs["numpy"], runs["pallas"]
    assert len(a) == len(b) > 0
    for ba, bb in zip(a, b):
        assert set(ba) == set(bb)
        for k in ba:
            assert ba[k].dtype == bb[k].dtype and ba[k].shape == bb[k].shape
            assert ba[k].tobytes() == bb[k].tobytes(), k

    mn, mp = metrics["numpy"], metrics["pallas"]
    assert mn.over_read_ratio == mp.over_read_ratio
    assert mp.fused_features > 0 and mp.transform_fused_s > 0
    assert mn.fused_features == 0 and mn.fallback_features > 0
    assert mp.kernel_launches < mn.kernel_launches
    assert 0 < mp.fused_frac < 1 and mn.fused_frac == 0


def test_service_threads_engine_to_workers():
    wh, t = _table(n_partitions=1, rows=256)
    service = DPPService(wh, enable_stripe_cache=False)
    sess = service.create_session("job", _spec(t), engine="pallas", n_workers=1)
    assert all(w.engine.name == "pallas" for w in sess.workers)
    batches = sess.run_to_completion(timeout_s=60)
    assert sum(b["label"].shape[0] for b in batches) == 256
