"""Split-scoped streaming read path (ISSUE 1 tentpole).

Stripe-pruned reads must be byte-identical to a full partition read +
row slice, and a multi-split session must read each partition's bytes
roughly once — not once per split.
"""
import numpy as np
import pytest

from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.dpp import DPPMaster, DPPSession, SessionSpec
from repro.core.dpp.simulator import split_over_read_amplification
from repro.core.reader import COALESCE_WINDOW, TableReader, plan_reads
from repro.core.schema import concat_batches, make_schema
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse

ROWS = 1024
STRIPE = 256


def _table(flattened=True, name="rp"):
    s = make_schema(name, 24, 8, seed=3)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(1, DataGenConfig(rows_per_partition=ROWS, seed=4),
               dwrf.DwrfWriterOptions(flattened=flattened, stripe_rows=STRIPE))
    return t


def _assert_batches_identical(a, b):
    assert a.num_rows == b.num_rows
    assert set(a.dense) == set(b.dense) and set(a.sparse) == set(b.sparse)
    for fid in a.dense:
        np.testing.assert_array_equal(
            np.nan_to_num(a.dense[fid]), np.nan_to_num(b.dense[fid])
        )
    for fid in a.sparse:
        np.testing.assert_array_equal(a.sparse[fid].offsets, b.sparse[fid].offsets)
        np.testing.assert_array_equal(a.sparse[fid].values, b.sparse[fid].values)
        if a.sparse[fid].scores is not None:
            np.testing.assert_array_equal(a.sparse[fid].scores, b.sparse[fid].scores)
    if a.labels is not None or b.labels is not None:
        np.testing.assert_array_equal(a.labels, b.labels)


@pytest.mark.parametrize("flattened", [True, False])
@pytest.mark.parametrize("coalesce", [0, COALESCE_WINDOW])
@pytest.mark.parametrize("row_range", [(0, 256), (256, 512), (100, 700), (768, 1024), (0, 1024)])
def test_read_rows_identical_to_full_read_plus_slice(flattened, coalesce, row_range):
    t = _table(flattened)
    proj = t.schema.logged_ids[:10]
    r = TableReader(t, proj, coalesce_window=coalesce)
    meta = t.partitions[0]
    lo, hi = row_range
    full = r.read_partition(meta)
    sub = r.read_rows(meta, lo, hi)
    _assert_batches_identical(sub.batch, full.batch.slice_rows(lo, hi))
    assert sub.bytes_read <= full.bytes_read


@pytest.mark.parametrize("flattened", [True, False])
def test_iter_stripes_concat_identical_to_read_rows(flattened):
    t = _table(flattened)
    proj = t.schema.logged_ids[:10]
    r = TableReader(t, proj)
    meta = t.partitions[0]
    lo, hi = 100, 900
    stripes = list(r.iter_stripes(meta, lo, hi))
    assert [s.stripe_index for s in stripes] == [0, 1, 2, 3]
    assert stripes[0].row_start == lo and stripes[-1].row_end == hi
    got = concat_batches([s.batch for s in stripes])
    ref = r.read_rows(meta, lo, hi)
    _assert_batches_identical(got, ref.batch)
    # streamed byte totals ~match the one-shot plan (per-stripe coalescing
    # can only lose cross-stripe merges, never read less than wanted)
    assert sum(s.bytes_used for s in stripes) == ref.bytes_used
    assert sum(s.bytes_read for s in stripes) >= ref.bytes_used


def test_stripe_read_accounting_is_per_stripe():
    t = _table()
    r = TableReader(t, t.schema.logged_ids[:6])
    meta = t.partitions[0]
    for sr in r.iter_stripes(meta, 0, ROWS):
        assert sr.rows_decoded == STRIPE
        assert sr.row_end - sr.row_start == STRIPE
        assert 0 < sr.bytes_used <= sr.bytes_read


def _session_spec(t, rows_per_split, batch_size=128):
    dense = t.schema.dense_ids[:6]
    sparse = t.schema.sparse_ids[:3]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=500)
    return SessionSpec(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=batch_size, rows_per_split=rows_per_split,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )


def test_storage_rx_regression_4_splits_per_partition():
    """Seed behavior re-read the whole partition once per split; split-scoped
    reads must cut storage RX ~4x for a 4-splits-per-partition session."""
    t = _table(name="rp4")
    spec = _session_spec(t, rows_per_split=ROWS // 4)
    sess = DPPSession(spec, t, n_workers=2)
    batches = sess.run_to_completion(timeout_s=60)
    assert sum(b["label"].shape[0] for b in batches) == ROWS
    m = sess.worker_metrics()
    assert m.splits_done == 4

    full_plan = plan_reads(t.partitions[0].footer, spec.feature_ids,
                           COALESCE_WINDOW)
    seed_rx = 4 * full_plan.bytes_planned     # what the pre-fix path read
    assert m.storage_rx_bytes <= seed_rx / 2  # acceptance: >= 2x better
    # and in fact ~4x: each split reads only its own quarter
    assert m.storage_rx_bytes <= 1.25 * full_plan.bytes_planned


def test_worker_over_read_ratio_is_one_when_stripe_aligned():
    t = _table(name="rp1")
    spec = _session_spec(t, rows_per_split=STRIPE)
    sess = DPPSession(spec, t, n_workers=1)
    sess.run_to_completion(timeout_s=60)
    m = sess.worker_metrics()
    assert m.rows_done == ROWS
    assert m.rows_decoded == ROWS
    assert m.stripes_read == ROWS // STRIPE
    assert m.over_read_ratio == 1.0


def test_master_builds_stripe_aligned_splits():
    spec = _session_spec(_table(name="rpa"), rows_per_split=300)
    # stripe 256: 300 rows/split rounds up to 512 (2 stripes per split)
    m = DPPMaster(spec, {0: ROWS}, partition_stripe_rows={0: STRIPE})
    splits = sorted(m._splits.values(), key=lambda s: s.row_start)
    assert [(s.row_start, s.row_end) for s in splits] == [(0, 512), (512, 1024)]
    # without stripe metadata the legacy split shape is preserved
    m2 = DPPMaster(spec, {0: ROWS})
    assert len(m2._splits) == -(-ROWS // 300)


def test_checkpoint_restore_preserves_stripe_alignment():
    spec = _session_spec(_table(name="rpc"), rows_per_split=300)
    m = DPPMaster(spec, {0: ROWS}, partition_stripe_rows={0: STRIPE})
    s1 = m.get_split("w0"); m.complete_split("w0", s1.split_id)
    m2 = DPPMaster.restore(m.checkpoint(), {0: ROWS})
    assert len(m2._splits) == len(m._splits)
    assert {(s.row_start, s.row_end) for s in m2._splits.values()} == \
           {(s.row_start, s.row_end) for s in m._splits.values()}


def test_map_plan_skips_labels_when_excluded():
    """Regression (ISSUE 10): ``plan_reads(include_labels=False)`` on a
    map-encoded file still planned the labels streams, inflating
    bytes_wanted for every label-free projection (projection_stats,
    prefetch sizing)."""
    t = _table(flattened=False, name="rpml")
    footer = t.partitions[0].footer
    proj = t.schema.logged_ids[:10]
    with_labels = plan_reads(footer, proj, 0, include_labels=True)
    without = plan_reads(footer, proj, 0, include_labels=False)
    assert any(s.kind == "labels" for _, _, s in with_labels.wanted)
    assert not any(s.kind == "labels" for _, _, s in without.wanted)
    label_bytes = sum(
        s.length for st in footer.stripes for s in st.streams
        if s.kind == "labels"
    )
    assert label_bytes > 0
    assert without.bytes_wanted == with_labels.bytes_wanted - label_bytes
    # projection_stats consumes the label-free plan: bytes_used must not
    # count label bytes the projection never asked for
    r = TableReader(t, proj)
    stats = r.projection_stats()
    assert stats["bytes_used"] == float(without.bytes_wanted)


def test_iter_stripes_reports_io_sizes():
    """Regression (ISSUE 10): ``StripeRead`` carried no per-extent I/O
    sizes, so streaming consumers lost the Table-6 size histogram that
    ``read_rows`` reports."""
    t = _table(name="rpio")
    meta = t.partitions[0]
    proj = t.schema.logged_ids[:10]
    for window in (0, COALESCE_WINDOW):
        r = TableReader(t, proj, coalesce_window=window)
        for sr in r.iter_stripes(meta, 0, ROWS):
            assert sr.io_sizes
            assert sum(sr.io_sizes) == sr.bytes_read
        if window:
            # coalescing merges the per-stream extents into a few I/Os
            assert len(sr.io_sizes) < len(proj)


def test_split_over_read_amplification_model():
    # pre-fix path: amplification = splits per partition
    assert split_over_read_amplification(ROWS, ROWS // 4, STRIPE,
                                         split_scoped=False) == 4.0
    # split-scoped + stripe-aligned: no over-read
    assert split_over_read_amplification(ROWS, ROWS // 4, STRIPE) == 1.0
    # split-scoped but unaligned: bounded stripe-edge waste only
    amp = split_over_read_amplification(ROWS, 300, STRIPE, stripe_aligned=False)
    assert 1.0 < amp < 2.0


# ---------------------------------------------------------------------------
# DWRF round-trip parity (ISSUE 10 satellites)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:      # hypothesis is dev-only; the suite must pass without
    HAVE_HYPOTHESIS = False

from repro.core.schema import ColumnBatch, SparseColumn


def _bits(a):
    return (a.view(np.int32) if a.dtype == np.float32 else a).tobytes()


def _assert_batches_bit_identical(a, b):
    """Stricter than ``_assert_batches_identical``: exact bit patterns
    (NaN payloads included) and scores *presence* — the lossy axis the
    legacy sparse_map layout collapsed."""
    assert a.num_rows == b.num_rows
    assert set(a.dense) == set(b.dense) and set(a.sparse) == set(b.sparse)
    for fid in a.dense:
        assert _bits(a.dense[fid]) == _bits(b.dense[fid])
    for fid in a.sparse:
        x, y = a.sparse[fid], b.sparse[fid]
        assert _bits(x.offsets) == _bits(y.offsets)
        assert _bits(x.values) == _bits(y.values)
        assert (x.scores is None) == (y.scores is None), fid
        if x.scores is not None:
            assert _bits(x.scores) == _bits(y.scores)
    assert (a.labels is None) == (b.labels is None)
    if a.labels is not None:
        assert _bits(a.labels) == _bits(b.labels)


def _random_batch(seed):
    """Random batch over the decoder's dispatch space: 0-row/ragged row
    counts, empty/partial/full dense presence, 0-nnz features, and every
    scores shape (absent, present, present-but-empty)."""
    rng = np.random.default_rng(seed)
    rows = int(rng.choice([0, 1, 7, 64, 257]))
    dense = {}
    for f in range(int(rng.integers(0, 4))):
        col = np.full(rows, np.nan, np.float32)
        present = rng.random(rows) < rng.choice([0.0, 0.5, 1.0])
        col[present] = rng.standard_normal(int(present.sum())).astype(np.float32)
        dense[f] = col
    sparse = {}
    for f in range(10, 10 + int(rng.integers(0, 4))):
        counts = rng.integers(0, int(rng.choice([1, 4])), rows) \
            if rows else np.zeros(0, np.int64)
        off = np.zeros(rows + 1, np.int64)
        np.cumsum(counts, out=off[1:])
        vals = rng.integers(0, 1 << 40, int(off[-1])).astype(np.int64)
        scored = bool(rng.integers(0, 2))
        sc = rng.random(int(off[-1])).astype(np.float32) if scored else None
        sparse[f] = SparseColumn(offsets=off, values=vals, scores=sc)
    labels = rng.random(rows).astype(np.float32) \
        if rng.integers(0, 2) else None
    return ColumnBatch(num_rows=rows, dense=dense, sparse=sparse, labels=labels)


def _decode_whole_file(f):
    parts = []
    for stripe in f.footer.stripes:
        fetch = {(s.fid, s.kind): f.data[s.offset: s.offset + s.length]
                 for s in stripe.streams}
        fids = sorted({s.fid for s in stripe.streams if s.fid >= 0})
        if not f.footer.flattened:
            fids = f.footer.feature_order
        parts.append(dwrf.decode_stripe_features(stripe, fetch, fids))
    return concat_batches(parts) if parts else None


def _check_roundtrip(seed, flattened, codec):
    batch = _random_batch(seed)
    f = dwrf.write_dwrf(batch, dwrf.DwrfWriterOptions(
        flattened=flattened, stripe_rows=64, codec=codec))
    got = _decode_whole_file(f)
    if got is None:
        assert batch.num_rows == 0
        return
    # flattened files only materialize features that exist in the batch;
    # dense features with no sparse twin etc. all round-trip exactly
    _assert_batches_bit_identical(batch, got)


@pytest.mark.parametrize("flattened", [True, False])
@pytest.mark.parametrize("codec", ["raw", "zlib"])
@pytest.mark.parametrize("seed", range(8))
def test_dwrf_roundtrip_bit_identical_seeded(flattened, codec, seed):
    _check_roundtrip(seed, flattened, codec)


if HAVE_HYPOTHESIS:

    @given(seed=st.integers(0, 2 ** 32 - 1),
           flattened=st.booleans(),
           codec=st.sampled_from(["raw", "zlib"]))
    @settings(max_examples=40, deadline=None)
    def test_dwrf_roundtrip_bit_identical_hypothesis(seed, flattened, codec):
        _check_roundtrip(seed, flattened, codec)


def test_map_roundtrip_preserves_empty_scores_presence():
    """Regression (ISSUE 10): the legacy sparse_map layout inferred scores
    presence from array length, so a scored feature hitting a 0-nnz stripe
    decoded with ``scores=None`` on the map path (diverging from the
    flattened encoding of the same batch).  The v2 layout carries an
    explicit presence flag."""
    rows = 32
    off = np.zeros(rows + 1, np.int64)            # 0 nnz everywhere
    batch = ColumnBatch(
        num_rows=rows, dense={},
        sparse={7: SparseColumn(offsets=off,
                                values=np.zeros(0, np.int64),
                                scores=np.zeros(0, np.float32))},
        labels=None,
    )
    for flattened in (True, False):
        f = dwrf.write_dwrf(batch, dwrf.DwrfWriterOptions(
            flattened=flattened, stripe_rows=rows, codec="raw"))
        got = _decode_whole_file(f)
        assert got.sparse[7].scores is not None, f"flattened={flattened}"
        assert len(got.sparse[7].scores) == 0
    # and the v2 blob is self-describing: its first packed array is the
    # format sentinel, so legacy readers can never misparse it as fids
    stream = next(s for s in f.footer.stripes[0].streams
                  if s.kind == "sparse_map")
    payload = dwrf.decode_stream(f.data[stream.offset: stream.offset + stream.length])
    arrays = dwrf._unpack_arrays(payload)
    assert int(arrays[0][0]) == dwrf.SPARSE_MAP_V2
    fids, flags, base = dwrf.sparse_map_layout(arrays)
    assert list(fids) == [7] and list(flags) == [True] and base == 3
