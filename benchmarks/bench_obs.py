"""Telemetry layer: disabled-tracer overhead + Table-7 stall attribution.

Two demonstrations, matching the observability acceptance criteria:

  (a) **zero-cost-when-disabled** — the hot paths guard instants with
      ``if tracer.enabled:`` (one attribute read when off) and open spans
      through the no-op ``NULL_TRACER`` context manager.  Both primitives
      are microbenched, then bounded against a real untraced DPP run:
      worst-case disabled overhead (every span site billed at the no-op
      with-span cost) must stay <= 2% of the run's wall clock.
  (b) **stall attribution end to end** — the same workload traced with a
      live ``Tracer`` produces an artifact that passes the report's
      ``--check`` gate; the per-tenant Table-7 table is embedded in
      ``BENCH_quick.json`` via ``emit_report``.
"""
from __future__ import annotations

import json
import os
import tempfile
import time

from benchmarks.common import emit, emit_report, time_us
from repro.core.dpp import DPPService
from repro.core.tectonic import TectonicFS
from repro.core.warehouse import Warehouse
from repro.obs import NULL_TRACER, Tracer
from repro.obs.report import build_report, check
from repro.obs.smoke import _make_table, _spec

OVERHEAD_BUDGET_PCT = 2.0


def _null_primitives(n: int = 100_000):
    """(guard_us, with_us): per-call cost of the two disabled-path shapes."""
    tracer = NULL_TRACER

    def guard_loop() -> None:
        for _ in range(n):
            if tracer.enabled:
                tracer.record("x", 0.0, 1.0)

    def with_loop() -> None:
        for _ in range(n):
            with tracer.span("x"):
                pass

    return time_us(guard_loop) / n, time_us(with_loop) / n


def _session_wall(rows: int, tracer, tag: str):
    """One 2-worker session over a fresh warehouse; returns (wall_s, svc)."""
    wh = Warehouse(TectonicFS(io_latency_scale=0.5))
    table = _make_table(wh, f"obs_bench_{tag}", 2, rows)
    svc = DPPService(wh, tracer=tracer)
    svc.create_session("bench", _spec(table), n_workers=2)
    t0 = time.perf_counter()
    results = svc.run_all(timeout_s=120)
    wall = time.perf_counter() - t0
    assert results["bench"], "bench session delivered no batches"
    return wall, svc


def run(quick: bool = False) -> None:
    rows = 512 if quick else 1024

    # (a) disabled-path cost, microbenched then bounded against real wall
    guard_us, with_us = _null_primitives()
    emit("obs.null_guard", guard_us, "per-site_us")
    emit("obs.null_span", with_us, "per-site_us")

    wall_off, _ = _session_wall(rows, NULL_TRACER, "off")
    tracer = Tracer()
    wall_on, svc = _session_wall(rows, tracer, "on")
    n_spans = len(tracer.spans())
    # worst case: every span the traced run recorded billed at the no-op
    # with-span cost on the disabled run's wall clock
    overhead_pct = 100.0 * (n_spans * with_us * 1e-6) / max(wall_off, 1e-9)
    emit(
        "obs.disabled_overhead", with_us * n_spans,
        f"spans={n_spans} wall_off_s={wall_off:.2f} wall_on_s={wall_on:.2f} "
        f"overhead_pct={overhead_pct:.4f}",
    )
    assert overhead_pct <= OVERHEAD_BUDGET_PCT, (
        f"disabled-tracer overhead bound {overhead_pct:.3f}% exceeds "
        f"{OVERHEAD_BUDGET_PCT}% budget"
    )

    # (b) the traced run's artifact must pass the report gate; embed the
    # Table-7 rows into BENCH_quick.json
    fd, path = tempfile.mkstemp(prefix="obs_bench_", suffix=".json")
    os.close(fd)
    try:
        metrics = {
            "tenants": {
                name: sess.registry.snapshot().values
                for name, sess in svc.sessions.items()
            },
            "cache": svc.tenant_summary(),
        }
        tracer.write(path, metrics=metrics)
        with open(path, "r", encoding="utf-8") as f:
            doc = json.load(f)
    finally:
        os.unlink(path)
    errs = check(doc)
    assert errs == [], f"trace artifact failed report checks: {errs}"
    report = build_report(doc)
    emit_report("obs.stall_attribution", report)
    blocked = 100.0 - report["ALL"]["compute_pct"]
    emit(
        "obs.stall_attribution", report["ALL"]["wall_us"],
        f"events={len(doc['traceEvents'])} blocked_pct={blocked:.2f} "
        f"fused_frac={report['ALL']['fused_frac']:.2f}",
    )
