"""MetricsRegistry: the metric dataclasses behind one snapshot/delta API.

Each layer registers its metrics source once (a metric dataclass, a
callable returning one, or a callable returning a plain number with an
explicit kind); ``snapshot()`` flattens everything into one
``{dotted.name: value}`` mapping with per-name counter/gauge typing taken
from the field metadata (:mod:`repro.obs.meta`).

``Snapshot.delta(prev)`` is the consumer contract the ``DPPSession``
monitor runs on: counters diff against the previous snapshot (a missing
previous value reads as 0, matching a from-zero start), gauges pass
through their current level.  ``ElasticController`` observations are
rebuilt from exactly these deltas (``autoscale.observation_from_delta``),
replacing the monitor's ad-hoc polling while keeping its decisions
byte-for-byte identical.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs.meta import flatten_metrics


@dataclasses.dataclass(frozen=True)
class Snapshot:
    """Immutable point-in-time view: flat values + per-name kinds."""

    values: Dict[str, float]
    kinds: Dict[str, str]          # name -> "counter" | "gauge"

    def get(self, name: str, default: float = 0.0) -> float:
        return self.values.get(name, default)

    def delta(self, prev: Optional["Snapshot"] = None) -> Dict[str, float]:
        """Per-name change since ``prev``: counters are diffed (missing
        previous = 0), gauges report their current level."""
        pv = prev.values if prev is not None else {}
        out: Dict[str, float] = {}
        for name, v in self.values.items():
            if self.kinds.get(name) == "gauge":
                out[name] = v
            else:
                out[name] = v - pv.get(name, 0)
        return out


EMPTY_SNAPSHOT = Snapshot(values={}, kinds={})


class MetricsRegistry:
    """Named metric sources, snapshotted on demand.

    Sources are re-read on every ``snapshot()`` call, so registering a
    getter (``registry.register("worker", sess.worker_metrics)``) always
    reflects the live fleet — including workers that crashed into the
    graveyard since the last tick.
    """

    def __init__(self):
        self._lock = threading.Lock()
        # name -> zero-arg callable returning a metric dataclass
        self._sources: List[Tuple[str, Callable[[], Any]]] = []
        # dotted name -> (kind, zero-arg callable returning a number)
        self._values: List[Tuple[str, str, Callable[[], float]]] = []

    def register(self, name: str, source: Any) -> None:
        """Register a metric dataclass (or a zero-arg callable returning
        one) under ``name``; its declared fields snapshot as
        ``name.field`` (nested metrics as ``name.outer.inner``)."""
        fn = source if callable(source) else (lambda s=source: s)
        with self._lock:
            self._sources.append((name, fn))

    def register_value(self, name: str, fn: Callable[[], float],
                       kind: str = "gauge") -> None:
        """Register one computed scalar under a dotted name — for derived
        signals no dataclass owns (fleet queue depth, active workers)."""
        if kind not in ("counter", "gauge"):
            raise ValueError(f"bad metric kind {kind!r}")
        with self._lock:
            self._values.append((name, kind, fn))

    def snapshot(self) -> Snapshot:
        with self._lock:
            sources = list(self._sources)
            values = list(self._values)
        flat: Dict[str, float] = {}
        kinds: Dict[str, str] = {}
        for name, fn in sources:
            obj = fn()
            if not dataclasses.is_dataclass(obj):
                raise TypeError(
                    f"source {name!r} returned {type(obj).__name__}, "
                    "expected a metric dataclass"
                )
            for field_name, kind, v in flatten_metrics(obj, f"{name}."):
                flat[field_name] = v
                kinds[field_name] = kind
        for name, kind, fn in values:
            flat[name] = fn()
            kinds[name] = kind
        return Snapshot(values=flat, kinds=kinds)
