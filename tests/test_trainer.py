import numpy as np
import pytest

from repro import configs as cfglib
from repro.optim import OptimizerConfig
from repro.train import Trainer, TrainerConfig


def _batches(cfg, n, bs=32, seed=0):
    # labels are a fixed linear function of the dense features (not random
    # coin flips), so a fit over a few dozen steps has signal to learn and
    # the decreasing-loss assertion is deterministic rather than marginal
    rng = np.random.default_rng(seed)
    w = np.random.default_rng(1234).normal(0, 1, cfg.num_dense).astype(np.float32)
    for _ in range(n):
        dense = rng.normal(0, 1, (bs, cfg.num_dense)).astype(np.float32)
        yield {
            "dense": dense,
            "sparse_ids": rng.integers(0, cfg.vocab_per_table,
                                       (bs, cfg.num_tables, cfg.max_ids_per_feature)).astype(np.int32),
            "sparse_mask": np.ones((bs, cfg.num_tables, cfg.max_ids_per_feature), np.float32),
            "label": (dense @ w > 0).astype(np.float32),
        }


def test_fit_decreases_loss(tmp_path):
    cfg = cfglib.get_smoke_config("dlrm-paper")
    tr = Trainer(cfg, OptimizerConfig(learning_rate=1e-2, warmup_steps=2, total_steps=40),
                 TrainerConfig(max_steps=40, checkpoint_dir=str(tmp_path)))
    state = tr.fit(_batches(cfg, 40))
    losses = [m.loss for m in tr.history]
    assert losses[-1] < losses[0]
    assert state["step"] == 40


def test_resume_from_checkpoint(tmp_path):
    cfg = cfglib.get_smoke_config("dlrm-paper")
    opt = OptimizerConfig(learning_rate=1e-2, warmup_steps=2, total_steps=40)
    tr1 = Trainer(cfg, opt, TrainerConfig(max_steps=20, checkpoint_dir=str(tmp_path),
                                          checkpoint_every=10))
    tr1.fit(_batches(cfg, 20))
    tr2 = Trainer(cfg, opt, TrainerConfig(max_steps=30, checkpoint_dir=str(tmp_path),
                                          checkpoint_every=10))
    state = tr2.fit(_batches(cfg, 30, seed=1))
    assert tr2.history[0].step == 21        # resumed, not restarted
    assert state["step"] == 30


def test_stall_accounting():
    cfg = cfglib.get_smoke_config("dlrm-paper")
    tr = Trainer(cfg, OptimizerConfig(warmup_steps=1, total_steps=5),
                 TrainerConfig(max_steps=5))
    import time

    def slow():
        for b in _batches(cfg, 5):
            time.sleep(0.05)
            yield b

    tr.fit(slow())
    assert tr.stall_fraction() > 0.05
