"""LM training through the DSI pipeline: a token corpus stored as DWRF
columnar partitions on simulated Tectonic, selectively read, packed into
fixed-length sequences, and fed to a smoke-scale qwen3 model.

  PYTHONPATH=src python examples/lm_data_pipeline.py
"""
from repro import configs as cfglib
from repro.core import tokens as T
from repro.core.warehouse import Warehouse
from repro.optim import OptimizerConfig
from repro.train import Trainer, TrainerConfig


def main():
    cfg = cfglib.get_smoke_config("qwen3-8b")
    wh = Warehouse()
    table = T.build_corpus(wh, n_partitions=3, docs_per_partition=96,
                           vocab_size=cfg.vocab_size, seed=0)
    print(f"corpus: {table.total_rows} docs, {table.total_bytes/1e6:.1f} MB on "
          f"{len(table.fs.nodes)} storage nodes")

    batches = T.lm_batches_from_table(table, seq_len=128, batch_size=8)
    trainer = Trainer(
        cfg,
        OptimizerConfig(learning_rate=3e-3, warmup_steps=5, total_steps=40),
        TrainerConfig(max_steps=40),
    )
    trainer.fit(batches)
    losses = [m.loss for m in trainer.history]
    print(f"steps={len(losses)} loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    st = table.fs.stats
    print(f"storage I/O: {st.num_ios} reads, {st.bytes_read/1e6:.1f} MB, "
          f"effective {st.effective_throughput_MBps:.0f} MB/s (HDD model)")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
