#!/usr/bin/env bash
# Tier-1 gate: the whole suite + benchmark smoke, one command.
#   ./scripts/ci.sh [extra pytest args...]
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
# benchmark smoke: every bench module must import; quick-capable sections run
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m benchmarks.run --quick
# doc drift: every path / python -m command the docs reference must exist
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/check_docs.py
