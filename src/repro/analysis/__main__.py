"""CLI for the invariant gate.

  PYTHONPATH=src python -m repro.analysis               # run the gate
  PYTHONPATH=src python -m repro.analysis --list-rules  # rule catalog
  PYTHONPATH=src python -m repro.analysis --write-baseline
  PYTHONPATH=src python -m repro.analysis --json        # JSON lines

Exit status: 0 when every finding is baselined or suppressed, 1 when new
findings exist, 2 on usage errors.  ``scripts/ci.sh`` runs this between
pytest and the benchmark smoke.

``--json`` emits one JSON object per finding (``status`` is ``"new"`` or
``"baselined"``) instead of the human rendering — same exit codes — so
CI artifacts and ``bench_diff``-style tooling can consume the gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional

from repro.analysis.core import (
    all_rules,
    load_baseline,
    run_checks,
    write_baseline,
)

DEFAULT_BASELINE = "scripts/analysis_baseline.txt"


def _find_repo(start: Path) -> Path:
    """The repo root is wherever ``src/repro`` lives: try cwd (how CI
    invokes us), then walk up from the installed package location."""
    if (start / "src" / "repro").is_dir():
        return start
    here = Path(__file__).resolve()
    for p in here.parents:
        if (p / "src" / "repro").is_dir():
            return p
    return start


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repo-native invariant linter (lock discipline, clock "
                    "injection, kernel parity, metrics contract, thread "
                    "hygiene)",
    )
    ap.add_argument("--root", type=Path, default=None,
                    help="repo root (default: auto-detected)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help=f"baseline file (default: <root>/{DEFAULT_BASELINE})")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: report every finding as new")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept current findings into the baseline and exit 0")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (default: all)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("--json", action="store_true",
                    help="emit findings as JSON lines (machine-readable)")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress the per-known-finding lines")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rid, summary in all_rules().items():
            print(f"{rid}  {summary}")
        return 0

    repo = _find_repo(args.root or Path.cwd())
    if not (repo / "src" / "repro").is_dir():
        print(f"repro.analysis: no src/repro under {repo}", file=sys.stderr)
        return 2
    baseline_path = args.baseline or (repo / DEFAULT_BASELINE)
    rules = None
    if args.rules:
        rules = [r.strip() for r in args.rules.split(",") if r.strip()]
    try:
        new, known = run_checks(
            repo, rules=rules,
            baseline=None if args.no_baseline else load_baseline(baseline_path),
        )
    except ValueError as e:
        print(f"repro.analysis: {e}", file=sys.stderr)
        return 2

    if args.write_baseline:
        write_baseline(baseline_path, new + known)
        print(f"repro.analysis: baselined {len(new) + len(known)} finding(s) "
              f"-> {baseline_path}")
        return 0

    if args.json:
        for status, batch in (("new", new), ("baselined", known)):
            for f in batch:
                print(json.dumps({
                    "rule": f.rule, "path": f.path, "line": f.line,
                    "symbol": f.symbol, "message": f.message,
                    "key": f.key, "status": status,
                }, sort_keys=True))
        return 1 if new else 0
    for f in new:
        print(f.render())
    if known and not args.quiet:
        for f in known:
            print(f"{f.render()}  (baselined)")
    n_rules = len(all_rules())
    if new:
        print(f"repro.analysis: {len(new)} new finding(s) "
              f"({len(known)} baselined) across {n_rules} rules — FAIL")
        return 1
    print(f"repro.analysis: ok — 0 new findings "
          f"({len(known)} baselined) across {n_rules} rules")
    return 0


if __name__ == "__main__":
    sys.exit(main())
