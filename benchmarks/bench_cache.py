"""Shared stripe-cache + dedup tier (ISSUE 2 tentpole; §5.2 / §7.2).

A combo-window workload — several concurrent DPP sessions over shared
partitions — measured three ways:

  * storage RX with vs without the shared ``StripeCache`` (acceptance:
    cached ≤ 0.6x the no-cache baseline for ≥2 overlapping sessions),
  * byte-identity of the served batches against the uncached read path,
    with ``over_read_ratio == 1.0`` for stripe-aligned sessions,
  * IOPS/W for HDD-only vs HDD+flash-cache vs SSD-only on the same
    extent trace (the §7.2 326%-IOPS/W-at-9%-capacity/W trade).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from benchmarks.common import emit
from repro.core import dwrf
from repro.core.cache import StripeCache, iops_per_watt
from repro.core.datagen import DataGenConfig
from repro.core.dpp import DPPService, SessionSpec
from repro.core.dpp.simulator import CacheTierSpec, RM1, dsi_power_split
from repro.core.schema import make_schema
from repro.core.tectonic import HDD, SSD, TectonicFS
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse

ROWS = 2048
STRIPE = 256
N_SESSIONS = 3


def _warehouse(rows: int, media=HDD) -> Warehouse:
    schema = make_schema("bc", 32, 8, seed=7)
    wh = Warehouse(TectonicFS(media=media))
    t = wh.create_table(schema)
    t.generate(2, DataGenConfig(rows_per_partition=rows, seed=8),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE))
    return wh


def _spec(wh: Warehouse, batch_size: int = 256) -> SessionSpec:
    t = wh.table("bc")
    dense = t.schema.dense_ids[:8]
    sparse = t.schema.sparse_ids[:4]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=1000)
    return SessionSpec(
        table="bc", partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=batch_size, rows_per_split=STRIPE,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )


def _run_sessions(wh: Warehouse, n_sessions: int, cache, timeout_s: float):
    """Run ``n_sessions`` concurrent identical sessions (a combo window);
    returns (per-session batches, fleet metrics, service)."""
    svc = DPPService(wh, stripe_cache=cache, enable_stripe_cache=cache is not None)
    for i in range(n_sessions):
        svc.create_session(f"job{i}", _spec(wh), n_workers=2)
    results = svc.run_all(timeout_s=timeout_s)
    return results, svc.fleet_metrics(), svc


def _batch_signature(batches: List[Dict[str, np.ndarray]]) -> List[tuple]:
    """Order-independent content signature of a session's served batches."""
    sig = []
    for b in batches:
        sig.append(tuple(
            (k, b[k].shape, float(np.nan_to_num(b[k]).sum())) for k in sorted(b)
        ))
    return sorted(sig)


def run(quick: bool = False) -> None:
    rows = 512 if quick else ROWS
    n_sessions = 2 if quick else N_SESSIONS
    timeout_s = 60.0 if quick else 180.0

    # -- no-cache baseline --------------------------------------------------
    wh0 = _warehouse(rows)
    res0, m0, _ = _run_sessions(wh0, n_sessions, cache=None, timeout_s=timeout_s)
    baseline_rx = m0.storage_rx_bytes
    hdd_stats = wh0.fs.stats
    hdd_ipw = iops_per_watt(
        hdd_stats.num_ios, hdd_stats.total_time_s, wh0.fs.power_W()
    )
    emit(
        f"cache.baseline_hdd.{n_sessions}_sessions", 0.0,
        f"storage_rx={baseline_rx} ios={hdd_stats.num_ios} "
        f"iops_per_watt={hdd_ipw:.2f} over_read={m0.over_read_ratio:.3f}",
    )

    # -- shared stripe cache (HDD + DRAM/flash tier) ------------------------
    wh1 = _warehouse(rows)
    # DRAM sized below the combo-window working set so the flash victim
    # tier actually absorbs spill traffic (and shows up in the IOPS/W row)
    cache = StripeCache(
        dram_capacity_bytes=192 * 1024,
        flash_capacity_bytes=256 * 1024 * 1024,
        flash_admit_reads=1 if quick else 2,
    )
    res1, m1, svc1 = _run_sessions(wh1, n_sessions, cache=cache, timeout_s=timeout_s)
    cut = m1.storage_rx_bytes / max(baseline_rx, 1)
    # cache fleet = HDD storage nodes + one flash cache device + DRAM
    tier_io = [wh1.fs.stats, cache.flash.io, cache.dram.io]
    cached_time = sum(s.total_time_s for s in tier_io)
    cached_ios = sum(s.num_ios for s in tier_io)
    cached_power = (
        wh1.fs.power_W() + cache.flash_media.power_W + cache.dram_media.power_W
    )
    cached_ipw = iops_per_watt(cached_ios, cached_time, cached_power)
    emit(
        f"cache.shared_stripe_cache.{n_sessions}_sessions", 0.0,
        f"storage_rx={m1.storage_rx_bytes} cache_rx={m1.cache_rx_bytes} "
        f"rx_cut={cut:.3f}x hit_rate={cache.hit_rate:.3f} "
        f"dram_hits={cache.dram.hits} flash_hits={cache.flash.hits} "
        f"iops_per_watt={cached_ipw:.2f} over_read={m1.over_read_ratio:.3f} "
        f"dedup_ratio={cache.dedup.stats.dedup_ratio:.2f}",
    )
    assert cut <= 0.6, f"storage RX cut {cut:.3f}x misses the 0.6x acceptance bar"
    assert m1.over_read_ratio == 1.0, m1.over_read_ratio
    assert cached_ipw > hdd_ipw, (cached_ipw, hdd_ipw)

    # a late-arriving job (combo-window straggler): its working set was
    # evicted from the small DRAM tier but admitted to flash, so it is
    # served by flash hits instead of HDD extents
    late = svc1.create_session("late", _spec(wh1), n_workers=2)
    late.run_to_completion(timeout_s=timeout_s)
    lm = late.worker_metrics()
    emit(
        "cache.late_session_flash_tier", 0.0,
        f"storage_rx={lm.storage_rx_bytes} cache_rx={lm.cache_rx_bytes} "
        f"dram_hits={cache.dram.hits} flash_hits={cache.flash.hits} "
        f"flash_stored={cache.flash.bytes_stored}",
    )

    # served batches must be byte-identical to the uncached path
    for name in res0:
        assert _batch_signature(res0[name]) == _batch_signature(res1[name]), (
            f"cached session {name} served different bytes than uncached"
        )
    emit(f"cache.byte_identity.{n_sessions}_sessions", 0.0, "identical=True")

    # -- SSD-only comparison (same workload, no cache) ----------------------
    wh2 = _warehouse(rows, media=SSD)
    _run_sessions(wh2, n_sessions, cache=None, timeout_s=timeout_s)
    ssd_stats = wh2.fs.stats
    ssd_ipw = iops_per_watt(
        ssd_stats.num_ios, ssd_stats.total_time_s, wh2.fs.power_W()
    )
    emit(
        "cache.media_iops_per_watt", 0.0,
        f"hdd={hdd_ipw:.2f} hdd_flash_cache={cached_ipw:.2f} ssd={ssd_ipw:.2f} "
        f"cache_vs_hdd={cached_ipw / max(hdd_ipw, 1e-9):.1f}x",
    )

    # -- fleet power: Fig. 1 with the cache tier absorbing the hit traffic --
    for tag, cache_spec in (
        ("no_cache", None),
        # byte-weighted: the fraction of ingested bytes the cache served
        ("cache_tier", CacheTierSpec(hit_frac=m1.cache_served_frac)),
    ):
        p = dsi_power_split(RM1, n_trainers=16, cache=cache_spec)
        emit(
            f"cache.power_split.{tag}", 0.0,
            f"storage_frac={p['storage_frac']:.4f} "
            f"cache_frac={p.get('cache_frac', 0.0):.4f}",
        )
