"""Decoder-only LM covering the dense / GQA / MLA / MoE / VLM families.

One definition, scan-over-layers (compile time constant in depth), three
entry points per model:

  * ``loss(params, batch)``       — training objective (chunked CE + MoE aux)
  * ``prefill(params, batch)``    — forward + KV-cache emission
  * ``decode_step(params, state)``— one-token serve step over the cache

Caches are stacked along a leading "stack" (layer) dimension so the decode
step is also a single ``lax.scan``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models import attention as attn
from repro.models import layers, moe as moe_lib
from repro.models.common import (
    ModelConfig,
    ParamSpec,
    abstract_params,
    init_params,
    stack_tree,
)

AUX_LOSS_COEF = 0.01


class DecoderLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters ---------------------------------------------------------

    def layer_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        specs: Dict[str, Any] = {
            "ln1": layers.rmsnorm_spec(cfg.d_model),
            "ln2": layers.rmsnorm_spec(cfg.d_model),
        }
        specs["attn"] = attn.mla_specs(cfg) if cfg.mla else attn.gqa_specs(cfg)
        if cfg.moe:
            specs["ffn"] = moe_lib.moe_specs(cfg)
        else:
            specs["ffn"] = layers.mlp_specs(cfg.d_model, cfg.d_ff, cfg.param_dtype)
        return specs

    def param_specs(self) -> Dict[str, Any]:
        cfg = self.cfg
        return {
            "embed": layers.embed_specs(cfg),
            "layers": stack_tree(self.layer_specs(), cfg.num_layers),
            "ln_f": layers.rmsnorm_spec(cfg.d_model),
        }

    def init(self, key: jax.Array) -> Dict[str, Any]:
        return init_params(self.param_specs(), key)

    def abstract(self) -> Dict[str, Any]:
        return abstract_params(self.param_specs())

    # -- input specs (dry-run stand-ins) ------------------------------------

    def input_specs(self, batch: int, seq: int, mode: str = "train") -> Dict[str, Any]:
        cfg = self.cfg
        ii32 = jnp.int32
        if mode == "train":
            specs = {
                "tokens": jax.ShapeDtypeStruct((batch, seq), ii32),
                "labels": jax.ShapeDtypeStruct((batch, seq), ii32),
            }
            if cfg.frontend == "vision":
                specs["image_embeds"] = jax.ShapeDtypeStruct(
                    (batch, cfg.num_patches, cfg.d_model), cfg.compute_dtype
                )
            return specs
        if mode == "prefill":
            specs = {"tokens": jax.ShapeDtypeStruct((batch, seq), ii32)}
            if cfg.frontend == "vision":
                specs["image_embeds"] = jax.ShapeDtypeStruct(
                    (batch, cfg.num_patches, cfg.d_model), cfg.compute_dtype
                )
            return specs
        if mode == "decode":
            return {
                "token": jax.ShapeDtypeStruct((batch, 1), ii32),
                "pos": jax.ShapeDtypeStruct((), ii32),
                "cache": self.abstract_cache(batch, seq),
            }
        raise ValueError(mode)

    def abstract_cache(self, batch: int, seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        l = cfg.num_layers
        dt = cfg.compute_dtype
        if cfg.mla:
            m = cfg.mla
            return {
                "c_kv": jax.ShapeDtypeStruct((l, batch, seq, m.kv_lora_rank), dt),
                "k_rope": jax.ShapeDtypeStruct((l, batch, seq, m.qk_rope_dim), dt),
            }
        return {
            "k": jax.ShapeDtypeStruct((l, batch, seq, cfg.num_kv_heads, cfg.head_dim), dt),
            "v": jax.ShapeDtypeStruct((l, batch, seq, cfg.num_kv_heads, cfg.head_dim), dt),
        }

    def init_cache(self, batch: int, seq: int) -> Dict[str, Any]:
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), self.abstract_cache(batch, seq)
        )

    def cache_logical_axes(self) -> Dict[str, Tuple]:
        if self.cfg.mla:
            return {
                "c_kv": ("stack", "batch", "kv_seq", None),
                "k_rope": ("stack", "batch", "kv_seq", None),
            }
        return {
            "k": ("stack", "batch", "kv_seq", "kv_heads", None),
            "v": ("stack", "batch", "kv_seq", "kv_heads", None),
        }

    # -- forward ------------------------------------------------------------

    def _layer_train(self, lp: Dict[str, Any], x: jax.Array, positions: jax.Array):
        cfg = self.cfg
        # sequence parallelism: the residual stream and norm outputs live
        # seq-sharded over the model axis; XLA turns the TP all-reduces into
        # reduce-scatter + all-gather pairs around the matmul regions while
        # all elementwise/norm traffic shrinks by the model-axis size.
        x = constrain(x, ("batch", "seq_sp", None))
        h = layers.rmsnorm(x, lp["ln1"], cfg.rms_eps)
        h = constrain(h, ("batch", "seq_sp", None))
        if cfg.mla:
            ctx, _ = attn.mla_prefill_attention(lp["attn"], h, positions, cfg, cfg.attn_chunk)
        else:
            q, k, v = attn.gqa_project_qkv(lp["attn"], h, positions, cfg)
            o = attn.blocked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk, k_chunk=cfg.attn_k_chunk)
            ctx = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        x = constrain(x + ctx, ("batch", "seq_sp", None))
        h = constrain(
            layers.rmsnorm(x, lp["ln2"], cfg.rms_eps), ("batch", "seq_sp", None)
        )
        if cfg.moe:
            f, aux = moe_lib.moe_forward(lp["ffn"], h, cfg)
        else:
            f, aux = layers.mlp(lp["ffn"], h), jnp.zeros((), jnp.float32)
        return constrain(x + f, ("batch", "seq_sp", None)), aux

    def backbone(self, params: Dict[str, Any], x: jax.Array, positions: jax.Array):
        cfg = self.cfg

        def body(carry, lp):
            h, aux = carry
            h2, aux_i = self._layer_train(lp, h, positions)
            return (h2, aux + aux_i), None

        body_fn = jax.checkpoint(body) if cfg.remat else body
        (x, aux), _ = jax.lax.scan(body_fn, (x, jnp.zeros((), jnp.float32)), params["layers"])
        return layers.rmsnorm(x, params["ln_f"], cfg.rms_eps), aux

    def embed_inputs(self, params, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        x = layers.embed_tokens(params["embed"], batch["tokens"], cfg)
        if cfg.frontend == "vision" and "image_embeds" in batch:
            p = cfg.num_patches
            img = batch["image_embeds"].astype(cfg.compute_dtype)
            x = jnp.concatenate([img, x[:, p:, :]], axis=1)
        return x

    def loss(self, params: Dict[str, Any], batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        tokens = batch["tokens"]
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
        x = self.embed_inputs(params, batch)
        x, aux = self.backbone(params, x, positions)
        mask = None
        if cfg.frontend == "vision":
            mask = (jnp.arange(tokens.shape[1]) >= cfg.num_patches)[None, :].astype(jnp.float32)
            mask = jnp.broadcast_to(mask, tokens.shape)
        ce = layers.chunked_softmax_xent(params["embed"], x, batch["labels"], cfg, mask)
        return ce + AUX_LOSS_COEF * aux

    # -- serving ------------------------------------------------------------

    def prefill(self, params: Dict[str, Any], batch: Dict[str, jax.Array]):
        """Forward over the prompt, emitting the stacked KV cache."""
        cfg = self.cfg
        tokens = batch["tokens"]
        b, s = tokens.shape
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x = self.embed_inputs(params, batch)

        def body(h, lp):
            h = constrain(h, ("batch", "seq_sp", None))
            hn = constrain(
                layers.rmsnorm(h, lp["ln1"], cfg.rms_eps), ("batch", "seq_sp", None)
            )
            if cfg.mla:
                ctx, (c_kv, k_rope) = attn.mla_prefill_attention(
                    lp["attn"], hn, positions, cfg, cfg.attn_chunk
                )
                cache = {"c_kv": c_kv.astype(cfg.compute_dtype),
                         "k_rope": k_rope.astype(cfg.compute_dtype)}
            else:
                q, k, v = attn.gqa_project_qkv(lp["attn"], hn, positions, cfg)
                o = attn.blocked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk, k_chunk=cfg.attn_k_chunk)
                ctx = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
                cache = {"k": k.astype(cfg.compute_dtype), "v": v.astype(cfg.compute_dtype)}
            h = constrain(h + ctx, ("batch", "seq_sp", None))
            hn = constrain(
                layers.rmsnorm(h, lp["ln2"], cfg.rms_eps), ("batch", "seq_sp", None)
            )
            if cfg.moe:
                f, _ = moe_lib.moe_forward(lp["ffn"], hn, cfg)
            else:
                f = layers.mlp(lp["ffn"], hn)
            return constrain(h + f, ("batch", "seq_sp", None)), cache

        x, cache = jax.lax.scan(body, x, params["layers"])
        x = layers.rmsnorm(x, params["ln_f"], cfg.rms_eps)
        logits = layers.output_logits(params["embed"], x[:, -1:, :], cfg)
        return logits, cache

    def decode_step(self, params: Dict[str, Any], batch: Dict[str, Any]):
        """One-token decode against a (stack, B, S, ...) cache."""
        cfg = self.cfg
        token, pos, cache = batch["token"], batch["pos"], batch["cache"]
        x = layers.embed_tokens(params["embed"], token, cfg)
        positions = jnp.broadcast_to(pos, token.shape)

        def body(h, inp):
            if cfg.mla:
                lp, c_kv, k_rope = inp
                hn = layers.rmsnorm(h, lp["ln1"], cfg.rms_eps)
                new_ckv, new_krope = attn.mla_compress(lp["attn"], hn, positions, cfg)
                c_kv = jax.lax.dynamic_update_slice(
                    c_kv, new_ckv.astype(c_kv.dtype), (0, pos, 0)
                )
                k_rope = jax.lax.dynamic_update_slice(
                    k_rope, new_krope.astype(k_rope.dtype), (0, pos, 0)
                )
                ctx = attn.mla_decode_attention(lp["attn"], hn, pos, c_kv, k_rope, cfg)
                new_cache = {"c_kv": c_kv, "k_rope": k_rope}
            else:
                lp, k_c, v_c = inp
                hn = layers.rmsnorm(h, lp["ln1"], cfg.rms_eps)
                q, k, v = attn.gqa_project_qkv(lp["attn"], hn, positions, cfg)
                k_c = jax.lax.dynamic_update_slice(
                    k_c, k.astype(k_c.dtype), (0, pos, 0, 0)
                )
                v_c = jax.lax.dynamic_update_slice(
                    v_c, v.astype(v_c.dtype), (0, pos, 0, 0)
                )
                o = attn.decode_attention(q, k_c, v_c, pos)
                ctx = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
                new_cache = {"k": k_c, "v": v_c}
            h = h + ctx
            hn = layers.rmsnorm(h, lp["ln2"], cfg.rms_eps)
            if cfg.moe:
                f, _ = moe_lib.moe_forward(lp["ffn"], hn, cfg)
            else:
                f = layers.mlp(lp["ffn"], hn)
            return h + f, new_cache

        if cfg.mla:
            xs = (params["layers"], cache["c_kv"], cache["k_rope"])
        else:
            xs = (params["layers"], cache["k"], cache["v"])
        x, new_cache = jax.lax.scan(body, x, xs)
        x = layers.rmsnorm(x, params["ln_f"], cfg.rms_eps)
        logits = layers.output_logits(params["embed"], x, cfg)
        return logits, new_cache
