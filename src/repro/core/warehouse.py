"""Hive-like data warehouse: partitioned tables of DWRF files on Tectonic.

Training jobs filter along two dimensions (§5.1): a set of partitions
(row filter) and a feature projection (column filter).  The warehouse also
maintains the feature-popularity statistics that drive feature reordering.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dwrf
from repro.core.datagen import DataGenConfig, generate_partition
from repro.core.popularity import PopularityTracker
from repro.core.schema import ColumnBatch, TableSchema
from repro.core.tectonic import TectonicFS


@dataclasses.dataclass
class PartitionMeta:
    index: int
    path: str
    num_rows: int
    nbytes: int
    footer: dwrf.DwrfFooter
    # bumped on every rewrite_partition: keys derived data (e.g. the
    # preprocessed-tensor cache) to one file version, independently of
    # whether a stripe cache is attached
    generation: int = 0


class Table:
    def __init__(self, name: str, schema: TableSchema, fs: TectonicFS):
        self.name = name
        self.schema = schema
        self.fs = fs
        self.partitions: Dict[int, PartitionMeta] = {}
        self.popularity = PopularityTracker()

    def _encode(
        self, batch: ColumnBatch, opts: Optional[dwrf.DwrfWriterOptions]
    ) -> dwrf.DwrfFile:
        opts = opts or dwrf.DwrfWriterOptions()
        if opts.feature_order is None and self.popularity.total_reads > 0:
            # feature reordering: order streams by recent read popularity
            opts = dataclasses.replace(
                opts, feature_order=self.popularity.feature_order()
            )
        return dwrf.write_dwrf(batch, opts)

    def write_partition(
        self,
        index: int,
        batch: ColumnBatch,
        opts: Optional[dwrf.DwrfWriterOptions] = None,
    ) -> PartitionMeta:
        return self.write_partition_encoded(index, self._encode(batch, opts))

    def write_partition_encoded(
        self, index: int, f: dwrf.DwrfFile
    ) -> PartitionMeta:
        """Install an already-encoded DWRF file as a partition — the hook
        for ingestion paths that assemble files out-of-band (e.g.
        ``dwrf.concat_dwrf`` merging differently-labeled halves, the
        fault-injection surface for poisoned-split testing)."""
        path = f"warehouse/{self.name}/part-{index:05d}.dwrf"
        self.fs.create(path, f.data)
        self._register_stripes(path, f.footer, f.data)
        meta = PartitionMeta(
            index=index, path=path, num_rows=f.footer.num_rows,
            nbytes=f.nbytes, footer=f.footer,
        )
        self.partitions[index] = meta
        return meta

    def rewrite_partition(
        self,
        index: int,
        batch: ColumnBatch,
        opts: Optional[dwrf.DwrfWriterOptions] = None,
    ) -> PartitionMeta:
        """Replace an existing partition's bytes (continuous feature
        engineering, §4).  ``TectonicFS.rewrite`` invalidates the attached
        cache's path entries and bumps the dedup generation *before* the
        new bytes land; the new stripes are then re-registered, so readers
        switch to the new content atomically and are never served a stale
        cached stripe."""
        old = self.partitions[index]
        f = self._encode(batch, opts)
        self.fs.rewrite(old.path, f.data)
        self._register_stripes(old.path, f.footer, f.data)
        meta = PartitionMeta(
            index=index, path=old.path, num_rows=batch.num_rows,
            nbytes=f.nbytes, footer=f.footer, generation=old.generation + 1,
        )
        self.partitions[index] = meta
        return meta

    def generate(
        self,
        n_partitions: int,
        gen_cfg: Optional[DataGenConfig] = None,
        opts: Optional[dwrf.DwrfWriterOptions] = None,
    ) -> None:
        gen_cfg = gen_cfg or DataGenConfig()
        for p in range(n_partitions):
            self.write_partition(p, generate_partition(self.schema, p, gen_cfg), opts)

    def _register_stripes(
        self, path: str, footer: dwrf.DwrfFooter, data: bytes
    ) -> None:
        """Content-hash every encoded stripe into the attached cache's dedup
        index so byte-identical stripes across partitions/tables collapse to
        one cache entry (RecD-style)."""
        cache = getattr(self.fs, "cache", None)
        if cache is None:
            return
        for st in footer.stripes:
            cache.dedup.register(
                path, st.offset, st.length, data[st.offset: st.offset + st.length]
            )

    @property
    def total_bytes(self) -> int:
        return sum(m.nbytes for m in self.partitions.values())

    @property
    def total_rows(self) -> int:
        return sum(m.num_rows for m in self.partitions.values())

    def select_partitions(self, indices: Optional[Sequence[int]] = None) -> List[PartitionMeta]:
        if indices is None:
            return [self.partitions[i] for i in sorted(self.partitions)]
        return [self.partitions[i] for i in indices]


class Warehouse:
    """A region's central warehouse: many models' tables on shared storage."""

    def __init__(self, fs: Optional[TectonicFS] = None):
        self.fs = fs or TectonicFS()
        self.tables: Dict[str, Table] = {}

    def create_table(self, schema: TableSchema) -> Table:
        t = Table(schema.name, schema, self.fs)
        self.tables[schema.name] = t
        return t

    def table(self, name: str) -> Table:
        return self.tables[name]

    def attach_cache(self, cache) -> None:
        """Install a shared ``StripeCache`` on this warehouse's filesystem
        and back-register the stripes of every partition already written, so
        a cache attached after ingestion still content-dedups old data."""
        self.fs.attach_cache(cache)
        for t in self.tables.values():
            for meta in t.partitions.values():
                data = self.fs.peek(meta.path)
                for st in meta.footer.stripes:
                    cache.dedup.register(
                        meta.path, st.offset, st.length,
                        data[st.offset: st.offset + st.length],
                    )
