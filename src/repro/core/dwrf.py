"""DWRF-like columnar file format (forked-ORC stand-in), byte-accurate.

Implements the storage-format co-design of §7.5:

  * **map encoding** (baseline): each stripe stores all features as two
    monolithic map streams (dense / sparse) — readers must fetch and decode
    entire rows even for a tiny feature projection.
  * **feature flattening (FF)**: every feature becomes its own stream(s)
    within the stripe, with a per-stripe stream directory, enabling
    column-selective reads.
  * **feature reordering (FR)**: stream order within a stripe follows a
    supplied popularity order, so coalesced reads over-read less.
  * **large stripes (LS)**: ``stripe_rows`` scales the stripe (and thus the
    contiguous extent of each feature stream).

Streams are compressed (pluggable codec, see below) and XOR-"encrypted"
(a cheap stand-in that still forces a full pass over the bytes — the
paper's datacenter tax).  All sizes are real byte counts; the Tectonic
layer stores the file bytes.

Compression is a codec registry rather than a hard dependency: every
stream carries a 1-byte codec id, ``zstd`` is used when the ``zstandard``
package is importable, and stdlib ``zlib`` is the always-available
fallback, so the format (and the test suite) works in environments
without optional packages installed.
"""
from __future__ import annotations

import dataclasses
import io
import struct
import zlib
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.schema import ColumnBatch, SparseColumn, TableSchema

_XOR_KEY = 0x5A
_MAGIC = b"DWRF"


def _encrypt(data: bytes) -> bytes:
    return bytes(np.frombuffer(data, np.uint8) ^ _XOR_KEY)


def _decrypt(data: bytes) -> bytes:
    return _encrypt(data)


# ---------------------------------------------------------------------------
# Codec registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Codec:
    cid: int                                   # codec id byte in each stream
    name: str
    compress: Callable[[bytes, int], bytes]    # (payload, level) -> bytes
    decompress: Callable[[bytes], bytes]


_CODECS: Dict[int, Codec] = {}
_CODECS_BY_NAME: Dict[str, Codec] = {}


def register_codec(codec: Codec) -> None:
    if codec.cid in _CODECS and _CODECS[codec.cid].name != codec.name:
        raise ValueError(
            f"codec id {codec.cid} already registered as "
            f"{_CODECS[codec.cid].name!r}"
        )
    if codec.name in _CODECS_BY_NAME and _CODECS_BY_NAME[codec.name].cid != codec.cid:
        raise ValueError(
            f"codec name {codec.name!r} already registered with id "
            f"{_CODECS_BY_NAME[codec.name].cid}"
        )
    _CODECS[codec.cid] = codec
    _CODECS_BY_NAME[codec.name] = codec


register_codec(Codec(cid=0, name="raw",
                     compress=lambda d, level: d,
                     decompress=lambda d: d))
register_codec(Codec(cid=1, name="zlib",
                     compress=lambda d, level: zlib.compress(d, level),
                     decompress=zlib.decompress))

try:
    import zstandard as _zstd
except ImportError:
    _zstd = None
else:
    register_codec(Codec(
        cid=2, name="zstd",
        compress=lambda d, level: _zstd.ZstdCompressor(level=level).compress(d),
        decompress=lambda d: _zstd.ZstdDecompressor().decompress(d),
    ))

DEFAULT_CODEC = "zstd" if _zstd is not None else "zlib"


def available_codecs() -> List[str]:
    return sorted(_CODECS_BY_NAME)


def get_codec(name: Optional[str] = None) -> Codec:
    name = name or DEFAULT_CODEC
    try:
        return _CODECS_BY_NAME[name]
    except KeyError:
        raise KeyError(
            f"unknown codec {name!r}; available: {available_codecs()}"
        ) from None


def encode_stream(payload: bytes, codec: Optional[str] = None, level: int = 1) -> bytes:
    c = get_codec(codec)
    return bytes([c.cid]) + _encrypt(c.compress(payload, level))


def split_stream(data: bytes) -> Tuple[Codec, bytes]:
    """Split a raw stream into (codec, still-encrypted body) without
    touching the bytes — decode engines batch the decrypt pass across
    streams and time decrypt vs decompress separately."""
    cid = data[0]
    codec = _CODECS.get(cid)
    if codec is None:
        raise KeyError(
            f"stream written with unavailable codec id {cid} "
            f"(available: {available_codecs()})"
        )
    return codec, data[1:]


def decode_stream(data: bytes) -> bytes:
    codec, body = split_stream(data)
    return codec.decompress(_decrypt(body))


# ---------------------------------------------------------------------------
# Stream payload (de)serialization
# ---------------------------------------------------------------------------


def _pack_arrays(arrays: Sequence[np.ndarray]) -> bytes:
    buf = io.BytesIO()
    buf.write(struct.pack("<I", len(arrays)))
    for a in arrays:
        dt = a.dtype.str.encode()
        buf.write(struct.pack("<I", len(dt)))
        buf.write(dt)
        buf.write(struct.pack("<Q", a.nbytes))
        buf.write(a.tobytes())
    return buf.getvalue()


def _unpack_arrays(data: bytes) -> List[np.ndarray]:
    buf = io.BytesIO(data)
    (n,) = struct.unpack("<I", buf.read(4))
    out = []
    for _ in range(n):
        (dl,) = struct.unpack("<I", buf.read(4))
        dt = np.dtype(buf.read(dl).decode())
        (nb,) = struct.unpack("<Q", buf.read(8))
        out.append(np.frombuffer(buf.read(nb), dt))
    return out


_DTYPE_CACHE: Dict[bytes, np.dtype] = {}


def packed_array_headers(data: bytes) -> List[Tuple[np.dtype, int, int]]:
    """Header walk over a ``_pack_arrays`` payload without materializing
    the arrays: [(dtype, data_offset, nbytes), ...].  Data regions are NOT
    word-aligned (dtype strings have odd lengths) — the batched decode
    engine gathers them with per-region shifts."""
    (n,) = struct.unpack_from("<I", data, 0)
    pos = 4
    out: List[Tuple[np.dtype, int, int]] = []
    for _ in range(n):
        (dl,) = struct.unpack_from("<I", data, pos)
        pos += 4
        # bytes() tolerates buffer inputs (ndarray payload views from the
        # batched engine's zero-copy decrypt); the cache keeps the hot
        # per-stream walk from re-parsing the same few dtype strings
        key = bytes(data[pos:pos + dl])
        dt = _DTYPE_CACHE.get(key)
        if dt is None:
            dt = _DTYPE_CACHE[key] = np.dtype(key.decode())
        pos += dl
        (nb,) = struct.unpack_from("<Q", data, pos)
        pos += 8
        out.append((dt, pos, nb))
        pos += nb
    return out


def _dense_payload(col: np.ndarray) -> bytes:
    present = ~np.isnan(col)
    packed = np.packbits(present.astype(np.uint8))
    return _pack_arrays([packed, col[present].astype(np.float32)])


def _dense_unpayload(data: bytes, rows: int) -> np.ndarray:
    packed, vals = _unpack_arrays(data)
    present = np.unpackbits(packed.view(np.uint8), count=rows).astype(bool)
    out = np.full(rows, np.nan, np.float32)
    out[present] = vals.astype(np.float32)
    return out


def _sparse_payload(col: SparseColumn) -> bytes:
    arrays = [col.offsets.astype(np.int64), col.values.astype(np.int64)]
    if col.scores is not None:
        arrays.append(col.scores.astype(np.float32))
    return _pack_arrays(arrays)


def _sparse_unpayload(data: bytes) -> SparseColumn:
    arrays = _unpack_arrays(data)
    return SparseColumn(
        offsets=arrays[0].astype(np.int64),
        values=arrays[1].astype(np.int64),
        scores=arrays[2].astype(np.float32) if len(arrays) > 2 else None,
    )


# sparse_map blob format-version sentinel ("SPM2" as a negative int64 —
# a legacy blob's first array holds non-negative feature ids, so the two
# layouts can never be confused).  v2 stores an explicit per-feature
# scores-presence flag array: the legacy layout inferred presence from
# ``len(scores)``, which collapses a *present-but-empty* scores array
# (a 0-nnz stripe of a scored feature) into ``None`` — diverging from
# the flattened encoding's round-trip.
SPARSE_MAP_V2 = -0x53504D32


def sparse_map_layout(
    arrays: Sequence[np.ndarray],
) -> Tuple[np.ndarray, Optional[np.ndarray], int]:
    """(fids, scores-present flags or None, index of the first offsets
    array).  Detects the v2 sparse_map layout vs the legacy one (flags
    absent: presence falls back to the lossy emptiness heuristic)."""
    a0 = arrays[0]
    if a0.size == 1 and a0.dtype.kind == "i" and int(a0[0]) == SPARSE_MAP_V2:
        return arrays[1].astype(np.int64), arrays[2].astype(bool), 3
    return a0.astype(np.int64), None, 1


# ---------------------------------------------------------------------------
# File structures
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamInfo:
    fid: int                  # -1 for map-encoded monolithic streams
    kind: str                 # dense | sparse | dense_map | sparse_map | labels
    offset: int               # byte offset within the file
    length: int


@dataclasses.dataclass
class StripeInfo:
    row_start: int
    num_rows: int
    offset: int
    length: int
    streams: List[StreamInfo]


@dataclasses.dataclass
class DwrfFooter:
    num_rows: int
    flattened: bool
    stripes: List[StripeInfo]
    feature_order: List[int]

    def stream_index(self) -> Dict[Tuple[int, int], StreamInfo]:
        """(stripe_idx, fid) -> StreamInfo for flattened files."""
        out = {}
        for si, stripe in enumerate(self.stripes):
            for s in stripe.streams:
                out[(si, s.fid)] = s
        return out


@dataclasses.dataclass
class DwrfFile:
    data: bytes
    footer: DwrfFooter

    @property
    def nbytes(self) -> int:
        return len(self.data)


@dataclasses.dataclass(frozen=True)
class DwrfWriterOptions:
    flattened: bool = True               # FF
    stripe_rows: int = 2048              # LS knob
    feature_order: Optional[Sequence[int]] = None   # FR (None = fid order)
    compression_level: int = 1
    codec: Optional[str] = None          # None = DEFAULT_CODEC (zstd if available)


def write_dwrf(batch: ColumnBatch, opts: DwrfWriterOptions) -> DwrfFile:
    """Encode a ColumnBatch into DWRF bytes + footer metadata."""
    buf = io.BytesIO()
    buf.write(_MAGIC)
    stripes: List[StripeInfo] = []

    all_fids = sorted(set(batch.dense) | set(batch.sparse))
    if opts.feature_order is not None:
        order = [f for f in opts.feature_order if f in set(all_fids)]
        order += [f for f in all_fids if f not in set(order)]
    else:
        order = all_fids

    row = 0
    while row < batch.num_rows:
        nrows = min(opts.stripe_rows, batch.num_rows - row)
        part = batch.slice_rows(row, row + nrows)
        stripe_off = buf.tell()
        streams: List[StreamInfo] = []

        def emit(fid: int, kind: str, payload: bytes):
            enc = encode_stream(payload, opts.codec, opts.compression_level)
            streams.append(StreamInfo(fid=fid, kind=kind, offset=buf.tell(), length=len(enc)))
            buf.write(enc)

        if opts.flattened:
            for fid in order:
                if fid in part.dense:
                    emit(fid, "dense", _dense_payload(part.dense[fid]))
                elif fid in part.sparse:
                    emit(fid, "sparse", _sparse_payload(part.sparse[fid]))
        else:
            # map encoding: one monolithic stream per map column type
            dense_blob = _pack_arrays(
                [np.asarray(sorted(part.dense), np.int64)]
                + [part.dense[f] for f in sorted(part.dense)]
            )
            emit(-1, "dense_map", dense_blob)
            sfids = sorted(part.sparse)
            # v2 layout: sentinel, fids, explicit scores-presence flags,
            # then (offsets, values, scores) per feature — a scores-absent
            # feature still ships an empty placeholder array, but the flag
            # (not the length) decides presence on decode
            sparse_parts: List[np.ndarray] = [
                np.asarray([SPARSE_MAP_V2], np.int64),
                np.asarray(sfids, np.int64),
                np.asarray(
                    [int(part.sparse[f].scores is not None) for f in sfids],
                    np.int64,
                ),
            ]
            for f in sfids:
                c = part.sparse[f]
                sparse_parts += [c.offsets, c.values]
                sparse_parts.append(
                    c.scores if c.scores is not None else np.zeros(0, np.float32)
                )
            emit(-1, "sparse_map", _pack_arrays(sparse_parts))

        if part.labels is not None:
            emit(-2, "labels", _pack_arrays([part.labels]))

        stripes.append(
            StripeInfo(
                row_start=row,
                num_rows=nrows,
                offset=stripe_off,
                length=buf.tell() - stripe_off,
                streams=streams,
            )
        )
        row += nrows

    footer = DwrfFooter(
        num_rows=batch.num_rows,
        flattened=opts.flattened,
        stripes=stripes,
        feature_order=list(order),
    )
    return DwrfFile(data=buf.getvalue(), footer=footer)


def concat_dwrf(files: Sequence[DwrfFile]) -> DwrfFile:
    """Byte-concatenate encoded DWRF files into one file whose footer
    indexes every input's stripes (offsets and row ranges rebased).

    This is how multi-source ingestion lands in one partition — e.g. the
    streaming join emitting a labeled head while the tail's labels have
    not arrived yet.  Note the hazard this enables (and which the DPP
    worker must surface as a ``data_error``): the halves may disagree on
    which streams exist per stripe, producing mixed labeled/unlabeled
    stripes inside one file.
    """
    assert files, "concat_dwrf of nothing"
    flattened = files[0].footer.flattened
    assert all(f.footer.flattened == flattened for f in files), (
        "cannot mix flattened and map-encoded files"
    )
    data = bytearray()
    stripes: List[StripeInfo] = []
    row_base = 0
    for f in files:
        byte_base = len(data)
        data.extend(f.data)
        for st in f.footer.stripes:
            stripes.append(
                StripeInfo(
                    row_start=st.row_start + row_base,
                    num_rows=st.num_rows,
                    offset=st.offset + byte_base,
                    length=st.length,
                    streams=[
                        dataclasses.replace(s, offset=s.offset + byte_base)
                        for s in st.streams
                    ],
                )
            )
        row_base += f.footer.num_rows
    footer = DwrfFooter(
        num_rows=row_base,
        flattened=flattened,
        stripes=stripes,
        feature_order=list(files[0].footer.feature_order),
    )
    return DwrfFile(data=bytes(data), footer=footer)


# ---------------------------------------------------------------------------
# Decoding (given raw stream bytes fetched from storage)
# ---------------------------------------------------------------------------


def decode_stripe_features(
    stripe: StripeInfo,
    fetch: Dict[Tuple[int, str], bytes],
    feature_ids: Sequence[int],
) -> ColumnBatch:
    """Decode the requested features of one stripe from fetched stream bytes.

    ``fetch`` maps (fid, kind) -> raw (encrypted+compressed) stream bytes.
    """
    dense: Dict[int, np.ndarray] = {}
    sparse: Dict[int, SparseColumn] = {}
    labels = None
    want = set(feature_ids)

    for s in stripe.streams:
        key = (s.fid, s.kind)
        if key not in fetch:
            continue
        payload = decode_stream(fetch[key])
        if s.kind == "dense":
            if s.fid in want:
                dense[s.fid] = _dense_unpayload(payload, stripe.num_rows)
        elif s.kind == "sparse":
            if s.fid in want:
                sparse[s.fid] = _sparse_unpayload(payload)
        elif s.kind == "labels":
            labels = _unpack_arrays(payload)[0].astype(np.float32)
        elif s.kind == "dense_map":
            arrays = _unpack_arrays(payload)
            fids = arrays[0].astype(np.int64)
            for i, fid in enumerate(fids):
                if fid in want:
                    dense[int(fid)] = arrays[1 + i].astype(np.float32)
        elif s.kind == "sparse_map":
            arrays = _unpack_arrays(payload)
            fids, flags, base = sparse_map_layout(arrays)
            for i, fid in enumerate(fids):
                off = arrays[base + 3 * i].astype(np.int64)
                val = arrays[base + 1 + 3 * i].astype(np.int64)
                sc = arrays[base + 2 + 3 * i]
                has_scores = (
                    bool(flags[i]) if flags is not None else len(sc) > 0
                )
                if fid in want:
                    sparse[int(fid)] = SparseColumn(
                        offsets=off,
                        values=val,
                        scores=sc.astype(np.float32) if has_scores else None,
                    )
    return ColumnBatch(
        num_rows=stripe.num_rows, dense=dense, sparse=sparse, labels=labels
    )
