"""Debug tool: attribute analyzer bytes / flops / collectives to HLO sites.

Usage:
  XLA_FLAGS=... python -m repro.launch.debug_hlo <hlo.txt>
or programmatically via ``attribute(text)``.
"""
from __future__ import annotations

import re
import sys
from typing import Dict, List, Tuple

from repro.launch.hlo_analysis import (
    Computation,
    Op,
    _BODY_RE,
    _CALLS_RE,
    _COLLECTIVES,
    _COND_RE,
    _CONTRACT_RE,
    _FREE_OPCODES,
    _GROUPS_IOTA_RE,
    _GROUPS_LIST_RE,
    _MEM_OPCODES,
    _OPERAND_RE,
    _TRIP_RE,
    _collective_wire,
    _dot_flops,
    _group_size,
    _shape_bytes,
    parse_module,
)


def attribute(text: str):
    comps = parse_module(text)
    entry = [c for c in comps.values() if c.is_entry][0]
    bytes_by_site: Dict[str, float] = {}
    wire_by_site: Dict[str, float] = {}
    flops_by_site: Dict[str, float] = {}

    def op_meta(op: Op) -> str:
        m = re.search(r'op_name="([^"]*)"', op.rest)
        tail = "/".join(m.group(1).split("/")[-3:]) if m else "?"
        return f"{op.opcode}:{tail}"

    def _op_bytes(op, comp):
        result = _shape_bytes(op.type_str)
        if op.opcode in ("dynamic-slice", "gather", "slice"):
            return 2.0 * result
        if op.opcode == "dynamic-update-slice":
            operands = _OPERAND_RE.findall(
                op.rest[: op.rest.index(")")] if ")" in op.rest else op.rest
            )
            upd = _shape_bytes(comp.symbols.get(operands[1], "")) if len(operands) > 1 else 0
            return 2.0 * upd
        if op.opcode in ("broadcast", "iota"):
            return float(result)
        nbytes = float(result)
        for o in _OPERAND_RE.findall(
            op.rest[: op.rest.index(")")] if ")" in op.rest else op.rest
        ):
            t = comp.symbols.get(o)
            if t:
                nbytes += _shape_bytes(t)
        return nbytes

    def walk(name: str, mult: float, fused: bool, depth: int = 0):
        comp = comps.get(name)
        if comp is None or depth > 64:
            return
        for op in comp.ops:
            if op.opcode in _FREE_OPCODES:
                continue
            if op.opcode == "while":
                tm = _TRIP_RE.search(op.rest)
                trip = int(tm.group(1)) if tm else 1
                bm = _BODY_RE.search(op.rest)
                if bm:
                    walk(bm.group(1), mult * trip, fused, depth + 1)
                continue
            if op.opcode in ("fusion", "call", "conditional"):
                for sub in _CALLS_RE.findall(op.rest):
                    walk(sub, mult, fused or op.opcode == "fusion", depth + 1)
            if op.opcode == "dot":
                flops_by_site[op_meta(op)] = (
                    flops_by_site.get(op_meta(op), 0)
                    + _dot_flops(op, comp.symbols) * mult
                )
            base = op.opcode.replace("-start", "")
            if base in _COLLECTIVES:
                nb = _shape_bytes(op.type_str)
                g = _group_size(op.rest)
                key = f"{op_meta(op)} g={g} {op.type_str[:44]}"
                wire_by_site[key] = wire_by_site.get(key, 0) + _collective_wire(base, nb, g) * mult
            if not fused and op.opcode in _MEM_OPCODES:
                key = f"{op_meta(op)} {op.type_str[:44]}"
                bytes_by_site[key] = bytes_by_site.get(key, 0) + _op_bytes(op, comp) * mult

    walk(entry.name, 1.0, False)
    return bytes_by_site, wire_by_site, flops_by_site


def report(text: str, top: int = 15):
    b, w, f = attribute(text)
    for title, d in (("BYTES", b), ("WIRE", w), ("FLOPS", f)):
        tot = sum(d.values()) or 1.0
        print(f"== {title} total {tot:.4e}")
        for k, v in sorted(d.items(), key=lambda kv: -kv[1])[:top]:
            print(f"  {v:.3e} {v / tot * 100:5.1f}%  {k[:130]}")


if __name__ == "__main__":
    report(open(sys.argv[1]).read(), int(sys.argv[2]) if len(sys.argv) > 2 else 15)
