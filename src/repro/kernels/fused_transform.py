"""Pallas TPU kernel: fused multi-feature transform — the §7.2 flagship.

The paper observed ~3 orders of magnitude speedup from applying one kernel
to a tensor combining 1000 sparse features versus launching per-feature
kernels.  The TPU-native version packs features into the 128-lane minor
dimension of an int32 tile; per-feature op codes and parameters ride along
as (1, features) rows, and a single pallas_call applies
hash/modulus/clamp/bucketize across every feature column — kernel-launch
amortization replaced by VMEM-tile batching.

Lane typing: the packed tile is int32, but a column is free to carry
float32 *bits* — the float-typed ops (``OP_CLAMP_F``, ``OP_BUCKETIZE_F``)
bitcast the lane in-kernel, compute in f32, and bitcast the result back.
That lets one launch mix sparse-id ops and dense-normalization ops, which
is what ``repro.core.engine.PallasEngine`` exploits to execute a whole
transform wave per ``pallas_call``.  ``OP_BUCKETIZE_F`` takes a per-feature
border row from the optional ``borders`` operand ((features, nb) f32,
padded with +inf) and reproduces ``np.searchsorted(borders, v)``
(side='left': count of borders strictly below v) bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sigrid_hash import _hash_u32

OP_IDENTITY = 0
OP_SIGRID_HASH = 1
OP_POSITIVE_MODULUS = 2
OP_CLAMP = 3          # int32 clamp: clip(ids, p0, p1)
OP_BUCKETIZE = 4      # linear int grid: clip((ids - p0) // p1, 0, 255)
OP_CLAMP_F = 5        # float32 lanes: clip(bits(ids), bits(p0), bits(p1))
OP_BUCKETIZE_F = 6    # float32 lanes: searchsorted-left over borders[f]


def _kernel(ids_ref, code_ref, p0_ref, p1_ref, borders_ref, out_ref):
    ids = ids_ref[...]                             # (br, bc) i32
    code = code_ref[...][0][None, :]               # (1, bc) -> broadcast
    p0 = p0_ref[...][0][None, :]
    p1 = p1_ref[...][0][None, :]
    borders = borders_ref[...]                     # (bc, nb) f32

    h = _hash_u32(ids.astype(jnp.uint32) ^ p0.astype(jnp.uint32))
    out_hash = (h % jnp.maximum(p1.astype(jnp.uint32), 1)).astype(jnp.int32)
    m = jnp.maximum(p1, 1)
    # jnp.mod floors to the divisor's sign, so one mod lands in [0, m);
    # adding m before a second mod would overflow int32 for m near 2^31
    out_mod = jnp.mod(ids, m)
    out_clamp = jnp.clip(ids, p0, p1)
    scale = jnp.maximum(p1, 1)
    out_bucket = jnp.clip((ids - p0) // scale, 0, 255)

    # float32 lanes: reinterpret bits, compute, reinterpret back.  Columns
    # holding int data produce garbage here — discarded by the select.
    f = jax.lax.bitcast_convert_type(ids, jnp.float32)
    lo = jax.lax.bitcast_convert_type(p0, jnp.float32)
    hi = jax.lax.bitcast_convert_type(p1, jnp.float32)
    out_clamp_f = jax.lax.bitcast_convert_type(
        jnp.clip(f, lo, hi), jnp.int32
    )
    out_bucket_f = jnp.sum(
        f[:, :, None] > borders[None, :, :], axis=-1, dtype=jnp.int32
    )

    out = jnp.where(code == OP_SIGRID_HASH, out_hash, ids)
    out = jnp.where(code == OP_POSITIVE_MODULUS, out_mod, out)
    out = jnp.where(code == OP_CLAMP, out_clamp, out)
    out = jnp.where(code == OP_BUCKETIZE, out_bucket, out)
    out = jnp.where(code == OP_CLAMP_F, out_clamp_f, out)
    out = jnp.where(code == OP_BUCKETIZE_F, out_bucket_f, out)
    out_ref[...] = out.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_cols", "interpret")
)
def fused_transform(
    ids: jax.Array,          # (rows, features) int32 (float cols bitcast)
    op_codes: jax.Array,     # (features,) int32
    param0: jax.Array,       # (features,) int32 (float params bitcast)
    param1: jax.Array,       # (features,) int32 (float params bitcast)
    borders=None,            # (features, nb) f32, +inf padded; BUCKETIZE_F
    *,
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
) -> jax.Array:
    rows, feats = ids.shape
    if borders is None:
        borders = jnp.full((feats, 1), jnp.inf, jnp.float32)
    nb = borders.shape[1]
    br = min(block_rows, rows)
    bc = min(block_cols, feats)
    grid = (pl.cdiv(rows, br), pl.cdiv(feats, bc))
    row = lambda a: a.reshape(1, feats).astype(jnp.int32)
    return pl.pallas_call(
        _kernel,
        grid_spec=pl.GridSpec(
            grid=grid,
            in_specs=[
                pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                pl.BlockSpec((1, bc), lambda i, j: (0, j)),
                pl.BlockSpec((1, bc), lambda i, j: (0, j)),
                pl.BlockSpec((1, bc), lambda i, j: (0, j)),
                pl.BlockSpec((bc, nb), lambda i, j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((rows, feats), jnp.int32),
        interpret=interpret,
    )(ids, row(op_codes), row(param0), row(param1),
      borders.astype(jnp.float32))
