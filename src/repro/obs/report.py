"""Table-7-style stall attribution from a trace artifact.

``python -m repro.obs.report ARTIFACT [--json] [--check]`` consumes a
Chrome-trace JSON written by :meth:`repro.obs.Tracer.write` (optionally
carrying a registry-snapshot ``metrics`` payload) and prints, per tenant
plus an ``ALL`` aggregate:

  * the share of wall-clock the trainer spent blocked (``client.stall``)
    attributed across storage reads, cache fills, extract+transform and
    load/materialize — the paper's Table 7 breakdown — plus the directly
    measured tiered-embedding fetch share (``embed.fetch``, ISSUE 9) and
    the remainder as compute.  Shares sum to 100 by construction.
  * bytes by source tier (storage vs stripe-cache RX, DRAM/flash
    resident), the over-read factor (stripe rows decoded per fresh row
    served — Table 9's E-stage amplification) and the fused-kernel
    fraction of transform time.

``--check`` validates the artifact structurally (the schema Perfetto
loads: complete ``X`` events, sorted non-negative timestamps, no span
left open) and the report's accounting identity, exiting non-zero on any
violation — the CI gate behind ``scripts/ci.sh``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional

# span name -> stall-attribution bucket (Table 7 rows)
_BUCKETS = {
    "storage.read": "storage",
    "cache.fill": "cache_fill",
    "extract.decode": "transform",
    "transform.fused": "transform",
    "transform.fallback": "transform",
    "load.materialize": "load",
}
_WEIGHTS = ("storage", "cache_fill", "transform", "load")
# directly-measured (non-blocked) trainer-side categories: unlike the
# _BUCKETS weights these are not a split of client.stall — they are their
# own slice of the wall clock (tiered embedding lookups, ISSUE 9)
_EMBED_SPAN = "embed.fetch"
_SHARE_KEYS = (
    "storage_pct", "cache_fill_pct", "transform_pct", "load_pct",
    "embed_fetch_pct", "compute_pct", "unattributed_pct",
)
# registry-snapshot names the byte/efficiency columns read
_SNAP_COLS = (
    "worker.storage_rx_bytes", "worker.cache_rx_bytes",
    "worker.rows_decoded", "worker.rows_done", "worker.rows_from_cache",
    "worker.transform_fused_s", "worker.transform_fallback_s",
)


def _tenant_of(ev: Dict[str, Any]) -> str:
    return str((ev.get("args") or {}).get("tenant", ""))


def _accumulate(evs: List[Dict[str, Any]]) -> Dict[str, float]:
    """Raw per-tenant sums (µs): wall, stall and one weight per bucket."""
    wall = float(sum(e["dur"] for e in evs if e["name"] == "session.run"))
    if wall <= 0.0 and evs:
        # no session.run span (e.g. a bare trainer trace): the tenant's
        # wall clock is the extent of its events
        wall = float(
            max(e["ts"] + e["dur"] for e in evs) - min(e["ts"] for e in evs)
        )
    stall = min(
        float(sum(e["dur"] for e in evs if e["name"] == "client.stall")),
        wall,
    )
    embed = min(
        float(sum(e["dur"] for e in evs if e["name"] == _EMBED_SPAN)),
        wall - stall,
    )
    row = {"wall_us": wall, "stall_us": stall, "embed_us": embed}
    for b in _WEIGHTS:
        row[f"w_{b}_us"] = 0.0
    for e in evs:
        b = _BUCKETS.get(e["name"])
        if b is not None:
            row[f"w_{b}_us"] += e["dur"]
    return row


def _shares(raw: Dict[str, float]) -> Dict[str, float]:
    """Split the blocked share across buckets proportionally to their
    span time; the identity ``sum(shares) == 100`` holds by
    construction (blocked + compute partition the wall clock)."""
    out = {k: 0.0 for k in _SHARE_KEYS}
    wall = raw["wall_us"]
    if wall <= 0.0:
        out["compute_pct"] = 100.0
        return out
    stall_pct = 100.0 * raw["stall_us"] / wall
    embed_pct = 100.0 * raw.get("embed_us", 0.0) / wall
    out["embed_fetch_pct"] = embed_pct
    out["compute_pct"] = 100.0 - stall_pct - embed_pct
    wsum = sum(raw[f"w_{b}_us"] for b in _WEIGHTS)
    if wsum > 0.0:
        for b in _WEIGHTS:
            out[f"{b}_pct"] = stall_pct * raw[f"w_{b}_us"] / wsum
    else:
        # blocked time with zero attributable span time: surface it
        # instead of silently inflating a bucket
        out["unattributed_pct"] = stall_pct
    return out


def _metric_cols(snap: Dict[str, float],
                 cache: Dict[str, float]) -> Dict[str, float]:
    fresh = snap.get("worker.rows_done", 0) - snap.get(
        "worker.rows_from_cache", 0
    )
    decoded = snap.get("worker.rows_decoded", 0)
    tf = snap.get("worker.transform_fused_s", 0.0)
    tb = snap.get("worker.transform_fallback_s", 0.0)
    return {
        "storage_rx_bytes": float(snap.get("worker.storage_rx_bytes", 0)),
        "cache_rx_bytes": float(snap.get("worker.cache_rx_bytes", 0)),
        "dram_bytes_stored": float(cache.get("dram_bytes_stored", 0.0)),
        "flash_bytes_stored": float(cache.get("flash_bytes_stored", 0.0)),
        "over_read": decoded / fresh if fresh > 0 else 1.0,
        "fused_frac": tf / (tf + tb) if (tf + tb) > 0.0 else 0.0,
    }


def build_report(doc: Dict[str, Any]) -> Dict[str, Dict[str, float]]:
    """Per-tenant rows (plus ``ALL``): raw µs sums, percentage shares
    and the byte/efficiency columns from the ``metrics`` payload."""
    events = [
        e for e in doc.get("traceEvents", []) if e.get("ph") == "X"
    ]
    metrics = doc.get("metrics") or {}
    tenant_snaps = metrics.get("tenants") or {}
    tenant_cache = metrics.get("cache") or {}
    tenants = sorted({_tenant_of(e) for e in events} | set(tenant_snaps))
    rows: Dict[str, Dict[str, float]] = {}
    total_raw: Dict[str, float] = {}
    total_snap: Dict[str, float] = {}
    total_cache: Dict[str, float] = {}
    for tenant in tenants:
        evs = [e for e in events if _tenant_of(e) == tenant]
        raw = _accumulate(evs)
        snap = tenant_snaps.get(tenant) or {}
        cache = tenant_cache.get(tenant) or {}
        rows[tenant] = {**raw, **_shares(raw), **_metric_cols(snap, cache)}
        for k, v in raw.items():
            total_raw[k] = total_raw.get(k, 0.0) + v
        for k in _SNAP_COLS:
            total_snap[k] = total_snap.get(k, 0.0) + snap.get(k, 0)
        for k in ("dram_bytes_stored", "flash_bytes_stored"):
            total_cache[k] = total_cache.get(k, 0.0) + cache.get(k, 0.0)
    if total_raw:
        rows["ALL"] = {
            **total_raw,
            **_shares(total_raw),
            **_metric_cols(total_snap, total_cache),
        }
    return rows


def check(doc: Dict[str, Any]) -> List[str]:
    """Structural + accounting validation; returns human-readable
    violations (empty = artifact is Perfetto-loadable and consistent)."""
    errs: List[str] = []
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return ["traceEvents missing or not a list"]
    last_ts = -1.0
    for i, e in enumerate(events):
        missing = [
            k for k in ("name", "ph", "ts", "dur", "pid", "tid")
            if k not in e
        ]
        if missing:
            errs.append(f"event {i}: missing {missing}")
            continue
        if e["ph"] != "X":
            errs.append(f"event {i} ({e['name']}): ph={e['ph']!r}, not 'X'")
        if e["ts"] < 0 or e["dur"] < 0:
            errs.append(
                f"event {i} ({e['name']}): negative ts/dur "
                f"({e['ts']}, {e['dur']})"
            )
        if e["ts"] < last_ts:
            errs.append(f"event {i} ({e['name']}): ts not sorted")
        last_ts = e["ts"]
    other = doc.get("otherData") or {}
    if other.get("open_spans", 0) != 0:
        errs.append(f"{other['open_spans']} span(s) left open at export")
    for tenant, row in build_report(doc).items():
        total = sum(row[k] for k in _SHARE_KEYS)
        if abs(total - 100.0) > 0.1:
            errs.append(
                f"tenant {tenant!r}: shares sum to {total:.3f}, not 100"
            )
        if row["unattributed_pct"] > 0.1:
            errs.append(
                f"tenant {tenant!r}: {row['unattributed_pct']:.2f}% of the "
                "wall clock is blocked time with no attributable span"
            )
    return errs


def _fmt_table(rows: Dict[str, Dict[str, float]]) -> str:
    head = (
        f"{'tenant':<12} {'wall_s':>8} {'storage%':>9} {'cachefill%':>10} "
        f"{'transform%':>10} {'load%':>7} {'embed%':>7} {'compute%':>9} "
        f"{'unattr%':>8}"
    )
    lines = [head, "-" * len(head)]
    for tenant, r in rows.items():
        lines.append(
            f"{tenant or '(none)':<12} {r['wall_us'] / 1e6:>8.2f} "
            f"{r['storage_pct']:>9.2f} {r['cache_fill_pct']:>10.2f} "
            f"{r['transform_pct']:>10.2f} {r['load_pct']:>7.2f} "
            f"{r['embed_fetch_pct']:>7.2f} "
            f"{r['compute_pct']:>9.2f} {r['unattributed_pct']:>8.2f}"
        )
    head2 = (
        f"{'tenant':<12} {'storage_rx':>12} {'cache_rx':>12} "
        f"{'dram_res':>10} {'flash_res':>10} {'over_read':>9} {'fused':>6}"
    )
    lines += ["", head2, "-" * len(head2)]
    for tenant, r in rows.items():
        lines.append(
            f"{tenant or '(none)':<12} {int(r['storage_rx_bytes']):>12} "
            f"{int(r['cache_rx_bytes']):>12} "
            f"{int(r['dram_bytes_stored']):>10} "
            f"{int(r['flash_bytes_stored']):>10} "
            f"{r['over_read']:>9.2f} {r['fused_frac']:>6.2f}"
        )
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Table-7-style stall attribution from a trace artifact",
    )
    ap.add_argument("artifact", help="Chrome-trace JSON from Tracer.write")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON instead of a table")
    ap.add_argument("--check", action="store_true",
                    help="validate the artifact + accounting; exit 1 on "
                         "any violation")
    args = ap.parse_args(argv)
    with open(args.artifact, "r", encoding="utf-8") as f:
        doc = json.load(f)
    rows = build_report(doc)
    if args.json:
        print(json.dumps(rows, indent=2, sort_keys=True))
    else:
        print(_fmt_table(rows))
    if args.check:
        errs = check(doc)
        if errs:
            for e in errs:
                print(f"CHECK FAILED: {e}", file=sys.stderr)
            return 1
        print(f"report check: OK ({len(doc['traceEvents'])} events, "
              f"{len(rows)} row(s))")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
