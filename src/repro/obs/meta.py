"""Per-field metric metadata: counter vs gauge, declared at the field.

Every metric dataclass in the repo (``WorkerMetrics``, ``TierStats``,
``IOStats``, ...) declares each numeric field through :func:`counter` or
:func:`gauge` instead of a bare default.  That single declaration drives:

  * **merge semantics** — :func:`merge_metrics` sums counters, applies the
    gauge's declared ``merge`` policy (``"sum"`` for occupancy that adds
    across disjoint instances, ``"last"``/``"max"`` otherwise), extends
    list-valued samples, recurses into nested metric dataclasses, and
    leaves non-metric fields (names, labels) alone — the blind
    add-every-field merge corrupted exactly those;
  * **registry typing** — ``MetricsRegistry`` snapshots counters and
    gauges differently (counters delta, gauges pass through);
  * **REPRO-M002** — the monotonicity rule's counter/gauge split is
    auto-discovered from these declarations instead of a hand-kept
    exemption list (see ``repro.analysis.checks_metrics``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

METRIC_KEY = "metric"        # field metadata key: "counter" | "gauge"
MERGE_KEY = "merge"          # gauge merge policy: "sum" | "last" | "max"


def counter(default: Any = 0, *,
            factory: Optional[Callable[[], Any]] = None) -> Any:
    """A monotonically-increasing cumulative field (work done, bytes
    moved).  List-valued counters (``factory=list``) accumulate samples
    and merge by extension."""
    meta = {METRIC_KEY: "counter"}
    if factory is not None:
        return dataclasses.field(default_factory=factory, metadata=meta)
    return dataclasses.field(default=default, metadata=meta)


def gauge(default: Any = 0, *, merge: str = "sum",
          factory: Optional[Callable[[], Any]] = None) -> Any:
    """A point-in-time level (occupancy, last-seen value).  Gauges may
    shrink (REPRO-M002 exempts them); ``merge`` declares how aggregation
    across instances combines them."""
    if merge not in ("sum", "last", "max"):
        raise ValueError(f"bad gauge merge policy {merge!r}")
    meta = {METRIC_KEY: "gauge", MERGE_KEY: merge}
    if factory is not None:
        return dataclasses.field(default_factory=factory, metadata=meta)
    return dataclasses.field(default=default, metadata=meta)


def metric_kind(f: dataclasses.Field) -> Optional[str]:
    """``"counter"`` / ``"gauge"`` for declared metric fields, else None."""
    return f.metadata.get(METRIC_KEY) if f.metadata else None


def metric_fields(obj: Any):
    """Yield ``(field, kind)`` for the declared metric fields of a metric
    dataclass (instance or class)."""
    for f in dataclasses.fields(obj):
        kind = metric_kind(f)
        if kind is not None:
            yield f, kind


def merge_metrics(dst: Any, src: Any) -> Any:
    """Merge ``src`` into ``dst`` field-by-field, driven by the metadata.

    Counters sum (lists extend); gauges combine per their declared
    policy; nested metric dataclasses recurse; fields with no metric
    declaration (identity strings, labels) are left untouched.  Returns
    ``dst`` for chaining.
    """
    if type(dst) is not type(src):
        raise TypeError(
            f"cannot merge {type(src).__name__} into {type(dst).__name__}"
        )
    for f, kind in metric_fields(dst):
        a, b = getattr(dst, f.name), getattr(src, f.name)
        if dataclasses.is_dataclass(a):
            merge_metrics(a, b)
        elif isinstance(a, list):
            a.extend(b)
        elif kind == "counter":
            setattr(dst, f.name, a + b)
        else:
            policy = f.metadata.get(MERGE_KEY, "sum")
            if policy == "sum":
                setattr(dst, f.name, a + b)
            elif policy == "max":
                setattr(dst, f.name, max(a, b))
            else:                       # "last": newest observation wins
                setattr(dst, f.name, b)
    return dst


def flatten_metrics(obj: Any, prefix: str = ""):
    """Yield ``(dotted_name, kind, value)`` for every numeric metric field,
    descending into nested metric dataclasses (``io.num_ios``).  Lists and
    non-numeric fields are skipped — snapshots carry scalars only."""
    for f, kind in metric_fields(obj):
        v = getattr(obj, f.name)
        name = f"{prefix}{f.name}"
        if dataclasses.is_dataclass(v):
            yield from flatten_metrics(v, prefix=name + ".")
        elif isinstance(v, (int, float)) and not isinstance(v, bool):
            yield name, kind, v
