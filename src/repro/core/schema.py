"""Feature schema and lifecycle for recommendation training tables.

Mirrors the paper's data model (§3.1, §4.3):
  * samples are structured rows of dense + sparse (+ scored) features,
  * tables hold tens of thousands of features, > 99% of bytes in features,
  * features move through a lifecycle (beta -> experimental -> active ->
    deprecated, Table 2) with hundreds added/removed monthly,
  * each feature has a coverage (fraction of rows logging it) and, for
    sparse features, an average list length (Table 5).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Iterable, List, Optional, Sequence

import numpy as np


class FeatureType(enum.Enum):
    DENSE = "dense"
    SPARSE = "sparse"          # id list
    SPARSE_SCORED = "scored"   # id list + float score per id


class FeatureStatus(enum.Enum):
    BETA = "beta"                # not logged; injectable for exploration
    EXPERIMENTAL = "experimental"
    ACTIVE = "active"
    DEPRECATED = "deprecated"    # still written until reaped


@dataclasses.dataclass
class FeatureDef:
    fid: int
    name: str
    ftype: FeatureType
    status: FeatureStatus = FeatureStatus.ACTIVE
    coverage: float = 0.45            # Table 5: avg coverage 0.29-0.45
    avg_length: float = 26.0          # Table 5: avg sparse length ~20-26
    cardinality: int = 100_000        # id space for sparse values
    popularity: float = 1.0           # read-popularity weight (drives Fig.7)

    @property
    def logged(self) -> bool:
        return self.status != FeatureStatus.BETA


@dataclasses.dataclass
class TableSchema:
    name: str
    features: Dict[int, FeatureDef]

    @property
    def dense_ids(self) -> List[int]:
        return sorted(
            f.fid for f in self.features.values()
            if f.ftype == FeatureType.DENSE and f.logged
        )

    @property
    def sparse_ids(self) -> List[int]:
        return sorted(
            f.fid for f in self.features.values()
            if f.ftype != FeatureType.DENSE and f.logged
        )

    @property
    def logged_ids(self) -> List[int]:
        return sorted(f.fid for f in self.features.values() if f.logged)

    def feature(self, fid: int) -> FeatureDef:
        return self.features[fid]

    def add(self, fdef: FeatureDef) -> None:
        assert fdef.fid not in self.features
        self.features[fdef.fid] = fdef

    def evolve(
        self,
        rng: np.random.Generator,
        n_new: int,
        promote_frac: float = 0.1,
        deprecate_frac: float = 0.05,
    ) -> None:
        """One engineering cycle (§4.3): add experimental features, promote
        some to active, deprecate some old ones."""
        next_id = max(self.features) + 1 if self.features else 0
        for i in range(n_new):
            self.add(_random_feature(rng, next_id + i, FeatureStatus.EXPERIMENTAL))
        for f in list(self.features.values()):
            if f.status == FeatureStatus.EXPERIMENTAL and rng.random() < promote_frac:
                f.status = FeatureStatus.ACTIVE
            elif f.status == FeatureStatus.ACTIVE and rng.random() < deprecate_frac:
                f.status = FeatureStatus.DEPRECATED

    def status_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.features.values():
            out[f.status.value] = out.get(f.status.value, 0) + 1
        return out


def _random_feature(rng: np.random.Generator, fid: int, status: FeatureStatus) -> FeatureDef:
    is_dense = rng.random() < 0.87   # Table 5: ~12k float vs ~1.8k sparse
    f = _random_feature_inner(rng, fid, status, is_dense)
    f.popularity = float((rng.pareto(1.2) + 0.05) * (0.3 + f.coverage) *
                         (1.0 + np.log1p(f.avg_length)))
    return f


def _random_feature_inner(rng, fid, status, is_dense) -> FeatureDef:
    return FeatureDef(
        fid=fid,
        name=f"f{fid}",
        ftype=FeatureType.DENSE if is_dense else (
            FeatureType.SPARSE_SCORED if rng.random() < 0.2 else FeatureType.SPARSE
        ),
        status=status,
        coverage=float(np.clip(rng.beta(2.0, 2.5), 0.02, 1.0)),
        avg_length=float(np.clip(rng.lognormal(2.6, 0.8), 1, 200)),
        cardinality=int(rng.choice([1_000, 10_000, 100_000, 1_000_000])),
        # Zipf-ish popularity so a small set of features dominates reads.
        # Popularity correlates with coverage & length: engineers favor
        # features with stronger signal, which also carry more bytes (§5.1:
        # read bytes % > read features %).
        popularity=0.0,
    )


def make_schema(
    name: str,
    n_dense: int,
    n_sparse: int,
    seed: int = 0,
) -> TableSchema:
    """Synthesize a production-like schema (Table 5 scale knobs)."""
    rng = np.random.default_rng(seed)
    feats: Dict[int, FeatureDef] = {}
    fid = 0
    for _ in range(n_dense):
        f = _random_feature(rng, fid, FeatureStatus.ACTIVE)
        f.ftype = FeatureType.DENSE
        feats[fid] = f
        fid += 1
    for _ in range(n_sparse):
        f = _random_feature(rng, fid, FeatureStatus.ACTIVE)
        f.ftype = FeatureType.SPARSE_SCORED if rng.random() < 0.2 else FeatureType.SPARSE
        feats[fid] = f
        fid += 1
    return TableSchema(name=name, features=feats)


# ---------------------------------------------------------------------------
# Columnar in-memory sample batches (what flows through the pipeline)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SparseColumn:
    """CSR-style variable-length id lists (+ optional scores)."""

    offsets: np.ndarray          # (rows+1,) int64
    values: np.ndarray           # (nnz,) int64
    scores: Optional[np.ndarray] = None  # (nnz,) float32

    @property
    def rows(self) -> int:
        return len(self.offsets) - 1

    def row(self, i: int) -> np.ndarray:
        return self.values[self.offsets[i]: self.offsets[i + 1]]

    def nbytes(self) -> int:
        n = self.offsets.nbytes + self.values.nbytes
        if self.scores is not None:
            n += self.scores.nbytes
        return n


def concat_sparse_columns(cols: Sequence[SparseColumn]) -> SparseColumn:
    """Row-concatenate CSR columns, rebasing offsets; scores are zero-padded
    when only some columns carry them."""
    if len(cols) == 1:
        return cols[0]
    offs = [np.zeros(1, np.int64)]
    vals: List[np.ndarray] = []
    has_scores = any(c.scores is not None for c in cols)
    scs: List[np.ndarray] = []
    base = 0
    for c in cols:
        offs.append(c.offsets[1:] + base)
        vals.append(c.values)
        if has_scores:
            scs.append(
                c.scores if c.scores is not None
                else np.zeros(len(c.values), np.float32)
            )
        base += len(c.values)
    return SparseColumn(
        offsets=np.concatenate(offs),
        values=np.concatenate(vals) if vals else np.zeros(0, np.int64),
        scores=np.concatenate(scs) if has_scores else None,
    )


@dataclasses.dataclass
class ColumnBatch:
    """A batch of rows in columnar layout: feature id -> column."""

    num_rows: int
    dense: Dict[int, np.ndarray]           # fid -> (rows,) float32 (NaN = missing)
    sparse: Dict[int, SparseColumn]        # fid -> CSR column
    labels: Optional[np.ndarray] = None    # (rows,) float32

    def nbytes(self) -> int:
        n = sum(a.nbytes for a in self.dense.values())
        n += sum(c.nbytes() for c in self.sparse.values())
        if self.labels is not None:
            n += self.labels.nbytes
        return n

    def select(self, feature_ids: Sequence[int]) -> "ColumnBatch":
        fset = set(feature_ids)
        return ColumnBatch(
            num_rows=self.num_rows,
            dense={k: v for k, v in self.dense.items() if k in fset},
            sparse={k: v for k, v in self.sparse.items() if k in fset},
            labels=self.labels,
        )

    def slice_rows(self, start: int, stop: int) -> "ColumnBatch":
        dense = {k: v[start:stop] for k, v in self.dense.items()}
        sparse = {}
        for k, c in self.sparse.items():
            off = c.offsets[start: stop + 1]
            vals = c.values[off[0]: off[-1]]
            sc = c.scores[off[0]: off[-1]] if c.scores is not None else None
            sparse[k] = SparseColumn(offsets=(off - off[0]), values=vals, scores=sc)
        return ColumnBatch(
            num_rows=stop - start,
            dense=dense,
            sparse=sparse,
            labels=self.labels[start:stop] if self.labels is not None else None,
        )


def concat_batches(batches: List[ColumnBatch]) -> ColumnBatch:
    assert batches
    dense_keys = set().union(*[set(b.dense) for b in batches])
    sparse_keys = set().union(*[set(b.sparse) for b in batches])
    total = sum(b.num_rows for b in batches)
    dense = {}
    for k in dense_keys:
        parts = [
            b.dense.get(k, np.full(b.num_rows, np.nan, np.float32)) for b in batches
        ]
        dense[k] = np.concatenate(parts)
    sparse = {}
    for k in sparse_keys:
        cols = []
        for b in batches:
            col = b.sparse.get(k)
            if col is None:    # feature absent in this batch: all-empty rows
                col = SparseColumn(
                    offsets=np.zeros(b.num_rows + 1, np.int64),
                    values=np.zeros(0, np.int64),
                )
            cols.append(col)
        sparse[k] = concat_sparse_columns(cols)
    labels = (
        np.concatenate([b.labels for b in batches])
        if all(b.labels is not None for b in batches)
        else None
    )
    return ColumnBatch(num_rows=total, dense=dense, sparse=sparse, labels=labels)
