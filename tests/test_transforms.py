import numpy as np
import pytest

from repro.core.schema import SparseColumn
from repro.core import transforms as T


def _random_lists(rng, n_rows, max_len, lo, hi, empty_frac=0.3):
    """Seeded stand-in for the hypothesis list-of-int-lists strategy."""
    out = []
    for _ in range(n_rows):
        ln = 0 if rng.random() < empty_frac else int(rng.integers(0, max_len + 1))
        out.append(rng.integers(lo, hi, size=ln).tolist())
    return out


def _col(lists, scores=None):
    lengths = [len(l) for l in lists]
    off = np.zeros(len(lists) + 1, np.int64)
    np.cumsum(lengths, out=off[1:])
    vals = np.concatenate([np.asarray(l, np.int64) for l in lists]) if lists else np.zeros(0, np.int64)
    sc = np.concatenate([np.asarray(s, np.float32) for s in scores]) if scores else None
    return SparseColumn(offsets=off, values=vals, scores=sc)


def test_sigrid_hash_range_and_determinism():
    c = _col([[1, 2, 3], [4], []])
    h1 = T.sigrid_hash(c, salt=7, max_value=100)
    h2 = T.sigrid_hash(c, salt=7, max_value=100)
    np.testing.assert_array_equal(h1.values, h2.values)
    assert (h1.values >= 0).all() and (h1.values < 100).all()
    h3 = T.sigrid_hash(c, salt=8, max_value=100)
    assert not np.array_equal(h1.values, h3.values)


def test_firstx():
    c = _col([[1, 2, 3, 4], [5], [6, 7]])
    out = T.firstx(c, 2)
    assert out.row(0).tolist() == [1, 2]
    assert out.row(1).tolist() == [5]
    assert out.row(2).tolist() == [6, 7]


def test_positive_modulus_negative_ids():
    c = _col([[-7, 7, -1]])
    out = T.positive_modulus(c, 5)
    assert out.values.tolist() == [3, 2, 4]


def test_map_id_with_default():
    c = _col([[1, 2, 99]])
    out = T.map_id(c, {1: 10, 2: 20}, default=-1)
    assert out.values.tolist() == [10, 20, -1]


def test_enumerate_ids():
    c = _col([[9, 9, 9], [5]])
    out = T.enumerate_ids(c)
    assert out.values.tolist() == [0, 1, 2, 0]


def test_compute_score():
    c = _col([[1, 2]], scores=[[1.0, 2.0]])
    out = T.compute_score(c, scale=2.0, bias=1.0)
    np.testing.assert_allclose(out.scores, [3.0, 5.0])


def test_id_list_intersection():
    a = _col([[1, 2, 3], [4, 5]])
    b = _col([[2, 3, 9], [6]])
    out = T.id_list_intersection(a, b)
    assert out.row(0).tolist() == [2, 3]
    assert out.row(1).tolist() == []


def test_cartesian_lengths():
    a = _col([[1, 2], [3]])
    b = _col([[10, 20, 30], []])
    out = T.cartesian(a, b)
    assert np.diff(out.offsets).tolist() == [6, 0]


def test_ngram_counts():
    c = _col([[1, 2, 3, 4], [7], [5, 6]])
    out = T.ngram(c, n=2)
    assert np.diff(out.offsets).tolist() == [3, 0, 1]
    # bigram hash depends on both members
    c2 = _col([[1, 2, 3, 5], [7], [5, 6]])
    out2 = T.ngram(c2, n=2)
    assert out.values[2] != out2.values[2]


def test_bucketize_and_onehot_and_dense_norms():
    vals = np.array([-5.0, 0.0, 5.0], np.float32)
    borders = np.array([-1.0, 1.0])
    b = T.bucketize(vals, borders)
    assert b.values.tolist() == [0, 1, 2]
    oh = T.onehot(vals, borders)
    assert oh.shape == (3, 3) and (oh.sum(1) == 1).all()
    assert np.isfinite(T.boxcox(vals)).all()
    assert np.isfinite(T.logit(np.array([0.2, 0.8], np.float32))).all()
    np.testing.assert_allclose(T.clamp(vals, -1, 1), [-1, 0, 1])
    hrs = T.get_local_hour(np.array([3600.0 * 30], np.float32))
    assert hrs[0] == 6.0


def test_sampling_reduces_rows():
    from repro.core.datagen import DataGenConfig, generate_partition
    from repro.core.schema import make_schema
    s = make_schema("t", 5, 3, seed=0)
    b = generate_partition(s, 0, DataGenConfig(rows_per_partition=400, seed=1))
    out = T.sampling(b, 0.5, seed=2)
    assert 100 < out.num_rows < 300
    assert out.labels.shape == (out.num_rows,)
    for fid, c in out.sparse.items():
        assert c.rows == out.num_rows
        assert len(c.values) == c.offsets[-1]


def test_pipeline_dag_and_histogram():
    pipe = T.default_dlrm_pipeline([0, 1], [10, 11], hash_size=50, n_derived=3)
    hist = pipe.op_class_histogram()
    assert hist["feature_gen"] == 3
    assert set(pipe.required_features()) == {0, 1, 10, 11}


def test_materialize_shapes():
    pipe = T.default_dlrm_pipeline([0], [10], hash_size=50)
    from repro.core.schema import ColumnBatch
    batch = ColumnBatch(
        num_rows=4,
        dense={0: np.array([1.0, np.nan, 3.0, 4.0], np.float32)},
        sparse={10: _col([[1, 2], [3], [], [4, 5, 6]])},
    )
    env = pipe(batch)
    out = T.materialize_dlrm_batch(env, ["d0"], ["s10"], max_ids=2)
    assert out["dense"].shape == (4, 1)
    assert out["sparse_ids"].shape == (4, 1, 2)
    assert out["sparse_mask"][0, 0].tolist() == [1.0, 1.0]
    assert out["sparse_mask"][2, 0].tolist() == [0.0, 0.0]
    assert out["sparse_mask"][3, 0].tolist() == [1.0, 1.0]   # truncated to 2


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("x", [1, 2, 6])
def test_firstx_property(seed, x):
    rng = np.random.default_rng(seed)
    lists = _random_lists(rng, int(rng.integers(1, 13)), 8, -10**9, 10**9)
    c = _col(lists)
    out = T.firstx(c, x)
    lens = np.diff(out.offsets)
    assert (lens <= x).all()
    for i, l in enumerate(lists):
        np.testing.assert_array_equal(out.row(i), np.asarray(l[:x], np.int64))


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("m", [2, 97, 10**6])
def test_hash_range_property(seed, m):
    rng = np.random.default_rng(seed)
    lists = _random_lists(rng, int(rng.integers(1, 11)), 6, 0, 10**9)
    c = _col(lists)
    out = T.sigrid_hash(c, salt=1, max_value=m)
    assert (out.values >= 0).all() and (out.values < m).all()
    np.testing.assert_array_equal(out.offsets, c.offsets)


# -- empty-selection edge cases (ISSUE 1 satellite) --------------------------


def test_firstx_all_empty_rows():
    c = _col([[], [], []])
    out = T.firstx(c, 4)
    assert out.rows == 3
    assert out.values.size == 0
    assert out.offsets.tolist() == [0, 0, 0, 0]


def test_ragged_gather_empty():
    idx = T._ragged_gather(np.zeros(0, np.int64), np.zeros(0, np.int64))
    assert idx.size == 0 and idx.dtype == np.int64


def test_sampling_zero_kept_rows():
    from repro.core.datagen import DataGenConfig, generate_partition
    from repro.core.schema import make_schema
    s = make_schema("t", 4, 2, seed=0)
    b = generate_partition(s, 0, DataGenConfig(rows_per_partition=64, seed=1))
    out = T.sampling(b, 0.0, seed=2)
    assert out.num_rows == 0
    assert out.labels.shape == (0,)
    for c in out.sparse.values():
        assert c.rows == 0 and c.values.size == 0


def test_sampling_with_empty_id_lists():
    b_sparse = _col([[1, 2], [], [3], [], [4, 5, 6]])
    from repro.core.schema import ColumnBatch
    b = ColumnBatch(
        num_rows=5,
        dense={0: np.arange(5, dtype=np.float32)},
        sparse={10: b_sparse},
        labels=np.zeros(5, np.float32),
    )
    out = T.sampling(b, 0.99, seed=0)   # keeps most rows, incl. empty ones
    assert 0 < out.num_rows <= 5
    c = out.sparse[10]
    assert c.rows == out.num_rows
    assert len(c.values) == c.offsets[-1]
    # each kept row's ids match the source row's ids
    kept_dense = out.dense[0].astype(np.int64)
    for i, src_row in enumerate(kept_dense):
        np.testing.assert_array_equal(c.row(i), b_sparse.row(int(src_row)))
