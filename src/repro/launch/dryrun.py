import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the sharding config is coherent end-to-end
(SPMD partitioning succeeds, no unsupported collectives, memory analysis
available) and extracts the roofline terms via the trip-count-aware HLO
analyzer.  Results append to an incremental JSONL so a crashed sweep
resumes where it left off.

Usage:
  python -m repro.launch.dryrun --arch llama3-405b --shape train_4k
  python -m repro.launch.dryrun --all            # full sweep, both meshes
  python -m repro.launch.dryrun --all --resume   # skip cells already done
"""
import argparse
import gc
import json
import sys
import time
import traceback

import jax

from repro import configs as cfglib
from repro.launch import mesh as meshlib
from repro.launch import roofline as rl
from repro.launch.hlo_analysis import analyze
from repro.launch.steps import make_step

RESULTS = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun.jsonl")


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True) -> dict:
    shape = cfglib.SHAPES[shape_name]
    cfg = cfglib.get_config(arch)
    mesh = meshlib.make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod,
        "chips": chips,
        "status": "running",
    }
    t0 = time.time()
    try:
        bundle = make_step(arch, shape_name, mesh)
        lowered = bundle.lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = None
        try:
            m = compiled.memory_analysis()
            mem = {
                "argument_bytes": m.argument_size_in_bytes,
                "output_bytes": m.output_size_in_bytes,
                "temp_bytes": m.temp_size_in_bytes,
                "alias_bytes": m.alias_size_in_bytes,
                "total_bytes_per_device": (
                    m.argument_size_in_bytes + m.output_size_in_bytes
                    + m.temp_size_in_bytes - m.alias_size_in_bytes
                ),
            }
        except Exception as e:  # CPU backend may lack pieces
            mem = {"error": str(e)}

        xla_cost = {}
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            xla_cost = {
                "flops_per_device_loopbody_once": float(ca.get("flops", 0.0)),
                "bytes_per_device_loopbody_once": float(ca.get("bytes accessed", 0.0)),
            }
        except Exception as e:
            xla_cost = {"error": str(e)}

        hlo_text = compiled.as_text()
        import jax.numpy as jnp
        bf16 = getattr(cfg, "compute_dtype", None) == jnp.bfloat16
        cost = analyze(hlo_text, bf16_activations=bf16)
        model_flops = rl.model_flops_estimate(cfg, shape)
        attn_flops = rl.attention_flops_estimate(cfg, shape)
        terms = rl.RooflineTerms(
            flops=cost.flops * chips,       # analyzer sees the per-device program
            hbm_bytes=cost.bytes_accessed * chips,
            wire_bytes_per_device=cost.wire_bytes,
            chips=chips,
            model_flops=model_flops,
        )
        rec.update(
            {
                "status": "ok",
                "lower_s": round(t_lower, 2),
                "compile_s": round(t_compile, 2),
                "memory_analysis": mem,
                "xla_cost_analysis": xla_cost,
                "hlo_flops_per_device": cost.flops,
                "hlo_bytes_per_device": cost.bytes_accessed,
                "collectives": cost.collective_counts,
                "wire_bytes_per_device": cost.wire_bytes,
                "unknown_trip_loops": cost.unknown_trip_loops,
                "model_flops": model_flops,
                "attention_flops": attn_flops,
                "roofline": terms.to_dict(),
                "hlo_size_chars": len(hlo_text),
                "dot_flops_top": dict(
                    sorted(cost.dot_flops_by_meta.items(), key=lambda kv: -kv[1])[:8]
                ),
            }
        )
        del compiled, lowered, bundle, hlo_text
    except Exception as e:
        rec.update({"status": "error", "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-4000:]})
    rec["wall_s"] = round(time.time() - t0, 2)
    gc.collect()
    if verbose:
        brief = {k: rec.get(k) for k in ("arch", "shape", "mesh", "status", "wall_s")}
        if rec["status"] == "ok":
            brief["bottleneck"] = rec["roofline"]["bottleneck"]
            brief["step_s"] = round(rec["roofline"]["step_time_s"], 4)
        else:
            brief["error"] = rec.get("error")
        print(json.dumps(brief), flush=True)
    return rec


def all_cells():
    for arch in cfglib.ARCH_IDS:
        for shape_name in cfglib.SHAPES:
            ok, why = cfglib.cell_supported(arch, shape_name)
            for multi_pod in (False, True):
                yield arch, shape_name, multi_pod, ok, why


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default=RESULTS)
    args = ap.parse_args()

    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)

    done = set()
    if args.resume and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skipped"):
                        done.add((r["arch"], r["shape"], r["multi_pod"]))
                except json.JSONDecodeError:
                    pass

    def emit(rec):
        with open(args.out, "a") as f:
            f.write(json.dumps(rec) + "\n")

    if args.all:
        n_err = 0
        for arch, shape_name, multi_pod, ok, why in all_cells():
            key = (arch, shape_name, multi_pod)
            if key in done:
                continue
            if not ok:
                emit({"arch": arch, "shape": shape_name, "multi_pod": multi_pod,
                      "status": "skipped", "reason": why})
                continue
            rec = run_cell(arch, shape_name, multi_pod)
            emit(rec)
            n_err += rec["status"] == "error"
        return 1 if n_err else 0

    rec = run_cell(args.arch, args.shape, args.multi_pod)
    emit(rec)
    return 0 if rec["status"] == "ok" else 1


if __name__ == "__main__":
    sys.exit(main())
