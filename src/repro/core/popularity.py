"""Feature/byte popularity tracking across training jobs (Fig. 7, §5.2).

Records which features (and how many bytes) each training job reads; from
this we derive the popularity CDF (x% most popular bytes -> y% of traffic)
and the feature order used by the feature-reordering writer optimization.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class PopularityTracker:
    read_bytes_by_feature: Dict[int, float] = dataclasses.field(default_factory=dict)
    read_count_by_feature: Dict[int, int] = dataclasses.field(default_factory=dict)
    total_reads: int = 0

    def record_job(self, feature_bytes: Dict[int, float]) -> None:
        self.total_reads += 1
        for fid, nb in feature_bytes.items():
            self.read_bytes_by_feature[fid] = self.read_bytes_by_feature.get(fid, 0.0) + nb
            self.read_count_by_feature[fid] = self.read_count_by_feature.get(fid, 0) + 1

    def feature_order(self) -> List[int]:
        """Most-popular-first order (the FR writer input)."""
        return [
            fid for fid, _ in sorted(
                self.read_bytes_by_feature.items(), key=lambda kv: -kv[1]
            )
        ]

    def popularity_cdf(
        self, stored_bytes_by_feature: Dict[int, float]
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Fig. 7: x = CDF of stored bytes (most popular first), y = CDF of
        read traffic those bytes absorb."""
        feats = sorted(
            stored_bytes_by_feature,
            key=lambda f: -(self.read_bytes_by_feature.get(f, 0.0)
                            / max(stored_bytes_by_feature[f], 1.0)),
        )
        stored = np.array([stored_bytes_by_feature[f] for f in feats], np.float64)
        traffic = np.array(
            [self.read_bytes_by_feature.get(f, 0.0) for f in feats], np.float64
        )
        x = np.cumsum(stored) / max(stored.sum(), 1.0)
        y = np.cumsum(traffic) / max(traffic.sum(), 1.0)
        return x, y

    def bytes_fraction_for_traffic(
        self, stored_bytes_by_feature: Dict[int, float], traffic_frac: float = 0.8
    ) -> float:
        """Fraction of stored bytes needed to serve ``traffic_frac`` of reads
        (paper: 18-39% of bytes serve 80% of traffic)."""
        x, y = self.popularity_cdf(stored_bytes_by_feature)
        idx = int(np.searchsorted(y, traffic_frac))
        return float(x[min(idx, len(x) - 1)])
