"""qwen2-72b — dense GQA with QKV bias [arXiv:2407.10671]."""
import dataclasses
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-72b",
    family="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
)

SMOKE = dataclasses.replace(
    CONFIG, name="qwen2-smoke", num_layers=2, d_model=128,
    num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512, remat=False,
)
