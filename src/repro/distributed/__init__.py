from repro.distributed.sharding import (
    AxisRules,
    TRAIN_RULES,
    SERVE_RULES,
    logical_to_spec,
    spec_tree,
    shard_tree,
)
