"""Shared stripe cache + dedup tier (ISSUE 2 tentpole).

Cross-job behavior: overlapping sessions hit instead of re-reading HDD,
byte-identical stripes across partitions collapse to one content entry,
Zipf-skewed partition popularity raises the hit rate, and the cached read
path serves bytes identical to the uncached one.
"""
import numpy as np
import pytest

from repro.core import dwrf
from repro.core.cache import (
    DedupIndex,
    StripeCache,
    TenantPolicy,
    TenantShare,
    stripe_digest,
)
from repro.core.datagen import DataGenConfig, generate_partition
from repro.core.dpp import DPPService, SessionSpec
from repro.core.dpp.tensor_cache import TensorCache
from repro.core.reader import COALESCE_WINDOW, TableReader, plan_reads
from repro.core.schema import make_schema
from repro.core.tectonic import TectonicFS
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse

ROWS = 512
STRIPE = 128

# whole-module lock-order sanitizer coverage (ISSUE 8): every cache test
# runs under lockdep via the marker-driven autouse fixture in conftest
pytestmark = pytest.mark.lockdep


def _warehouse(n_partitions=2, name="ct", seed=3):
    s = make_schema(name, 16, 6, seed=seed)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(n_partitions, DataGenConfig(rows_per_partition=ROWS, seed=4),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE))
    return wh, t


def _assert_batches_identical(a, b):
    assert a.num_rows == b.num_rows
    assert set(a.dense) == set(b.dense) and set(a.sparse) == set(b.sparse)
    for fid in a.dense:
        np.testing.assert_array_equal(
            np.nan_to_num(a.dense[fid]), np.nan_to_num(b.dense[fid])
        )
    for fid in a.sparse:
        np.testing.assert_array_equal(a.sparse[fid].offsets, b.sparse[fid].offsets)
        np.testing.assert_array_equal(a.sparse[fid].values, b.sparse[fid].values)
    if a.labels is not None or b.labels is not None:
        np.testing.assert_array_equal(a.labels, b.labels)


# -- dedup index -------------------------------------------------------------


def test_dedup_index_resolves_content_keys():
    idx = DedupIndex()
    payload = b"x" * 100
    d = idx.register("p1", 4, 100, payload)
    assert d == stripe_digest(payload)
    # sub-extent inside the stripe -> content key with relative offset
    assert idx.resolve("p1", 10, 20) == ("c", d, 6, 20)
    # crossing the stripe boundary -> path-addressed (generation-scoped)
    assert idx.resolve("p1", 50, 100) == ("p", ("p1", 0), 50, 100)
    assert idx.resolve("other", 10, 20) == ("p", ("other", 0), 10, 20)
    # a rewrite bumps the generation: pre-rewrite keys can never match
    idx.invalidate("p1")
    assert idx.resolve("p1", 50, 100) == ("p", ("p1", 1), 50, 100)


def test_dedup_collapses_identical_stripes_across_partitions():
    s = make_schema("dd", 12, 4, seed=1)
    wh = Warehouse()
    t = wh.create_table(s)
    cache = StripeCache()
    wh.attach_cache(cache)
    batch = generate_partition(s, 0, DataGenConfig(rows_per_partition=ROWS, seed=9))
    opts = dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE)
    t.write_partition(0, batch, opts)
    t.write_partition(1, batch, opts)      # byte-identical content, new path
    st = cache.dedup.stats
    assert st.stripes_registered == 2 * (ROWS // STRIPE)
    assert cache.dedup.unique_stripes == ROWS // STRIPE
    assert st.dedup_ratio == pytest.approx(2.0)

    # reading partition 1 after partition 0 is ALL cache hits: the content
    # keys match even though partition 1's path was never read
    r = TableReader(t, s.logged_ids[:6], record_popularity=False)
    a = r.read_rows(t.partitions[0], 0, ROWS)
    assert a.bytes_from_storage > 0 and a.bytes_from_cache == 0
    b = r.read_rows(t.partitions[1], 0, ROWS)
    assert b.bytes_from_storage == 0 and b.bytes_from_cache == b.bytes_read
    _assert_batches_identical(a.batch, b.batch)


# -- cached read path --------------------------------------------------------


def test_cached_reads_byte_identical_and_storage_only_on_miss():
    wh, t = _warehouse()
    r = TableReader(t, t.schema.logged_ids[:8], record_popularity=False)
    meta = t.partitions[0]
    uncached = r.read_rows(meta, 0, ROWS)

    cache = StripeCache()
    wh.attach_cache(cache)
    miss = r.read_rows(meta, 0, ROWS)
    hit = r.read_rows(meta, 0, ROWS)
    _assert_batches_identical(uncached.batch, miss.batch)
    _assert_batches_identical(uncached.batch, hit.batch)
    assert miss.bytes_from_storage == miss.bytes_read
    assert hit.bytes_from_storage == 0
    assert hit.bytes_from_cache == hit.bytes_read == miss.bytes_read


def test_plan_reads_reports_cached_bytes():
    wh, t = _warehouse()
    cache = StripeCache()
    wh.attach_cache(cache)
    meta = t.partitions[0]
    proj = t.schema.logged_ids[:8]
    plan = plan_reads(meta.footer, proj, cache=cache, path=meta.path)
    assert plan.bytes_cached_planned == 0
    TableReader(t, proj, record_popularity=False).read_rows(meta, 0, ROWS)
    plan = plan_reads(meta.footer, proj, cache=cache, path=meta.path)
    assert plan.bytes_cached_planned == plan.bytes_planned
    # a window-coalesced plan spans stripes; segment-granular probing must
    # still see the cached stripes instead of reporting 0
    plan_w = plan_reads(meta.footer, proj, COALESCE_WINDOW,
                        cache=cache, path=meta.path)
    assert plan_w.bytes_cached_planned == plan_w.bytes_planned > 0


def test_flash_victim_tier_with_popularity_admission():
    wh, t = _warehouse()
    meta = t.partitions[0]
    proj = t.schema.logged_ids[:8]
    # DRAM big enough for one stripe only; flash takes popular victims
    probe = TableReader(t, proj, record_popularity=False)
    stripe_bytes = next(iter(probe.iter_stripes(meta, 0, STRIPE))).bytes_read
    cache = StripeCache(
        dram_capacity_bytes=int(1.5 * stripe_bytes),
        flash_admit_reads=2,
    )
    wh.attach_cache(cache)
    r = TableReader(t, proj, record_popularity=False)
    for _ in range(3):   # epochs over the partition: reuse with evictions
        list(r.iter_stripes(meta, 0, ROWS))
    assert cache.dram.evictions > 0
    assert cache.flash.admitted > 0          # popular victims spilled down
    assert cache.flash.hits > 0              # and were served from flash
    assert cache.flash.io.num_ios > 0        # flash I/O charged to the model
    assert cache.flash.rejected > 0          # one-touch victims stayed out


def test_one_touch_scan_does_not_enter_flash():
    wh, t = _warehouse(n_partitions=4)
    probe = TableReader(t, t.schema.logged_ids[:8], record_popularity=False)
    stripe_bytes = next(iter(probe.iter_stripes(t.partitions[0], 0, STRIPE))).bytes_read
    cache = StripeCache(dram_capacity_bytes=int(1.2 * stripe_bytes),
                        flash_admit_reads=2)
    wh.attach_cache(cache)
    r = TableReader(t, t.schema.logged_ids[:8], record_popularity=False)
    for p in range(4):                       # scan every partition once
        list(r.iter_stripes(t.partitions[p], 0, ROWS))
    assert cache.dram.evictions > 0
    assert cache.flash.admitted == 0         # nothing was read twice


def test_reattach_does_not_double_register_dedup_stats():
    wh, t = _warehouse()
    cache = StripeCache()
    wh.attach_cache(cache)
    before = (cache.dedup.stats.stripes_registered,
              cache.dedup.stats.logical_bytes,
              cache.dedup.stats.dedup_ratio)
    wh.attach_cache(cache)       # e.g. DPPService over an attached warehouse
    assert (cache.dedup.stats.stripes_registered,
            cache.dedup.stats.logical_bytes,
            cache.dedup.stats.dedup_ratio) == before


def test_single_flight_coalesces_concurrent_misses():
    import threading

    cache = StripeCache()
    key = ("p", "f", 0, 4)
    claims, hits = [], []
    started = threading.Event()

    def first():
        got = cache.get_or_claim(key)
        assert got is None          # cold: this thread owns the fill
        claims.append(1)
        started.set()
        cache.admit(key, b"data")   # releases the waiting reader

    def second():
        started.wait(5)
        got = cache.get_or_claim(key)   # blocks until the fill, then hits
        hits.append(got.payload)

    t2 = threading.Thread(target=second)
    t2.start()
    first()
    t2.join(5)
    assert claims == [1] and hits == [b"data"]
    assert cache.misses == 1 and cache.dram.hits == 1


# -- invalidation under churn (ISSUE 3) --------------------------------------


def test_rewrite_then_read_returns_new_bytes():
    wh, t = _warehouse()
    cache = StripeCache()
    wh.attach_cache(cache)
    proj = t.schema.logged_ids[:8]
    opts = dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE)
    r = TableReader(t, proj, record_popularity=False)
    old = r.read_rows(t.partitions[0], 0, ROWS)
    warm = r.read_rows(t.partitions[0], 0, ROWS)
    assert warm.bytes_from_cache == warm.bytes_read   # fully cached

    new_batch = generate_partition(
        t.schema, 0, DataGenConfig(rows_per_partition=ROWS, seed=99)
    )
    t.rewrite_partition(0, new_batch, opts)

    # reference: the same new batch served by a cache-less warehouse
    wh2 = Warehouse()
    t2 = wh2.create_table(t.schema)
    t2.write_partition(0, new_batch, opts)
    ref = TableReader(t2, proj, record_popularity=False).read_rows(
        t2.partitions[0], 0, ROWS
    )

    fresh = r.read_rows(t.partitions[0], 0, ROWS)
    assert fresh.bytes_from_cache == 0                # nothing stale served
    _assert_batches_identical(fresh.batch, ref.batch)
    again = r.read_rows(t.partitions[0], 0, ROWS)     # and the new bytes cache
    assert again.bytes_from_cache == again.bytes_read
    _assert_batches_identical(again.batch, ref.batch)


def test_generation_prevents_stale_admit_after_rewrite():
    # an in-flight reader that resolved its key BEFORE a rewrite and admits
    # the old bytes AFTER it must not poison post-rewrite readers
    cache = StripeCache()
    key_old = cache.resolve("f", 0, 4)
    cache.invalidate_path("f")            # the rewrite lands mid-read
    cache.admit(key_old, b"OLD!")         # stale late admit
    key_new = cache.resolve("f", 0, 4)
    assert key_new != key_old
    assert cache.get(key_new) is None     # old bytes unreachable


def test_rewrite_racing_inflight_read_never_poisons_cache(lockdep):
    # a rewrite landing in the middle of read_extents_ex must not let the
    # in-flight reader admit its pre-rewrite snapshot bytes under keys
    # that describe the NEW file version.  Runs under the lock-order
    # sanitizer: the rewrite-from-inside-a-read path nests
    # TectonicFS._mutate_lock / StripeCache._lock both ways around if the
    # discipline regresses — exactly what lockdep's teardown would flag.
    wh, t = _warehouse()
    cache = StripeCache()
    wh.attach_cache(cache)
    proj = t.schema.logged_ids[:8]
    opts = dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE)
    new_batch = generate_partition(
        t.schema, 0, DataGenConfig(rows_per_partition=ROWS, seed=77)
    )
    r = TableReader(t, proj, record_popularity=False)
    old_meta = t.partitions[0]

    orig_segments = cache.dedup.segments
    fired = []

    def seg_hook(path, off, ln):
        if not fired and path == old_meta.path:
            fired.append(1)
            t.rewrite_partition(0, new_batch, opts)   # lands mid-read
        return orig_segments(path, off, ln)

    # iter_stripes calls segments only inside read_extents_ex — i.e. AFTER
    # the (data, generation) snapshot — which is exactly the racing window
    cache.dedup.segments = seg_hook
    try:
        sr = next(iter(r.iter_stripes(old_meta, 0, STRIPE)))
    finally:
        cache.dedup.segments = orig_segments
    assert fired
    assert sr.bytes_from_storage == sr.bytes_read   # old bytes, not cache

    wh2 = Warehouse()
    t2 = wh2.create_table(t.schema)
    t2.write_partition(0, new_batch, opts)
    ref = TableReader(t2, proj, record_popularity=False).read_rows(
        t2.partitions[0], 0, ROWS
    )
    post = r.read_rows(t.partitions[0], 0, ROWS)
    _assert_batches_identical(post.batch, ref.batch)   # never the old bytes
    again = r.read_rows(t.partitions[0], 0, ROWS)
    _assert_batches_identical(again.batch, ref.batch)


def test_inflight_read_admit_checks_generation():
    # the precise poisoning interleaving: reader snapshots OLD bytes, a
    # same-geometry rewrite + re-registration lands before the reader
    # resolves its keys, so resolve() describes the NEW content — the
    # reader must NOT admit its old snapshot under that key
    fs = TectonicFS()
    cache = StripeCache()
    fs.attach_cache(cache)
    old, new = b"A" * 100, b"B" * 100
    fs.create("f", old)
    cache.dedup.register("f", 0, 100, old)

    orig_segments = cache.dedup.segments
    fired = []

    def seg_hook(path, off, ln):
        out = orig_segments(path, off, ln)
        if not fired:
            fired.append(1)
            fs.rewrite("f", new)                     # invalidates + bumps gen
            cache.dedup.register("f", 0, 100, new)   # same span geometry
        return out

    cache.dedup.segments = seg_hook
    try:
        racing = fs.read_extents_ex("f", [(0, 100)])
    finally:
        cache.dedup.segments = orig_segments
    assert racing.blobs == [old]          # the pre-rewrite reader gets old bytes
    assert fs.read_all("f") == new        # ...but nobody after it ever does
    assert fs.read_all("f") == new        # (and the cached copy is the new one)


def test_ttl_expiry_evicts():
    now = [0.0]
    cache = StripeCache(ttl_s=5.0, clock=lambda: now[0])
    key = cache.resolve("f", 0, 4)
    cache.admit(key, b"data")
    assert cache.get(key) is not None
    now[0] = 5.1
    assert cache.get(key) is None         # expired, not served
    assert cache.dram.expired == 1
    assert cache.dram.bytes_stored == 0   # storage reclaimed
    cache.admit(key, b"data")             # a fresh fill restarts the clock
    assert cache.get(key) is not None


# -- tenancy (ISSUE 3) -------------------------------------------------------


def test_tenant_shares_protect_working_set_from_antagonist():
    policy = TenantPolicy({"vip": TenantShare(dram_frac=0.6)})
    cache = StripeCache(dram_capacity_bytes=1000, tenancy=policy,
                        flash_admit_reads=10**9)      # DRAM-only
    vip_keys = [("p", (f"v{i}", 0), 0, 100) for i in range(5)]   # 500 B set
    for k in vip_keys:
        cache.admit(k, b"x" * 100, tenant="vip")
    # antagonist streams 30 one-touch entries through the tier
    for i in range(30):
        cache.admit(("p", (f"a{i}", 0), 0, 100), b"y" * 100, tenant="scan")
    # vip's resident set (within its 600 B guarantee) survived untouched
    for k in vip_keys:
        assert cache.get(k, tenant="vip") is not None
    assert cache.tenants["vip"].dram.evictions == 0
    assert cache.tenants["scan"].dram.evictions > 0
    # and the antagonist could still use the rest of the tier
    assert cache.tenants["scan"].dram.bytes_stored > 0


def test_borrow_when_idle_lets_lone_tenant_use_whole_tier():
    policy = TenantPolicy({"vip": TenantShare(dram_frac=0.3)})
    cache = StripeCache(dram_capacity_bytes=1000, tenancy=policy,
                        flash_admit_reads=10**9)
    for i in range(10):                   # 1000 B: far over the 300 B share
        cache.admit(("p", (f"v{i}", 0), 0, 100), b"x" * 100, tenant="vip")
    assert cache.tenants["vip"].dram.bytes_stored == 1000
    assert cache.dram.evictions == 0      # no one to give space back to


def test_tenant_byte_accounting_sums_to_tier_totals():
    wh, t = _warehouse(n_partitions=3)
    probe = TableReader(t, t.schema.logged_ids[:8], record_popularity=False)
    stripe_bytes = next(iter(probe.iter_stripes(t.partitions[0], 0, STRIPE))).bytes_read
    cache = StripeCache(dram_capacity_bytes=int(2.5 * stripe_bytes),
                        flash_admit_reads=2,
                        tenancy=TenantPolicy({"a": TenantShare(0.4, 0.4)}))
    wh.attach_cache(cache)
    ra = TableReader(t, t.schema.logged_ids[:8], record_popularity=False, tenant="a")
    rb = TableReader(t, t.schema.logged_ids[:8], record_popularity=False, tenant="b")
    for _ in range(2):
        for p in range(3):
            list(ra.iter_stripes(t.partitions[p], 0, ROWS))
            list(rb.iter_stripes(t.partitions[p], 0, ROWS))
    assert cache.dram.evictions > 0       # tier was contended
    for tier in ("dram", "flash"):
        for field in ("bytes_stored", "admitted", "evictions", "hits",
                      "bytes_served", "expired", "rejected"):
            total = getattr(getattr(cache, tier), field)
            by_tenant = sum(
                getattr(getattr(ts, tier), field) for ts in cache.tenants.values()
            )
            assert by_tenant == total, (tier, field, by_tenant, total)
    assert sum(ts.misses for ts in cache.tenants.values()) == cache.misses


def test_tenant_share_sum_validated():
    policy = TenantPolicy()
    policy.set_share("a", dram_frac=0.7)
    with pytest.raises(ValueError):
        policy.set_share("b", dram_frac=0.5)
    policy.set_share("b", dram_frac=0.3)          # exactly 1.0 is fine
    policy.set_share("a", dram_frac=0.6)          # re-registering replaces
    # the constructor path validates too — no bypass via the shares dict
    with pytest.raises(ValueError):
        TenantPolicy({"a": TenantShare(dram_frac=0.9),
                      "b": TenantShare(dram_frac=0.9)})
    # releasing a share frees its budget for the next job
    policy.clear_share("a")
    policy.set_share("c", dram_frac=0.7)


def test_session_share_released_on_stop():
    from repro.core.dpp import DPPService

    s = make_schema("shr", 16, 4, seed=2)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(1, DataGenConfig(rows_per_partition=ROWS, seed=4),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE))
    svc = DPPService(wh)
    # two sequential jobs may each reserve 0.6: the first share lapses
    # with its session instead of permanently exhausting the 1.0 budget
    for name in ("j1", "j2"):
        sess = svc.create_session(name, _spec(t), n_workers=1, dram_share=0.6)
        sess.run_to_completion(timeout_s=60)
        assert name not in svc.stripe_cache.tenancy.shares
    # a failed construction must not leak its reservation either
    import dataclasses as _dc

    bad = _dc.replace(_spec(t), partitions=(0, 99))    # partition 99 missing
    with pytest.raises(KeyError):
        svc.create_session("j3", bad, n_workers=1, dram_share=0.6)
    assert "j3" not in svc.stripe_cache.tenancy.shares
    svc.create_session("j4", _spec(t), n_workers=1, dram_share=0.6)


# -- cross-job behavior ------------------------------------------------------


def _spec(t, batch_size=128):
    dense = t.schema.dense_ids[:4]
    sparse = t.schema.sparse_ids[:2]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=500)
    return SessionSpec(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=batch_size, rows_per_split=STRIPE,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )


def _batch_signature(batches):
    sig = []
    for b in batches:
        sig.append(tuple(
            (k, b[k].shape, float(np.nan_to_num(b[k]).sum())) for k in sorted(b)
        ))
    return sorted(sig)


def test_concurrent_sessions_share_cache_and_serve_identical_rows():
    wh0, t0 = _warehouse(name="cs")
    svc0 = DPPService(wh0, enable_stripe_cache=False)
    for i in range(2):
        svc0.create_session(f"j{i}", _spec(t0), n_workers=2)
    res0 = svc0.run_all(timeout_s=60)
    m0 = svc0.fleet_metrics()

    wh1, t1 = _warehouse(name="cs")
    svc1 = DPPService(wh1)
    for i in range(2):
        svc1.create_session(f"j{i}", _spec(t1), n_workers=2)
    res1 = svc1.run_all(timeout_s=60)
    m1 = svc1.fleet_metrics()

    # same tensors served, over-read invariant intact
    for name in res0:
        assert _batch_signature(res0[name]) == _batch_signature(res1[name])
    assert m1.over_read_ratio == 1.0
    # the two sessions overlap fully: the cache halves storage RX
    assert m1.ingest_rx_bytes == m0.storage_rx_bytes
    assert m1.storage_rx_bytes <= 0.6 * m0.storage_rx_bytes
    assert m1.cache_rx_bytes > 0
    assert svc1.stripe_cache.hit_rate >= 0.5


def test_hit_rate_rises_with_zipf_skew():
    rng_partitions = 8
    n_accesses = 24
    hit_rates = {}
    for a in (0.0, 1.4):
        wh, t = _warehouse(n_partitions=rng_partitions, name=f"zipf{a}")
        # DRAM holds ~2 of 8 partitions: only a skewed access stream reuses
        r = TableReader(t, t.schema.logged_ids[:6], record_popularity=False)
        one = r.read_rows(t.partitions[0], 0, ROWS).bytes_read
        cache = StripeCache(dram_capacity_bytes=int(2.2 * one),
                            flash_admit_reads=10**9)   # DRAM-only
        wh.attach_cache(cache)
        rng = np.random.default_rng(5)
        if a == 0.0:
            seq = rng.integers(0, rng_partitions, n_accesses)
        else:
            seq = (rng.zipf(a + 1.0, n_accesses) - 1) % rng_partitions
        for p in seq:
            r.read_rows(t.partitions[int(p)], 0, ROWS)
        hit_rates[a] = cache.hit_rate
    assert hit_rates[1.4] > hit_rates[0.0] + 0.2, hit_rates


# -- tensor cache satellite --------------------------------------------------


def test_tensor_cache_rejects_oversized_insert():
    tc = TensorCache(capacity_bytes=1000)
    tc.put(("small",), [{"x": np.zeros(100, np.float32)}], cpu_s=0.1)   # 400 B
    tc.put(("big",), [{"x": np.zeros(1000, np.float32)}], cpu_s=0.1)   # 4000 B
    assert tc.get(("big",)) is None          # rejected, not stored
    assert tc.stats.rejected == 1
    assert tc.get(("small",)) is not None    # and nothing was evicted for it
    assert tc.stats.bytes_stored == 400 <= tc.capacity_bytes


def test_tensor_cache_put_refreshes_lru_on_insert_hit():
    tc = TensorCache(capacity_bytes=3000)
    mk = lambda v: [{"x": np.full(250, v, np.float32)}]     # 1000 B each
    tc.put(("a",), mk(1.0), cpu_s=0.1)
    tc.put(("b",), mk(2.0), cpu_s=0.1)
    tc.put(("c",), mk(3.0), cpu_s=0.1)
    # re-insert "a": idempotent (first entry wins) but must refresh recency
    tc.put(("a",), mk(99.0), cpu_s=0.1)
    assert tc.get(("a",))[0]["x"][0] == 1.0
    tc.put(("d",), mk(4.0), cpu_s=0.1)       # evicts LRU = "b", not "a"
    assert tc.get(("b",)) is None
    assert tc.get(("a",)) is not None
    assert tc.stats.bytes_stored <= 3000
