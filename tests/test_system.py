"""End-to-end behaviour tests for the paper's system: warehouse -> DPP ->
DLRM training with fault tolerance and popularity-driven reordering."""
import numpy as np
import pytest

from repro import configs as cfglib
from repro.core import dwrf
from repro.core.datagen import DataGenConfig, generate_partition
from repro.core.dpp import DPPSession, SessionSpec
from repro.core.reader import TableReader
from repro.core.schema import make_schema
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse
from repro.launch.train import dlrm_dpp_batches
from repro.optim import OptimizerConfig
from repro.train import Trainer, TrainerConfig


def test_full_pipeline_trains_dlrm():
    cfg = cfglib.get_smoke_config("dlrm-paper")
    batches, session = dlrm_dpp_batches(cfg, batch_size=128)
    tr = Trainer(cfg, OptimizerConfig(learning_rate=1e-3, warmup_steps=5, total_steps=25),
                 TrainerConfig(max_steps=25))
    state = tr.fit(batches)
    session.stop()
    losses = [m.loss for m in tr.history]
    assert losses[-1] < losses[0]
    assert state["step"] > 10
    m = session.worker_metrics()
    # ETL accounting invariants (Table 9 shape): all phases nonzero
    assert m.storage_rx_bytes > 0 and m.extract_out_bytes > 0 and m.tx_bytes > 0
    bd = m.cycle_breakdown()
    assert abs(sum(bd.values()) - 1.0) < 1e-6


def test_popularity_tracking_feeds_reordering():
    schema = make_schema("systest", 60, 12, seed=0)
    wh = Warehouse()
    t = wh.create_table(schema)
    t.generate(1, DataGenConfig(rows_per_partition=512, seed=1))
    proj = schema.logged_ids[:8]
    for _ in range(2):
        r = TableReader(t, proj)
        r.read_partition(t.partitions[0])
        r.finish_job()
    meta = t.write_partition(5, generate_partition(schema, 5, DataGenConfig(rows_per_partition=256)))
    head = meta.footer.feature_order[: len(proj)]
    assert set(head) <= set(proj)        # popular projection written first


def test_one_epoch_semantics():
    """Production jobs read each sample exactly once (§5.1)."""
    schema = make_schema("ep", 10, 4, seed=2)
    wh = Warehouse()
    t = wh.create_table(schema)
    t.generate(2, DataGenConfig(rows_per_partition=512, seed=3))
    dense, sparse = schema.dense_ids[:4], schema.sparse_ids[:2]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=100)
    spec = SessionSpec(
        table="ep", partitions=(0, 1),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=128, rows_per_split=256,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )
    sess = DPPSession(spec, t, n_workers=2)
    batches = sess.run_to_completion(timeout_s=60)
    assert sum(b["label"].shape[0] for b in batches) == 1024   # exactly one epoch
