"""In-process DPP session runner: Master + Workers + Clients + monitor.

The fully-managed-service behavior of §3.2.1 in one process: launches the
Master and an initial worker fleet, monitors health (restarting dead
Workers without checkpoint restore — they are stateless), runs the
auto-scaling controller, and wires Clients for the training side.

``DPPService`` is the multi-tenant front-end: it runs many concurrent
sessions over one warehouse behind a single shared ``StripeCache``
handle, so combo-window jobs re-reading the same partitions (§5.2) hit
DRAM/flash instead of HDD.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.core.cache import StripeCache
from repro.core.dpp.autoscale import (
    ElasticController, ElasticPolicy, observation_from_delta,
)
from repro.core.dpp.client import ClientMetrics, DPPClient, SessionFailed
from repro.core.dpp.master import DPPMaster, SessionSpec
from repro.core.dpp.prefetch import PrefetchPlanner
from repro.core.dpp.worker import DPPWorker, WorkerMetrics
from repro.core.warehouse import Table, Warehouse
from repro.obs import NULL_TRACER, MetricsRegistry, merge_metrics


class DPPSession:
    # deliberately lock-free (REPRO-R001 / racedep allowlist): `_wid` is
    # only bumped by _launch_worker, which runs in __init__ and then only
    # ever on the single monitor thread; `_monitor` is written once by
    # the thread calling start()
    _unshared = ("_wid", "_monitor")

    def __init__(
        self,
        spec: SessionSpec,
        table: Table,
        n_workers: int = 2,
        n_clients: int = 1,
        auto_scale: bool = False,
        monitor_interval_s: float = 0.2,
        lease_s: float = 5.0,
        max_workers: int = 16,
        tensor_cache=None,
        name: str = "session",
        prefetch: bool = False,
        prefetch_depth: int = 4,
        on_stop=None,
        dispatch_budget: int = 3,
        elastic_policy: Optional[ElasticPolicy] = None,
        engine: str = "numpy",
        decode_engine: str = "numpy",
        double_buffer: bool = True,
        clock: Callable[[], float] = time.time,
        tracer=NULL_TRACER,
    ):
        self.spec = spec
        self.table = table
        self.name = name                   # tenant id for the stripe cache
        self._on_stop = on_stop            # e.g. release the tenant's share
        self.engine = engine               # TransformEngine for every worker
        self.decode_engine = decode_engine # DecodeEngine for every worker
        self.double_buffer = double_buffer # fetch/decode overlap in extract
        self.tracer = tracer
        if tracer.enabled and not table.fs.tracer.enabled:
            # storage/cache spans come from the shared fs: attach once,
            # never downgrade a real tracer someone else installed
            table.fs.attach_tracer(tracer)
        # injected clock (REPRO-C001): deadlines/scale-event timestamps are
        # testable without wall-clock sleeps; shared with the master
        self._clock = clock
        partition_rows = {p: table.partitions[p].num_rows for p in spec.partitions}
        # stripe-aligned splits: the writer emits uniform stripes, so the
        # first stripe's row count is the partition's stripe size
        partition_stripe_rows = {
            p: (table.partitions[p].footer.stripes[0].num_rows
                if table.partitions[p].footer.stripes else 0)
            for p in spec.partitions
        }
        self.master = DPPMaster(
            spec, partition_rows, lease_s=lease_s,
            partition_stripe_rows=partition_stripe_rows,
            dispatch_budget=dispatch_budget,
            clock=clock,
        )
        # feedback-driven elastic scaling (ISSUE 4): stall rate + queue
        # depth drive worker count and prefetch depth, with hysteresis
        self.controller = ElasticController(
            elastic_policy
            or ElasticPolicy(max_workers=max_workers),
            prefetch_depth=prefetch_depth,
        )
        self.tensor_cache = tensor_cache
        # background cache warmer for upcoming splits (ISSUE 3): fetches
        # only the segments plan_reads reports uncached, off-thread
        self.prefetcher: Optional[PrefetchPlanner] = (
            PrefetchPlanner(
                table, self.master, spec.feature_ids,
                tenant=name, depth=prefetch_depth,
            )
            if prefetch else None
        )
        self.workers: List[DPPWorker] = []
        # removed workers (crashed-and-replaced, drained scale-downs) keep
        # contributing to the session's byte/cycle accounting
        self._graveyard: List[DPPWorker] = []
        self._wid = 0
        for _ in range(n_workers):
            self._launch_worker()
        self.clients = [
            DPPClient(f"client{i}", self.workers, prefetcher=self.prefetcher,
                      master=self.master, tenant=name, tracer=tracer)
            for i in range(n_clients)
        ]
        # unified metrics registry: every signal the monitor (and the
        # Table-7 stall report) consumes comes from one snapshot/delta API
        self.registry = MetricsRegistry()
        self.registry.register("worker", self.worker_metrics)
        self.registry.register("client", self._client_metrics)
        self.registry.register_value(
            "fleet.buffered_batches",
            lambda: sum(w.buffered for w in self.workers), kind="gauge",
        )
        self.registry.register_value(
            "fleet.active_workers",
            lambda: sum(1 for w in self.workers if not w.retired),
            kind="gauge",
        )
        # one computed counter, not per-worker values: a single sum keeps
        # the float accumulation order identical to the old inline monitor
        # arithmetic, so controller decisions stay byte-for-byte the same
        self.registry.register_value(
            "fleet.busy_s",
            lambda: sum(
                w.metrics.busy_s for w in self.workers + self._graveyard
            ),
            kind="counter",
        )
        self.auto_scale = auto_scale
        self.monitor_interval_s = monitor_interval_s
        self._stop = threading.Event()
        self._monitor: Optional[threading.Thread] = None
        self.scale_events: List[Dict] = []
        self.restart_events: List[str] = []

    # -- lifecycle ------------------------------------------------------------

    def _launch_worker(self, fail_after: Optional[int] = None) -> DPPWorker:
        w = DPPWorker(
            f"w{self._wid}", self.master, self.table,
            fail_after_splits=fail_after, tensor_cache=self.tensor_cache,
            tenant=self.name, engine=self.engine,
            decode_engine=self.decode_engine, double_buffer=self.double_buffer,
            tracer=self.tracer,
        )
        self._wid += 1
        self.workers.append(w)
        return w

    def start(self) -> None:
        if self.prefetcher is not None:
            self.prefetcher.start()
        for w in self.workers:
            if w._thread is None:
                w.start()
        self._monitor = threading.Thread(target=self._monitor_loop, daemon=True)
        self._monitor.start()

    def stop(self) -> None:
        self._stop.set()
        if self.prefetcher is not None:
            self.prefetcher.stop()
        # join the monitor BEFORE snapshotting the fleet: it is the only
        # thread that launches workers, so afterwards no worker can be
        # born unseen and leak past the stop/join below
        if self._monitor is not None:
            self._monitor.join(timeout=5.0)
        fleet = list(self.workers)
        for w in fleet:
            w.stop()
        for w in fleet:
            w.join(timeout=2.0)
        if self.prefetcher is not None:
            self.prefetcher.join(timeout=2.0)
        if self._on_stop is not None:
            self._on_stop()

    # -- monitor: health + autoscaling -----------------------------------------

    def _monitor_loop(self) -> None:
        prev = None                    # previous registry Snapshot
        while not self._stop.is_set() and not self.master.finished:
            time.sleep(self.monitor_interval_s)
            # health: restart dead workers (stateless -> no restore needed);
            # retired (drained) workers exited on purpose — remove them once
            # their buffers are empty instead of "restarting" the scale-down
            for w in list(self.workers):
                if w._thread is None or w._thread.is_alive():
                    continue
                if w.retired:
                    if w.buffered == 0:
                        self.workers.remove(w)
                        self._graveyard.append(w)
                        for c in self.clients:
                            c.rebind(self.workers)
                elif not w.alive and not self.master.finished:
                    self.master.forget_worker(w.worker_id)
                    # keep the corpse in the fleet until clients drain its
                    # buffer — batches of splits the Master already counted
                    # done must not vanish with the worker.  The retired
                    # branch above removes it once empty.
                    w.retired = True
                    nw = self._launch_worker()
                    nw.start()
                    self.restart_events.append(w.worker_id)
                    for c in self.clients:
                        c.rebind(self.workers)
            if not self.auto_scale:
                continue
            # observation via the registry: counters (stalls, waits, busy)
            # arrive as per-tick deltas, gauges (queue depth, active
            # workers) as levels — the arithmetic lives with the
            # controller in autoscale.observation_from_delta
            snap = self.registry.snapshot()
            delta = snap.delta(prev)
            prev = snap
            decision = self.controller.observe(
                observation_from_delta(delta, self.monitor_interval_s)
            )
            if decision.prefetch_depth is not None and self.prefetcher is not None:
                self.prefetcher.set_depth(decision.prefetch_depth)
            if decision.worker_delta > 0:
                for _ in range(decision.worker_delta):
                    w = self._launch_worker()
                    w.start()
                for c in self.clients:
                    c.rebind(self.workers)
            elif decision.worker_delta < 0:
                active = [w for w in self.workers if not w.retired]
                victims = active[decision.worker_delta:]
                for v in victims:
                    # graceful drain: finish + deliver the in-flight split,
                    # stop pulling new ones, retire without a health restart
                    v.retired = True
                    v.drain()
            if decision.worker_delta != 0:
                self.scale_events.append({
                    "t": self._clock(), "delta": decision.worker_delta,
                    "reason": decision.reason,
                })

    # -- state + aggregate metrics ------------------------------------------------

    @property
    def state(self) -> str:
        """``SessionState``: RUNNING / COMPLETED / DEGRADED / FAILED."""
        return self.master.state

    def failure_report(self):
        """Quarantined splits with their exception chains (ISSUE 4)."""
        return self.master.failure_report()

    def worker_metrics(self) -> WorkerMetrics:
        total = WorkerMetrics()
        for w in list(self.workers) + list(self._graveyard):
            total.merge(w.metrics)
        return total

    def _client_metrics(self) -> ClientMetrics:
        total = ClientMetrics()
        for c in self.clients:
            merge_metrics(total, c.metrics)
        return total

    def run_to_completion(
        self, max_batches: Optional[int] = None, timeout_s: float = 120.0
    ) -> List[Dict[str, np.ndarray]]:
        """Drive client 0 until the dataset is exhausted (one epoch, §5.1).

        A DEGRADED session drains normally — every healthy split's batches
        are delivered and the quarantine is left for ``failure_report()``.
        A FAILED session raises ``SessionFailed`` (from the client) with
        the offending splits attached; the fleet is stopped either way.
        """
        self.start()
        out = []
        deadline = self._clock() + timeout_s
        try:
            # session.run bounds the tenant's wall clock: the stall report
            # divides every other span's time by this one's duration
            with self.tracer.span("session.run", tenant=self.name) as sp:
                while self._clock() < deadline:
                    # short poll: the post-exhaustion drain check costs one
                    # poll interval, not a whole client timeout (which would
                    # be billed as trainer stall time and swamp the Table-7
                    # metric)
                    batch = self.clients[0].get_batch(timeout=0.25)
                    if batch is not None:
                        out.append(batch)
                        if max_batches and len(out) >= max_batches:
                            break
                        continue
                    if self.master.finished and all(
                        w.buffered == 0 for w in self.workers
                    ):
                        break
                sp.set(batches=len(out))
        finally:
            self.stop()
        return out


class DPPService:
    """Multi-tenant DPP front-end: concurrent sessions over one warehouse
    sharing a single ``StripeCache`` (and optional ``TensorCache``).

    Production DPP is a fleet serving many training jobs at once; the
    cross-job locality the paper measures (§5.2: jobs in a combo window
    re-read the same partitions) only pays off if the cache handle is
    shared *across* sessions, which is exactly what this class wires up.
    """

    def __init__(
        self,
        warehouse: Warehouse,
        stripe_cache: Optional[StripeCache] = None,
        tensor_cache=None,
        enable_stripe_cache: bool = True,
        clock: Callable[[], float] = time.time,
        tracer=NULL_TRACER,
    ):
        self.warehouse = warehouse
        self._clock = clock
        self.tracer = tracer
        if tracer.enabled:
            warehouse.fs.attach_tracer(tracer)
        self.stripe_cache = stripe_cache or (
            StripeCache() if enable_stripe_cache else None
        )
        if self.stripe_cache is not None:
            warehouse.attach_cache(self.stripe_cache)
        self.tensor_cache = tensor_cache
        self.sessions: Dict[str, DPPSession] = {}
        self.session_errors: Dict[str, SessionFailed] = {}

    def create_session(
        self,
        name: str,
        spec: SessionSpec,
        dram_share: float = 0.0,
        flash_share: float = 0.0,
        **kw,
    ) -> DPPSession:
        """Register a session; its ``name`` is the cache tenant id.  A
        non-zero ``dram_share``/``flash_share`` reserves that fraction of
        the shared tier for this job (borrow-when-idle: unreserved and
        idle capacity stays usable by everyone).  The reservation lapses
        automatically when the session stops, so sequential jobs can each
        claim large shares without exhausting the 1.0 budget and a dead
        job's resident bytes stop being eviction-protected.

        ``engine="pallas"`` (forwarded to every worker) runs the transform
        stage wave-fused through ``kernels.fused_transform`` instead of
        per-feature numpy; ``decode_engine="pallas"`` does the same for the
        extract stage (whole-stripe batched decode via ``kernels.decode``,
        see ``repro.core.decode``) and ``double_buffer`` overlaps stripe
        N+1's extent fetch with stripe N's decode.  All engines produce
        byte-identical batches, so mixed-engine fleets can share one
        ``TensorCache``."""
        reserve = (dram_share or flash_share) and self.stripe_cache is not None
        if reserve:
            # validate the share up front (so an over-committed request
            # fails before any session machinery spins up) ...
            self.stripe_cache.tenancy.set_share(name, dram_share, flash_share)
        kw.setdefault("tracer", self.tracer)
        try:
            sess = DPPSession(
                spec, self.warehouse.table(spec.table), name=name,
                on_stop=(
                    (lambda: self.stripe_cache.tenancy.clear_share(name))
                    if reserve else None
                ),
                tensor_cache=kw.pop("tensor_cache", self.tensor_cache), **kw,
            )
        except BaseException:
            if reserve:
                # ... but never leak the reservation if construction fails:
                # on_stop only runs for sessions that actually exist
                self.stripe_cache.tenancy.clear_share(name)
            raise
        self.sessions[name] = sess
        return sess

    def run_all(
        self, max_batches: Optional[int] = None, timeout_s: float = 120.0
    ) -> Dict[str, List[Dict[str, np.ndarray]]]:
        """Run every registered session to completion concurrently —
        the combo-window workload whose overlapping reads the shared
        cache collapses."""
        results: Dict[str, List[Dict[str, np.ndarray]]] = {}
        self.session_errors: Dict[str, SessionFailed] = {}

        def _drive(name: str, sess: DPPSession) -> None:
            try:
                results[name] = sess.run_to_completion(max_batches, timeout_s)
            except SessionFailed as e:
                # one tenant's poisoned data must not take down the fleet:
                # record the structured failure, keep the other sessions
                results[name] = []
                self.session_errors[name] = e

        threads = [
            threading.Thread(target=_drive, args=(n, s), daemon=True)
            for n, s in self.sessions.items()
        ]
        for t in threads:
            t.start()
        deadline = self._clock() + timeout_s
        for t in threads:
            t.join(max(0.0, deadline - self._clock()))
        # a wedged session past the deadline reports empty rather than
        # silently dropping its key
        for name in self.sessions:
            results.setdefault(name, [])
        return results

    def fleet_metrics(self) -> WorkerMetrics:
        total = WorkerMetrics()
        for s in self.sessions.values():
            total.merge(s.worker_metrics())
        return total

    def cache_summary(self) -> Dict[str, float]:
        return self.stripe_cache.summary() if self.stripe_cache else {}

    def tenant_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-job cache accounting (hits, resident bytes, evictions)."""
        return self.stripe_cache.tenant_summary() if self.stripe_cache else {}
