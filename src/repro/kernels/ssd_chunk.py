"""Pallas TPU kernel: Mamba-2 SSD chunked forward.

The SSM-trainer hot spot: per (batch·head, chunk) grid cell, the kernel
computes the intra-chunk quadratic contribution ((C·Bᵀ) ∘ L) x on the MXU,
adds the inter-chunk carried-state contribution, and updates the running
(P, N) state in VMEM scratch — the state never round-trips to HBM between
chunks (the XLA scan carries it through HBM every chunk).  Grid order on
TPU is row-major with the chunk dim innermost, so the scratch carry across
chunks is sequential per (batch, head), mirroring the flash-attention
pattern.

Layout: heads ride the leading grid dim (one head per cell keeps every
block 2D and MXU-aligned for P, N in {64, 128}).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref, *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0]                                   # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)             # (1, Q) row
    a = a_ref[0, 0].astype(jnp.float32)            # scalar A (negative)
    bv = b_ref[0]                                  # (Q, N)
    cv = c_ref[0]                                  # (Q, N)

    da = dt[0] * a                                 # (Q,)
    cs = jnp.cumsum(da)                            # (Q,)
    seg = cs[:, None] - cs[None, :]                # (Q, Q)
    causal = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.exp(jnp.where(causal, seg, -1e30))

    cb = jnp.dot(cv, bv.T, preferred_element_type=jnp.float32)   # (Q, Q)
    m = cb * L * dt[0][None, :]
    y_intra = jnp.dot(m.astype(x.dtype), x, preferred_element_type=jnp.float32)

    state = state_ref[...]                         # (N, P) f32
    y_inter = jnp.dot(
        (cv.astype(jnp.float32) * jnp.exp(cs)[:, None]), state,
        preferred_element_type=jnp.float32,
    )                                              # (Q, P)

    decay_out = jnp.exp(cs[-1] - cs)               # (Q,)
    dstate = jnp.dot(
        (bv.astype(jnp.float32) * (dt[0] * decay_out)[:, None]).T,
        x.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )                                              # (N, P)
    state_ref[...] = jnp.exp(cs[-1]) * state + dstate

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_forward(
    x: jax.Array,      # (BH, S, P) — batch*heads flattened
    dt: jax.Array,     # (BH, S) post-softplus
    a: jax.Array,      # (BH,) negative decay per head
    b_: jax.Array,     # (BH, S, N)
    c_: jax.Array,     # (BH, S, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Returns y (BH, S, P) = SSD(x, dt, A, B, C) with zero initial state."""
    bh, s, p = x.shape
    n = b_.shape[-1]
    q = min(chunk, s)
    assert s % q == 0, (s, q)
    nc = s // q
    grid = (bh, nc)
    return pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, q, p), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, 1, q), lambda g, c: (g, 0, c)),
            pl.BlockSpec((1, 1), lambda g, c: (g, 0)),
            pl.BlockSpec((1, q, n), lambda g, c: (g, c, 0)),
            pl.BlockSpec((1, q, n), lambda g, c: (g, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda g, c: (g, c, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(x, dt.reshape(bh, 1, s), a.reshape(bh, 1), b_, c_)
