"""Table 9 (DPP worker throughput + workers/trainer) and Fig. 9 (bottleneck
breakdown), both analytic (fleet hardware) and measured (this container)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_us
from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.dpp import DPPSession, SessionSpec
from repro.core.dpp.simulator import (
    C_V1, C_V2, C_SOTA, NODE_SPECS, WORKLOADS, worker_throughput, workers_per_trainer,
)
from repro.core.schema import make_schema
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse


def run() -> None:
    for name, w in WORKLOADS.items():
        t = worker_throughput(w, C_V1)
        emit(
            f"table9.{name}", 0.0,
            f"kQPS={t.kqps:.2f} storageRX={t.storage_rx_gbps:.2f}GB/s "
            f"trRX={t.transform_rx_gbps:.2f} TX={t.tx_gbps:.2f} "
            f"workers_per_trainer={workers_per_trainer(w, C_V1):.1f} bound={t.bound}",
        )
        emit(
            f"fig9.{name}.utilization", 0.0,
            " ".join(f"{k}={v:.2f}" for k, v in t.utilization.items()),
        )
    # §6.3 forward-looking: bottleneck shift across node generations
    for node in ("C-v1", "C-v2", "C-v3", "C-vSotA"):
        b = worker_throughput(WORKLOADS["RM2"], NODE_SPECS[node]).bound
        emit(f"table10.RM2_bound.{node}", 0.0, f"bound={b}")

    # measured on this container: one real DPP worker epoch
    schema = make_schema("bdpp", 60, 12, seed=0)
    wh = Warehouse()
    t = wh.create_table(schema)
    t.generate(1, DataGenConfig(rows_per_partition=4096, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=1024))
    dense, sparse = schema.dense_ids[:20], schema.sparse_ids[:8]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=100_000, n_derived=6)
    spec = SessionSpec(
        table="bdpp", partitions=(0,), feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs), batch_size=512, rows_per_split=1024,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse) + tuple(f"g{j}" for j in range(6)),
        max_ids_per_feature=16,
    )
    sess = DPPSession(spec, t, n_workers=1)
    import time
    t0 = time.perf_counter()
    batches = sess.run_to_completion(timeout_s=120)
    wall = time.perf_counter() - t0
    m = sess.worker_metrics()
    rows = sum(b["label"].shape[0] for b in batches)
    emit(
        "table9.measured_local_worker", wall / max(rows, 1) * 1e6,
        f"kQPS={rows/wall/1e3:.2f} storage_rx={m.storage_rx_bytes} tx={m.tx_bytes} "
        f"stripes_read={m.stripes_read} over_read={m.over_read_ratio:.3f} "
        f"breakdown=" + "/".join(f"{k}:{v:.2f}" for k, v in m.cycle_breakdown().items()),
    )
