"""Lock-discipline rules (the StripeCache/TectonicFS/DPPMaster convention).

Classes that guard shared state declare a lock attribute in ``__init__``
(``self._lock = threading.Lock()`` — any ``_*lock`` name, ``Lock`` or
``RLock``).  The repo convention, established by PRs 2-4 and enforced
here:

  * public methods mutate ``self.*`` state only inside a
    ``with self._lock:`` block (REPRO-L001);
  * helpers that *assume* the lock is held carry a ``_locked`` suffix,
    never acquire the lock themselves, and are only called from inside a
    lock region or from other ``_locked`` helpers (REPRO-L002);
  * a private helper that mutates shared state without acquiring the lock
    must carry the ``_locked`` suffix so call sites know the contract
    (REPRO-L003; helpers only ever called from ``__init__`` are exempt —
    pre-publication state is not yet shared).

Mutation means: assignment / augmented assignment / deletion whose target
is rooted at ``self.<attr>`` (subscripts included, so
``self._dram[k] = e`` counts), or a call to a known mutating method
(``append``/``pop``/``update``/``record``/...) on a ``self``-rooted
receiver.  Locals and parameters are never flagged — cross-object aliasing
is out of scope for a repo-native linter.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.core import CheckContext, Finding, attr_chain, checker, rule

L001 = rule("REPRO-L001",
            "public method of a lock-declaring class mutates shared state "
            "outside `with self._lock`")
L002 = rule("REPRO-L002",
            "`_locked` helper called outside a lock region, or itself "
            "acquires the lock")
L003 = rule("REPRO-L003",
            "private helper mutates shared state without the lock and "
            "lacks the `_locked` suffix")

_LOCK_ATTR_RE = re.compile(r"^_\w*lock$")

_MUTATORS = {
    "append", "add", "pop", "remove", "clear", "update", "insert",
    "extend", "discard", "setdefault", "popitem", "move_to_end",
    "record", "record_job", "merge",
}


def _self_root(node: ast.AST) -> Optional[str]:
    """First attribute above a ``self`` root, descending through attribute
    and subscript chains: ``self._dram[k].expires`` -> ``_dram``."""
    attrs: List[str] = []
    while True:
        if isinstance(node, ast.Attribute):
            attrs.append(node.attr)
            node = node.value
        elif isinstance(node, ast.Subscript):
            node = node.value
        else:
            break
    if isinstance(node, ast.Name) and node.id == "self" and attrs:
        return attrs[-1]
    return None


def _declared_locks(cls: ast.ClassDef) -> Set[str]:
    """Lock attributes assigned from ``threading.Lock()``/``RLock()``."""
    locks: Set[str] = set()
    for node in ast.walk(cls):
        if not isinstance(node, ast.Assign):
            continue
        if not (isinstance(node.value, ast.Call)):
            continue
        chain = attr_chain(node.value.func)
        if not chain or chain[-1] not in ("Lock", "RLock"):
            continue
        for t in node.targets:
            root = _self_root(t)
            if root and _LOCK_ATTR_RE.match(root):
                locks.add(root)
    return locks


def _is_lock_expr(expr: ast.AST, locks: Set[str]) -> bool:
    """``with <anything>.<lockname>:`` opens a lock region — the receiver
    may be ``self``, a local alias, or another instance (``m._lock`` in a
    classmethod)."""
    chain = attr_chain(expr)
    return bool(chain) and len(chain) >= 2 and chain[-1] in locks


class _MethodScan(ast.NodeVisitor):
    """One pass over a method body tracking lock depth."""

    def __init__(self, locks: Set[str]):
        self.locks = locks
        self.depth = 0
        # (line, root_attr) of self-rooted mutations at depth 0
        self.unlocked_mutations: List[Tuple[int, str]] = []
        # (line, helper_name, depth>0) for calls to *_locked helpers
        self.locked_calls: List[Tuple[int, str, bool]] = []
        # lines where `with self._lock` appears (for the L002 self-acquire check)
        self.acquires: List[int] = []

    def visit_With(self, node: ast.With) -> None:
        is_lock = any(_is_lock_expr(i.context_expr, self.locks)
                      for i in node.items)
        if is_lock:
            self.acquires.append(node.lineno)
            self.depth += 1
        self.generic_visit(node)
        if is_lock:
            self.depth -= 1

    def _mutation(self, target: ast.AST, line: int) -> None:
        if isinstance(target, (ast.Tuple, ast.List)):
            for el in target.elts:
                self._mutation(el, line)
            return
        root = _self_root(target)
        if root and root not in self.locks and self.depth == 0:
            self.unlocked_mutations.append((line, root))

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            self._mutation(t, node.lineno)
        self.generic_visit(node)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._mutation(node.target, node.lineno)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._mutation(node.target, node.lineno)
        self.generic_visit(node)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._mutation(t, node.lineno)
        self.generic_visit(node)

    def visit_Call(self, node: ast.Call) -> None:
        if isinstance(node.func, ast.Attribute):
            name = node.func.attr
            if name in _MUTATORS:
                root = _self_root(node.func.value)
                if root and self.depth == 0:
                    self.unlocked_mutations.append((node.lineno, root))
            if name.endswith("_locked"):
                self.locked_calls.append((node.lineno, name, self.depth > 0))
        self.generic_visit(node)


def _call_sites(cls: ast.ClassDef, method: str) -> List[str]:
    """Names of methods within ``cls`` that call ``<recv>.<method>(...)``."""
    sites: List[str] = []
    for fn in cls.body:
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        for node in ast.walk(fn):
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == method):
                sites.append(fn.name)
    return sites


@checker("lock-discipline")
def check_locks(ctx: CheckContext):
    findings: List[Finding] = []
    for mod in ctx.src_modules():
        for cls in [n for n in ast.walk(mod.tree) if isinstance(n, ast.ClassDef)]:
            locks = _declared_locks(cls)
            if not locks:
                continue
            lockdesc = "/".join(f"self.{l}" for l in sorted(locks))
            for fn in cls.body:
                if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                if fn.name.startswith("__"):
                    continue   # __init__ et al: pre-publication state
                scan = _MethodScan(locks)
                for stmt in fn.body:
                    scan.visit(stmt)
                sym = f"{cls.name}.{fn.name}"
                public = not fn.name.startswith("_")
                is_locked_helper = fn.name.endswith("_locked")
                if public:
                    for line, root in scan.unlocked_mutations:
                        findings.append(Finding(
                            L001, mod.rel, line,
                            f"mutates self.{root} outside `with {lockdesc}`",
                            sym,
                        ))
                elif not is_locked_helper and scan.unlocked_mutations:
                    sites = _call_sites(cls, fn.name)
                    if not sites or any(s != "__init__" for s in sites):
                        line, root = scan.unlocked_mutations[0]
                        findings.append(Finding(
                            L003, mod.rel, line,
                            f"mutates self.{root} without {lockdesc}; "
                            "rename with a `_locked` suffix (callers must "
                            "hold the lock) or acquire the lock",
                            sym,
                        ))
                if is_locked_helper and scan.acquires:
                    findings.append(Finding(
                        L002, mod.rel, scan.acquires[0],
                        f"`_locked` helper acquires {lockdesc} itself "
                        "(callers already hold it — deadlock hazard)",
                        sym,
                    ))
                for line, callee, under_lock in scan.locked_calls:
                    if not under_lock and not is_locked_helper:
                        findings.append(Finding(
                            L002, mod.rel, line,
                            f"calls {callee}() outside a `with {lockdesc}` "
                            "block",
                            sym,
                        ))
    return findings
