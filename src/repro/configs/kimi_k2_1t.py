"""kimi-k2-1t-a32b — trillion-param MoE 384e top-8 (assignment-table config)
[arXiv:2501.kimi2]."""
import dataclasses
from repro.models.common import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    moe=MoEConfig(
        num_experts=384, top_k=8, d_ff=2048,
        num_shared_experts=1, shared_d_ff=2048,
    ),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="kimi-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=64,
    vocab_size=512,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=64, num_shared_experts=1, shared_d_ff=64),
    remat=False,
)
