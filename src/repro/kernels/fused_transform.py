"""Pallas TPU kernel: fused multi-feature transform — the §7.2 flagship.

The paper observed ~3 orders of magnitude speedup from applying one kernel
to a tensor combining 1000 sparse features versus launching per-feature
kernels.  The TPU-native version packs features into the 128-lane minor
dimension of an int32 tile; per-feature op codes and parameters ride along
as (1, features) rows, and a single pallas_call applies
hash/modulus/clamp/bucketize across every feature column — kernel-launch
amortization replaced by VMEM-tile batching.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.sigrid_hash import _hash_u32

OP_IDENTITY = 0
OP_SIGRID_HASH = 1
OP_POSITIVE_MODULUS = 2
OP_CLAMP = 3
OP_BUCKETIZE = 4


def _kernel(ids_ref, code_ref, p0_ref, p1_ref, out_ref):
    ids = ids_ref[...]                             # (br, bc) i32
    code = code_ref[...][0][None, :]               # (1, bc) -> broadcast
    p0 = p0_ref[...][0][None, :]
    p1 = p1_ref[...][0][None, :]

    h = _hash_u32(ids.astype(jnp.uint32) ^ p0.astype(jnp.uint32))
    out_hash = (h % jnp.maximum(p1.astype(jnp.uint32), 1)).astype(jnp.int32)
    m = jnp.maximum(p1, 1)
    out_mod = jnp.mod(jnp.mod(ids, m) + m, m)
    out_clamp = jnp.clip(ids, p0, p1)
    scale = jnp.maximum(p1, 1)
    out_bucket = jnp.clip((ids - p0) // scale, 0, 255)

    out = jnp.where(code == OP_SIGRID_HASH, out_hash, ids)
    out = jnp.where(code == OP_POSITIVE_MODULUS, out_mod, out)
    out = jnp.where(code == OP_CLAMP, out_clamp, out)
    out = jnp.where(code == OP_BUCKETIZE, out_bucket, out)
    out_ref[...] = out.astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("block_rows", "block_cols", "interpret")
)
def fused_transform(
    ids: jax.Array,          # (rows, features) int32
    op_codes: jax.Array,     # (features,) int32
    param0: jax.Array,       # (features,) int32
    param1: jax.Array,       # (features,) int32
    *,
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
) -> jax.Array:
    rows, feats = ids.shape
    br = min(block_rows, rows)
    bc = min(block_cols, feats)
    grid = (pl.cdiv(rows, br), pl.cdiv(feats, bc))
    row = lambda a: a.reshape(1, feats).astype(jnp.int32)
    return pl.pallas_call(
        _kernel,
        grid_spec=pl.GridSpec(
            grid=grid,
            in_specs=[
                pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                pl.BlockSpec((1, bc), lambda i, j: (0, j)),
                pl.BlockSpec((1, bc), lambda i, j: (0, j)),
                pl.BlockSpec((1, bc), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((rows, feats), jnp.int32),
        interpret=interpret,
    )(ids, row(op_codes), row(param0), row(param1))
