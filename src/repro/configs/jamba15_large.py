"""jamba-1.5-large — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2
[arXiv:2403.19887]."""
import dataclasses
from repro.models.common import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=24576),
    ssm=SSMConfig(d_state=16, head_dim=64, expand=2, conv_width=4, chunk=256),
    block_period=8,
    attn_index=4,
    moe_period=2,
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="jamba-smoke",
    num_layers=8,          # one block
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=256),
    ssm=SSMConfig(d_state=16, head_dim=16, expand=2, conv_width=4, chunk=32),
    remat=False,
)
