"""Ambient sharding context: lets pure model code apply logical-axis
sharding constraints without threading (mesh, rules) through every call.

``steps.py`` activates the context when building a step; under no context
(smoke tests on one device) ``constrain`` is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import AxisRules, logical_to_spec

_CTX: contextvars.ContextVar[Optional[Tuple[Mesh, AxisRules]]] = contextvars.ContextVar(
    "sharding_ctx", default=None
)


@contextlib.contextmanager
def sharding_context(mesh: Mesh, rules: AxisRules):
    token = _CTX.set((mesh, rules))
    try:
        yield
    finally:
        _CTX.reset(token)


def current_context() -> Optional[Tuple[Mesh, AxisRules]]:
    return _CTX.get()


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """Apply a sharding constraint derived from logical axis names (no-op
    outside a sharding context or on rank mismatch)."""
    ctx = _CTX.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    if len(logical_axes) != x.ndim:
        return x
    spec = logical_to_spec(logical_axes, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
