"""Multi-tenant capacity control for the shared stripe cache (ISSUE 3).

The §7.2 cache-tier argument only survives production if one misbehaving
job cannot wash the shared tier: a single antagonist scanning cold
partitions would otherwise evict every other job's working set (the
classic cache-pollution failure InTune, arXiv 2308.08500, attacks with
per-job resource allocation).  ``TenantPolicy`` gives each session/job a
configurable *guaranteed* fraction of each tier's capacity:

  * eviction prefers victims owned by tenants **over** their guarantee
    (in LRU order), so a tenant whose resident bytes fit its share is
    never evicted by someone else's traffic;
  * admission stays unconditional — **borrow-when-idle** semantics: a
    lone job fills the whole tier, and only loses its borrowed bytes
    (never its guaranteed ones) when other tenants need the space back.

Per-tenant ``TierStats`` charge every hit, byte, admission, and eviction
to the owning job, so capacity abuse is attributable and per-job hit
rates are directly reportable (``benchmarks/bench_tenancy.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass
class TenantShare:
    """Guaranteed capacity fractions of the DRAM and flash tiers."""

    dram_frac: float = 0.0
    flash_frac: float = 0.0

    def frac(self, tier: str) -> float:
        return self.dram_frac if tier == "dram" else self.flash_frac


class TenantPolicy:
    """Per-tenant guaranteed capacity shares with borrow-when-idle.

    A tenant with no registered share has a guarantee of 0 bytes: it may
    still use the whole tier while idle capacity exists, but its entries
    are always the first eviction victims.  With no shares registered at
    all, eviction degenerates to plain LRU (every entry is over its
    0-byte guarantee) — the pre-tenancy behavior.
    """

    def __init__(self, shares: Optional[Dict[str, TenantShare]] = None):
        self.shares: Dict[str, TenantShare] = {}
        for tenant, share in (shares or {}).items():
            # route through set_share so the sum<=1.0 validation cannot be
            # bypassed by constructing the policy with an over-committed dict
            self.set_share(tenant, share.dram_frac, share.flash_frac)

    def set_share(
        self, tenant: str, dram_frac: float = 0.0, flash_frac: float = 0.0
    ) -> TenantShare:
        for name, frac in (("dram", dram_frac), ("flash", flash_frac)):
            total = frac + sum(
                s.frac(name) for t, s in self.shares.items() if t != tenant
            )
            if total > 1.0 + 1e-9:
                raise ValueError(
                    f"{name} shares would sum to {total:.3f} > 1.0 "
                    f"adding tenant {tenant!r}"
                )
        share = TenantShare(dram_frac, flash_frac)
        self.shares[tenant] = share
        return share

    def clear_share(self, tenant: str) -> None:
        """Release a tenant's reservation (its job ended): the guarantee
        lapses, so its resident bytes become ordinary borrowable LRU
        entries and its fraction is free for future tenants."""
        self.shares.pop(tenant, None)

    def frac(self, tenant: Optional[str], tier: str) -> float:
        share = self.shares.get(tenant)
        return share.frac(tier) if share is not None else 0.0

    def guaranteed_bytes(
        self, tenant: Optional[str], tier: str, capacity_bytes: int
    ) -> int:
        return int(self.frac(tenant, tier) * capacity_bytes)
