"""DPP Clients: trainer-side data plane (§3.2.1).

One Client per training node.  Exposes ``get_batch()`` (the hook the
training runtime calls); requests are routed to Workers with partitioned
round-robin so the number of connections per Client and per Worker stays
capped, and data-stall time (waiting on an empty buffer) is accounted —
the trainer-side metric behind Table 7.
"""
from __future__ import annotations

import dataclasses
import time
import zlib
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.dpp.master import SessionState
from repro.obs import NULL_TRACER, counter


@dataclasses.dataclass
class ClientMetrics:
    batches: int = counter()
    rx_bytes: int = counter()
    stall_s: float = counter(0.0)
    stalls: int = counter()
    wait_calls: int = counter()


class SessionFailed(RuntimeError):
    """The session reached a terminal ``FAILED`` state: every split was
    quarantined, so no batch will ever arrive.  Carries the Master's
    per-split failure reports (exception chains included) so the trainer
    logs the *cause* — a poisoned partition, a dead fleet — instead of a
    generic timeout."""

    def __init__(self, state: str, failures: Sequence) -> None:
        self.state = state
        self.failures = list(failures)     # List[SplitFailure]
        head = self.failures[0] if self.failures else None
        detail = (
            f"; first: split {head.split_id} (partition {head.partition}, "
            f"rows [{head.row_start}, {head.row_end})) after "
            f"{head.dispatches} dispatches — {head.last_error.strip().splitlines()[-1]}"
            if head else ""
        )
        super().__init__(
            f"DPP session {state}: {len(self.failures)} split(s) "
            f"quarantined{detail}"
        )


class DPPClient:
    def __init__(
        self,
        client_id: str,
        workers: Sequence,                 # List[DPPWorker]
        fanout: int = 4,                   # partitioned round-robin cap
        prefetcher=None,                   # optional PrefetchPlanner to poke
        master=None,                       # optional DPPMaster for state checks
        tenant: Optional[str] = None,      # owning session (span label)
        tracer=NULL_TRACER,                # span Tracer (obs layer)
    ):
        self.client_id = client_id
        self._all_workers = list(workers)
        self.fanout = fanout
        self.prefetcher = prefetcher
        self.master = master
        self.tenant = tenant
        self.tracer = tracer
        self.metrics = ClientMetrics()
        self._rr = 0
        # stable digest, NOT hash(): str hashing is randomized per process
        # by PYTHONHASHSEED, which would scramble the client->worker
        # partitioning across runs/restarts of the same trainer
        self._partition_offset = (
            zlib.crc32(client_id.encode()) % max(len(workers), 1)
        )

    def rebind(self, workers: Sequence) -> None:
        """Auto-scaling / worker restarts change the worker set."""
        self._all_workers = list(workers)

    def _my_workers(self) -> List:
        live = [w for w in self._all_workers if w.alive or w.buffered > 0]
        if not live:
            return []
        k = min(self.fanout, len(live))
        start = self._partition_offset % len(live)
        return [live[(start + i) % len(live)] for i in range(k)]

    def _note_stall(self) -> None:
        if self.prefetcher is not None:
            # starving trainer: accelerate cache warming immediately
            self.prefetcher.poke()

    def _check_failed(self) -> None:
        """A terminally-FAILED session will never produce another batch:
        raise the structured error now rather than burning the timeout.
        (DEGRADED sessions keep serving — their healthy splits drain.)
        Only called on the stall path, so the Master's lock is not taken
        on every hot-path sweep."""
        if self.master is None:
            return
        if self.master.state == SessionState.FAILED and not any(
            w.buffered for w in self._all_workers
        ):
            raise SessionFailed(
                SessionState.FAILED, self.master.failure_report()
            )

    def get_batch(
        self, timeout: float = 10.0
    ) -> Optional[Dict[str, np.ndarray]]:
        """Round-robin poll over this client's worker partition."""
        t0 = time.perf_counter()
        deadline = t0 + timeout
        stalled = False
        self.metrics.wait_calls += 1
        while time.perf_counter() < deadline:
            mine = self._my_workers()
            if not mine:
                time.sleep(0.005)
                stalled = True
                self._check_failed()
                self._note_stall()
                continue
            for i in range(len(mine)):
                w = mine[(self._rr + i) % len(mine)]
                batch = w.get_batch(timeout=0.0) if w.buffered else None
                if batch is None and w.alive:
                    batch = w.get_batch(timeout=0.002)
                if batch is not None:
                    self._rr = (self._rr + i + 1) % max(len(mine), 1)
                    self.metrics.batches += 1
                    self.metrics.rx_bytes += sum(a.nbytes for a in batch.values())
                    # data-stall time (Table 7) accrues ONLY when the
                    # trainer actually waited; a batch served on the first
                    # sweep is a zero-stall call, not stall time
                    if stalled:
                        self.metrics.stalls += 1
                        t_now = time.perf_counter()
                        self.metrics.stall_s += t_now - t0
                        if self.tracer.enabled:
                            self.tracer.record(
                                "client.stall", t0, t_now,
                                tenant=self.tenant or "",
                                client=self.client_id,
                            )
                    return batch
            stalled = True
            self._check_failed()
            self._note_stall()
        t_now = time.perf_counter()
        self.metrics.stall_s += t_now - t0
        self.metrics.stalls += 1
        if self.tracer.enabled:
            self.tracer.record(
                "client.stall", t0, t_now,
                tenant=self.tenant or "", client=self.client_id,
            )
        self._check_failed()
        return None
