import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_pytree, save_pytree


def _state():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "e": jnp.ones((4, 2), jnp.bfloat16)},
        "opt": {"mu": {"w": jnp.zeros((3, 4))}, "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    s = _state()
    save_pytree(s, str(tmp_path / "ck"))
    out = load_pytree(s, str(tmp_path / "ck"))
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]), np.asarray(s["params"]["w"]))
    assert out["params"]["e"].dtype == np.dtype("bfloat16") or str(out["params"]["e"].dtype) == "bfloat16"
    assert int(out["opt"]["step"]) == 7


def test_manager_latest_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    s = _state()
    for step in (10, 20, 30):
        mgr.save(step, s)
    assert mgr.all_steps() == [20, 30]
    assert mgr.latest_step() == 30
    step, out = mgr.restore(s)
    assert step == 30


def test_incomplete_checkpoint_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    s = _state()
    mgr.save(10, s)
    # simulate crash mid-save: dir without manifest
    os.makedirs(tmp_path / "step_00000020")
    assert mgr.latest_step() == 10
    step, _ = mgr.restore(s)
    assert step == 10
