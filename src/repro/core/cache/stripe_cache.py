"""Two-tier shared stripe cache: DRAM + simulated flash (the "DSI cache
tier" of §7.2).

Concurrent training jobs re-read the same popular partitions (§5.2/§6);
serving that re-read traffic from HDD forces the ~8x
throughput-to-storage overprovisioning of §7.2.  This cache sits between
``TectonicFS`` and the DPP fleet and turns cross-job stripe overlap into
DRAM/flash hits:

  * **DRAM tier** — small, recency-managed (LRU).  Every miss is admitted
    here; a byte served from DRAM costs (nearly) nothing.
  * **Flash tier** — large victim cache built on ``MediaSpec``/``IOStats``
    from ``tectonic.py``.  Admission is *popularity-aware*: a DRAM
    eviction victim is written to flash only once its content has been
    read at least ``flash_admit_reads`` times (tracked with a
    ``PopularityTracker``), so one-touch scan traffic cannot wash the
    flash tier (the classic cache-pollution failure for training scans).

The tier is **multi-tenant** (ISSUE 3): every lookup/admission carries the
requesting job's tenant id.  A ``TenantPolicy`` gives each job a
guaranteed capacity share per tier — eviction prefers victims owned by
tenants over their guarantee, admission stays unconditional
(borrow-when-idle) — and per-tenant ``TierStats`` charge hits, bytes,
admissions, and evictions to the owning job.

Correctness under churn: entries carry an optional TTL, and partition
rewrites (``TectonicFS.rewrite``/``append``) invalidate the path —
dropping its path-addressed entries and bumping the ``DedupIndex``
generation so keys resolved before the rewrite can never be re-served
after it.

Keys come from ``DedupIndex.resolve`` and are content-addressed where
possible, so byte-identical stripes across partitions/tables occupy one
entry (RecD-style dedup).  Per-tier hit/eviction/byte counters plus the
flash ``IOStats`` make the §7.2 IOPS/W comparison directly computable via
``iops_per_watt``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from typing import Callable, Dict, Optional, Tuple

from repro.core.cache.dedup import CacheKey, DedupIndex
from repro.core.cache.tenancy import TenantPolicy
from repro.core.popularity import PopularityTracker
from repro.core.tectonic import IOStats, MediaSpec
from repro.obs import counter, gauge

# Cache-tier device models.  DRAM is effectively seek-free; FLASH is a
# single NVMe cache device (drive-level power, unlike the SSD *node* spec
# in tectonic.py), keeping the §7.2 IOPS/W ordering HDD < flash < DRAM.
DRAM_TIER = MediaSpec(name="dram", seek_ms=0.001, transfer_MBps=20_000.0,
                      capacity_TB=0.000256, power_W=5.0)
FLASH_TIER = MediaSpec(name="flash", seek_ms=0.02, transfer_MBps=3_500.0,
                       capacity_TB=1.92, power_W=25.0)

# Tenant id used for accounting when a caller does not identify itself,
# so per-tenant byte sums always equal the tier totals.
ANON_TENANT = "_anon"


def iops_per_watt(num_ios: int, time_s: float, power_W: float) -> float:
    """Served IOPS per watt for a tier/fleet that spent ``time_s`` of
    device time serving ``num_ios`` I/Os at ``power_W`` draw."""
    if time_s <= 0 or power_W <= 0:
        return 0.0
    return (num_ios / time_s) / power_W


@dataclasses.dataclass
class TierStats:
    name: str                      # identity label, not a metric: never merged
    hits: int = counter()
    bytes_served: int = counter()
    admitted: int = counter()
    bytes_stored: int = gauge()    # current occupancy: evictions shrink it
    evictions: int = counter()
    expired: int = counter()       # TTL expiries (counted apart from evictions)
    rejected: int = counter()      # flash admissions refused (unpopular)
    io: IOStats = counter(factory=IOStats)


@dataclasses.dataclass
class TenantStats:
    """Per-job view of the shared tier: reads charged to the reading
    tenant, storage/evictions charged to the owning (admitting) tenant."""

    tenant: str                    # identity label, not a metric: never merged
    dram: TierStats = counter(factory=lambda: TierStats("dram"))
    flash: TierStats = counter(factory=lambda: TierStats("flash"))
    misses: int = counter()

    @property
    def hits(self) -> int:
        return self.dram.hits + self.flash.hits

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    @property
    def bytes_stored(self) -> int:
        return self.dram.bytes_stored + self.flash.bytes_stored


@dataclasses.dataclass
class CacheLookup:
    payload: bytes
    tier: str                      # "dram" | "flash"


@dataclasses.dataclass
class _Entry:
    payload: bytes
    tenant: str                    # owning (admitting) tenant
    expires: float                 # absolute clock() deadline; inf = no TTL


class StripeCache:
    """Shared, thread-safe, two-tier, multi-tenant extent cache."""

    def __init__(
        self,
        dram_capacity_bytes: int = 64 * 1024 * 1024,
        flash_capacity_bytes: int = 512 * 1024 * 1024,
        dram_media: MediaSpec = DRAM_TIER,
        flash_media: MediaSpec = FLASH_TIER,
        flash_admit_reads: int = 2,
        dedup: Optional[DedupIndex] = None,
        tenancy: Optional[TenantPolicy] = None,
        ttl_s: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.dedup = dedup or DedupIndex()
        self.dram_capacity_bytes = dram_capacity_bytes
        self.flash_capacity_bytes = flash_capacity_bytes
        self.dram_media = dram_media
        self.flash_media = flash_media
        self.flash_admit_reads = flash_admit_reads
        self.tenancy = tenancy or TenantPolicy()
        self.ttl_s = ttl_s
        self._clock = clock
        self.popularity = PopularityTracker()
        self._lock = threading.Lock()
        self._dram: "OrderedDict[CacheKey, _Entry]" = OrderedDict()
        self._flash: "OrderedDict[CacheKey, _Entry]" = OrderedDict()
        # (kind, ident) -> stored keys of that stripe/path, for sub-range
        # serving: a narrower projection of an already-cached range hits
        self._groups: Dict[Tuple, set] = {}
        # single-flight: keys one reader is currently filling; concurrent
        # readers of the same stripe wait for the fill instead of issuing
        # a duplicate storage I/O (request coalescing)
        self._inflight: Dict[CacheKey, threading.Event] = {}
        self.dram = TierStats("dram")
        self.flash = TierStats("flash")
        self.tenants: Dict[str, TenantStats] = {}
        self.misses = 0

    # -- key resolution ------------------------------------------------------

    def resolve(self, path: str, offset: int, length: int) -> CacheKey:
        return self.dedup.resolve(path, offset, length)

    def invalidate_path(self, path: str) -> None:
        """The file at ``path`` was rewritten: drop its content mapping,
        bump the path generation (so pre-rewrite keys cannot be re-served),
        and purge any path-addressed entries (content entries stay valid —
        they are addressed by the bytes themselves)."""
        with self._lock:
            self.dedup.invalidate(path)
            for store, stats, tier in (
                (self._dram, self.dram, "dram"), (self._flash, self.flash, "flash")
            ):
                stale = [k for k in store if k[0] == "p" and k[1][0] == path]
                for k in stale:
                    e = store.pop(k)
                    self._charge_removal_locked(stats, tier, e, expired=False)
                    self._note_locked(k)

    # -- per-tenant accounting ----------------------------------------------

    def _tenant_locked(self, tenant: Optional[str]) -> TenantStats:
        name = tenant if tenant is not None else ANON_TENANT
        ts = self.tenants.get(name)
        if ts is None:
            ts = self.tenants[name] = TenantStats(name)
        return ts

    def _tenant_tier_locked(self, tenant: Optional[str], tier: str) -> TierStats:
        return getattr(self._tenant_locked(tenant), tier)

    def _charge_removal_locked(
        self, stats: TierStats, tier: str, e: _Entry, expired: bool
    ) -> None:
        owner = self._tenant_tier_locked(e.tenant, tier)
        for s in (stats, owner):
            s.bytes_stored -= len(e.payload)
            if expired:
                s.expired += 1
            else:
                s.evictions += 1

    # -- read path -----------------------------------------------------------

    def _record_read_locked(self, key: CacheKey, nbytes: int) -> None:
        # popularity is tracked per content identity: one "job read" of
        # nbytes against the key's stable integer id
        self.popularity.record_job({hash(key): float(nbytes)})

    def _expired(self, e: _Entry) -> bool:
        return e.expires <= self._clock()

    def _purge_expired_locked(self, group: Tuple) -> None:
        """Reclaim expired entries of one stripe/path group (TTL sweep on
        touch — there is no background reaper thread)."""
        for k in list(self._groups.get(group, ())):
            for store, stats, tier in (
                (self._dram, self.dram, "dram"), (self._flash, self.flash, "flash")
            ):
                e = store.get(k)
                if e is not None and self._expired(e):
                    store.pop(k)
                    self._charge_removal_locked(stats, tier, e, expired=True)
            self._note_locked(k)

    def _containing_key_locked(self, key: CacheKey) -> Optional[CacheKey]:
        """A stored key of the same stripe/path whose range covers ``key``'s
        (the key itself included); DRAM copies preferred.  Expired entries
        never serve."""
        off, ln = key[2], key[3]
        best = None
        for k in self._groups.get(key[:2], ()):
            if k[2] <= off and off + ln <= k[2] + k[3]:
                e = self._dram.get(k)
                if e is not None and not self._expired(e):
                    return k
                e = self._flash.get(k)
                if e is not None and not self._expired(e):
                    best = k
        return best

    def _note_locked(self, key: CacheKey) -> None:
        """Sync ``key``'s group-index membership with the tier stores."""
        g = key[:2]
        if key in self._dram or key in self._flash:
            self._groups.setdefault(g, set()).add(key)
        else:
            s = self._groups.get(g)
            if s is not None:
                s.discard(key)
                if not s:
                    del self._groups[g]

    def _lookup_locked(
        self, key: CacheKey, tenant: Optional[str]
    ) -> Optional[CacheLookup]:
        if self.ttl_s is not None:
            self._purge_expired_locked(key[:2])
        k = self._containing_key_locked(key)
        if k is None:
            return None
        entry = self._dram.get(k)
        if entry is not None:
            store, stats, media, tier = (
                self._dram, self.dram, self.dram_media, "dram"
            )
        else:
            entry = self._flash[k]
            store, stats, media, tier = (
                self._flash, self.flash, self.flash_media, "flash"
            )
        stored = entry.payload
        payload = (
            stored if k == key
            else stored[key[2] - k[2]: key[2] - k[2] + key[3]]
        )
        store.move_to_end(k)
        self._record_read_locked(key, len(payload))
        for s in (stats, self._tenant_tier_locked(tenant, tier)):
            s.hits += 1
            s.bytes_served += len(payload)
        stats.io.record(len(payload), media)
        if tier == "flash":
            # promote the whole entry so the next read is a DRAM hit; the
            # admitting tenant keeps ownership of the promoted copy
            self._admit_dram_locked(k, stored, entry.tenant)
        return CacheLookup(payload, tier)

    def _miss_locked(self, key: CacheKey, tenant: Optional[str]) -> None:
        self.misses += 1
        self._tenant_locked(tenant).misses += 1
        self._record_read_locked(key, 0)   # a miss still counts one read

    def get(
        self, key: CacheKey, tenant: Optional[str] = None
    ) -> Optional[CacheLookup]:
        with self._lock:
            hit = self._lookup_locked(key, tenant)
            if hit is None:
                self._miss_locked(key, tenant)
            return hit

    def get_or_claim(
        self, key: CacheKey, timeout_s: float = 10.0, tenant: Optional[str] = None
    ) -> Optional[CacheLookup]:
        """``get`` with single-flight fills: on a cold key the first caller
        claims the fill (returns ``None``; it MUST ``admit`` or ``abort``
        the key), and concurrent callers block until the fill lands, then
        hit — one storage I/O per stripe no matter how many overlapping
        sessions miss it simultaneously."""
        while True:
            with self._lock:
                hit = self._lookup_locked(key, tenant)
                if hit is not None:
                    return hit
                ev = self._inflight.get(key)
                if ev is None:
                    self._inflight[key] = threading.Event()
                    self._miss_locked(key, tenant)
                    return None
            ev.wait(timeout_s)   # filled or aborted; re-check either way

    def peek(self, key: CacheKey) -> bool:
        """Non-mutating membership probe (used by read planning and the
        prefetch planner); an expired entry does not count as present."""
        with self._lock:
            return self._containing_key_locked(key) is not None

    # -- admission / eviction ------------------------------------------------

    def admit(
        self, key: CacheKey, payload: bytes, tenant: Optional[str] = None
    ) -> None:
        """Admit a freshly-read extent (and release any single-flight claim
        on it).  Always enters DRAM; DRAM victims spill to flash only if
        their content has proven popular.  The entry is charged to
        ``tenant`` until evicted."""
        with self._lock:
            if self.ttl_s is not None:
                self._purge_expired_locked(key[:2])
            k = self._containing_key_locked(key)
            if k is None or k == key:
                self._admit_dram_locked(key, payload, tenant)
            # else: a wider stored range already serves this key
            self._release_locked(key)

    def abort(self, key: CacheKey) -> None:
        """Release a single-flight claim without filling it (the claiming
        read failed); blocked readers re-race for the claim."""
        with self._lock:
            self._release_locked(key)

    def _release_locked(self, key: CacheKey) -> None:
        ev = self._inflight.pop(key, None)
        if ev is not None:
            ev.set()

    def _expiry(self) -> float:
        return self._clock() + self.ttl_s if self.ttl_s is not None else float("inf")

    def _pick_victim_locked(
        self, store: "OrderedDict[CacheKey, _Entry]", tier: str, capacity: int
    ) -> CacheKey:
        """LRU among tenants over their guaranteed share; a tenant whose
        resident bytes fit its guarantee is never evicted by others (the
        borrow-when-idle flip side: only borrowed bytes are reclaimed)."""
        if not self.tenancy.shares:
            return next(iter(store))   # no guarantees: plain O(1) LRU
        # with shares set, protected entries cluster at the MRU end (they
        # are the ones being re-read), so this scan normally stops at the
        # first few LRU entries; worst case is bounded by the protected
        # tenants' resident entry count
        for k, e in store.items():   # OrderedDict iterates LRU-first
            owner = self._tenant_tier_locked(e.tenant, tier)
            if owner.bytes_stored > self.tenancy.guaranteed_bytes(
                e.tenant, tier, capacity
            ):
                return k
        return next(iter(store))    # everyone within guarantee: plain LRU

    def _admit_dram_locked(
        self, key: CacheKey, payload: bytes, tenant: Optional[str]
    ) -> None:
        if len(payload) > self.dram_capacity_bytes:
            self._admit_flash_locked(key, payload, tenant)
            return
        if key in self._dram:
            # freshly re-read bytes: refresh recency and the TTL deadline
            self._dram.move_to_end(key)
            self._dram[key].expires = self._expiry()
            return
        self._dram[key] = _Entry(
            payload, tenant if tenant is not None else ANON_TENANT, self._expiry()
        )
        for s in (self.dram, self._tenant_tier_locked(tenant, "dram")):
            s.admitted += 1
            s.bytes_stored += len(payload)
        self._note_locked(key)
        while self.dram.bytes_stored > self.dram_capacity_bytes and len(self._dram) > 1:
            vk = self._pick_victim_locked(
                self._dram, "dram", self.dram_capacity_bytes
            )
            ve = self._dram.pop(vk)
            self._charge_removal_locked(self.dram, "dram", ve, expired=False)
            self._admit_flash_locked(vk, ve.payload, ve.tenant)
            self._note_locked(vk)

    def _is_popular(self, key: CacheKey) -> bool:
        return self.popularity.read_count_by_feature.get(
            hash(key), 0
        ) >= self.flash_admit_reads

    def _admit_flash_locked(
        self, key: CacheKey, payload: bytes, tenant: Optional[str]
    ) -> None:
        if key in self._flash:
            self._flash.move_to_end(key)
            self._flash[key].expires = self._expiry()
            return
        if len(payload) > self.flash_capacity_bytes or not self._is_popular(key):
            self.flash.rejected += 1
            self._tenant_tier_locked(tenant, "flash").rejected += 1
            return
        self._flash[key] = _Entry(
            payload, tenant if tenant is not None else ANON_TENANT, self._expiry()
        )
        for s in (self.flash, self._tenant_tier_locked(tenant, "flash")):
            s.admitted += 1
            s.bytes_stored += len(payload)
        self._note_locked(key)
        # flash admission is a device write: charge it to the tier's I/O model
        self.flash.io.record(len(payload), self.flash_media)
        while self.flash.bytes_stored > self.flash_capacity_bytes and len(self._flash) > 1:
            vk = self._pick_victim_locked(
                self._flash, "flash", self.flash_capacity_bytes
            )
            ve = self._flash.pop(vk)
            self._charge_removal_locked(self.flash, "flash", ve, expired=False)
            self._note_locked(vk)

    # -- reporting -----------------------------------------------------------

    @property
    def hits(self) -> int:
        return self.dram.hits + self.flash.hits

    @property
    def bytes_served(self) -> int:
        return self.dram.bytes_served + self.flash.bytes_served

    @property
    def hit_rate(self) -> float:
        n = self.hits + self.misses
        return self.hits / n if n else 0.0

    def tier_iops_per_watt(self) -> Dict[str, float]:
        return {
            "dram": iops_per_watt(self.dram.io.num_ios, self.dram.io.total_time_s,
                                  self.dram_media.power_W),
            "flash": iops_per_watt(self.flash.io.num_ios, self.flash.io.total_time_s,
                                   self.flash_media.power_W),
        }

    def summary(self) -> Dict[str, float]:
        return {
            "hit_rate": self.hit_rate,
            "dram_hits": float(self.dram.hits),
            "flash_hits": float(self.flash.hits),
            "misses": float(self.misses),
            "bytes_served": float(self.bytes_served),
            "dram_bytes_stored": float(self.dram.bytes_stored),
            "flash_bytes_stored": float(self.flash.bytes_stored),
            "dedup_ratio": self.dedup.stats.dedup_ratio,
            "unique_stripes": float(self.dedup.unique_stripes),
            "expired": float(self.dram.expired + self.flash.expired),
            "tenants": float(len(self.tenants)),
        }

    def tenant_summary(self) -> Dict[str, Dict[str, float]]:
        """Per-job accounting: the attribution view of the shared tier."""
        return {
            name: {
                "hit_rate": ts.hit_rate,
                "hits": float(ts.hits),
                "misses": float(ts.misses),
                "dram_bytes_stored": float(ts.dram.bytes_stored),
                "flash_bytes_stored": float(ts.flash.bytes_stored),
                "dram_evictions": float(ts.dram.evictions),
                "flash_evictions": float(ts.flash.evictions),
                "bytes_served": float(ts.dram.bytes_served + ts.flash.bytes_served),
            }
            for name, ts in self.tenants.items()
        }
