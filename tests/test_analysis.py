"""Fixture tests for the ``repro.analysis`` invariant gate.

Each rule gets >= 2 positive fixtures (a violation the checker must flag)
and >= 1 negative fixture (compliant code it must stay silent on), built
as throwaway mini-repos under ``tmp_path``.  The CLI-level tests pin the
exit-code contract ``scripts/ci.sh`` relies on: 0 on a clean tree, 1 on
new findings, 2 on usage errors.
"""
from __future__ import annotations

import textwrap
from pathlib import Path

import pytest

from repro.analysis import all_rules, load_baseline, run_checks
from repro.analysis.__main__ import main as cli_main

REPO = Path(__file__).resolve().parents[1]


def _repo(tmp_path: Path, files: dict) -> Path:
    (tmp_path / "src" / "repro").mkdir(parents=True, exist_ok=True)
    for rel, text in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(text))
    return tmp_path


def _findings(repo: Path, rule: str):
    new, _known = run_checks(repo, rules=[rule])
    return new


# -- REPRO-L001: public mutation outside the lock ----------------------------

LOCKED_HEADER = """\
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.x = 0
            self._items = []
"""


def test_l001_assign_outside_lock(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def bump(self):
            self.x += 1
    """})
    f = _findings(repo, "REPRO-L001")
    assert len(f) == 1 and f[0].symbol == "C.bump" and "self.x" in f[0].message


def test_l001_mutator_call_outside_lock(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def push(self, v):
            self._items.append(v)
    """})
    f = _findings(repo, "REPRO-L001")
    assert len(f) == 1 and "_items" in f[0].message


def test_l001_negative_mutation_under_lock(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def bump(self):
            with self._lock:
                self.x += 1
                self._items.append(self.x)
    """})
    assert _findings(repo, "REPRO-L001") == []


def test_l001_negative_class_without_lock(tmp_path):
    # no declared lock -> the discipline doesn't apply
    repo = _repo(tmp_path, {"src/repro/m.py": """\
        class Plain:
            def __init__(self):
                self.x = 0
            def bump(self):
                self.x += 1
    """})
    assert _findings(repo, "REPRO-L001") == []


# -- REPRO-L002: _locked helper contract -------------------------------------


def test_l002_locked_helper_acquires_lock(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def _bump_locked(self):
            with self._lock:
                self.x += 1
    """})
    f = _findings(repo, "REPRO-L002")
    assert len(f) == 1 and "deadlock" in f[0].message


def test_l002_locked_helper_called_outside_lock(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def _bump_locked(self):
            self.x += 1

        def bump(self):
            self._bump_locked()
    """})
    f = _findings(repo, "REPRO-L002")
    assert len(f) == 1 and f[0].symbol == "C.bump"


def test_l002_negative_called_under_lock(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def _bump_locked(self):
            self.x += 1

        def bump(self):
            with self._lock:
                self._bump_locked()
    """})
    assert _findings(repo, "REPRO-L002") == []


# -- REPRO-L003: unlocked private helper without the suffix ------------------


def test_l003_private_helper_without_suffix(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def _drain(self):
            self._items.clear()

        def flush(self):
            with self._lock:
                self._drain()
    """})
    f = _findings(repo, "REPRO-L003")
    assert len(f) == 1 and f[0].symbol == "C._drain" \
        and "_locked" in f[0].message


def test_l003_uncalled_private_helper(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def _reset(self):
            self.x = 0
    """})
    assert len(_findings(repo, "REPRO-L003")) == 1


def test_l003_negative_suffixed_helper(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def _drain_locked(self):
            self._items.clear()
    """})
    assert _findings(repo, "REPRO-L003") == []


def test_l003_negative_init_only_callee(tmp_path):
    # helpers called only from __init__ touch pre-publication state
    repo = _repo(tmp_path, {"src/repro/m.py": """\
        import threading

        class C:
            def __init__(self):
                self._lock = threading.Lock()
                self.x = 0
                self._seed()

            def _seed(self):
                self.x = 42
    """})
    assert _findings(repo, "REPRO-L003") == []


# -- REPRO-C001: clock injection ---------------------------------------------


def test_c001_time_time_in_dpp(tmp_path):
    repo = _repo(tmp_path, {"src/repro/core/dpp/m.py": """\
        import time

        def deadline(s):
            return time.time() + s
    """})
    f = _findings(repo, "REPRO-C001")
    assert len(f) == 1 and "time.time" in f[0].message


def test_c001_time_monotonic_in_cache(tmp_path):
    repo = _repo(tmp_path, {"src/repro/core/cache/m.py": """\
        import time

        class T:
            def now(self):
                return time.monotonic()
    """})
    f = _findings(repo, "REPRO-C001")
    assert len(f) == 1 and f[0].symbol == "T.now"


def test_c001_negative_injection_default_and_scope(tmp_path):
    repo = _repo(tmp_path, {
        # references (not calls) are the injection idiom; perf_counter ok
        "src/repro/core/dpp/m.py": """\
            import time

            class M:
                def __init__(self, clock=time.time):
                    self._clock = clock

                def now(self):
                    t0 = time.perf_counter()
                    return self._clock(), time.perf_counter() - t0
        """,
        # out of scope: direct calls elsewhere are allowed
        "src/repro/core/other.py": """\
            import time

            def wall():
                return time.time()
        """,
    })
    assert _findings(repo, "REPRO-C001") == []


# -- REPRO-K001/K002: kernel parity ------------------------------------------


def _kernel_repo(tmp_path, fused: str, ref: str, suite: str) -> Path:
    return _repo(tmp_path, {
        "src/repro/kernels/fused_transform.py": fused,
        "src/repro/kernels/ref.py": ref,
        "tests/test_engine.py": suite,
    })


def test_k001_op_missing_from_ref(tmp_path):
    repo = _kernel_repo(
        tmp_path, "OP_FOO = 0\nOP_BAZ = 1\n", "OP_FOO = 0\n", "OP_FOO OP_BAZ",
    )
    f = _findings(repo, "REPRO-K001")
    assert len(f) == 1 and "OP_BAZ" in f[0].message \
        and "no parity oracle" in f[0].message


def test_k001_value_mismatch_and_dead_oracle(tmp_path):
    repo = _kernel_repo(
        tmp_path, "OP_FOO = 0\n", "OP_FOO = 3\nOP_QUX = 1\n", "OP_FOO",
    )
    msgs = sorted(x.message for x in _findings(repo, "REPRO-K001"))
    assert len(msgs) == 2
    assert "diverge" in msgs[0] and "OP_QUX" in msgs[1]


def test_k001_negative_matching_tables(tmp_path):
    repo = _kernel_repo(
        tmp_path, "OP_FOO = 0\nOP_BAR = 1\n", "OP_FOO = 0\nOP_BAR = 1\n", "x",
    )
    assert _findings(repo, "REPRO-K001") == []


def test_k002_op_not_exercised(tmp_path):
    repo = _kernel_repo(
        tmp_path, "OP_FOO = 0\nOP_BAR = 1\n", "OP_FOO = 0\nOP_BAR = 1\n",
        "def test_foo():\n    use('OP_FOO')\n",
    )
    f = _findings(repo, "REPRO-K002")
    assert len(f) == 1 and "OP_BAR" in f[0].message


def test_k002_suite_missing(tmp_path):
    repo = _repo(tmp_path, {
        "src/repro/kernels/fused_transform.py": "OP_FOO = 0\n",
        "src/repro/kernels/ref.py": "OP_FOO = 0\n",
    })
    f = _findings(repo, "REPRO-K002")
    assert len(f) == 1 and "suite missing" in f[0].message


def test_k002_negative_transform_name_counts(tmp_path):
    # OP_SIGRID_HASH is exercised via a "SigridHash" spec string
    repo = _kernel_repo(
        tmp_path, "OP_SIGRID_HASH = 1\nOP_CLAMP_F = 5\n",
        "OP_SIGRID_HASH = 1\nOP_CLAMP_F = 5\n",
        'TransformSpec("SigridHash", ...); TransformSpec("Clamp", ...)',
    )
    assert _findings(repo, "REPRO-K002") == []


def test_k002_dispatch_kernel_without_differential_test(tmp_path):
    # a public kernel in ops.py absent from tests/test_kernels.py is the
    # untested-op hole one layer up (ISSUE 9)
    repo = _repo(tmp_path, {
        "src/repro/kernels/fused_transform.py": "OP_FOO = 0\n",
        "src/repro/kernels/ref.py": "OP_FOO = 0\n",
        "tests/test_engine.py": "OP_FOO",
        "src/repro/kernels/ops.py": """\
            def embedding_bag(t, i, m):
                return t

            def _private_helper():
                pass
        """,
        "tests/test_kernels.py": "def test_nothing():\n    pass\n",
    })
    f = _findings(repo, "REPRO-K002")
    assert len(f) == 1 and "embedding_bag" in f[0].message \
        and "test_kernels" in f[0].message


def test_k002_dispatch_suite_missing_entirely(tmp_path):
    repo = _repo(tmp_path, {
        "src/repro/kernels/fused_transform.py": "OP_FOO = 0\n",
        "src/repro/kernels/ref.py": "OP_FOO = 0\n",
        "tests/test_engine.py": "OP_FOO",
        "src/repro/kernels/ops.py": "def flash_attention(q, k, v):\n"
                                    "    return q\n",
    })
    f = _findings(repo, "REPRO-K002")
    assert len(f) == 1 and "differential suite missing" in f[0].message


def test_k002_dispatch_negative_covered_and_ops_absent(tmp_path):
    # every public kernel named by the suite -> clean; and a repo with no
    # ops.py at all (the older fixtures) must stay clean too
    repo = _repo(tmp_path, {
        "src/repro/kernels/fused_transform.py": "OP_FOO = 0\n",
        "src/repro/kernels/ref.py": "OP_FOO = 0\n",
        "tests/test_engine.py": "OP_FOO",
        "src/repro/kernels/ops.py": "def embedding_bag(t, i, m):\n"
                                    "    return t\n",
        "tests/test_kernels.py": "def test_bag():\n"
                                 "    embedding_bag(1, 2, 3)\n",
    })
    assert _findings(repo, "REPRO-K002") == []
    bare = _kernel_repo(tmp_path / "bare", "OP_FOO = 0\n", "OP_FOO = 0\n",
                        "OP_FOO")
    assert _findings(bare, "REPRO-K002") == []


def test_k002_decode_kernel_without_differential_test(tmp_path):
    # a public kernel in kernels/decode.py absent from tests/test_decode.py
    # is an extract-path op outside the parity net (ISSUE 10)
    repo = _repo(tmp_path, {
        "src/repro/kernels/fused_transform.py": "OP_FOO = 0\n",
        "src/repro/kernels/ref.py": "OP_FOO = 0\n",
        "tests/test_engine.py": "OP_FOO",
        "src/repro/kernels/decode.py": """\
            def xor_decrypt_kernel(w):
                return w

            def _pad(w):
                return w
        """,
        "tests/test_decode.py": "def test_nothing():\n    pass\n",
    })
    f = _findings(repo, "REPRO-K002")
    assert len(f) == 1 and "xor_decrypt_kernel" in f[0].message \
        and "test_decode" in f[0].message


def test_k002_decode_suite_missing_entirely(tmp_path):
    repo = _repo(tmp_path, {
        "src/repro/kernels/fused_transform.py": "OP_FOO = 0\n",
        "src/repro/kernels/ref.py": "OP_FOO = 0\n",
        "tests/test_engine.py": "OP_FOO",
        "src/repro/kernels/decode.py": "def dense_unpack_kernel(b, v):\n"
                                       "    return v\n",
    })
    f = _findings(repo, "REPRO-K002")
    assert len(f) == 1 and "decode differential suite missing" in f[0].message


def test_k002_decode_negative_covered_and_module_absent(tmp_path):
    repo = _repo(tmp_path, {
        "src/repro/kernels/fused_transform.py": "OP_FOO = 0\n",
        "src/repro/kernels/ref.py": "OP_FOO = 0\n",
        "tests/test_engine.py": "OP_FOO",
        "src/repro/kernels/decode.py": "def ragged_gather_kernel(s, i, h):\n"
                                       "    return s\n",
        "tests/test_decode.py": "def test_gather():\n"
                                "    ragged_gather_kernel(1, 2, 3)\n",
    })
    assert _findings(repo, "REPRO-K002") == []
    bare = _kernel_repo(tmp_path / "bare2", "OP_FOO = 0\n", "OP_FOO = 0\n",
                        "OP_FOO")
    assert _findings(bare, "REPRO-K002") == []


# -- REPRO-M001/M002: metrics contract ---------------------------------------

WORKER_METRICS = """\
    import dataclasses

    from repro.obs import counter, gauge

    @dataclasses.dataclass
    class WorkerMetrics:
        batches: int = counter()
        bytes_read: int = counter()
        bytes_stored: int = gauge()
"""


def _bench_findings(repo):
    return [f for f in _findings(repo, "REPRO-M001")
            if f.path.startswith("benchmarks/")]


def test_m001_unknown_field_on_getter_local(tmp_path):
    repo = _repo(tmp_path, {
        "src/repro/core/dpp/worker.py": WORKER_METRICS,
        "benchmarks/bench_x.py": """\
            def main(sess):
                m = sess.worker_metrics()
                return m.batches + m.bogus_field
        """,
    })
    f = _bench_findings(repo)
    assert len(f) == 1 and ".bogus_field" in f[0].message


def test_m001_unknown_field_on_metrics_chain(tmp_path):
    repo = _repo(tmp_path, {
        "src/repro/core/dpp/worker.py": WORKER_METRICS,
        "benchmarks/bench_x.py": """\
            def main(sess):
                return sess.prefetcher.metrics.nonexistent
        """,
    })
    f = _bench_findings(repo)
    assert len(f) == 1 and ".nonexistent" in f[0].message


def test_m001_negative_known_fields_and_reassignment(tmp_path):
    repo = _repo(tmp_path, {
        "src/repro/core/dpp/worker.py": WORKER_METRICS,
        "benchmarks/bench_x.py": """\
            def main(sess, table, p):
                m = sess.worker_metrics()
                total = m.batches + m.bytes_read
                m = table.partitions[p]        # tracking must drop here
                return total + m.footer.num_rows
        """,
    })
    assert _bench_findings(repo) == []


def test_m002_counter_decrements(tmp_path):
    repo = _repo(tmp_path, {
        "src/repro/core/dpp/worker.py": WORKER_METRICS,
        "src/repro/core/foo.py": """\
            def oops(m):
                m.batches -= 1
                m.bytes_read = m.bytes_read - 4
        """,
    })
    f = _findings(repo, "REPRO-M002")
    assert len(f) == 2
    assert {".batches" in x.message or ".bytes_read" in x.message for x in f} == {True}


def test_m002_negative_gauge_and_increment(tmp_path):
    repo = _repo(tmp_path, {
        "src/repro/core/dpp/worker.py": WORKER_METRICS,
        "src/repro/core/foo.py": """\
            def fine(m, n):
                m.batches += 1
                m.bytes_stored -= n      # gauge: eviction shrinks it
        """,
    })
    assert _findings(repo, "REPRO-M002") == []


def test_m001_drift_when_no_metric_class_discovered(tmp_path):
    repo = _repo(tmp_path, {
        "src/repro/core/foo.py": """\
            import dataclasses

            @dataclasses.dataclass
            class NotMetrics:
                batches: int = 0
        """,
    })
    f = _findings(repo, "REPRO-M001")
    assert len(f) == 1 and "no metric class discovered" in f[0].message


def test_m001_discovery_needs_no_hand_kept_list(tmp_path):
    # a metric class in a brand-new module is picked up automatically
    repo = _repo(tmp_path, {
        "src/repro/core/shiny/new_module.py": WORKER_METRICS,
        "benchmarks/bench_x.py": """\
            def main(sess):
                m = sess.worker_metrics()
                return m.batches + m.bytes_stored + m.bogus
        """,
    })
    f = _bench_findings(repo)
    assert len(f) == 1 and ".bogus" in f[0].message


# -- REPRO-S001: span hygiene ------------------------------------------------


def test_s001_span_assigned_to_variable(tmp_path):
    repo = _repo(tmp_path, {"src/repro/core/foo.py": """\
        class Thing:
            def work(self):
                h = self.tracer.span("storage.read")
                h.__enter__()
    """})
    f = _findings(repo, "REPRO-S001")
    assert len(f) == 1 and f[0].symbol == "Thing.work"


def test_s001_bare_span_call_expression(tmp_path):
    repo = _repo(tmp_path, {"src/repro/core/foo.py": """\
        def work(tracer):
            tracer.span("cache.fill", bytes=1)
    """})
    assert len(_findings(repo, "REPRO-S001")) == 1


def test_s001_with_span_and_atomic_apis_ok(tmp_path):
    repo = _repo(tmp_path, {"src/repro/core/foo.py": """\
        class Thing:
            def work(self, row):
                with self.tracer.span("storage.read") as sp:
                    sp.set(bytes=2)
                self.tracer.record("client.stall", 0.0, 1.0)
                self.tracer.instant("cache.hit")
                return row.span("not-a-tracer")   # unrelated .span method
    """})
    assert _findings(repo, "REPRO-S001") == []


def test_s001_scope_is_core_only(tmp_path):
    repo = _repo(tmp_path, {"src/repro/train/foo.py": """\
        def work(tracer):
            return tracer.span("train.step")
    """})
    assert _findings(repo, "REPRO-S001") == []


# -- REPRO-T001/T002: thread hygiene -----------------------------------------


def test_t001_unbound_thread_never_joined(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": """\
        import threading

        def fire(fn):
            threading.Thread(target=fn).start()
    """})
    f = _findings(repo, "REPRO-T001")
    assert len(f) == 1 and f[0].symbol == "fire"


def test_t001_bound_thread_never_joined(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": """\
        import threading

        class S:
            def start(self):
                self._t = threading.Thread(target=self.run)
                self._t.start()
    """})
    assert len(_findings(repo, "REPRO-T001")) == 1


def test_t001_negative_daemon_join_and_loop_join(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": """\
        import threading

        def a(fn):
            threading.Thread(target=fn, daemon=True).start()

        def b(fn):
            t = threading.Thread(target=fn)
            t.start()
            t.join()

        def c(fns):
            ts = [threading.Thread(target=f) for f in fns]
            for t in ts:
                t.start()
            for t in ts:
                t.join()
    """})
    assert _findings(repo, "REPRO-T001") == []


def test_t002_bare_except(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": """\
        def a():
            try:
                risky()
            except:
                pass

        def b():
            try:
                risky()
            except:
                return None
    """})
    assert len(_findings(repo, "REPRO-T002")) == 2


def test_t002_negative_typed_except(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": """\
        def a():
            try:
                risky()
            except Exception:
                pass
            except (KeyError, ValueError) as e:
                raise e
    """})
    assert _findings(repo, "REPRO-T002") == []


# -- suppression: inline noqa + baseline -------------------------------------


def test_noqa_on_finding_line(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def bump(self):
            self.x += 1  # repro: noqa(REPRO-L001)
    """})
    assert _findings(repo, "REPRO-L001") == []


def test_noqa_on_line_above_and_bare(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def bump(self):
            # repro: noqa
            self.x += 1
    """})
    assert _findings(repo, "REPRO-L001") == []


def test_noqa_for_other_rule_does_not_suppress(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def bump(self):
            self.x += 1  # repro: noqa(REPRO-T001)
    """})
    assert len(_findings(repo, "REPRO-L001")) == 1


def test_baseline_moves_finding_to_known(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def bump(self):
            self.x += 1
    """})
    new, known = run_checks(repo, rules=["REPRO-L001"])
    assert len(new) == 1 and known == []
    new2, known2 = run_checks(
        repo, rules=["REPRO-L001"], baseline=[new[0].key],
    )
    assert new2 == [] and len(known2) == 1
    # baseline keys are line-free: adding a blank line must not invalidate
    p = repo / "src/repro/m.py"
    p.write_text("\n" + p.read_text())
    new3, known3 = run_checks(
        repo, rules=["REPRO-L001"], baseline=[new[0].key],
    )
    assert new3 == [] and len(known3) == 1


# -- CLI contract -------------------------------------------------------------


def test_cli_clean_on_real_tree():
    """The acceptance bar: the gate exits 0 on the repo itself (with its
    checked-in baseline)."""
    assert cli_main(["--root", str(REPO), "-q"]) == 0


def test_cli_real_baseline_is_empty():
    assert load_baseline(REPO / "scripts" / "analysis_baseline.txt") == []


def test_cli_fails_on_violation(tmp_path, capsys):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def bump(self):
            self.x += 1
    """})
    rc = cli_main(["--root", str(repo), "--no-baseline",
                   "--rules", "REPRO-L001"])
    out = capsys.readouterr().out
    assert rc == 1
    assert "REPRO-L001" in out and "src/repro/m.py" in out and "FAIL" in out


def test_cli_unknown_rule_is_usage_error(tmp_path):
    repo = _repo(tmp_path, {"src/repro/m.py": "x = 1\n"})
    assert cli_main(["--root", str(repo), "--rules", "REPRO-Z999"]) == 2


def test_cli_write_baseline_roundtrip(tmp_path, capsys):
    repo = _repo(tmp_path, {"src/repro/m.py": LOCKED_HEADER + """\

        def bump(self):
            self.x += 1
    """})
    base = repo / "scripts" / "analysis_baseline.txt"
    args = ["--root", str(repo), "--rules", "REPRO-L001",
            "--baseline", str(base)]
    assert cli_main(args + ["--write-baseline"]) == 0
    assert len(load_baseline(base)) == 1
    assert cli_main(args) == 0          # baselined -> green
    capsys.readouterr()


def test_cli_list_rules(capsys):
    assert cli_main(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rid in all_rules():
        assert rid in out
    assert len(all_rules()) == 13


def test_rule_catalog_is_stable():
    assert sorted(all_rules()) == [
        "REPRO-C001",
        "REPRO-K001", "REPRO-K002",
        "REPRO-L001", "REPRO-L002", "REPRO-L003",
        "REPRO-M001", "REPRO-M002",
        "REPRO-R001", "REPRO-R002",
        "REPRO-S001",
        "REPRO-T001", "REPRO-T002",
    ]


# -- REPRO-R001: unlocked assignment on a race-instrumented class ------------

RACED_WORKER_HEADER = """\
    import threading

    class DPPWorker:
        def __init__(self):
            self._lock = threading.Lock()
            self.alive = True
"""


def test_r001_unlocked_assign_on_instrumented_class(tmp_path):
    repo = _repo(tmp_path, {"src/repro/core/dpp/worker.py":
                            RACED_WORKER_HEADER + """\

        def _run(self):
            self.alive = False
    """})
    f = _findings(repo, "REPRO-R001")
    assert len(f) == 1 and f[0].symbol == "DPPWorker._run"
    assert "_unshared" in f[0].message


def test_r001_lockless_instrumented_class_flagged(tmp_path):
    repo = _repo(tmp_path, {"src/repro/core/dpp/prefetch.py": """\
        class PrefetchPlanner:
            def __init__(self):
                self.depth = 4

            def set_depth(self, d):
                self.depth = d
    """})
    f = _findings(repo, "REPRO-R001")
    assert len(f) == 1 and f[0].symbol == "PrefetchPlanner.set_depth"


def test_r001_negative_unshared_declaration(tmp_path):
    repo = _repo(tmp_path, {"src/repro/core/dpp/prefetch.py": """\
        class PrefetchPlanner:
            _unshared = ("depth",)

            def __init__(self):
                self.depth = 4

            def set_depth(self, d):
                self.depth = d
    """})
    assert _findings(repo, "REPRO-R001") == []


def test_r001_negative_assign_under_lock_or_elsewhere(tmp_path):
    # locked assignment is fine; so is the same shape on a class that is
    # not in the instrumented set (plain module path)
    repo = _repo(tmp_path, {
        "src/repro/core/dpp/worker.py": RACED_WORKER_HEADER + """\

        def _run(self):
            with self._lock:
                self.alive = False
    """,
        "src/repro/other.py": """\
        class Uninstrumented:
            def __init__(self):
                self.alive = True

            def _run(self):
                self.alive = False
    """})
    assert _findings(repo, "REPRO-R001") == []


# -- REPRO-R002: double-checked locking --------------------------------------


def test_r002_unlocked_test_of_published_attr(tmp_path):
    repo = _repo(tmp_path, {"src/repro/fs.py": """\
        import threading

        class FS:
            def __init__(self):
                self._lock = threading.Lock()
                self.cache = None

            def attach(self, c):
                with self._lock:
                    self.cache = c

            def read(self):
                if self.cache is None:
                    return 0
                return 1
    """})
    f = _findings(repo, "REPRO-R002")
    assert len(f) == 1 and f[0].symbol == "FS.read"
    assert "self.cache" in f[0].message


def test_r002_chained_attr_test_flagged(tmp_path):
    repo = _repo(tmp_path, {"src/repro/fs.py": """\
        import threading

        class FS:
            def __init__(self):
                self._lock = threading.Lock()
                self.tracer = None

            def attach(self, t):
                with self._lock:
                    self.tracer = t

            def read(self):
                if self.tracer.enabled:
                    return 1
                return 0
    """})
    f = _findings(repo, "REPRO-R002")
    assert len(f) == 1 and "self.tracer" in f[0].message


def test_r002_negative_snapshot_into_local(tmp_path):
    repo = _repo(tmp_path, {"src/repro/fs.py": """\
        import threading

        class FS:
            def __init__(self):
                self._lock = threading.Lock()
                self.cache = None

            def attach(self, c):
                with self._lock:
                    self.cache = c

            def read(self):
                with self._lock:
                    cache = self.cache
                if cache is None:
                    return 0
                return 1
    """})
    assert _findings(repo, "REPRO-R002") == []
