import numpy as np
import pytest

from repro.core.tectonic import BLOCK_BYTES, HDD, SSD, IOStats, TectonicFS


def test_create_read_roundtrip():
    fs = TectonicFS(num_nodes=6)
    data = bytes(np.random.default_rng(0).integers(0, 256, 100_000, np.uint8))
    fs.create("a/b", data)
    assert fs.read_all("a/b") == data
    chunks = fs.read_extents("a/b", [(10, 100), (50_000, 5_000)])
    assert chunks[0] == data[10:110]
    assert chunks[1] == data[50_000:55_000]


def test_append_only_guard():
    fs = TectonicFS()
    fs.create("x", b"123")
    with pytest.raises(AssertionError):
        fs.create("x", b"456")
    fs.append("x", b"456")
    assert fs.read_all("x") == b"123456"


def test_io_cost_model_seek_dominates_small_ios():
    fs = TectonicFS(media=HDD)
    fs.create("f", b"0" * (4 * BLOCK_BYTES))
    fs.read_extents("f", [(i * 1000, 20_000) for i in range(50)])   # ~20KB I/Os
    small = fs.stats.effective_throughput_MBps
    fs.reset_stats()
    fs.read_extents("f", [(0, 8 * 1024 * 1024)])
    big = fs.stats.effective_throughput_MBps
    assert big > 3 * small            # HDD seek cliff (Table 12's 97% drop)


def test_ssd_iops_per_watt_ratio():
    # paper §7.2: SSD ~326% IOPS/W, ~9% capacity/W vs HDD
    hdd_iops_w = HDD.max_iops / HDD.power_W
    ssd_iops_w = SSD.max_iops / SSD.power_W
    assert 2.5 < (ssd_iops_w / hdd_iops_w) / 100 or ssd_iops_w / hdd_iops_w > 3
    cap_ratio = (SSD.capacity_TB / SSD.power_W) / (HDD.capacity_TB / HDD.power_W)
    assert cap_ratio < 0.15


def test_replication_and_usage():
    fs = TectonicFS(num_nodes=5)
    fs.create("f", b"z" * 1000)
    assert sum(n.used_bytes for n in fs.nodes) == 3 * 1000


def test_append_does_not_double_count_node_usage():
    # regression: append used to re-place the whole file without releasing
    # the old blocks, double-counting per-node used_bytes every time
    fs = TectonicFS(num_nodes=5)
    fs.create("f", b"a" * 1000)
    for _ in range(3):
        fs.append("f", b"b" * 500)
    assert fs.size("f") == 2500
    assert sum(n.used_bytes for n in fs.nodes) == 3 * 2500
    # multi-block files release every block's replicas too
    big = b"c" * (BLOCK_BYTES + 1000)
    fs.create("g", big)
    fs.append("g", b"d" * 100)
    expected = 3 * (2500 + len(big) + 100)
    assert sum(n.used_bytes for n in fs.nodes) == expected
