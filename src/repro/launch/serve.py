"""Serving driver: continuous batched decode with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
      --batch 4 --prompt-len 32 --decode-steps 16
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro import configs as cfglib
from repro.models import build_model


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = cfglib.get_smoke_config(args.arch) if args.smoke else cfglib.get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    b, s = args.batch, args.prompt_len
    prompt = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.frontend == "vision":
        prompt["image_embeds"] = jnp.asarray(
            rng.normal(0, 0.02, (b, cfg.num_patches, cfg.d_model)), cfg.compute_dtype
        )
    if cfg.frontend == "audio":
        prompt["frames"] = jnp.asarray(
            rng.normal(0, 0.02, (b, s, cfg.d_model)), cfg.compute_dtype
        )
        prompt["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, max(s // 8, 8))), jnp.int32
        )

    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode_step)

    t0 = time.perf_counter()
    logits, _ = prefill(params, prompt)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    # decode against a fresh fixed-capacity cache (the serving layout)
    cache = jax.tree.map(
        lambda sd: jnp.zeros(sd.shape, sd.dtype), model.abstract_cache(b, args.cache_len)
    )
    token = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    toks = [token]
    t0 = time.perf_counter()
    for i in range(args.decode_steps):
        batch = {"token": token, "pos": jnp.asarray(i, jnp.int32), "cache": cache}
        logits, cache = decode(params, batch)
        token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        toks.append(token)
    token.block_until_ready()
    t_decode = time.perf_counter() - t0

    seq = jnp.concatenate(toks, axis=1)
    print(f"arch={cfg.name} prefill_s={t_prefill:.3f} "
          f"decode_tok_per_s={b * args.decode_steps / t_decode:.1f}")
    print("sampled tokens[0]:", np.asarray(seq[0])[:16].tolist())
    ok = bool(np.isfinite(np.asarray(logits, np.float32)).all())
    print("finite logits:", ok)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
