"""Lockset race detector (`repro.analysis.racedep`) regression suite.

Mirrors ``test_lockdep.py``: seeded-bug fixtures prove detection (an
unlocked cross-thread write, disjoint locksets), negative fixtures prove
the exemptions hold (common lock, ``_unshared`` allowlist, ``__init__``
publication, read-only sharing), a restore test proves instrumentation
is transparent after the context exits, and raced-marked integration
tests run real subsystems under the fixture.
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.analysis import lockdep as ld
from repro.analysis import racedep as rd
from repro.analysis.racedep import RaceError


class Racy:
    """No lock at all: cross-thread writes must be reported."""

    def __init__(self):
        self.x = 0


class Guarded:
    """Every access under one lock: never reported."""

    def __init__(self):
        self._lock = threading.Lock()
        self.n = 0

    def bump(self):
        with self._lock:
            self.n += 1

    def read(self):
        with self._lock:
            return self.n


class SplitBrain:
    """Two threads each hold *a* lock — just not the same one."""

    def __init__(self):
        self._a_lock = threading.Lock()
        self._b_lock = threading.Lock()
        self.v = 0

    def via_a(self):
        with self._a_lock:
            self.v += 1

    def via_b(self):
        with self._b_lock:
            self.v += 1


class Allowlisted:
    _unshared = ("flag",)

    def __init__(self):
        self.flag = False


def _in_thread(fn):
    t = threading.Thread(target=fn)
    t.start()
    t.join()


def _run(cls_list, body):
    with ld.patched(name_filter=lambda s: True) as graph:
        with rd.instrument(graph, classes=cls_list) as det:
            body()
    return det


# -- seeded bugs --------------------------------------------------------------


def test_seeded_unlocked_write_detected():
    def body():
        obj = Racy()
        _in_thread(lambda: setattr(obj, "x", 1))
        obj.x = 2

    det = _run([Racy], body)
    races = det.races()
    assert len(races) == 1
    assert (races[0].cls, races[0].attr) == ("Racy", "x")
    with pytest.raises(RaceError) as ei:
        det.assert_no_races()
    msg = str(ei.value)
    assert "Racy.x" in msg and "_unshared" in msg and "REPRO-R001" in msg
    # both access sites and the accessing threads are in the report
    assert "test_racedep.py" in msg and "MainThread" in msg


def test_seeded_disjoint_locksets_detected():
    def body():
        obj = SplitBrain()
        _in_thread(obj.via_a)
        obj.via_b()
        # lockset refinement starts at the sharing access (Eraser):
        # a further access under the *other* lock empties the candidate
        _in_thread(obj.via_a)

    det = _run([SplitBrain], body)
    races = det.races()
    assert len(races) == 1 and races[0].attr == "v"
    # the report names the locks that were held (but did not intersect)
    assert "lock" in det.report()


def test_unlocked_read_of_written_attr_detected():
    # write under a lock, read with none: lockset intersection still empty
    class HalfGuarded:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0

        def bump(self):
            with self._lock:
                self.n += 1

    def body():
        obj = HalfGuarded()
        _in_thread(obj.bump)
        _ = obj.n           # naked read: candidate lockset becomes {}
        _in_thread(obj.bump)   # shared-phase write with the set empty

    det = _run([HalfGuarded], body)
    assert [r.attr for r in det.races()] == ["n"]


# -- exemptions / clean runs --------------------------------------------------


def test_common_lock_is_clean():
    def body():
        obj = Guarded()
        _in_thread(obj.bump)
        obj.bump()
        assert obj.read() == 2

    det = _run([Guarded], body)
    assert det.races() == []
    det.assert_no_races()
    assert "ok" in det.report()


def test_unshared_allowlist_suppresses():
    def body():
        obj = Allowlisted()
        _in_thread(lambda: setattr(obj, "flag", True))
        assert obj.flag is True

    det = _run([Allowlisted], body)
    assert det.races() == []


def test_init_publication_is_exempt():
    # construction writes many attrs with no lock; later cross-thread
    # READS never make that a race (write happened pre-publication)
    def body():
        obj = Racy()
        _in_thread(lambda: obj.x)
        _ = obj.x

    det = _run([Racy], body)
    assert det.races() == []


def test_thread_handoff_is_exempt():
    # build in thread A, mutate only in thread B: exclusive ownership
    # transfers without a report (the Eraser Virgin->Exclusive path)
    def body():
        obj = Racy()

        def worker():
            obj.x = 1
            obj.x = 2

        _in_thread(worker)

    det = _run([Racy], body)
    assert det.races() == []


def test_instrument_restores_class_protocol():
    get0, set0 = Racy.__getattribute__, Racy.__setattr__
    with ld.patched(name_filter=lambda s: True) as graph:
        with rd.instrument(graph, classes=[Racy]):
            assert Racy.__getattribute__ is not get0
    assert Racy.__getattribute__ is get0
    assert Racy.__setattr__ is set0
    assert "__getattribute__" not in Racy.__dict__
    assert "__setattr__" not in Racy.__dict__
    assert "__init__" in Racy.__dict__   # its own __init__ came back


def test_unshared_union_across_mro():
    class Base:
        _unshared = ("a",)

    class Sub(Base):
        _unshared = ("b",)

    assert rd._unshared_of(Sub) == frozenset({"a", "b"})


# -- real-tree integration (the CI raced gate) --------------------------------


def _tiny_table():
    from repro.core import dwrf
    from repro.core.datagen import DataGenConfig
    from repro.core.schema import make_schema
    from repro.core.warehouse import Warehouse

    s = make_schema("rt", 8, 3, seed=0)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(1, DataGenConfig(rows_per_partition=256, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=128))
    return t


@pytest.mark.raced
def test_session_run_is_race_free(raced):
    """A real multi-worker session end to end under the race detector:
    the current tree must produce zero findings (empty baseline)."""
    from repro.core.dpp import DPPSession, SessionSpec
    from repro.core.transforms import default_dlrm_pipeline

    t = _tiny_table()
    dense = t.schema.dense_ids[:3]
    sparse = t.schema.sparse_ids[:2]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=64)
    spec = SessionSpec(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=64, rows_per_split=128,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=4,
    )
    sess = DPPSession(spec, t, n_workers=2)
    batches = sess.run_to_completion()
    assert batches, "session produced no batches"
    # teardown asserts no races and no lock-order cycles


@pytest.mark.raced
def test_cache_cross_thread_traffic_is_race_free(raced):
    """StripeCache + DedupIndex + TensorCache exercised from two threads
    under the detector."""
    from repro.core.cache import StripeCache
    from repro.core.dpp.master import SessionSpec, Split
    from repro.core.dpp.tensor_cache import TensorCache

    cache = StripeCache(dram_capacity_bytes=1 << 20)
    tc = TensorCache(capacity_bytes=1 << 20)
    spec = SessionSpec(table="t", partitions=(0,), feature_ids=(0,),
                       transform_specs=(), rows_per_split=64)
    split = Split(split_id=0, partition=0, row_start=0, row_end=64)
    key = TensorCache.key(spec, split, 0)
    payload = b"z" * 64

    def worker():
        k = cache.resolve("/p", 0, 64)
        cache.admit(k, payload, tenant="a")
        tc.put(key, [{"d": np.zeros(4, dtype=np.float32)}], cpu_s=0.01)

    th = threading.Thread(target=worker)
    th.start()
    th.join()
    k = cache.resolve("/p", 0, 64)
    assert cache.peek(k)
    assert tc.get(key) is not None
    cache.invalidate_path("/p")
    assert not cache.peek(cache.resolve("/p", 0, 64))
