"""Concurrency sanitizers: zero-cost-when-off proof + instrumented cost.

The sanitizer suite (docs/static_analysis.md, "runtime sanitizers") is
opt-in: the ``raced``/``lockdep`` fixtures and ``sched.controlled`` only
patch class protocol and lock factories inside their context managers.
This section proves the off state is *exactly* free, mirroring the
``bench_obs.py`` zero-cost-when-disabled gate:

  (a) **structural 0%** — after every sanitizer context exits,
      ``threading.Lock``/``RLock``, ``queue.Queue.put/get`` and the
      instrumented classes' ``__getattribute__``/``__setattr__``/
      ``__init__`` are identity-equal to the pristine objects.  The
      uninstrumented path therefore executes byte-identical code: the
      overhead is 0% by construction, not by measurement.
  (b) **measured bound** — the same guarded-bump loop is timed pristine
      vs after a full instrument/restore cycle; the delta must stay
      under a loose budget that cleanly separates "restored" from
      "accidentally left on" (instrumented attribute access is >10x).
  (c) **cost-when-on** — the instrumented loop is timed for the record
      so the price of turning the fixture on is visible in trend data.
"""
from __future__ import annotations

import queue
import threading

from benchmarks.common import emit, time_us
from repro.analysis import lockdep as ld
from repro.analysis import racedep as rd
from repro.analysis import sched as sc

# loose on purpose: timing jitter is real, but a leaked patch costs
# >1000% here, so anything under this bound means "restored"
OVERHEAD_BUDGET_PCT = 10.0


def _make_probe() -> type:
    """Fresh guarded-counter class: one lock acquire + two attr accesses
    per bump — the same shape the racedep fixture instruments on real
    classes.  A *new* class (new code objects) per measurement keeps the
    adaptive interpreter's per-site specialization state independent
    across the pristine / instrumented / restored timings."""

    class Probe:
        def __init__(self) -> None:
            self._lock = threading.Lock()
            self.n = 0

        def bump(self) -> None:
            with self._lock:
                self.n += 1

    return Probe


def _bump_us(cls: type, n: int) -> float:
    """Per-call cost of ``cls().bump`` over a fresh instance."""
    probe = cls()

    def loop() -> None:
        for _ in range(n):
            probe.bump()

    return time_us(loop) / n


def run(quick: bool = False) -> None:
    n = 20_000 if quick else 100_000

    # pristine references BEFORE any sanitizer has ever patched
    probe_cls = _make_probe()
    real_lock, real_rlock = threading.Lock, threading.RLock
    real_put, real_get = queue.Queue.put, queue.Queue.get
    get0 = probe_cls.__getattribute__
    set0 = probe_cls.__setattr__
    init0 = probe_cls.__dict__["__init__"]

    off_before_us = _bump_us(_make_probe(), n)
    emit("sanitizers.bump_pristine", off_before_us, "per-call_us")

    # (c) full stack on: lockdep graph + racedep attribute wrappers
    with ld.patched(name_filter=lambda s: True) as graph:
        with rd.instrument(graph, classes=[probe_cls]):
            on_us = _bump_us(probe_cls, n)
    emit("sanitizers.bump_instrumented", on_us,
         f"x{on_us / max(off_before_us, 1e-9):.1f}_vs_pristine")

    # exercise the scheduler's patch/restore cycle too (no exploration —
    # just the controlled() context that CI's sched gate enters per run)
    with sc.controlled(name_filter=lambda s: True):
        pass

    # (a) structural 0%: everything is the pristine object again
    assert threading.Lock is real_lock, "sanitizers leaked threading.Lock"
    assert threading.RLock is real_rlock, "sanitizers leaked threading.RLock"
    assert queue.Queue.put is real_put, "sanitizers leaked Queue.put"
    assert queue.Queue.get is real_get, "sanitizers leaked Queue.get"
    assert probe_cls.__getattribute__ is get0, "racedep leaked __getattribute__"
    assert probe_cls.__setattr__ is set0, "racedep leaked __setattr__"
    assert "__getattribute__" not in probe_cls.__dict__
    assert "__setattr__" not in probe_cls.__dict__
    assert probe_cls.__dict__["__init__"] is init0, "racedep leaked __init__"
    emit("sanitizers.off_identity", 0.0,
         "restored=Lock,RLock,Queue.put,Queue.get,getattr,setattr,init")

    # (b) measured bound on the restored path (fresh class: independent
    # specialization state, same shape)
    off_after_us = _bump_us(_make_probe(), n)
    overhead_pct = 100.0 * (off_after_us - off_before_us) \
        / max(off_before_us, 1e-9)
    emit("sanitizers.off_overhead", off_after_us,
         f"overhead_pct={overhead_pct:.2f}")
    assert overhead_pct <= OVERHEAD_BUDGET_PCT, (
        f"uninstrumented path slowed {overhead_pct:.2f}% after sanitizer "
        f"teardown (budget {OVERHEAD_BUDGET_PCT}%): a patch leaked"
    )
