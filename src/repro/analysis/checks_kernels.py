"""Kernel-parity rules (REPRO-K001/K002).

The differential suite in ``tests/test_engine.py`` caught three real
kernel bugs in PR 5 — it only keeps that power if every fused op stays
inside its net.  Two structural guarantees:

  * **K001** — every ``OP_*`` code defined in
    ``src/repro/kernels/fused_transform.py`` has a counterpart of the
    same name (and value) in ``src/repro/kernels/ref.py``, and vice
    versa.  The ref module IS the parity oracle; an op without a ref is
    untestable by construction.
  * **K002** — every ``OP_*`` code is exercised by
    ``tests/test_engine.py``.  An op counts as exercised when the test
    source references the ``OP_<NAME>`` constant itself, or uses the
    op's transform name in a spec (``OP_SIGRID_HASH`` -> ``SigridHash``).
    Float-lane variants (``OP_CLAMP_F``) map to their base transform
    (``Clamp``) — the engine selects the ``_F`` lane from operand dtype,
    so a float-typed ``Clamp`` differential exercises it.

    K002 also covers the public kernel API: every public function the
    dispatch layer ``src/repro/kernels/ops.py`` defines (``embedding_bag``,
    ``flash_attention``, ...) must be named by the differential suite in
    ``tests/test_kernels.py`` — a dispatchable kernel nobody
    parity-tests is exactly the untested-op hole, one layer up.

    And the decode layer: every public kernel ``src/repro/kernels/decode.py``
    defines (``xor_decrypt``, ``dense_unpack``, ``ragged_gather``) must be
    named by the decode differential suite in ``tests/test_decode.py`` —
    the extract path promises byte-identical batches across engines, which
    is only a promise while each decode kernel sits inside that net.

A new op can therefore never land without a ref implementation and a
differential test naming it.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional

from repro.analysis.core import CheckContext, Finding, checker, rule

K001 = rule("REPRO-K001",
            "OP_* code missing its counterpart in kernels/ref.py (or ref "
            "defines an op the kernel does not)")
K002 = rule("REPRO-K002",
            "OP_* code not exercised by the differential suite in "
            "tests/test_engine.py")

FUSED = "src/repro/kernels/fused_transform.py"
REF = "src/repro/kernels/ref.py"
SUITE = "tests/test_engine.py"
OPS = "src/repro/kernels/ops.py"
KSUITE = "tests/test_kernels.py"
DECODE = "src/repro/kernels/decode.py"
DSUITE = "tests/test_decode.py"


def _op_defs(mod) -> Dict[str, Optional[int]]:
    """Module-level ``OP_NAME = <int>`` assignments -> {name: value}."""
    ops: Dict[str, Optional[int]] = {}
    for node in mod.tree.body:
        if isinstance(node, ast.Assign) and len(node.targets) == 1:
            t = node.targets[0]
            if isinstance(t, ast.Name) and t.id.startswith("OP_"):
                val = node.value
                ops[t.id] = (
                    val.value if isinstance(val, ast.Constant)
                    and isinstance(val.value, int) else None
                )
    return ops


def transform_name(op_const: str) -> str:
    """``OP_SIGRID_HASH`` -> ``SigridHash``; float-lane variants map to
    their base transform (``OP_CLAMP_F`` -> ``Clamp``)."""
    base = op_const[len("OP_"):]
    base = re.sub(r"_F$", "", base)
    return "".join(w.capitalize() for w in base.split("_"))


@checker("kernel-parity")
def check_kernel_parity(ctx: CheckContext):
    findings: List[Finding] = []
    fused = ctx.load(FUSED)
    ref = ctx.load(REF)
    suite = ctx.load(SUITE)
    if fused is None:
        return [Finding(K001, FUSED, 1, "kernel module missing/unparsable")]
    fused_ops = _op_defs(fused)
    ref_ops = _op_defs(ref) if ref is not None else {}
    if ref is None:
        findings.append(Finding(K001, REF, 1, "ref module missing/unparsable"))
    for name, value in sorted(fused_ops.items()):
        line = next(
            (i + 1 for i, ln in enumerate(fused.lines)
             if ln.startswith(f"{name} ")), 1
        )
        if name not in ref_ops:
            findings.append(Finding(
                K001, FUSED, line,
                f"{name} has no counterpart in kernels/ref.py — the fused "
                "op has no parity oracle",
            ))
        elif ref_ops[name] is not None and value is not None \
                and ref_ops[name] != value:
            findings.append(Finding(
                K001, FUSED, line,
                f"{name} = {value} but kernels/ref.py says {ref_ops[name]} "
                "— op-code tables diverge",
            ))
    for name in sorted(set(ref_ops) - set(fused_ops)):
        findings.append(Finding(
            K001, REF, 1,
            f"{name} defined in ref.py only — dead oracle or missing "
            "fused implementation",
        ))
    if suite is None:
        findings.append(Finding(
            K002, SUITE, 1,
            "differential suite missing — no op is parity-tested",
        ))
        return findings
    for name in sorted(fused_ops):
        if name in suite.text or transform_name(name) in suite.text:
            continue
        line = next(
            (i + 1 for i, ln in enumerate(fused.lines)
             if ln.startswith(f"{name} ")), 1
        )
        findings.append(Finding(
            K002, FUSED, line,
            f"{name} is never exercised by {SUITE} (neither the constant "
            f"nor a {transform_name(name)!r} spec appears)",
        ))
    findings.extend(_check_ops_coverage(ctx))
    findings.extend(_check_decode_coverage(ctx))
    return findings


def _public_kernel_defs(mod) -> Dict[str, int]:
    """Top-level public ``def``s in the dispatch module -> {name: line}."""
    return {
        node.name: node.lineno
        for node in mod.tree.body
        if isinstance(node, ast.FunctionDef) and not node.name.startswith("_")
    }


def _check_ops_coverage(ctx: CheckContext) -> List[Finding]:
    """K002, dispatch layer: every public kernel in ``kernels/ops.py`` must
    be named by the differential suite in ``tests/test_kernels.py``."""
    ops = ctx.load(OPS)
    if ops is None:
        return []
    kernels = _public_kernel_defs(ops)
    if not kernels:
        return []
    ksuite = ctx.load(KSUITE)
    if ksuite is None:
        return [Finding(
            K002, KSUITE, 1,
            "kernel differential suite missing — no dispatchable kernel "
            "is parity-tested",
        )]
    return [
        Finding(
            K002, OPS, line,
            f"public kernel {name!r} is never exercised by {KSUITE} — a "
            "dispatchable op without a differential test",
        )
        for name, line in sorted(kernels.items())
        if name not in ksuite.text
    ]


def _check_decode_coverage(ctx: CheckContext) -> List[Finding]:
    """K002, decode layer: every public kernel in ``kernels/decode.py``
    must be named by the decode differential suite in
    ``tests/test_decode.py`` — the engines' byte-identity guarantee rests
    on each decode kernel staying inside the parity net."""
    decode = ctx.load(DECODE)
    if decode is None:
        return []
    kernels = _public_kernel_defs(decode)
    if not kernels:
        return []
    dsuite = ctx.load(DSUITE)
    if dsuite is None:
        return [Finding(
            K002, DSUITE, 1,
            "decode differential suite missing — no decode kernel is "
            "parity-tested",
        )]
    return [
        Finding(
            K002, DECODE, line,
            f"decode kernel {name!r} is never exercised by {DSUITE} — an "
            "extract-path op without a differential test",
        )
        for name, line in sorted(kernels.items())
        if name not in dsuite.text
    ]
