"""Serve a small LM with batched requests: prefill + continuous decode.

  PYTHONPATH=src python examples/serve_lm.py [--arch qwen3-8b]
"""
import argparse
import subprocess
import sys

from repro.launch import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    args = ap.parse_args()
    sys.argv = [
        "serve", "--arch", args.arch, "--smoke",
        "--batch", "8", "--prompt-len", "64", "--decode-steps", "32",
    ]
    raise SystemExit(serve.main())


if __name__ == "__main__":
    main()
