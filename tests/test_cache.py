"""Shared stripe cache + dedup tier (ISSUE 2 tentpole).

Cross-job behavior: overlapping sessions hit instead of re-reading HDD,
byte-identical stripes across partitions collapse to one content entry,
Zipf-skewed partition popularity raises the hit rate, and the cached read
path serves bytes identical to the uncached one.
"""
import numpy as np
import pytest

from repro.core import dwrf
from repro.core.cache import DedupIndex, StripeCache, stripe_digest
from repro.core.datagen import DataGenConfig, generate_partition
from repro.core.dpp import DPPService, SessionSpec
from repro.core.dpp.tensor_cache import TensorCache
from repro.core.reader import COALESCE_WINDOW, TableReader, plan_reads
from repro.core.schema import make_schema
from repro.core.tectonic import TectonicFS
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse

ROWS = 512
STRIPE = 128


def _warehouse(n_partitions=2, name="ct", seed=3):
    s = make_schema(name, 16, 6, seed=seed)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(n_partitions, DataGenConfig(rows_per_partition=ROWS, seed=4),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE))
    return wh, t


def _assert_batches_identical(a, b):
    assert a.num_rows == b.num_rows
    assert set(a.dense) == set(b.dense) and set(a.sparse) == set(b.sparse)
    for fid in a.dense:
        np.testing.assert_array_equal(
            np.nan_to_num(a.dense[fid]), np.nan_to_num(b.dense[fid])
        )
    for fid in a.sparse:
        np.testing.assert_array_equal(a.sparse[fid].offsets, b.sparse[fid].offsets)
        np.testing.assert_array_equal(a.sparse[fid].values, b.sparse[fid].values)
    if a.labels is not None or b.labels is not None:
        np.testing.assert_array_equal(a.labels, b.labels)


# -- dedup index -------------------------------------------------------------


def test_dedup_index_resolves_content_keys():
    idx = DedupIndex()
    payload = b"x" * 100
    d = idx.register("p1", 4, 100, payload)
    assert d == stripe_digest(payload)
    # sub-extent inside the stripe -> content key with relative offset
    assert idx.resolve("p1", 10, 20) == ("c", d, 6, 20)
    # crossing the stripe boundary -> path-addressed fallback
    assert idx.resolve("p1", 50, 100) == ("p", "p1", 50, 100)
    assert idx.resolve("other", 10, 20) == ("p", "other", 10, 20)


def test_dedup_collapses_identical_stripes_across_partitions():
    s = make_schema("dd", 12, 4, seed=1)
    wh = Warehouse()
    t = wh.create_table(s)
    cache = StripeCache()
    wh.attach_cache(cache)
    batch = generate_partition(s, 0, DataGenConfig(rows_per_partition=ROWS, seed=9))
    opts = dwrf.DwrfWriterOptions(flattened=True, stripe_rows=STRIPE)
    t.write_partition(0, batch, opts)
    t.write_partition(1, batch, opts)      # byte-identical content, new path
    st = cache.dedup.stats
    assert st.stripes_registered == 2 * (ROWS // STRIPE)
    assert cache.dedup.unique_stripes == ROWS // STRIPE
    assert st.dedup_ratio == pytest.approx(2.0)

    # reading partition 1 after partition 0 is ALL cache hits: the content
    # keys match even though partition 1's path was never read
    r = TableReader(t, s.logged_ids[:6], record_popularity=False)
    a = r.read_rows(t.partitions[0], 0, ROWS)
    assert a.bytes_from_storage > 0 and a.bytes_from_cache == 0
    b = r.read_rows(t.partitions[1], 0, ROWS)
    assert b.bytes_from_storage == 0 and b.bytes_from_cache == b.bytes_read
    _assert_batches_identical(a.batch, b.batch)


# -- cached read path --------------------------------------------------------


def test_cached_reads_byte_identical_and_storage_only_on_miss():
    wh, t = _warehouse()
    r = TableReader(t, t.schema.logged_ids[:8], record_popularity=False)
    meta = t.partitions[0]
    uncached = r.read_rows(meta, 0, ROWS)

    cache = StripeCache()
    wh.attach_cache(cache)
    miss = r.read_rows(meta, 0, ROWS)
    hit = r.read_rows(meta, 0, ROWS)
    _assert_batches_identical(uncached.batch, miss.batch)
    _assert_batches_identical(uncached.batch, hit.batch)
    assert miss.bytes_from_storage == miss.bytes_read
    assert hit.bytes_from_storage == 0
    assert hit.bytes_from_cache == hit.bytes_read == miss.bytes_read


def test_plan_reads_reports_cached_bytes():
    wh, t = _warehouse()
    cache = StripeCache()
    wh.attach_cache(cache)
    meta = t.partitions[0]
    proj = t.schema.logged_ids[:8]
    plan = plan_reads(meta.footer, proj, cache=cache, path=meta.path)
    assert plan.bytes_cached_planned == 0
    TableReader(t, proj, record_popularity=False).read_rows(meta, 0, ROWS)
    plan = plan_reads(meta.footer, proj, cache=cache, path=meta.path)
    assert plan.bytes_cached_planned == plan.bytes_planned
    # a window-coalesced plan spans stripes; segment-granular probing must
    # still see the cached stripes instead of reporting 0
    plan_w = plan_reads(meta.footer, proj, COALESCE_WINDOW,
                        cache=cache, path=meta.path)
    assert plan_w.bytes_cached_planned == plan_w.bytes_planned > 0


def test_flash_victim_tier_with_popularity_admission():
    wh, t = _warehouse()
    meta = t.partitions[0]
    proj = t.schema.logged_ids[:8]
    # DRAM big enough for one stripe only; flash takes popular victims
    probe = TableReader(t, proj, record_popularity=False)
    stripe_bytes = next(iter(probe.iter_stripes(meta, 0, STRIPE))).bytes_read
    cache = StripeCache(
        dram_capacity_bytes=int(1.5 * stripe_bytes),
        flash_admit_reads=2,
    )
    wh.attach_cache(cache)
    r = TableReader(t, proj, record_popularity=False)
    for _ in range(3):   # epochs over the partition: reuse with evictions
        list(r.iter_stripes(meta, 0, ROWS))
    assert cache.dram.evictions > 0
    assert cache.flash.admitted > 0          # popular victims spilled down
    assert cache.flash.hits > 0              # and were served from flash
    assert cache.flash.io.num_ios > 0        # flash I/O charged to the model
    assert cache.flash.rejected > 0          # one-touch victims stayed out


def test_one_touch_scan_does_not_enter_flash():
    wh, t = _warehouse(n_partitions=4)
    probe = TableReader(t, t.schema.logged_ids[:8], record_popularity=False)
    stripe_bytes = next(iter(probe.iter_stripes(t.partitions[0], 0, STRIPE))).bytes_read
    cache = StripeCache(dram_capacity_bytes=int(1.2 * stripe_bytes),
                        flash_admit_reads=2)
    wh.attach_cache(cache)
    r = TableReader(t, t.schema.logged_ids[:8], record_popularity=False)
    for p in range(4):                       # scan every partition once
        list(r.iter_stripes(t.partitions[p], 0, ROWS))
    assert cache.dram.evictions > 0
    assert cache.flash.admitted == 0         # nothing was read twice


def test_reattach_does_not_double_register_dedup_stats():
    wh, t = _warehouse()
    cache = StripeCache()
    wh.attach_cache(cache)
    before = (cache.dedup.stats.stripes_registered,
              cache.dedup.stats.logical_bytes,
              cache.dedup.stats.dedup_ratio)
    wh.attach_cache(cache)       # e.g. DPPService over an attached warehouse
    assert (cache.dedup.stats.stripes_registered,
            cache.dedup.stats.logical_bytes,
            cache.dedup.stats.dedup_ratio) == before


def test_single_flight_coalesces_concurrent_misses():
    import threading

    cache = StripeCache()
    key = ("p", "f", 0, 4)
    claims, hits = [], []
    started = threading.Event()

    def first():
        got = cache.get_or_claim(key)
        assert got is None          # cold: this thread owns the fill
        claims.append(1)
        started.set()
        cache.admit(key, b"data")   # releases the waiting reader

    def second():
        started.wait(5)
        got = cache.get_or_claim(key)   # blocks until the fill, then hits
        hits.append(got.payload)

    t2 = threading.Thread(target=second)
    t2.start()
    first()
    t2.join(5)
    assert claims == [1] and hits == [b"data"]
    assert cache.misses == 1 and cache.dram.hits == 1


# -- cross-job behavior ------------------------------------------------------


def _spec(t, batch_size=128):
    dense = t.schema.dense_ids[:4]
    sparse = t.schema.sparse_ids[:2]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=500)
    return SessionSpec(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=batch_size, rows_per_split=STRIPE,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )


def _batch_signature(batches):
    sig = []
    for b in batches:
        sig.append(tuple(
            (k, b[k].shape, float(np.nan_to_num(b[k]).sum())) for k in sorted(b)
        ))
    return sorted(sig)


def test_concurrent_sessions_share_cache_and_serve_identical_rows():
    wh0, t0 = _warehouse(name="cs")
    svc0 = DPPService(wh0, enable_stripe_cache=False)
    for i in range(2):
        svc0.create_session(f"j{i}", _spec(t0), n_workers=2)
    res0 = svc0.run_all(timeout_s=60)
    m0 = svc0.fleet_metrics()

    wh1, t1 = _warehouse(name="cs")
    svc1 = DPPService(wh1)
    for i in range(2):
        svc1.create_session(f"j{i}", _spec(t1), n_workers=2)
    res1 = svc1.run_all(timeout_s=60)
    m1 = svc1.fleet_metrics()

    # same tensors served, over-read invariant intact
    for name in res0:
        assert _batch_signature(res0[name]) == _batch_signature(res1[name])
    assert m1.over_read_ratio == 1.0
    # the two sessions overlap fully: the cache halves storage RX
    assert m1.ingest_rx_bytes == m0.storage_rx_bytes
    assert m1.storage_rx_bytes <= 0.6 * m0.storage_rx_bytes
    assert m1.cache_rx_bytes > 0
    assert svc1.stripe_cache.hit_rate >= 0.5


def test_hit_rate_rises_with_zipf_skew():
    rng_partitions = 8
    n_accesses = 24
    hit_rates = {}
    for a in (0.0, 1.4):
        wh, t = _warehouse(n_partitions=rng_partitions, name=f"zipf{a}")
        # DRAM holds ~2 of 8 partitions: only a skewed access stream reuses
        r = TableReader(t, t.schema.logged_ids[:6], record_popularity=False)
        one = r.read_rows(t.partitions[0], 0, ROWS).bytes_read
        cache = StripeCache(dram_capacity_bytes=int(2.2 * one),
                            flash_admit_reads=10**9)   # DRAM-only
        wh.attach_cache(cache)
        rng = np.random.default_rng(5)
        if a == 0.0:
            seq = rng.integers(0, rng_partitions, n_accesses)
        else:
            seq = (rng.zipf(a + 1.0, n_accesses) - 1) % rng_partitions
        for p in seq:
            r.read_rows(t.partitions[int(p)], 0, ROWS)
        hit_rates[a] = cache.hit_rate
    assert hit_rates[1.4] > hit_rates[0.0] + 0.2, hit_rates


# -- tensor cache satellite --------------------------------------------------


def test_tensor_cache_put_refreshes_lru_on_insert_hit():
    tc = TensorCache(capacity_bytes=3000)
    mk = lambda v: [{"x": np.full(250, v, np.float32)}]     # 1000 B each
    tc.put(("a",), mk(1.0), cpu_s=0.1)
    tc.put(("b",), mk(2.0), cpu_s=0.1)
    tc.put(("c",), mk(3.0), cpu_s=0.1)
    # re-insert "a": idempotent (first entry wins) but must refresh recency
    tc.put(("a",), mk(99.0), cpu_s=0.1)
    assert tc.get(("a",))[0]["x"][0] == 1.0
    tc.put(("d",), mk(4.0), cpu_s=0.1)       # evicts LRU = "b", not "a"
    assert tc.get(("b",)) is None
    assert tc.get(("a",)) is not None
    assert tc.stats.bytes_stored <= 3000
