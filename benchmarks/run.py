"""Benchmark driver: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only storage,dpp,...] [--quick]

Prints ``name,us_per_call,derived`` CSV rows.

``--quick`` is the CI smoke path: every section module is imported (so
benchmarks can never silently rot), and sections whose ``run`` accepts a
``quick`` flag are executed with a scaled-down workload.  Quick runs also
write ``BENCH_quick.json`` next to this file — per-section metric rows
plus wall-clock timestamps — so CI artifacts and trend tooling get a
machine-readable record instead of scraping stdout.
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time
import traceback
from pathlib import Path

from benchmarks import common

SECTIONS = [
    "storage",          # Tables 3/4/5/6
    "reader",           # split-scoped streaming reads (ISSUE 1)
    "cache",            # shared stripe cache + dedup tier (ISSUE 2)
    "tenancy",          # multi-tenant cache control plane + prefetch (ISSUE 3)
    "faults",           # dispatch budgets, quarantine, elastic scaling (ISSUE 4)
    "popularity",       # Fig 7
    "dpp",              # Table 9 / Fig 9 / Table 10
    "trainer",          # Table 8 / Fig 8 / Table 7
    "train_e2e",        # closed loop: DPP -> tiered embeddings -> DLRM (ISSUE 9)
    "optimizations",    # Table 12
    "kernels",          # §7.2 fused transform + hot kernels
    "engine",           # §7.2 fused TransformEngine vs per-feature (ISSUE 5)
    "extract",          # §6.3 batched stripe decode vs per-stream (ISSUE 10)
    "obs",              # telemetry overhead + Table-7 stall attribution
    "sanitizers",       # race/interleaving sanitizers: zero-cost-when-off (ISSUE 8)
    "power",            # Fig 1
    "coordination",     # Figs 4/5/6, Table 2
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="comma-separated section list")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: import every section, run the quick-capable ones")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failures = []
    report = {
        "started_at": time.time(),
        "mode": "quick" if args.quick else "full",
        "sections": {},
    }
    for section in SECTIONS:
        if only and section not in only:
            continue
        print(f"# === {section} ===")
        row_mark = len(common.ROWS)
        report_mark = len(common.REPORTS)
        t0 = time.time()
        status = "ok"
        try:
            mod = __import__(f"benchmarks.bench_{section}", fromlist=["run"])
            if args.quick:
                if "quick" in inspect.signature(mod.run).parameters:
                    mod.run(quick=True)
                else:
                    status = "import-only"
                    print(f"# {section}: import-only (no quick mode)")
            else:
                mod.run()
        except Exception as e:  # keep going; report at the end
            failures.append((section, e))
            status = f"failed: {e}"
            traceback.print_exc()
        report["sections"][section] = {
            "status": status,
            "started_at": t0,
            "elapsed_s": time.time() - t0,
            # the rows this section emit()-ed, keyed like the CSV output
            "metrics": [
                {"name": n, "us_per_call": us, "derived": d}
                for n, us, d in common.ROWS[row_mark:]
            ],
            # structured payloads (emit_report): e.g. the obs section's
            # per-tenant stall-attribution table
            "reports": {n: p for n, p in common.REPORTS[report_mark:]},
        }
    report["finished_at"] = time.time()
    if args.quick:
        out = Path(__file__).resolve().parent.parent / "BENCH_quick.json"
        out.write_text(json.dumps(report, indent=2) + "\n")
        print(f"# wrote {out.name}: {len(report['sections'])} section(s), "
              f"{sum(len(s['metrics']) for s in report['sections'].values())} "
              "metric row(s)")
    if failures:
        print(f"# FAILED sections: {[s for s, _ in failures]}")
        sys.exit(1)
    print("# all benchmark sections completed")


if __name__ == "__main__":
    main()
