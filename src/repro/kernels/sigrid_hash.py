"""Pallas TPU kernel: SigridHash (Table 11's hottest op — 11.9x on GPU).

TPU adaptation: ids are packed into (rows, 128-aligned) int32 tiles; the
hash is two multiply-xor-shift rounds on 32-bit lanes (VPU-friendly — no
64-bit lanes on TPU), blocked into VMEM tiles of (block_rows, block_cols).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hash_u32(x: jax.Array) -> jax.Array:
    x ^= x >> 16
    x = x * jnp.uint32(0x7FEB352D)
    x ^= x >> 15
    x = x * jnp.uint32(0x846CA68B)
    x ^= x >> 16
    return x


def _kernel(ids_ref, out_ref, *, salt: int, max_value: int):
    x = ids_ref[...].astype(jnp.uint32) ^ jnp.uint32(salt)
    x = _hash_u32(x)
    out_ref[...] = (x % jnp.uint32(max_value)).astype(jnp.int32)


@functools.partial(
    jax.jit, static_argnames=("salt", "max_value", "block_rows", "block_cols", "interpret")
)
def sigrid_hash(
    ids: jax.Array,
    salt: int,
    max_value: int,
    *,
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """ids: (rows, cols) int32 -> hashed int32 in [0, max_value)."""
    rows, cols = ids.shape
    br = min(block_rows, rows)
    bc = min(block_cols, cols)
    grid = (pl.cdiv(rows, br), pl.cdiv(cols, bc))
    return pl.pallas_call(
        functools.partial(_kernel, salt=salt, max_value=max_value),
        grid_spec=pl.GridSpec(
            grid=grid,
            in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        ),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        interpret=interpret,
    )(ids)
