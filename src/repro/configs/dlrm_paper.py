"""dlrm-paper — the paper's own model family (RM3-like scale, Table 4)."""
import dataclasses
from repro.models.dlrm import DLRMConfig

CONFIG = DLRMConfig(
    name="dlrm-paper",
    num_dense=504,
    num_tables=42,
    vocab_per_table=2_000_000,
    embed_dim=128,
    max_ids_per_feature=32,
    bottom_mlp=(512, 256, 128),
    top_mlp=(1024, 1024, 512, 256, 1),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="dlrm-smoke",
    num_dense=16,
    num_tables=8,
    vocab_per_table=1000,
    embed_dim=16,
    max_ids_per_feature=8,
    bottom_mlp=(32, 16),
    top_mlp=(64, 32, 1),
)
