"""Architecture config registry + assigned input shapes.

Each assigned architecture has a module defining ``CONFIG`` (the exact
public config) and ``SMOKE`` (a reduced same-family config for CPU tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Tuple

ARCH_IDS = [
    "mamba2-2.7b",
    "codeqwen1.5-7b",
    "llama3-405b",
    "qwen2-72b",
    "qwen3-8b",
    "jamba-1.5-large-398b",
    "llava-next-mistral-7b",
    "deepseek-v2-236b",
    "kimi-k2-1t-a32b",
    "seamless-m4t-large-v2",
    "dlrm-paper",
]

_MODULES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "llama3-405b": "llama3_405b",
    "qwen2-72b": "qwen2_72b",
    "qwen3-8b": "qwen3_8b",
    "jamba-1.5-large-398b": "jamba15_large",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    "kimi-k2-1t-a32b": "kimi_k2_1t",
    "seamless-m4t-large-v2": "seamless_m4t_v2",
    "dlrm-paper": "dlrm_paper",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Reduced shapes used by per-arch smoke tests (same modes, tiny extents).
SMOKE_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 128, 2, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 128, 1, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 64, 2, "decode"),
    "long_500k": ShapeSpec("long_500k", 256, 1, "decode"),
}


def get_config(arch: str) -> Any:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> Any:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE


def cell_supported(arch: str, shape: str) -> Tuple[bool, str]:
    """Whether an (arch, shape) dry-run cell applies (see DESIGN.md §4)."""
    cfg = get_config(arch)
    if arch == "dlrm-paper":
        if shape != "train_4k":
            return False, "DLRM has no sequence/KV-cache serving shapes"
        return True, ""
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention architecture: 500k decode requires sub-quadratic attention"
    return True, ""
