"""Runtime lockset data-race detector ("racedep", after Eraser).

The lock-order sanitizer (``lockdep``) proves that locks nest
consistently; what it cannot see is state that is touched with **no**
lock at all — an unlocked read of a flag another thread writes, or two
threads guarding one field with *different* locks.  Those are the races
that schedule-dependent controller bugs hide behind (ISSUE 8), and no
amount of static lock-discipline lint can find them: the lint proves
each class takes *its own* lock, not that every shared access does.

Mechanism (the Eraser lockset algorithm, adapted to attribute
granularity): :func:`instrument` wraps the core threaded classes'
``__setattr__`` / ``__getattribute__`` so every instance-attribute
access is observed together with the set of :class:`~.lockdep.TrackedLock`
names the accessing thread currently holds (read off the shared
:class:`~.lockdep.LockGraph`).  Per ``(object, attribute)`` a small state
machine runs:

  * **exclusive** — accessed by a single thread so far: no constraint
    (thread-confined state is fine, and publication hand-offs — build in
    thread A, use only in thread B — never false-positive);
  * **shared** — a second thread touched it: the *candidate lockset*
    starts as the locks held at that access and is intersected at every
    later access;
  * **shared-modified** — some access in the shared phase was a write:
    if the candidate lockset is (or becomes) empty, no single lock
    protects the attribute — a data race is reported with both access
    sites, the accessing threads, and the acquisition stacks of the
    locks involved.

Exemptions:

  * ``__init__`` publication — accesses made while the object's own
    ``__init__`` frame is still running are ignored: construction-time
    state is pre-publication by definition;
  * ``_unshared`` allowlist — a class-level
    ``_unshared = ("alive", ...)`` tuple names attributes that are
    *deliberately* unlocked (GIL-atomic single-word flags, single-writer
    telemetry).  REPRO-R001 (``checks_races.py``) statically enforces
    that every unlocked non-``__init__`` assignment on an instrumented
    class is either lock-guarded or declared here, so the allowlist can
    never drift silently;
  * lock attributes themselves (``_lock``-style names) — reading the
    lock in order to take it is inherently a pre-lock access.

Usage (the opt-in ``raced`` pytest fixture in ``tests/conftest.py``)::

    def test_heavy_concurrency(raced):
        ...build caches/masters/workers inside the test...
        # teardown runs raced.assert_no_races()

Like lockdep, detection needs no actual unfortunate timing: one
unlocked write plus one access from a second thread is enough, however
the schedule landed.  (Schedule-dependent *atomicity* violations —
check-then-act windows under correct locking — are the sibling tool's
job: see ``repro.analysis.sched``.)
"""
from __future__ import annotations

import _thread
import dataclasses
import re
import sys
import threading
from contextlib import contextmanager
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

from repro.analysis.lockdep import LockGraph, _stack_summary


class RaceError(AssertionError):
    """An attribute is shared across threads with an empty lockset."""


READ = "read"
WRITE = "write"

_LOCK_ATTR_RE = re.compile(r"^_\w*lock$")

# module path -> instrumented class names; single source of truth shared
# with the REPRO-R001/R002 static rules (checks_races.py) and the default
# class set of instrument().
INSTRUMENTED_CLASSES: Dict[str, Tuple[str, ...]] = {
    "src/repro/core/dpp/master.py": ("DPPMaster",),
    "src/repro/core/dpp/worker.py": ("DPPWorker",),
    "src/repro/core/dpp/service.py": ("DPPSession",),
    "src/repro/core/dpp/tensor_cache.py": ("TensorCache",),
    "src/repro/core/dpp/prefetch.py": ("PrefetchPlanner",),
    "src/repro/core/dpp/autoscale.py": ("ElasticController",),
    "src/repro/core/cache/stripe_cache.py": ("StripeCache",),
    "src/repro/core/cache/dedup.py": ("DedupIndex",),
    "src/repro/core/tectonic.py": ("TectonicFS",),
}

_IN_INIT_FLAG = "_racedep_in_init"


def core_classes() -> Tuple[type, ...]:
    """The default instrumentation set: every core threaded class."""
    from repro.core.cache.dedup import DedupIndex
    from repro.core.cache.stripe_cache import StripeCache
    from repro.core.dpp.autoscale import ElasticController
    from repro.core.dpp.master import DPPMaster
    from repro.core.dpp.prefetch import PrefetchPlanner
    from repro.core.dpp.service import DPPSession
    from repro.core.dpp.tensor_cache import TensorCache
    from repro.core.dpp.worker import DPPWorker
    from repro.core.tectonic import TectonicFS

    return (DPPMaster, DPPWorker, DPPSession, StripeCache, DedupIndex,
            TensorCache, PrefetchPlanner, ElasticController, TectonicFS)


def _unshared_of(cls: type) -> frozenset:
    """Union of ``_unshared`` declarations across the MRO (a subclass
    extends, never hides, its base's allowlist)."""
    names: Set[str] = set()
    for c in cls.__mro__:
        names.update(c.__dict__.get("_unshared", ()))
    return frozenset(names)


def _stack() -> Tuple[str, ...]:
    return tuple(fr for fr in _stack_summary()
                 if "racedep.py" not in fr and "lockdep.py" not in fr)


def _access_site() -> str:
    """``file.py:lineno in func`` of the nearest caller frame outside the
    sanitizer machinery — cheap enough to capture on the hot path."""
    f = sys._getframe(1)
    while f is not None:
        name = Path(f.f_code.co_filename).name
        if name not in ("racedep.py", "lockdep.py"):
            return f"{name}:{f.f_lineno} in {f.f_code.co_name}"
        f = f.f_back
    return "<unknown>"


@dataclasses.dataclass
class _Access:
    """One sampled access (transition into sharing, or a shared write)."""

    thread: str
    kind: str                            # READ | WRITE
    site: str
    locks: Tuple[str, ...]               # lock names held
    lock_stacks: Tuple[Tuple[str, Tuple[str, ...]], ...]  # (name, stack)
    stack: Tuple[str, ...]


@dataclasses.dataclass
class _AttrState:
    obj: object                          # strong ref: pins id() stability
    cls: str
    attr: str
    owner: str                           # first post-__init__ thread
    owner_site: str                      # its most recent access site
    lockset: Optional[Set[str]] = None   # None = still exclusive
    threads: Set[str] = dataclasses.field(default_factory=set)
    shared_write: bool = False
    sharing: Optional[_Access] = None    # the access that broke exclusivity
    write: Optional[_Access] = None      # first write in the shared phase


@dataclasses.dataclass
class Race:
    """One reported data race, aggregated per (class, attribute)."""

    cls: str
    attr: str
    threads: Tuple[str, ...]
    instances: int
    owner_site: str
    sharing: _Access
    write: _Access


class RaceDetector:
    """Shared lockset state machine fed by the instrumented classes."""

    def __init__(self, graph: Optional[LockGraph] = None):
        self.graph = graph if graph is not None else LockGraph()
        # a REAL lock: note() runs while threading.Lock may be patched
        self._mu = _thread.allocate_lock()
        self._state: Dict[Tuple[int, str], _AttrState] = {}

    # -- hot path ------------------------------------------------------------

    def note(self, obj: object, cls: type, attr: str, kind: str) -> None:
        held = self.graph._held()
        tname = threading.current_thread().name
        key = (id(obj), attr)
        with self._mu:
            st = self._state.get(key)
            if st is None:
                self._state[key] = _AttrState(
                    obj=obj, cls=cls.__name__, attr=attr,
                    owner=tname, owner_site=_access_site(),
                )
                return
            if st.lockset is None:                     # exclusive phase
                if tname == st.owner:
                    st.owner_site = _access_site()
                    return
                # second thread: start lockset refinement at this access
                locks = tuple(sorted({h.name for h in held}))
                acc = _Access(
                    thread=tname, kind=kind, site=_access_site(),
                    locks=locks,
                    lock_stacks=tuple((h.name, h.stack) for h in held),
                    stack=_stack(),
                )
                st.lockset = set(locks)
                st.threads = {st.owner, tname}
                st.sharing = acc
                if kind == WRITE:
                    st.shared_write = True
                    st.write = acc
                return
            # shared phase: intersect, record the first write sample
            st.threads.add(tname)
            locks = {h.name for h in held}
            st.lockset &= locks
            if kind == WRITE:
                if not st.shared_write or (st.write is not None
                                           and st.write.locks
                                           and not (set(st.write.locks)
                                                    & st.lockset)):
                    # (re)sample so the report shows a write that is
                    # actually unprotected under the final lockset
                    st.write = _Access(
                        thread=tname, kind=kind, site=_access_site(),
                        locks=tuple(sorted(locks)),
                        lock_stacks=tuple((h.name, h.stack) for h in held),
                        stack=_stack(),
                    )
                st.shared_write = True

    # -- analysis ------------------------------------------------------------

    def races(self) -> List[Race]:
        """Shared-modified attributes whose candidate lockset is empty,
        aggregated per (class, attribute) across instances."""
        with self._mu:
            states = list(self._state.values())
        grouped: Dict[Tuple[str, str], List[_AttrState]] = {}
        for st in states:
            if st.lockset is not None and st.shared_write and not st.lockset:
                grouped.setdefault((st.cls, st.attr), []).append(st)
        out: List[Race] = []
        for (cls, attr), sts in sorted(grouped.items()):
            threads: Set[str] = set()
            for st in sts:
                threads.update(st.threads)
            pick = sts[0]
            out.append(Race(
                cls=cls, attr=attr, threads=tuple(sorted(threads)),
                instances=len(sts), owner_site=pick.owner_site,
                sharing=pick.sharing, write=pick.write or pick.sharing,
            ))
        return out

    def report(self) -> str:
        races = self.races()
        with self._mu:
            n_attrs = len(self._state)
        if not races:
            return (f"racedep: ok — {n_attrs} shared-attribute site(s) "
                    "observed, no empty-lockset access")
        lines = [f"racedep: {len(races)} data race(s) — attribute(s) "
                 "accessed by >=2 threads with an empty lockset:"]
        for r in races:
            lines.append(
                f"  {r.cls}.{r.attr} — threads {', '.join(r.threads)} "
                f"({r.instances} instance(s))"
            )
            lines.append(f"    first (exclusive) access: {r.owner_site} "
                         f"[thread {'/'.join(t for t in r.threads)}]")
            lines.append(f"    sharing {r.sharing.kind}: {r.sharing.site} "
                         f"[thread {r.sharing.thread}] holding "
                         f"{list(r.sharing.locks) or 'no locks'}")
            for fr in r.sharing.stack[-4:]:
                lines.append(f"      {fr}")
            if r.write is not r.sharing:
                lines.append(f"    unprotected write: {r.write.site} "
                             f"[thread {r.write.thread}] holding "
                             f"{list(r.write.locks) or 'no locks'}")
                for fr in r.write.stack[-4:]:
                    lines.append(f"      {fr}")
            for name, stack in (r.sharing.lock_stacks + r.write.lock_stacks):
                lines.append(f"      (lock {name} acquired at "
                             f"{stack[-1] if stack else '?'})")
            lines.append(
                f"    fix: guard {r.cls}.{r.attr} with one lock on every "
                f"access, or declare it in {r.cls}._unshared with a comment "
                "explaining why unlocked access is safe (REPRO-R001)"
            )
        return "\n".join(lines)

    def assert_no_races(self) -> None:
        if self.races():
            raise RaceError(self.report())


# -- class instrumentation ----------------------------------------------------


def _should_track(name: str, unshared: frozenset, inst_dict: dict) -> bool:
    if name.startswith("__") or name.startswith("_racedep"):
        return False
    if name in unshared or _LOCK_ATTR_RE.match(name):
        return False
    if _IN_INIT_FLAG in inst_dict:
        return False                      # __init__ publication exemption
    return name in inst_dict              # instance data only, not methods


@contextmanager
def instrument(
    graph: Optional[LockGraph] = None,
    classes: Optional[Sequence[type]] = None,
):
    """Wrap ``classes``' (default: every core threaded class) attribute
    protocol so a :class:`RaceDetector` observes each instance-attribute
    access with the current thread's held-lock set.  Yields the detector;
    callers run ``det.assert_no_races()`` after the workload.

    Compose with :func:`~.lockdep.patched` (pass its graph) so the held
    set reflects the repo's locks::

        with lockdep.patched(name_filter=...) as g:
            with racedep.instrument(g) as det:
                ...workload...
        det.assert_no_races()
    """
    det = RaceDetector(graph)
    targets = tuple(classes) if classes is not None else core_classes()
    saved: List[Tuple[type, Dict[str, Optional[object]]]] = []

    for cls in targets:
        unshared = _unshared_of(cls)
        orig_get = cls.__getattribute__
        orig_set = cls.__setattr__
        orig_init = cls.__init__

        def make(cls=cls, unshared=unshared, orig_get=orig_get,
                 orig_set=orig_set, orig_init=orig_init):
            def __getattribute__(self, name):
                value = orig_get(self, name)
                if name != "__dict__" and _should_track(
                    name, unshared, orig_get(self, "__dict__")
                ):
                    det.note(self, cls, name, READ)
                return value

            def __setattr__(self, name, value):
                orig_set(self, name, value)
                if _should_track(name, unshared,
                                 orig_get(self, "__dict__")):
                    det.note(self, cls, name, WRITE)

            def __init__(self, *a, **kw):
                d = orig_get(self, "__dict__")
                d[_IN_INIT_FLAG] = True
                try:
                    orig_init(self, *a, **kw)
                finally:
                    orig_get(self, "__dict__").pop(_IN_INIT_FLAG, None)

            return __getattribute__, __setattr__, __init__

        wrapped_get, wrapped_set, wrapped_init = make()
        saved.append((cls, {
            "__getattribute__": cls.__dict__.get("__getattribute__"),
            "__setattr__": cls.__dict__.get("__setattr__"),
            "__init__": cls.__dict__.get("__init__"),
        }))
        cls.__getattribute__ = wrapped_get      # type: ignore[assignment]
        cls.__setattr__ = wrapped_set           # type: ignore[assignment]
        cls.__init__ = wrapped_init             # type: ignore[assignment]

    try:
        yield det
    finally:
        for cls, originals in reversed(saved):
            for name, fn in originals.items():
                if fn is None:
                    # the class inherited it: drop our override entirely
                    if name in cls.__dict__:
                        delattr(cls, name)
                else:
                    setattr(cls, name, fn)
