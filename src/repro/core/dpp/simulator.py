"""Analytic DSI fleet simulator (§6, §7.1, Fig. 1/8/9, Tables 8-10).

Scales the byte/cycle coefficients measured from this repo's CPU
implementation (and the paper's published ratios) to fleet-sized hardware:
given a node spec (Table 10) and a model's preprocessing workload, compute
achievable DPP-worker throughput and its binding resource; given trainer
ingest demand (Table 8), compute workers-per-trainer, trainer frontend
utilization (Fig. 8), and the storage/preprocessing/training power split
(Fig. 1).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class NodeSpec:
    """Table 10."""
    name: str
    cores: int
    nic_gbps: float
    memory_gb: float
    mem_bw_gbps: float        # GB/s

    @property
    def mem_bw_per_core(self) -> float:
        return self.mem_bw_gbps / self.cores

    @property
    def nic_bw_per_core_gbps(self) -> float:
        return self.nic_gbps / self.cores


C_V1 = NodeSpec("C-v1", cores=18, nic_gbps=12.5, memory_gb=64, mem_bw_gbps=75)
C_V2 = NodeSpec("C-v2", cores=26, nic_gbps=25.0, memory_gb=64, mem_bw_gbps=92)
C_V3 = NodeSpec("C-v3", cores=36, nic_gbps=25.0, memory_gb=64, mem_bw_gbps=83)
C_SOTA = NodeSpec("C-vSotA", cores=64, nic_gbps=100.0, memory_gb=1024, mem_bw_gbps=205)
NODE_SPECS = {n.name: n for n in (C_V1, C_V2, C_V3, C_SOTA)}


@dataclasses.dataclass(frozen=True)
class ModelWorkload:
    """Per-sample preprocessing coefficients for one RM (calibrated to
    reproduce Table 9 on C-v1).

    ``*_cyc_per_byte`` are CPU cycles per byte of the respective phase input;
    ``mem_traffic_x`` is DRAM bytes moved per byte processed (format
    conversions, copies, TLS ~3x amplification — §6.2/§7.2).
    """
    name: str
    sample_bytes_storage: float       # compressed bytes read per sample
    sample_bytes_raw: float           # decoded bytes per sample (transform RX)
    sample_bytes_tensor: float        # materialized tensor bytes (TX)
    extract_cyc_per_byte: float
    transform_cyc_per_byte: float
    mem_traffic_x: float
    trainer_gbps: float               # Table 8 demand per 8-GPU node (GB/s)
    mem_capacity_per_kqps_gb: float = 0.5

    @property
    def kqps_ratio(self) -> float:
        return 1.0


# Calibrated so C-v1 reproduces Table 9 (kQPS, RX/TX, workers per trainer).
# mem_traffic_x calibrated from Fig. 9 memBW utilization at saturation
# (LLC-miss traffic: transforms 50.4%, extraction 24.9%, net 21.1% — §6.3);
# cycle coefficients calibrated to Table 9 kQPS on C-v1.
RM1 = ModelWorkload(
    "RM1", sample_bytes_storage=0.8e9 / 11623e0, sample_bytes_raw=1.37e9 / 11623,
    sample_bytes_tensor=0.68e9 / 11623,
    extract_cyc_per_byte=8.0, transform_cyc_per_byte=24.9, mem_traffic_x=52.0,
    trainer_gbps=16.50,
)
RM2 = ModelWorkload(
    "RM2", sample_bytes_storage=1.2e9 / 7995, sample_bytes_raw=0.96e9 / 7995,
    sample_bytes_tensor=0.50e9 / 7995,
    extract_cyc_per_byte=10.0, transform_cyc_per_byte=18.0, mem_traffic_x=54.0,
    trainer_gbps=4.69,
)
RM3 = ModelWorkload(
    "RM3", sample_bytes_storage=0.8e9 / 36921, sample_bytes_raw=1.01e9 / 36921,
    sample_bytes_tensor=0.22e9 / 36921,
    extract_cyc_per_byte=6.0, transform_cyc_per_byte=9.0, mem_traffic_x=37.0,
    trainer_gbps=12.00, mem_capacity_per_kqps_gb=1.73,
)
WORKLOADS = {"RM1": RM1, "RM2": RM2, "RM3": RM3}

CPU_GHZ = 2.5


@dataclasses.dataclass
class WorkerThroughput:
    kqps: float
    bound: str
    storage_rx_gbps: float
    transform_rx_gbps: float
    tx_gbps: float
    utilization: Dict[str, float]


def worker_throughput(w: ModelWorkload, node: NodeSpec) -> WorkerThroughput:
    """Max sustainable samples/s for one DPP worker on ``node`` and which
    resource binds (§6.3)."""
    cyc_per_sample = (
        w.sample_bytes_raw * w.extract_cyc_per_byte
        + w.sample_bytes_raw * w.transform_cyc_per_byte
    )
    cpu_qps = node.cores * CPU_GHZ * 1e9 / cyc_per_sample

    # full-duplex NIC at ~80% practical line rate (paper: ~10 of 12.5 Gbps)
    practical = 0.8 * node.nic_gbps / 8 * 1e9
    nic_in_qps = practical / w.sample_bytes_storage
    nic_out_qps = practical / w.sample_bytes_tensor
    nic_qps = min(nic_in_qps, nic_out_qps)

    membw_qps = node.mem_bw_gbps * 1e9 / (w.sample_bytes_raw * w.mem_traffic_x)
    memcap_qps = node.memory_gb / w.mem_capacity_per_kqps_gb * 1e3

    candidates = {
        "cpu": cpu_qps, "nic": nic_qps,
        "mem_bw": membw_qps, "mem_capacity": memcap_qps,
    }
    bound = min(candidates, key=candidates.get)
    qps = candidates[bound]
    return WorkerThroughput(
        kqps=qps / 1e3,
        bound=bound,
        storage_rx_gbps=qps * w.sample_bytes_storage / 1e9,
        transform_rx_gbps=qps * w.sample_bytes_raw / 1e9,
        tx_gbps=qps * w.sample_bytes_tensor / 1e9,
        utilization={k: qps / v for k, v in candidates.items()},
    )


def workers_per_trainer(w: ModelWorkload, node: NodeSpec) -> float:
    """Table 9 rightmost column: workers to feed one 8-GPU trainer node."""
    wt = worker_throughput(w, node)
    return w.trainer_gbps / max(wt.tx_gbps, 1e-9)


def split_over_read_amplification(
    partition_rows: int,
    rows_per_split: int,
    stripe_rows: int,
    split_scoped: bool = True,
    stripe_aligned: bool = True,
) -> float:
    """Rows decoded per row served across one partition's splits.

    ``split_scoped=False`` models a read path where every split re-reads
    and decodes the whole partition: amplification equals the number of
    splits per partition, so adding workers multiplies wasted bytes.
    Split-scoped reads only pay stripe-edge trim waste, and stripe-aligned
    splits eliminate even that (amplification 1.0).
    """
    partition_rows = max(1, partition_rows)
    n_splits = -(-partition_rows // max(1, rows_per_split))
    if not split_scoped:
        return float(n_splits)
    if stripe_aligned or stripe_rows <= 0:
        return 1.0
    decoded = 0
    for s in range(n_splits):
        lo = s * rows_per_split
        hi = min(partition_rows, lo + rows_per_split)
        first = (lo // stripe_rows) * stripe_rows
        last = min(partition_rows, -(-hi // stripe_rows) * stripe_rows)
        decoded += last - first
    return decoded / partition_rows


# ---------------------------------------------------------------------------
# Trainer frontend model (Fig. 8, Table 7)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainerFrontend:
    """2-socket trainer host frontend (§6.2)."""
    cores: int = 56
    nic_gbps: float = 200.0          # 2 x 100G frontend NICs
    mem_bw_gbps: float = 150.0
    # datacenter tax: cycles and DRAM bytes per ingested byte
    load_cyc_per_byte: float = 6.0   # TLS + thrift + memcpy + net stack
    mem_traffic_x: float = 4.0


def trainer_loading_utilization(
    gbps: float, fe: TrainerFrontend = TrainerFrontend()
) -> Dict[str, float]:
    """CPU / memBW / NIC utilization at a given ingest rate (Fig. 8)."""
    cyc = gbps * 1e9 * fe.load_cyc_per_byte
    return {
        "cpu": cyc / (fe.cores * CPU_GHZ * 1e9),
        "mem_bw": gbps * fe.mem_traffic_x / fe.mem_bw_gbps,
        "nic": gbps * 8 / fe.nic_gbps,
    }


def colocated_preprocessing_stall(
    w: ModelWorkload,
    fe: TrainerFrontend = TrainerFrontend(),
    demand_scale: float = 0.19,      # Table 7 used a V100-era 8-GPU node
) -> Dict[str, float]:
    """Table 7: run extract+transform on the trainer host itself and compute
    the resulting GPU stall fraction."""
    demand_qps = demand_scale * w.trainer_gbps * 1e9 / w.sample_bytes_tensor
    cyc_per_sample = w.sample_bytes_raw * (
        w.extract_cyc_per_byte + w.transform_cyc_per_byte
    ) + w.sample_bytes_tensor * fe.load_cyc_per_byte
    cpu_qps = fe.cores * CPU_GHZ * 1e9 / cyc_per_sample
    membw_qps = fe.mem_bw_gbps * 1e9 / (
        w.sample_bytes_raw * w.mem_traffic_x + w.sample_bytes_tensor * fe.mem_traffic_x
    )
    achievable = min(cpu_qps, membw_qps)
    stall = max(0.0, 1.0 - achievable / demand_qps)
    return {
        "gpu_stall_frac": stall,
        "cpu_util": min(1.0, demand_qps / cpu_qps),
        "mem_bw_util": min(1.0, demand_qps / membw_qps),
    }


# ---------------------------------------------------------------------------
# Power model (Fig. 1, §7.5)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PowerSpec:
    trainer_node_W: float = 6500.0        # 8-GPU ZionEX-class node
    dpp_node_W: float = 350.0
    storage_node_W: float = 450.0
    storage_node_MBps: float = 1500.0     # ~30-disk HDD node at coalesced DSI I/O sizes


@dataclasses.dataclass(frozen=True)
class CacheTierSpec:
    """A shared DRAM/flash cache tier in front of the HDD fleet (§7.2).

    ``hit_frac`` is the fraction of storage-read **bytes** the tier absorbs
    — feed it a byte-weighted measurement such as
    ``WorkerMetrics.cache_served_frac``, not the request-count
    ``StripeCache.hit_rate`` (sub-range hits and whole-stripe misses have
    very different sizes).  Cache nodes serve far more MB/s per watt than
    HDD storage nodes, which is where the IOPS/W win comes from.
    """
    hit_frac: float
    tier_node_W: float = 75.0             # flash cache node (NVMe + host share)
    tier_node_MBps: float = 6000.0


def dsi_power_split(
    w: ModelWorkload,
    n_trainers: int,
    node: NodeSpec = C_V1,
    power: PowerSpec = PowerSpec(),
    storage_amplification: float = 1.0,   # over-read already in byte ratios
    cache: Optional[CacheTierSpec] = None,
) -> Dict[str, float]:
    """Fig. 1: storage/preprocessing/training power split for one job.
    With a ``CacheTierSpec``, the hit fraction of read traffic moves from
    HDD storage nodes to (cheaper-per-byte-served) cache-tier nodes."""
    n_workers = workers_per_trainer(w, node) * n_trainers
    storage_MBps = w.trainer_gbps * 1e3 * n_trainers * (
        w.sample_bytes_storage / w.sample_bytes_tensor
    ) * storage_amplification
    cache_MBps = 0.0
    if cache is not None:
        cache_MBps = storage_MBps * cache.hit_frac
        storage_MBps -= cache_MBps
    n_storage = storage_MBps / power.storage_node_MBps
    p = {
        "training_W": n_trainers * power.trainer_node_W,
        "preprocessing_W": n_workers * power.dpp_node_W,
        "storage_W": n_storage * power.storage_node_W,
    }
    if cache is not None:
        p["cache_W"] = cache_MBps / cache.tier_node_MBps * cache.tier_node_W
    total = sum(p.values())
    p.update({k.replace("_W", "_frac"): v / total for k, v in list(p.items())})
    return p
