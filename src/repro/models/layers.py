"""Shared neural-net building blocks (pure functions over param dicts)."""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.distributed.context import constrain
from repro.models.common import ModelConfig, ParamSpec


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_spec(dim: int) -> ParamSpec:
    return ParamSpec((dim,), ("embed",), dtype=jnp.float32, init="ones")


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)                     # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                        # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU)
# ---------------------------------------------------------------------------


def mlp_specs(d_model: int, d_ff: int, dtype: Any) -> Dict[str, ParamSpec]:
    return {
        "wi_gate": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype, "scaled"),
        "wi_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype, "scaled"),
        "wo": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype, "scaled"),
    }


def mlp(params: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    gate = jnp.einsum("...d,df->...f", x, params["wi_gate"])
    up = jnp.einsum("...d,df->...f", x, params["wi_up"])
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    hidden = constrain(hidden, ("batch", "seq", "mlp"))
    out = jnp.einsum("...f,fd->...d", hidden, params["wo"])
    return constrain(out, ("batch", "seq", None))


# ---------------------------------------------------------------------------
# Embedding + logits
# ---------------------------------------------------------------------------


def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    specs = {
        "tok": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), cfg.param_dtype),
    }
    if not cfg.tie_embeddings:
        specs["out"] = ParamSpec(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), cfg.param_dtype, "scaled"
        )
    return specs


def embed_tokens(params: Dict[str, jax.Array], tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(params["tok"], tokens, axis=0).astype(cfg.compute_dtype)
    return constrain(x, ("batch", "seq", None))


def output_logits(params: Dict[str, jax.Array], x: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = params["tok"].T if cfg.tie_embeddings else params["out"]
    return jnp.einsum("...d,dv->...v", x, w.astype(cfg.compute_dtype))


def chunked_softmax_xent(
    params: Dict[str, jax.Array],
    x: jax.Array,
    labels: jax.Array,
    cfg: ModelConfig,
    mask: Optional[jax.Array] = None,
) -> jax.Array:
    """Cross-entropy over (B, S, d_model) activations without materializing
    the full (B, S, vocab) logits: scan over sequence chunks.

    The 128k-163k vocabularies of the assigned archs make full-logit
    materialization the dominant activation-memory term; chunking bounds it
    at (B, chunk, vocab_shard).
    """
    b, s, d = x.shape
    chunk = min(cfg.logit_chunk, s)
    if s % chunk:
        chunk = s  # fall back for odd smoke shapes
    n = s // chunk
    w = (params["tok"].T if cfg.tie_embeddings else params["out"]).astype(cfg.compute_dtype)

    xs = x.reshape(b, n, chunk, d).swapaxes(0, 1)            # (n, B, C, d)
    ls = labels.reshape(b, n, chunk).swapaxes(0, 1)          # (n, B, C)
    if mask is None:
        ms = jnp.ones((n, b, chunk), jnp.float32)
    else:
        ms = mask.reshape(b, n, chunk).swapaxes(0, 1).astype(jnp.float32)

    def body(carry, inp):
        xc, lc, mc = inp
        logits = jnp.einsum("bcd,dv->bcv", xc, w).astype(jnp.float32)
        logits = constrain(logits, ("batch", None, "vocab"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mc
        return carry + jnp.sum(nll), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls, ms))
    denom = jnp.maximum(jnp.sum(ms), 1.0)
    return total / denom
