"""DPP data plane: stateless Workers (§3.2.1).

Per split: **extract** (read + decrypt + decompress + decode raw stream
chunks, filter unused features), **transform** (per-feature DAG via
high-performance vectorized kernels), and partially **load** (batch into
ready-to-serve tensors kept in a bounded in-memory buffer).

Splits are processed as a two-stage producer/consumer pipeline: a
producer thread streams one stripe at a time from storage
(``TableReader.iter_stripes``) into a small prefetch buffer while the
consumer overlaps transform + load on the previous stripe.  A split only
reads the stripes covering its own row range — never the whole partition.

Workers account bytes and CPU-time per ETL phase — the measurements behind
Table 9 ("Storage RX / Transform RX / TX") and Fig. 9's cycle breakdown —
plus per-stripe accounting (stripes read, rows decoded vs. rows served)
that makes read over-scoping measurable.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import traceback
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.dpp.master import (
    REPORT_DATA_ERROR,
    DPPMaster,
    SessionSpec,
    Split,
)
from repro.core.engine import make_engine
from repro.core.reader import TableReader
from repro.core.transforms import materialize_dlrm_batch
from repro.core.warehouse import Table
from repro.obs import NULL_TRACER, counter, merge_metrics


@dataclasses.dataclass
class WorkerMetrics:
    storage_rx_bytes: int = counter()  # compressed, served by storage nodes
    cache_rx_bytes: int = counter()    # compressed, served by the stripe cache
    extract_out_bytes: int = counter() # decoded columnar bytes (transform RX)
    tx_bytes: int = counter()          # materialized tensor bytes (transform TX)
    extract_s: float = counter(0.0)
    transform_s: float = counter(0.0)
    load_s: float = counter(0.0)
    splits_done: int = counter()
    data_errors: int = counter()       # splits reported as data_error
    rows_done: int = counter()         # rows served to clients
    stripes_read: int = counter()      # stripes fetched + decoded
    rows_decoded: int = counter()      # stripe rows decoded (incl. trim waste)
    rows_from_cache: int = counter()   # rows served by tensor-cache hits
    # per-engine transform accounting (mirrored from EngineStats — §7.2):
    fused_features: int = counter()            # ops served by fused kernels
    fallback_features: int = counter()         # ops served per-feature
    kernel_launches: int = counter()           # fused + per-feature calls
    transform_fused_s: float = counter(0.0)    # transform_s: fused path
    transform_fallback_s: float = counter(0.0) # transform_s: numpy path
    # per-engine extract accounting (mirrored from DecodeStats):
    extract_fused_s: float = counter(0.0)      # decode: batched-kernel path
    extract_fallback_s: float = counter(0.0)   # decode: per-stream path
    decode_launches: int = counter()           # decode kernel launches
    # per-extent I/O sizes of this worker's stripe fetches (Table 6)
    io_sizes: List[int] = counter(factory=list)

    def merge(self, o: "WorkerMetrics") -> None:
        # summing behavior comes from the per-field counter/gauge
        # metadata, not from blindly adding every dataclass field
        merge_metrics(self, o)

    @property
    def busy_s(self) -> float:
        return self.extract_s + self.transform_s + self.load_s

    @property
    def ingest_rx_bytes(self) -> int:
        """Total compressed bytes ingested, whatever tier served them."""
        return self.storage_rx_bytes + self.cache_rx_bytes

    @property
    def cache_served_frac(self) -> float:
        total = self.ingest_rx_bytes
        return self.cache_rx_bytes / total if total else 0.0

    @property
    def over_read_ratio(self) -> float:
        """Rows decoded per storage-served row (cache hits excluded);
        1.0 = perfectly split-scoped reads."""
        storage_rows = self.rows_done - self.rows_from_cache
        if storage_rows <= 0:
            return 1.0      # nothing read from storage: nothing over-read
        return self.rows_decoded / storage_rows

    @property
    def fused_frac(self) -> float:
        """Fraction of transform op executions served by fused kernels."""
        total = self.fused_features + self.fallback_features
        return self.fused_features / total if total else 0.0

    def cycle_breakdown(self) -> Dict[str, float]:
        t = max(self.busy_s, 1e-9)
        return {
            "extraction": self.extract_s / t,
            "transformation": self.transform_s / t,
            "load_misc": self.load_s / t,
        }


class DPPWorker:
    """Stateless worker: pulls splits, produces tensor batches into a buffer."""

    # deliberately lock-free (REPRO-R001 / racedep allowlist): `alive`
    # and `retired` are GIL-atomic monotone booleans — `alive` is
    # written only by the worker loop on exit, `retired` only by the
    # session monitor on scale-down, and readers tolerate staleness by
    # design (a late read means one extra poll, never lost data);
    # `_thread` is written once by the launching thread in start()
    _unshared = ("alive", "retired", "_thread")

    def __init__(
        self,
        worker_id: str,
        master: DPPMaster,
        table: Table,
        buffer_size: int = 8,
        fail_after_splits: Optional[int] = None,   # fault-injection hook
        tensor_cache=None,                         # shared TensorCache (§7.5)
        prefetch_stripes: int = 2,                 # extract-ahead depth
        tenant: Optional[str] = None,              # owning job for cache shares
        engine="numpy",                            # TransformEngine name/factory
        decode_engine="numpy",                     # DecodeEngine name/factory
        double_buffer: bool = True,                # overlap fetch N+1 / decode N
        tracer=NULL_TRACER,                        # span Tracer (obs layer)
    ):
        self.worker_id = worker_id
        self.master = master
        self.table = table
        self.tenant = tenant
        self.tracer = tracer
        self.spec = master.spec
        self.pipeline = self.spec.pipeline()       # pulled from Master at startup
        # transform stage executor (§7.2): "numpy" = per-feature reference,
        # "pallas" = wave-fused kernel launches; engines are byte-identical
        self.engine = make_engine(engine, self.pipeline)
        # extract-stage decode strategy, same contract (see repro.core.decode)
        self.decode_engine = decode_engine
        self.double_buffer = double_buffer
        self.buffer: "queue.Queue[Dict[str, np.ndarray]]" = queue.Queue(buffer_size)
        self.metrics = WorkerMetrics()
        self.fail_after_splits = fail_after_splits
        self.tensor_cache = tensor_cache
        self.prefetch_stripes = max(1, prefetch_stripes)
        self._stop = threading.Event()
        self._drain = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.alive = True
        self.retired = False        # scale-down victim: don't health-restart

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()

    def drain(self) -> None:
        """Graceful scale-down: stop pulling new splits but finish —
        and deliver — the one in flight.  ``stop()`` by contrast abandons
        undelivered batches (its split is never reported ``ok``, so a
        hard-stopped worker's split is re-dispatched, not lost)."""
        self._drain.set()

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread:
            self._thread.join(timeout)

    # -- main loop ------------------------------------------------------------

    def _run(self) -> None:
        reader = TableReader(
            self.table, list(self.spec.feature_ids), record_popularity=False,
            tenant=self.tenant, tracer=self.tracer,
            decode_engine=self.decode_engine, double_buffer=self.double_buffer,
        )
        while not self._stop.is_set():
            if self._drain.is_set():
                break       # graceful exit: current split already delivered
            if (
                self.fail_after_splits is not None
                and self.metrics.splits_done >= self.fail_after_splits
            ):
                self.alive = False  # simulated crash: stop heartbeating
                return
            split = self.master.get_split(self.worker_id)
            if split is None:
                if self.master.finished:
                    break
                time.sleep(0.01)
                continue
            try:
                batches = self.process_split(reader, split)
            except Exception:
                # Extract/transform raised on this split's bytes.  The
                # worker is fine — only the data is suspect — so report a
                # typed data_error with the traceback (distinct from a
                # lease expiry, which signals a LOST worker) and move on
                # to the next split instead of dying and forcing a
                # restart-and-retry livelock.
                self.metrics.data_errors += 1
                self.master.complete_split(
                    self.worker_id, split.split_id,
                    status=REPORT_DATA_ERROR, error=traceback.format_exc(),
                )
                continue
            delivered = True
            for batch in batches:
                placed = False
                while not self._stop.is_set():
                    try:
                        self.buffer.put(batch, timeout=0.1)
                        placed = True
                        break
                    except queue.Full:
                        # back-pressured on a full buffer, not lost: the
                        # heartbeat extends our lease so the Master never
                        # charges a slow consumer as a dead worker
                        self.master.heartbeat(self.worker_id)
                        continue
                if not placed:
                    delivered = False   # hard-stopped mid-delivery
                    break
            if delivered:
                self.master.complete_split(self.worker_id, split.split_id)
            # else: no ok report — the lease lapses and the split is
            # re-dispatched rather than marked done with dropped batches
        self.alive = False

    # -- ETL -------------------------------------------------------------------

    def process_split(self, reader: TableReader, split: Split):
        """Extract + transform + batch one split; returns tensor minibatches.

        Two-stage pipeline: a producer thread streams the split's stripes
        from storage into a bounded prefetch queue; this (consumer) thread
        overlaps transform + load on already-extracted stripes.  Batch
        boundaries are identical to a monolithic read: full ``batch_size``
        chunks over the split's rows, one partial batch at the end.
        """
        meta = self.table.partitions[split.partition]

        if self.tensor_cache is not None:
            from repro.core.dpp.tensor_cache import TensorCache

            # generation-aware key: a partition rewrite bumps
            # ``meta.generation``, so post-rewrite splits can never be
            # served the pre-rewrite preprocessed tensors
            key = TensorCache.key(self.spec, split, meta.generation)
            cached = self.tensor_cache.get(key)
            if cached is not None:
                self.metrics.splits_done += 1
                self.metrics.rows_done += split.row_end - split.row_start
                self.metrics.rows_from_cache += split.row_end - split.row_start
                return cached

        t_split0 = time.perf_counter()
        prefetch: "queue.Queue" = queue.Queue(self.prefetch_stripes)
        abort = threading.Event()   # consumer died: let the producer exit

        def _put(item) -> bool:
            while not abort.is_set():
                try:
                    prefetch.put(item, timeout=0.1)
                    return True
                except queue.Full:
                    continue
            return False

        def _produce() -> None:
            try:
                t0 = time.perf_counter()
                for sr in reader.iter_stripes(meta, split.row_start, split.row_end):
                    t1 = time.perf_counter()
                    if not _put((sr, t1 - t0)):
                        return
                    t0 = time.perf_counter()
                _put((_EOS, 0.0))
            except BaseException as e:  # surface extraction failures
                _put((e, 0.0))

        producer = threading.Thread(target=_produce, daemon=True)
        producer.start()

        m = self.metrics
        bs = self.spec.batch_size
        split_labeled: Optional[bool] = None   # first stripe sets the law
        out: List[Dict[str, np.ndarray]] = []
        # transformed stripes awaiting batch emission: (env, labels, rows).
        # Concatenated once per emission, not once per stripe, so carry rows
        # are not re-copied for every stripe that arrives.
        pending: List[Tuple[Dict[str, Any], Optional[np.ndarray], int]] = []
        pending_rows = 0
        n_served = 0

        def _emit(env, labels, start, stop):
            sub_env = _slice_env(env, start, stop)
            tensors = materialize_dlrm_batch(
                sub_env,
                self.spec.dense_keys,
                self.spec.sparse_keys,
                self.spec.max_ids_per_feature,
                labels=labels[start:stop] if labels is not None else None,
            )
            out.append(tensors)

        def _drain(final: bool) -> None:
            nonlocal pending, pending_rows, n_served
            if pending_rows == 0 or (not final and pending_rows < bs):
                return
            env = _concat_envs([p[0] for p in pending])
            labels = _concat_labels(pending)
            start = 0
            while pending_rows - start >= bs:
                _emit(env, labels, start, start + bs)
                start += bs
            if final and start < pending_rows:
                _emit(env, labels, start, pending_rows)
                start = pending_rows
            n_served += start
            if start < pending_rows:
                pending = [(
                    _slice_env(env, start, pending_rows),
                    labels[start:pending_rows] if labels is not None else None,
                    pending_rows - start,
                )]
            else:
                pending = []
            pending_rows -= start

        try:
            while True:
                item, extract_dt = prefetch.get()
                if item is _EOS:
                    break
                if isinstance(item, BaseException):
                    raise item
                sr = item
                # long splits must not look like lost workers mid-ETL
                self.master.heartbeat(self.worker_id)
                m.extract_s += extract_dt
                m.storage_rx_bytes += sr.bytes_from_storage
                m.cache_rx_bytes += sr.bytes_from_cache
                m.stripes_read += 1
                m.rows_decoded += sr.rows_decoded
                m.io_sizes.extend(sr.io_sizes)
                m.extract_out_bytes += sr.batch.nbytes()

                t2 = time.perf_counter()
                env = self.engine.run(sr.batch)
                t3 = time.perf_counter()
                m.transform_s += t3 - t2
                # engine counters are cumulative per exclusive engine, so a
                # straight mirror keeps the worker metric cumulative too
                es = self.engine.stats
                if self.tracer.enabled:
                    # before the mirror below, m still holds the previous
                    # cumulative per-path seconds — the difference is this
                    # stripe's fused/fallback attribution
                    self._trace_transform(t2, t3, es, m, split.split_id)
                m.fused_features = es.fused_features
                m.fallback_features = es.fallback_features
                m.kernel_launches = es.kernel_launches
                m.transform_fused_s = es.fused_s
                m.transform_fallback_s = es.fallback_s

                # per-SPLIT label uniformity, checked at stripe arrival:
                # the _concat_labels guard below only sees one drain window
                # at a time, so a label transition landing exactly on a
                # batch-aligned boundary would slip through it silently
                stripe_labeled = sr.batch.labels is not None
                if split_labeled is None:
                    split_labeled = stripe_labeled
                elif stripe_labeled != split_labeled:
                    raise ValueError(
                        "mixed labeled/unlabeled stripes within one split: "
                        f"stripe at rows [{sr.row_start}, {sr.row_end}) is "
                        f"{'labeled' if stripe_labeled else 'unlabeled'} but "
                        "the split started "
                        f"{'labeled' if split_labeled else 'unlabeled'}"
                    )
                pending.append((env, sr.batch.labels, sr.batch.num_rows))
                pending_rows += sr.batch.num_rows
                _drain(final=False)
                t_load = time.perf_counter()
                m.load_s += t_load - t3
                if self.tracer.enabled:
                    self.tracer.record(
                        "load.materialize", t3, t_load,
                        tenant=self.tenant or "", worker=self.worker_id,
                        split=split.split_id,
                    )
        except BaseException:
            abort.set()   # unblock the producer; it exits without a consumer
            raise

        producer.join()
        # decode-engine counters are cumulative per exclusive reader, so a
        # straight mirror (like the transform mirror above) keeps the
        # worker metric cumulative; done once the producer is quiescent
        ds = reader.decode.stats
        m.extract_fused_s = ds.fused_s
        m.extract_fallback_s = ds.fallback_s
        m.decode_launches = ds.kernel_launches
        t4 = time.perf_counter()
        _drain(final=True)
        t_load = time.perf_counter()
        m.load_s += t_load - t4
        if self.tracer.enabled:
            self.tracer.record(
                "load.materialize", t4, t_load,
                tenant=self.tenant or "", worker=self.worker_id,
                split=split.split_id,
            )

        if self.tensor_cache is not None:
            self.tensor_cache.put(key, out, cpu_s=time.perf_counter() - t_split0)

        m.tx_bytes += sum(sum(a.nbytes for a in b.values()) for b in out)
        m.splits_done += 1
        m.rows_done += n_served
        return out

    def _trace_transform(self, t0: float, t1: float, es, m: WorkerMetrics,
                         split_id: int) -> None:
        """Record this stripe's transform interval, partitioned into
        fused/fallback spans by the engine's per-path second deltas
        (``m`` must still hold the pre-mirror cumulative values)."""
        d_fused = es.fused_s - m.transform_fused_s
        d_fallback = es.fallback_s - m.transform_fallback_s
        labels = dict(tenant=self.tenant or "", worker=self.worker_id,
                      split=split_id)
        total = d_fused + d_fallback
        if total <= 0.0:
            self.tracer.record("transform.fallback", t0, t1, **labels)
            return
        cut = t0 + (t1 - t0) * (d_fused / total)
        if d_fused > 0.0:
            self.tracer.record("transform.fused", t0, cut, **labels)
        if d_fallback > 0.0:
            self.tracer.record("transform.fallback", cut, t1, **labels)

    # -- serving to clients ------------------------------------------------------

    def get_batch(self, timeout: float = 0.5) -> Optional[Dict[str, np.ndarray]]:
        try:
            return self.buffer.get(timeout=timeout)
        except queue.Empty:
            return None

    @property
    def buffered(self) -> int:
        return self.buffer.qsize()


_EOS = object()   # end-of-stripes sentinel for the prefetch queue


def _concat_envs(envs: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Row-concatenate transform environments (pending stripes, in order)."""
    from repro.core.schema import SparseColumn, concat_sparse_columns

    if len(envs) == 1:
        return envs[0]
    out: Dict[str, Any] = {}
    for k, v0 in envs[0].items():
        if isinstance(v0, SparseColumn):
            out[k] = concat_sparse_columns([e[k] for e in envs])
        else:
            out[k] = np.concatenate([e[k] for e in envs], axis=0)
    return out


def _concat_labels(
    pending: List[Tuple[Dict[str, Any], Optional[np.ndarray], int]]
) -> Optional[np.ndarray]:
    has_labels = [labels is not None for _, labels, _ in pending]
    if not any(has_labels):
        return None
    if not all(has_labels):
        # fabricating zeros for the unlabeled stripes would silently
        # corrupt training targets — a split must be uniformly labeled
        raise ValueError(
            "mixed labeled/unlabeled stripes within one split: "
            f"{sum(has_labels)}/{len(has_labels)} stripes carry labels"
        )
    if len(pending) == 1:
        return pending[0][1]
    return np.concatenate([labels for _, labels, _ in pending])


def _slice_env(env: Dict[str, Any], start: int, stop: int) -> Dict[str, Any]:
    from repro.core.schema import SparseColumn

    out = {}
    for k, v in env.items():
        if isinstance(v, SparseColumn):
            off = v.offsets[start: stop + 1]
            out[k] = SparseColumn(
                offsets=off - off[0],
                values=v.values[off[0]: off[-1]],
                scores=v.scores[off[0]: off[-1]] if v.scores is not None else None,
            )
        else:
            out[k] = v[start:stop]
    return out
