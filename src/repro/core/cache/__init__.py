from repro.core.cache.dedup import CacheKey, DedupIndex, DedupStats, stripe_digest
from repro.core.cache.stripe_cache import (
    DRAM_TIER,
    FLASH_TIER,
    CacheLookup,
    StripeCache,
    TierStats,
    iops_per_watt,
)
