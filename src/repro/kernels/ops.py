"""Public kernel API: jit'd wrappers that pick the Pallas TPU kernel on TPU
and fall back to interpret mode (CPU validation) or the jnp oracle."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.bucketize import bucketize as _bucketize_pallas

# the fused oracle is hot enough (whole transform waves) to deserve XLA
# compilation rather than eager per-op dispatch
_fused_ref = jax.jit(ref.fused_transform)
from repro.kernels.decode import dense_unpack as _dense_unpack_pallas
from repro.kernels.decode import ragged_gather as _ragged_gather_pallas
from repro.kernels.decode import xor_decrypt as _xor_pallas
from repro.kernels.embedding_bag import embedding_bag as _embag_pallas
from repro.kernels.flash_attention import flash_attention as _flash_pallas
from repro.kernels.fused_transform import fused_transform as _fused_pallas
from repro.kernels.sigrid_hash import sigrid_hash as _sigrid_pallas
from repro.kernels.ssd_chunk import ssd_chunk_forward as _ssd_pallas

# the decode oracles run whole-stripe batches per call — worth XLA
# compilation for the off-TPU fused path, like the transform oracle
_xor_ref = jax.jit(ref.xor_decrypt)
_dense_unpack_ref = jax.jit(ref.dense_unpack)
_ragged_gather_ref = jax.jit(ref.ragged_gather)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sigrid_hash(ids, salt: int, max_value: int, *, use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _sigrid_pallas(ids, salt, max_value, interpret=not _on_tpu())
    return ref.sigrid_hash(ids, salt, max_value)


def bucketize(values, borders, *, use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _bucketize_pallas(values, borders, interpret=not _on_tpu())
    return ref.bucketize(values, borders)


def fused_transform(ids, op_codes, param0, param1, borders=None, *,
                    block_rows: int = 256, block_cols: int = 512,
                    use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _fused_pallas(
            ids, op_codes, param0, param1, borders,
            block_rows=block_rows, block_cols=block_cols,
            interpret=not _on_tpu(),
        )
    return _fused_ref(ids, op_codes, param0, param1, borders)


def xor_decrypt(words, *, use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _xor_pallas(words, interpret=not _on_tpu())
    return _xor_ref(words)


def dense_unpack(bitmap_words, values, *, use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _dense_unpack_pallas(bitmap_words, values,
                                    interpret=not _on_tpu())
    return _dense_unpack_ref(bitmap_words, values)


def ragged_gather(src, idx, shift, *, use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _ragged_gather_pallas(src, idx, shift,
                                     interpret=not _on_tpu())
    return _ragged_gather_ref(src, idx, shift)


def embedding_bag(table, ids, mask, *, mode: str = "mean",
                  use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _embag_pallas(table, ids, mask, mode=mode,
                             interpret=not _on_tpu())
    return ref.embedding_bag(table, ids, mask, mode=mode)


def flash_attention(q, k, v, *, causal: bool = True, use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _flash_pallas(q, k, v, causal=causal, interpret=not _on_tpu())
    return ref.flash_attention(q, k, v, causal=causal)


def ssd_chunk_forward(x, dt, a, b_, c_, *, chunk: int = 256,
                      use_pallas: Optional[bool] = None):
    use = _on_tpu() if use_pallas is None else use_pallas
    if use:
        return _ssd_pallas(x, dt, a, b_, c_, chunk=chunk, interpret=not _on_tpu())
    return ref.ssd_chunk_forward(x, dt, a, b_, c_)
