"""deepseek-v2-236b — MLA (kv_lora=512) + MoE 160e top-6, 2 shared
[arXiv:2405.04434]."""
import dataclasses
from repro.models.common import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    head_dim=128,
    d_ff=1536,
    vocab_size=102400,
    mla=MLAConfig(
        q_lora_rank=1536, kv_lora_rank=512,
        qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    ),
    moe=MoEConfig(
        num_experts=160, top_k=6, d_ff=1536,
        num_shared_experts=2, shared_d_ff=3072,
    ),
)

SMOKE = dataclasses.replace(
    CONFIG,
    name="deepseek-smoke",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=64,
    vocab_size=512,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=64, num_shared_experts=1, shared_d_ff=64),
    remat=False,
)
