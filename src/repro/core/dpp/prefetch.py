"""Client-side prefetch of uncached segments (ISSUE 3 tentpole).

Table 7's data-stall metric is dominated by cold reads at the head of a
session and whenever a worker's extract falls behind the trainer.  The
planner overlaps that warehouse I/O with training: it peeks at the
Master's upcoming (not-yet-leased) splits, plans their reads, and — using
``plan_reads``' ``bytes_cached_planned`` — issues background fills for
**only the segments the shared ``StripeCache`` does not already hold**.
By the time a worker leases the split, its stripes are DRAM hits and the
storage latency has been paid off the critical path.

Fills fan out over a small thread pool (one split per thread), mirroring
how a production client keeps several storage round-trips in flight.
``DPPClient.get_batch`` pokes the planner whenever it stalls, so a
starving trainer immediately accelerates warming instead of waiting for
the next poll tick.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import List, Optional, Sequence, Tuple

from repro.core.dpp.master import DPPMaster, Split
from repro.core.reader import COALESCE_WINDOW, plan_reads
from repro.core.warehouse import Table
from repro.obs import counter


@dataclasses.dataclass
class PrefetchMetrics:
    plans: int = counter()                # splits planned
    splits_warmed: int = counter()        # splits with at least one fill issued
    bytes_fetched: int = counter()        # storage bytes pulled ahead of workers
    bytes_already_cached: int = counter() # planned bytes the cache already held
    pokes: int = counter()                # stall-triggered wakeups from clients


class PrefetchPlanner:
    """Background cache warmer for a session's upcoming splits."""

    # deliberately lock-free (REPRO-R001 / racedep allowlist): `depth` is
    # a GIL-atomic int the monitor thread retunes while the planner loop
    # reads it per iteration (a stale read costs one tick of lag, never
    # correctness); `_thread` is written once by the launching thread
    _unshared = ("depth", "_thread")

    def __init__(
        self,
        table: Table,
        master: DPPMaster,
        feature_ids: Sequence[int],
        tenant: Optional[str] = None,
        depth: int = 4,
        fanout: int = 4,
        coalesce_window: int = COALESCE_WINDOW,
        interval_s: float = 0.01,
    ):
        self.table = table
        self.master = master
        self.feature_ids = list(feature_ids)
        self.tenant = tenant
        self.depth = max(1, depth)
        self.fanout = max(1, fanout)
        self.coalesce_window = coalesce_window
        self.interval_s = interval_s
        self.metrics = PrefetchMetrics()
        # split id -> path generation at warm time: a partition rewrite
        # bumps the generation and invalidates the cached bytes, so its
        # splits must become warmable again, not skipped forever
        self._warmed: dict = {}
        self._stop = threading.Event()
        self._wake = threading.Event()
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------

    def start(self) -> None:
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        self._wake.set()

    def join(self, timeout: Optional[float] = None) -> None:
        if self._thread:
            self._thread.join(timeout)

    def poke(self) -> None:
        """A client stalled: warm the next splits now, not at the next tick."""
        self.metrics.pokes += 1
        self._wake.set()

    def set_depth(self, depth: int) -> None:
        """Autoscale knob (ISSUE 4): how many upcoming splits to keep
        cache-warm.  Deeper under stall pressure, shallower when the
        trainer is saturated and warming ahead only wastes cache space."""
        self.depth = max(1, int(depth))

    # -- planning ------------------------------------------------------------

    def _uncached_extents(self, split: Split) -> Tuple[str, List[Tuple[int, int]]]:
        """The (offset, length) segments of ``split``'s planned reads that
        the stripe cache does not hold — the only bytes worth fetching."""
        meta = self.table.partitions[split.partition]
        cache = self.table.fs.cache
        plan = plan_reads(
            meta.footer, self.feature_ids, self.coalesce_window,
            row_start=split.row_start, row_end=split.row_end,
            cache=cache, path=meta.path,
        )
        self.metrics.plans += 1
        self.metrics.bytes_already_cached += plan.bytes_cached_planned
        if plan.bytes_cached_planned >= plan.bytes_planned:
            return meta.path, []
        uncached: List[Tuple[int, int]] = []
        for off, ln in plan.extents:
            for seg_off, seg_len in cache.dedup.segments(meta.path, off, ln):
                if not cache.peek(cache.resolve(meta.path, seg_off, seg_len)):
                    uncached.append((seg_off, seg_len))
        return meta.path, uncached

    def prefetch_once(self) -> int:
        """Warm up to ``depth`` upcoming splits; returns bytes fetched.
        Safe to call synchronously (tests) or from the planner thread."""
        cache = self.table.fs.cache
        if cache is None:
            return 0
        work: List[Tuple[str, List[Tuple[int, int]]]] = []
        for split in self.master.peek_pending(self.depth):
            if self._stop.is_set():
                continue
            gen = cache.dedup.generation(self.table.partitions[split.partition].path)
            if self._warmed.get(split.split_id) == gen:
                continue
            self._warmed[split.split_id] = gen
            path, uncached = self._uncached_extents(split)
            if uncached:
                work.append((path, uncached))
        if not work:
            return 0
        fetched = [0] * len(work)

        def _fill(i: int, path: str, extents: List[Tuple[int, int]]) -> None:
            # read_extents_ex admits every missed segment into the shared
            # cache; hits (someone else fetched first) cost nothing
            io = self.table.fs.read_extents_ex(path, extents, tenant=self.tenant)
            fetched[i] = io.storage_bytes

        threads = [
            threading.Thread(target=_fill, args=(i, p, ex), daemon=True)
            for i, (p, ex) in enumerate(work)
        ]
        for group in range(0, len(threads), self.fanout):
            chunk = threads[group: group + self.fanout]
            for t in chunk:
                t.start()
            for t in chunk:
                t.join()
        total = sum(fetched)
        self.metrics.bytes_fetched += total
        self.metrics.splits_warmed += sum(1 for f in fetched if f > 0)
        return total

    def _loop(self) -> None:
        while not self._stop.is_set():
            if self.master.finished:
                return
            if self.prefetch_once() == 0:
                self._wake.wait(self.interval_s)
                self._wake.clear()
