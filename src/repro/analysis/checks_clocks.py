"""Clock-injection rule (REPRO-C001).

The TTL, lease, and heartbeat logic in ``core/cache`` and ``core/dpp`` is
deterministic under test ONLY because absolute time is read through an
injected ``clock=`` callable (``StripeCache(ttl_s=..., clock=fake)``,
``DPPMaster(clock=fake)``).  A direct ``time.time()`` /
``time.monotonic()`` call in those packages silently re-couples the logic
to the wall clock and turns every TTL/lease test flaky.

Banned: *calls* to ``time.time`` / ``time.monotonic`` anywhere under
``src/repro/core/cache/`` and ``src/repro/core/dpp/``.

Allowed:

  * referencing ``time.time``/``time.monotonic`` without calling it —
    that is exactly how the injected default is declared
    (``clock: Callable[[], float] = time.monotonic``);
  * ``time.sleep`` (waiting is not reading the clock);
  * ``time.perf_counter`` (duration measurement for metrics, never used
    in control-flow deadlines that tests need to fake).
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.core import (
    CheckContext,
    Finding,
    attr_chain,
    checker,
    enclosing_symbol,
    rule,
)

C001 = rule("REPRO-C001",
            "direct time.time()/time.monotonic() call in a clock-injected "
            "package (core/cache, core/dpp)")

_SCOPES = ("src/repro/core/cache/", "src/repro/core/dpp/")
_BANNED = {("time", "time"), ("time", "monotonic")}


class _Scan(ast.NodeVisitor):
    def __init__(self):
        self.stack: List[ast.AST] = []
        self.hits: List[tuple] = []   # (line, dotted-name, symbol)

    def _push(self, node):
        self.stack.append(node)
        self.generic_visit(node)
        self.stack.pop()

    visit_ClassDef = visit_FunctionDef = visit_AsyncFunctionDef = _push

    def visit_Call(self, node: ast.Call) -> None:
        chain = attr_chain(node.func)
        if chain and tuple(chain) in _BANNED:
            self.hits.append(
                (node.lineno, ".".join(chain), enclosing_symbol(self.stack))
            )
        self.generic_visit(node)


@checker("clock-injection")
def check_clocks(ctx: CheckContext):
    findings: List[Finding] = []
    for mod in ctx.src_modules():
        if not mod.rel.startswith(_SCOPES):
            continue
        scan = _Scan()
        scan.visit(mod.tree)
        for line, name, sym in scan.hits:
            findings.append(Finding(
                C001, mod.rel, line,
                f"calls {name}() directly; inject a `clock=` callable "
                "(reference the time function only as the default value)",
                sym,
            ))
    return findings
