"""Online preprocessing transformations (Table 11) + the per-feature DAG.

These are the CPU (numpy) implementations DPP Workers execute — the
production path of §6.3/§6.4.  The Pallas kernels in ``repro.kernels``
are the accelerated-DSI exploration of §7.2 and are validated against
these semantics.

Transform classes (§6.4): dense normalization (Logit, BoxCox, Onehot,
Clamp, GetLocalHour), sparse normalization (SigridHash, FirstX,
PositiveModulus, MapId, Enumerate, ComputeScore), and feature generation
(Bucketize, NGram, Cartesian, IdListTransform) — the latter being the
~75%-of-cycles class.  Sampling is row-level.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.schema import ColumnBatch, SparseColumn

Column = Union[np.ndarray, SparseColumn]


# ---------------------------------------------------------------------------
# Hashing (SigridHash) — 32-bit multiply-xor-shift mix, vectorized
# ---------------------------------------------------------------------------


def _mix64(x: np.ndarray) -> np.ndarray:
    x = x.astype(np.uint64, copy=True)
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(30)
        x *= np.uint64(0xBF58476D1CE4E5B9)
        x ^= x >> np.uint64(27)
        x *= np.uint64(0x94D049BB133111EB)
        x ^= x >> np.uint64(31)
    return x


def _mix32(x: np.ndarray) -> np.ndarray:
    """The canonical SigridHash mixer: two multiply-xor-shift rounds on
    uint32 lanes.  Bit-for-bit identical to ``repro.kernels.ref._mix64``
    and the Pallas ``_hash_u32`` — TPU vector lanes are 32-bit, so the
    numpy reference and the fused kernel share one hash so engines can
    produce byte-identical batches (and TensorCache entries stay
    engine-agnostic)."""
    x = x.astype(np.uint32, copy=True)
    with np.errstate(over="ignore"):
        x ^= x >> np.uint32(16)
        x *= np.uint32(0x7FEB352D)
        x ^= x >> np.uint32(15)
        x *= np.uint32(0x846CA68B)
        x ^= x >> np.uint32(16)
    return x


def sigrid_hash(col: SparseColumn, salt: int, max_value: int) -> SparseColumn:
    """Hash-normalize a sparse id list into [0, max_value).

    Ids and salt are truncated to their low 32 bits before mixing (the
    lane-width contract shared with ``kernels.fused_transform``);
    ``max_value`` must be in ``[1, 2**32)``.
    """
    h = _mix32(col.values.astype(np.uint32) ^ np.uint32(salt & 0xFFFFFFFF))
    return SparseColumn(
        offsets=col.offsets,
        values=(h % np.uint32(max_value)).astype(np.int64),
        scores=col.scores,
    )


# ---------------------------------------------------------------------------
# Dense normalization
# ---------------------------------------------------------------------------


def boxcox(col: np.ndarray, lmbda: float = 0.5) -> np.ndarray:
    x = np.maximum(np.nan_to_num(col, nan=0.0), 0.0) + 1.0
    if abs(lmbda) < 1e-9:
        return np.log(x).astype(np.float32)
    return ((x ** lmbda - 1.0) / lmbda).astype(np.float32)


def logit(col: np.ndarray, eps: float = 1e-6) -> np.ndarray:
    p = np.clip(np.nan_to_num(col, nan=0.5), eps, 1.0 - eps)
    return np.log(p / (1.0 - p)).astype(np.float32)


def clamp(col: np.ndarray, lo: float, hi: float) -> np.ndarray:
    return np.clip(np.nan_to_num(col, nan=0.0), lo, hi).astype(np.float32)


def onehot(col: np.ndarray, borders: np.ndarray) -> np.ndarray:
    """Dense value -> one-hot over len(borders)+1 buckets: (rows, bins)."""
    idx = np.searchsorted(borders, np.nan_to_num(col, nan=0.0))
    out = np.zeros((len(col), len(borders) + 1), np.float32)
    out[np.arange(len(col)), idx] = 1.0
    return out


def get_local_hour(col: np.ndarray, tz_offset_s: int = 0) -> np.ndarray:
    ts = np.nan_to_num(col, nan=0.0).astype(np.int64) + tz_offset_s
    return ((ts // 3600) % 24).astype(np.float32)


def bucketize(col: np.ndarray, borders: np.ndarray) -> SparseColumn:
    """Feature generation: dense value -> categorical bucket id (sparse).

    Comparisons happen in float32 (borders and values are both cast), the
    pipeline-wide dense precision — and the lane dtype of the fused Pallas
    kernel, which must reproduce these semantics bit-for-bit.
    """
    b32 = np.asarray(borders, np.float32)
    v32 = np.nan_to_num(col, nan=0.0).astype(np.float32)
    idx = np.searchsorted(b32, v32).astype(np.int64)
    n = len(col)
    return SparseColumn(
        offsets=np.arange(n + 1, dtype=np.int64), values=idx, scores=None
    )


# ---------------------------------------------------------------------------
# Sparse normalization / generation
# ---------------------------------------------------------------------------


def firstx(col: SparseColumn, x: int) -> SparseColumn:
    lengths = np.minimum(np.diff(col.offsets), x)
    new_off = np.zeros(len(col.offsets), np.int64)
    np.cumsum(lengths, out=new_off[1:])
    idx = _ragged_gather(col.offsets[:-1], lengths)
    return SparseColumn(
        offsets=new_off,
        values=col.values[idx],
        scores=col.scores[idx] if col.scores is not None else None,
    )


def _ragged_gather(starts: np.ndarray, lengths: np.ndarray) -> np.ndarray:
    """Indices selecting, for each row i, ``lengths[i]`` consecutive source
    elements beginning at ``starts[i]``."""
    total = int(lengths.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    ends = np.cumsum(lengths)
    within = np.arange(total) - np.repeat(ends - lengths, lengths)
    return np.repeat(starts, lengths) + within


def positive_modulus(col: SparseColumn, m: int) -> SparseColumn:
    v = np.mod(np.mod(col.values, m) + m, m)
    return SparseColumn(offsets=col.offsets, values=v, scores=col.scores)


def map_id(col: SparseColumn, mapping: Dict[int, int], default: int = 0) -> SparseColumn:
    keys = np.asarray(sorted(mapping), np.int64)
    vals = np.asarray([mapping[k] for k in sorted(mapping)], np.int64)
    idx = np.searchsorted(keys, col.values)
    idx = np.clip(idx, 0, len(keys) - 1)
    hit = keys[idx] == col.values if len(keys) else np.zeros(len(col.values), bool)
    out = np.where(hit, vals[idx] if len(keys) else 0, default)
    return SparseColumn(offsets=col.offsets, values=out.astype(np.int64), scores=col.scores)


def enumerate_ids(col: SparseColumn) -> SparseColumn:
    """Python enumerate(): replace each id with its position in the list."""
    lengths = np.diff(col.offsets)
    total = int(lengths.sum())
    pos = np.arange(total) - np.repeat(col.offsets[:-1], lengths)
    return SparseColumn(offsets=col.offsets, values=pos.astype(np.int64), scores=col.scores)


def compute_score(col: SparseColumn, scale: float = 1.0, bias: float = 0.0) -> SparseColumn:
    sc = col.scores if col.scores is not None else np.ones(len(col.values), np.float32)
    return SparseColumn(
        offsets=col.offsets, values=col.values,
        scores=(sc * scale + bias).astype(np.float32),
    )


def id_list_intersection(a: SparseColumn, b: SparseColumn) -> SparseColumn:
    """IdListTransform: per-row intersection of two id lists."""
    rows = a.rows
    out_vals: List[np.ndarray] = []
    lengths = np.zeros(rows, np.int64)
    for i in range(rows):
        inter = np.intersect1d(a.row(i), b.row(i), assume_unique=False)
        out_vals.append(inter)
        lengths[i] = len(inter)
    off = np.zeros(rows + 1, np.int64)
    np.cumsum(lengths, out=off[1:])
    vals = np.concatenate(out_vals) if out_vals else np.zeros(0, np.int64)
    return SparseColumn(offsets=off, values=vals.astype(np.int64), scores=None)


def cartesian(a: SparseColumn, b: SparseColumn, mod: int = 1 << 31) -> SparseColumn:
    """Cartesian product of two sparse features, ids combined by hashing."""
    rows = a.rows
    la = np.diff(a.offsets)
    lb = np.diff(b.offsets)
    lengths = la * lb
    off = np.zeros(rows + 1, np.int64)
    np.cumsum(lengths, out=off[1:])
    total = int(off[-1])
    vals = np.zeros(total, np.int64)
    p = 0
    for i in range(rows):
        va, vb = a.row(i), b.row(i)
        if len(va) and len(vb):
            prod = (va[:, None] * np.int64(1000003) + vb[None, :]).reshape(-1)
            vals[p: p + len(prod)] = prod
            p += len(prod)
    h = _mix64(vals.astype(np.uint64)) % np.uint64(mod)
    return SparseColumn(offsets=off, values=h.astype(np.int64), scores=None)


def ngram(col: SparseColumn, n: int = 2, mod: int = 1 << 31) -> SparseColumn:
    """n-grams over each row's id list (feature generation)."""
    rows = col.rows
    lengths = np.maximum(np.diff(col.offsets) - (n - 1), 0)
    off = np.zeros(rows + 1, np.int64)
    np.cumsum(lengths, out=off[1:])
    total = int(off[-1])
    vals = np.zeros(total, np.uint64)
    starts = np.repeat(col.offsets[:-1], lengths)
    within = np.arange(total) - np.repeat(off[:-1], lengths)
    base = starts + within
    acc = np.zeros(total, np.uint64)
    with np.errstate(over="ignore"):
        for j in range(n):
            acc = acc * np.uint64(1000003) + col.values[base + j].astype(np.uint64)
    h = _mix64(acc) % np.uint64(mod)
    return SparseColumn(offsets=off, values=h.astype(np.int64), scores=None)


def sampling(batch: ColumnBatch, rate: float, seed: int = 0) -> ColumnBatch:
    """Row-level random sampling."""
    rng = np.random.default_rng(seed)
    keep = np.where(rng.random(batch.num_rows) < rate)[0]
    # build a contiguous subset via repeated row slicing on sorted indices
    dense = {k: v[keep] for k, v in batch.dense.items()}
    sparse = {}
    for k, c in batch.sparse.items():
        lengths = np.diff(c.offsets)[keep]
        off = np.zeros(len(keep) + 1, np.int64)
        np.cumsum(lengths, out=off[1:])
        idx = _ragged_gather(c.offsets[keep], lengths)
        sparse[k] = SparseColumn(
            offsets=off,
            values=c.values[idx],
            scores=c.scores[idx] if c.scores is not None else None,
        )
    return ColumnBatch(
        num_rows=len(keep),
        dense=dense,
        sparse=sparse,
        labels=batch.labels[keep] if batch.labels is not None else None,
    )


# ---------------------------------------------------------------------------
# Transform DAG ("compiled PyTorch module" analogue)
# ---------------------------------------------------------------------------

OP_CLASS = {
    "Logit": "dense_norm", "BoxCox": "dense_norm", "Onehot": "dense_norm",
    "Clamp": "dense_norm", "GetLocalHour": "dense_norm",
    "SigridHash": "sparse_norm", "FirstX": "sparse_norm",
    "PositiveModulus": "sparse_norm", "MapId": "sparse_norm",
    "Enumerate": "sparse_norm", "ComputeScore": "sparse_norm",
    "Bucketize": "feature_gen", "NGram": "feature_gen",
    "Cartesian": "feature_gen", "IdListTransform": "feature_gen",
    "Sampling": "row",
}


@dataclasses.dataclass(frozen=True)
class TransformSpec:
    op: str
    inputs: Tuple[str, ...]          # env keys (feature ids are "f<id>")
    output: str
    params: Tuple[Tuple[str, Any], ...] = ()

    @property
    def kwargs(self) -> Dict[str, Any]:
        return dict(self.params)


_OPS: Dict[str, Callable[..., Column]] = {
    "SigridHash": sigrid_hash,
    "BoxCox": boxcox,
    "Logit": logit,
    "Clamp": clamp,
    "Onehot": onehot,
    "GetLocalHour": get_local_hour,
    "Bucketize": bucketize,
    "FirstX": firstx,
    "PositiveModulus": positive_modulus,
    "MapId": map_id,
    "Enumerate": enumerate_ids,
    "ComputeScore": compute_score,
    "IdListTransform": id_list_intersection,
    "Cartesian": cartesian,
    "NGram": ngram,
}


class TransformPipeline:
    """Topologically-ordered transform DAG over a ColumnBatch.

    The "session spec" a DPP Master ships to Workers: feature projection +
    per-feature transform DAGs + output materialization plan.
    """

    def __init__(self, specs: Sequence[TransformSpec]):
        self.specs = list(specs)

    def required_features(self) -> List[int]:
        fids = set()
        produced = {s.output for s in self.specs}
        for s in self.specs:
            for inp in s.inputs:
                if inp.startswith("f") and inp not in produced:
                    fids.add(int(inp[1:]))
        return sorted(fids)

    def __call__(self, batch: ColumnBatch) -> Dict[str, Column]:
        env: Dict[str, Column] = {}
        for fid, col in batch.dense.items():
            env[f"f{fid}"] = col
        for fid, col in batch.sparse.items():
            env[f"f{fid}"] = col
        for s in self.specs:
            fn = _OPS[s.op]
            args = [env[i] for i in s.inputs]
            env[s.output] = fn(*args, **s.kwargs)
        return env

    def op_class_histogram(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for s in self.specs:
            c = OP_CLASS.get(s.op, "other")
            out[c] = out.get(c, 0) + 1
        return out


def materialize_dlrm_batch(
    env: Dict[str, Column],
    dense_keys: Sequence[str],
    sparse_keys: Sequence[str],
    max_ids: int,
    labels: Optional[np.ndarray] = None,
) -> Dict[str, np.ndarray]:
    """Batch transformed features into the DLRM tensor format (load phase)."""
    rows = None
    dense_cols = []
    for k in dense_keys:
        c = np.nan_to_num(np.asarray(env[k], np.float32), nan=0.0)
        if c.ndim > 1:
            c = c[:, 0]
        rows = len(c)
        dense_cols.append(c)
    dense = (
        np.stack(dense_cols, axis=1) if dense_cols else np.zeros((rows or 0, 0), np.float32)
    )

    sp_ids = []
    sp_mask = []
    for k in sparse_keys:
        col: SparseColumn = env[k]  # type: ignore
        rows = col.rows
        ids = np.zeros((rows, max_ids), np.int64)
        mask = np.zeros((rows, max_ids), np.float32)
        lengths = np.minimum(np.diff(col.offsets), max_ids)
        take = _ragged_gather(col.offsets[:-1], lengths)
        r_idx = np.repeat(np.arange(rows), lengths)
        c_idx = np.arange(len(take)) - np.repeat(
            np.concatenate([[0], np.cumsum(lengths)[:-1]]), lengths
        )
        ids[r_idx, c_idx] = col.values[take]
        mask[r_idx, c_idx] = 1.0
        sp_ids.append(ids)
        sp_mask.append(mask)

    out = {
        "dense": dense.astype(np.float32),
        "sparse_ids": (
            np.stack(sp_ids, axis=1) if sp_ids else np.zeros((rows or 0, 0, max_ids), np.int64)
        ).astype(np.int32),
        "sparse_mask": (
            np.stack(sp_mask, axis=1) if sp_mask else np.zeros((rows or 0, 0, max_ids), np.float32)
        ),
    }
    if labels is not None:
        out["label"] = labels.astype(np.float32)
    return out


def default_dlrm_pipeline(
    dense_fids: Sequence[int],
    sparse_fids: Sequence[int],
    hash_size: int = 100_000,
    firstx: int = 32,
    n_derived: int = 0,
) -> TransformPipeline:
    """A production-shaped pipeline: normalize every dense + sparse feature,
    derive ``n_derived`` generated features (NGram / Cartesian / Bucketize —
    the expensive class)."""
    specs: List[TransformSpec] = []
    for i, fid in enumerate(dense_fids):
        op = ["BoxCox", "Logit", "Clamp"][i % 3]
        params = (("lo", -10.0), ("hi", 10.0)) if op == "Clamp" else ()
        specs.append(TransformSpec(op, (f"f{fid}",), f"d{fid}", params))
    for fid in sparse_fids:
        specs.append(
            TransformSpec("FirstX", (f"f{fid}",), f"t{fid}", (("x", firstx),))
        )
        specs.append(
            TransformSpec(
                "SigridHash", (f"t{fid}",), f"s{fid}",
                (("salt", fid), ("max_value", hash_size)),
            )
        )
    sf = list(sparse_fids)
    for j in range(n_derived):
        if j % 3 == 0 and len(sf) >= 1:
            specs.append(
                TransformSpec(
                    "NGram", (f"s{sf[j % len(sf)]}",), f"g{j}",
                    (("n", 2), ("mod", hash_size)),
                )
            )
        elif j % 3 == 1 and len(sf) >= 2:
            specs.append(
                TransformSpec(
                    "Cartesian",
                    (f"s{sf[j % len(sf)]}", f"s{sf[(j + 1) % len(sf)]}"),
                    f"g{j}",
                    (("mod", hash_size),),
                )
            )
        elif dense_fids:
            d = dense_fids[j % len(dense_fids)]
            specs.append(
                TransformSpec(
                    "Bucketize", (f"f{d}",), f"g{j}",
                    (("borders", np.linspace(-3, 3, 63)),),
                )
            )
    return TransformPipeline(specs)
