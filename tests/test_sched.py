"""Deterministic interleaving explorer (`repro.analysis.sched`) suite.

Mirrors ``test_lockdep.py``: seeded-bug fixtures prove detection (a
check-then-act lost update, a lock-order deadlock schedule), clean
fixtures prove correct code passes every schedule, pruning tests pin the
sleep-set reduction (commuting ops collapse to one schedule, conflicting
ops stay fully enumerated), and the gate's real control-plane scenarios
must pass exhaustively — the runtime analogue of an empty baseline.
"""
from __future__ import annotations

import queue
import threading

import pytest

from repro.analysis import sched as sc
from repro.analysis.sched import (
    SCENARIOS,
    Exploration,
    Scenario,
    ScheduleError,
    explore,
    yield_point,
)


class LostUpdate(Scenario):
    """Seeded atomicity violation: read, window, write-back — two
    concurrent bumps can both read 0 and store 1."""

    name = "seeded lost update"

    def setup(self):
        return {"n": 0}

    def threads(self, state):
        def bump():
            v = state["n"]
            yield_point("n")         # the check-then-act window
            state["n"] = v + 1

        return [bump, bump]

    def check(self, state):
        assert state["n"] == 2, f"lost update: n={state['n']}"


class AtomicBump(Scenario):
    """Same shape with the window closed by a lock: passes everywhere."""

    name = "atomic locked bump"

    def setup(self):
        return {"n": 0, "lk": threading.Lock()}

    def threads(self, state):
        def bump():
            with state["lk"]:
                v = state["n"]
                yield_point("n")     # window still exists, but lock held
                state["n"] = v + 1

        return [bump, bump]

    def check(self, state):
        assert state["n"] == 2


class SeededDeadlock(Scenario):
    name = "seeded lock-order inversion"

    def setup(self):
        return {"a": threading.Lock(), "b": threading.Lock()}

    def threads(self, state):
        def ab():
            with state["a"]:
                yield_point("inv")
                with state["b"]:
                    pass

        def ba():
            with state["b"]:
                yield_point("inv")
                with state["a"]:
                    pass

        return [ab, ba]


class Commuting(Scenario):
    """Ops on distinct resources: one equivalence class, one schedule."""

    name = "commuting yields"

    def setup(self):
        return {}

    def threads(self, state):
        return [lambda: yield_point("x"), lambda: yield_point("y")]


class Conflicting(Scenario):
    """Two threads, two conflicting ops each: all 6 interleavings of
    (a1 a2) vs (b1 b2) are distinct and must be visited."""

    name = "conflicting yields"

    def setup(self):
        return {"order": []}

    def threads(self, state):
        def t(tag):
            def run():
                yield_point("shared")
                state["order"].append(tag)
                yield_point("shared")
                state["order"].append(tag)

            return run

        return [t("a"), t("b")]


class BoundedQueue(Scenario):
    """put/get enabledness on a maxsize-1 queue: the explorer must never
    deadlock (put blocked on full + get blocked on empty cannot coexist)
    and FIFO order must hold in every schedule."""

    name = "bounded queue handoff"

    def setup(self):
        return {"q": queue.Queue(maxsize=1), "got": []}

    def threads(self, state):
        def producer():
            state["q"].put(1)
            state["q"].put(2)

        def consumer():
            state["got"].append(state["q"].get())
            state["got"].append(state["q"].get())

        return [producer, consumer]

    def check(self, state):
        assert state["got"] == [1, 2], state["got"]
        assert state["q"].qsize() == 0


ANY = lambda s: True   # noqa: E731  — track every lock the fixture builds


def test_seeded_lost_update_is_caught():
    with pytest.raises(ScheduleError) as ei:
        explore(LostUpdate())
    msg = str(ei.value)
    assert "lost update" in msg
    # the exact failing schedule is part of the report
    assert "yield(n)" in msg and "T0" in msg and "T1" in msg


def test_locked_bump_passes_every_schedule():
    res = explore(AtomicBump(), name_filter=ANY)
    assert isinstance(res, Exploration)
    assert res.exhausted and res.schedules >= 2


def test_seeded_deadlock_schedule_is_found():
    with pytest.raises(ScheduleError) as ei:
        explore(SeededDeadlock(), name_filter=ANY)
    msg = str(ei.value)
    assert "DEADLOCK" in msg and "acquire" in msg


def test_sleep_sets_prune_commuting_ops():
    res = explore(Commuting())
    assert res.schedules == 1       # one Mazurkiewicz class
    assert res.pruned >= 1          # siblings abandoned as equivalent
    assert res.exhausted


def test_conflicting_ops_fully_enumerated():
    res = explore(Conflicting())
    assert res.schedules == 6       # C(4,2): all distinct interleavings
    assert res.exhausted


def test_bounded_queue_enabledness():
    res = explore(BoundedQueue())
    assert res.exhausted and res.schedules >= 1


def test_max_schedules_truncates():
    res = explore(Conflicting(), max_schedules=2)
    assert not res.exhausted
    assert res.schedules + res.pruned == 2


def test_patching_is_restored():
    real_lock, real_rlock = threading.Lock, threading.RLock
    real_put, real_get = queue.Queue.put, queue.Queue.get
    explore(Commuting())
    assert threading.Lock is real_lock
    assert threading.RLock is real_rlock
    assert queue.Queue.put is real_put
    assert queue.Queue.get is real_get


def test_yield_point_is_noop_outside_runs():
    yield_point("anything")   # must not raise or block


# -- the CI gate's real scenarios (runtime empty baseline) --------------------


@pytest.mark.parametrize("scenario", SCENARIOS, ids=lambda s: s.name)
def test_control_plane_scenario_holds_everywhere(scenario):
    res = explore(scenario)
    assert res.exhausted, f"{scenario.name} truncated: {res}"
    assert res.schedules >= 1


def test_cli_runs_all_scenarios(capsys):
    assert sc.main(["-q"]) == 0
    assert sc.main(["--list"]) == 0
    out = capsys.readouterr().out
    assert len(out.strip().splitlines()) == len(SCENARIOS)
    assert sc.main(["-k", "no-such-scenario"]) == 2
