import pytest

from repro.core.dpp.simulator import (
    C_V1, C_V2, C_SOTA, RM1, RM2, RM3, WORKLOADS,
    colocated_preprocessing_stall, dsi_power_split,
    trainer_loading_utilization, worker_throughput, workers_per_trainer,
)

# Paper Table 9 targets
TABLE9 = {
    "RM1": dict(kqps=11.6, rx=0.8, trx=1.37, tx=0.68, wpt=24.2),
    "RM2": dict(kqps=8.0, rx=1.2, trx=0.96, tx=0.50, wpt=9.4),
    "RM3": dict(kqps=36.9, rx=0.8, trx=1.01, tx=0.22, wpt=55.2),
}


@pytest.mark.parametrize("name", list(TABLE9))
def test_table9_reproduction(name):
    w = WORKLOADS[name]
    t = worker_throughput(w, C_V1)
    ref = TABLE9[name]
    assert abs(t.kqps - ref["kqps"]) / ref["kqps"] < 0.08
    assert abs(t.storage_rx_gbps - ref["rx"]) / ref["rx"] < 0.08
    assert abs(t.transform_rx_gbps - ref["trx"]) / ref["trx"] < 0.08
    assert abs(t.tx_gbps - ref["tx"]) / ref["tx"] < 0.08
    assert abs(workers_per_trainer(w, C_V1) - ref["wpt"]) / ref["wpt"] < 0.12


def test_bottleneck_identities():
    # §6.3: RM1 cpu(+memBW), RM2 NIC on C-v1, RM3 memory capacity
    assert worker_throughput(RM1, C_V1).bound == "cpu"
    assert worker_throughput(RM1, C_V1).utilization["mem_bw"] > 0.85
    assert worker_throughput(RM2, C_V1).bound == "nic"
    assert worker_throughput(RM3, C_V1).bound == "mem_capacity"
    # §6.3: on C-v2 RM2 shifts to memory bandwidth
    assert worker_throughput(RM2, C_V2).bound == "mem_bw"


def test_table7_colocated_stall():
    r = colocated_preprocessing_stall(RM1)
    assert 0.45 < r["gpu_stall_frac"] < 0.7      # paper: 56%
    assert r["cpu_util"] > 0.85                   # paper: 92%


def test_fig8_loading_scaling_monotone():
    u1 = trainer_loading_utilization(5.0)
    u2 = trainer_loading_utilization(16.5)
    assert all(u2[k] > u1[k] for k in u1)
    assert u2["cpu"] < 1.0 and u2["nic"] < 1.0


def test_fig1_dsi_power_can_exceed_training():
    p1 = dsi_power_split(RM1, 16)
    assert p1["preprocessing_frac"] + p1["storage_frac"] > 0.5
    p2 = dsi_power_split(RM2, 16)
    assert p2["training_frac"] > p1["training_frac"]   # diverse across models
