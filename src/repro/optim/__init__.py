from repro.optim.optimizers import (
    OptimizerConfig,
    adamw_init,
    adamw_update,
    global_norm,
    clip_by_global_norm,
    compress_grads,
    decompress_grads,
    wsd_schedule,
)
