import time

import numpy as np
import pytest

from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.dpp import AutoScaler, DPPMaster, DPPSession, SessionSpec
from repro.core.schema import make_schema
from repro.core.transforms import default_dlrm_pipeline
from repro.core.warehouse import Warehouse


def _table(n_partitions=2, rows=1024):
    s = make_schema("dpt", 20, 6, seed=0)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(n_partitions, DataGenConfig(rows_per_partition=rows, seed=1),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
    return t


def _spec(t, **kw):
    dense = t.schema.dense_ids[:6]
    sparse = t.schema.sparse_ids[:3]
    pipe = default_dlrm_pipeline(dense, sparse, hash_size=500)
    d = dict(
        table=t.schema.name, partitions=tuple(t.partitions),
        feature_ids=tuple(pipe.required_features()),
        transform_specs=tuple(pipe.specs),
        batch_size=256, rows_per_split=256,
        dense_keys=tuple(f"d{f}" for f in dense),
        sparse_keys=tuple(f"s{f}" for f in sparse),
        max_ids_per_feature=8,
    )
    d.update(kw)
    return SessionSpec(**d)


def test_session_one_epoch_exact_batches():
    t = _table()
    sess = DPPSession(_spec(t), t, n_workers=2)
    batches = sess.run_to_completion(timeout_s=60)
    assert len(batches) == 2 * 1024 // 256
    assert batches[0]["dense"].shape == (256, 6)
    total_rows = sum(b["label"].shape[0] for b in batches)
    assert total_rows == 2 * 1024


def test_worker_failure_restart_completes_epoch():
    t = _table()
    # the ONLY worker dies after 2 splits; the monitor must restart it or the
    # epoch cannot complete
    sess = DPPSession(_spec(t), t, n_workers=1, lease_s=1.0, monitor_interval_s=0.1)
    sess.workers[0].fail_after_splits = 2
    batches = sess.run_to_completion(timeout_s=60)
    total_rows = sum(b["label"].shape[0] for b in batches)
    assert total_rows == 2 * 1024
    assert len(sess.restart_events) >= 1


def test_master_checkpoint_restore_resumes():
    t = _table()
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows)
    s1 = m.get_split("w0"); m.complete_split("w0", s1.split_id)
    s2 = m.get_split("w0"); m.complete_split("w0", s2.split_id)
    ckpt = m.checkpoint()
    m2 = DPPMaster.restore(ckpt, rows)
    done, total = m2.progress
    assert done == 2
    seen = set()
    while True:
        s = m2.get_split("w1")
        if s is None:
            break
        seen.add(s.split_id)
        m2.complete_split("w1", s.split_id)
    assert s1.split_id not in seen and s2.split_id not in seen
    assert m2.finished


def test_straggler_lease_redispatch():
    t = _table(n_partitions=1, rows=512)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=0.05)
    s = m.get_split("slow")
    time.sleep(0.1)   # lease expires; straggler mitigation re-dispatches
    s2 = m.get_split("fast")
    assert s2.split_id == s.split_id


def test_forget_worker_releases_leases():
    t = _table(n_partitions=1, rows=512)
    spec = _spec(t)
    rows = {p: t.partitions[p].num_rows for p in spec.partitions}
    m = DPPMaster(spec, rows, lease_s=100.0)
    s = m.get_split("dead")
    m.forget_worker("dead")
    s2 = m.get_split("alive")
    assert s2.split_id == s.split_id


def test_autoscaler_decisions():
    a = AutoScaler(max_workers=64)
    assert a.decide(4, buffered_batches=0, mean_cpu_util=0.9, stalls_since_last=3) > 0
    assert a.decide(4, buffered_batches=100, mean_cpu_util=0.1, stalls_since_last=0) < 0
    assert a.decide(4, buffered_batches=10, mean_cpu_util=0.6, stalls_since_last=0) == 0
    # respects max
    assert a.decide(64, buffered_batches=0, mean_cpu_util=1.0, stalls_since_last=5) == 0


def test_autoscaling_session_scales_out():
    t = _table(n_partitions=2, rows=2048)
    sess = DPPSession(_spec(t), t, n_workers=1, auto_scale=True,
                      monitor_interval_s=0.05, max_workers=4)
    batches = sess.run_to_completion(timeout_s=90)
    total_rows = sum(b["label"].shape[0] for b in batches)
    assert total_rows == 2 * 2048
