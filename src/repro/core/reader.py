"""Selective table reader: feature projection -> I/O plan -> decoded columns.

Implements the read-path co-design ladder of Table 12:
  * map files: whole-stripe reads (baseline; massive over-read),
  * flattened files: per-feature stream reads (tiny I/Os, HDD seek cliff),
  * **coalesced reads (CR)**: merge selected stream extents whose gap keeps
    the merged I/O within ``coalesce_window`` bytes (1.25 MiB, §7.5) —
    over-reading the skipped bytes to amortize seeks,
  * feature reordering (FR) happens at write time (warehouse) and shows up
    here as fewer over-read bytes inside each coalesced window.

Every read returns both the decoded columns and an I/O accounting record
(bytes used vs read, I/O size distribution — Tables 5 and 6).

Reads are **split-scoped**: ``plan_reads`` takes an optional row range and
prunes to the stripes that overlap it, so a DPP split only fetches and
decodes its own stripes instead of re-reading the whole partition.
``TableReader.iter_stripes`` streams one stripe at a time for
producer/consumer pipelines; ``read_rows`` materializes an exact row range.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import dwrf
from repro.core.decode import make_decode_engine
from repro.core.schema import ColumnBatch
from repro.core.tectonic import ExtentRead, IOStats, TectonicFS
from repro.core.warehouse import PartitionMeta, Table
from repro.obs import NULL_TRACER

COALESCE_WINDOW = int(1.25 * 1024 * 1024)   # §7.5


@dataclasses.dataclass
class ReadPlan:
    extents: List[Tuple[int, int]]                      # (offset, len) I/Os
    wanted: List[Tuple[int, int, dwrf.StreamInfo]]      # (stripe_idx, fid, stream)
    bytes_wanted: int
    bytes_planned: int
    stripe_indices: List[int] = dataclasses.field(default_factory=list)
    stripes_total: int = 0
    bytes_cached_planned: int = 0      # planned bytes the stripe cache holds

    @property
    def over_read_ratio(self) -> float:
        return self.bytes_planned / max(self.bytes_wanted, 1)


@dataclasses.dataclass
class ReadResult:
    batch: ColumnBatch
    bytes_read: int
    bytes_used: int
    io_sizes: List[int]
    feature_bytes: Dict[int, int]
    stripes_read: int = 0
    stripes_total: int = 0
    rows_decoded: int = 0
    bytes_from_cache: int = 0    # of bytes_read, served by the stripe cache
    bytes_from_storage: int = 0


@dataclasses.dataclass
class StripeRead:
    """One decoded stripe, trimmed to the requested row range."""

    stripe_index: int
    row_start: int               # absolute rows covered after trimming
    row_end: int
    batch: ColumnBatch
    bytes_read: int
    bytes_used: int
    rows_decoded: int            # stripe rows decoded (>= row_end - row_start)
    bytes_from_cache: int = 0    # of bytes_read, served by the stripe cache
    bytes_from_storage: int = 0
    # per-extent I/O sizes of this stripe's fetch (Table 6 distribution —
    # previously only read_rows reported these, so streaming consumers
    # lost the size histogram entirely)
    io_sizes: List[int] = dataclasses.field(default_factory=list)


def _trim_stripe(
    part: ColumnBatch, stripe: dwrf.StripeInfo, lo: int, hi: int
) -> Tuple[ColumnBatch, int, int]:
    """Drop stripe-edge rows outside [lo, hi); returns the trimmed batch and
    the kept row range relative to the stripe."""
    t0 = max(lo - stripe.row_start, 0)
    t1 = min(hi - stripe.row_start, stripe.num_rows)
    if t0 > 0 or t1 < stripe.num_rows:
        part = part.slice_rows(t0, t1)
    return part, t0, t1


def stripes_overlapping(
    footer: dwrf.DwrfFooter,
    row_start: Optional[int] = None,
    row_end: Optional[int] = None,
) -> List[int]:
    """Indices of stripes intersecting [row_start, row_end)."""
    lo = 0 if row_start is None else row_start
    hi = footer.num_rows if row_end is None else row_end
    return [
        si for si, st in enumerate(footer.stripes)
        if st.row_start < hi and st.row_start + st.num_rows > lo
    ]


def _coalesce_extents(
    streams: Sequence[dwrf.StreamInfo], coalesce_window: int
) -> List[Tuple[int, int]]:
    """Merge offset-sorted stream extents whose span fits the window."""
    extents: List[Tuple[int, int]] = []
    for s in streams:
        if (
            coalesce_window
            and extents
            and s.offset + s.length - extents[-1][0] <= coalesce_window
        ):
            off, ln = extents[-1]
            extents[-1] = (off, max(ln, s.offset + s.length - off))
        else:
            extents.append((s.offset, s.length))
    return extents


def plan_reads(
    footer: dwrf.DwrfFooter,
    feature_ids: Sequence[int],
    coalesce_window: int = 0,
    include_labels: bool = True,
    row_start: Optional[int] = None,
    row_end: Optional[int] = None,
    cache=None,
    path: Optional[str] = None,
) -> ReadPlan:
    """Build the extent list for a feature projection over one file.

    With a row range, only the stripes overlapping [row_start, row_end)
    are planned — the split-scoped read path.  With a ``StripeCache`` and
    the file's ``path``, each planned extent is probed (non-mutating) and
    ``bytes_cached_planned`` reports how much the cache would serve —
    extent reads then only hit storage on miss.
    """
    want_f = set(feature_ids)
    stripe_idx = stripes_overlapping(footer, row_start, row_end)
    wanted: List[Tuple[int, int, dwrf.StreamInfo]] = []
    for si in stripe_idx:
        stripe = footer.stripes[si]
        if footer.flattened:
            for s in stripe.streams:
                if s.fid in want_f or (include_labels and s.kind == "labels"):
                    wanted.append((si, s.fid, s))
        else:
            # map encoding: must read the monolithic map streams; labels
            # streams still follow the projection flag, like the
            # flattened branch (they were unconditionally planned before,
            # inflating bytes_wanted for label-free projections)
            for s in stripe.streams:
                if not include_labels and s.kind == "labels":
                    continue
                wanted.append((si, s.fid, s))

    streams = sorted((s for _, _, s in wanted), key=lambda s: s.offset)
    bytes_wanted = sum(s.length for s in streams)
    extents = _coalesce_extents(streams, coalesce_window)
    bytes_planned = sum(l for _, l in extents)
    bytes_cached = 0
    if cache is not None and path is not None:
        # probe at stripe-segment granularity — the cache's storage unit —
        # so window-coalesced extents still report their cached portions
        for off, ln in extents:
            for seg_off, seg_len in cache.dedup.segments(path, off, ln):
                if cache.peek(cache.resolve(path, seg_off, seg_len)):
                    bytes_cached += seg_len
    return ReadPlan(
        extents=extents, wanted=wanted,
        bytes_wanted=bytes_wanted, bytes_planned=bytes_planned,
        stripe_indices=stripe_idx, stripes_total=len(footer.stripes),
        bytes_cached_planned=bytes_cached,
    )


class TableReader:
    """Reads a feature projection from a table's partitions with accounting."""

    def __init__(
        self,
        table: Table,
        feature_ids: Sequence[int],
        coalesce_window: int = COALESCE_WINDOW,
        record_popularity: bool = True,
        tenant: Optional[str] = None,
        tracer=NULL_TRACER,
        decode_engine=None,
        double_buffer: bool = False,
    ):
        self.table = table
        self.feature_ids = list(feature_ids)
        self.coalesce_window = coalesce_window
        self.record_popularity = record_popularity
        # job identity for the stripe cache's per-tenant shares/accounting
        self.tenant = tenant
        self.tracer = tracer
        # stripe decode strategy (name / instance / factory — see
        # repro.core.decode); engines are byte-compatible, so this never
        # changes the batches, only how they are produced
        self.decode = make_decode_engine(decode_engine)
        # overlap stripe N+1's extent fetch with stripe N's decode in
        # iter_stripes (the producer half of the DPP worker)
        self.double_buffer = double_buffer
        self._job_feature_bytes: Dict[int, float] = {}

    def _fetch_streams(
        self, meta: PartitionMeta, plan: ReadPlan
    ) -> Tuple[Dict[int, Dict[Tuple[int, str], bytes]], Dict[int, int], "ExtentRead"]:
        """Execute a plan: fetch extents, slice each wanted stream back out
        of its (possibly merged) extent.  Returns per-stripe raw stream bytes,
        per-feature byte counts, and the cache/storage source accounting."""
        io = self.table.fs.read_extents_ex(
            meta.path, plan.extents, tenant=self.tenant
        )
        extent_map: List[Tuple[int, bytes]] = [
            (off, blob) for (off, _), blob in zip(plan.extents, io.blobs)
        ]
        extent_offsets = np.array([e[0] for e in extent_map])

        per_stripe: Dict[int, Dict[Tuple[int, str], bytes]] = {}
        feature_bytes: Dict[int, int] = {}
        for si, fid, s in plan.wanted:
            ei = int(np.searchsorted(extent_offsets, s.offset, "right") - 1)
            off0, blob = extent_map[ei]
            raw = blob[s.offset - off0: s.offset - off0 + s.length]
            per_stripe.setdefault(si, {})[(s.fid, s.kind)] = raw
            if fid >= 0:
                feature_bytes[fid] = feature_bytes.get(fid, 0) + s.length
        return per_stripe, feature_bytes, io

    def _record_feature_bytes(self, feature_bytes: Dict[int, int]) -> None:
        for fid, nb in feature_bytes.items():
            self._job_feature_bytes[fid] = self._job_feature_bytes.get(fid, 0) + nb

    def read_rows(
        self,
        meta: PartitionMeta,
        row_start: Optional[int] = None,
        row_end: Optional[int] = None,
    ) -> ReadResult:
        """Read exactly [row_start, row_end), fetching only overlapping
        stripes (one coalesced extent batch across those stripes)."""
        footer = meta.footer
        lo = 0 if row_start is None else max(0, row_start)
        hi = footer.num_rows if row_end is None else min(row_end, footer.num_rows)
        plan = plan_reads(
            footer, self.feature_ids, self.coalesce_window,
            row_start=lo, row_end=hi,
            cache=self.table.fs.cache, path=meta.path,
        )
        per_stripe, feature_bytes, io = self._fetch_streams(meta, plan)

        from repro.core.schema import concat_batches

        parts: List[ColumnBatch] = []
        rows_decoded = 0
        for si in sorted(per_stripe):
            stripe = footer.stripes[si]
            with self.tracer.span(
                "extract.decode", tenant=self.tenant or "",
                path=meta.path, stripe=si, engine=self.decode.name,
            ) as sp:
                part = self.decode.decode_stripe(
                    stripe, per_stripe[si], self.feature_ids
                )
                sp.set(rows=part.num_rows)
            rows_decoded += part.num_rows
            part, _, _ = _trim_stripe(part, stripe, lo, hi)
            parts.append(part)
        batch = (
            concat_batches(parts) if parts
            else ColumnBatch(num_rows=0, dense={}, sparse={})
        )

        self._record_feature_bytes(feature_bytes)
        return ReadResult(
            batch=batch,
            bytes_read=plan.bytes_planned,
            bytes_used=plan.bytes_wanted,
            io_sizes=[l for _, l in plan.extents],
            feature_bytes=feature_bytes,
            stripes_read=len(plan.stripe_indices),
            stripes_total=plan.stripes_total,
            rows_decoded=rows_decoded,
            bytes_from_cache=io.cache_bytes,
            bytes_from_storage=io.storage_bytes,
        )

    def iter_stripes(
        self,
        meta: PartitionMeta,
        row_start: Optional[int] = None,
        row_end: Optional[int] = None,
    ) -> Iterator[StripeRead]:
        """Stream one stripe at a time: fetch + decode each overlapping
        stripe's coalesced extents independently instead of materializing
        the whole range.  The producer half of a producer/consumer split."""
        footer = meta.footer
        lo = 0 if row_start is None else max(0, row_start)
        hi = footer.num_rows if row_end is None else min(row_end, footer.num_rows)
        # one footer pass for the whole range, then per-stripe coalescing
        full = plan_reads(footer, self.feature_ids, 0, row_start=lo, row_end=hi)
        by_stripe: Dict[int, List[Tuple[int, int, dwrf.StreamInfo]]] = {}
        for si, fid, s in full.wanted:
            by_stripe.setdefault(si, []).append((si, fid, s))
        plans: List[Tuple[int, ReadPlan]] = []
        for si in full.stripe_indices:
            wanted = by_stripe.get(si, [])
            streams = sorted((s for _, _, s in wanted), key=lambda s: s.offset)
            extents = _coalesce_extents(streams, self.coalesce_window)
            plans.append((si, ReadPlan(
                extents=extents, wanted=wanted,
                bytes_wanted=sum(s.length for s in streams),
                bytes_planned=sum(l for _, l in extents),
                stripe_indices=[si], stripes_total=len(footer.stripes),
            )))

        def _start_fetch(k: int):
            """Kick off plan k's extent fetch on a daemon thread (the
            double-buffer slot: stripe N+1's I/O overlaps stripe N's
            decode).  Errors surface at join time, on the caller."""
            import threading

            slot: Dict[str, object] = {}

            def run():
                try:
                    slot["res"] = self._fetch_streams(meta, plans[k][1])
                except BaseException as exc:
                    slot["err"] = exc

            th = threading.Thread(
                target=run, name=f"stripe-prefetch-{plans[k][0]}", daemon=True
            )
            th.start()
            return slot, th

        pending = _start_fetch(0) if self.double_buffer and plans else None
        for k, (si, plan) in enumerate(plans):
            if pending is not None:
                slot, th = pending
                th.join()
                # start stripe k+1's fetch before decoding stripe k
                pending = (
                    _start_fetch(k + 1) if k + 1 < len(plans) else None
                )
                if "err" in slot:
                    raise slot["err"]
                per_stripe, feature_bytes, io = slot["res"]
            else:
                per_stripe, feature_bytes, io = self._fetch_streams(meta, plan)
            stripe = footer.stripes[si]
            with self.tracer.span(
                "extract.decode", tenant=self.tenant or "",
                path=meta.path, stripe=si, engine=self.decode.name,
            ) as sp:
                part = self.decode.decode_stripe(
                    stripe, per_stripe.get(si, {}), self.feature_ids
                )
                sp.set(rows=part.num_rows)
            rows_decoded = part.num_rows
            part, t0, t1 = _trim_stripe(part, stripe, lo, hi)
            self._record_feature_bytes(feature_bytes)
            yield StripeRead(
                stripe_index=si,
                row_start=stripe.row_start + t0,
                row_end=stripe.row_start + t1,
                batch=part,
                bytes_read=plan.bytes_planned,
                bytes_used=plan.bytes_wanted,
                rows_decoded=rows_decoded,
                bytes_from_cache=io.cache_bytes,
                bytes_from_storage=io.storage_bytes,
                io_sizes=[l for _, l in plan.extents],
            )

    def read_partition(
        self, meta: PartitionMeta, row_limit: Optional[int] = None
    ) -> ReadResult:
        return self.read_rows(meta, 0, row_limit if row_limit else None)

    def finish_job(self) -> None:
        """Record this job's feature-read footprint into table popularity."""
        if self.record_popularity and self._job_feature_bytes:
            self.table.popularity.record_job(self._job_feature_bytes)
            self._job_feature_bytes = {}

    # -- dataset-level accounting (Tables 3 & 5) ----------------------------

    def projection_stats(self, partitions: Optional[Sequence[int]] = None) -> Dict[str, float]:
        metas = self.table.select_partitions(partitions)
        bytes_total = sum(m.nbytes for m in metas)
        bytes_used = 0
        feats_total = len(self.table.schema.logged_ids)
        for m in metas:
            plan = plan_reads(m.footer, self.feature_ids, 0, include_labels=False)
            bytes_used += plan.bytes_wanted
        return {
            "pct_features_used": 100.0 * len(self.feature_ids) / max(feats_total, 1),
            "pct_bytes_used": 100.0 * bytes_used / max(bytes_total, 1),
            "bytes_total": float(bytes_total),
            "bytes_used": float(bytes_used),
        }
