"""§4: Fig. 4 (combo-job skew), Fig. 5 (utilization peaks), Fig. 6 (regional
demand), Table 2 (feature lifecycle)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core.coordination import (
    ReleaseProcessConfig, combo_duration_skew, daily_utilization,
    regional_demand, simulate, utilization_peak_to_mean,
)
from repro.core.schema import make_schema


def run() -> None:
    cfg = ReleaseProcessConfig(days=180, seed=0)
    jobs = simulate(cfg)
    skew = combo_duration_skew(jobs)
    emit(
        "fig4.combo_job_skew", 0.0,
        f"n={skew['n_jobs']:.0f} p50={skew['p50_days']:.1f}d p95={skew['p95_days']:.1f}d "
        f"max={skew['max_days']:.1f}d killed={skew['killed_frac']:.2f} "
        f"failed={skew['failed_frac']:.2f}",
    )
    util = daily_utilization(jobs, cfg.days)
    emit("fig5.utilization_peak_to_mean", 0.0,
         f"{utilization_peak_to_mean(util):.2f}x (combo windows drive peaks)")
    rd = regional_demand(jobs)
    multi = sum(1 for m in rd.values() if len(m) > 1)
    tot = {m: sum(v.values()) for m, v in rd.items()}
    top = max(tot.values()) / max(min(tot.values()), 1e-9)
    emit("fig6.regional_demand", 0.0,
         f"models={len(rd)} multi_region={multi} demand_spread={top:.0f}x")

    # §7.3: global scheduler bin-packing (storage saved vs replicate-everywhere)
    from repro.core.scheduler import (
        Region, demands_from_release_sim, greedy_colocate,
        replicate_everywhere, replication_report,
    )
    demands = demands_from_release_sim(jobs, {})
    total_peak = sum(d.peak_compute for d in demands)
    regions = [Region(f"R{i}", capacity=total_peak, storage_pb=1e3) for i in range(5)]
    rep = replication_report(
        demands, replicate_everywhere(demands, regions), greedy_colocate(demands, regions)
    )
    emit("sec7_3.scheduler_binpacking", 0.0,
         f"storage_saved={rep['storage_saved_frac']*100:.0f}% "
         f"peak_region_load={rep['max_region_peak_packed']:.0f} "
         f"(baseline {rep['max_region_peak_baseline']:.0f})")

    # Table 2: feature lifecycle over a 6-month window
    schema = make_schema("t2", 400, 60, seed=0)
    rng = np.random.default_rng(1)
    for month in range(6):
        schema.evolve(rng, n_new=120, promote_frac=0.12, deprecate_frac=0.04)
    c = schema.status_counts()
    emit("table2.feature_lifecycle", 0.0,
         " ".join(f"{k}={v}" for k, v in sorted(c.items())))
