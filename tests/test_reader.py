import numpy as np
import pytest

from repro.core import dwrf
from repro.core.datagen import DataGenConfig
from repro.core.reader import (
    COALESCE_WINDOW, TableReader, plan_reads, stripes_overlapping,
)
from repro.core.schema import make_schema
from repro.core.warehouse import Warehouse


@pytest.fixture(scope="module")
def table():
    s = make_schema("rt", 80, 15, seed=1)
    wh = Warehouse()
    t = wh.create_table(s)
    t.generate(2, DataGenConfig(rows_per_partition=1024, seed=2),
               dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256))
    return t


def test_selective_read_decodes_only_projection(table):
    proj = table.schema.logged_ids[:9]
    r = TableReader(table, proj)
    res = r.read_partition(table.partitions[0])
    got = set(res.batch.dense) | set(res.batch.sparse)
    assert got == set(proj) & set(table.schema.logged_ids)
    assert res.batch.labels is not None
    assert res.bytes_used <= res.bytes_read


def test_coalescing_reduces_io_count_and_bounds_window(table):
    proj = table.schema.logged_ids[::7]
    meta = table.partitions[0]
    plan_nc = plan_reads(meta.footer, proj, coalesce_window=0)
    plan_c = plan_reads(meta.footer, proj, coalesce_window=COALESCE_WINDOW)
    assert len(plan_c.extents) <= len(plan_nc.extents)
    assert all(l <= COALESCE_WINDOW for _, l in plan_c.extents)
    assert plan_c.bytes_planned >= plan_c.bytes_wanted
    # same set of wanted streams either way
    assert plan_c.bytes_wanted == plan_nc.bytes_wanted


def test_extents_sorted_disjoint(table):
    proj = table.schema.logged_ids[::5]
    plan = plan_reads(table.partitions[0].footer, proj, coalesce_window=COALESCE_WINDOW)
    prev_end = -1
    for off, ln in plan.extents:
        assert off >= prev_end
        prev_end = off + ln


def test_feature_reordering_reduces_over_read(table):
    proj = sorted(np.random.default_rng(3).choice(
        table.schema.logged_ids, size=10, replace=False).tolist())
    # record popularity from a few jobs so the writer reorders
    for _ in range(3):
        r = TableReader(table, proj)
        r.read_partition(table.partitions[0])
        r.finish_job()
    from repro.core.datagen import generate_partition
    meta_new = table.write_partition(
        50, generate_partition(table.schema, 50, DataGenConfig(rows_per_partition=1024, seed=9)),
        dwrf.DwrfWriterOptions(flattened=True, stripe_rows=256),
    )
    window = 64 * 1024
    plan_old = plan_reads(table.partitions[0].footer, proj, window)
    plan_new = plan_reads(meta_new.footer, proj, window)
    assert plan_new.over_read_ratio <= plan_old.over_read_ratio + 1e-9


def test_io_stats_recorded(table):
    table.fs.reset_stats()
    r = TableReader(table, table.schema.logged_ids[:5])
    r.read_partition(table.partitions[1])
    st_ = table.fs.stats
    assert st_.num_ios > 0 and st_.bytes_read > 0
    pct = st_.percentiles()
    assert pct["p50"] > 0


# -- split-scoped planning (stripe pruning) ----------------------------------


def test_plan_reads_row_range_prunes_stripes(table):
    footer = table.partitions[0].footer
    proj = table.schema.logged_ids[:9]
    full = plan_reads(footer, proj)
    sub = plan_reads(footer, proj, row_start=256, row_end=512)
    assert full.stripe_indices == list(range(len(footer.stripes)))
    assert sub.stripe_indices == stripes_overlapping(footer, 256, 512)
    assert len(sub.stripe_indices) < len(full.stripe_indices)
    assert sub.bytes_planned < full.bytes_planned
    assert sub.bytes_wanted < full.bytes_wanted
    # the pruned plan's streams are a subset of the full plan's
    full_offsets = {s.offset for _, _, s in full.wanted}
    assert all(s.offset in full_offsets for _, _, s in sub.wanted)


def test_stripes_overlapping_boundaries(table):
    footer = table.partitions[0].footer   # 1024 rows, 256-row stripes
    assert stripes_overlapping(footer, 0, 256) == [0]
    assert stripes_overlapping(footer, 256, 257) == [1]
    assert stripes_overlapping(footer, 255, 257) == [0, 1]
    assert stripes_overlapping(footer, 0, 1024) == [0, 1, 2, 3]
    assert stripes_overlapping(footer) == [0, 1, 2, 3]
    assert stripes_overlapping(footer, 512, 512) == []


def test_read_rows_bytes_scale_with_split_not_partition(table):
    proj = table.schema.logged_ids[:9]
    r = TableReader(table, proj)
    meta = table.partitions[0]
    full = r.read_partition(meta)
    quarter = r.read_rows(meta, 0, 256)
    assert quarter.stripes_read == 1 and full.stripes_read == 4
    assert quarter.bytes_read < full.bytes_read / 2
    assert quarter.rows_decoded == 256
